"""Fig. 4 reproduction: average JCT vs number of racks, six wired-only
baselines vs the optimal method with 0/1/2 wireless subchannels.

Paper setting: network factor ρ=0.5, job size from production statistics
(≤10 tasks), wired and wireless rates equal. We report means over seeds per
(M, scheduler) and the fraction of optimal runs proved to optimality within
the time budget (HiGHS/Gurobi-class exactness is solver-budget-bound).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, emit
from repro.core import (
    ProblemInstance,
    g_list_master_schedule,
    g_list_schedule,
    list_schedule,
    partition_schedule,
    random_job,
    random_schedule,
    solve_bnb,
)


def run(n_tasks: int = 8, seeds: int | None = None, time_limit: float = 10.0):
    seeds = seeds if seeds is not None else (12 if FULL else 6)
    racks = (2, 4, 6, 8) if not FULL else (2, 3, 4, 5, 6, 7, 8, 9, 10)
    rows = []
    for M in racks:
        acc: dict[str, list[float]] = {}
        proved = []
        for seed in range(seeds):
            rng = np.random.default_rng(1000 + seed)
            job = random_job(rng, None, n_tasks=n_tasks, rho=0.5)
            inst0 = ProblemInstance(job=job, n_racks=M, n_wireless=0)
            acc.setdefault("random", []).append(
                random_schedule(inst0, np.random.default_rng(seed)).makespan
            )
            acc.setdefault("list", []).append(list_schedule(inst0).makespan)
            acc.setdefault("partition", []).append(
                partition_schedule(inst0).makespan
            )
            acc.setdefault("g_list", []).append(g_list_schedule(inst0).makespan)
            acc.setdefault("g_list_master", []).append(
                g_list_master_schedule(inst0).makespan
            )
            for k in (0, 1, 2):
                inst = ProblemInstance(job=job, n_racks=M, n_wireless=k)
                r = solve_bnb(inst, time_limit=time_limit)
                acc.setdefault(f"optimal_k{k}", []).append(r.makespan)
                if k == 0:
                    proved.append(r.proved_optimal)
        for name, vals in acc.items():
            rows.append((M, name, float(np.mean(vals))))
        base = np.mean(acc["optimal_k0"])
        gain1 = 100 * (1 - np.mean(acc["optimal_k1"]) / base)
        gain2 = 100 * (1 - np.mean(acc["optimal_k2"]) / base)
        emit(
            f"fig4_M{M}",
            0.0,
            f"jct_opt_wired={base:.1f};gain_1wl={gain1:.1f}%;gain_2wl={gain2:.1f}%;"
            f"proved={np.mean(proved):.2f};glist={np.mean(acc['g_list']):.1f};"
            f"random={np.mean(acc['random']):.1f}",
        )
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
