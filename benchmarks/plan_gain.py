"""Scheduler-integration benchmark: gradient-reduction overlap planned by the
paper's joint solver vs greedy overlap vs serial (no overlap), across the
assigned architectures and network provisioning levels (beyond-paper table).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import ARCH_IDS, get_config
from repro.distribution.plan import LinkSpec, backward_profile, plan_gradient_schedule


def run():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        g_secs, g_bytes = backward_profile(cfg, tokens_per_device=4096)
        for aux in (0, 1, 2):
            link = LinkSpec(ici_share=10e9, aux_channels=aux, aux_rate=4e9)
            plan = plan_gradient_schedule(g_secs, g_bytes, link, time_limit=5.0)
            emit(
                f"plan_{arch}_aux{aux}",
                1e6 * plan.t_optimal,
                f"gain_vs_serial={100 * plan.gain_vs_serial:.1f}%;"
                f"gain_vs_greedy={100 * plan.gain_vs_greedy:.2f}%;"
                f"proved={plan.proved_optimal}",
            )


def main():
    run()


if __name__ == "__main__":
    main()
