"""Shared benchmark utilities: CSV emission plus the machine-readable
``BENCH_<name>.json`` trajectory record.

Every ``emit`` call prints the historical ``name,us_per_call,derived``
CSV line *and* appends a structured record to an in-process buffer;
``write_json`` flushes the buffer as a ``BENCH`` schema document so the
perf trajectory (wall-times, JCTs, prune rates) can be tracked across
PRs and uploaded as a CI artifact. ``benchmarks/run.py``,
``benchmarks/solver_scaling.py`` and ``benchmarks/online_serving.py``
expose it via ``--json out.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

import numpy as np

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"

# Structured records accumulated by emit(); flushed by write_json().
RESULTS: list[dict] = []

# BENCH_<name>.json schema version (bump on breaking changes).
# v2: every result record carries a "kind" discriminator — "timing" for
# classic us_per_call rows, "stress" for the online stress-lane records
# (sustained-throughput runs whose metrics carry percentile latencies and
# the flat-latency ratio), "slo" for the admission-SLO comparison rows,
# and "solver_throughput" for the engine's sustained candidate-throughput
# records (cands_per_s, mega-batch speedup). New kinds are additive, not
# schema breaks.
BENCH_SCHEMA = "repro-bench-v2"


def timer(fn, *args, repeats: int = 3, **kwargs):
    """Returns (result, best_wall_seconds)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best


def _parse_derived(derived: str) -> dict:
    """Best-effort ``k=v;k=v`` parse of a derived string (strings kept)."""
    out: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def emit(
    name: str, us_per_call: float, derived: str = "", kind: str = "timing"
) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    RESULTS.append(
        {
            "name": name,
            "kind": kind,
            "us_per_call": float(us_per_call),
            "derived": derived,
            "metrics": _parse_derived(derived),
        }
    )


def reset_results() -> None:
    RESULTS.clear()


def bench_arg_parser(description: str | None = None) -> argparse.ArgumentParser:
    """Parser shared by every benchmark entry point: the ``--json`` flag.

    Modules add their own extra flags on the returned parser; after
    running, pass ``args.json`` (if set) to :func:`write_json`. Keeping
    the flag here means the BENCH CLI stays identical across
    ``run.py`` / ``solver_scaling.py`` / ``online_serving.py``.
    """
    parser = argparse.ArgumentParser(description=description)
    parser.add_argument(
        "--json",
        metavar="OUT.JSON",
        default=None,
        help="also write the machine-readable BENCH record here",
    )
    return parser


def write_json(path: str, bench: str, config: dict | None = None) -> None:
    """Flush the accumulated records as a ``BENCH_<name>.json`` document.

    Schema: ``{"schema", "bench", "config", "environment", "results"}``
    where each result is
    ``{"name", "kind", "us_per_call", "derived", "metrics"}``
    (``metrics`` is the parsed key=value view of ``derived`` — wall
    times, JCTs, prune rates, percentile latencies, ...; ``kind``
    discriminates ``"timing"`` rows from ``"stress"`` records).
    """
    payload = {
        "schema": BENCH_SCHEMA,
        "bench": bench,
        "config": {"full": FULL, **(config or {})},
        "environment": {
            "platform": platform.platform(),
            "python": platform.python_version(),
            "numpy": np.__version__,
        },
        "results": list(RESULTS),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {len(RESULTS)} benchmark records -> {path}", flush=True)
