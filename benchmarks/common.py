"""Shared benchmark utilities."""

from __future__ import annotations

import os
import time

import numpy as np

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def timer(fn, *args, repeats: int = 3, **kwargs):
    """Returns (result, best_wall_seconds)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
