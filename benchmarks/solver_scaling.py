"""Solver scaling study (§IV-D claim): RP via HiGHS B&B vs the bisection FP
decomposition vs the combinatorial B&B vs the JAX-vectorized search."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FULL, emit
from repro.core import (
    ProblemInstance,
    random_job,
    solve_bisection,
    solve_bnb,
    solve_optimal,
    vectorized_search,
)


def run():
    sizes = (4, 5, 6, 7) if not FULL else (4, 5, 6, 7, 8)
    seeds = 3
    for n in sizes:
        walls = {"milp": [], "bisect": [], "bnb": [], "vectorized": []}
        gaps = []
        for seed in range(seeds):
            rng = np.random.default_rng(3000 + seed)
            job = random_job(rng, None, n_tasks=n, rho=0.5)
            inst = ProblemInstance(job=job, n_racks=min(n, 4), n_wireless=1)
            t0 = time.perf_counter()
            r_m = solve_optimal(inst, time_limit=60)
            walls["milp"].append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            r_bi = solve_bisection(inst, time_limit_per_fp=30)
            walls["bisect"].append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            r_b = solve_bnb(inst, time_limit=60)
            walls["bnb"].append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            r_v = vectorized_search(inst)
            walls["vectorized"].append(time.perf_counter() - t0)
            gaps.append(abs(r_b.makespan - r_m.makespan))
        emit(
            f"solver_scaling_n{n}",
            1e6 * float(np.mean(walls["bnb"])),
            ";".join(
                f"{k}={1e3 * np.mean(v):.1f}ms" for k, v in walls.items()
            )
            + f";max_disagreement={max(gaps):.3f}",
        )


def main():
    run()


if __name__ == "__main__":
    main()
