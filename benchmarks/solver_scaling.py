"""Solver scaling study (§IV-D claim): RP via HiGHS B&B vs the bisection FP
decomposition vs the combinatorial B&B vs the JAX-vectorized search."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FULL, emit
from repro.core import (
    ProblemInstance,
    random_job,
    schedule_fleet,
    solve_bisection,
    solve_bnb,
    solve_optimal,
    vectorized_search,
)


def run():
    sizes = (4, 5, 6, 7) if not FULL else (4, 5, 6, 7, 8)
    seeds = 3
    for n in sizes:
        walls = {"milp": [], "bisect": [], "bnb": [], "vectorized": []}
        gaps = []
        pruned, considered = 0, 0
        for seed in range(seeds):
            rng = np.random.default_rng(3000 + seed)
            job = random_job(rng, None, n_tasks=n, rho=0.5)
            inst = ProblemInstance(job=job, n_racks=min(n, 4), n_wireless=1)
            t0 = time.perf_counter()
            r_m = solve_optimal(inst, time_limit=60)
            walls["milp"].append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            r_bi = solve_bisection(inst, time_limit_per_fp=30)
            walls["bisect"].append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            r_b = solve_bnb(inst, time_limit=60)
            walls["bnb"].append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            r_v = vectorized_search(inst)
            walls["vectorized"].append(time.perf_counter() - t0)
            pruned += r_v.n_pruned
            considered += r_v.n_candidates
            gaps.append(abs(r_b.makespan - r_m.makespan))
        emit(
            f"solver_scaling_n{n}",
            1e6 * float(np.mean(walls["bnb"])),
            ";".join(
                f"{k}={1e3 * np.mean(v):.1f}ms" for k, v in walls.items()
            )
            + f";max_disagreement={max(gaps):.3f}"
            + f";lb_pruned={pruned}/{considered}",
        )


def run_sampled_throughput():
    """Candidate throughput of the batch engine in the sampled regime.

    Several fresh instances of one size bucket: the op-table formulation
    compiles once and amortizes across all of them (the seed engine paid a
    full retrace+compile per instance).
    """
    n_inst = 3 if not FULL else 8
    n_samples = 8192
    insts = []
    for seed in range(n_inst):
        rng = np.random.default_rng(4000 + seed)
        job = random_job(rng, None, n_tasks=10, rho=0.5)
        insts.append(ProblemInstance(job=job, n_racks=6, n_wireless=1))
    # Warm every measured instance's size bucket so the figure is sustained
    # throughput (the seed engine re-paid a trace+compile per instance).
    for inst in insts:
        vectorized_search(inst, max_enumerate=1000, n_samples=n_samples)
    total_cands, total_pruned, wall = 0, 0, 0.0
    for seed, inst in enumerate(insts):
        t0 = time.perf_counter()
        r = vectorized_search(
            inst, max_enumerate=1000, n_samples=n_samples, seed=seed
        )
        wall += time.perf_counter() - t0
        total_cands += r.n_candidates
        total_pruned += r.n_pruned
    emit(
        "vectorized_sampled_throughput",
        1e6 * wall / n_inst,
        f"cands_per_s={total_cands / wall:.0f};lb_pruned={total_pruned}/{total_cands}"
        f";instances={n_inst}",
        kind="solver_throughput",
    )


def run_fleet_megabatch():
    """Fleet mega-batch vs one-instance-at-a-time over 8 heterogeneous jobs.

    ``schedule_fleet`` packs all 8 candidate streams into shared launches
    (at most one compiled program per stage); the sequential loop pays its
    compiles and dispatches per instance. Both produce identical
    per-instance results, so the delta is pure batching/compile overhead.
    """
    n_inst = 8
    insts = []
    for seed in range(n_inst):
        rng = np.random.default_rng(5000 + seed)
        job = random_job(rng, None, n_tasks=5 + seed % 4, rho=1.5)
        insts.append(
            ProblemInstance(job=job, n_racks=3 + seed % 3, n_wireless=1 + seed % 2)
        )
    kw = dict(batch_size=512)
    t0 = time.perf_counter()
    fleet = schedule_fleet(insts, **kw)
    wall_fleet = time.perf_counter() - t0
    t0 = time.perf_counter()
    seq = [vectorized_search(inst, **kw) for inst in insts]
    wall_seq = time.perf_counter() - t0
    assert all(
        a.makespan == b.makespan for a, b in zip(fleet.results, seq)
    ), "fleet/solo mismatch"
    emit(
        "fleet_megabatch_8inst",
        1e6 * wall_fleet,
        f"seq_ms={1e3 * wall_seq:.1f};speedup={wall_seq / wall_fleet:.2f}x"
        f";lb_pruned={fleet.n_pruned}/{fleet.n_candidates}"
        f";launches=s1:{fleet.n_stage1_launches},s2:{fleet.n_stage2_launches}"
        f";traces=s1:{fleet.n_stage1_traces},s2:{fleet.n_stage2_traces}",
        kind="solver_throughput",
    )


def run_portfolio_refinement():
    """Portfolio vs plain local search at the same candidate budget.

    Dense sampled-regime shuffles (full bipartite MapReduce) with a weak
    initial sample, so refinement does the heavy lifting; both arms get
    identical rounds x pool proposals. Reported JCT is the mean final
    makespan across seeds; per-strategy yield comes from the fleet's
    aggregated ``strategy_stats``. The table in ``docs/benchmarks.md`` is
    produced by this function.
    """
    from repro.core.dag import make_onestage_mapreduce

    n_seeds = 6 if not FULL else 12
    rounds = 16
    insts = [
        ProblemInstance(
            job=make_onestage_mapreduce(
                np.random.default_rng(s), n_map=9, n_reduce=9, rho=1.0
            ),
            n_racks=6,
            n_wireless=1,
        )
        for s in range(n_seeds)
    ]
    kw = dict(
        max_enumerate=500,
        n_samples=64,
        batch_size=512,
        refine_rounds=rounds,
        refine_pool=256,
        refine_patience=rounds,
        seed=list(range(n_seeds)),
    )
    t0 = time.perf_counter()
    plain = schedule_fleet(insts, strategies=("mutation",), **kw)
    wall_plain = time.perf_counter() - t0
    t0 = time.perf_counter()
    port = schedule_fleet(insts, strategies="portfolio", **kw)
    wall_port = time.perf_counter() - t0
    wins = sum(
        q.makespan < p.makespan - 1e-9
        for p, q in zip(plain.results, port.results)
    )
    yields = ";".join(
        f"{name}:y={s.yield_per_eval:.3f},evald={s.evaluated},w={s.weight:.2f}"
        for name, s in sorted(port.strategy_stats.items())
    )
    emit(
        "portfolio_vs_local_search",
        1e6 * wall_port / n_seeds,
        f"jct_plain={plain.makespans.mean():.2f}"
        f";jct_portfolio={port.makespans.mean():.2f}"
        f";reduction={100 * (1 - port.makespans.mean() / plain.makespans.mean()):.1f}%"
        f";wins={wins}/{n_seeds};plain_ms={1e3 * wall_plain:.0f}"
        f";{yields}",
    )


def main(argv=None):
    from benchmarks import common

    parser = common.bench_arg_parser(__doc__)
    parser.add_argument(
        "--throughput",
        action="store_true",
        help="run only the sustained-throughput sections (the "
        'kind="solver_throughput" BENCH records) — skips the slow '
        "MILP/B&B scaling sweep and the portfolio study",
    )
    args = parser.parse_args(argv)
    if not args.throughput:
        run()
    run_sampled_throughput()
    run_fleet_megabatch()
    if not args.throughput:
        run_portfolio_refinement()
    if args.json:
        common.write_json(
            args.json, bench="solver_scaling",
            config={"throughput_only": args.throughput},
        )


if __name__ == "__main__":
    main()
