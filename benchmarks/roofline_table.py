"""Render the §Roofline table from the dry-run JSON into EXPERIMENTS.md."""

from __future__ import annotations

import json
import sys


def render(path: str = "EXPERIMENTS/dryrun_final.json") -> str:
    with open(path) as f:
        data = json.load(f)
    PEAK = 197e12
    rows = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | useful | roofline-frac | HBM GiB/dev |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|",
    ]
    for r in data:
        if r["mesh"] != "16x16":
            continue
        if r.get("skip"):
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP (sub-quadratic-only shape) | — | — | — |"
            )
            continue
        if not r["ok"]:
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | |")
            continue
        t = r["terms"]
        dom = max(
            ("compute_s", "memory_s", "collective_s"), key=lambda k: t[k]
        ).replace("_s", "")
        # roofline fraction: useful model compute time over the modeled
        # step time (= dominant term, perfect-overlap assumption) — the
        # fraction of peak the cell achieves at its bottleneck.
        chips = 256
        useful_s = r["model_flops"] / chips / PEAK
        max_term = max(t["compute_s"], t["memory_s"], t["collective_s"])
        frac = useful_s / max_term if max_term else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | {dom} | "
            f"{t['useful_flops_ratio']:.2f} | {frac:.3f} | "
            f"{r['peak_memory_per_device'] / 2**30:.2f} |"
        )
    return "\n".join(rows)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "EXPERIMENTS/dryrun_final.json"
    table = render(path)
    try:
        with open("EXPERIMENTS.md") as f:
            doc = f.read()
        if "<!-- ROOFLINE_TABLE -->" in doc:
            doc = doc.replace("<!-- ROOFLINE_TABLE -->", table, 1)
            with open("EXPERIMENTS.md", "w") as f:
                f.write(doc)
            print("EXPERIMENTS.md updated")
            return
    except FileNotFoundError:
        pass
    print(table)


if __name__ == "__main__":
    main()
