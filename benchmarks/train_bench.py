"""Training-step wall benchmark on the reduced llama config (host CPU) plus
scheduler-integration (plan) gain measurement."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timer
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.models.lm import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.steps import build_train_step, make_train_state


def run():
    cfg = smoke_config("llama3_2_3b")
    model = build_model(cfg)
    data = make_pipeline(
        DataConfig(vocab_size=cfg.vocab_size, global_batch=8, seq_len=64)
    )
    step = jax.jit(build_train_step(model, AdamWConfig(), n_micro=2))
    state = make_train_state(model, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in data.batch_for_step(0).items()}
    state, _ = step(state, batch)  # compile
    jax.block_until_ready(state.params)

    def one():
        s2, m = step(state, batch)
        jax.block_until_ready(s2.params)
        return m

    _, t = timer(one)
    tokens = 8 * 64
    emit("train_step_smoke_llama", 1e6 * t, f"tokens_s={tokens / t:.0f}")


def main():
    run()


if __name__ == "__main__":
    main()
