"""Online serving benchmarks: JCT and scheduler throughput under
continuous job arrival (the paper's §V production scenario), plus the
comparisons that back the tables in ``docs/benchmarks.md``.

  run()                 — arrival-rate sweep: mean/p95 JCT, queueing delay,
                          scheduler throughput and true (channel-feasible)
                          utilizations, with bandwidth augmentation on
                          (|K|=2) and off (|K|=0), fleet policy vs the
                          online FIFO-solo and greedy-list baselines.
  run_warm_vs_cold()    — warm-started vs cold-started re-optimization at
                          equal candidate budget on the production mix
                          (per-seed mean JCT; the docs table).
  run_admission_modes() — default overtaking vs preserve_order FIFO vs
                          channel-proven backfilling on a production mix
                          with rack- and wireless-demand spread (per-seed
                          mean JCT + backfill counters; the docs table).
  run_arbitration_modes() — FIFO vs sigma (bottleneck-first coflow
                          order) vs search (portfolio permutation
                          neighborhoods) cross-job commit-order
                          arbitration, on dense single-epoch wired
                          bursts and on the production mix (per-seed
                          mean JCT + queueing delta; the docs table).
  run_admission_slo()   — overload sweep (arrival rate pushed past cluster
                          saturation) over the SLO-tiered multi-tenant
                          production mix: FIFO vs EDF vs EDF+defer vs
                          weighted-fair admission at identical solver and
                          arbitration settings, so the *admission policy*
                          — not solver quality — separates the curves.
                          Emits per-rate deadline-miss counts, per-tier
                          SLO attainment and tenant p99 queueing delay
                          (the docs table); ``--smoke`` runs a reduced
                          scale and exits non-zero when EDF misses more
                          deadlines than FIFO (the CI bench-lane
                          regression check).
  run_topology_modes()  — reconfigurable-topology sweep: a static
                          degree-limited transceiver configuration vs
                          per-epoch demand-driven re-matching (with
                          reconfiguration-delay accounting) vs matching
                          under a seeded link-outage trace, across
                          wireless-demand fractions; ``--topology
                          --smoke`` gates all-ones bit-identity and
                          matching >= static (the CI bench-lane check).
  run_stress()          — ``--stress``: sustained-throughput lane. Streams
                          a 100k-arrival production trace through the
                          O(active) serving core (lazy workload iterator,
                          periodic interval-index compaction, per-job
                          records elided, streaming percentile stats) and
                          *asserts* flat per-epoch commit latency: the
                          second-half mean must stay within
                          ``STRESS_LATENCY_RATIO``x of the first-half
                          mean, else the process exits non-zero (the CI
                          stress smoke job runs a reduced-scale version).

All JCT/utilization figures are measured under channel-feasible commits
(cross-job wired/wireless arbitration), so they are NOT comparable to the
PR 4 records, which allowed physically overlapping transfers. Quick mode
keeps each section under ~a minute on the CPU container;
REPRO_BENCH_FULL=1 widens seeds and rates. ``--json out.json`` writes the
machine-readable BENCH record.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FULL, emit
from repro.online import (
    DEFAULT_SLO_TIERS,
    OnlineScheduler,
    production_arrivals,
    stream_production_arrivals,
    tiered_production_arrivals,
)

# Cluster and engine configuration shared by both sections. The engine
# budget keeps the production-mix jobs (tasks ~ U[5,10]) in the *sampled*
# regime (max_enumerate below the canonical counts), where search quality
# — and therefore warm starts — matters; see docs/benchmarks.md.
CLUSTER = dict(n_racks=6, n_wireless=2)
SOLVER = dict(
    max_enumerate=64,
    n_samples=64,
    batch_size=256,
    refine_rounds=2,
    refine_pool=96,
    strategies="portfolio",
)
SERVICE = dict(
    window=5.0,
    require_full_demand=True,
    preserve_order=True,
    solver_kwargs=SOLVER,
)


def _stream(seed: int, rate: float, n_jobs: int, n_wireless: int):
    return production_arrivals(
        seed,
        rate=rate,
        n_jobs=n_jobs,
        n_racks=CLUSTER["n_racks"],
        n_wireless=n_wireless,
        min_rack_demand=4,
    )


def run() -> None:
    """JCT / throughput vs arrival rate, augmentation on/off, vs baselines."""
    rates = (1 / 80, 1 / 40) if not FULL else (1 / 120, 1 / 80, 1 / 40, 1 / 25)
    n_jobs = 8 if not FULL else 16
    seed = 0
    for rate in rates:
        for n_wl, tag in ((CLUSTER["n_wireless"], "aug_on"), (0, "aug_off")):
            evs = _stream(seed, rate, n_jobs, n_wl)
            svc = OnlineScheduler(
                CLUSTER["n_racks"], n_wl, warm_start=True, seed=seed, **SERVICE
            )
            t0 = time.perf_counter()
            res = svc.serve(evs)
            wall = time.perf_counter() - t0
            emit(
                f"online_rate{1 / rate:.0f}_{tag}",
                1e6 * wall / n_jobs,
                f"mean_jct={res.mean_jct:.1f};p95_jct={res.p95_jct:.1f}"
                f";mean_queue={res.mean_queueing_delay:.1f}"
                f";makespan={res.makespan:.1f}"
                f";jobs_per_solver_s={res.jobs_per_solver_second:.2f}"
                f";rack_util={res.rack_utilization:.2f}"
                f";wired_util={res.wired_utilization:.2f}"
                f";wireless_util={res.wireless_utilization:.2f}"
                f";pruned={res.n_pruned};cands={res.n_candidates}"
                f";epochs={res.n_epochs};batches={res.n_batches}",
            )
        # Online baselines at the same rate (augmentation on).
        for policy in ("greedy_list", "fifo_solo"):
            evs = _stream(seed, rate, n_jobs, CLUSTER["n_wireless"])
            svc = OnlineScheduler(
                CLUSTER["n_racks"],
                CLUSTER["n_wireless"],
                policy=policy,
                seed=seed,
                **SERVICE,
            )
            t0 = time.perf_counter()
            res = svc.serve(evs)
            wall = time.perf_counter() - t0
            emit(
                f"online_rate{1 / rate:.0f}_{policy}",
                1e6 * wall / n_jobs,
                f"mean_jct={res.mean_jct:.1f};p95_jct={res.p95_jct:.1f}"
                f";mean_queue={res.mean_queueing_delay:.1f}"
                f";makespan={res.makespan:.1f}"
                f";rack_util={res.rack_utilization:.2f}"
                f";wired_util={res.wired_utilization:.2f}"
                f";wireless_util={res.wireless_utilization:.2f}",
            )


def run_warm_vs_cold() -> None:
    """Warm-started vs cold-started re-optimization, equal candidate budget.

    Production-scenario mix at a rate that queues most jobs (so queued
    jobs are re-planned several times before admission). Both arms run
    the identical service configuration and per-solve budget; the warm
    arm additionally seeds each re-solve's sweep with the job's incumbent
    assignments (budget-neutral: seeds displace random samples) and
    serves a job's best simulated incumbent when a fresh re-solve fails
    to beat it. The docs/benchmarks.md table is this function's output.
    """
    n_seeds = 6 if not FULL else 10
    rate, n_jobs = 1 / 40, 10
    rows = []
    wins = losses = 0
    for seed in range(n_seeds):
        evs = _stream(seed, rate, n_jobs, CLUSTER["n_wireless"])
        t0 = time.perf_counter()
        warm = OnlineScheduler(
            CLUSTER["n_racks"], CLUSTER["n_wireless"],
            warm_start=True, seed=seed, **SERVICE,
        ).serve(evs)
        cold = OnlineScheduler(
            CLUSTER["n_racks"], CLUSTER["n_wireless"],
            warm_start=False, seed=seed, **SERVICE,
        ).serve(evs)
        wall = time.perf_counter() - t0
        d = cold.mean_jct - warm.mean_jct
        wins += d > 1e-9
        losses += d < -1e-9
        rows.append((seed, warm.mean_jct, cold.mean_jct, d))
        emit(
            f"online_warm_vs_cold_seed{seed}",
            1e6 * wall / n_jobs,
            f"warm_jct={warm.mean_jct:.2f};cold_jct={cold.mean_jct:.2f}"
            f";delta={d:.2f};warm_solves={warm.n_solves}"
            f";cold_solves={cold.n_solves}"
            f";warm_queue={warm.mean_queueing_delay:.1f}",
        )
    warm_mean = float(np.mean([r[1] for r in rows]))
    cold_mean = float(np.mean([r[2] for r in rows]))
    emit(
        "online_warm_vs_cold_summary",
        0,
        f"warm_mean_jct={warm_mean:.2f};cold_mean_jct={cold_mean:.2f}"
        f";reduction={100 * (1 - warm_mean / cold_mean):.2f}%"
        f";wins={wins}/{n_seeds};losses={losses}/{n_seeds}",
    )


def run_admission_modes() -> None:
    """Default overtaking vs preserve_order FIFO vs channel-proven
    backfilling, at equal everything else.

    Production mix with a rack-demand *and* wireless-demand spread (not
    every job uses the augmentation links), at a rate that keeps a deep
    queue — the regime where head-of-line blocking costs and backfilling
    can overtake. All three arms run full-demand admission and the same
    engine budget; ``backfill`` additionally lets a queued job overtake
    the blocked head-of-line job when arbitration proves the overtake
    cannot delay its admission epoch (completion within the reservation,
    or shadow slack). The docs/benchmarks.md admission-mode table is this
    function's output.
    """
    n_seeds = 6 if not FULL else 10
    rate, n_jobs = 1 / 12, 12
    modes = (
        ("default", dict()),
        ("preserve_order", dict(preserve_order=True)),
        ("backfill", dict(preserve_order=True, backfill=True)),
    )
    means = {tag: [] for tag, _ in modes}
    backfills = rejected = 0
    bf_wins = bf_losses = 0
    for seed in range(n_seeds):
        evs = production_arrivals(
            seed,
            rate=rate,
            n_jobs=n_jobs,
            n_racks=CLUSTER["n_racks"],
            n_wireless=CLUSTER["n_wireless"],
            min_rack_demand=2,
            min_wireless_demand=0,
        )
        per_seed = {}
        t0 = time.perf_counter()
        for tag, kw in modes:
            res = OnlineScheduler(
                CLUSTER["n_racks"], CLUSTER["n_wireless"], window=5.0,
                require_full_demand=True, warm_start=True, seed=seed,
                solver_kwargs=SOLVER, **kw,
            ).serve(evs)
            per_seed[tag] = res
            means[tag].append(res.mean_jct)
        wall = time.perf_counter() - t0
        bf, po = per_seed["backfill"], per_seed["preserve_order"]
        backfills += bf.n_backfilled
        rejected += bf.n_backfill_rejected
        d = po.mean_jct - bf.mean_jct
        bf_wins += d > 1e-9
        bf_losses += d < -1e-9
        emit(
            f"online_admission_modes_seed{seed}",
            1e6 * wall / (len(modes) * n_jobs),
            f"default_jct={per_seed['default'].mean_jct:.1f}"
            f";preserve_order_jct={po.mean_jct:.1f}"
            f";backfill_jct={bf.mean_jct:.1f}"
            f";n_backfilled={bf.n_backfilled}"
            f";n_backfill_rejected={bf.n_backfill_rejected}"
            f";backfill_rack_util={bf.rack_utilization:.2f}"
            f";backfill_wired_util={bf.wired_utilization:.2f}",
        )
    mean_of = {tag: float(np.mean(v)) for tag, v in means.items()}
    emit(
        "online_admission_modes_summary",
        0,
        f"default_mean_jct={mean_of['default']:.2f}"
        f";preserve_order_mean_jct={mean_of['preserve_order']:.2f}"
        f";backfill_mean_jct={mean_of['backfill']:.2f}"
        f";backfill_reduction="
        f"{100 * (1 - mean_of['backfill'] / mean_of['preserve_order']):.2f}%"
        f";backfill_wins={bf_wins}/{n_seeds};backfill_losses={bf_losses}/{n_seeds}"
        f";backfilled={backfills};rejected={rejected}",
    )


def _dense_burst(seed: int, n_jobs: int = 4):
    """One admission epoch of simultaneous wired-heavy map-reduce jobs
    with a per-seed spread of transfer volumes (rho in [0.25, 8]) — the
    regime where the cross-job commit order *is* the coflow schedule.
    Every job demands 2 racks of 8, so the batch is co-admitted and the
    wired channel is the only shared resource."""
    import dataclasses

    from repro.core.dag import make_onestage_mapreduce
    from repro.online import trace_arrivals

    rng = np.random.default_rng(seed)
    rhos = rng.uniform(0.25, 8.0, size=n_jobs)
    jobs = [
        make_onestage_mapreduce(rng, n_map=3, n_reduce=2, rho=float(r))
        for r in rhos
    ]
    evs = trace_arrivals([0.0] * n_jobs, jobs, n_racks=8, n_wireless=0)
    return [
        dataclasses.replace(e, inst=dataclasses.replace(e.inst, n_racks=2))
        for e in evs
    ]


def run_arbitration_modes() -> None:
    """FIFO vs sigma vs search cross-job commit-order arbitration.

    Both workloads run the greedy-list policy — the order-sensitive
    path, where each job is *solved* at commit time against the busy
    intervals of the epoch's earlier commits (the fleet engine already
    serializes an epoch's transfers at solve time, so reordering its
    pre-solved schedules is a no-op by design). ``dense`` is a
    single-epoch burst of wired-heavy jobs: the epoch's replayed total
    JCT is the stream's total JCT, so ``search`` (FIFO-first, strict
    improvement only) is never worse than FIFO *by construction* and the
    measured deltas are pure ordering gains. ``production`` is the usual
    arrival mix at a queue-building rate — reordering one epoch shifts
    later residuals, so gains are no longer guaranteed epoch-by-epoch;
    the table shows they hold end to end. ``sigma`` commits the
    bottleneck-first heuristic order unconditionally (no replay search),
    so it can lose where the wired-volume proxy misranks a batch. The
    docs/benchmarks.md arbitration-mode table is this function's output.
    """
    n_seeds = 6 if not FULL else 10
    modes = ("fifo", "sigma", "search")
    sections = (
        ("dense", lambda seed: _dense_burst(seed),
         dict(n_racks=8, n_wireless=0, window=1.0)),
        ("production", lambda seed: production_arrivals(
            seed, rate=1 / 4, n_jobs=12, n_racks=CLUSTER["n_racks"],
            n_wireless=0, min_rack_demand=2),
         dict(n_racks=CLUSTER["n_racks"], n_wireless=0, window=5.0)),
    )
    for section, make, cfg in sections:
        means = {m: [] for m in modes}
        wins = losses = reordered = evals = 0
        for seed in range(n_seeds):
            evs = make(seed)
            per_seed = {}
            t0 = time.perf_counter()
            for mode in modes:
                res = OnlineScheduler(
                    cfg["n_racks"], cfg["n_wireless"], window=cfg["window"],
                    policy="greedy_list", seed=seed, arbitration=mode,
                ).serve(evs)
                per_seed[mode] = res
                means[mode].append(res.mean_jct)
            wall = time.perf_counter() - t0
            fifo, search = per_seed["fifo"], per_seed["search"]
            d = fifo.mean_jct - search.mean_jct
            wins += d > 1e-9
            losses += d < -1e-9
            reordered += search.n_epochs_reordered
            evals += search.n_order_evals
            emit(
                f"online_arbitration_{section}_seed{seed}",
                1e6 * wall / (len(modes) * len(evs)),
                f"fifo_jct={fifo.mean_jct:.1f}"
                f";sigma_jct={per_seed['sigma'].mean_jct:.1f}"
                f";search_jct={search.mean_jct:.1f}"
                f";search_delta={d:.1f}"
                f";fifo_queue={fifo.mean_queueing_delay:.1f}"
                f";search_queue={search.mean_queueing_delay:.1f}"
                f";reordered={search.n_epochs_reordered}"
                f";order_evals={search.n_order_evals}"
                f";gain={search.arbitration_gain:.1f}",
            )
        mean_of = {m: float(np.mean(v)) for m, v in means.items()}
        emit(
            f"online_arbitration_{section}_summary",
            0,
            f"fifo_mean_jct={mean_of['fifo']:.2f}"
            f";sigma_mean_jct={mean_of['sigma']:.2f}"
            f";search_mean_jct={mean_of['search']:.2f}"
            f";search_reduction="
            f"{100 * (1 - mean_of['search'] / mean_of['fifo']):.2f}%"
            f";search_wins={wins}/{n_seeds};search_losses={losses}/{n_seeds}"
            f";epochs_reordered={reordered};order_evals={evals}",
        )


# SLO overload lane: the weighted-fair arm maps each tier's fairness
# share into the service's weight lookup (tenant tag first, tier tag as
# fallback — these are tier shares), and bounds starvation at 4 overtakes.
SLO_TIER_SHARES = {t.name: t.share for t in DEFAULT_SLO_TIERS}
SLO_MAX_OVERTAKES = 4


def run_admission_slo(smoke: bool = False) -> bool:
    """Overload sweep: admission policy vs deadline misses past saturation.

    The SLO-tiered production mix (gold/silver with deadlines from the
    rigorous critical-path bound, best-effort bronze) is served at
    arrival rates from near-saturation to well past it. Every arm runs
    the greedy-list policy at identical settings, so JCT and miss deltas
    are attributable to the admission order alone: FIFO (arrival order),
    EDF (earliest deadline first), EDF with ``admission_control="defer"``
    (a commit whose replayed completion proves a miss waits for a less
    contended epoch), and weighted-fair (tier-share weights, starvation
    bounded at ``SLO_MAX_OVERTAKES`` overtakes — counted and asserted by
    the service). Emits one record per (rate, seed) and a per-rate
    summary; returns ``True`` iff EDF's total deadline misses are <=
    FIFO's at every rate (the ``--smoke`` CI gate; ``smoke=True`` only
    shrinks the scale).
    """
    # The smoke gate runs the *moderate*-overload regime (about 2-3x past
    # the service rate), where deadline-aware ordering provably pays; at
    # extreme overload nearly every deadline is lost no matter the order
    # and EDF's classic domino effect can cost a miss or two vs FIFO —
    # the full sweep keeps such a rate in the table on purpose (that is
    # the regime the defer/reject admission control exists for).
    if smoke:
        rates, n_seeds, n_jobs = (1 / 12,), 3, 10
    elif not FULL:
        rates, n_seeds, n_jobs = (1 / 24, 1 / 12, 1 / 6), 4, 14
    else:
        rates, n_seeds, n_jobs = (1 / 48, 1 / 24, 1 / 12, 1 / 6, 1 / 3), 8, 20
    arms = (
        ("fifo", dict(admission="fifo")),
        ("edf", dict(admission="edf")),
        ("edf_defer", dict(admission="edf", admission_control="defer")),
        (
            "wfair",
            dict(
                admission="wfair",
                tenant_weights=SLO_TIER_SHARES,
                max_overtakes=SLO_MAX_OVERTAKES,
            ),
        ),
    )
    edf_never_worse = True
    for rate in rates:
        misses = {tag: 0 for tag, _ in arms}
        deadline_jobs = {tag: 0 for tag, _ in arms}
        jcts = {tag: [] for tag, _ in arms}
        gold_slo = {tag: [] for tag, _ in arms}
        for seed in range(n_seeds):
            evs = tiered_production_arrivals(
                seed,
                rate=rate,
                n_jobs=n_jobs,
                n_racks=CLUSTER["n_racks"],
                n_wireless=CLUSTER["n_wireless"],
                min_rack_demand=2,
            )
            per_arm = {}
            t0 = time.perf_counter()
            for tag, kw in arms:
                res = OnlineScheduler(
                    CLUSTER["n_racks"],
                    CLUSTER["n_wireless"],
                    window=5.0,
                    policy="greedy_list",
                    seed=seed,
                    **kw,
                ).serve(evs)
                per_arm[tag] = res
                misses[tag] += res.n_deadline_missed
                deadline_jobs[tag] += res.n_deadline_jobs
                jcts[tag].append(res.mean_jct)
                gold_slo[tag].append(res.slo_attainment.get("gold", 1.0))
            wall = time.perf_counter() - t0
            fifo, edf = per_arm["fifo"], per_arm["edf"]
            wf = per_arm["wfair"]
            emit(
                f"online_slo_rate{1 / rate:.0f}_seed{seed}",
                1e6 * wall / (len(arms) * n_jobs),
                f"fifo_miss={fifo.n_deadline_missed}"
                f"/{fifo.n_deadline_jobs}"
                f";edf_miss={edf.n_deadline_missed}/{edf.n_deadline_jobs}"
                f";edf_defer_miss={per_arm['edf_defer'].n_deadline_missed}"
                f";edf_deferrals={per_arm['edf_defer'].n_deadline_deferrals}"
                f";wfair_miss={wf.n_deadline_missed}"
                f";wfair_max_overtaken={wf.max_overtakes_observed}"
                f";fifo_jct={fifo.mean_jct:.1f};edf_jct={edf.mean_jct:.1f}"
                f";wfair_jct={wf.mean_jct:.1f}",
                kind="slo",
            )
        if misses["edf"] > misses["fifo"]:
            edf_never_worse = False
        fmt = lambda tag: (
            f"{tag}_miss={misses[tag]}/{deadline_jobs[tag]}"
            f";{tag}_jct={float(np.mean(jcts[tag])):.1f}"
            f";{tag}_gold_slo={float(np.mean(gold_slo[tag])):.2f}"
        )
        emit(
            f"online_slo_rate{1 / rate:.0f}_summary",
            0,
            ";".join(fmt(tag) for tag, _ in arms),
            kind="slo",
        )
    return edf_never_worse


# Topology lane configuration: every rack transceiver holds one
# subchannel link (degree 1), each subchannel accepts half the cluster
# (channel_degree), and a reconfiguration takes TOPOLOGY_DELTA time
# units on the affected subchannel. Wireless runs at 2x the wired rate
# so the reachability mask actually binds the solver's channel choices.
TOPOLOGY_DELTA = 1.0
TOPOLOGY_WIRELESS_RATE = 2.0


def run_topology_modes(smoke: bool = False) -> bool:
    """Static vs per-epoch-matched vs outage-degraded reconfigurable
    topology, across wireless-demand fractions.

    All arms serve the identical production stream with the greedy-list
    policy under a degree-limited transceiver model (each rack holds one
    subchannel link, each subchannel accepts half the racks):

    - ``static``  — the uniform-weight matching is configured once and
      never changes; jobs granted racks outside their subchannel's rack
      set fall back to wired.
    - ``matching`` — the cluster re-matches every admission epoch against
      the pending batch's aggregate wireless demand (idle subchannels
      only; each reconfiguration charges a ``TOPOLOGY_DELTA`` busy
      interval), so links follow the *free* racks.
    - ``matching_outages`` — same, under a seeded link-flap trace; the
      scheduler replans around dead links via the active-mask
      fingerprint.

    The wireless-demand axis is ``min_wireless_demand``: at 0 most jobs
    are wired-only, at ``n_wireless`` every job wants the full
    augmentation. Emits one ``kind="topology"`` record per (fraction,
    seed, arm) plus per-fraction summaries. Returns ``True`` iff (a) a
    ``topology="static"`` all-ones serve is bit-identical to the
    topology-free serve on the smoke stream, and (b) per-epoch matching's
    mean JCT is no worse than the static configuration's, averaged over
    the smoke seeds (the ``--topology --smoke`` CI gate; ``smoke=True``
    only shrinks the scale).
    """
    from repro.core.instance import Topology
    from repro.online.workload import link_outage_trace

    n_racks, n_wl = CLUSTER["n_racks"], CLUSTER["n_wireless"]
    if smoke:
        fractions, n_seeds, n_jobs = (n_wl,), 3, 10
    elif not FULL:
        fractions, n_seeds, n_jobs = (0, 1, n_wl), 4, 10
    else:
        fractions, n_seeds, n_jobs = (0, 1, n_wl), 8, 16
    # Queue-building rate: fragmented free sets are where re-matching can
    # follow the free racks and a frozen configuration cannot.
    rate = 1 / 6
    base = Topology(
        reach=np.ones((n_racks, n_wl), dtype=bool),
        degree=1,
        channel_degree=max(1, n_racks // n_wl),
        delta=TOPOLOGY_DELTA,
    )
    # The static arm freezes the uniform-weight matching of the same model.
    static_topo = Topology(reach=base.match(np.ones(n_racks)))
    horizon = 4.0 * n_jobs / rate

    def _evs(seed: int, frac: int):
        return production_arrivals(
            seed,
            rate=rate,
            n_jobs=n_jobs,
            n_racks=n_racks,
            n_wireless=n_wl,
            min_rack_demand=2,
            min_wireless_demand=frac,
            wireless_rate=TOPOLOGY_WIRELESS_RATE,
        )

    def _serve(evs, seed, **topo_kw):
        return OnlineScheduler(
            n_racks, n_wl, window=5.0, policy="greedy_list", seed=seed,
            **topo_kw,
        ).serve(evs)

    # Gate (a): the all-ones static path is bit-identical to no topology.
    evs0 = _evs(0, fractions[0])
    plain = _serve(evs0, 0)
    allones = _serve(
        evs0, 0, topology="static",
        cluster_topology=Topology.all_ones(n_racks, n_wl),
    )
    identical = (
        plain.mean_jct == allones.mean_jct
        and plain.makespan == allones.makespan
    )
    emit(
        "online_topology_allones_identity",
        0,
        f"plain_jct={plain.mean_jct:.4f};allones_jct={allones.mean_jct:.4f}"
        f";identical={identical}",
        kind="topology",
    )

    matching_never_worse = True
    arms = (
        ("static", dict(topology="static", cluster_topology=static_topo)),
        ("matching", dict(topology="matching", cluster_topology=base)),
        ("matching_outages", dict(topology="matching", cluster_topology=base)),
    )
    for frac in fractions:
        means = {tag: [] for tag, _ in arms}
        means["free"] = []
        reconfigs = flaps = 0
        for seed in range(n_seeds):
            evs = _evs(seed, frac)
            outages = link_outage_trace(
                seed, n_racks, n_wl, horizon,
                outage_rate=0.002, mean_downtime=30.0,
            )
            per_arm = {}
            t0 = time.perf_counter()
            # Unrestricted reference: no mask at all (full reachability).
            free = _serve(evs, seed)
            means["free"].append(free.mean_jct)
            for tag, kw in arms:
                extra = dict(outages=outages) if tag.endswith("outages") else {}
                res = _serve(evs, seed, **kw, **extra)
                per_arm[tag] = res
                means[tag].append(res.mean_jct)
            wall = time.perf_counter() - t0
            mt, st = per_arm["matching"], per_arm["static"]
            reconfigs += mt.n_reconfigs
            flaps += per_arm["matching_outages"].n_link_events
            emit(
                f"online_topology_wl{frac}_seed{seed}",
                1e6 * wall / ((len(arms) + 1) * n_jobs),
                f"free_jct={free.mean_jct:.1f}"
                f";static_jct={st.mean_jct:.1f}"
                f";matching_jct={mt.mean_jct:.1f}"
                f";outages_jct={per_arm['matching_outages'].mean_jct:.1f}"
                f";reconfigs={mt.n_reconfigs}"
                f";outage_reconfigs={per_arm['matching_outages'].n_reconfigs}"
                f";link_events={per_arm['matching_outages'].n_link_events}"
                f";static_wireless_util={st.wireless_utilization:.2f}"
                f";matching_wireless_util={mt.wireless_utilization:.2f}",
                kind="topology",
            )
        mean_of = {tag: float(np.mean(v)) for tag, v in means.items()}
        if mean_of["matching"] > mean_of["static"] + 1e-9:
            matching_never_worse = False
        emit(
            f"online_topology_wl{frac}_summary",
            0,
            f"free_mean_jct={mean_of['free']:.2f}"
            f";static_mean_jct={mean_of['static']:.2f}"
            f";matching_mean_jct={mean_of['matching']:.2f}"
            f";outages_mean_jct={mean_of['matching_outages']:.2f}"
            f";matching_reduction="
            f"{100 * (1 - mean_of['matching'] / mean_of['static']):.2f}%"
            f";reconfigs={reconfigs};link_events={flaps}",
            kind="topology",
        )
    return identical and matching_never_worse


# Stress lane configuration: a throughput-oriented serving setup — the
# greedy-list policy (per-job host heuristic, no engine launches) admits on
# residual capacity with overtaking, the timeline compacts every
# STRESS_COMPACT epochs, per-job records are elided, and the workload is a
# lazily streamed production trace. The flat-latency acceptance bound:
STRESS_LATENCY_RATIO = 1.5
STRESS_COMPACT = 8
STRESS_CLUSTER = dict(n_racks=8, n_wireless=2)
# Tracer-overhead acceptance bound for the --trace arm: a fully traced
# serve (spans + decision events + job marks every epoch) must finish
# within this factor of the untraced NullTracer serve.
TRACER_OVERHEAD_RATIO = 1.05


def _stress_serve(n_jobs: int, rate: float, seed: int, tracer=None):
    """One stress-lane serve (shared by the untraced and traced arms)."""
    evs = stream_production_arrivals(
        seed,
        rate=rate,
        n_jobs=n_jobs,
        n_racks=STRESS_CLUSTER["n_racks"],
        n_wireless=STRESS_CLUSTER["n_wireless"],
        min_rack_demand=3,
    )
    svc = OnlineScheduler(
        STRESS_CLUSTER["n_racks"],
        STRESS_CLUSTER["n_wireless"],
        window=5.0,
        policy="greedy_list",
        seed=seed,
        compact_interval=STRESS_COMPACT,
        record_jobs=False,
        track_epoch_latency=True,
        tracer=tracer,
    )
    t0 = time.perf_counter()
    res = svc.serve(evs)
    return res, time.perf_counter() - t0


def run_stress(
    n_jobs: int = 100_000,
    rate: float = 1 / 60,
    seed: int = 0,
    trace_out: str | None = None,
) -> tuple[float, float | None]:
    """Sustained-throughput stress lane; returns (flat-latency ratio,
    tracer-overhead ratio or None).

    Serves ``n_jobs`` streamed production arrivals end to end and measures
    the wall time of every epoch's arbitrate-and-commit stage. With the
    interval index compacting every ``STRESS_COMPACT`` epochs the
    steady-state cost depends only on *active* jobs, so the per-epoch
    commit latency must stay flat: the second-half mean is required to be
    within ``STRESS_LATENCY_RATIO`` x the first-half mean. Emits one
    ``kind="stress"`` BENCH record with the streaming p50/p90/p99
    queueing-delay and JCT percentiles and the peak gauges.

    With ``trace_out`` set, the same stream is served a second time under
    a live :class:`repro.obs.Tracer`, the Perfetto trace is written to
    ``trace_out``, and the record gains ``traced_wall_s`` /
    ``tracer_overhead`` fields; the simulated outcome must match the
    untraced serve exactly.
    """
    res, wall = _stress_serve(n_jobs, rate, seed)
    if res.n_jobs != n_jobs:
        raise RuntimeError(f"stress lane served {res.n_jobs}/{n_jobs} jobs")
    lat = res.epoch_commit_latency
    half = len(lat) // 2
    first = float(np.mean(lat[:half]))
    second = float(np.mean(lat[half:]))
    ratio = second / first if first > 0 else float("inf")
    tl = res.timeline
    derived = (
        f"n_jobs={res.n_jobs};n_epochs={res.n_epochs}"
        f";wall_s={wall:.1f};jobs_per_s={res.n_jobs / wall:.0f}"
        f";latency_ratio={ratio:.3f}"
        f";first_half_us={1e6 * first:.1f};second_half_us={1e6 * second:.1f}"
        f";queue_p50={res.p50_queueing_delay:.1f}"
        f";queue_p90={res.p90_queueing_delay:.1f}"
        f";queue_p99={res.p99_queueing_delay:.1f}"
        f";jct_p50={res.p50_jct:.1f};jct_p90={res.p90_jct:.1f}"
        f";jct_p99={res.p99_jct:.1f}"
        f";peak_active={res.peak_active};peak_queue={res.peak_queue_depth}"
        f";intervals_retained={tl.n_intervals}"
        f";intervals_compacted={tl.n_compacted}"
        f";rack_util={res.rack_utilization:.2f}"
        f";wired_util={res.wired_utilization:.2f}"
    )
    overhead = None
    if trace_out:
        from repro.obs import Tracer, write_chrome_trace

        tracer = Tracer()
        traced, traced_wall = _stress_serve(n_jobs, rate, seed, tracer=tracer)
        if (traced.n_jobs, traced.n_epochs, traced.horizon) != (
            res.n_jobs, res.n_epochs, res.horizon,
        ):
            raise RuntimeError("traced stress serve diverged from untraced")
        overhead = traced_wall / wall
        write_chrome_trace(tracer, trace_out)
        print(
            f"wrote Perfetto trace ({len(tracer.spans)} spans, "
            f"{len(tracer.job_marks)} job marks) -> {trace_out}",
            flush=True,
        )
        derived += (
            f";traced_wall_s={traced_wall:.1f}"
            f";tracer_overhead={overhead:.3f}"
        )
    emit(
        f"online_stress_greedy_list_{n_jobs // 1000}k",
        1e6 * wall / n_jobs,
        derived,
        kind="stress",
    )
    return ratio, overhead


def main(argv=None):
    from benchmarks import common

    parser = common.bench_arg_parser(__doc__)
    parser.add_argument(
        "--skip-sweep",
        action="store_true",
        help="run only the warm-vs-cold, admission-mode and "
        "arbitration-mode sections",
    )
    parser.add_argument(
        "--stress",
        action="store_true",
        help="run only the sustained-throughput stress lane and assert "
        "flat per-epoch commit latency",
    )
    parser.add_argument(
        "--stress-jobs",
        type=int,
        default=100_000,
        metavar="N",
        help="stress-lane stream length (CI smoke uses a reduced scale)",
    )
    parser.add_argument(
        "--trace",
        metavar="OUT.JSON",
        default=None,
        help="with --stress: serve the stream a second time under a live "
        "tracer, write the Chrome/Perfetto trace here, and gate the "
        f"tracer overhead at {TRACER_OVERHEAD_RATIO}x the untraced wall",
    )
    parser.add_argument(
        "--admission-slo",
        action="store_true",
        help="run only the SLO overload sweep (FIFO/EDF/defer/wfair "
        "admission under rates past saturation)",
    )
    parser.add_argument(
        "--topology",
        action="store_true",
        help="run only the reconfigurable-topology sweep (static vs "
        "per-epoch matching vs matching under link outages)",
    )
    parser.add_argument(
        "--smoke",
        action="store_true",
        help="with --admission-slo or --topology: reduced-scale smoke "
        "that exits non-zero on a policy regression (EDF vs FIFO misses, "
        "matching vs static JCT + all-ones bit-identity)",
    )
    args = parser.parse_args(argv)
    if args.topology:
        ok = run_topology_modes(smoke=args.smoke)
        if args.json:
            common.write_json(
                args.json,
                bench="online_serving_topology",
                config={"smoke": args.smoke},
            )
        if args.smoke and not ok:
            raise SystemExit(
                "topology smoke FAILED: all-ones static path diverged "
                "from the topology-free serve, or per-epoch matching's "
                "mean JCT exceeded the static configuration's"
            )
        if args.smoke:
            print(
                "topology smoke passed: all-ones static is bit-identical "
                "and matching mean JCT <= static at every smoke fraction",
                flush=True,
            )
        return
    if args.admission_slo or args.smoke:
        ok = run_admission_slo(smoke=args.smoke)
        if args.json:
            common.write_json(
                args.json,
                bench="online_serving_slo",
                config={"smoke": args.smoke},
            )
        # Only the reduced-scale smoke is a CI gate; the full sweep
        # deliberately includes extreme-overload rates where EDF's
        # domino effect can lose to FIFO (that regime is the table's
        # point, not a regression).
        if args.smoke and not ok:
            raise SystemExit(
                "admission SLO smoke FAILED: EDF missed more deadlines "
                "than FIFO under moderate overload"
            )
        if args.smoke:
            print("admission SLO smoke passed: EDF <= FIFO deadline "
                  "misses at every smoke rate", flush=True)
        return
    if args.stress:
        ratio, overhead = run_stress(
            n_jobs=args.stress_jobs, trace_out=args.trace
        )
        if args.json:
            common.write_json(
                args.json,
                bench="online_serving_stress",
                config={"n_jobs": args.stress_jobs,
                        "traced": args.trace is not None},
            )
        if ratio > STRESS_LATENCY_RATIO:
            raise SystemExit(
                f"flat-latency check FAILED: second-half mean commit latency "
                f"{ratio:.3f}x first-half (bound {STRESS_LATENCY_RATIO}x)"
            )
        print(
            f"flat-latency check passed: {ratio:.3f}x <= "
            f"{STRESS_LATENCY_RATIO}x",
            flush=True,
        )
        if overhead is not None:
            if overhead > TRACER_OVERHEAD_RATIO:
                raise SystemExit(
                    f"tracer-overhead check FAILED: traced serve "
                    f"{overhead:.3f}x untraced (bound "
                    f"{TRACER_OVERHEAD_RATIO}x)"
                )
            print(
                f"tracer-overhead check passed: {overhead:.3f}x <= "
                f"{TRACER_OVERHEAD_RATIO}x",
                flush=True,
            )
        return
    if not args.skip_sweep:
        run()
    run_warm_vs_cold()
    run_admission_modes()
    run_arbitration_modes()
    run_admission_slo()
    run_topology_modes()
    if args.json:
        common.write_json(args.json, bench="online_serving")


if __name__ == "__main__":
    main()