"""Fig. 5 reproduction: average wireless-augmentation gain vs network factor
ρ ∈ [0.1, 10], for different job sizes, with M = |V| racks (paper setting).

Expected qualitative shape (paper): gain rises with ρ then falls (at high ρ
the optimal collapses to a single rack where wireless cannot help); larger
jobs gain more; the second subchannel adds less than the first.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, emit
from repro.core import ProblemInstance, random_job, solve_bnb


def run(time_limit: float = 10.0):
    rhos = (0.1, 0.5, 1.0, 2.0, 5.0, 10.0)
    sizes = (6, 8) if not FULL else (6, 8, 10)
    seeds = 8 if FULL else 5
    rows = []
    for n in sizes:
        for rho in rhos:
            g1s, g2s = [], []
            for seed in range(seeds):
                rng = np.random.default_rng(2000 + seed)
                job = random_job(rng, None, n_tasks=n, rho=rho)
                base = solve_bnb(
                    ProblemInstance(job=job, n_racks=n, n_wireless=0),
                    time_limit=time_limit,
                ).makespan
                m1 = solve_bnb(
                    ProblemInstance(job=job, n_racks=n, n_wireless=1),
                    time_limit=time_limit,
                ).makespan
                m2 = solve_bnb(
                    ProblemInstance(job=job, n_racks=n, n_wireless=2),
                    time_limit=time_limit,
                ).makespan
                g1s.append(100 * (1 - m1 / base))
                g2s.append(100 * (1 - m2 / base))
            rows.append((n, rho, np.mean(g1s), np.mean(g2s)))
            emit(
                f"fig5_n{n}_rho{rho}",
                0.0,
                f"gain_1wl={np.mean(g1s):.2f}%;gain_2wl={np.mean(g2s):.2f}%",
            )
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
