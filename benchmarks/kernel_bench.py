"""Kernel micro-benchmarks.

NOTE (CPU container): Pallas kernels execute in interpret mode here, so
wall-clock numbers characterize the HOST fallback, not TPU performance —
TPU performance is assessed structurally via §Roofline. The jnp flash twin
is XLA-compiled and its timing is meaningful on this host.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timer
from repro.kernels import ops
from repro.models.flash import flash_attention as jnp_flash


def run():
    rng = np.random.default_rng(0)
    B, S, H, KV, D = 1, 1024, 8, 2, 128
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.bfloat16)

    f = jax.jit(lambda q, k, v: jnp_flash(q, k, v, True, 256))
    f(q, k, v).block_until_ready()
    _, t = timer(lambda: f(q, k, v).block_until_ready())
    flops = 2 * 2 * B * S * S * H * D / 2  # causal half
    emit("jnp_flash_fwd_1k", 1e6 * t, f"gflops_s={flops / t / 1e9:.1f}")

    _, t = timer(
        lambda: ops.flash_attention(q, k, v, True, 128, 128).block_until_ready(),
        repeats=1,
    )
    emit("pallas_flash_interpret_1k", 1e6 * t, "interpret-mode(host)")

    qd = jnp.asarray(rng.standard_normal((4, H, D)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((4, 4096, KV, D)), jnp.float32)
    _, t = timer(
        lambda: ops.decode_attention(qd, kd, kd, jnp.int32(4096)).block_until_ready(),
        repeats=1,
    )
    emit("pallas_decode_interpret_4k", 1e6 * t, "interpret-mode(host)")

    w = jnp.asarray(
        np.where(np.triu(np.ones((4096, 16, 16)), 1), 5.0, -np.inf), jnp.float32
    )
    _, t = timer(lambda: ops.batched_critical_path(w).block_until_ready(), repeats=1)
    emit("pallas_cpm_interpret_4096x16", 1e6 * t, "interpret-mode(host)")
    _, t = timer(
        lambda: ops.batched_critical_path(w, block_b=256).block_until_ready(),
        repeats=1,
    )
    emit("pallas_cpm_interpret_4096x16_bb256", 1e6 * t, "interpret-mode(host)")


def run_search_engine():
    """The two stages of the vectorized search substrate on one size bucket."""
    from repro.core import ProblemInstance, random_job
    from repro.core.vectorized import (
        batched_lower_bound,
        make_batched_evaluator,
        sample_assignments,
    )

    rng = np.random.default_rng(0)
    job = random_job(rng, None, n_tasks=10, rho=0.5)
    inst = ProblemInstance(job=job, n_racks=6, n_wireless=1)
    racks = sample_assignments(rng, 10, 6, 8192)

    evaluate = make_batched_evaluator(inst)
    np.asarray(evaluate(racks))  # compile the bucket
    _, t = timer(lambda: np.asarray(evaluate(racks)))
    emit("optable_scan_eval_8192xN10", 1e6 * t, f"cands_per_s={racks.shape[0] / t:.0f}")

    batched_lower_bound(inst, racks, use_kernel=True)  # compile the bucket
    _, t = timer(lambda: batched_lower_bound(inst, racks, use_kernel=True))
    emit("pallas_cpm_lb_8192xN10", 1e6 * t, f"cands_per_s={racks.shape[0] / t:.0f}")

    _, t = timer(lambda: batched_lower_bound(inst, racks, use_kernel=False))
    emit("edgelist_lb_8192xN10", 1e6 * t, f"cands_per_s={racks.shape[0] / t:.0f}")


def main():
    run()
    run_search_engine()


if __name__ == "__main__":
    main()
