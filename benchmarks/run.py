"""Benchmark harness: one module per paper table/figure plus framework
benches. Prints ``name,us_per_call,derived`` CSV lines.

  fig4_jct_vs_racks  — paper Fig. 4 (JCT vs racks, baselines ± wireless)
  fig5_gain_vs_factor — paper Fig. 5 (gain vs network factor)
  solver_scaling     — §IV-D decomposition / solver comparison
  plan_gain          — beyond-paper scheduler->training integration
  kernel_bench       — Pallas kernels (interpret on CPU; see §Roofline for TPU)
  train_bench        — end-to-end smoke train step

REPRO_BENCH_FULL=1 enables the paper-scale sweeps.
"""

from __future__ import annotations

import time
import traceback


def main() -> None:
    from benchmarks import (
        fig4_jct_vs_racks,
        fig5_gain_vs_factor,
        kernel_bench,
        plan_gain,
        solver_scaling,
        train_bench,
    )

    print("name,us_per_call,derived")
    for mod in (
        fig4_jct_vs_racks,
        fig5_gain_vs_factor,
        solver_scaling,
        plan_gain,
        kernel_bench,
        train_bench,
    ):
        t0 = time.perf_counter()
        try:
            mod.run()
            print(
                f"_section_{mod.__name__.split('.')[-1]},"
                f"{1e6 * (time.perf_counter() - t0):.0f},ok"
            )
        except Exception:  # noqa: BLE001 — keep the harness running
            traceback.print_exc()
            print(f"_section_{mod.__name__.split('.')[-1]},0,FAILED")


if __name__ == "__main__":
    main()
