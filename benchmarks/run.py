"""Benchmark harness: one module per paper table/figure plus framework
benches. Prints ``name,us_per_call,derived`` CSV lines; ``--json out.json``
additionally writes the machine-readable ``BENCH`` record (see
``benchmarks/common.py``) so the perf trajectory is tracked across PRs.

  fig4_jct_vs_racks  — paper Fig. 4 (JCT vs racks, baselines ± wireless)
  fig5_gain_vs_factor — paper Fig. 5 (gain vs network factor)
  solver_scaling     — §IV-D decomposition / solver comparison
  online_serving     — arrival-driven serving: JCT/throughput vs rate
  plan_gain          — beyond-paper scheduler->training integration
  kernel_bench       — Pallas kernels (interpret on CPU; see §Roofline for TPU)
  train_bench        — end-to-end smoke train step

REPRO_BENCH_FULL=1 enables the paper-scale sweeps.
"""

from __future__ import annotations

import time
import traceback


def main(argv=None) -> None:
    from benchmarks import (
        common,
        fig4_jct_vs_racks,
        fig5_gain_vs_factor,
        kernel_bench,
        online_serving,
        plan_gain,
        solver_scaling,
        train_bench,
    )

    args = common.bench_arg_parser(__doc__).parse_args(argv)
    print("name,us_per_call,derived")
    for mod in (
        fig4_jct_vs_racks,
        fig5_gain_vs_factor,
        solver_scaling,
        online_serving,
        plan_gain,
        kernel_bench,
        train_bench,
    ):
        t0 = time.perf_counter()
        try:
            mod.run()
            common.emit(
                f"_section_{mod.__name__.split('.')[-1]}",
                1e6 * (time.perf_counter() - t0),
                "ok",
            )
        except Exception:  # noqa: BLE001 — keep the harness running
            traceback.print_exc()
            common.emit(f"_section_{mod.__name__.split('.')[-1]}", 0, "FAILED")
    if args.json:
        common.write_json(args.json, bench="all")


if __name__ == "__main__":
    main()
