"""Docs lane: execute documentation code snippets and check intra-repo links.

Checks, over ``README.md`` and ``docs/*.md``:

  1. every fenced ```python block runs (blocks in one file share a
     namespace, in order, like a doctest session);
  2. every relative markdown link ``[text](path)`` resolves to a file or
     directory in the repo (http/mailto/anchor links are skipped).

Run from the repo root (CI's docs lane, and ``tests/test_docs.py``):

    PYTHONPATH=src python tools/check_docs.py

Exit code 0 iff all snippets ran and all links resolve.
"""

from __future__ import annotations

import re
import sys
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _rel(path: Path) -> str:
    try:
        return str(path.relative_to(REPO))
    except ValueError:
        return str(path)

FENCE_RE = re.compile(r"^```(\w*)\s*$")
# [text](target) — excluding images' srcs being dirs is fine; skip ![
LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files() -> list[Path]:
    docs = [REPO / "README.md"]
    docs += sorted((REPO / "docs").glob("*.md"))
    return [p for p in docs if p.exists()]


def iter_code_blocks(text: str):
    """Yield (first_line_number, language, source) for fenced blocks."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if not m:
            i += 1
            continue
        lang = m.group(1).lower()
        start = i + 1
        j = start
        while j < len(lines) and not lines[j].startswith("```"):
            j += 1
        yield start + 1, lang, "\n".join(lines[start:j])
        i = j + 1


def check_snippets(path: Path) -> list[str]:
    """Run the file's python blocks in one shared namespace, in order."""
    errors: list[str] = []
    ns: dict = {"__name__": f"docsnippet_{path.stem}"}
    for lineno, lang, src in iter_code_blocks(path.read_text()):
        if lang != "python":
            continue
        try:
            exec(compile(src, f"{path.name}:{lineno}", "exec"), ns)
        except Exception:
            errors.append(
                f"{_rel(path)}:{lineno}: snippet failed:\n"
                + traceback.format_exc(limit=3)
            )
    return errors


def check_links(path: Path) -> list[str]:
    errors: list[str] = []
    text = path.read_text()
    for lineno, line in enumerate(text.splitlines(), start=1):
        for target in LINK_RE.findall(line):
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                errors.append(
                    f"{_rel(path)}:{lineno}: broken link -> {target}"
                )
    return errors


def main() -> int:
    files = doc_files()
    if not files:
        print("no documentation files found", file=sys.stderr)
        return 1
    errors: list[str] = []
    for path in files:
        errors += check_links(path)
    # Links first (cheap); then snippets, which may import jax etc.
    for path in files:
        errors += check_snippets(path)
    for e in errors:
        print(e, file=sys.stderr)
    n_py = sum(
        1
        for p in files
        for _, lang, _src in iter_code_blocks(p.read_text())
        if lang == "python"
    )
    print(
        f"checked {len(files)} docs, {n_py} python snippets: "
        + ("FAIL" if errors else "ok")
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
