"""Offline trace analyzer: the debugging entry point for serving traces.

Loads a Chrome/Perfetto trace written by
``repro.obs.export.write_chrome_trace`` (e.g. by
``benchmarks/online_serving.py --stress --trace out.json``) and prints:

  1. the per-epoch latency breakdown (collect / plan / commit wall time);
  2. the top-k slowest jobs with their queueing attribution — admission
     queueing vs the ``makespan - solver_makespan`` cross-job channel
     gap, split by wired/wireless resource;
  3. optionally, the full decision audit trail for one job id
     (``--job N``): every admission reorder, rejection proof, backfill
     verdict, and arbitration order that touched it.

``--json OUT.json`` additionally writes the same report (per-epoch
breakdown, commit-latency total, top-k slow jobs, optional audit) as a
machine-readable JSON document for dashboards and regression scripts.

Usage (from the repo root):

    PYTHONPATH=src python tools/trace_report.py out.json [--top 10] \
        [--job 42] [--json report.json]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs.report import load_trace, render_report, report_dict  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Perfetto trace JSON written by --trace")
    ap.add_argument(
        "--top", type=int, default=5, help="slowest jobs to show (default 5)"
    )
    ap.add_argument(
        "--job", type=int, default=None, help="print the decision audit for this job id"
    )
    ap.add_argument(
        "--json",
        default=None,
        metavar="OUT.json",
        help="also write the report as machine-readable JSON to this path",
    )
    args = ap.parse_args(argv)
    trace = load_trace(args.trace)
    print(render_report(trace, top=args.top, job=args.job))
    if args.json:
        doc = report_dict(trace, top=args.top, job=args.job)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
