"""Online arrival-driven serving (the paper's §V production scenario):
jobs arrive over time, queue for residual cluster capacity, and are
(re-)optimized in windowed `schedule_fleet` mega-batches. Queued jobs are
re-planned every epoch with warm-started search (incumbent seed pools +
keep-incumbent commits), and the same trace is replayed under the online
FIFO-solo and greedy-list baselines for comparison. A final O(active)
pass re-serves the trace from a lazy arrival stream with interval-index
compaction and streaming-only stats — bit-identical JCTs, O(1) memory.

Run:  PYTHONPATH=src python examples/serve_jobs.py
"""

from repro.online import (
    OnlineScheduler,
    production_arrivals,
    stream_production_arrivals,
)

CLUSTER = dict(n_racks=6, n_wireless=2)
SOLVER = dict(
    max_enumerate=64, n_samples=64, batch_size=256,
    refine_rounds=2, refine_pool=96, strategies="portfolio",
)


def main() -> None:
    arrivals = production_arrivals(
        seed=0, rate=1 / 40, n_jobs=10, min_rack_demand=4, **CLUSTER
    )
    print(
        f"production-mix trace: {len(arrivals)} jobs over "
        f"{arrivals[-1].time:.0f} time units on a "
        f"{CLUSTER['n_racks']}-rack / {CLUSTER['n_wireless']}-subchannel cluster"
    )

    service = dict(
        window=5.0, require_full_demand=True, preserve_order=True,
        solver_kwargs=SOLVER, seed=0,
    )
    svc = OnlineScheduler(
        CLUSTER["n_racks"], CLUSTER["n_wireless"], warm_start=True, **service
    )
    res = svc.serve(arrivals)

    print("\n  id family              arrive  admit  racks  makespan  queue     JCT")
    for j in res.jobs:
        print(
            f"  {j.job_id:2d} {j.family:<19s} {j.arrival:6.0f} {j.admitted:6.0f} "
            f"{j.n_racks_granted:5d} {j.makespan:9.1f} {j.queueing_delay:6.1f} "
            f"{j.jct:7.1f}  ({j.n_solves} solve{'s' if j.n_solves > 1 else ''})"
        )
    print(f"\nfleet (warm): {res.summary()}")
    print(
        f"    queue p50/p90/p99 = {res.p50_queueing_delay:.1f}/"
        f"{res.p90_queueing_delay:.1f}/{res.p99_queueing_delay:.1f}, "
        f"jct p50/p90/p99 = {res.p50_jct:.1f}/{res.p90_jct:.1f}/"
        f"{res.p99_jct:.1f}, peak active {res.peak_active}, "
        f"peak queue {res.peak_queue_depth}"
    )
    res.timeline.assert_feasible(full=True)  # committed timeline is channel-feasible

    # Channel-proven backfilling: overtake the blocked head-of-line job
    # only when arbitration proves its admission epoch cannot slip.
    bf = OnlineScheduler(
        CLUSTER["n_racks"], CLUSTER["n_wireless"], warm_start=True,
        backfill=True, **service,
    ).serve(arrivals)
    print(
        f"    backfill: mean JCT {bf.mean_jct:7.1f} "
        f"({100 * (bf.mean_jct / res.mean_jct - 1):+.1f}% vs FIFO), "
        f"{bf.n_backfilled} backfilled, "
        f"{bf.n_backfill_rejected} candidates rejected by the no-delay proof"
    )

    for policy in ("greedy_list", "fifo_solo"):
        base = OnlineScheduler(
            CLUSTER["n_racks"], CLUSTER["n_wireless"], policy=policy, **service
        ).serve(arrivals)
        print(
            f"{policy:>12s}: mean JCT {base.mean_jct:7.1f} "
            f"(+{100 * (base.mean_jct / res.mean_jct - 1):.1f}% vs fleet), "
            f"p95 {base.p95_jct:.1f}, queue {base.mean_queueing_delay:.1f}"
        )

    # O(active) serving: same trace as a lazy stream, compaction on,
    # per-job records elided — the committed schedule is bit-identical.
    stream = stream_production_arrivals(
        seed=0, rate=1 / 40, n_jobs=10, min_rack_demand=4, **CLUSTER
    )
    lean = OnlineScheduler(
        CLUSTER["n_racks"], CLUSTER["n_wireless"], warm_start=True,
        compact_interval=4, record_jobs=False, **service,
    ).serve(stream)
    assert abs(lean.mean_jct - res.mean_jct) < 1e-9
    print(
        f"   streaming: mean JCT {lean.mean_jct:7.1f} (bit-identical), "
        f"{lean.timeline.n_compacted} intervals compacted, "
        f"{lean.timeline.n_intervals} retained"
    )


if __name__ == "__main__":
    main()
