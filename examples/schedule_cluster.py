"""Periodic multi-job cluster scheduling (the paper's production scenario):
a day's worth of periodic jobs ([15]-style workload) scheduled one by one on
a hybrid DCN, comparing wired-only against wireless-augmented operation and
a straggler re-plan.

Run:  PYTHONPATH=src python examples/schedule_cluster.py
"""

import numpy as np

from repro.core import ProblemInstance, random_job, solve_bnb, vectorized_search, wired_only
from repro.distribution.plan import LinkSpec, backward_profile, replan
from repro.configs import get_config


def main() -> None:
    rng = np.random.default_rng(42)
    n_jobs = 8
    total0, total2, totalv, proved = 0.0, 0.0, 0.0, 0
    pruned, considered = 0, 0
    print(f"scheduling {n_jobs} periodic jobs (tasks ~ U[5,10], rho=0.5) ...")
    for j in range(n_jobs):
        job = random_job(np.random.default_rng(100 + j), None, rho=0.5)
        inst = ProblemInstance(job=job, n_racks=8, n_wireless=2)
        r0 = solve_bnb(wired_only(inst), time_limit=10)
        r2 = solve_bnb(inst, time_limit=10)
        rv = vectorized_search(inst, max_enumerate=20_000)
        total0 += r0.makespan
        total2 += r2.makespan
        totalv += rv.makespan
        proved += r2.proved_optimal
        pruned += rv.n_pruned
        considered += rv.n_candidates
        print(
            f"  job {j}: |V|={job.n_tasks:2d} wired={r0.makespan:7.1f} "
            f"+wireless={r2.makespan:7.1f} "
            f"gain={100 * (1 - r2.makespan / r0.makespan):5.1f}% "
            f"batch-search={rv.makespan:7.1f} "
            f"(pruned {rv.n_pruned}/{rv.n_candidates})"
        )
    print(
        f"\nfleet: avg wired JCT={total0 / n_jobs:.1f}, augmented="
        f"{total2 / n_jobs:.1f} ({100 * (1 - total2 / total0):.1f}% reduction, "
        f"{proved}/{n_jobs} proved optimal); batch engine avg JCT="
        f"{totalv / n_jobs:.1f} with {pruned}/{considered} candidates LB-pruned"
    )

    # Straggler mitigation on the training-integration side.
    cfg = get_config("llama3_2_3b")
    g_secs, g_bytes = backward_profile(cfg, tokens_per_device=4096)
    healthy = replan(g_secs, g_bytes, LinkSpec())
    degraded = replan(g_secs, g_bytes, LinkSpec(), compute_slowdown=1.6, degraded_aux=1)
    print(
        f"\nstraggler re-plan: healthy step {healthy.t_optimal:.3f}s -> "
        f"degraded pod (1.6x compute, 1 aux circuit lost) {degraded.t_optimal:.3f}s; "
        f"schedule re-derived in-flight (fault-tolerance hook)"
    )


if __name__ == "__main__":
    main()
