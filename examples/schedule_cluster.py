"""Periodic multi-job cluster scheduling (the paper's production scenario):
a day's worth of periodic jobs ([15]-style workload) on a hybrid DCN. The
heterogeneous fleet is solved in ONE padded mega-batch (`schedule_fleet`:
shared launches + combined §IV-A LB pruning across all jobs at once),
with the full refinement portfolio (mutation + elite crossover +
simulated annealing under the yield-driven allocator) polishing the
sampled-regime jobs, cross-checked per job against exact B&B under
wired-only vs wireless-augmented operation, plus a straggler re-plan.

Run:  PYTHONPATH=src python examples/schedule_cluster.py
"""

import numpy as np

from repro.core import ProblemInstance, random_job, schedule_fleet, solve_bnb, wired_only
from repro.distribution.plan import LinkSpec, backward_profile, replan
from repro.configs import get_config


def main() -> None:
    n_jobs = 8
    total0, total2, proved = 0.0, 0.0, 0
    print(f"scheduling {n_jobs} periodic jobs (tasks ~ U[5,10], rho=0.5) ...")
    insts = []
    for j in range(n_jobs):
        job = random_job(np.random.default_rng(100 + j), None, rho=0.5)
        insts.append(ProblemInstance(job=job, n_racks=8, n_wireless=2))

    # The whole heterogeneous fleet in one mega-batch search; sampled-regime
    # jobs get the full strategy portfolio for refinement.
    fleet = schedule_fleet(
        insts, max_enumerate=20_000, n_samples=2048, strategies="portfolio"
    )

    for j, (inst, rv) in enumerate(zip(insts, fleet.results)):
        r0 = solve_bnb(wired_only(inst), time_limit=10)
        r2 = solve_bnb(inst, time_limit=10)
        total0 += r0.makespan
        total2 += r2.makespan
        proved += r2.proved_optimal
        print(
            f"  job {j}: |V|={inst.job.n_tasks:2d} wired={r0.makespan:7.1f} "
            f"+wireless={r2.makespan:7.1f} "
            f"gain={100 * (1 - r2.makespan / r0.makespan):5.1f}% "
            f"fleet-search={rv.makespan:7.1f} "
            f"(pruned {rv.n_pruned}/{rv.n_candidates})"
        )
    print(
        f"\nfleet: avg wired JCT={total0 / n_jobs:.1f}, augmented="
        f"{total2 / n_jobs:.1f} ({100 * (1 - total2 / total0):.1f}% reduction, "
        f"{proved}/{n_jobs} proved optimal); mega-batch engine avg JCT="
        f"{float(fleet.makespans.mean()):.1f} with "
        f"{fleet.n_pruned}/{fleet.n_candidates} candidates LB-pruned in "
        f"{fleet.n_stage1_launches}+{fleet.n_stage2_launches} shared launches "
        f"({fleet.n_stage1_traces}+{fleet.n_stage2_traces} program traces)"
    )
    if fleet.strategy_stats:
        counters = "; ".join(
            f"{name}: {s.evaluated} evaluated, {s.improved} improving, "
            f"yield={s.yield_per_eval:.3f}, w={s.weight:.2f}"
            for name, s in sorted(fleet.strategy_stats.items())
        )
        print(f"refinement portfolio: {counters}")

    # Straggler mitigation on the training-integration side.
    cfg = get_config("llama3_2_3b")
    g_secs, g_bytes = backward_profile(cfg, tokens_per_device=4096)
    healthy = replan(g_secs, g_bytes, LinkSpec())
    degraded = replan(g_secs, g_bytes, LinkSpec(), compute_slowdown=1.6, degraded_aux=1)
    print(
        f"\nstraggler re-plan: healthy step {healthy.t_optimal:.3f}s -> "
        f"degraded pod (1.6x compute, 1 aux circuit lost) {degraded.t_optimal:.3f}s; "
        f"schedule re-derived in-flight (fault-tolerance hook)"
    )


if __name__ == "__main__":
    main()
