"""Batched serving example: prefill a batch of prompts, then decode with the
KV cache through the serve_step — the path the decode_32k/long_500k dry-run
cells lower at production scale.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models.lm import build_model
from repro.runtime.steps import build_serve_step


def main() -> None:
    cfg = smoke_config("llama3_2_3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    B, prompt_len, gen_len = 4, 16, 24
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, prompt_len)), jnp.int32
    )

    # Prefill: run the prompt through the cache via decode steps (teacher
    # forcing); production prefill lowers model.prefill instead.
    cache = model.init_cache(B, prompt_len + gen_len + 1)
    serve_step = jax.jit(build_serve_step(model))
    for t in range(prompt_len):
        logits, cache = serve_step(params, cache, prompts[:, t])

    tokens = [jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)]
    t0 = time.perf_counter()
    for _ in range(gen_len - 1):
        logits, cache = serve_step(params, cache, tokens[-1])
        tokens.append(jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32))
    dt = time.perf_counter() - t0
    out = jnp.stack(tokens, axis=1)
    print(f"prompts  : {np.asarray(prompts)[:, :8]}...")
    print(f"generated: {np.asarray(out)}")
    print(
        f"{B} sequences x {gen_len} tokens in {dt:.2f}s "
        f"({B * gen_len / dt:.1f} tok/s on host CPU, batched KV-cache decode)"
    )


if __name__ == "__main__":
    main()
