"""Quickstart: solve one hybrid-DCN joint scheduling instance end to end.

Builds a production-style DAG job, solves it optimally with and without
wireless bandwidth augmentation (the paper's core experiment), executes both
schedules in the discrete-event simulator, and prints the verified timeline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    ProblemInstance,
    check_feasible,
    g_list_schedule,
    lower_bound,
    make_onestage_mapreduce,
    solve_bnb,
    upper_bound,
    wired_only,
)


def main() -> None:
    rng = np.random.default_rng(7)
    job = make_onestage_mapreduce(rng, n_map=4, n_reduce=2, rho=1.0)
    inst = ProblemInstance(job=job, n_racks=4, n_wireless=2)

    print(f"job: {job.n_tasks} tasks, {job.n_edges} edges (one-stage MapReduce)")
    print(f"bounds: T_min={lower_bound(inst):.1f}  T_max={upper_bound(inst):.1f}")

    heur = g_list_schedule(inst, use_wireless=True)
    print(f"G-List heuristic:            {heur.makespan:8.2f}")

    opt0 = solve_bnb(wired_only(inst), time_limit=30)
    print(f"optimal, wired only:         {opt0.makespan:8.2f} "
          f"(proved={opt0.proved_optimal})")

    opt2 = solve_bnb(inst, time_limit=30)
    print(f"optimal, +2 wireless:        {opt2.makespan:8.2f} "
          f"(proved={opt2.proved_optimal})")
    gain = 100 * (1 - opt2.makespan / opt0.makespan)
    print(f"wireless augmentation gain:  {gain:8.1f}%")

    # Independently verify both schedules against OP's constraints.
    check_feasible(inst, opt2.schedule)
    check_feasible(wired_only(inst), opt0.schedule)
    print("\ntimeline (optimal with wireless):")
    s = opt2.schedule
    for v in np.argsort(s.start):
        print(f"  task {v}: rack {s.rack[v]}  t=[{s.start[v]:7.2f}, "
              f"{s.start[v] + job.p[v]:7.2f})")
    names = {0: "wired", 1: "local"}
    for e in range(job.n_edges):
        u, v = job.edges[e]
        ch = names.get(int(s.chan[e]), f"wireless{int(s.chan[e]) - 2}")
        print(f"  edge {u}->{v}: {ch:10s} start={s.tstart[e]:7.2f}")


if __name__ == "__main__":
    main()
