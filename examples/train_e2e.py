"""End-to-end training driver: ~100M-parameter llama-family model trained
for a few hundred steps on the synthetic pipeline, with checkpointing and
the scheduler-planned gradient-reduction schedule printed up front.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--dim 256]

On this CPU container the default is a reduced width; pass --dim 768
--layers 12 for the full ~100M configuration if you have the patience (or a
real accelerator).
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distribution.plan import LinkSpec, backward_profile, plan_gradient_schedule
from repro.models.lm import build_model, count_params
from repro.optim.adamw import AdamWConfig
from repro.runtime.steps import build_train_step, make_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("llama3_2_3b"),
        n_layers=args.layers,
        d_model=args.dim,
        n_heads=max(4, args.dim // 64),
        n_kv_heads=max(2, args.dim // 128),
        head_dim=64,
        d_ff=args.dim * 4,
        vocab_size=4096,
    )
    model = build_model(cfg)
    state = make_train_state(model, jax.random.PRNGKey(0))
    n_params = count_params(state.params)
    print(f"model: {cfg.n_layers}L d={cfg.d_model} params={n_params:,}")

    # Paper-solver communication plan for this model's backward pass.
    g_secs, g_bytes = backward_profile(cfg, tokens_per_device=args.batch * args.seq)
    plan = plan_gradient_schedule(g_secs, g_bytes, LinkSpec(), time_limit=3.0)
    print(
        f"reduction plan: {100 * plan.gain_vs_serial:.1f}% faster than serial, "
        f"buckets->channels {plan.channel_of_bucket.tolist()} "
        f"(proved={plan.proved_optimal})"
    )

    data = make_pipeline(
        DataConfig(vocab_size=cfg.vocab_size, global_batch=args.batch, seq_len=args.seq)
    )
    opt = AdamWConfig(
        lr_peak=3e-3, lr_min=3e-4, warmup_steps=20, total_steps=args.steps
    )
    step = jax.jit(build_train_step(model, opt, n_micro=2))

    start = 0
    if ckpt.latest_step(args.ckpt_dir) is not None:
        restored, start = ckpt.restore(args.ckpt_dir, jax.tree.map(np.asarray, state))
        state = jax.tree.map(jnp.asarray, restored)
        print(f"resumed from checkpoint at step {start}")

    t0 = time.perf_counter()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_for_step(s).items()}
        state, metrics = step(state, batch)
        if s % 20 == 0 or s == args.steps - 1:
            dt = time.perf_counter() - t0
            print(
                f"step {s:4d}  loss={float(metrics['loss']):.4f}  "
                f"gnorm={float(metrics['grad_norm']):.3f}  "
                f"lr={float(metrics['lr']):.2e}  [{dt:.1f}s]"
            )
        if s and s % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, s, jax.tree.map(np.asarray, state))
            print(f"checkpointed step {s}")
    print("done.")


if __name__ == "__main__":
    main()
