"""Online serving layer contracts: workload generator reproducibility and
feasibility, cluster-timeline residual/commit semantics, the degenerate
reduction of the service to one ``schedule_fleet`` call, event-loop
conservation properties, the warm-start seed-pool hook (budget
neutrality and never-worse), the portfolio allocator's ``yield_decay``
option, online baselines, and the benchmark JSON emitter."""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import (
    ONLINE_BASELINES,
    ProblemInstance,
    check_feasible,
    g_list_schedule,
    random_job,
    schedule_fleet,
    vectorized_search,
)
from repro.core.dag import make_onestage_mapreduce
from repro.core.portfolio import Portfolio, build_strategies
from repro.online import (
    ClusterTimeline,
    OnlineScheduler,
    poisson_arrivals,
    production_arrivals,
    trace_arrivals,
)

FAST_SOLVER = dict(
    max_enumerate=500, n_samples=128, batch_size=256,
    refine_rounds=2, refine_pool=128,
)
SAMPLED_SOLVER = dict(
    max_enumerate=64, n_samples=64, batch_size=256,
    refine_rounds=2, refine_pool=96, strategies="portfolio",
)


# ---------------------------------------------------------------------------
# Workload generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gen", ["poisson", "production"])
def test_workload_streams_are_reproducible(gen):
    make = {
        "poisson": lambda s: poisson_arrivals(s, rate=0.02, n_jobs=12),
        "production": lambda s: production_arrivals(s, rate=0.02, n_jobs=12),
    }[gen]
    a, b = make(7), make(7)
    c = make(8)
    assert len(a) == len(b) == 12
    for ea, eb in zip(a, b):
        assert ea.time == eb.time and ea.family == eb.family
        assert np.array_equal(ea.inst.job.p, eb.inst.job.p)
        assert np.array_equal(ea.inst.job.edges, eb.inst.job.edges)
        assert np.array_equal(ea.inst.job.d, eb.inst.job.d)
        assert ea.inst.n_racks == eb.inst.n_racks
    assert any(x.time != y.time for x, y in zip(a, c))  # seed matters


@pytest.mark.parametrize("gen", ["poisson", "production", "trace"])
def test_workload_times_sorted_nonnegative_and_ids_unique(gen):
    if gen == "trace":
        jobs = [random_job(np.random.default_rng(s), None) for s in range(6)]
        evs = trace_arrivals([5.0, 1.0, 3.0, 0.0, 9.0, 2.0], jobs)
    elif gen == "poisson":
        evs = poisson_arrivals(3, rate=0.05, n_jobs=10)
    else:
        evs = production_arrivals(3, rate=0.05, n_jobs=10)
    times = [e.time for e in evs]
    assert times == sorted(times)
    assert all(t >= 0.0 for t in times)
    assert sorted(e.job_id for e in evs) == list(range(len(evs)))


def test_workload_instances_pass_check_feasible_on_greedy_schedule():
    evs = poisson_arrivals(1, rate=0.05, n_jobs=5) + production_arrivals(
        1, rate=0.05, n_jobs=5
    )
    for e in evs:
        sched = g_list_schedule(e.inst, use_wireless=True)
        assert check_feasible(e.inst, sched) == sched.makespan
        assert sched.makespan > 0.0


def test_production_mix_covers_families_and_demands():
    evs = production_arrivals(0, rate=0.05, n_jobs=40, n_racks=6, min_rack_demand=4)
    fams = {e.family for e in evs}
    assert fams == {"simple_mapreduce", "onestage_mapreduce", "random_workflow"}
    demands = {e.inst.n_racks for e in evs}
    assert demands <= {4, 5, 6} and len(demands) > 1
    assert 5 <= min(e.inst.job.n_tasks for e in evs)
    assert max(e.inst.job.n_tasks for e in evs) <= 10


def test_trace_arrivals_validation():
    jobs = [random_job(np.random.default_rng(0), None)]
    with pytest.raises(ValueError):
        trace_arrivals([1.0, 2.0], jobs)  # length mismatch
    with pytest.raises(ValueError):
        trace_arrivals([-1.0], jobs)  # negative time


# ---------------------------------------------------------------------------
# Cluster timeline
# ---------------------------------------------------------------------------

def test_cluster_residual_and_commit_roundtrip():
    cl = ClusterTimeline(n_racks=4, n_wireless=2)
    inst = ProblemInstance(
        job=random_job(np.random.default_rng(0), None, n_tasks=6),
        n_racks=3,
        n_wireless=2,
    )
    view = cl.residual_view(inst, 0.0)
    assert view.full and view.inst.n_racks == 3 and view.inst.n_wireless == 2
    assert list(view.rack_map) == [0, 1, 2]
    sched = g_list_schedule(view.inst, use_wireless=True)
    comp = cl.commit(view, sched, t=10.0)
    assert comp == 10.0 + sched.makespan
    # Racks the job used are held past t=10; rack 3 stays free.
    used = sorted({int(view.rack_map[r]) for r in sched.rack})
    free_now = set(cl.free_racks(10.0).tolist())
    assert not (set(used) & free_now) and 3 in free_now
    # After the completion everything is free again.
    assert cl.free_racks(comp + 1e-6).size == 4
    assert cl.free_wireless(comp + 1e-6).size == 2


def test_cluster_rack_pool_grants_are_exclusive():
    cl = ClusterTimeline(n_racks=6, n_wireless=1)
    inst = ProblemInstance(
        job=random_job(np.random.default_rng(1), None, n_tasks=5),
        n_racks=4,
        n_wireless=1,
    )
    pool = cl.free_racks(0.0)
    v1 = cl.residual_view(inst, 0.0, rack_pool=pool)
    pool = pool[v1.inst.n_racks:]
    v2 = cl.residual_view(inst, 0.0, rack_pool=pool)
    assert list(v1.rack_map) == [0, 1, 2, 3]
    assert list(v2.rack_map) == [4, 5] and v2.inst.n_racks == 2 and not v2.full
    assert cl.residual_view(inst, 0.0, rack_pool=pool[2:]) is None


def test_cluster_wireless_pool_grants_are_exclusive():
    """Wireless subchannels are granted from a shrinking per-epoch pool
    exactly like racks: co-admitted jobs get disjoint physical
    subchannels, and an exhausted pool degrades later jobs to wired-only
    (the PR 4 model handed every free subchannel to each co-admitted
    job)."""
    cl = ClusterTimeline(n_racks=8, n_wireless=3)
    inst = ProblemInstance(
        job=random_job(np.random.default_rng(2), None, n_tasks=5),
        n_racks=2,
        n_wireless=2,
    )
    pool, pool_w = cl.free_racks(0.0), cl.free_wireless(0.0)
    v1 = cl.residual_view(inst, 0.0, rack_pool=pool, wireless_pool=pool_w)
    pool, pool_w = pool[v1.inst.n_racks:], pool_w[v1.inst.n_wireless:]
    v2 = cl.residual_view(inst, 0.0, rack_pool=pool, wireless_pool=pool_w)
    pool, pool_w = pool[v2.inst.n_racks:], pool_w[v2.inst.n_wireless:]
    v3 = cl.residual_view(inst, 0.0, rack_pool=pool, wireless_pool=pool_w)
    assert list(v1.wireless_map) == [0, 1] and v1.full
    assert list(v2.wireless_map) == [2] and v2.inst.n_wireless == 1 and not v2.full
    assert list(v3.wireless_map) == [] and v3.inst.n_wireless == 0  # wired-only


def test_arbitration_sequences_cross_job_wired_transfers():
    """Two jobs committed at the same epoch whose engine schedules both
    use the wired channel from local time 0: arbitration must shift the
    second job's transfers into the gaps left by the first — committed
    wired windows are disjoint (the audit), the second job's completion
    reflects the shift exactly, and the intra-job decision vectors are
    untouched."""
    cl = ClusterTimeline(n_racks=4, n_wireless=0)
    rng = np.random.default_rng(3)
    insts = [
        ProblemInstance(job=random_job(rng, None, n_tasks=6, rho=1.5), n_racks=2)
        for _ in range(2)
    ]
    pool = cl.free_racks(0.0)
    views, scheds, placed = [], [], []
    for inst in insts:
        v = cl.residual_view(inst, 0.0, rack_pool=pool)
        pool = pool[v.inst.n_racks:]
        s = g_list_schedule(v.inst, use_wireless=False)
        q = cl.arbitrate(v, s, 0.0)
        cl.commit(v, q, 0.0, job_id=len(views))
        views.append(v)
        scheds.append(s)
        placed.append(q)
    assert len(cl.wired_intervals) > 0
    cl.assert_feasible()
    # First commit is untouched (empty cluster), second keeps rack/chan.
    assert placed[0] is scheds[0]
    assert np.array_equal(placed[1].rack, scheds[1].rack)
    assert np.array_equal(placed[1].chan, scheds[1].chan)
    # Both jobs used wired from t~0 in their own frames, so the second
    # must have been delayed by the first on the shared channel.
    assert placed[1].makespan > scheds[1].makespan
    assert check_feasible(views[1].inst, placed[1]) == placed[1].makespan


def test_release_at_exact_time_regrants_without_double_booking():
    """The _EPS-window regression: a resource whose hold ends at exactly
    ``t`` is re-grantable at ``t`` (holds are recorded at exact float
    completion times and wakeups reuse them bit-for-bit), while an
    in-flight hold only ``_EPS/2`` past ``t`` is busy — the PR 4
    ``<= t + _EPS`` comparison would have granted it and double-booked
    the resource."""
    from repro.online.cluster import _EPS

    cl = ClusterTimeline(n_racks=3, n_wireless=2)
    inst = ProblemInstance(
        job=random_job(np.random.default_rng(4), None, n_tasks=5),
        n_racks=2,
        n_wireless=1,
    )
    view = cl.residual_view(inst, 0.0)
    sched = g_list_schedule(view.inst, use_wireless=True)
    comp = cl.commit(view, sched, 0.0, job_id=0)
    # Released at exactly the recorded completion: re-grantable there.
    assert cl.free_racks(comp).size == 3
    assert cl.free_wireless(comp).size == 2
    view2 = cl.residual_view(inst, comp)
    sched2 = g_list_schedule(view2.inst, use_wireless=True)
    cl.commit(view2, cl.arbitrate(view2, sched2, comp), comp, job_id=1)
    cl.assert_feasible()  # back-to-back commits never overlap
    # An in-flight hold _EPS/2 past t is NOT free at t.
    cl2 = ClusterTimeline(n_racks=2, n_wireless=1)
    cl2.rack_hold[0] = 1.0 + _EPS / 2
    cl2.wireless_hold[0] = 1.0 + _EPS / 2
    assert list(cl2.free_racks(1.0)) == [1]
    assert cl2.free_wireless(1.0).size == 0


# ---------------------------------------------------------------------------
# Degenerate reduction: one epoch == one schedule_fleet call
# ---------------------------------------------------------------------------

def test_degenerate_arrivals_match_schedule_fleet():
    """All jobs at t=0, one admission window, demands fitting the cluster:
    the online service's per-job assignments and JCTs must be bit-for-bit
    a direct ``schedule_fleet`` call on the demand-shaped instances.

    Under the channel-feasible model the reduction requires the cluster
    to grant every job its full demanded shape on *disjoint* physical
    resources — racks AND wireless subchannels are exclusive grants, so
    the cluster carries the sum of the subchannel demands — and the
    shared wired channel to carry no cross-job traffic (wired is made
    slow enough that the engine never routes a transfer onto it, which
    the committed timeline verifies). Then cross-job arbitration is the
    identity and the service adds exactly nothing."""
    demands = (2, 3, 3)
    jobs = [random_job(np.random.default_rng(40 + j), None, rho=0.8) for j in range(3)]
    evs = trace_arrivals([0.0] * 3, jobs, n_racks=8, n_wireless=2, wired_rate=1e-6)
    evs = [
        dataclasses.replace(e, inst=dataclasses.replace(e.inst, n_racks=d))
        for e, d in zip(evs, demands)
    ]
    svc = OnlineScheduler(8, 6, window=0.0, seed=11, solver_kwargs=FAST_SOLVER)
    res = svc.serve(evs)
    direct = schedule_fleet(
        [e.inst for e in evs],
        seed=[11 + 1009 * e.job_id for e in evs],
        **FAST_SOLVER,
    )
    assert res.n_epochs == 1 and res.n_batches == 1
    # The premise of the bit-for-bit claim, verified on the committed
    # timeline: no wired traffic, disjoint subchannel grants.
    assert res.timeline.wired_intervals == []
    res.timeline.assert_feasible()
    offsets = np.cumsum([0] + list(demands[:-1]))
    for job, dres, off in zip(res.jobs, direct.results, offsets):
        assert job.queueing_delay == 0.0
        assert job.jct == dres.makespan  # bit-for-bit, no tolerance
        assert job.makespan == job.solver_makespan  # arbitration = identity
        # Local labels map onto the contiguous physical grant.
        assert np.array_equal(job.assignment, dres.best_assignment + off)


def test_degenerate_reduction_holds_for_warm_and_cold():
    jobs = [random_job(np.random.default_rng(60 + j), None) for j in range(2)]
    evs = trace_arrivals([0.0, 0.0], jobs, n_racks=8, n_wireless=1)
    evs = [
        dataclasses.replace(e, inst=dataclasses.replace(e.inst, n_racks=4))
        for e in evs
    ]
    a = OnlineScheduler(8, 1, window=0.0, warm_start=True,
                        solver_kwargs=FAST_SOLVER).serve(evs)
    b = OnlineScheduler(8, 1, window=0.0, warm_start=False,
                        solver_kwargs=FAST_SOLVER).serve(evs)
    assert [j.jct for j in a.jobs] == [j.jct for j in b.jobs]


# ---------------------------------------------------------------------------
# Event loop conservation properties
# ---------------------------------------------------------------------------

def _serve(seed=0, rate=1 / 30, n_jobs=8, **kw):
    evs = production_arrivals(
        seed, rate=rate, n_jobs=n_jobs, n_racks=6, n_wireless=2, min_rack_demand=4
    )
    args = dict(window=5.0, solver_kwargs=FAST_SOLVER, seed=seed)
    args.update(kw)
    return evs, OnlineScheduler(6, 2, **args).serve(evs)


def test_event_loop_serves_every_job_exactly_once():
    evs, res = _serve()
    assert sorted(j.job_id for j in res.jobs) == [e.job_id for e in evs]
    for j, e in zip(res.jobs, evs):
        assert j.arrival == e.time
        assert j.admitted >= j.arrival  # no time travel
        assert j.queueing_delay >= 0.0
        assert j.jct >= j.makespan  # JCT includes queueing
        assert j.completion == j.admitted + j.makespan
        assert 1 <= j.n_racks_granted <= e.inst.n_racks
        assert np.all(j.assignment < 6)  # physical rack range
    assert res.horizon == max(j.completion for j in res.jobs)
    assert 0.0 < res.rack_utilization <= 1.0


def test_service_is_deterministic():
    _, a = _serve(seed=3)
    _, b = _serve(seed=3)
    assert [j.jct for j in a.jobs] == [j.jct for j in b.jobs]
    assert a.n_epochs == b.n_epochs and a.n_candidates == b.n_candidates


def test_contention_causes_queueing_and_preserve_order_is_fifo():
    # High rate on a small cluster: some job must queue.
    evs, res = _serve(seed=1, rate=1 / 5, n_jobs=6, require_full_demand=True,
                      preserve_order=True)
    assert res.mean_queueing_delay > 0.0
    # FIFO: admissions are non-decreasing in arrival order.
    adm = [j.admitted for j in res.jobs]
    assert all(a <= b + 1e-9 for a, b in zip(adm, adm[1:]))
    # Queued fleet jobs were re-planned while waiting.
    assert any(j.n_solves > 1 for j in res.jobs)


def test_online_baselines_run_and_fifo_solo_serializes():
    evs, fifo = _serve(seed=2, rate=1 / 10, n_jobs=5, policy="fifo_solo")
    # Solo: at most one job on the cluster at any time -> execution
    # intervals are pairwise disjoint.
    spans = sorted((j.admitted, j.completion) for j in fifo.jobs)
    for (s0, e0), (s1, _) in zip(spans, spans[1:]):
        assert s1 >= e0 - 1e-9
    _, greedy = _serve(seed=2, rate=1 / 10, n_jobs=5, policy="greedy_list")
    assert greedy.n_candidates == 0  # no search in the baseline
    assert len(greedy.jobs) == 5
    assert set(ONLINE_BASELINES) == {"fifo_solo", "edf_solo", "greedy_list"}


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        OnlineScheduler(4, 1, policy="nope")
    with pytest.raises(ValueError, match="preserve_order"):
        OnlineScheduler(4, 1, backfill=True)  # backfill extends FIFO


# ---------------------------------------------------------------------------
# Timeline feasibility audit (the channel-feasibility property)
# ---------------------------------------------------------------------------

def _assert_no_cross_job_overlap(timeline, tol=1e-9):
    """Independent audit: no two committed transfers of different jobs may
    overlap on the same physical wired channel or wireless subchannel
    (and no two tasks on one rack). Red on the PR 4 model — which never
    gated the wired channel across jobs and shared subchannels within an
    epoch — green under channel-feasible commits."""
    resources = [("wired", timeline.wired_intervals)]
    resources += [
        (f"wireless[{k}]", ivs) for k, ivs in enumerate(timeline.wireless_intervals)
    ]
    resources += [(f"rack[{i}]", ivs) for i, ivs in enumerate(timeline.rack_intervals)]
    for name, ivs in resources:
        ordered = sorted(ivs)
        for (s0, e0, j0), (s1, e1, j1) in zip(ordered, ordered[1:]):
            assert s1 >= e0 - tol, (
                f"{name}: job {j0} [{s0}, {e0}) overlaps job {j1} [{s1}, {e1})"
            )


@pytest.mark.parametrize("gen", ["poisson", "production"])
@pytest.mark.parametrize("policy", ["fleet", "greedy_list"])
def test_committed_timelines_are_channel_feasible(gen, policy):
    """Property, over seeded Poisson and production-mix streams: every
    committed timeline is physically feasible on every wired channel and
    wireless subchannel, and all three utilizations are true fractions."""
    for seed in (0, 1):
        if gen == "poisson":
            evs = poisson_arrivals(
                seed, rate=1 / 8, n_jobs=6, n_racks=6, n_wireless=2
            )
        else:
            evs = production_arrivals(
                seed, rate=1 / 8, n_jobs=6, n_racks=6, n_wireless=2,
                min_rack_demand=2, min_wireless_demand=0,
            )
        svc = OnlineScheduler(
            6, 2, window=5.0, policy=policy, seed=seed, solver_kwargs=FAST_SOLVER
        )
        res = svc.serve(evs)
        _assert_no_cross_job_overlap(res.timeline)
        res.timeline.assert_feasible()
        # There was real cross-epoch wired traffic to arbitrate.
        assert len(res.timeline.wired_intervals) > 0
        for u in (
            res.rack_utilization,
            res.wired_utilization,
            res.wireless_utilization,
        ):
            assert 0.0 <= u <= 1.0


def test_cross_job_channel_queueing_is_visible_in_makespans():
    """Under contention the served makespan includes the cross-job channel
    wait, so makespan >= solver_makespan per job with strict inequality
    somewhere on a contended stream."""
    evs = production_arrivals(
        1, rate=1 / 4, n_jobs=6, n_racks=6, n_wireless=2, min_rack_demand=2
    )
    res = OnlineScheduler(
        6, 2, window=5.0, seed=1, solver_kwargs=FAST_SOLVER
    ).serve(evs)
    gaps = [j.makespan - j.solver_makespan for j in res.jobs]
    assert all(g >= -1e-9 for g in gaps)
    assert max(gaps) > 1e-9


# ---------------------------------------------------------------------------
# Backfilling (channel-proven head-of-line overtaking)
# ---------------------------------------------------------------------------

def _scaled(job, factor):
    return dataclasses.replace(job, p=job.p * factor, d=job.d * factor)


def _hol_stream(tail_factor):
    """t=0: a long 3-rack job takes racks 0-2 of a 4-rack cluster.
    t=1: a 2-rack job arrives — head-of-line blocked (one rack free).
    t=2: a 1-rack job scaled by ``tail_factor`` arrives behind it."""
    rng = np.random.default_rng(9)
    jobs = [
        _scaled(random_job(rng, None, n_tasks=6), 10.0),
        random_job(rng, None, n_tasks=6),
        _scaled(random_job(rng, None, n_tasks=5), tail_factor),
    ]
    evs = trace_arrivals([0.0, 1.0, 2.0], jobs, n_racks=4, n_wireless=0)
    demands = (3, 2, 1)
    return [
        dataclasses.replace(e, inst=dataclasses.replace(e.inst, n_racks=d))
        for e, d in zip(evs, demands)
    ]


def _serve_hol(evs, backfill):
    svc = OnlineScheduler(
        4, 0, window=0.0, policy="greedy_list", require_full_demand=True,
        preserve_order=True, backfill=backfill,
    )
    return svc.serve(evs)


def test_backfill_overtakes_when_provably_harmless():
    """A short job behind a blocked head-of-line job is admitted at its
    arrival epoch (it finishes before the head job's reservation), the
    head job's admission epoch is bit-for-bit the preserve_order one,
    and the short job's JCT collapses."""
    evs = _hol_stream(tail_factor=0.02)
    po = _serve_hol(evs, backfill=False)
    bf = _serve_hol(evs, backfill=True)
    assert bf.n_backfilled == 1 and bf.jobs[2].backfilled
    assert bf.jobs[2].admitted == 2.0  # admitted at its own arrival epoch
    assert po.jobs[2].admitted >= po.jobs[1].admitted  # FIFO held it back
    # The head-of-line job's admission epoch is untouched — exact, no
    # tolerance: backfilling provably never delays it.
    assert bf.jobs[1].admitted == po.jobs[1].admitted
    assert bf.jobs[0].admitted == po.jobs[0].admitted == 0.0
    assert bf.mean_jct < po.mean_jct
    bf.timeline.assert_feasible()


def test_backfill_rejects_candidates_it_cannot_prove():
    """A long job behind the blocked head-of-line job must NOT overtake:
    it would hold its rack past the head job's reservation. The trace
    then serves exactly like preserve_order."""
    evs = _hol_stream(tail_factor=50.0)
    po = _serve_hol(evs, backfill=False)
    bf = _serve_hol(evs, backfill=True)
    assert bf.n_backfilled == 0 and bf.n_backfill_rejected >= 1
    assert [j.jct for j in bf.jobs] == [j.jct for j in po.jobs]
    assert not any(j.backfilled for j in bf.jobs)


def test_backfill_improves_mean_jct_on_production_mix():
    """The acceptance contract: on the production mix, backfilling is
    never worse than preserve_order FIFO and strictly better where it
    triggers (the docs/benchmarks.md admission-mode table is the fleet-
    policy version of this comparison)."""
    improved = triggered = 0
    for seed in (2, 4):
        evs = production_arrivals(
            seed, rate=1 / 12, n_jobs=12, n_racks=6, n_wireless=2,
            min_rack_demand=2, min_wireless_demand=0,
        )
        args = dict(window=5.0, policy="greedy_list", require_full_demand=True,
                    seed=seed)
        po = OnlineScheduler(6, 2, preserve_order=True, **args).serve(evs)
        bf = OnlineScheduler(
            6, 2, preserve_order=True, backfill=True, **args
        ).serve(evs)
        bf.timeline.assert_feasible()
        assert bf.mean_jct <= po.mean_jct + 1e-9
        triggered += bf.n_backfilled > 0
        improved += bf.mean_jct < po.mean_jct - 1e-9
    assert triggered >= 1 and improved >= 1


# ---------------------------------------------------------------------------
# Warm-start seed-pool hook
# ---------------------------------------------------------------------------

def dense_instance(seed):
    job = make_onestage_mapreduce(
        np.random.default_rng(seed), n_map=9, n_reduce=9, rho=1.0
    )
    return ProblemInstance(job=job, n_racks=6, n_wireless=1)


def test_seed_pool_is_budget_neutral_and_never_worse():
    from repro.core.vectorized import make_batched_evaluator

    inst = dense_instance(0)
    kw = dict(max_enumerate=500, n_samples=256, batch_size=512,
              refine_rounds=2, refine_pool=128)
    cold = vectorized_search(inst, seed=0, **kw)
    # Seed with the cold incumbent: same sweep budget, and the seeded
    # sweep must re-discover at least that incumbent's greedy quality.
    warm = vectorized_search(
        inst, seed=0, seed_pool=cold.best_assignment[None, :], **kw
    )

    def sweep_candidates(res):
        return res.n_candidates - sum(
            s.proposed for s in res.strategy_stats.values()
        )

    assert sweep_candidates(warm) == sweep_candidates(cold)  # budget-neutral
    evaluate = make_batched_evaluator(inst)
    g_warm = float(np.asarray(evaluate(warm.best_assignment[None, :]))[0])
    g_cold = float(np.asarray(evaluate(cold.best_assignment[None, :]))[0])
    assert g_warm <= g_cold + 1e-6  # the seed is re-evaluated in the sweep


def test_seed_pool_folds_foreign_labels_and_ignores_enumerate_regime():
    inst = ProblemInstance(
        job=random_job(np.random.default_rng(2), None, n_tasks=5), n_racks=3
    )
    n = inst.job.n_tasks
    # Labels from a 10-rack view fold into [0, 3); enumerated regime
    # ignores seeds entirely (the sweep is already exhaustive).
    pool = np.full((2, n), 7, dtype=np.int64)
    a = vectorized_search(inst, seed=0, max_enumerate=10_000, seed_pool=pool)
    b = vectorized_search(inst, seed=0, max_enumerate=10_000)
    assert a.makespan == b.makespan and a.n_candidates == b.n_candidates


def test_schedule_fleet_seed_pool_validation():
    insts = [dense_instance(s) for s in range(2)]
    with pytest.raises(ValueError, match="seed pool"):
        schedule_fleet(insts, seed_pools=[None])  # wrong length


def test_warm_service_never_worse_than_cold_on_contended_trace():
    """The service-level guarantee behind the docs table: with full-demand
    FIFO admission and common random numbers, warm-started re-optimization
    is never worse than cold-start at equal per-solve budget — per job, on
    the served schedule's solver makespan (the provable invariant: the
    warm chain starts at exactly the cold arm's committed solve and
    keep-incumbent commits are monotone; post-arbitration completions
    additionally depend on the neighbors sharing the channels)."""
    for seed in (0, 5):
        evs = production_arrivals(
            seed, rate=1 / 40, n_jobs=6, n_racks=6, n_wireless=2, min_rack_demand=4
        )
        args = dict(window=5.0, require_full_demand=True, preserve_order=True,
                    solver_kwargs=SAMPLED_SOLVER, seed=seed)
        warm = OnlineScheduler(6, 2, warm_start=True, **args).serve(evs)
        cold = OnlineScheduler(6, 2, warm_start=False, **args).serve(evs)
        for w, c in zip(warm.jobs, cold.jobs):
            assert w.job_id == c.job_id
            assert w.solver_makespan <= c.solver_makespan + 1e-9
        assert warm.mean_jct <= cold.mean_jct + 1e-9


# ---------------------------------------------------------------------------
# Portfolio allocator yield decay (satellite)
# ---------------------------------------------------------------------------

def _drive_portfolio(yield_decay, vals_by_round):
    """Run synthetic rounds through a 2-strategy portfolio and return the
    weight trajectory. Each round both strategies propose, and the given
    per-strategy best values are fed back as scored evaluations."""
    inst = dense_instance(1)
    p = Portfolio(
        build_strategies(("mutation", "crossover")),
        inst,
        np.random.default_rng(0),
        pool_size=8,
        yield_decay=yield_decay,
    )
    n = inst.job.n_tasks
    best = np.zeros(n, dtype=np.int64)
    traj = []
    for r, (v0, v1) in enumerate(vals_by_round):
        start_best = 100.0 - r  # improving incumbent
        pool, tags = p.begin_round(best, start_best)
        for s_idx, v in ((0, v0), (1, v1)):
            m = tags == s_idx
            p.observe(tags[m], pool[m], np.full(m.sum(), v), start_best)
        p.end_round(best, min(start_best, v0, v1))
        traj.append(p.weights.copy())
    return traj


def test_yield_decay_default_off_is_bit_for_bit():
    rounds = [(95.0, 99.0), (99.0, 93.0), (99.0, 99.0)]
    base = _drive_portfolio(0.0, rounds)
    # Manual reference of the memoryless multiplicative-weights update.
    inst = dense_instance(1)
    ref = Portfolio(
        build_strategies(("mutation", "crossover")),
        inst,
        np.random.default_rng(0),
        pool_size=8,
    )
    assert ref.yield_decay == 0.0  # default off
    for got, want in zip(base, _drive_portfolio(0.0, rounds)):
        assert np.array_equal(got, want)
    # Against a hand-computed first round: strategy 0 improves by 5 over
    # its 4 evaluated rows, strategy 1 by 1 -> weights follow exp(eta*y/max).
    w = np.ones(2)
    yields = np.array([5.0 / 4.0, 1.0 / 4.0])
    w = w * np.exp(2.0 * yields / yields.max())
    w = np.clip(w / w.mean(), 0.05, 20.0)
    assert np.allclose(base[0], w)


def test_yield_decay_stalled_rounds_freeze_weights():
    """A stalled round must not re-apply stale evidence: after one lucky
    round, rounds with zero current yield leave the weights untouched
    (decay only shapes how the NEXT productive round's shift is split)."""
    lucky_then_stalled = [(90.0, 99.0)] + [(999.0, 999.0)] * 4
    traj = _drive_portfolio(0.3, lucky_then_stalled)
    for later in traj[1:]:
        assert np.array_equal(later, traj[0])


def test_yield_decay_remembers_stale_rounds():
    # Strategy 0 wins round 0, then goes quiet; strategy 1 wins later.
    rounds = [(90.0, 99.0), (99.0, 98.0), (99.0, 98.5)]
    memoryless = _drive_portfolio(0.0, rounds)
    decayed = _drive_portfolio(0.5, rounds)
    # With decay, strategy 0's early yield keeps boosting its weight
    # after it stops producing; memoryless forgets it immediately.
    assert decayed[-1][0] / decayed[-1][1] > memoryless[-1][0] / memoryless[-1][1]
    with pytest.raises(ValueError):
        _drive_portfolio(1.0, rounds)  # decay must be < 1


# ---------------------------------------------------------------------------
# Metrics (satellite)
# ---------------------------------------------------------------------------

def _result_with(jobs, solver_wall):
    from repro.online.metrics import JobMetrics, OnlineResult

    return OnlineResult(
        jobs=[
            JobMetrics(
                job_id=i, family="f", arrival=0.0, admitted=0.0,
                completion=1.0, makespan=1.0, n_racks_granted=1,
                n_wireless_granted=0, n_solves=1,
            )
            for i in range(jobs)
        ],
        policy="greedy_list", warm_start=False, n_epochs=1, n_batches=0,
        n_solves=jobs, n_candidates=0, n_pruned=0, solver_wall=solver_wall,
        horizon=1.0, rack_utilization=0.5, wired_utilization=0.1,
        wireless_utilization=0.0,
    )


def test_jobs_per_solver_second_zero_cost_is_infinite():
    """A zero-cost policy has infinite scheduler throughput, not zero —
    the PR 4 ``0.0`` made baseline rows read as the slowest scheduler in
    every benchmark table. ``summary()`` renders it as ``inf``."""
    res = _result_with(jobs=3, solver_wall=0.0)
    assert res.jobs_per_solver_second == float("inf")
    assert "jobs_per_solver_s=inf" in res.summary()
    timed = _result_with(jobs=3, solver_wall=1.5)
    assert timed.jobs_per_solver_second == pytest.approx(2.0)
    assert "jobs_per_solver_s=2.00" in timed.summary()
    empty = _result_with(jobs=0, solver_wall=0.0)
    assert empty.jobs_per_solver_second == 0.0


# ---------------------------------------------------------------------------
# Benchmark JSON emitter (satellite)
# ---------------------------------------------------------------------------

def test_bench_json_schema_roundtrip(tmp_path):
    from benchmarks import common

    common.reset_results()
    try:
        common.emit("unit_case", 12.5, "mean_jct=101.5;wins=3/6;mode=quick")
        out = tmp_path / "BENCH_unit.json"
        common.write_json(str(out), bench="unit", config={"seeds": 6})
        doc = json.loads(out.read_text())
        assert doc["schema"] == common.BENCH_SCHEMA
        assert doc["bench"] == "unit" and doc["config"]["seeds"] == 6
        (rec,) = doc["results"]
        assert rec["name"] == "unit_case" and rec["us_per_call"] == 12.5
        assert rec["metrics"]["mean_jct"] == 101.5
        assert rec["metrics"]["wins"] == "3/6"  # non-numeric kept verbatim
    finally:
        common.reset_results()


@pytest.mark.slow
def test_online_serving_benchmark_arrival_sweep(tmp_path):
    """Nightly: the arrival-rate sweep runs end-to-end and its JSON
    artifact carries JCT + throughput metrics for every rate."""
    from benchmarks import common, online_serving

    common.reset_results()
    try:
        out = tmp_path / "BENCH_online_serving.json"
        online_serving.main(["--json", str(out)])
        doc = json.loads(out.read_text())
        names = [r["name"] for r in doc["results"]]
        assert any(n.startswith("online_rate") for n in names)
        assert "online_warm_vs_cold_summary" in names
        summary = next(
            r for r in doc["results"] if r["name"] == "online_warm_vs_cold_summary"
        )
        assert summary["metrics"]["losses"].startswith("0/")
        # Channel-feasible records: every sweep row carries true
        # utilizations, and the admission-mode comparison is tracked.
        for rec in doc["results"]:
            if rec["name"].startswith("online_rate"):
                for key in ("rack_util", "wired_util", "wireless_util"):
                    assert 0.0 <= rec["metrics"][key] <= 1.0
        modes = next(
            r for r in doc["results"]
            if r["name"] == "online_admission_modes_summary"
        )
        assert modes["metrics"]["backfill_losses"].startswith("0/")
        assert (
            modes["metrics"]["backfill_mean_jct"]
            <= modes["metrics"]["preserve_order_mean_jct"]
        )
    finally:
        common.reset_results()
