"""Online serving layer contracts: workload generator reproducibility and
feasibility, cluster-timeline residual/commit semantics, the degenerate
reduction of the service to one ``schedule_fleet`` call, event-loop
conservation properties, the warm-start seed-pool hook (budget
neutrality and never-worse), the portfolio allocator's ``yield_decay``
option, online baselines, and the benchmark JSON emitter."""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import (
    ONLINE_BASELINES,
    ProblemInstance,
    check_feasible,
    g_list_schedule,
    random_job,
    schedule_fleet,
    vectorized_search,
)
from repro.core.dag import make_onestage_mapreduce
from repro.core.portfolio import Portfolio, build_strategies
from repro.online import (
    ClusterTimeline,
    OnlineScheduler,
    poisson_arrivals,
    production_arrivals,
    trace_arrivals,
)

FAST_SOLVER = dict(
    max_enumerate=500, n_samples=128, batch_size=256,
    refine_rounds=2, refine_pool=128,
)
SAMPLED_SOLVER = dict(
    max_enumerate=64, n_samples=64, batch_size=256,
    refine_rounds=2, refine_pool=96, strategies="portfolio",
)


# ---------------------------------------------------------------------------
# Workload generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gen", ["poisson", "production"])
def test_workload_streams_are_reproducible(gen):
    make = {
        "poisson": lambda s: poisson_arrivals(s, rate=0.02, n_jobs=12),
        "production": lambda s: production_arrivals(s, rate=0.02, n_jobs=12),
    }[gen]
    a, b = make(7), make(7)
    c = make(8)
    assert len(a) == len(b) == 12
    for ea, eb in zip(a, b):
        assert ea.time == eb.time and ea.family == eb.family
        assert np.array_equal(ea.inst.job.p, eb.inst.job.p)
        assert np.array_equal(ea.inst.job.edges, eb.inst.job.edges)
        assert np.array_equal(ea.inst.job.d, eb.inst.job.d)
        assert ea.inst.n_racks == eb.inst.n_racks
    assert any(x.time != y.time for x, y in zip(a, c))  # seed matters


@pytest.mark.parametrize("gen", ["poisson", "production", "trace"])
def test_workload_times_sorted_nonnegative_and_ids_unique(gen):
    if gen == "trace":
        jobs = [random_job(np.random.default_rng(s), None) for s in range(6)]
        evs = trace_arrivals([5.0, 1.0, 3.0, 0.0, 9.0, 2.0], jobs)
    elif gen == "poisson":
        evs = poisson_arrivals(3, rate=0.05, n_jobs=10)
    else:
        evs = production_arrivals(3, rate=0.05, n_jobs=10)
    times = [e.time for e in evs]
    assert times == sorted(times)
    assert all(t >= 0.0 for t in times)
    assert sorted(e.job_id for e in evs) == list(range(len(evs)))


def test_workload_instances_pass_check_feasible_on_greedy_schedule():
    evs = poisson_arrivals(1, rate=0.05, n_jobs=5) + production_arrivals(
        1, rate=0.05, n_jobs=5
    )
    for e in evs:
        sched = g_list_schedule(e.inst, use_wireless=True)
        assert check_feasible(e.inst, sched) == sched.makespan
        assert sched.makespan > 0.0


def test_production_mix_covers_families_and_demands():
    evs = production_arrivals(0, rate=0.05, n_jobs=40, n_racks=6, min_rack_demand=4)
    fams = {e.family for e in evs}
    assert fams == {"simple_mapreduce", "onestage_mapreduce", "random_workflow"}
    demands = {e.inst.n_racks for e in evs}
    assert demands <= {4, 5, 6} and len(demands) > 1
    assert 5 <= min(e.inst.job.n_tasks for e in evs)
    assert max(e.inst.job.n_tasks for e in evs) <= 10


def test_trace_arrivals_validation():
    jobs = [random_job(np.random.default_rng(0), None)]
    with pytest.raises(ValueError):
        trace_arrivals([1.0, 2.0], jobs)  # length mismatch
    with pytest.raises(ValueError):
        trace_arrivals([-1.0], jobs)  # negative time


# ---------------------------------------------------------------------------
# Cluster timeline
# ---------------------------------------------------------------------------

def test_cluster_residual_and_commit_roundtrip():
    cl = ClusterTimeline(n_racks=4, n_wireless=2)
    inst = ProblemInstance(
        job=random_job(np.random.default_rng(0), None, n_tasks=6),
        n_racks=3,
        n_wireless=2,
    )
    view = cl.residual_view(inst, 0.0)
    assert view.full and view.inst.n_racks == 3 and view.inst.n_wireless == 2
    assert list(view.rack_map) == [0, 1, 2]
    sched = g_list_schedule(view.inst, use_wireless=True)
    comp = cl.commit(view, sched, t=10.0)
    assert comp == 10.0 + sched.makespan
    # Racks the job used are held past t=10; rack 3 stays free.
    used = sorted({int(view.rack_map[r]) for r in sched.rack})
    free_now = set(cl.free_racks(10.0).tolist())
    assert not (set(used) & free_now) and 3 in free_now
    # After the completion everything is free again.
    assert cl.free_racks(comp + 1e-6).size == 4
    assert cl.free_wireless(comp + 1e-6).size == 2


def test_cluster_rack_pool_grants_are_exclusive():
    cl = ClusterTimeline(n_racks=6, n_wireless=1)
    inst = ProblemInstance(
        job=random_job(np.random.default_rng(1), None, n_tasks=5),
        n_racks=4,
        n_wireless=1,
    )
    pool = cl.free_racks(0.0)
    v1 = cl.residual_view(inst, 0.0, rack_pool=pool)
    pool = pool[v1.inst.n_racks:]
    v2 = cl.residual_view(inst, 0.0, rack_pool=pool)
    assert list(v1.rack_map) == [0, 1, 2, 3]
    assert list(v2.rack_map) == [4, 5] and v2.inst.n_racks == 2 and not v2.full
    assert cl.residual_view(inst, 0.0, rack_pool=pool[2:]) is None


# ---------------------------------------------------------------------------
# Degenerate reduction: one epoch == one schedule_fleet call
# ---------------------------------------------------------------------------

def test_degenerate_arrivals_match_schedule_fleet():
    """All jobs at t=0, one admission window, demands fitting the cluster:
    the online service's per-job assignments and JCTs must be bit-for-bit
    a direct ``schedule_fleet`` call on the demand-shaped instances."""
    demands = (2, 3, 3)
    jobs = [random_job(np.random.default_rng(40 + j), None, rho=0.8) for j in range(3)]
    evs = trace_arrivals([0.0] * 3, jobs, n_racks=8, n_wireless=2)
    evs = [
        dataclasses.replace(e, inst=dataclasses.replace(e.inst, n_racks=d))
        for e, d in zip(evs, demands)
    ]
    svc = OnlineScheduler(8, 2, window=0.0, seed=11, solver_kwargs=FAST_SOLVER)
    res = svc.serve(evs)
    direct = schedule_fleet(
        [e.inst for e in evs],
        seed=[11 + 1009 * e.job_id for e in evs],
        **FAST_SOLVER,
    )
    assert res.n_epochs == 1 and res.n_batches == 1
    offsets = np.cumsum([0] + list(demands[:-1]))
    for job, dres, off in zip(res.jobs, direct.results, offsets):
        assert job.queueing_delay == 0.0
        assert job.jct == dres.makespan  # bit-for-bit, no tolerance
        # Local labels map onto the contiguous physical grant.
        assert np.array_equal(job.assignment, dres.best_assignment + off)


def test_degenerate_reduction_holds_for_warm_and_cold():
    jobs = [random_job(np.random.default_rng(60 + j), None) for j in range(2)]
    evs = trace_arrivals([0.0, 0.0], jobs, n_racks=8, n_wireless=1)
    evs = [
        dataclasses.replace(e, inst=dataclasses.replace(e.inst, n_racks=4))
        for e in evs
    ]
    a = OnlineScheduler(8, 1, window=0.0, warm_start=True,
                        solver_kwargs=FAST_SOLVER).serve(evs)
    b = OnlineScheduler(8, 1, window=0.0, warm_start=False,
                        solver_kwargs=FAST_SOLVER).serve(evs)
    assert [j.jct for j in a.jobs] == [j.jct for j in b.jobs]


# ---------------------------------------------------------------------------
# Event loop conservation properties
# ---------------------------------------------------------------------------

def _serve(seed=0, rate=1 / 30, n_jobs=8, **kw):
    evs = production_arrivals(
        seed, rate=rate, n_jobs=n_jobs, n_racks=6, n_wireless=2, min_rack_demand=4
    )
    args = dict(window=5.0, solver_kwargs=FAST_SOLVER, seed=seed)
    args.update(kw)
    return evs, OnlineScheduler(6, 2, **args).serve(evs)


def test_event_loop_serves_every_job_exactly_once():
    evs, res = _serve()
    assert sorted(j.job_id for j in res.jobs) == [e.job_id for e in evs]
    for j, e in zip(res.jobs, evs):
        assert j.arrival == e.time
        assert j.admitted >= j.arrival  # no time travel
        assert j.queueing_delay >= 0.0
        assert j.jct >= j.makespan  # JCT includes queueing
        assert j.completion == j.admitted + j.makespan
        assert 1 <= j.n_racks_granted <= e.inst.n_racks
        assert np.all(j.assignment < 6)  # physical rack range
    assert res.horizon == max(j.completion for j in res.jobs)
    assert 0.0 < res.rack_utilization <= 1.0


def test_service_is_deterministic():
    _, a = _serve(seed=3)
    _, b = _serve(seed=3)
    assert [j.jct for j in a.jobs] == [j.jct for j in b.jobs]
    assert a.n_epochs == b.n_epochs and a.n_candidates == b.n_candidates


def test_contention_causes_queueing_and_preserve_order_is_fifo():
    # High rate on a small cluster: some job must queue.
    evs, res = _serve(seed=1, rate=1 / 5, n_jobs=6, require_full_demand=True,
                      preserve_order=True)
    assert res.mean_queueing_delay > 0.0
    # FIFO: admissions are non-decreasing in arrival order.
    adm = [j.admitted for j in res.jobs]
    assert all(a <= b + 1e-9 for a, b in zip(adm, adm[1:]))
    # Queued fleet jobs were re-planned while waiting.
    assert any(j.n_solves > 1 for j in res.jobs)


def test_online_baselines_run_and_fifo_solo_serializes():
    evs, fifo = _serve(seed=2, rate=1 / 10, n_jobs=5, policy="fifo_solo")
    # Solo: at most one job on the cluster at any time -> execution
    # intervals are pairwise disjoint.
    spans = sorted((j.admitted, j.completion) for j in fifo.jobs)
    for (s0, e0), (s1, _) in zip(spans, spans[1:]):
        assert s1 >= e0 - 1e-9
    _, greedy = _serve(seed=2, rate=1 / 10, n_jobs=5, policy="greedy_list")
    assert greedy.n_candidates == 0  # no search in the baseline
    assert len(greedy.jobs) == 5
    assert set(ONLINE_BASELINES) == {"fifo_solo", "greedy_list"}


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        OnlineScheduler(4, 1, policy="nope")


# ---------------------------------------------------------------------------
# Warm-start seed-pool hook
# ---------------------------------------------------------------------------

def dense_instance(seed):
    job = make_onestage_mapreduce(
        np.random.default_rng(seed), n_map=9, n_reduce=9, rho=1.0
    )
    return ProblemInstance(job=job, n_racks=6, n_wireless=1)


def test_seed_pool_is_budget_neutral_and_never_worse():
    from repro.core.vectorized import make_batched_evaluator

    inst = dense_instance(0)
    kw = dict(max_enumerate=500, n_samples=256, batch_size=512,
              refine_rounds=2, refine_pool=128)
    cold = vectorized_search(inst, seed=0, **kw)
    # Seed with the cold incumbent: same sweep budget, and the seeded
    # sweep must re-discover at least that incumbent's greedy quality.
    warm = vectorized_search(
        inst, seed=0, seed_pool=cold.best_assignment[None, :], **kw
    )

    def sweep_candidates(res):
        return res.n_candidates - sum(
            s.proposed for s in res.strategy_stats.values()
        )

    assert sweep_candidates(warm) == sweep_candidates(cold)  # budget-neutral
    evaluate = make_batched_evaluator(inst)
    g_warm = float(np.asarray(evaluate(warm.best_assignment[None, :]))[0])
    g_cold = float(np.asarray(evaluate(cold.best_assignment[None, :]))[0])
    assert g_warm <= g_cold + 1e-6  # the seed is re-evaluated in the sweep


def test_seed_pool_folds_foreign_labels_and_ignores_enumerate_regime():
    inst = ProblemInstance(
        job=random_job(np.random.default_rng(2), None, n_tasks=5), n_racks=3
    )
    n = inst.job.n_tasks
    # Labels from a 10-rack view fold into [0, 3); enumerated regime
    # ignores seeds entirely (the sweep is already exhaustive).
    pool = np.full((2, n), 7, dtype=np.int64)
    a = vectorized_search(inst, seed=0, max_enumerate=10_000, seed_pool=pool)
    b = vectorized_search(inst, seed=0, max_enumerate=10_000)
    assert a.makespan == b.makespan and a.n_candidates == b.n_candidates


def test_schedule_fleet_seed_pool_validation():
    insts = [dense_instance(s) for s in range(2)]
    with pytest.raises(ValueError, match="seed pool"):
        schedule_fleet(insts, seed_pools=[None])  # wrong length


def test_warm_service_never_worse_than_cold_on_contended_trace():
    """The service-level guarantee behind the docs table: with full-demand
    FIFO admission and common random numbers, warm-started re-optimization
    is never worse than cold-start at equal per-solve budget."""
    for seed in (0, 5):
        evs = production_arrivals(
            seed, rate=1 / 40, n_jobs=6, n_racks=6, n_wireless=2, min_rack_demand=4
        )
        args = dict(window=5.0, require_full_demand=True, preserve_order=True,
                    solver_kwargs=SAMPLED_SOLVER, seed=seed)
        warm = OnlineScheduler(6, 2, warm_start=True, **args).serve(evs)
        cold = OnlineScheduler(6, 2, warm_start=False, **args).serve(evs)
        assert warm.mean_jct <= cold.mean_jct + 1e-9


# ---------------------------------------------------------------------------
# Portfolio allocator yield decay (satellite)
# ---------------------------------------------------------------------------

def _drive_portfolio(yield_decay, vals_by_round):
    """Run synthetic rounds through a 2-strategy portfolio and return the
    weight trajectory. Each round both strategies propose, and the given
    per-strategy best values are fed back as scored evaluations."""
    inst = dense_instance(1)
    p = Portfolio(
        build_strategies(("mutation", "crossover")),
        inst,
        np.random.default_rng(0),
        pool_size=8,
        yield_decay=yield_decay,
    )
    n = inst.job.n_tasks
    best = np.zeros(n, dtype=np.int64)
    traj = []
    for r, (v0, v1) in enumerate(vals_by_round):
        start_best = 100.0 - r  # improving incumbent
        pool, tags = p.begin_round(best, start_best)
        for s_idx, v in ((0, v0), (1, v1)):
            m = tags == s_idx
            p.observe(tags[m], pool[m], np.full(m.sum(), v), start_best)
        p.end_round(best, min(start_best, v0, v1))
        traj.append(p.weights.copy())
    return traj


def test_yield_decay_default_off_is_bit_for_bit():
    rounds = [(95.0, 99.0), (99.0, 93.0), (99.0, 99.0)]
    base = _drive_portfolio(0.0, rounds)
    # Manual reference of the memoryless multiplicative-weights update.
    inst = dense_instance(1)
    ref = Portfolio(
        build_strategies(("mutation", "crossover")),
        inst,
        np.random.default_rng(0),
        pool_size=8,
    )
    assert ref.yield_decay == 0.0  # default off
    for got, want in zip(base, _drive_portfolio(0.0, rounds)):
        assert np.array_equal(got, want)
    # Against a hand-computed first round: strategy 0 improves by 5 over
    # its 4 evaluated rows, strategy 1 by 1 -> weights follow exp(eta*y/max).
    w = np.ones(2)
    yields = np.array([5.0 / 4.0, 1.0 / 4.0])
    w = w * np.exp(2.0 * yields / yields.max())
    w = np.clip(w / w.mean(), 0.05, 20.0)
    assert np.allclose(base[0], w)


def test_yield_decay_stalled_rounds_freeze_weights():
    """A stalled round must not re-apply stale evidence: after one lucky
    round, rounds with zero current yield leave the weights untouched
    (decay only shapes how the NEXT productive round's shift is split)."""
    lucky_then_stalled = [(90.0, 99.0)] + [(999.0, 999.0)] * 4
    traj = _drive_portfolio(0.3, lucky_then_stalled)
    for later in traj[1:]:
        assert np.array_equal(later, traj[0])


def test_yield_decay_remembers_stale_rounds():
    # Strategy 0 wins round 0, then goes quiet; strategy 1 wins later.
    rounds = [(90.0, 99.0), (99.0, 98.0), (99.0, 98.5)]
    memoryless = _drive_portfolio(0.0, rounds)
    decayed = _drive_portfolio(0.5, rounds)
    # With decay, strategy 0's early yield keeps boosting its weight
    # after it stops producing; memoryless forgets it immediately.
    assert decayed[-1][0] / decayed[-1][1] > memoryless[-1][0] / memoryless[-1][1]
    with pytest.raises(ValueError):
        _drive_portfolio(1.0, rounds)  # decay must be < 1


# ---------------------------------------------------------------------------
# Benchmark JSON emitter (satellite)
# ---------------------------------------------------------------------------

def test_bench_json_schema_roundtrip(tmp_path):
    from benchmarks import common

    common.reset_results()
    try:
        common.emit("unit_case", 12.5, "mean_jct=101.5;wins=3/6;mode=quick")
        out = tmp_path / "BENCH_unit.json"
        common.write_json(str(out), bench="unit", config={"seeds": 6})
        doc = json.loads(out.read_text())
        assert doc["schema"] == common.BENCH_SCHEMA
        assert doc["bench"] == "unit" and doc["config"]["seeds"] == 6
        (rec,) = doc["results"]
        assert rec["name"] == "unit_case" and rec["us_per_call"] == 12.5
        assert rec["metrics"]["mean_jct"] == 101.5
        assert rec["metrics"]["wins"] == "3/6"  # non-numeric kept verbatim
    finally:
        common.reset_results()


@pytest.mark.slow
def test_online_serving_benchmark_arrival_sweep(tmp_path):
    """Nightly: the arrival-rate sweep runs end-to-end and its JSON
    artifact carries JCT + throughput metrics for every rate."""
    from benchmarks import common, online_serving

    common.reset_results()
    try:
        out = tmp_path / "BENCH_online_serving.json"
        online_serving.main(["--json", str(out)])
        doc = json.loads(out.read_text())
        names = [r["name"] for r in doc["results"]]
        assert any(n.startswith("online_rate") for n in names)
        assert "online_warm_vs_cold_summary" in names
        summary = next(
            r for r in doc["results"] if r["name"] == "online_warm_vs_cold_summary"
        )
        assert summary["metrics"]["losses"].startswith("0/")
    finally:
        common.reset_results()
