"""Property layer locking in the §IV-A bound algebra and the fleet contract.

Two invariants, over randomized instances:

  1. Bound sandwich:  max(cpm_lb, load_lb) <= exact B&B optimum <= greedy
     score — per candidate assignment the combined stage-1 bound never
     exceeds that candidate's greedy score, and across candidates
     min(bound) <= optimum <= min(greedy).

  2. Fleet equivalence: every per-instance result of ``schedule_fleet`` is
     bit-for-bit the result of the single-instance solver (assignment,
     makespan, prune/eval counters).

Runs under Hypothesis when it is installed (CI's ``pip install -e .[test]``
lane); falls back to a fixed seeded sweep of the same checks otherwise
(this container ships without hypothesis by design).
"""

import numpy as np
import pytest

from repro.core import (
    ProblemInstance,
    contention_lower_bounds,
    random_job,
    schedule_fleet,
    solve_bnb,
)
from repro.core.vectorized import (
    batched_lower_bound,
    enumerate_assignments,
    make_batched_evaluator,
)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


def _instance(seed: int, n_tasks: int, n_racks: int, rho: float, n_wireless: int):
    rng = np.random.default_rng(seed)
    job = random_job(rng, None, n_tasks=n_tasks, rho=rho)
    return ProblemInstance(job=job, n_racks=n_racks, n_wireless=n_wireless)


def _check_bound_sandwich(seed, n_tasks, n_racks, rho, n_wireless):
    inst = _instance(seed, n_tasks, n_racks, rho, n_wireless)
    cands = enumerate_assignments(inst.job.n_tasks, inst.n_racks)
    lbs_kernel = batched_lower_bound(inst, cands, use_kernel=True)
    lbs_ref = batched_lower_bound(inst, cands, use_kernel=False)
    np.testing.assert_allclose(lbs_kernel, lbs_ref, rtol=1e-5, atol=1e-3)

    scores = np.asarray(make_batched_evaluator(inst)(cands))
    # per-candidate admissibility w.r.t. the greedy evaluator
    assert (lbs_kernel <= scores + 1e-3).all()
    # ... including the host-side contention terms on their own
    host = contention_lower_bounds(inst, cands)
    assert (host <= scores + 1e-3).all()

    opt = solve_bnb(inst, time_limit=30)
    assert opt.proved_optimal
    # min over candidates: max(cpm_lb, load_lb) <= optimum <= greedy score
    assert float(lbs_kernel.min()) <= opt.makespan + 1e-3
    assert opt.makespan <= float(scores.min()) + 1e-3


def _check_fleet_equivalence(seeds, n_tasks_list, n_racks, batch_size):
    # Shared with the deterministic fleet tests so both lanes assert the
    # same bit-for-bit contract.
    from test_vectorized import _assert_fleet_matches_solo

    insts = [
        _instance(s, n, n_racks, 1.0, 1) for s, n in zip(seeds, n_tasks_list)
    ]
    fleet = schedule_fleet(insts, batch_size=batch_size)
    _assert_fleet_matches_solo(insts, fleet, batch_size=batch_size)


def test_bnb_assignment_bound_hook_preserves_optimum():
    """An admissible custom bound through solve_bnb's level-1 hook must not
    change the optimum (here: the §IV-A contention bound on complete
    assignments, the same term family the fleet pruner fuses on-device)."""

    def hook(inst, rack):
        rack = np.asarray(rack)
        if (rack < 0).any():
            return 0.0
        return float(contention_lower_bounds(inst, rack[None, :])[0])

    for seed in range(3):
        inst = _instance(seed, n_tasks=5, n_racks=3, rho=1.0, n_wireless=1)
        base = solve_bnb(inst, time_limit=30)
        hooked = solve_bnb(inst, time_limit=30, assignment_bound=hook)
        assert hooked.makespan == pytest.approx(base.makespan, abs=1e-9)
        assert hooked.proved_optimal


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=8,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        seed=st.integers(0, 10**6),
        n_tasks=st.integers(3, 6),
        n_racks=st.integers(2, 3),
        rho=st.floats(0.25, 2.0, allow_nan=False),
        n_wireless=st.integers(0, 2),
    )
    def test_bound_sandwich_property(seed, n_tasks, n_racks, rho, n_wireless):
        _check_bound_sandwich(seed, n_tasks, n_racks, rho, n_wireless)

    @settings(
        max_examples=5,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        base=st.integers(0, 10**6),
        sizes=st.lists(st.integers(3, 6), min_size=2, max_size=3),
        n_racks=st.integers(2, 3),
    )
    def test_fleet_matches_solo_property(base, sizes, n_racks):
        seeds = [base + i for i in range(len(sizes))]
        _check_fleet_equivalence(seeds, sizes, n_racks, batch_size=32)

else:  # fixed seeded sweep of the same properties

    @pytest.mark.parametrize("case", range(8))
    def test_bound_sandwich_property(case):
        rng = np.random.default_rng(1000 + case)
        _check_bound_sandwich(
            seed=int(rng.integers(10**6)),
            n_tasks=int(rng.integers(3, 7)),
            n_racks=int(rng.integers(2, 4)),
            rho=float(rng.uniform(0.25, 2.0)),
            n_wireless=int(rng.integers(0, 3)),
        )

    @pytest.mark.parametrize("case", range(4))
    def test_fleet_matches_solo_property(case):
        rng = np.random.default_rng(2000 + case)
        k = int(rng.integers(2, 4))
        _check_fleet_equivalence(
            seeds=[int(rng.integers(10**6)) for _ in range(k)],
            n_tasks_list=[int(rng.integers(3, 7)) for _ in range(k)],
            n_racks=int(rng.integers(2, 4)),
            batch_size=32,
        )
