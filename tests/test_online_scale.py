"""O(active) serving-core contracts: the sorted interval index, timeline
compaction, the incremental feasibility audit, the service's incremental
free sets, streaming workload/metrics, and — the tentpole equivalence —
``serve()`` with aggressive per-epoch ``compact()`` bit-identical to the
uncompacted path on seeded Poisson and production streams.

The compaction property runs under Hypothesis when installed (CI's
``pip install -e .[test]`` lane); otherwise a fixed seeded sweep of the
same check (this container ships without hypothesis by design).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import ProblemInstance, random_job, schedule_fleet
from repro.core.simulator import build_op_tables
from repro.online import (
    ClusterTimeline,
    OnlineScheduler,
    StreamingSeries,
    poisson_arrivals,
    production_arrivals,
    stream_poisson_arrivals,
    stream_production_arrivals,
)
from repro.online.service import _FreeSet

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

FAST_SOLVER = dict(
    max_enumerate=500, n_samples=128, batch_size=256,
    refine_rounds=2, refine_pool=128,
)


# ---------------------------------------------------------------------------
# Compaction equivalence (the tentpole property)
# ---------------------------------------------------------------------------

def _stream(kind: str, seed: int, n_jobs: int, rate: float):
    if kind == "poisson":
        return poisson_arrivals(seed, rate=rate, n_jobs=n_jobs)
    return production_arrivals(seed, rate=rate, n_jobs=n_jobs)


def _assert_results_identical(a, b):
    assert len(a.jobs) == len(b.jobs)
    for ja, jb in zip(a.jobs, b.jobs):
        for f in dataclasses.fields(ja):
            va, vb = getattr(ja, f.name), getattr(jb, f.name)
            if isinstance(va, np.ndarray):
                assert np.array_equal(va, vb), f.name
            else:
                assert va == vb, f.name
    assert a.horizon == b.horizon
    assert a.n_epochs == b.n_epochs
    assert a.rack_utilization == b.rack_utilization
    assert a.wired_utilization == b.wired_utilization
    assert a.wireless_utilization == b.wireless_utilization


def _check_compaction_equivalence(kind, seed, n_jobs, rate, policy):
    evs = _stream(kind, seed, n_jobs, rate)
    kw = dict(window=5.0, policy=policy, seed=seed)
    if policy == "fleet":
        kw["solver_kwargs"] = FAST_SOLVER
    plain = OnlineScheduler(6, 2, **kw).serve(evs)
    compacted = OnlineScheduler(6, 2, compact_interval=1, **kw).serve(evs)
    _assert_results_identical(plain, compacted)
    # Compaction actually retired history (the streams overlap in time) and
    # the retained index is the uncompacted one minus the retirees.
    assert compacted.timeline.n_compacted > 0
    assert (
        compacted.timeline.n_intervals + compacted.timeline.n_compacted
        == plain.timeline.n_intervals
    )
    # Busy accumulators are charged at commit: identical on both arms.
    assert compacted.timeline.wired_busy_time == plain.timeline.wired_busy_time


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=6,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        kind=st.sampled_from(["poisson", "production"]),
        seed=st.integers(0, 10**6),
        n_jobs=st.integers(4, 10),
        rate=st.sampled_from([1 / 20, 1 / 60]),
    )
    def test_compaction_equivalence_property(kind, seed, n_jobs, rate):
        _check_compaction_equivalence(kind, seed, n_jobs, rate, "greedy_list")

else:  # fixed seeded sweep of the same property

    @pytest.mark.parametrize("case", range(6))
    def test_compaction_equivalence_property(case):
        rng = np.random.default_rng(3000 + case)
        _check_compaction_equivalence(
            kind=["poisson", "production"][case % 2],
            seed=int(rng.integers(10**6)),
            n_jobs=int(rng.integers(4, 11)),
            rate=float([1 / 20, 1 / 60][case % 2]),
            policy="greedy_list",
        )


def test_compaction_equivalence_fleet_policy():
    """The engine path (warm starts, keep-incumbent, mega-batches) is just
    as oblivious to compaction as the baselines."""
    _check_compaction_equivalence("production", 3, 6, 1 / 30, "fleet")


# ---------------------------------------------------------------------------
# Interval index + compaction unit contracts
# ---------------------------------------------------------------------------

def _committed_cluster(seed=0, n_jobs=8):
    evs = production_arrivals(seed, rate=1 / 20, n_jobs=n_jobs)
    res = OnlineScheduler(6, 2, window=5.0, policy="greedy_list",
                         seed=seed).serve(evs)
    return res


def test_interval_index_is_sorted_and_tail_query_matches_scan():
    res = _committed_cluster()
    tl = res.timeline
    assert tl.wired_intervals, "stream must contend on the wired channel"
    starts = [s for s, _, _ in tl.wired_intervals]
    ends = [e for _, e, _ in tl.wired_intervals]
    assert starts == sorted(starts)
    assert ends == sorted(ends)  # disjointness makes the end column sorted
    for t in (0.0, ends[len(ends) // 2], res.horizon):
        tail = ClusterTimeline._tail(tl.wired_intervals, t)
        assert tail == [iv for iv in tl.wired_intervals if iv[1] > t]


def test_compact_retires_only_finished_intervals_and_raises_frontier():
    res = _committed_cluster()
    tl = res.timeline
    t_mid = res.horizon / 2
    before = tl.n_intervals
    keep = len(ClusterTimeline._tail(tl.wired_intervals, t_mid))
    dropped = tl.compact(t_mid)
    assert dropped > 0 and tl.n_intervals == before - dropped
    assert len(tl.wired_intervals) == keep
    assert all(e > t_mid for _, e, _ in tl.wired_intervals)
    assert tl.compact_frontier == t_mid
    assert tl.n_compacted == dropped
    # Queries at or past the frontier still work; earlier ones refuse
    # (the retired history cannot be replayed).
    inst = production_arrivals(0, rate=1.0, n_jobs=1)[0].inst
    view = tl.residual_view(inst, res.horizon)
    assert tl.channel_busy(view, res.horizon) == {}
    with pytest.raises(RuntimeError, match="compaction frontier"):
        tl.channel_busy(view, t_mid - 1.0)


def test_utilization_out_of_range_raises_not_asserts():
    tl = ClusterTimeline(2, 1)
    tl.rack_busy_time = 1e9  # corrupt the accumulator
    with pytest.raises(RuntimeError, match="utilization"):
        tl.utilization(1.0)


def test_incremental_audit_catches_overlap_and_full_rescan():
    tl = ClusterTimeline(2, 0)
    tl._insert("wired channel", tl.wired_intervals, (0.0, 10.0, 1))
    tl._insert("wired channel", tl.wired_intervals, (5.0, 8.0, 2))
    with pytest.raises(AssertionError, match="overlap"):
        tl.assert_feasible()
    # The incremental backlog was consumed by the failed audit; the full
    # rescan still sees the (retained) overlap.
    with pytest.raises(AssertionError, match="overlap"):
        tl.assert_feasible(full=True)
    # Disjoint commits audit clean, incrementally and fully.
    tl2 = ClusterTimeline(2, 0)
    for iv in [(0.0, 1.0, 1), (2.0, 3.0, 2), (1.0, 2.0, 3)]:
        tl2._insert("wired channel", tl2.wired_intervals, iv)
    tl2.assert_feasible()
    tl2.assert_feasible(full=True)


def test_incremental_audit_only_checks_new_intervals():
    tl = ClusterTimeline(1, 0)
    tl._insert("wired channel", tl.wired_intervals, (0.0, 1.0, 1))
    tl.assert_feasible()
    assert not tl._audit_backlog
    # Corrupting retained history escapes the incremental audit (that is
    # the point: O(new) not O(all)) but not the full rescan.
    tl.wired_intervals.append((0.5, 0.9, 99))
    tl.wired_intervals.sort()
    tl.assert_feasible()  # incremental: no new commits, nothing to check
    with pytest.raises(AssertionError, match="overlap"):
        tl.assert_feasible(full=True)


# ---------------------------------------------------------------------------
# Incremental free sets
# ---------------------------------------------------------------------------

def test_free_set_matches_nonzero_reference_under_random_traffic():
    rng = np.random.default_rng(0)
    n = 9
    hold = np.zeros(n)
    fs = _FreeSet(n)
    t = 0.0
    for _ in range(400):
        t += float(rng.exponential(2.0))
        fs.advance(t, hold)
        ref = np.nonzero(hold <= t)[0]
        assert np.array_equal(fs.as_array(), ref)
        # Grant a random subset of the free ids, sometimes re-extending a
        # hold that is already in the heap (the stale-entry path).
        for i in ref[: int(rng.integers(0, ref.size + 1))]:
            hold[i] = t + float(rng.exponential(5.0))
            fs.grant(int(i), float(hold[i]))


def test_free_set_stale_heap_entry_self_corrects():
    hold = np.zeros(3)
    fs = _FreeSet(3)
    hold[1] = 10.0
    fs.grant(1, 10.0)
    # The hold is extended after the first grant's heap entry was pushed.
    hold[1] = 20.0
    fs.grant(1, 20.0)
    fs.advance(15.0, hold)  # pops the stale (10.0, 1) entry, re-checks
    assert fs.as_array().tolist() == [0, 2]
    fs.advance(20.0, hold)
    assert fs.as_array().tolist() == [0, 1, 2]


# ---------------------------------------------------------------------------
# Streaming workload generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["poisson", "production"])
def test_streaming_generators_match_list_api(kind):
    if kind == "poisson":
        eager = poisson_arrivals(5, rate=0.05, n_jobs=10)
        lazy = list(stream_poisson_arrivals(5, rate=0.05, n_jobs=10))
    else:
        eager = production_arrivals(5, rate=0.05, n_jobs=10,
                                    min_wireless_demand=0)
        lazy = list(
            stream_production_arrivals(5, rate=0.05, n_jobs=10,
                                       min_wireless_demand=0)
        )
    assert len(eager) == len(lazy) == 10
    for a, b in zip(eager, lazy):
        assert a.time == b.time and a.job_id == b.job_id
        assert a.family == b.family
        assert a.inst.n_racks == b.inst.n_racks
        assert a.inst.n_wireless == b.inst.n_wireless
        assert np.array_equal(a.inst.job.p, b.inst.job.p)
        assert np.array_equal(a.inst.job.edges, b.inst.job.edges)
        assert np.array_equal(a.inst.job.d, b.inst.job.d)


def test_streaming_generators_validate_eagerly():
    with pytest.raises(ValueError, match="rate"):
        stream_poisson_arrivals(0, rate=0.0, n_jobs=1)
    with pytest.raises(ValueError, match="min_rack_demand"):
        stream_production_arrivals(0, rate=1.0, n_jobs=1, min_rack_demand=99)


def test_serve_accepts_lazy_stream_and_matches_list_serve():
    kw = dict(window=5.0, policy="greedy_list", seed=2)
    a = OnlineScheduler(6, 2, **kw).serve(
        production_arrivals(2, rate=1 / 30, n_jobs=8)
    )
    b = OnlineScheduler(6, 2, **kw).serve(
        stream_production_arrivals(2, rate=1 / 30, n_jobs=8)
    )
    _assert_results_identical(a, b)


def test_unsorted_lazy_stream_is_rejected():
    evs = production_arrivals(0, rate=1 / 30, n_jobs=4)
    shuffled = [evs[1], evs[0], evs[2], evs[3]]
    with pytest.raises(ValueError, match="sorted"):
        OnlineScheduler(6, 2, policy="greedy_list").serve(iter(shuffled))
    # A materialized (indexable) sequence is sorted for the caller, as the
    # pre-pipeline service did.
    res = OnlineScheduler(6, 2, policy="greedy_list", window=5.0).serve(shuffled)
    assert [j.job_id for j in res.jobs] == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# Streaming metrics
# ---------------------------------------------------------------------------

def test_streaming_series_exact_small_sample():
    xs = [5.0, 1.0, 9.0, 3.0, 7.0]
    s = StreamingSeries()
    for x in xs:
        s.push(x)
    assert s.count == 5 and s.min == 1.0 and s.max == 9.0
    assert s.mean == pytest.approx(np.mean(xs))
    for p in (0.5, 0.9, 0.99):
        assert s.quantile(p) == pytest.approx(np.percentile(xs, 100 * p))


def test_streaming_series_p2_tracks_true_quantiles_at_scale():
    rng = np.random.default_rng(42)
    xs = rng.gamma(2.0, 50.0, size=20_000)
    s = StreamingSeries()
    for x in xs:
        s.push(x)
    assert s.count == xs.size
    assert s.mean == pytest.approx(float(xs.mean()))
    assert s.max == float(xs.max()) and s.min == float(xs.min())
    for p in (0.5, 0.9, 0.99):
        true = float(np.percentile(xs, 100 * p))
        assert s.quantile(p) == pytest.approx(true, rel=0.05)


def test_streaming_series_p2_switch_boundary_stays_in_range():
    """The exact->sketch handoff at ``exact_max`` samples: right after
    the switch the P-squared markers have seen almost no post-seed data
    and the parabolic adjustment can wander — every tracked percentile
    must still land inside the observed [min, max] at every stream
    length through the transition, finite, and close to exact for the
    median."""
    rng = np.random.default_rng(7)
    xs = list(rng.gamma(2.0, 50.0, size=80))
    s = StreamingSeries()
    for n, x in enumerate(xs, start=1):
        s.push(x)
        if n < 60:
            continue
        lo, hi = min(xs[:n]), max(xs[:n])
        for p in (0.5, 0.9, 0.95, 0.99):
            v = s.quantile(p)
            assert np.isfinite(v)
            assert lo <= v <= hi, (n, p, v, lo, hi)
    # One sample past the switch the high quantiles clamp to the
    # observed range rather than extrapolating beyond it.
    assert s.count == 80
    assert s.quantile(0.5) == pytest.approx(
        float(np.percentile(xs, 50)), rel=0.25
    )


def test_streaming_series_sketch_handles_nonfinite_samples():
    """Non-finite observations can poison the P-squared marker heights
    into NaN; the accessor must fall back to the nearest observed
    extreme instead of returning NaN."""
    s = StreamingSeries()
    for x in range(65):
        s.push(float(x))
    s.push(float("nan"))
    for _ in range(10):
        s.push(1.0)
    for p in (0.5, 0.99):
        v = s.quantile(p)
        assert not np.isnan(v)


def test_streaming_series_constant_stream_through_switch_is_exact():
    s = StreamingSeries()
    for _ in range(70):
        s.push(3.5)
    for p in (0.5, 0.9, 0.95, 0.99):
        assert s.quantile(p) == 3.5


def test_streaming_series_empty_and_untracked():
    # Zero-sample statistics are NaN (there is no quantile of nothing),
    # never 0.0 — renderers turn NaN into "n/a" / omitted lines.
    s = StreamingSeries()
    for v in (s.quantile(0.5), s.mean, s.min, s.max):
        assert np.isnan(v)
    for _ in range(200):
        s.push(1.0)
    with pytest.raises(KeyError, match="not tracked"):
        s.quantile(0.123)


def test_online_result_reports_streaming_percentiles():
    evs = production_arrivals(0, rate=1 / 20, n_jobs=10)
    res = OnlineScheduler(6, 2, window=5.0, policy="greedy_list",
                         seed=0).serve(evs)
    assert res.queue_stats is not None and res.queue_stats.count == 10
    assert res.jct_stats is not None and res.jct_stats.count == 10
    # Small-n mode: the streaming figures are the exact percentiles.
    assert res.p50_jct == pytest.approx(float(np.percentile(res.jcts, 50)))
    assert res.p99_queueing_delay == pytest.approx(
        float(np.percentile(res.queueing_delays, 99))
    )
    assert res.peak_active >= 1
    assert res.peak_queue_depth >= 1
    assert res.n_served == 10 and res.n_jobs == 10
    out = res.summary()
    assert "queue_p50/p90/p99=" in out and "jct_p50/p90/p99=" in out
    assert "peak_active=" in out


def test_record_jobs_off_keeps_stats_and_counters():
    evs = production_arrivals(1, rate=1 / 20, n_jobs=10)
    kw = dict(window=5.0, policy="greedy_list", seed=1)
    full = OnlineScheduler(6, 2, **kw).serve(evs)
    lean = OnlineScheduler(6, 2, record_jobs=False, **kw).serve(evs)
    assert lean.jobs == [] and lean.n_served == 10 and lean.n_jobs == 10
    assert lean.horizon == full.horizon
    assert lean.n_epochs == full.n_epochs
    for p in (0.5, 0.9, 0.99):
        assert lean.jct_stats.quantile(p) == full.jct_stats.quantile(p)
        assert lean.queue_stats.quantile(p) == full.queue_stats.quantile(p)
    assert lean.mean_jct == pytest.approx(full.mean_jct)
    assert "jobs=10" in lean.summary()


def test_epoch_latency_tracking_is_opt_in():
    evs = production_arrivals(0, rate=1 / 20, n_jobs=6)
    kw = dict(window=5.0, policy="greedy_list", seed=0)
    off = OnlineScheduler(6, 2, **kw).serve(evs)
    on = OnlineScheduler(6, 2, track_epoch_latency=True, **kw).serve(evs)
    assert off.epoch_commit_latency is None
    assert on.epoch_commit_latency is not None
    assert len(on.epoch_commit_latency) == on.n_epochs
    assert all(x >= 0.0 for x in on.epoch_commit_latency)


# ---------------------------------------------------------------------------
# Bounded re-plan + op-table cache
# ---------------------------------------------------------------------------

def test_bounded_replan_preserves_cold_commits_with_fewer_solves():
    """Cold admission solves ignore queue history, so skipping planning
    re-solves while the free-capacity fingerprint is unchanged cannot
    change any committed schedule — only the solve counter."""
    evs = production_arrivals(4, rate=1 / 8, n_jobs=6)
    kw = dict(window=5.0, warm_start=False, require_full_demand=True,
              preserve_order=True, solver_kwargs=FAST_SOLVER, seed=4)
    always = OnlineScheduler(6, 2, replan="always", **kw).serve(evs)
    bounded = OnlineScheduler(6, 2, replan="changed", **kw).serve(evs)
    # Not _assert_results_identical: per-job n_solves differs by design.
    assert len(always.jobs) == len(bounded.jobs)
    for ja, jb in zip(always.jobs, bounded.jobs):
        assert ja.admitted == jb.admitted
        assert ja.completion == jb.completion
        assert np.array_equal(ja.assignment, jb.assignment)
    assert bounded.n_solves <= always.n_solves
    assert bounded.mean_jct == pytest.approx(always.mean_jct)


def test_schedule_fleet_accepts_prebuilt_op_tables():
    rng = np.random.default_rng(0)
    insts = [
        ProblemInstance(job=random_job(np.random.default_rng(s), None,
                                       n_tasks=5, rho=1.0),
                        n_racks=3, n_wireless=1)
        for s in range(3)
    ]
    base = schedule_fleet(insts, seed=0, **FAST_SOLVER)
    cached = schedule_fleet(
        insts, seed=0, op_tables=[build_op_tables(i) for i in insts],
        **FAST_SOLVER,
    )
    for a, b in zip(base.results, cached.results):
        assert a.makespan == b.makespan
        assert np.array_equal(a.best_assignment, b.best_assignment)
        assert a.n_candidates == b.n_candidates
        assert a.n_pruned == b.n_pruned
    with pytest.raises(ValueError, match="one OpTables"):
        schedule_fleet(insts, op_tables=[build_op_tables(insts[0])])


# ---------------------------------------------------------------------------
# Stress lane smoke
# ---------------------------------------------------------------------------

def test_stress_lane_smoke_emits_stress_record():
    from benchmarks import common
    from benchmarks.online_serving import run_stress

    common.reset_results()
    try:
        ratio, overhead = run_stress(n_jobs=300)
        assert overhead is None  # untraced arm does not rerun the stream
        assert np.isfinite(ratio) and ratio > 0
        rec = common.RESULTS[-1]
        assert rec["kind"] == "stress"
        m = rec["metrics"]
        assert m["n_jobs"] == 300
        assert m["latency_ratio"] == pytest.approx(ratio, abs=5e-4)
        for k in ("queue_p50", "queue_p90", "queue_p99",
                  "jct_p50", "jct_p90", "jct_p99",
                  "peak_active", "peak_queue", "intervals_compacted"):
            assert k in m
    finally:
        common.reset_results()


def test_stress_lane_traced_arm_writes_perfetto_trace(tmp_path):
    from benchmarks import common
    from benchmarks.online_serving import run_stress
    from repro.obs.report import (
        commit_latency_total,
        epoch_breakdown,
        load_trace,
    )

    out = tmp_path / "stress_trace.json"
    common.reset_results()
    try:
        ratio, overhead = run_stress(n_jobs=300, trace_out=str(out))
        assert np.isfinite(ratio)
        # Overhead is wall-clock noise at this scale; just require the
        # traced serve actually ran and the record carries the fields.
        assert overhead is not None and overhead > 0
        m = common.RESULTS[-1]["metrics"]
        assert m["tracer_overhead"] == pytest.approx(overhead, abs=5e-4)
        assert "traced_wall_s" in m
        trace = load_trace(out)
        rows = epoch_breakdown(trace)
        assert len(rows) == m["n_epochs"]
        assert commit_latency_total(trace) > 0.0
    finally:
        common.reset_results()
