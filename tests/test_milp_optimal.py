"""RP linearization correctness: three independent solvers must agree.

The RP MILP (HiGHS B&B — the paper's method), the combinatorial B&B, and the
§IV-D bisection decomposition are mutually independent implementations;
agreement on the optimum across random instances validates the reformulation
(constraints (11)–(26)) against OP's semantics (enforced by check_feasible).
"""

import numpy as np
import pytest

from repro.core import (
    ProblemInstance,
    check_feasible,
    lower_bound,
    random_job,
    solve_bisection,
    solve_bnb,
    solve_optimal,
    upper_bound,
)
from repro.core.milp import build_rp
from repro.core.solver_milp import solve_rp

EPS_SLACK = 0.15  # the paper's ε=0.1 strict-precedence slack


def make_instance(seed, n_tasks=5, n_racks=3, n_wireless=None, rho=None):
    rng = np.random.default_rng(seed)
    if n_wireless is None:
        n_wireless = int(rng.integers(0, 3))
    if rho is None:
        rho = float(rng.uniform(0.2, 2.0))
    job = random_job(rng, None, n_tasks=n_tasks, rho=rho)
    return ProblemInstance(job=job, n_racks=n_racks, n_wireless=n_wireless)


@pytest.mark.parametrize("seed", range(6))
def test_three_solvers_agree(seed):
    inst = make_instance(seed)
    r_milp = solve_optimal(inst, time_limit=90)
    r_bnb = solve_bnb(inst, time_limit=60)
    r_bis = solve_bisection(inst, time_limit_per_fp=60, rel_tol=1e-4)
    assert r_milp.schedule is not None
    check_feasible(inst, r_milp.schedule, tol=1e-4)
    check_feasible(inst, r_bnb.schedule)
    assert r_bnb.makespan == pytest.approx(r_milp.makespan, abs=EPS_SLACK)
    assert r_bis.makespan == pytest.approx(
        r_milp.makespan, abs=max(EPS_SLACK, 1e-3 * r_milp.makespan + 1e-4)
    )


@pytest.mark.parametrize("seed", range(3))
def test_paper_exact_binding_equivalent(seed):
    """(12)/(13) verbatim vs tight big-M binding reach the same optimum."""
    inst = make_instance(seed, n_tasks=4)
    a = solve_optimal(inst, time_limit=60, paper_exact_binding=False)
    b = solve_optimal(inst, time_limit=60, paper_exact_binding=True)
    assert a.makespan == pytest.approx(b.makespan, abs=EPS_SLACK)


def test_optimal_within_paper_bounds():
    for seed in range(5):
        inst = make_instance(seed + 50, n_tasks=5)
        r = solve_bnb(inst, time_limit=30)
        assert lower_bound(inst) - 1e-6 <= r.makespan <= upper_bound(inst) + 1e-6


def test_wireless_augmentation_never_worse():
    """More subchannels can only reduce the optimal JCT (monotonicity)."""
    for seed in range(4):
        rng = np.random.default_rng(seed)
        job = random_job(rng, None, n_tasks=5, rho=1.0)
        prev = None
        for k in (0, 1, 2):
            inst = ProblemInstance(job=job, n_racks=3, n_wireless=k)
            mk = solve_bnb(inst, time_limit=30).makespan
            if prev is not None:
                assert mk <= prev + EPS_SLACK
            prev = mk


def test_rp_model_dimensions():
    inst = make_instance(0, n_tasks=4, n_racks=2, n_wireless=1)
    model = build_rp(inst)
    vm = model.vm
    n, M, m, C = vm.n, vm.M, vm.m, vm.C
    assert C == 3  # wired + local + 1 wireless
    expected = (
        2 * n * M + 2 * m * C + vm.n_pairs_v * M + n * (n - 1)
        + vm.n_pairs_e * (C - 1) + m * (m - 1) + 1
    )
    assert vm.n_vars == expected
    res = solve_rp(model, time_limit=60)
    assert res.schedule is not None


def test_infeasible_fp_detected():
    """FP with ℓ below T_min must be infeasible (status 2)."""
    inst = make_instance(1, n_tasks=4)
    lo = lower_bound(inst)
    model = build_rp(inst, tmax=lo * 0.5, feasibility_only=True)
    res = solve_rp(model, time_limit=60, verify=False)
    assert res.schedule is None
