"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # interpret-mode oracle sweeps dominate suite wall time

from repro.kernels import ops
from repro.kernels.ref import (
    ref_combined_lb,
    ref_critical_path,
    ref_decode_attention,
    ref_flash_attention,
)
from repro.models.flash import flash_attention as jnp_flash


@pytest.mark.parametrize(
    "B,S,H,KV,D,bq,bk,causal",
    [
        (1, 256, 4, 4, 128, 128, 128, True),
        (2, 128, 8, 2, 64, 64, 64, True),
        (1, 512, 8, 8, 128, 128, 256, False),
        (1, 128, 4, 1, 128, 32, 128, True),   # MQA
        (2, 256, 16, 4, 64, 128, 64, True),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_matches_oracle(B, S, H, KV, D, bq, bk, causal, dtype):
    rng = np.random.default_rng(hash((B, S, H, KV, D)) % 2**31)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), dtype)
    got = ops.flash_attention(q, k, v, causal, bq, bk)
    want = ref_flash_attention(q, k, v, causal)
    tol = 4e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_flash_kernel_matches_jnp_flash_twin():
    """kernels/flash_attention and models/flash share the blocking scheme."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((2, 256, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 4, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 4, 64)), jnp.float32)
    a = ops.flash_attention(q, k, v, True, 64, 64)
    b = jnp_flash(q, k, v, True, 64)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize(
    "B,H,KV,D,T,kvlen",
    [
        (2, 8, 2, 128, 1024, 700),
        (1, 4, 4, 64, 512, 512),
        (3, 8, 8, 128, 2048, 1),
        (2, 16, 2, 64, 4096, 3000),
    ],
)
def test_decode_kernel_matches_oracle(B, H, KV, D, T, kvlen):
    rng = np.random.default_rng(hash((B, H, T)) % 2**31)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, KV, D)), jnp.float32)
    got = ops.decode_attention(q, k, v, jnp.int32(kvlen))
    want = ref_decode_attention(q, k, v, jnp.int32(kvlen))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_decode_kernel_per_batch_lengths():
    rng = np.random.default_rng(3)
    B, H, KV, D, T = 4, 8, 4, 64, 512
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, KV, D)), jnp.float32)
    lens = jnp.asarray([1, 100, 256, 512], jnp.int32)
    got = ops.decode_attention(q, k, v, lens)
    want = ref_decode_attention(q, k, v, lens)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,n", [(8, 8), (16, 12), (32, 16)])
def test_cpm_kernel_matches_oracle(B, n):
    rng = np.random.default_rng(n)
    w = np.full((B, n, n), -np.inf)
    for b in range(B):
        for _ in range(3 * n):
            u, v = sorted(rng.choice(n, 2, replace=False))
            w[b, u, v] = max(w[b, u, v], rng.uniform(1, 10))
    got = np.asarray(ops.batched_critical_path(jnp.asarray(w, jnp.float32)))
    want = ref_critical_path(w)
    np.testing.assert_allclose(got, want, atol=1e-4)


def _ragged_lb_megabatch(rng, B, n):
    """Mega-batch mimicking a heterogeneous fleet: each row a different-size
    DAG padded to n (padded nodes have no edges and zero duration), some
    rows all-padding."""
    w = np.full((B, n, n), -np.inf)
    p = np.zeros((B, n), np.float32)
    extra = np.full(B, -np.inf, np.float32)
    for b in range(B):
        nb = int(rng.integers(0, n + 1))  # 0 = all-padding row
        p[b, :nb] = rng.uniform(1, 100, size=nb)
        for _ in range(3 * nb):
            if nb >= 2:
                u, v = sorted(rng.choice(nb, 2, replace=False))
                w[b, u, v] = max(w[b, u, v], rng.uniform(1, 10))
        if rng.uniform() < 0.7 and nb:
            extra[b] = rng.uniform(0, 300)
    return w, p, extra


@pytest.mark.parametrize("B,n,block_b", [(13, 8, 8), (32, 12, 8), (257, 16, 64)])
def test_combined_lb_kernel_matches_oracle_ragged(B, n, block_b):
    """Fused contention-LB kernel vs the NumPy reference on ragged/padded
    mega-batches, including all-padding rows and odd batch sizes."""
    rng = np.random.default_rng(B * n)
    w, p, extra = _ragged_lb_megabatch(rng, B, n)
    got = np.asarray(
        ops.batched_combined_lb(
            jnp.asarray(w, jnp.float32), jnp.asarray(p), jnp.asarray(extra),
            block_b=block_b,
        )
    )
    want = ref_combined_lb(w, p, extra)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-5)
    # all-padding rows come out exactly 0 (no work, disabled extra)
    empty = (p.sum(axis=1) == 0) & ~np.isfinite(extra)
    assert (got[empty] == 0.0).all()


def test_combined_lb_kernel_extra_term_dominates():
    """Rows where the contention term exceeds the critical path must return
    the contention term (max fusion, not overwrite)."""
    rng = np.random.default_rng(7)
    B, n = 16, 8
    w, p, _ = _ragged_lb_megabatch(rng, B, n)
    cpm_only = ref_combined_lb(w, p, np.full(B, -np.inf, np.float32))
    extra = cpm_only + rng.uniform(1, 50, size=B).astype(np.float32)
    got = np.asarray(
        ops.batched_combined_lb(
            jnp.asarray(w, jnp.float32), jnp.asarray(p), jnp.asarray(extra)
        )
    )
    np.testing.assert_allclose(got, extra, atol=1e-4, rtol=1e-5)
    # and when extra is dominated, the critical-path bound survives
    got_lo = np.asarray(
        ops.batched_combined_lb(
            jnp.asarray(w, jnp.float32), jnp.asarray(p),
            jnp.asarray(cpm_only - 1.0),
        )
    )
    np.testing.assert_allclose(got_lo, cpm_only, atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("B,n,block_b", [(13, 8, 8), (32, 12, 8), (257, 16, 64)])
def test_combined_lb_kernel_mask_matches_oracle_ragged(B, n, block_b):
    """Matching-feasibility mask path vs the NumPy reference on ragged
    mega-batches: per-edge wired uplifts on a random subset of edges,
    including all-padding rows."""
    rng = np.random.default_rng(B * n + 1)
    w, p, extra = _ragged_lb_megabatch(rng, B, n)
    mask = np.zeros((B, n, n), np.float32)
    sel = np.isfinite(w) & (rng.uniform(size=w.shape) < 0.5)
    mask[sel] = rng.uniform(0, 20, size=int(sel.sum()))
    got = np.asarray(
        ops.batched_combined_lb(
            jnp.asarray(w, jnp.float32), jnp.asarray(p), jnp.asarray(extra),
            mask=jnp.asarray(mask), block_b=block_b,
        )
    )
    want = ref_combined_lb(w, p, extra, mask=mask)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-5)
    # all-padding rows still come out exactly 0 under a mask
    empty = (p.sum(axis=1) == 0) & ~np.isfinite(extra)
    assert (got[empty] == 0.0).all()
    # the masked bound is never below the unmasked bound (uplift >= 0)
    base = np.asarray(
        ops.batched_combined_lb(
            jnp.asarray(w, jnp.float32), jnp.asarray(p), jnp.asarray(extra),
            block_b=block_b,
        )
    )
    assert (got >= base - 1e-4).all()


def test_combined_lb_kernel_zero_mask_is_identity():
    """An all-zeros mask (all-ones topology) returns exactly the unmasked
    kernel's values."""
    rng = np.random.default_rng(11)
    B, n = 24, 10
    w, p, extra = _ragged_lb_megabatch(rng, B, n)
    base = np.asarray(
        ops.batched_combined_lb(
            jnp.asarray(w, jnp.float32), jnp.asarray(p), jnp.asarray(extra)
        )
    )
    zero = np.asarray(
        ops.batched_combined_lb(
            jnp.asarray(w, jnp.float32), jnp.asarray(p), jnp.asarray(extra),
            mask=jnp.zeros((B, n, n), jnp.float32),
        )
    )
    np.testing.assert_array_equal(base, zero)


def test_jnp_flash_gradients_match_naive():
    import jax

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 128, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 2, 32)), jnp.float32)

    f1 = lambda q, k, v: jnp.sum(jnp.sin(jnp_flash(q, k, v, True, 32)))
    f2 = lambda q, k, v: jnp.sum(jnp.sin(ref_flash_attention(q, k, v, True)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)
