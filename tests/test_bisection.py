"""Unit + property layer for the §IV-D bisection decomposition.

Three families of checks on :func:`repro.core.solve_bisection`:

  1. Bracket invariant: the recorded ``history`` is a valid bisection
     trajectory — the interval only ever shrinks, an infeasible midpoint
     raises ``lo`` to the midpoint, a feasible one drops ``hi`` to the
     achieved makespan (at or below the midpoint, modulo the FP solver's
     numeric slack), and the returned makespan is never below the final
     lower bracket.
  2. Convergence tolerance: the loop exits only once the gap clears
     ``max(abs_tol, rel_tol * max(1, hi))`` (or ``max_iters`` runs out),
     tightening ``rel_tol`` never loosens the final gap, and
     ``max_iters=0`` degenerates to the always-feasible single-rack
     fallback with an honest ``iterations == 0``.
  3. Agreement property: on random small instances the bisection optimum
     matches the combinatorial B&B optimum to within the requested
     tolerance, and the returned schedule passes OP feasibility. Runs
     under Hypothesis when installed, else a fixed seeded sweep of the
     same check (this container ships without hypothesis by design).
"""

import numpy as np
import pytest

from repro.core import (
    ProblemInstance,
    check_feasible,
    lower_bound,
    random_job,
    solve_bisection,
    solve_bnb,
    upper_bound,
)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

# FP feasibility is certified by solve_rp at a small numeric tolerance, so
# an "achieved" makespan may sit a hair above the probed midpoint.
FP_SLACK = 1e-3


def make_instance(seed, n_tasks=5, n_racks=3, n_wireless=None, rho=None):
    rng = np.random.default_rng(seed)
    if n_wireless is None:
        n_wireless = int(rng.integers(0, 3))
    if rho is None:
        rho = float(rng.uniform(0.2, 2.0))
    job = random_job(rng, None, n_tasks=n_tasks, rho=rho)
    return ProblemInstance(job=job, n_racks=n_racks, n_wireless=n_wireless)


def _assert_valid_trajectory(inst, res):
    """The bracket-update invariants, replayed from ``history``."""
    lo0, hi0 = lower_bound(inst), upper_bound(inst)
    if res.history:
        assert res.history[0][0] == pytest.approx(lo0)
        assert res.history[0][1] == pytest.approx(hi0)
    for i, (lo, hi, feasible) in enumerate(res.history):
        assert lo < hi
        mid = 0.5 * (lo + hi)
        if i + 1 < len(res.history):
            nlo, nhi, _ = res.history[i + 1]
            if feasible:
                # hi jumps to the achieved makespan, at or below mid.
                assert nlo == pytest.approx(lo)
                assert nhi <= mid + FP_SLACK
            else:
                assert nlo == pytest.approx(mid)
                assert nhi == pytest.approx(hi)
            # The interval never grows.
            assert nlo >= lo - 1e-12 and nhi <= hi + 1e-12
    assert res.iterations == len(res.history)
    # The optimum can't be below the proven lower bracket.
    final_lo = lo0
    for lo, hi, feasible in res.history:
        if not feasible:
            final_lo = 0.5 * (lo + hi)
    assert res.makespan >= final_lo - FP_SLACK


@pytest.mark.parametrize("seed", range(4))
def test_bracket_invariant(seed):
    inst = make_instance(seed)
    res = solve_bisection(inst, rel_tol=1e-3, time_limit_per_fp=60)
    assert res.schedule is not None
    check_feasible(inst, res.schedule, tol=1e-4)
    assert res.makespan == pytest.approx(res.schedule.makespan)
    _assert_valid_trajectory(inst, res)


def test_convergence_tolerance_respected():
    inst = make_instance(11)
    rel_tol = 1e-2
    res = solve_bisection(inst, rel_tol=rel_tol, max_iters=64,
                          time_limit_per_fp=60)
    # The loop only exits once the bracket clears the tolerance (max_iters
    # is generous enough to never bind here: each iteration at least
    # halves the gap).
    hi = res.makespan  # final hi tracks the incumbent's makespan
    assert res.final_gap <= max(1e-6, rel_tol * max(1.0, hi)) + 1e-12
    assert res.iterations < 64
    assert res.wall_s >= 0.0


def test_tighter_tolerance_never_loosens_gap():
    inst = make_instance(12)
    loose = solve_bisection(inst, rel_tol=3e-2, time_limit_per_fp=60)
    tight = solve_bisection(inst, rel_tol=1e-3, time_limit_per_fp=60)
    assert tight.final_gap <= loose.final_gap + 1e-12
    assert tight.iterations >= loose.iterations
    # Both brackets contain the same optimum: tightening can only improve
    # (lower) the certified makespan.
    assert tight.makespan <= loose.makespan + FP_SLACK


def test_max_iters_zero_falls_back_to_single_rack():
    inst = make_instance(13)
    res = solve_bisection(inst, max_iters=0)
    assert res.iterations == 0
    assert res.history == []
    assert res.schedule is not None
    check_feasible(inst, res.schedule)
    # The fallback is the always-feasible T_max witness.
    assert res.makespan <= upper_bound(inst) + FP_SLACK


def _check_agreement(seed, n_tasks, n_racks, n_wireless, rho):
    inst = make_instance(
        seed, n_tasks=n_tasks, n_racks=n_racks, n_wireless=n_wireless, rho=rho
    )
    res = solve_bisection(inst, rel_tol=1e-3, time_limit_per_fp=60)
    assert res.schedule is not None
    check_feasible(inst, res.schedule, tol=1e-4)
    _assert_valid_trajectory(inst, res)
    opt = solve_bnb(inst, time_limit=60)
    assert opt.proved_optimal
    tol = max(1e-3 * max(1.0, opt.makespan) + FP_SLACK, res.final_gap + FP_SLACK)
    assert res.makespan == pytest.approx(opt.makespan, abs=tol)


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=6,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        seed=st.integers(0, 10**6),
        n_tasks=st.integers(3, 5),
        n_racks=st.integers(2, 3),
        n_wireless=st.integers(0, 2),
        rho=st.floats(0.25, 2.0, allow_nan=False),
    )
    def test_bisection_matches_bnb_property(
        seed, n_tasks, n_racks, n_wireless, rho
    ):
        _check_agreement(seed, n_tasks, n_racks, n_wireless, rho)

else:  # fixed seeded sweep of the same property

    @pytest.mark.parametrize("case", range(6))
    def test_bisection_matches_bnb_property(case):
        rng = np.random.default_rng(4200 + case)
        _check_agreement(
            seed=int(rng.integers(10**6)),
            n_tasks=int(rng.integers(3, 6)),
            n_racks=int(rng.integers(2, 4)),
            n_wireless=int(rng.integers(0, 3)),
            rho=float(rng.uniform(0.25, 2.0)),
        )
