"""Coflow-aware arbitration-order search, locked by an exhaustive-
permutation oracle.

Four layers:

  1. Unit contracts on the pure search module (``repro.core.coflow``):
     sigma ordering, coflow extraction, the arbitration-strategy
     registry, and ``search_commit_order`` against synthetic objectives.
  2. The oracle layer: epoch batches of <= 5 jobs are brute-forced
     through the cluster's ``replay_commit_order`` (every permutation
     trial-committed via the real ``channel_busy`` arbitration path);
     the exhaustive search returns exactly the oracle optimum, sigma
     lands inside the oracle envelope (and *is* the oracle on the
     single-shared-resource workload it is a 2-approximation for), and
     ``arbitration="search"`` is never worse than FIFO by construction.
  3. Property layer: any commit permutation of a feasible epoch batch
     commits to a timeline that passes the full O(n log n) overlap
     audit, trial replay predicts real commits bit-for-bit, and the
     default ``arbitration="fifo"`` service is bit-identical across
     runs and insensitive to the (unused) search knobs on seeded
     Poisson / production streams. Runs under Hypothesis when installed
     (CI's ``pip install -e .[test]`` lane); falls back to a fixed
     seeded sweep otherwise, as in ``test_bounds_properties.py``.
  4. Backfill interaction: reordering an epoch never delays the blocked
     head-of-line job's admission epoch, and the PR-5 backfill counters
     are unchanged under ``arbitration="sigma"``.
"""

import dataclasses
import itertools

import numpy as np
import pytest

from repro.core import ProblemInstance, g_list_schedule, random_job
from repro.core.coflow import (
    Coflow,
    DEFAULT_ORDER_PORTFOLIO,
    WIRED,
    build_order_strategies,
    coflow_from_instance,
    coflow_from_schedule,
    search_commit_order,
    sigma_order,
    wireless_resource,
)
from repro.core.dag import make_onestage_mapreduce
from repro.core.portfolio import (
    ARBITRATION_STRATEGIES,
    SearchView,
    register_arbitration_strategy,
)
from repro.online import (
    ClusterTimeline,
    OnlineScheduler,
    poisson_arrivals,
    production_arrivals,
    replay_commit_order,
    trace_arrivals,
)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


def _cf(index, **demand):
    return Coflow(index=index, job_id=index, demand=demand)


# ---------------------------------------------------------------------------
# Unit: sigma ordering
# ---------------------------------------------------------------------------

def test_sigma_single_resource_is_shortest_demand_first():
    cfs = [_cf(0, wired=5.0), _cf(1, wired=3.0), _cf(2, wired=8.0)]
    assert sigma_order(cfs) == [1, 0, 2]


def test_sigma_all_equal_is_fifo():
    cfs = [_cf(i, wired=2.0) for i in range(4)]
    assert sigma_order(cfs) == [0, 1, 2, 3]


def test_sigma_zero_demand_coflows_head_the_order_in_fifo_rank():
    cfs = [_cf(0, wired=5.0), _cf(1), _cf(2, wired=1.0), _cf(3)]
    order = sigma_order(cfs)
    assert order == [1, 3, 2, 0]


def test_sigma_multi_resource_bottleneck_first():
    # wireless:0 carries load 9 (the bottleneck); coflow 0 dominates it
    # and goes last even though its wired demand is smallest.
    cfs = [
        Coflow(0, 0, {WIRED: 1.0, wireless_resource(0): 8.0}),
        Coflow(1, 1, {WIRED: 4.0, wireless_resource(0): 1.0}),
        Coflow(2, 2, {WIRED: 3.0}),
    ]
    order = sigma_order(cfs)
    assert order[-1] == 0
    assert sorted(order) == [0, 1, 2]


def test_sigma_is_a_permutation_on_random_batches():
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(1, 8))
        cfs = [
            Coflow(i, i, {WIRED: float(rng.uniform(0.0, 5.0))})
            for i in range(n)
        ]
        assert sorted(sigma_order(cfs)) == list(range(n))


# ---------------------------------------------------------------------------
# Unit: coflow extraction
# ---------------------------------------------------------------------------

def _mr_inst(seed, rho, n_racks=2, n_wireless=0):
    job = make_onestage_mapreduce(
        np.random.default_rng(seed), n_map=3, n_reduce=2, rho=rho
    )
    return ProblemInstance(job=job, n_racks=n_racks, n_wireless=n_wireless)


def test_coflow_from_instance_charges_wired_volume():
    inst = _mr_inst(0, rho=4.0)
    cf = coflow_from_instance(inst, index=3, job_id=17)
    assert cf.index == 3 and cf.job_id == 17
    assert cf.demand == {WIRED: pytest.approx(float(np.sum(inst.q_wired)))}
    assert cf.total == pytest.approx(float(np.sum(inst.q_wired)))


def test_coflow_from_schedule_matches_simulated_wired_busy_time():
    cl = ClusterTimeline(n_racks=4, n_wireless=0)
    inst = _mr_inst(1, rho=4.0)
    view = cl.residual_view(inst, 0.0)
    sched = g_list_schedule(view.inst, use_wireless=False)
    cf = coflow_from_schedule(view, sched, index=0)
    dur = view.inst.duration_on(sched.chan)
    wired = sum(
        float(dur[e])
        for e in range(view.inst.job.n_edges)
        if int(sched.chan[e]) == 0 and float(dur[e]) > 0.0
    )
    assert cf.demand.get(WIRED, 0.0) == pytest.approx(wired)
    assert wired > 0.0  # the workload actually exercises the wire


def test_coflow_from_schedule_maps_wireless_to_physical_subchannels():
    cl = ClusterTimeline(n_racks=4, n_wireless=3)
    # Occupy subchannel 0 so the residual grant maps local 0 -> phys 1.
    cl.wireless_hold[0] = 100.0
    inst = _mr_inst(2, rho=4.0, n_wireless=2)
    view = cl.residual_view(inst, 0.0)
    assert list(view.wireless_map) == [1, 2]
    sched = g_list_schedule(view.inst, use_wireless=True)
    cf = coflow_from_schedule(view, sched, index=0)
    for key in cf.demand:
        assert key in (WIRED, wireless_resource(1), wireless_resource(2))
        assert key != wireless_resource(0)


# ---------------------------------------------------------------------------
# Unit: strategy registry and neighborhoods
# ---------------------------------------------------------------------------

def test_registry_has_default_portfolio():
    for name in DEFAULT_ORDER_PORTFOLIO:
        assert name in ARBITRATION_STRATEGIES


def test_registry_rejects_duplicates_and_anonymous():
    with pytest.raises(ValueError, match="duplicate"):

        @register_arbitration_strategy
        class Dup:  # pragma: no cover - rejected before use
            name = "order_swap"

    with pytest.raises(ValueError, match="needs a `name`"):
        register_arbitration_strategy(type("Anon", (), {}))


def test_build_order_strategies_shapes_and_errors():
    default = build_order_strategies()
    assert [s.name for s in default] == list(DEFAULT_ORDER_PORTFOLIO)
    single = build_order_strategies("order_swap")
    assert [s.name for s in single] == ["order_swap"]
    with pytest.raises(ValueError, match="unknown arbitration strategy"):
        build_order_strategies(("no_such",))
    with pytest.raises(ValueError, match="duplicate"):
        build_order_strategies(("order_swap", "order_swap"))


@pytest.mark.parametrize("name", sorted(DEFAULT_ORDER_PORTFOLIO))
def test_order_strategies_propose_valid_permutations(name):
    rng = np.random.default_rng(5)
    strat = ARBITRATION_STRATEGIES[name]()
    for n in (2, 3, 5, 9):
        base = rng.permutation(n).astype(np.int32)
        view = SearchView(
            inst=None, rng=rng, best_rack=base, best_val=0.0,
            elites=[], round_index=0,
        )
        pool = strat.propose(view, 32)
        assert pool.shape == (32, n)
        for row in pool:
            assert sorted(int(x) for x in row) == list(range(n))
        # Neighborhood moves actually move (n >= 2 always has a swap).
        assert any(not np.array_equal(row, base) for row in pool)


# ---------------------------------------------------------------------------
# Unit: search_commit_order on synthetic objectives
# ---------------------------------------------------------------------------

def _srpt_objective(durations):
    """Total completion time of serially processing jobs in order — the
    classic single-machine objective whose optimum is shortest-first."""

    def evaluate(order):
        tot, clock = 0.0, 0.0
        for i in order:
            clock += durations[i]
            tot += clock
        return tot

    return evaluate


def test_search_exhaustive_small_batches_return_oracle():
    durations = [5.0, 1.0, 4.0]
    ev = _srpt_objective(durations)
    res = search_commit_order(ev, 3, rng=np.random.default_rng(0))
    assert res.exhaustive and res.n_evals == 6
    assert res.order == (1, 2, 0)  # shortest-first
    assert res.objective == pytest.approx(ev((1, 2, 0)))
    assert res.fifo_objective == pytest.approx(ev((0, 1, 2)))


def test_search_neighborhood_beats_fifo_and_never_worse():
    durations = [9.0, 2.0, 7.0, 1.0, 5.0]
    ev = _srpt_objective(durations)
    res = search_commit_order(
        ev, 5, rng=np.random.default_rng(3), rounds=4, pool_size=16
    )
    assert not res.exhaustive
    assert res.objective <= res.fifo_objective
    assert res.objective < res.fifo_objective  # plenty of budget: improves
    assert sorted(res.order) == list(range(5))


def test_search_seeds_are_evaluated_and_validated():
    ev = _srpt_objective([3.0, 1.0, 2.0, 4.0])
    srpt = (1, 2, 0, 3)
    res = search_commit_order(
        ev, 4, rng=np.random.default_rng(0), seeds=(srpt,), rounds=0,
        exhaustive_max=0,
    )
    assert res.order == srpt  # the seed is the SRPT optimum
    with pytest.raises(ValueError, match="not a permutation"):
        search_commit_order(
            ev, 4, rng=np.random.default_rng(0), seeds=((0, 0, 1, 2),),
            rounds=0, exhaustive_max=0,
        )
    with pytest.raises(ValueError, match="at least one job"):
        search_commit_order(ev, 0, rng=np.random.default_rng(0))


def test_search_caches_duplicate_orders():
    calls = []
    durations = [2.0, 1.0]

    def ev(order):
        calls.append(order)
        return _srpt_objective(durations)(order)

    res = search_commit_order(ev, 2, rng=np.random.default_rng(0))
    assert res.n_evals == len(calls) == len(set(calls)) == 2


def test_search_tuple_objectives_compare_lexicographically():
    # Rejections dominate: an order with a smaller total but one more
    # rejection must lose.
    objs = {
        (0, 1): (1, 5.0),
        (1, 0): (0, 50.0),
    }
    res = search_commit_order(
        lambda o: objs[o], 2, rng=np.random.default_rng(0)
    )
    assert res.order == (1, 0) and res.objective == (0, 50.0)


# ---------------------------------------------------------------------------
# Oracle layer: brute force through the real replay (satellite contract)
# ---------------------------------------------------------------------------

def _epoch_views(cl, insts, t=0.0):
    """Disjoint residual views for one epoch batch, drawn from shrinking
    pools exactly as the service's admission stage does."""
    pool = cl.free_racks(t)
    views = []
    for inst in insts:
        v = cl.residual_view(inst, t, rack_pool=pool)
        assert v is not None and v.full
        pool = pool[inst.n_racks:]
        views.append(v)
    return views


def _greedy_solver(view, busy):
    return g_list_schedule(
        view.inst, use_wireless=view.inst.n_wireless > 0, channel_busy=busy
    )


def _contended_batch(rhos):
    insts = [_mr_inst(j, rho=rho) for j, rho in enumerate(rhos)]
    cl = ClusterTimeline(n_racks=2 * len(insts), n_wireless=0)
    return cl, _epoch_views(cl, insts)


@pytest.mark.parametrize("rhos", [
    (8.0, 0.5, 4.0),
    (8.0, 0.5, 4.0, 2.0),
    (6.0, 1.0, 3.0, 9.0, 0.25),
])
def test_oracle_exhaustive_search_matches_brute_force(rhos):
    """Batches of <= 5 jobs brute-forced through ``replay_commit_order``:
    the exhaustive search returns exactly the oracle optimum."""
    cl, views = _contended_batch(rhos)
    n = len(views)

    def evaluate(order):
        return replay_commit_order(
            cl, 0.0, views, order, solver=_greedy_solver
        ).objective

    oracle = min(
        evaluate(perm) for perm in itertools.permutations(range(n))
    )
    res = search_commit_order(
        evaluate, n, rng=np.random.default_rng(0), exhaustive_max=n
    )
    assert res.exhaustive
    assert res.objective == oracle
    assert evaluate(res.order) == oracle


@pytest.mark.parametrize("rhos", [
    (8.0, 0.5, 4.0),
    (8.0, 0.5, 4.0, 2.0),
])
def test_oracle_sigma_within_envelope_and_search_never_worse(rhos):
    cl, views = _contended_batch(rhos)
    n = len(views)

    def evaluate(order):
        return replay_commit_order(
            cl, 0.0, views, order, solver=_greedy_solver
        ).objective

    all_objs = [
        evaluate(perm) for perm in itertools.permutations(range(n))
    ]
    oracle, worst = min(all_objs), max(all_objs)
    fifo_obj = evaluate(tuple(range(n)))
    coflows = [
        coflow_from_instance(v.inst, index=i) for i, v in enumerate(views)
    ]
    sigma_obj = evaluate(tuple(sigma_order(coflows)))
    # Sigma sits inside the oracle envelope...
    assert oracle <= sigma_obj <= worst
    # ...and the full search (sigma-seeded, FIFO-first) is never worse
    # than FIFO even with a tiny neighborhood budget.
    res = search_commit_order(
        evaluate, n, rng=np.random.default_rng(1),
        seeds=(tuple(sigma_order(coflows)),), rounds=1, pool_size=4,
        exhaustive_max=3 if n > 3 else n,
    )
    assert res.objective <= fifo_obj


def test_oracle_sigma_is_optimal_on_single_shared_resource_batch():
    """With only the wired channel shared and transfers dominating,
    bottleneck-first degenerates to shortest-demand-first — the optimal
    ordering for total completion time on one shared link. Lock that the
    heuristic actually lands on the oracle here (not just inside the
    envelope)."""
    cl, views = _contended_batch((8.0, 0.5, 4.0))
    n = len(views)

    def evaluate(order):
        return replay_commit_order(
            cl, 0.0, views, order, solver=_greedy_solver
        ).objective

    oracle = min(
        evaluate(perm) for perm in itertools.permutations(range(n))
    )
    coflows = [
        coflow_from_instance(v.inst, index=i) for i, v in enumerate(views)
    ]
    assert evaluate(tuple(sigma_order(coflows))) == oracle


# ---------------------------------------------------------------------------
# Property layer: permutation feasibility + replay/commit bit-identity
# ---------------------------------------------------------------------------

def _commit_in_order(cl, views, order, t=0.0):
    """Really commit the batch in ``order`` through the live path the
    service uses (busy-seeded solve, then commit) and return completions
    by batch position."""
    comps = [None] * len(views)
    for pos in order:
        view = views[pos]
        placed = _greedy_solver(view, cl.channel_busy(view, t))
        comps[pos] = cl.commit(view, placed, t)
    return comps


def _check_any_permutation_feasible(perm_seed):
    rng = np.random.default_rng(perm_seed)
    rhos = tuple(float(r) for r in rng.uniform(0.25, 8.0, size=4))
    cl, views = _contended_batch(rhos)
    order = tuple(int(i) for i in rng.permutation(len(views)))
    predicted = replay_commit_order(
        cl, 0.0, views, order, solver=_greedy_solver
    )
    comps = _commit_in_order(cl, views, order)
    cl.assert_feasible(full=True)
    # Trial replay predicted the real commits bit-for-bit.
    assert comps == predicted.completions
    assert predicted.n_rejected == 0


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=15,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(st.integers(min_value=0, max_value=10_000))
    def test_any_commit_permutation_is_feasible_hypothesis(perm_seed):
        _check_any_permutation_feasible(perm_seed)

else:

    @pytest.mark.parametrize("perm_seed", range(8))
    def test_any_commit_permutation_is_feasible_seeded(perm_seed):
        _check_any_permutation_feasible(perm_seed)


def _job_fingerprint(res):
    return [
        (m.job_id, m.admitted, m.jct, m.queueing_delay, m.backfilled)
        for m in res.jobs
    ]


@pytest.mark.parametrize("gen", ["poisson", "production"])
def test_fifo_arbitration_is_bit_identical_to_default(gen):
    """``arbitration="fifo"`` short-circuits before any replay, RNG draw,
    or float work — the served stream is bit-identical to the default
    construction and insensitive to the (unused) search knobs."""
    make = {
        "poisson": lambda: poisson_arrivals(
            11, rate=1 / 8, n_jobs=10, n_racks=4, n_wireless=2,
        ),
        "production": lambda: production_arrivals(
            11, rate=1 / 8, n_jobs=10, n_racks=4, n_wireless=2,
        ),
    }[gen]
    args = dict(window=4.0, policy="greedy_list", seed=11)
    base = OnlineScheduler(4, 2, **args).serve(make())
    fifo = OnlineScheduler(4, 2, arbitration="fifo", **args).serve(make())
    knobs = OnlineScheduler(
        4, 2, arbitration="fifo", arbitration_rounds=9,
        arbitration_pool=99, **args
    ).serve(make())
    fp = _job_fingerprint(base)
    assert _job_fingerprint(fifo) == fp
    assert _job_fingerprint(knobs) == fp
    for r in (base, fifo, knobs):
        assert r.n_order_evals == 0 and r.n_epochs_reordered == 0
        assert r.arbitration_gain == 0.0 and r.arbitration == "fifo"


def test_sigma_and_search_streams_pass_full_audit():
    evs = production_arrivals(
        5, rate=1 / 6, n_jobs=10, n_racks=6, n_wireless=2,
    )
    fifo = OnlineScheduler(
        6, 2, window=4.0, policy="greedy_list", seed=5
    ).serve(evs)
    for mode in ("sigma", "search"):
        res = OnlineScheduler(
            6, 2, window=4.0, policy="greedy_list", seed=5,
            arbitration=mode,
        ).serve(evs)
        res.timeline.assert_feasible(full=True)
        assert res.n_served == fifo.n_served
        assert res.arbitration == mode
        if mode == "search":
            # FIFO-first evaluation: the committed order of every epoch
            # replays no worse than FIFO, so the summed gain is >= 0.
            assert res.arbitration_gain >= -1e-9


def test_search_improves_contended_epoch_end_to_end():
    """The probe workload: four simultaneous wired-heavy jobs on the
    baseline policy. Search (and sigma) must strictly beat FIFO."""
    evs = []
    for j, rho in enumerate((8.0, 0.5, 4.0, 2.0)):
        inst = _mr_inst(j, rho=rho)
        evs.append(dataclasses.replace(
            trace_arrivals([0.0], [inst.job], n_racks=2, n_wireless=0)[0],
            job_id=j,
        ))
    results = {}
    for mode in ("fifo", "sigma", "search"):
        res = OnlineScheduler(
            8, 0, window=1.0, seed=0, policy="greedy_list",
            arbitration=mode,
        ).serve(evs)
        res.timeline.assert_feasible(full=True)
        results[mode] = res
    assert results["search"].mean_jct <= results["fifo"].mean_jct + 1e-9
    assert results["search"].mean_jct < results["fifo"].mean_jct - 1e-6
    assert results["sigma"].mean_jct < results["fifo"].mean_jct - 1e-6
    assert results["search"].n_epochs_reordered >= 1
    assert results["search"].arbitration_gain > 0.0


def test_arbitration_constructor_validation():
    with pytest.raises(ValueError, match="arbitration must be"):
        OnlineScheduler(4, 0, arbitration="lifo")
    with pytest.raises(ValueError, match="non-negative"):
        OnlineScheduler(4, 0, arbitration_rounds=-1)
    with pytest.raises(ValueError, match="positive"):
        OnlineScheduler(4, 0, arbitration_pool=0)
    with pytest.raises(ValueError, match="wireless_grants"):
        OnlineScheduler(4, 0, wireless_grants="shared")


# ---------------------------------------------------------------------------
# Backfill interaction under reordering (satellite contract)
# ---------------------------------------------------------------------------

def _scaled(job, factor):
    return dataclasses.replace(job, p=job.p * factor, d=job.d * factor)


def _hol_stream(tail_factor):
    """The PR-5 head-of-line trace: t=0 a long 3-rack job takes racks
    0-2 of a 4-rack cluster; t=1 a 2-rack job arrives (blocked); t=2 a
    1-rack job scaled by ``tail_factor`` arrives behind it."""
    rng = np.random.default_rng(9)
    jobs = [
        _scaled(random_job(rng, None, n_tasks=6), 10.0),
        random_job(rng, None, n_tasks=6),
        _scaled(random_job(rng, None, n_tasks=5), tail_factor),
    ]
    evs = trace_arrivals([0.0, 1.0, 2.0], jobs, n_racks=4, n_wireless=0)
    demands = (3, 2, 1)
    return [
        dataclasses.replace(e, inst=dataclasses.replace(e.inst, n_racks=d))
        for e, d in zip(evs, demands)
    ]


def _serve_hol(evs, arbitration):
    svc = OnlineScheduler(
        4, 0, window=0.0, policy="greedy_list", require_full_demand=True,
        preserve_order=True, backfill=True, arbitration=arbitration,
    )
    return svc.serve(evs)


@pytest.mark.parametrize("arbitration", ["sigma", "search"])
def test_reordering_never_delays_head_of_line_admission(arbitration):
    """Backfilled jobs under coflow reordering never delay the blocked
    head-of-line job's admission epoch, and the PR-5 backfill counters
    hold exactly."""
    evs = _hol_stream(tail_factor=0.02)
    fifo = _serve_hol(evs, "fifo")
    re = _serve_hol(evs, arbitration)
    assert re.n_backfilled == fifo.n_backfilled == 1
    assert re.jobs[2].backfilled
    assert re.jobs[2].admitted == 2.0  # its own arrival epoch
    # Exact, no tolerance: the head-of-line job's admission epoch is
    # bit-for-bit the FIFO one.
    assert re.jobs[1].admitted == fifo.jobs[1].admitted
    assert re.jobs[0].admitted == fifo.jobs[0].admitted == 0.0
    re.timeline.assert_feasible(full=True)


@pytest.mark.parametrize("arbitration", ["sigma", "search"])
def test_reordering_keeps_backfill_rejections(arbitration):
    """A long job the proof cannot clear must stay rejected no matter
    the commit order (``n_backfilled`` matches the PR-5 baseline)."""
    evs = _hol_stream(tail_factor=50.0)
    fifo = _serve_hol(evs, "fifo")
    re = _serve_hol(evs, arbitration)
    assert re.n_backfilled == fifo.n_backfilled == 0
    assert re.n_backfill_rejected >= 1
    assert [j.jct for j in re.jobs] == [j.jct for j in fifo.jobs]


# ---------------------------------------------------------------------------
# Interval wireless grants ride along on the same representation
# ---------------------------------------------------------------------------

def test_interval_wireless_grants_stay_feasible_and_never_lose_jobs():
    evs = production_arrivals(
        7, rate=1 / 6, n_jobs=10, n_racks=4, n_wireless=2,
        min_wireless_demand=1,
    )
    hold = OnlineScheduler(
        4, 2, window=4.0, policy="greedy_list", seed=7,
    ).serve(evs)
    interval = OnlineScheduler(
        4, 2, window=4.0, policy="greedy_list", seed=7,
        wireless_grants="interval",
    ).serve(evs)
    interval.timeline.assert_feasible(full=True)
    assert interval.n_served == hold.n_served == 10
