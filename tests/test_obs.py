"""Observability layer: tracer primitives, exporters, the offline
report, and the traced ⇄ untraced serve bit-identity lock.

Four layers:

  1. Tracer units: span nesting/attrs/durations, typed events, job
     marks, the counter/gauge/series registry, and the NullTracer
     contract (shared no-op span context, ``enabled=False``).
  2. Exporters: Chrome ``trace_event`` structure (spans → "X", decisions
     → "i", job lifecycles → async "b"/"n"/"e" on the simulated-time
     pid) with JSON-safe attr coercion, and the Prometheus text
     exposition (counters, labelled gauges, summary quantiles that are
     *omitted* — not zeroed — for empty series).
  3. Serving integration: a traced serve is bit-identical to an
     untraced one on every policy family; the exported commit-stage
     spans reconcile with ``epoch_commit_latency``; decision events
     fire on the admission/arbitration/backfill/compaction branches;
     the solver fleet's spans and counters match ``FleetResult``.
  4. ``StreamingSeries`` edges that the exposition leans on: the
     exact→sketch handoff at ``exact_max``, single-sample quantiles,
     and zero-sample NaN semantics.
"""

import json
import math

import numpy as np
import pytest

from repro.core import ProblemInstance, make_onestage_mapreduce, schedule_fleet
from repro.obs import (
    NULL_TRACER,
    NullTracer,
    Tracer,
    as_tracer,
    chrome_trace_events,
    prometheus_exposition,
    write_chrome_trace,
)
from repro.obs.report import (
    commit_latency_total,
    decision_audit,
    epoch_breakdown,
    job_table,
    load_trace,
    render_report,
)
from repro.online import (
    OnlineScheduler,
    StreamingSeries,
    poisson_arrivals,
    production_arrivals,
    tiered_production_arrivals,
)


def _fingerprint(res):
    return [
        (
            m.job_id, m.admitted, m.completion, m.makespan,
            m.n_racks_granted, m.n_wireless_granted, m.backfilled,
        )
        for m in res.jobs
    ]


# ---------------------------------------------------------------------------
# Layer 1: tracer primitives
# ---------------------------------------------------------------------------


def test_span_nesting_and_attrs():
    tr = Tracer()
    with tr.span("outer", epoch=3) as outer:
        with tr.span("inner") as inner:
            inner.set(rows=7)
    assert [s.name for s in tr.spans] == ["outer", "inner"]
    o, i = tr.spans
    assert (o.depth, o.parent) == (0, -1)
    assert (i.depth, i.parent) == (1, o.index)
    assert i.attrs == {"rows": 7}
    assert o.attrs == {"epoch": 3}
    # Both closed: durations are finite, inner nests inside outer.
    assert 0.0 <= i.duration <= o.duration
    assert outer.duration == o.duration
    assert tr._stack == []
    assert tr.spans_named("inner") == [i]


def test_events_attach_to_enclosing_span():
    tr = Tracer()
    tr.event("orphan", x=1)
    with tr.span("s"):
        tr.event("inside", job_id=5)
    assert tr.events[0].span == -1
    assert tr.events[1].span == tr.spans[0].index
    assert tr.events_of("inside")[0].attrs == {"job_id": 5}


def test_metrics_registry_keys_by_sorted_labels():
    tr = Tracer()
    tr.count("jobs")
    tr.count("jobs", 2.0)
    tr.gauge("slo", 0.5, tier="gold")
    tr.gauge("slo", 0.9, tier="gold")  # latest wins
    tr.observe("lat", 1.0, tenant="a")
    tr.observe("lat", 3.0, tenant="a")
    assert tr.counters["jobs"] == 3.0
    assert tr.gauges[("slo", (("tier", "gold"),))] == 0.9
    s = tr.series[("lat", (("tenant", "a"),))]
    assert (s.count, s.mean) == (2, 2.0)
    adopted = StreamingSeries()
    tr.adopt_series("jct", adopted)
    assert tr.series[("jct", ())] is adopted


def test_null_tracer_is_inert_singleton():
    nt = NullTracer()
    assert not nt.enabled and not NULL_TRACER.enabled
    ctx = nt.span("anything", k=1)
    assert ctx is nt.span("other")  # one shared context, never allocates
    with ctx as c:
        c.set(ignored=True)
        assert c.duration == 0.0
    assert nt.event("e") is None and nt.count("c") is None
    assert nt.job(1, "arrival", 0.0) is None
    assert as_tracer(None) is NULL_TRACER
    tr = Tracer()
    assert as_tracer(tr) is tr


# ---------------------------------------------------------------------------
# Layer 2: exporters
# ---------------------------------------------------------------------------


def test_chrome_trace_structure_and_json_safety():
    tr = Tracer()
    with tr.span("epoch", epoch=0):
        tr.event("fleet_solve", n_candidates=np.int64(12), gain=float("nan"))
    tr.job(7, "arrival", 10.0, family="mapreduce")
    tr.job(7, "admit", 12.5, backfilled=np.bool_(False))
    tr.job(7, "complete", 20.0, makespan=7.5)
    doc = chrome_trace_events(tr)
    json.dumps(doc)  # numpy / NaN attrs must serialize
    by_ph = {}
    for e in doc["traceEvents"]:
        by_ph.setdefault(e["ph"], []).append(e)
    (x,) = by_ph["X"]
    assert x["name"] == "epoch" and x["pid"] == 1 and x["tid"] == 0
    assert x["dur"] >= 0.0 and x["ts"] >= 0.0
    (i,) = by_ph["i"]
    assert i["name"] == "fleet_solve" and i["args"]["n_candidates"] == 12
    assert by_ph["b"][0]["ts"] == pytest.approx(10.0 * 1e6)
    assert by_ph["e"][0]["ts"] == pytest.approx(20.0 * 1e6)
    marks = by_ph["b"] + by_ph["n"] + by_ph["e"]
    assert all(m["pid"] == 2 and m["id"] == 7 for m in marks)
    assert {m["args"]["phase"] for m in marks} == {"arrival", "admit", "complete"}


def test_chrome_trace_open_span_gets_zero_duration():
    tr = Tracer()
    tr.span("never_exited")  # deliberately not used as a context manager
    doc = chrome_trace_events(tr)
    (x,) = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert x["dur"] == 0.0
    json.dumps(doc)


def test_prometheus_exposition_renders_all_kinds():
    tr = Tracer()
    tr.count("serve_epochs", 14)
    tr.gauge("slo_attainment", 0.75, tier="gold")
    tr.gauge("slo_attainment", 1.0, tier="bronze")
    for v in (1.0, 2.0, 3.0, 4.0):
        tr.observe("epoch_latency", v)
    tr.observe("queueing_delay", 9.0, tenant="t0")
    text = prometheus_exposition(tr)
    assert "# TYPE serve_epochs counter\nserve_epochs 14" in text
    assert '# TYPE slo_attainment gauge' in text
    assert 'slo_attainment{tier="gold"} 0.75' in text
    assert 'slo_attainment{tier="bronze"} 1' in text
    assert 'epoch_latency{quantile="0.5"} 2.5' in text
    assert "epoch_latency_count 4" in text
    assert "epoch_latency_sum 10" in text
    assert 'queueing_delay{tenant="t0",quantile="0.99"} 9' in text
    assert 'queueing_delay_sum{tenant="t0"} 9' in text


def test_prometheus_exposition_omits_quantiles_for_empty_series():
    tr = Tracer()
    tr.adopt_series("jct", StreamingSeries())
    text = prometheus_exposition(tr)
    assert "quantile" not in text
    assert "jct_count 0" in text
    assert "jct_sum 0" in text  # sum of nothing is 0, never NaN
    assert "nan" not in text.lower()


# ---------------------------------------------------------------------------
# Layer 3: serving integration
# ---------------------------------------------------------------------------

_CONFIGS = {
    "greedy": dict(policy="greedy_list"),
    "backfill": dict(
        policy="greedy_list", require_full_demand=True, preserve_order=True,
        backfill=True,
    ),
    "edf_search_compact": dict(
        policy="greedy_list", admission="edf", arbitration="search",
        compact_interval=2, admission_control="defer",
    ),
    "fleet": dict(
        solver_kwargs=dict(max_enumerate=64, n_samples=32, batch_size=128,
                           refine_rounds=1, refine_pool=32),
    ),
}


def _stream(name):
    if name == "edf_search_compact":
        return tiered_production_arrivals(3, rate=1 / 6, n_jobs=12,
                                          n_racks=6, n_wireless=2)
    n = 5 if name == "fleet" else 10
    return production_arrivals(3, rate=1 / 10, n_jobs=n, n_racks=6,
                               n_wireless=2)


@pytest.mark.parametrize("name", sorted(_CONFIGS))
def test_traced_serve_is_bit_identical(name):
    kw = _CONFIGS[name]
    base = OnlineScheduler(6, 2, window=5.0, seed=3, **kw).serve(_stream(name))
    tr = Tracer()
    traced = OnlineScheduler(6, 2, window=5.0, seed=3, tracer=tr,
                             **kw).serve(_stream(name))
    assert _fingerprint(traced) == _fingerprint(base)
    assert traced.n_epochs == base.n_epochs
    assert traced.n_backfilled == base.n_backfilled
    # The trace actually recorded the serve.
    assert len(tr.spans_named("epoch")) == base.n_epochs
    arrivals = [m for m in tr.job_marks if m.phase == "arrival"]
    assert len(arrivals) == len(_stream(name))
    completes = [m for m in tr.job_marks if m.phase == "complete"]
    assert len(completes) == base.n_served


def test_explicit_null_tracer_matches_default():
    stream = poisson_arrivals(11, rate=1 / 8, n_jobs=10, n_racks=4,
                              n_wireless=2)
    base = OnlineScheduler(4, 2, window=4.0, policy="greedy_list",
                           seed=11).serve(stream)
    nulled = OnlineScheduler(4, 2, window=4.0, policy="greedy_list",
                             seed=11, tracer=NULL_TRACER).serve(stream)
    assert _fingerprint(nulled) == _fingerprint(base)


def test_traced_serve_decision_events_and_gauges():
    tr = Tracer()
    OnlineScheduler(6, 2, window=5.0, seed=3, tracer=tr,
                    **_CONFIGS["edf_search_compact"]).serve(
        _stream("edf_search_compact"))
    kinds = {e.kind for e in tr.events}
    assert "arbitration_order" in kinds
    assert "timeline_compact" in kinds
    for e in tr.events_of("arbitration_order"):
        assert e.attrs["policy"] == "search"
        assert isinstance(e.attrs["order"], list)
    # End-of-serve metrics landed in the registry.
    assert ("prune_rate", ()) in tr.gauges
    assert tr.counters["serve_epochs"] > 0
    assert any(name == "tenant_queueing_delay"
               for name, _ in tr.series)
    text = prometheus_exposition(tr)
    assert "tenant_queueing_delay_count{tenant=" in text


def test_admission_reorder_event_fires_for_edf():
    tr = Tracer()
    OnlineScheduler(6, 2, window=5.0, seed=3, admission="edf",
                    policy="greedy_list", tracer=tr).serve(
        _stream("edf_search_compact"))
    reorders = tr.events_of("admission_reorder")
    assert reorders and all(e.attrs["policy"] == "edf" for e in reorders)


def test_trace_report_round_trip(tmp_path):
    tr = Tracer()
    res = OnlineScheduler(4, 2, window=4.0, policy="greedy_list", seed=11,
                          track_epoch_latency=True, tracer=tr).serve(
        poisson_arrivals(11, rate=1 / 8, n_jobs=10, n_racks=4, n_wireless=2))
    path = tmp_path / "trace.json"
    write_chrome_trace(tr, path)
    trace = load_trace(path)

    rows = epoch_breakdown(trace)
    assert len(rows) == res.n_epochs
    for r in rows:
        stage_sum = (r["collect_arrivals"] + r["plan_batch"]
                     + r["arbitrate_and_commit"])
        assert stage_sum <= r["total"] + 1e-9

    # Acceptance: span-summed commit latency reconciles with the
    # track_epoch_latency timer within 1% (construction makes it exact
    # up to µs float round-trip).
    tracked = sum(res.epoch_commit_latency)
    assert commit_latency_total(trace) == pytest.approx(tracked, rel=0.01)

    jobs = job_table(trace, top=5)
    assert 0 < len(jobs) <= 5
    jcts = [r["jct"] for r in jobs]
    assert jcts == sorted(jcts, reverse=True)
    for r in jobs:
        assert r["jct"] == pytest.approx(r["complete"] - r["arrival"])
        assert r["queueing_delay"] == pytest.approx(r["admit"] - r["arrival"])
        assert r["channel_queueing"] == pytest.approx(
            r["makespan"] - r["solver_makespan"])

    audit = decision_audit(trace, jobs[0]["job_id"])
    assert [r["kind"] for r in audit][:1] == ["job:arrival"]
    assert {"job:admit", "job:complete"} <= {r["kind"] for r in audit}

    report = render_report(trace, top=3, job=jobs[0]["job_id"])
    assert "per-epoch latency breakdown" in report
    assert "slowest jobs" in report
    assert f"decision audit for job {jobs[0]['job_id']}" in report


def test_trace_report_cli(tmp_path, capsys):
    import sys
    sys.path.insert(0, "tools")
    try:
        import trace_report
    finally:
        sys.path.pop(0)
    tr = Tracer()
    OnlineScheduler(4, 2, window=4.0, policy="greedy_list", seed=11,
                    tracer=tr).serve(
        poisson_arrivals(11, rate=1 / 8, n_jobs=6, n_racks=4, n_wireless=2))
    path = tmp_path / "t.json"
    write_chrome_trace(tr, path)
    assert trace_report.main([str(path), "--top", "2"]) == 0
    out = capsys.readouterr().out
    assert "per-epoch latency breakdown" in out


def test_fleet_solver_spans_match_fleet_result():
    rng = np.random.default_rng(0)
    insts = [
        ProblemInstance(
            job=make_onestage_mapreduce(rng, n_map=3, n_reduce=2, rho=1.0),
            n_racks=2, n_wireless=1,
        )
        for _ in range(3)
    ]
    tr = Tracer()
    fleet = schedule_fleet(insts, max_enumerate=32, n_samples=32,
                           batch_size=64, refine_rounds=1, refine_pool=16,
                           tracer=tr)
    (top,) = tr.spans_named("schedule_fleet")
    assert top.attrs["n_instances"] == 3
    # Tiny instances enumerate exhaustively, so stage 1 may never launch
    # — stage 2 (exact evaluation) always does.
    assert tr.spans_named("stage2_launch")
    assert tr.counters["stage1_launches"] == fleet.n_stage1_launches
    assert tr.counters["stage2_launches"] == fleet.n_stage2_launches
    (ev,) = tr.events_of("fleet_solve")
    assert ev.attrs["n_instances"] == 3
    assert ev.attrs["n_candidates"] == fleet.n_candidates
    assert ev.attrs["n_pruned"] == fleet.n_pruned
    assert ev.attrs["n_evaluated"] == fleet.n_evaluated
    (py,) = tr.events_of("portfolio_yields")
    for name, row in py.attrs["strategies"].items():
        assert set(row) >= {"proposed", "evaluated", "improvement",
                            "yield_per_eval"}


def test_empty_serve_summary_and_exposition():
    tr = Tracer()
    res = OnlineScheduler(4, 2, window=4.0, policy="greedy_list",
                          tracer=tr).serve([])
    assert res.n_served == 0
    assert math.isnan(res.mean_jct) and math.isnan(res.p95_jct)
    text = res.summary()
    assert "n/a" in text and "nan" not in text
    expo = prometheus_exposition(tr)
    assert "nan" not in expo.lower()
    assert "jct_count 0" in expo


def test_all_rejected_serve_renders():
    # Impossible deadlines + reject control: nothing is ever admitted.
    import dataclasses
    stream = [
        dataclasses.replace(ev, deadline=ev.time + 1e-6)
        for ev in production_arrivals(3, rate=1 / 10, n_jobs=4, n_racks=6,
                                      n_wireless=2)
    ]
    tr = Tracer()
    res = OnlineScheduler(6, 2, window=5.0, policy="greedy_list",
                          admission_control="reject", tracer=tr).serve(stream)
    assert res.n_served == 0
    assert len(res.rejected_job_ids) == 4
    assert "n/a" in res.summary()
    assert tr.events_of("deadline_reject") or tr.events_of("deadline_hopeless")
    assert "nan" not in prometheus_exposition(tr).lower()


# ---------------------------------------------------------------------------
# Layer 4: StreamingSeries edges the exposition leans on
# ---------------------------------------------------------------------------


def test_series_exact_to_sketch_boundary():
    rng = np.random.default_rng(7)
    xs = rng.exponential(10.0, size=65)
    s = StreamingSeries(exact_max=64)
    for x in xs[:64]:
        s.push(x)
    # At exactly exact_max the buffer is still alive: quantiles exact.
    assert s._exact is not None
    for p in s.quantiles:
        assert s.quantile(p) == pytest.approx(np.percentile(xs[:64], 100 * p))
    s.push(xs[64])  # 65th observation flips to the P² sketches
    assert s._exact is None and s._sketches is not None
    assert s.count == 65
    for p in s.quantiles:
        exact = np.percentile(xs, 100 * p)
        lo, hi = np.min(xs), np.max(xs)
        est = s.quantile(p)
        assert lo <= est <= hi
        assert abs(est - exact) <= 0.35 * (hi - lo)
    with pytest.raises(KeyError):
        s.quantile(0.123)  # untracked quantile only answerable pre-sketch


def test_series_single_sample_quantiles():
    s = StreamingSeries()
    s.push(42.0)
    assert (s.count, s.mean, s.min, s.max) == (1, 42.0, 42.0, 42.0)
    for p in (0.5, 0.9, 0.99):
        assert s.quantile(p) == 42.0
