"""Reconfigurable-topology contracts.

Four families:

1. ``Topology`` model unit tests — mask algebra (``pair_reach`` /
   ``pair_connected`` / ``edge_channels`` / ``restrict``), the greedy
   weighted b-matching (degree limits, pinning, determinism), and every
   validation raise.
2. Golden bit-identity — the default all-ones ``Topology`` is
   value-identical to ``topology=None`` on solo, fleet, and online
   serves, while a restricted mask provably changes the optimal
   placement (a hand-built instance whose free optimum splits racks over
   wireless and whose masked optimum must co-locate).
3. Exhaustive small-instance oracle — on fleet batches of <= 3 jobs the
   co-optimized solve (full reach) is never worse than the brute-force
   optimum under ANY fixed feasible matching, and masked fleet solves
   equal their brute-force oracles exactly.
4. Online layer — ``ClusterTimeline`` matching state (idle-only
   reconfiguration, delta charged as an audited busy interval, outage
   gating of residual views) and the seeded ``link_outage_trace``.

Plus the ``durations_matrix`` vectorization regression riding along.
"""

import dataclasses
import itertools

import numpy as np
import pytest

from repro.core import (
    CH_LOCAL,
    CH_WIRED,
    DagJob,
    ProblemInstance,
    contention_lower_bounds,
    random_job,
    schedule_fleet,
    simulate,
    vectorized_search,
)
from repro.core.baselines import g_list_schedule
from repro.core.instance import Topology
from repro.online import OnlineScheduler, poisson_arrivals
from repro.online.cluster import RECONFIG_JOB, ClusterTimeline
from repro.online.workload import link_outage_trace

SOLVER_KW = dict(max_enumerate=64, n_samples=32, batch_size=64)


def make_instance(seed, n_tasks=5, n_racks=3, n_wireless=2, topology=None):
    rng = np.random.default_rng(seed)
    return ProblemInstance(
        job=random_job(rng, None, n_tasks=n_tasks, rho=2.0),
        n_racks=n_racks,
        n_wireless=n_wireless,
        topology=topology,
    )


def fork_instance(**kwargs):
    """One source task feeding two heavy children: the free optimum splits
    the children across racks (parallel compute, cheap wireless transfer);
    with wireless unreachable the cross transfer costs q_wired = 50 and the
    optimum must co-locate everything."""
    job = DagJob(
        p=np.array([1.0, 10.0, 10.0]),
        edges=np.array([[0, 1], [0, 2]]),
        d=np.array([1.0, 1.0]),
    )
    return ProblemInstance(
        job=job,
        n_racks=2,
        n_wireless=1,
        wired_rate=1.0 / 50.0,
        wireless_rate=1.0,
        **kwargs,
    )


# -- 1. Topology model --------------------------------------------------------


def test_validation_errors():
    ones = np.ones((2, 2), dtype=bool)
    with pytest.raises(ValueError, match="n_racks, n_wireless"):
        Topology(reach=np.ones(4, dtype=bool))
    with pytest.raises(ValueError, match="degree"):
        Topology(reach=ones, degree=-1)
    with pytest.raises(ValueError, match="channel_degree"):
        Topology(reach=ones, channel_degree=-2)
    with pytest.raises(ValueError, match="delta"):
        Topology(reach=ones, delta=-0.5)
    with pytest.raises(ValueError, match="weight"):
        Topology(reach=ones).match(np.ones(3))
    with pytest.raises(ValueError, match="shape"):
        make_instance(0, n_racks=3, n_wireless=2, topology=Topology(reach=ones))
    with pytest.raises(ValueError, match="shape"):
        ClusterTimeline(3, 2, topology=Topology(reach=ones))


def test_all_ones_and_reach_mask():
    t = Topology.all_ones(3, 2, delta=1.5)
    assert t.n_racks == 3 and t.n_wireless == 2 and t.delta == 1.5
    assert t.is_all_ones
    assert not Topology(reach=np.array([[1, 0], [1, 1]], bool)).is_all_ones
    inst = make_instance(0)
    np.testing.assert_array_equal(
        inst.reach_mask, np.ones((3, 2), dtype=bool)
    )
    masked = dataclasses.replace(inst, topology=Topology.all_ones(3, 2))
    np.testing.assert_array_equal(masked.reach_mask, inst.reach_mask)


def test_pair_algebra_and_restrict():
    # rack 0 -> {k0}, rack 1 -> {k0, k1}, rack 2 -> {k1}
    t = Topology(reach=np.array([[1, 0], [1, 1], [0, 1]], bool))
    pr = t.pair_reach()
    assert pr.shape == (3, 3, 2)
    assert pr[0, 1, 0] and not pr[0, 1, 1]
    conn = t.pair_connected()
    assert conn[0, 1] and conn[1, 2] and not conn[0, 2]
    np.testing.assert_array_equal(conn, conn.T)
    np.testing.assert_array_equal(t.edge_channels(0, 1), [0])
    np.testing.assert_array_equal(t.edge_channels(1, 1), [0, 1])
    assert t.edge_channels(0, 2).size == 0
    sub = t.restrict(np.array([1, 2]), np.array([1]))
    np.testing.assert_array_equal(sub.reach, [[True], [True]])
    assert sub.degree == t.degree and sub.delta == t.delta


def test_match_degree_limits_and_determinism():
    t = Topology(reach=np.ones((3, 2), bool), degree=1, channel_degree=2)
    m = t.match(np.array([3.0, 2.0, 1.0]))
    assert (m.sum(axis=1) <= 1).all()
    assert (m.sum(axis=0) <= 2).all()
    # Heaviest racks claim k0 first (ties break on index), rack 2 spills
    # onto k1 once k0 is at channel_degree.
    np.testing.assert_array_equal(m, [[1, 0], [1, 0], [0, 1]])
    np.testing.assert_array_equal(m, t.match(np.array([3.0, 2.0, 1.0])))
    # Zero-weight racks get no links at all.
    np.testing.assert_array_equal(
        t.match(np.array([0.0, 0.0, 5.0])).sum(axis=1), [0, 0, 1]
    )
    # Unbounded degrees: every positive-weight candidate link configures.
    assert Topology(reach=np.ones((3, 2), bool)).match(np.ones(3)).all()


def test_match_feasible_and_keep():
    t = Topology(reach=np.ones((2, 2), bool), degree=1)
    feas = np.array([[0, 1], [1, 1]], bool)
    m = t.match(np.array([2.0, 1.0]), feasible=feas)
    assert not m[0, 0]  # masked-out link never configured
    assert (m <= feas).all()
    # A pinned link survives even at zero weight and eats the degree
    # budget, so rack 0 gets nothing else under degree=1.
    keep = np.array([[1, 0], [0, 0]], bool)
    m = t.match(np.array([0.0, 5.0]), keep=keep)
    assert m[0, 0] and m[0].sum() == 1
    assert m[1].sum() == 1


# -- durations_matrix regression ---------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_durations_matrix_matches_per_channel_loop(seed):
    inst = make_instance(seed, n_tasks=6, n_wireless=3)
    m = inst.durations_matrix()
    assert m.shape == (inst.job.n_edges, inst.n_channels)
    for c in range(inst.n_channels):
        chan = np.full(inst.job.n_edges, c)
        np.testing.assert_array_equal(m[:, c], inst.duration_on(chan))


# -- 2. Golden bit-identity + restricted mask ---------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_all_ones_bit_identical_solo(seed):
    inst = make_instance(seed)
    masked = dataclasses.replace(
        inst, topology=Topology.all_ones(inst.n_racks, inst.n_wireless)
    )
    a = vectorized_search(inst, seed=seed, **SOLVER_KW)
    b = vectorized_search(masked, seed=seed, **SOLVER_KW)
    assert a.makespan == b.makespan
    np.testing.assert_array_equal(a.best_assignment, b.best_assignment)
    np.testing.assert_array_equal(a.schedule.chan, b.schedule.chan)
    np.testing.assert_array_equal(a.schedule.start, b.schedule.start)
    assert a.n_candidates == b.n_candidates
    assert a.n_pruned == b.n_pruned


def test_all_ones_bit_identical_fleet():
    insts = [make_instance(s, n_tasks=4 + s) for s in range(3)]
    masked = [
        dataclasses.replace(i, topology=Topology.all_ones(i.n_racks, i.n_wireless))
        for i in insts
    ]
    a = schedule_fleet(insts, seed=7, **SOLVER_KW)
    b = schedule_fleet(masked, seed=7, **SOLVER_KW)
    np.testing.assert_array_equal(a.makespans, b.makespans)
    for ra, rb in zip(a.results, b.results):
        np.testing.assert_array_equal(ra.best_assignment, rb.best_assignment)
        np.testing.assert_array_equal(ra.schedule.chan, rb.schedule.chan)


def test_restricted_mask_changes_placement():
    free = fork_instance()
    blocked = fork_instance(topology=Topology(reach=np.zeros((2, 1), bool)))
    # 8 assignments total: exhaustive, so both solves are exact optima.
    a = vectorized_search(free, max_enumerate=8, n_samples=0)
    b = vectorized_search(blocked, max_enumerate=8, n_samples=0)
    # Free optimum splits the children (1 + 1 wireless transfer + 10);
    # masked optimum co-locates (1 + 10 + 10 serialized on one rack).
    assert a.makespan == pytest.approx(12.0)
    assert b.makespan == pytest.approx(21.0)
    assert len(np.unique(a.best_assignment)) == 2
    assert len(np.unique(b.best_assignment)) == 1
    assert (a.schedule.chan >= 2).any()
    assert not (b.schedule.chan >= 2).any()


def test_masked_schedule_respects_reach():
    topo = Topology(reach=np.array([[1, 0], [1, 1], [0, 1]], bool))
    for seed in range(3):
        inst = make_instance(seed, n_tasks=6, topology=topo)
        res = vectorized_search(inst, seed=seed, **SOLVER_KW)
        rack, chan = res.schedule.rack, res.schedule.chan
        for e, c in enumerate(chan):
            if c >= 2:
                u, v = inst.job.edges[e]
                assert topo.reach[rack[u], c - 2]
                assert topo.reach[rack[v], c - 2]


def test_simulator_rejects_unreachable_fixed_pick():
    inst = fork_instance(topology=Topology(reach=np.zeros((2, 1), bool)))
    with pytest.raises(ValueError, match="unreachable"):
        simulate(
            inst, np.array([0, 1, 0]), chan=np.array([2, -1], dtype=np.int64)
        )


def test_greedy_baseline_respects_reach():
    topo = Topology(reach=np.array([[1, 0], [1, 1], [0, 1]], bool))
    for seed in range(4):
        inst = make_instance(seed, n_tasks=7, topology=topo)
        sched = g_list_schedule(inst)
        for e, c in enumerate(sched.chan):
            if c >= 2:
                u, v = inst.job.edges[e]
                assert topo.reach[sched.rack[u], c - 2]
                assert topo.reach[sched.rack[v], c - 2]


def test_masked_bounds_admissible():
    """The sharpened masked §IV-A bound stays a true lower bound on the
    masked simulate makespan, and never falls below the unmasked bound."""
    topo = Topology(reach=np.array([[1, 0], [1, 1], [0, 1]], bool))
    for seed in range(3):
        inst = make_instance(seed, n_tasks=5)
        masked = dataclasses.replace(inst, topology=topo)
        racks = np.array(
            list(itertools.product(range(3), repeat=5)), dtype=np.int64
        )
        lb_free = contention_lower_bounds(inst, racks)
        lb_mask = contention_lower_bounds(masked, racks)
        assert (lb_mask >= lb_free - 1e-12).all()
        for a, lb in zip(racks, lb_mask):
            assert simulate(masked, a).makespan >= lb - 1e-9


# -- 3. Exhaustive matching oracle (batches <= 3) -----------------------------


def brute_optimum(inst):
    """Exact optimum by enumerating every rack assignment (AUTO channels)."""
    best = np.inf
    for a in itertools.product(range(inst.n_racks), repeat=inst.job.n_tasks):
        best = min(best, simulate(inst, np.array(a)).makespan)
    return best


def feasible_matchings(n_racks, n_wireless, degree):
    """Every reach mask obeying the per-rack degree limit."""
    rows = [
        r
        for r in itertools.product([False, True], repeat=n_wireless)
        if sum(r) <= degree
    ]
    for combo in itertools.product(rows, repeat=n_racks):
        yield np.array(combo, dtype=bool)


def test_cooptimized_matching_never_worse_exhaustive():
    """Acceptance oracle: on batches of <= 3 small jobs, the co-optimized
    solve over the full reach mask is never worse than the exact optimum
    under ANY fixed feasible matching (degree 1), because every fixed
    matching is a restriction of the full mask. Exhaustive enumeration on
    both sides makes the comparison exact, and a mixed-mask fleet batch
    must reproduce its per-instance brute-force oracles."""
    insts = [
        fork_instance(),
        make_instance(1, n_tasks=3, n_racks=2, n_wireless=2),
        make_instance(2, n_tasks=3, n_racks=2, n_wireless=2),
    ]
    # Align shapes: give the fork instance 2 subchannels too.
    insts[0] = dataclasses.replace(insts[0], n_wireless=2)
    full = schedule_fleet(insts, max_enumerate=16, n_samples=0)
    for i, inst in enumerate(insts):
        assert full.makespans[i] == pytest.approx(brute_optimum(inst))
    picked = []
    for mask in feasible_matchings(2, 2, degree=1):
        masked = [
            dataclasses.replace(i, topology=Topology(reach=mask))
            for i in insts
        ]
        for i, m in enumerate(masked):
            fixed_opt = brute_optimum(m)
            assert full.makespans[i] <= fixed_opt + 1e-9
        picked.append(masked[0])
    # Mixed-topology fleet batch: exactness under each mask in one launch.
    sample = picked[:3]
    fleet = schedule_fleet(sample, max_enumerate=16, n_samples=0)
    for i, m in enumerate(sample):
        assert fleet.makespans[i] == pytest.approx(brute_optimum(m))


# -- 4. Online layer: timeline matching state + outage traces -----------------


def test_cluster_topology_inert_without_topology():
    cl = ClusterTimeline(3, 2)
    assert cl.active_reach() is None
    assert cl.topology_signature() is None
    assert cl.reconfigure(np.ones(3), 0.0) == 0
    with pytest.raises(RuntimeError, match="topology"):
        cl.set_link(0, 0, False)
    view = cl.residual_view(make_instance(0), 0.0)
    assert view.inst.topology is None


def test_reconfigure_idle_only_and_delta_charged():
    topo = Topology(reach=np.ones((3, 2), bool), degree=1, delta=2.0)
    cl = ClusterTimeline(3, 2, topology=topo)
    # Initial matching is the full reach mask; pin subchannel 0 busy.
    cl.wireless_hold[0] = 10.0
    before = cl.matching.copy()
    n = cl.reconfigure(np.array([3.0, 2.0, 1.0]), t=1.0)
    # Busy subchannel 0 keeps its configured links verbatim.
    np.testing.assert_array_equal(cl.matching[:, 0], before[:, 0])
    assert n >= 1 and cl.n_reconfigs == n
    # Reconfigured idle subchannel 1 carries the delta busy interval.
    ivs = cl.wireless_intervals[1]
    assert any(iv == (1.0, 3.0, RECONFIG_JOB) for iv in ivs)
    assert cl.wireless_hold[1] == 3.0
    cl.assert_feasible(full=True)
    # Degree 1 now binds: racks hold at most one link across channels.
    assert ((cl.matching.sum(axis=1)) <= 1 + before.sum(axis=1)).all()


def test_reconfigure_same_matching_is_free():
    topo = Topology(reach=np.ones((2, 1), bool), delta=5.0)
    cl = ClusterTimeline(2, 1, topology=topo)
    # Unbounded degrees: the match of any positive weight is all-ones,
    # identical to the initial matching, so nothing reconfigures and no
    # delta is charged.
    assert cl.reconfigure(np.ones(2), t=0.0) == 0
    assert cl.wireless_intervals[0] == []
    assert cl.n_reconfigs == 0


def test_set_link_outage_gates_views():
    topo = Topology(reach=np.ones((2, 2), bool))
    cl = ClusterTimeline(2, 2, topology=topo)
    sig0 = cl.topology_signature()
    assert cl.set_link(0, 1, False)
    assert not cl.set_link(0, 1, False)  # no-op flip reports unchanged
    assert not cl.active_reach()[0, 1]
    assert cl.topology_signature() != sig0
    view = cl.residual_view(make_instance(0, n_racks=2, n_wireless=2), 0.0)
    assert view.inst.topology is not None
    assert not view.inst.topology.reach[0, 1]
    assert cl.set_link(0, 1, True)
    assert cl.topology_signature() == sig0


def test_link_outage_trace_deterministic_and_sorted():
    kw = dict(n_racks=3, n_wireless=2, horizon=500.0, outage_rate=0.02)
    a = link_outage_trace(0, **kw)
    b = link_outage_trace(0, **kw)
    assert a and a == b
    assert a != link_outage_trace(1, **kw)
    keys = [(e.time, e.rack, e.subchannel) for e in a]
    assert keys == sorted(keys)
    for rack in range(3):
        for k in range(2):
            flips = [e.up for e in a if (e.rack, e.subchannel) == (rack, k)]
            # Per-link events alternate down/up starting with an outage.
            assert flips == [i % 2 == 1 for i in range(len(flips))]
    assert link_outage_trace(0, 2, 1, horizon=100.0, outage_rate=0.0) == []
    with pytest.raises(ValueError):
        link_outage_trace(0, 0, 1, horizon=10.0)
    with pytest.raises(ValueError):
        link_outage_trace(0, 2, 1, horizon=10.0, outage_rate=-1.0)


def test_online_all_ones_static_bit_identical():
    """The serving-loop golden: an all-ones static cluster topology serves
    bit-identically to no topology at all — including through the
    warm-start incumbent path, whose shape keys must treat an all-ones
    induced mask and a topology-free planning instance as the same."""
    arrivals = poisson_arrivals(seed=3, rate=1 / 15.0, n_jobs=6, n_racks=3)
    kw = dict(window=4.0, solver_kwargs=SOLVER_KW, seed=3, warm_start=True)
    plain = OnlineScheduler(3, 2, **kw).serve(arrivals)
    topo = OnlineScheduler(
        3, 2, cluster_topology=Topology.all_ones(3, 2), **kw
    ).serve(arrivals)
    assert plain.mean_jct == topo.mean_jct
    assert plain.makespan == topo.makespan
    assert topo.n_reconfigs == 0 and topo.n_link_events == 0
    for a, b in zip(plain.jobs, topo.jobs):
        assert (a.admitted, a.completion, a.solver_makespan) == (
            b.admitted,
            b.completion,
            b.solver_makespan,
        )


def test_online_matching_mode_serves_with_outages():
    topo = Topology(reach=np.ones((4, 2), bool), degree=1, delta=0.5)
    outages = link_outage_trace(
        5, 4, 2, horizon=400.0, outage_rate=0.01, mean_downtime=20.0
    )
    res = OnlineScheduler(
        4,
        2,
        window=4.0,
        policy="greedy_list",
        topology="matching",
        cluster_topology=topo,
        outages=outages,
        seed=5,
    ).serve(poisson_arrivals(seed=5, rate=1 / 20.0, n_jobs=6, n_racks=4))
    assert res.n_jobs == 6
    assert res.n_link_events > 0
    assert res.n_reconfigs >= 0
    assert np.isfinite(res.mean_jct) and res.makespan > 0


def test_online_topology_knob_validation():
    with pytest.raises(ValueError, match="topology"):
        OnlineScheduler(2, 1, topology="adaptive")
    with pytest.raises(ValueError, match="cluster_topology"):
        OnlineScheduler(2, 1, topology="matching")
    with pytest.raises(ValueError, match="outage"):
        OnlineScheduler(2, 1, outages=link_outage_trace(0, 2, 1, horizon=50.0))
