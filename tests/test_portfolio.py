"""Portfolio refinement contracts: fixed-seed determinism, incumbent
safety (no strategy can make the returned result worse), bit-for-bit
reproduction of the pre-portfolio (PR 2) mutation loop by the
single-strategy default, yield-counter accounting, and the acceptance
scenario — on a dense sampled-regime instance where plain local search
stalls, the full portfolio at the same candidate budget is never worse
and strictly better on most seeds."""

import numpy as np
import pytest

from repro.core import ProblemInstance, schedule_fleet, vectorized_search
from repro.core.dag import make_onestage_mapreduce, make_random_workflow
from repro.core.portfolio import (
    DEFAULT_PORTFOLIO,
    AnnealingStrategy,
    CrossoverStrategy,
    ElitePool,
    MutationStrategy,
    Portfolio,
    StrategyStats,
    build_strategies,
    merge_strategy_stats,
    mutate_pool,
    spec_length,
)
from repro.core.vectorized import batched_lower_bound, make_batched_evaluator


def dense_instance(seed, n_map=9, n_reduce=9, n_racks=6, rho=1.0):
    """Full-bipartite shuffle: dense enough that the sampled regime with a
    weak initial sample leaves real work for refinement."""
    job = make_onestage_mapreduce(
        np.random.default_rng(seed), n_map=n_map, n_reduce=n_reduce, rho=rho
    )
    return ProblemInstance(job=job, n_racks=n_racks, n_wireless=1)


SAMPLED = dict(max_enumerate=500, n_samples=64, batch_size=512, refine_pool=256)


# ---------------------------------------------------------------------------
# Unit: elite pool, allocator, spec resolution
# ---------------------------------------------------------------------------

def test_elite_pool_orders_dedupes_and_caps():
    pool = ElitePool(capacity=3)
    a = np.array([0, 1, 2], np.int32)
    pool.add(a, 5.0)
    pool.add(a, 5.0)  # duplicate assignment: dropped
    assert len(pool) == 1
    pool.add(np.array([1, 1, 2], np.int32), 3.0)
    pool.add(np.array([2, 1, 2], np.int32), 4.0)
    pool.add(np.array([0, 0, 0], np.int32), 10.0)  # worse than worst: dropped
    assert pool.vals == [3.0, 4.0, 5.0]
    pool.add(np.array([2, 2, 2], np.int32), 1.0)  # evicts the worst
    assert pool.vals == [1.0, 3.0, 4.0]
    assert len(pool) == 3


def test_elite_pool_add_batch_matches_sequential():
    rng = np.random.default_rng(0)
    racks = rng.integers(0, 3, size=(40, 4)).astype(np.int32)
    vals = rng.uniform(1, 9, size=40)
    a, b = ElitePool(capacity=5), ElitePool(capacity=5)
    a.add_batch(racks, vals)
    for j in np.argsort(vals, kind="stable"):
        b.add(racks[j], float(vals[j]))
    assert a.vals == b.vals


def test_allocator_single_strategy_gets_full_budget():
    inst = dense_instance(0)
    p = Portfolio(
        build_strategies(None), inst, np.random.default_rng(0), pool_size=257
    )
    assert list(p._allocations()) == [257]


def test_allocator_sums_to_budget_and_follows_weights():
    inst = dense_instance(0)
    p = Portfolio(
        build_strategies("portfolio"), inst, np.random.default_rng(0), pool_size=100
    )
    counts = p._allocations()
    assert counts.sum() == 100 and (counts > 0).all()
    p.weights = np.array([8.0, 1.0, 1.0])
    skewed = p._allocations()
    assert skewed.sum() == 100
    assert skewed[0] > counts[0]  # winner gets more
    assert skewed[1] >= 10 and skewed[2] >= 10  # min-share floor holds


def test_spec_resolution_and_errors():
    assert spec_length(None) == 1
    assert spec_length("portfolio") == len(DEFAULT_PORTFOLIO) == 3
    names = [s.name for s in build_strategies("portfolio")]
    assert names == ["mutation", "crossover", "annealing"]
    assert isinstance(build_strategies([AnnealingStrategy])[0], AnnealingStrategy)
    assert build_strategies([MutationStrategy()])[0].name == "mutation"
    with pytest.raises(ValueError):
        build_strategies(["no_such_strategy"])
    with pytest.raises(ValueError):
        build_strategies(["mutation", "mutation"])
    with pytest.raises(TypeError):
        build_strategies([42])


def test_fleet_rejects_live_strategy_objects():
    insts = [dense_instance(s) for s in range(2)]
    with pytest.raises(ValueError):
        schedule_fleet(insts, strategies=[AnnealingStrategy()], **SAMPLED)


def test_fleet_accepts_strategy_classes_as_factories():
    """Classes and zero-arg factories give each instance a private copy."""
    insts = [dense_instance(s) for s in range(2)]
    fleet = schedule_fleet(
        insts, strategies=(MutationStrategy, AnnealingStrategy),
        refine_rounds=2, **SAMPLED,
    )
    assert set(fleet.strategy_stats) == {"mutation", "annealing"}


def test_zero_refine_pool_rounds_are_noops():
    """refine_pool=0 must not crash: every round proposes nothing."""
    inst = dense_instance(0)
    res = vectorized_search(
        inst, seed=0, strategies="portfolio", refine_rounds=3,
        refine_patience=3, max_enumerate=500, n_samples=64, batch_size=512,
        refine_pool=0,
    )
    base = vectorized_search(
        inst, seed=0, refine_rounds=0, **SAMPLED
    )
    assert res.makespan == base.makespan
    assert all(s.proposed == 0 for s in res.strategy_stats.values())


def test_starved_strategy_round_is_rng_silent():
    """refine_pool=2 across 3 strategies starves annealing (allocation 0):
    it must not re-judge a stale candidate or consume RNG, so the run is
    deterministic and annealing proposes nothing."""
    inst = dense_instance(1)
    kw = dict(
        seed=4, strategies="portfolio", refine_rounds=4, refine_patience=4,
        max_enumerate=500, n_samples=64, batch_size=512, refine_pool=2,
    )
    a = vectorized_search(inst, **kw)
    b = vectorized_search(inst, **kw)
    assert a.makespan == b.makespan
    assert np.array_equal(a.best_assignment, b.best_assignment)
    assert a.strategy_stats["annealing"].proposed == 0


def test_strategy_shape_validation():
    class Bad:
        name = "bad"

        def propose(self, view, count):
            return np.zeros((count + 1, view.best_rack.shape[0]), np.int32)

        def observe(self, view, racks, vals):
            pass

        def end_round(self, view):
            pass

    inst = dense_instance(0)
    with pytest.raises(ValueError, match="proposed shape"):
        vectorized_search(
            inst, strategies=[Bad()], refine_rounds=2, **SAMPLED
        )


# ---------------------------------------------------------------------------
# Determinism and bit-for-bit PR 2 reproduction
# ---------------------------------------------------------------------------

def test_portfolio_fixed_seed_is_deterministic():
    inst = dense_instance(2)
    a = vectorized_search(
        inst, seed=7, strategies="portfolio", refine_rounds=6, **SAMPLED
    )
    b = vectorized_search(
        inst, seed=7, strategies="portfolio", refine_rounds=6, **SAMPLED
    )
    assert a.makespan == b.makespan
    assert np.array_equal(a.best_assignment, b.best_assignment)
    assert a.n_evaluated == b.n_evaluated and a.n_pruned == b.n_pruned
    for name in a.strategy_stats:
        sa, sb = a.strategy_stats[name], b.strategy_stats[name]
        assert dataclass_tuple(sa) == dataclass_tuple(sb)


def dataclass_tuple(s: StrategyStats):
    return (s.proposed, s.pruned, s.evaluated, s.improved, s.improvement, s.weight)


def test_portfolio_fleet_matches_solo_bit_for_bit():
    """Fleet packing must not perturb the portfolio's RNG or scores."""
    insts = [dense_instance(s) for s in range(3)]
    fleet = schedule_fleet(
        insts, seed=1, strategies="portfolio", refine_rounds=4, **SAMPLED
    )
    for i, inst in enumerate(insts):
        solo = vectorized_search(
            inst, seed=1, strategies="portfolio", refine_rounds=4, **SAMPLED
        )
        got = fleet.results[i]
        assert solo.makespan == got.makespan
        assert np.array_equal(solo.best_assignment, got.best_assignment)
        assert solo.n_evaluated == got.n_evaluated
        for name in solo.strategy_stats:
            assert dataclass_tuple(solo.strategy_stats[name]) == dataclass_tuple(
                got.strategy_stats[name]
            )
    merged = merge_strategy_stats(r.strategy_stats for r in fleet.results)
    for name, agg in fleet.strategy_stats.items():
        assert dataclass_tuple(agg) == dataclass_tuple(merged[name])


def test_mutation_only_reproduces_pr2_refinement_bit_for_bit():
    """The default (single-mutation-strategy) portfolio must walk exactly
    the pre-portfolio refinement loop: same RNG stream, same pruning
    decisions, same incumbent updates, same counters — verified against a
    host reimplementation of the PR 2 loop built from the public pieces."""
    inst = dense_instance(4)
    R, P = 6, 256
    base = vectorized_search(inst, seed=3, refine_rounds=0, **SAMPLED)
    full = vectorized_search(inst, seed=3, refine_rounds=R, **SAMPLED)

    evaluate = make_batched_evaluator(inst)
    best = base.best_assignment.copy()
    best_val = float(np.asarray(evaluate(best[None, :]))[0])
    rng = np.random.default_rng(3 + 1)  # the driver's refinement stream
    n_eval, n_pruned, rounds = base.n_evaluated, base.n_pruned, 0
    for _ in range(R):
        pool = mutate_pool(rng, best, inst, P)
        lbs = batched_lower_bound(inst, pool, use_kernel=True)
        keep = lbs < best_val - 1e-6
        n_pruned += int((~keep).sum())
        surv = pool[keep]
        prev = best_val
        if surv.shape[0]:
            vals = np.asarray(evaluate(surv))
            n_eval += vals.shape[0]
            j = int(np.argmin(vals))
            if float(vals[j]) < best_val:
                best_val = float(vals[j])
                best = surv[j].astype(np.int64)
        rounds += 1
        if not (best_val < prev - 1e-9):
            break

    assert full.refine_rounds == rounds
    assert np.array_equal(full.best_assignment, best)
    assert full.n_evaluated == n_eval
    assert full.n_pruned == n_pruned
    assert full.makespan == vectorized_search(inst, seed=3, refine_rounds=R, **SAMPLED).makespan


# ---------------------------------------------------------------------------
# Incumbent safety: no strategy can return a worse result than its input
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "spec", [("crossover",), ("annealing",), ("mutation",), "portfolio"]
)
def test_strategies_never_worsen_incumbent(spec):
    inst = dense_instance(1)
    base = vectorized_search(inst, seed=0, refine_rounds=0, **SAMPLED)
    refined = vectorized_search(
        inst, seed=0, strategies=spec, refine_rounds=6, refine_patience=6, **SAMPLED
    )
    assert refined.makespan <= base.makespan + 1e-6


def test_annealing_walker_accepts_worse_but_incumbent_holds():
    """The SA walker drifts (temperature acceptance) while the driver's
    strict-improvement rule keeps the incumbent monotone."""
    inst = dense_instance(5)
    res = vectorized_search(
        inst,
        seed=2,
        strategies=[AnnealingStrategy(t0_frac=5.0, alpha=1.0)],  # hot walker
        refine_rounds=8,
        refine_patience=8,
        **SAMPLED,
    )
    base = vectorized_search(inst, seed=2, refine_rounds=0, **SAMPLED)
    assert res.makespan <= base.makespan + 1e-6


# ---------------------------------------------------------------------------
# Counter accounting
# ---------------------------------------------------------------------------

def test_strategy_counter_accounting():
    inst = dense_instance(3)
    res = vectorized_search(
        inst, seed=0, strategies="portfolio", refine_rounds=8,
        refine_patience=8, **SAMPLED,
    )
    stats = res.strategy_stats
    assert set(stats) == {"mutation", "crossover", "annealing"}
    for s in stats.values():
        assert s.proposed == s.pruned + s.evaluated
        assert 0 <= s.improved <= s.evaluated
        assert s.improvement >= 0.0 and s.weight > 0.0
    # refinement proposals are part of the global candidate accounting
    refine_proposed = sum(s.proposed for s in stats.values())
    assert refine_proposed == res.refine_rounds * SAMPLED["refine_pool"]
    assert res.n_evaluated + res.n_pruned == res.n_candidates
    # yield property is consistent
    for s in stats.values():
        if s.evaluated:
            assert s.yield_per_eval == pytest.approx(s.improvement / s.evaluated)


def test_fleet_surfaces_aggregated_strategy_stats():
    insts = [dense_instance(s) for s in range(2)]
    fleet = schedule_fleet(
        insts, strategies="portfolio", refine_rounds=4, **SAMPLED
    )
    assert set(fleet.strategy_stats) == {"mutation", "crossover", "annealing"}
    for name, agg in fleet.strategy_stats.items():
        assert agg.proposed == sum(
            r.strategy_stats[name].proposed for r in fleet.results
        )


# ---------------------------------------------------------------------------
# Acceptance: portfolio vs stalled plain local search, same budget
# ---------------------------------------------------------------------------

def test_portfolio_beats_stalled_local_search_same_budget():
    """Dense sampled-regime instances where plain mutation local search
    stalls: at the SAME total candidate budget (same rounds, pool, and
    patience) the full portfolio is never worse on any seed and strictly
    better on at least one, with per-strategy yield counters surfaced."""
    R = 16
    kw = dict(refine_rounds=R, refine_patience=R, **SAMPLED)
    strictly_better = 0
    insts = [dense_instance(s) for s in range(6)]
    plain = schedule_fleet(insts, seed=list(range(6)), strategies=("mutation",), **kw)
    port = schedule_fleet(insts, seed=list(range(6)), strategies="portfolio", **kw)
    for seed in range(6):
        p, q = plain.results[seed], port.results[seed]
        # identical proposal budget per round on both sides
        assert q.refine_rounds * SAMPLED["refine_pool"] == sum(
            s.proposed for s in q.strategy_stats.values()
        )
        assert q.makespan <= p.makespan + 1e-9, f"portfolio worse on seed {seed}"
        strictly_better += q.makespan < p.makespan - 1e-9
    assert strictly_better >= 1
    # the yield counters that justify the win are surfaced on the fleet
    assert sum(s.improved for s in port.strategy_stats.values()) > 0
    assert any(
        s.improvement > 0
        for name, s in port.strategy_stats.items()
        if name != "mutation"
    ), "crossover/annealing contributed nothing — portfolio win is vacuous"
