"""Tests for the roofline HLO analyzer and the scheduler->training planner."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis
from repro.distribution.plan import (
    LinkSpec,
    backward_profile,
    plan_gradient_schedule,
    replan,
)


def test_analyzer_multiplies_scan_trip_counts():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jnp.ones((64, 64))
    compiled = jax.jit(f).lower(x).compile()
    cost = analyze_hlo(compiled.as_text())
    assert cost.flops == pytest.approx(10 * 2 * 64**3)
    # XLA's own analysis is known NOT to multiply (the reason this exists).
    xla = xla_cost_analysis(compiled).get("flops", 0.0)
    assert xla < cost.flops / 2


def test_analyzer_nested_scans():
    def g(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jnp.ones((32, 32))
    cost = analyze_hlo(jax.jit(g).lower(x).compile().as_text())
    assert cost.flops == pytest.approx(15 * 2 * 32**3)


def test_analyzer_counts_hbm_and_no_collectives_on_1_device():
    def f(x, w):
        return jax.nn.relu(x @ w)

    x = jnp.ones((128, 256))
    w = jnp.ones((256, 64))
    cost = analyze_hlo(jax.jit(f).lower(x, w).compile().as_text())
    assert cost.flops == pytest.approx(2 * 128 * 256 * 64)
    assert cost.hbm_bytes > 0
    assert cost.total_collective_bytes == 0.0


def test_backward_profile_shapes():
    from repro.configs import get_config

    cfg = get_config("llama3_2_3b")
    secs, bts = backward_profile(cfg, tokens_per_device=4096, groups=8)
    assert secs.shape == (8,) and bts.shape == (8,)
    assert (secs > 0).all() and (bts > 0).all()
    # total grad bytes ~ 2 bytes/param for the transformer trunk
    assert bts.sum() == pytest.approx(2 * 28 * (3072 * 24 * 128 * 2
        + 2 * 3072 * 8 * 128 + 3 * 3072 * 8192), rel=0.05)


def test_plan_beats_or_matches_serial_and_verifies():
    from repro.core.schedule import check_feasible

    g_secs = np.asarray([0.5, 0.4, 0.6, 0.3])
    g_bytes = np.asarray([4e9, 3e9, 5e9, 2e9])
    plan = plan_gradient_schedule(g_secs, g_bytes, LinkSpec(), time_limit=5.0)
    assert plan.t_optimal <= plan.t_serial + 1e-9
    assert plan.t_optimal <= plan.t_greedy + 1e-9
    assert plan.gain_vs_serial >= 0.0
    # channel assignment covers every bucket
    assert plan.channel_of_bucket.shape == (4,)


def test_plan_uses_aux_channels_under_contention():
    # Tiny compute, huge transfers, slow wired share: aux channels must win.
    g_secs = np.full(4, 0.01)
    g_bytes = np.full(4, 10e9)
    no_aux = plan_gradient_schedule(
        g_secs, g_bytes, LinkSpec(ici_share=5e9, aux_channels=0), time_limit=5.0
    )
    with_aux = plan_gradient_schedule(
        g_secs, g_bytes, LinkSpec(ici_share=5e9, aux_channels=3, aux_rate=5e9),
        time_limit=5.0,
    )
    assert with_aux.t_optimal < no_aux.t_optimal * 0.6  # ~4x parallel channels
    assert (with_aux.channel_of_bucket >= 2).any()  # aux actually used


def test_replan_degradation_monotone():
    g_secs = np.asarray([0.5, 0.5, 0.5, 0.5])
    g_bytes = np.asarray([2e9, 2e9, 2e9, 2e9])
    healthy = replan(g_secs, g_bytes, LinkSpec())
    slow = replan(g_secs, g_bytes, LinkSpec(), compute_slowdown=2.0)
    fewer = replan(g_secs, g_bytes, LinkSpec(), degraded_aux=0)
    assert slow.t_optimal >= healthy.t_optimal
    assert fewer.t_optimal >= healthy.t_optimal - 1e-9
