"""End-to-end integration: train a tiny model until loss drops, crash it,
restore from checkpoint, and verify bit-exact continuation (fault-tolerance
contract). Plus the paper-pipeline integration test."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # end-to-end train/crash/restore loops

from repro.checkpoint import ckpt
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.models.lm import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.steps import TrainState, build_train_step, make_train_state


def _setup(arch="llama3_2_3b", seed=0):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    data = make_pipeline(
        DataConfig(vocab_size=cfg.vocab_size, global_batch=8, seq_len=32, seed=seed)
    )
    opt = AdamWConfig(lr_peak=3e-3, lr_min=3e-4, warmup_steps=5, total_steps=200)
    step = jax.jit(build_train_step(model, opt, n_micro=2))
    state = make_train_state(model, jax.random.PRNGKey(seed))
    return model, data, step, state


def _to_jnp(batch):
    return {k: jnp.asarray(v) for k, v in batch.items()}


def test_loss_decreases():
    _, data, step, state = _setup()
    losses = []
    for s in range(30):
        state, metrics = step(state, _to_jnp(data.batch_for_step(s)))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_checkpoint_restart_exact(tmp_path):
    """Crash at step 10, restore, continue: must match the uninterrupted run
    exactly (deterministic data pipeline + full state snapshot)."""
    _, data, step, state = _setup(seed=1)
    ckdir = str(tmp_path / "ck")

    # Uninterrupted run to step 20.
    s_ref = state
    for s in range(20):
        s_ref, _ = step(s_ref, _to_jnp(data.batch_for_step(s)))

    # Run to 10, checkpoint, "crash", restore, continue to 20.
    s_a = state
    for s in range(10):
        s_a, _ = step(s_a, _to_jnp(data.batch_for_step(s)))
    ckpt.save(ckdir, 10, jax.tree.map(np.asarray, s_a))

    restored, at = ckpt.restore(ckdir, s_a)
    assert at == 10
    s_b = jax.tree.map(jnp.asarray, restored)
    for s in range(10, 20):
        s_b, _ = step(s_b, _to_jnp(data.batch_for_step(s)))

    for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paper_pipeline_end_to_end():
    """Generate production-style jobs, schedule with the paper's optimal
    method and baselines, execute every schedule, verify feasibility, and
    check the wireless-augmentation gain is non-negative (Fig. 4 semantics)."""
    from repro.core import (
        ProblemInstance,
        check_feasible,
        g_list_schedule,
        random_job,
        solve_bnb,
        wired_only,
    )

    rng = np.random.default_rng(0)
    gains = []
    for seed in range(5):
        job = random_job(np.random.default_rng(seed), None, n_tasks=6, rho=0.5)
        inst_w = ProblemInstance(job=job, n_racks=6, n_wireless=1)
        inst_0 = wired_only(inst_w)
        opt_w = solve_bnb(inst_w, time_limit=20)
        opt_0 = solve_bnb(inst_0, time_limit=20)
        check_feasible(inst_w, opt_w.schedule)
        check_feasible(inst_0, opt_0.schedule)
        # optimal with wireless <= optimal wired-only <= G-List wired-only
        assert opt_w.makespan <= opt_0.makespan + 0.15
        assert opt_0.makespan <= g_list_schedule(inst_0).makespan + 1e-6
        gains.append((opt_0.makespan - opt_w.makespan) / opt_0.makespan)
    assert np.mean(gains) >= 0.0


def test_elastic_restart_different_host_count(tmp_path):
    """Checkpoint written by 1 host restores under a 4-host layout."""
    _, data, step, state = _setup(seed=2)
    for s in range(3):
        state, _ = step(state, _to_jnp(data.batch_for_step(s)))
    ckdir = str(tmp_path / "ck")
    flat_state = jax.tree.map(np.asarray, state)
    ckpt.save(ckdir, 3, flat_state, host_id=0, n_hosts=1)
    restored, at = ckpt.restore(ckdir, flat_state)
    assert at == 3
    for a, b in zip(jax.tree.leaves(flat_state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
