"""Property + unit tests for the paper's scheduling core (OP/RP semantics)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'test' extra (pip install -e .[test])"
)
from hypothesis import given, settings, strategies as st

from repro.core import (
    BASELINES,
    ProblemInstance,
    check_feasible,
    lower_bound,
    random_job,
    simulate,
    single_rack_schedule,
    upper_bound,
)
from repro.core.dag import (
    DagJob,
    make_onestage_mapreduce,
    make_simple_mapreduce,
    make_random_workflow,
    topological_order,
)


def make_instance(seed, n_tasks=6, n_racks=3, n_wireless=1, rho=0.5):
    rng = np.random.default_rng(seed)
    job = random_job(rng, None, n_tasks=n_tasks, rho=rho)
    return ProblemInstance(job=job, n_racks=n_racks, n_wireless=n_wireless)


# ---------------------------------------------------------------------------
# DAG + generators
# ---------------------------------------------------------------------------

def test_generators_produce_valid_dags(rng):
    for fam, fn in (
        ("simple", lambda: make_simple_mapreduce(rng, n_map=5)),
        ("onestage", lambda: make_onestage_mapreduce(rng, n_map=3, n_reduce=2)),
        ("random", lambda: make_random_workflow(rng, n_tasks=8)),
    ):
        job = fn()
        topological_order(job.n_tasks, job.edges)  # raises on cycle
        assert (job.p >= 1.0).all() and (job.p <= 100.0).all()


def test_network_factor_scaling(rng):
    for rho in (0.1, 1.0, 5.0):
        job = make_onestage_mapreduce(rng, n_map=4, n_reduce=3, rho=rho)
        inst = ProblemInstance(job=job, n_racks=4)
        assert np.mean(inst.q_wired) == pytest.approx(
            rho * np.mean(job.p), rel=1e-6
        )


def test_dag_rejects_cycles():
    with pytest.raises(ValueError):
        DagJob(p=[1.0, 1.0], edges=[[0, 1], [1, 0]], d=[1.0, 1.0])


# ---------------------------------------------------------------------------
# Bounds (§IV-A)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_bounds_sandwich_heuristics(seed):
    """T_min <= any feasible schedule <= T_max for the single-rack scheme."""
    inst = make_instance(seed)
    lo, hi = lower_bound(inst), upper_bound(inst)
    assert lo <= hi + 1e-9
    s = single_rack_schedule(inst)
    assert s.makespan <= hi + 1e-6
    assert s.makespan >= lo - 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n_wireless=st.integers(0, 3))
def test_all_baselines_feasible_and_bounded(seed, n_wireless):
    inst = make_instance(seed, n_wireless=n_wireless)
    lo = lower_bound(inst)
    rng = np.random.default_rng(seed)
    for name, fn in BASELINES.items():
        sched = fn(inst, rng) if name == "random" else fn(inst)
        mk = check_feasible(inst, sched)
        assert mk >= lo - 1e-6, f"{name} beats the lower bound?!"


# ---------------------------------------------------------------------------
# Simulator (serial SGS executor)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_racks=st.integers(1, 5),
    n_wireless=st.integers(0, 2),
)
def test_simulate_any_assignment_is_feasible(seed, n_racks, n_wireless):
    inst = make_instance(seed, n_racks=n_racks, n_wireless=n_wireless)
    rng = np.random.default_rng(seed + 1)
    rack = rng.integers(0, n_racks, size=inst.job.n_tasks)
    sched = simulate(inst, rack, use_wireless=n_wireless > 0)
    check_feasible(inst, sched)
    assert (sched.rack == rack).all(), "simulator must respect the assignment"


def test_simulate_rejects_inconsistent_local_channel():
    inst = make_instance(0, n_racks=3)
    rack = np.zeros(inst.job.n_tasks, dtype=np.int64)
    rack[inst.job.edges[0, 1]] = 1  # first edge crosses racks
    chan = np.full(inst.job.n_edges, -1, dtype=np.int64)
    chan[0] = 1  # CH_LOCAL on a cross edge
    with pytest.raises(ValueError):
        simulate(inst, rack, chan=chan)


def test_wireless_cannot_hurt():
    """The earliest-finish channel choice means adding subchannels never
    increases the greedy makespan on the same assignment."""
    for seed in range(10):
        inst0 = make_instance(seed, n_wireless=0)
        inst2 = ProblemInstance(
            job=inst0.job, n_racks=inst0.n_racks, n_wireless=2
        )
        rng = np.random.default_rng(seed)
        rack = rng.integers(0, inst0.n_racks, size=inst0.job.n_tasks)
        m0 = simulate(inst0, rack, use_wireless=False).makespan
        m2 = simulate(inst2, rack, use_wireless=True).makespan
        assert m2 <= m0 + 1e-6
