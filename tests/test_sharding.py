"""Sharding policy unit tests (no multi-device mesh needed: rules operate on
shapes; divisibility degradation is pure logic)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distribution.sharding import (
    activation_rules,
    batch_axes,
    cache_sharding,
    fit_spec,
    param_sharding,
)


def local_mesh():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_fit_spec_divisibility_degradation():
    mesh = local_mesh()
    # 1-extent axes always divide
    assert fit_spec(mesh, (8, 8), P("data", "model")) == P("data", "model")


def test_fit_spec_drops_indivisible():
    # Fake a 16-way model axis via a mesh-like shim.
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    m = FakeMesh()
    assert fit_spec(m, (256206, 1024), P("model", "data")) == P(None, "data")
    assert fit_spec(m, (102400, 8192), P("model", "data")) == P("model", "data")
    assert fit_spec(m, (1, 4096), P(("pod", "data"), None)) == P(None, None)


def test_param_sharding_covers_all_archs():
    from repro.configs import ARCH_IDS, smoke_config
    from repro.models.lm import build_model

    mesh = local_mesh()
    for arch in ARCH_IDS:
        cfg = smoke_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        shard_tree = param_sharding(shapes, mesh)
        assert len(jax.tree.leaves(shard_tree)) == len(jax.tree.leaves(shapes))


def test_activation_rules_have_expected_axes():
    mesh = local_mesh()
    rules = activation_rules(mesh)
    assert set(rules) >= {
        "act_hidden", "act_logits", "act_ffn", "act_heads", "act_expert",
    }
    assert batch_axes(mesh) == ("data",)


def test_cache_sharding_rank_dispatch():
    from repro.configs import smoke_config
    from repro.models.lm import build_model

    mesh = local_mesh()
    cfg = smoke_config("jamba_v0_1_52b")
    model = build_model(cfg)
    cache_shapes = jax.eval_shape(lambda: model.init_cache(2, 64))
    tree = cache_sharding(cache_shapes, mesh)
    assert len(jax.tree.leaves(tree)) == len(jax.tree.leaves(cache_shapes))
