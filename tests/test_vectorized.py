"""JAX-vectorized assignment search: score validity, LB soundness, and the
fleet mega-batch contracts (bit-for-bit solo equivalence, prune-rate
regression, one-launch/one-trace compile accounting)."""

import numpy as np
import pytest

from repro.core import ProblemInstance, check_feasible, random_job, solve_bnb
from repro.core.vectorized import (
    batched_lower_bound,
    enumerate_assignments,
    make_batched_evaluator,
    schedule_fleet,
    vectorized_search,
)


def make_instance(seed, n_tasks=5, n_racks=3, n_wireless=1):
    rng = np.random.default_rng(seed)
    job = random_job(rng, None, n_tasks=n_tasks, rho=1.0)
    return ProblemInstance(job=job, n_racks=n_racks, n_wireless=n_wireless)


def test_enumerate_assignments_canonical():
    a = enumerate_assignments(4, 3)
    # Bell-ish count for restricted growth strings capped at 3 racks: 14
    assert a.shape == (14, 4)
    assert (a[:, 0] == 0).all()  # first task always opens rack 0
    # canonical: each new label is at most 1 + max of previous labels
    for row in a:
        mx = 0
        for x in row:
            assert x <= mx + 1
            mx = max(mx, x)


@pytest.mark.parametrize("seed", range(4))
def test_vectorized_score_upper_bounds_optimum(seed):
    inst = make_instance(seed)
    res = vectorized_search(inst)
    check_feasible(inst, res.schedule)
    opt = solve_bnb(inst, time_limit=30)
    assert res.makespan >= opt.makespan - 0.15
    # the exhaustive-canonical search with greedy sequencing is usually tight
    assert res.makespan <= opt.makespan * 1.5 + 1e-6


@pytest.mark.parametrize("seed", range(4))
def test_batched_lower_bound_sound(seed):
    inst = make_instance(seed)
    cands = enumerate_assignments(inst.job.n_tasks, inst.n_racks)
    lbs = batched_lower_bound(inst, cands)
    evaluate = make_batched_evaluator(inst)
    import jax.numpy as jnp

    scores = np.asarray(evaluate(jnp.asarray(cands)))
    # LB per assignment must not exceed the greedy score of that assignment.
    assert (lbs <= scores + 1e-3).all()


def test_batched_lb_matches_kernel_path(seed=0):
    inst = make_instance(seed)
    cands = enumerate_assignments(inst.job.n_tasks, inst.n_racks)
    a = batched_lower_bound(inst, cands, use_kernel=False)
    b = batched_lower_bound(inst, cands, use_kernel=True)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("seed", range(3))
def test_lb_opt_greedy_sandwich(seed, use_kernel):
    """min LB <= exact optimum <= vectorized greedy score, on both LB paths."""
    inst = make_instance(seed, n_tasks=5, n_racks=3)
    cands = enumerate_assignments(inst.job.n_tasks, inst.n_racks)
    lbs = batched_lower_bound(inst, cands, use_kernel=use_kernel)
    opt = solve_bnb(inst, time_limit=30)
    res = vectorized_search(inst, use_kernel=use_kernel)
    assert float(lbs.min()) <= opt.makespan + 1e-3
    assert opt.makespan <= res.makespan + 0.15
    # per-assignment: LB never exceeds that assignment's greedy score
    evaluate = make_batched_evaluator(inst)
    scores = np.asarray(evaluate(cands))
    assert (lbs <= scores + 1e-3).all()


def test_lb_pruning_is_exact_and_counted():
    """Pruned search returns the same winner as the unpruned sweep, and the
    candidate accounting (evaluated + pruned = considered) holds."""
    inst = make_instance(1, n_tasks=7, n_racks=4)
    pruned = vectorized_search(inst, batch_size=64)
    full = vectorized_search(inst, batch_size=64, lb_prune=False)
    assert pruned.makespan == pytest.approx(full.makespan, abs=1e-6)
    assert pruned.n_evaluated + pruned.n_pruned == pruned.n_candidates
    assert full.n_pruned == 0 and full.n_evaluated == full.n_candidates
    assert pruned.n_evaluated <= full.n_evaluated


def test_size_bucket_shares_compiled_program():
    """Two different instances in the same size bucket must not retrace the
    scan evaluator (the no-per-instance-recompile contract)."""
    from repro.core import vectorized as V
    from repro.core.dag import make_onestage_mapreduce

    insts = [
        ProblemInstance(
            job=make_onestage_mapreduce(
                np.random.default_rng(s), n_map=3, n_reduce=3, rho=1.0
            ),
            n_racks=3,
            n_wireless=1,
        )
        for s in (10, 11)
    ]
    cands = enumerate_assignments(6, 3)
    evaluate0 = make_batched_evaluator(insts[0])
    v0 = np.asarray(evaluate0(cands))
    before = V.TRACE_COUNT
    evaluate1 = make_batched_evaluator(insts[1])
    out = np.asarray(evaluate1(cands))
    assert V.TRACE_COUNT == before, "same-bucket instance retraced the scan"
    assert out.shape == (cands.shape[0],) and (out > 0).all()
    assert not np.allclose(v0, out)  # different durations, same program


def test_refinement_never_hurts_sampled_regime():
    inst = make_instance(3, n_tasks=11, n_racks=6)
    base = vectorized_search(
        inst, max_enumerate=1000, n_samples=512, refine_rounds=0
    )
    refined = vectorized_search(
        inst, max_enumerate=1000, n_samples=512, refine_rounds=4
    )
    assert refined.makespan <= base.makespan + 1e-6
    assert refined.refine_rounds >= 1


def _assert_fleet_matches_solo(insts, fleet, **search_kwargs):
    for i, inst in enumerate(insts):
        solo = vectorized_search(inst, **search_kwargs)
        got = fleet.results[i]
        assert np.array_equal(solo.best_assignment, got.best_assignment)
        assert solo.makespan == got.makespan  # bit-for-bit, both via simulate
        assert solo.n_candidates == got.n_candidates
        assert solo.n_pruned == got.n_pruned
        assert solo.n_evaluated == got.n_evaluated
        assert solo.refine_rounds == got.refine_rounds
        check_feasible(inst, got.schedule)


def test_fleet_matches_single_instance_bit_for_bit():
    """Heterogeneous fleet results == solo solver results, including the
    prune/eval counters (multi-chunk streams so stage-1 pruning is live)."""
    insts = [
        make_instance(s, n_tasks=5 + s % 3, n_racks=3 + s % 2) for s in range(4)
    ]
    fleet = schedule_fleet(insts, batch_size=64)
    _assert_fleet_matches_solo(insts, fleet, batch_size=64)
    assert fleet.n_pruned == sum(r.n_pruned for r in fleet.results)
    assert fleet.n_evaluated + fleet.n_pruned == fleet.n_candidates


def test_dense_prune_rate_regression():
    """Dense shuffle instance where the contention-free critical-path bound
    prunes 0%: the combined §IV-A bound must prune >0% and never discard the
    incumbent-optimal candidate."""
    from repro.core.dag import make_onestage_mapreduce

    job = make_onestage_mapreduce(
        np.random.default_rng(0), n_map=4, n_reduce=3, rho=2.0
    )
    inst = ProblemInstance(job=job, n_racks=4, n_wireless=1)
    old = vectorized_search(inst, batch_size=64, contention=False)
    new = vectorized_search(inst, batch_size=64)
    full = vectorized_search(inst, batch_size=64, lb_prune=False)
    assert old.n_pruned == 0, "seed no longer reproduces the 0%-prune gap"
    assert new.n_pruned > 0
    assert new.makespan == pytest.approx(full.makespan, abs=1e-9)
    assert new.n_evaluated + new.n_pruned == new.n_candidates


def test_fleet_one_sharded_launch_and_compile_count():
    """8 heterogeneous instances: one sharded stage-2 launch when each fits
    a single chunk, and at most one fresh trace per stage; a second fleet in
    the same size bucket must not retrace at all (checked with JAX's
    compilation counters)."""
    from repro.core.dag import make_onestage_mapreduce

    def fleets(base):
        # Heterogeneous shapes across slots (different task/edge/rack
        # counts), but the same shape profile for both fleets so the second
        # one provably lands in the same size bucket.
        return [
            ProblemInstance(
                job=make_onestage_mapreduce(
                    np.random.default_rng(base + s),
                    n_map=2 + s % 3,
                    n_reduce=1 + s % 2,
                    rho=1.0,
                ),
                n_racks=2 + s % 3,
                n_wireless=1 + s % 2,
            )
            for s in range(8)
        ]

    insts = fleets(50)
    fleet = schedule_fleet(insts, batch_size=512)
    # every instance's canonical enumeration fits one 512-chunk -> the whole
    # sweep is one mega-batch dispatch
    assert fleet.n_stage2_launches == 1
    assert fleet.n_stage1_traces <= 1 and fleet.n_stage2_traces <= 1
    assert fleet.n_stage1_traces + fleet.n_stage2_traces <= 2

    # Cross-check with JAX's own compilation counters where available
    # (jax._src.test_util is internal; fall back to the module counters,
    # which the assertion below covers either way).
    try:
        from jax._src import test_util as jtu

        miss_counter = jtu.count_jit_tracing_cache_miss
    except (ImportError, AttributeError):
        miss_counter = None
    if miss_counter is not None:
        with miss_counter() as misses:
            fleet2 = schedule_fleet(fleets(90), batch_size=512)
        assert misses[0] == 0, "same-bucket fleet retraced a device program"
    else:
        fleet2 = schedule_fleet(fleets(90), batch_size=512)
    assert fleet2.n_stage1_traces == 0 and fleet2.n_stage2_traces == 0


def test_fleet_compile_count_with_pruning():
    """Multi-chunk fleet (stage-1 pruning live): still at most one trace per
    stage across the whole run."""
    insts = [make_instance(s, n_tasks=7, n_racks=4) for s in range(8)]
    fleet = schedule_fleet(insts, batch_size=64)
    assert fleet.n_pruned > 0  # bound is actually engaged
    assert fleet.n_stage1_traces <= 1 and fleet.n_stage2_traces <= 1
    assert fleet.n_stage1_launches > 1 and fleet.n_stage2_launches > 1


def test_fleet_seed_sequence_and_validation():
    insts = [make_instance(s) for s in range(2)]
    fleet = schedule_fleet(insts, batch_size=64, seed=[3, 4])
    for i, inst in enumerate(insts):
        solo = vectorized_search(inst, batch_size=64, seed=3 + i)
        assert solo.makespan == fleet.results[i].makespan
    with pytest.raises(ValueError):
        schedule_fleet([])
    with pytest.raises(ValueError):
        schedule_fleet(insts, seed=[1, 2, 3])


@pytest.mark.slow
def test_sharded_evaluator_matches_single_device():
    """shard_map path on 4 forced host devices agrees with 1-device scores."""
    import subprocess
    import sys

    code = (
        "import numpy as np, jax\n"
        "assert jax.local_device_count() == 4\n"
        "from repro.core.vectorized import make_batched_evaluator, "
        "enumerate_assignments\n"
        "from repro.core import ProblemInstance, random_job\n"
        "rng = np.random.default_rng(0)\n"
        "job = random_job(rng, None, n_tasks=5, rho=1.0)\n"
        "inst = ProblemInstance(job=job, n_racks=3, n_wireless=1)\n"
        "cands = enumerate_assignments(5, 3)\n"
        "print(repr(np.asarray(make_batched_evaluator(inst)(cands)).tolist()))\n"
    )
    import os

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True
    )
    assert out.returncode == 0, out.stderr
    sharded = np.asarray(eval(out.stdout.strip().splitlines()[-1]))
    inst = make_instance(0, n_tasks=5, n_racks=3)
    local = np.asarray(make_batched_evaluator(inst)(enumerate_assignments(5, 3)))
    np.testing.assert_allclose(sharded, local, rtol=1e-5, atol=1e-4)
