"""JAX-vectorized assignment search: score validity and LB soundness."""

import numpy as np
import pytest

from repro.core import ProblemInstance, check_feasible, random_job, solve_bnb
from repro.core.vectorized import (
    batched_lower_bound,
    enumerate_assignments,
    make_batched_evaluator,
    vectorized_search,
)


def make_instance(seed, n_tasks=5, n_racks=3, n_wireless=1):
    rng = np.random.default_rng(seed)
    job = random_job(rng, None, n_tasks=n_tasks, rho=1.0)
    return ProblemInstance(job=job, n_racks=n_racks, n_wireless=n_wireless)


def test_enumerate_assignments_canonical():
    a = enumerate_assignments(4, 3)
    # Bell-ish count for restricted growth strings capped at 3 racks: 14
    assert a.shape == (14, 4)
    assert (a[:, 0] == 0).all()  # first task always opens rack 0
    # canonical: each new label is at most 1 + max of previous labels
    for row in a:
        mx = 0
        for x in row:
            assert x <= mx + 1
            mx = max(mx, x)


@pytest.mark.parametrize("seed", range(4))
def test_vectorized_score_upper_bounds_optimum(seed):
    inst = make_instance(seed)
    res = vectorized_search(inst)
    check_feasible(inst, res.schedule)
    opt = solve_bnb(inst, time_limit=30)
    assert res.makespan >= opt.makespan - 0.15
    # the exhaustive-canonical search with greedy sequencing is usually tight
    assert res.makespan <= opt.makespan * 1.5 + 1e-6


@pytest.mark.parametrize("seed", range(4))
def test_batched_lower_bound_sound(seed):
    inst = make_instance(seed)
    cands = enumerate_assignments(inst.job.n_tasks, inst.n_racks)
    lbs = batched_lower_bound(inst, cands)
    evaluate = make_batched_evaluator(inst)
    import jax.numpy as jnp

    scores = np.asarray(evaluate(jnp.asarray(cands)))
    # LB per assignment must not exceed the greedy score of that assignment.
    assert (lbs <= scores + 1e-3).all()


def test_batched_lb_matches_kernel_path(seed=0):
    inst = make_instance(seed)
    cands = enumerate_assignments(inst.job.n_tasks, inst.n_racks)
    a = batched_lower_bound(inst, cands, use_kernel=False)
    b = batched_lower_bound(inst, cands, use_kernel=True)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)
