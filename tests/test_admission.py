"""Deadline/SLO-tiered multi-tenant admission, locked by an exhaustive-
permutation oracle and a property layer.

Five layers:

  1. Bit-identity regression: the default (``admission="fifo"``) service
     reproduces the PR-7 committed timelines and counters *exactly* —
     hardcoded golden fingerprints, no tolerance — and stays bit-identical
     when the unused SLO knobs are set or the stream is tier-annotated.
  2. The oracle layer: epoch batches of <= 5 deadline-carrying jobs are
     brute-forced through ``replay_commit_order(deadlines=...)`` (every
     admission order trial-committed via the real arbitration path).
     EDF's miss count sits inside the oracle envelope, is never worse
     than FIFO on any oracle case, and *is* the oracle optimum on
     slack-separated batches; the replay's miss prediction matches real
     commits bit-for-bit for every permutation.
  3. Service-level SLO semantics: EDF reduces misses end-to-end on a
     contended batch, ``admission_control="reject"`` drops provably
     unmeetable jobs on the rigorous lower-bound proof, ``"defer"``
     postpones commits the replay proves late, ``wfair`` serves a light
     tenant ahead of a heavy tenant's backlog, and ``max_overtakes``
     bounds starvation (with ``max_overtakes=0`` degenerating to the
     bit-exact FIFO stream).
  4. Property layer: seeded tiered overload streams always serve to a
     timeline that passes the full overlap audit, per-job overtake
     counts respect the bound, and SLO counters reconcile with the
     per-job records. Runs under Hypothesis when installed; falls back
     to a fixed seeded sweep otherwise, as in ``test_coflow.py``.
  5. Backfill interaction: the PR-5 head-of-line protections hold
     unchanged under ``admission="edf"`` / ``"wfair"``, including the
     shadow-slack rejection path.
"""

import dataclasses
import itertools

import numpy as np
import pytest

from repro.core import ProblemInstance, g_list_schedule, random_job
from repro.core.baselines import ONLINE_BASELINES, edf_solo_schedule
from repro.core.bounds import lower_bound
from repro.core.dag import make_onestage_mapreduce
from repro.online import (
    ClusterTimeline,
    DEFAULT_SLO_TIERS,
    JobMetrics,
    OnlineResult,
    OnlineScheduler,
    SloTier,
    StreamingSeries,
    poisson_arrivals,
    production_arrivals,
    replay_commit_order,
    stream_tiered_arrivals,
    tiered_poisson_arrivals,
    tiered_production_arrivals,
    trace_arrivals,
)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


def _mr_inst(seed, rho, n_racks=2, n_wireless=0):
    job = make_onestage_mapreduce(
        np.random.default_rng(seed), n_map=3, n_reduce=2, rho=rho
    )
    return ProblemInstance(job=job, n_racks=n_racks, n_wireless=n_wireless)


def _greedy_solver(view, busy):
    return g_list_schedule(
        view.inst, use_wireless=view.inst.n_wireless > 0, channel_busy=busy
    )


def _epoch_views(cl, insts, t=0.0):
    pool = cl.free_racks(t)
    views = []
    for inst in insts:
        v = cl.residual_view(inst, t, rack_pool=pool)
        assert v is not None and v.full
        pool = pool[inst.n_racks:]
        views.append(v)
    return views


def _contended_batch(rhos):
    insts = [_mr_inst(j, rho=rho) for j, rho in enumerate(rhos)]
    cl = ClusterTimeline(n_racks=2 * len(insts), n_wireless=0)
    return cl, _epoch_views(cl, insts)


def _fingerprint(res):
    return [
        (
            m.job_id, m.admitted, m.completion, m.makespan,
            m.n_racks_granted, m.n_wireless_granted,
        )
        for m in res.jobs
    ]


def _counters(res):
    return dict(
        n_epochs=res.n_epochs, n_served=res.n_served,
        n_backfilled=res.n_backfilled, horizon=res.horizon,
    )


# ---------------------------------------------------------------------------
# Layer 1: bit-identity regression against the PR-7 committed streams
# ---------------------------------------------------------------------------

# Captured from the pre-SLO service (fingerprints are exact floats; any
# drift in the default admission path shows up as a hard mismatch).
GOLDEN = {
    "poisson_greedy": (
        [
            (0, 5.836739450539523, 195.78957216834257, 189.95283271780306, 4, 2),
            (1, 16.381835878860898, 418.68809243295493, 402.30625655409403, 1, 1),
            (2, 33.44939756803102, 327.40563586630014, 293.9562382982691, 1, 1),
            (3, 195.78957216834257, 370.4895126568325, 174.69994048848991, 2, 2),
            (4, 327.40563586630014, 695.9528892089182, 368.5472533426181, 1, 2),
            (5, 370.4895126568325, 685.9593238662846, 315.4698112094522, 2, 2),
            (6, 418.68809243295493, 955.5595087554225, 536.8714163224676, 1, 1),
            (7, 685.9593238662846, 899.797617573844, 213.83829370755933, 2, 2),
            (8, 695.9528892089182, 1002.8612373965613, 306.908348187643, 1, 1),
            (9, 899.797617573844, 1125.7259381972435, 225.9283206233994, 2, 2),
        ],
        dict(n_epochs=15, n_served=10, n_backfilled=0,
             horizon=1125.7259381972435),
    ),
    "production_greedy": (
        [
            (0, 15.920019856074667, 226.27434510916513, 210.35432525309045, 4, 2),
            (1, 15.920019856074667, 336.6657294456994, 320.74570958962477, 2, 0),
            (2, 21.21895659870807, 429.5246762110269, 408.3057196123188, 1, 2),
            (3, 30.904245357643262, 419.53715975949956, 388.6329144018563, 1, 2),
            (4, 226.27434510916513, 540.0038062985801, 313.729461189415, 2, 2),
            (5, 336.6657294456994, 445.1816043123805, 108.51587486668112, 2, 0),
            (6, 419.53715975949956, 759.9134629130151, 340.3763031535156, 1, 2),
            (7, 429.5246762110269, 814.6102407180558, 385.08556450702895, 1, 2),
            (8, 445.1816043123805, 658.2565358179612, 213.07493150558074, 2, 2),
            (9, 540.0038062985801, 723.7806453308452, 183.77683903226512, 2, 0),
        ],
        dict(n_epochs=14, n_served=10, n_backfilled=0,
             horizon=814.6102407180558),
    ),
    "production_backfill": (
        [
            (0, 6.320177752136479, 218.56516831668898, 212.2449905645525, 5, 2),
            (1, 218.56516831668898, 402.23179121015073, 183.66662289346175, 4, 2),
            (2, 402.23179121015073, 533.6377508380277, 131.40595962787697, 4, 2),
            (3, 533.6377508380277, 772.6704786034334, 239.03272776540564, 6, 2),
            (4, 772.6704786034334, 874.3065635008235, 101.63608489739013, 5, 2),
            (5, 874.3065635008235, 1090.7353524984942, 216.42878899767084, 6, 2),
            (6, 1090.7353524984942, 1303.3777668330479, 212.64241433455368, 3, 2),
            (7, 1303.3777668330479, 1593.4139813875609, 290.03621455451304, 5, 2),
        ],
        dict(n_epochs=12, n_served=8, n_backfilled=0,
             horizon=1593.4139813875609),
    ),
    "production_fleet": (
        [
            (0, 6.1001481267803985, 217.14539798702484, 211.04524986024444, 5, 2),
            (1, 18.262137412159362, 271.7465923371507, 253.48445492499133, 2, 0),
            (2, 217.14539798702484, 348.5513576149018, 131.40595962787697, 4, 2),
            (3, 217.14539798702484, 691.8271308510732, 474.6817328640484, 1, 0),
            (4, 271.7465923371507, 395.1547551642818, 123.40816282713115, 3, 1),
        ],
        dict(n_epochs=6, n_served=5, n_backfilled=0,
             horizon=691.8271308510732),
    ),
}


def _serve_golden(name, **extra):
    if name == "poisson_greedy":
        evs = poisson_arrivals(11, rate=1 / 8, n_jobs=10, n_racks=4,
                               n_wireless=2)
        svc = OnlineScheduler(4, 2, window=4.0, policy="greedy_list",
                              seed=11, **extra)
    elif name == "production_greedy":
        evs = production_arrivals(5, rate=1 / 6, n_jobs=10, n_racks=6,
                                  n_wireless=2)
        svc = OnlineScheduler(6, 2, window=4.0, policy="greedy_list",
                              seed=5, **extra)
    elif name == "production_backfill":
        evs = production_arrivals(3, rate=1 / 12, n_jobs=8, n_racks=6,
                                  n_wireless=2)
        svc = OnlineScheduler(
            6, 2, window=5.0, policy="greedy_list", seed=3,
            require_full_demand=True, preserve_order=True, backfill=True,
            **extra,
        )
    else:  # production_fleet
        evs = production_arrivals(3, rate=1 / 10, n_jobs=5, n_racks=6,
                                  n_wireless=2)
        svc = OnlineScheduler(
            6, 2, window=5.0, seed=3,
            solver_kwargs=dict(max_enumerate=64, n_samples=64,
                               batch_size=256, refine_rounds=1,
                               refine_pool=64),
            **extra,
        )
    return svc.serve(evs)


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_default_admission_reproduces_pr7_goldens(name):
    """The default service path is bit-identical to the pre-SLO loop:
    exact float equality against hardcoded fingerprints, no tolerance."""
    rows, ctr = GOLDEN[name]
    res = _serve_golden(name)
    assert _fingerprint(res) == rows
    assert _counters(res) == ctr
    assert res.admission == "fifo"
    assert res.n_deadline_jobs == res.n_deadline_missed == 0
    assert res.n_deadline_rejected == res.n_deadline_deferrals == 0


@pytest.mark.parametrize("name", ["poisson_greedy", "production_backfill"])
def test_fifo_admission_with_unused_slo_knobs_is_bit_identical(name):
    """``admission="fifo"`` short-circuits before any sort, RNG draw, or
    float work — explicitly setting it (plus inert SLO knobs) reproduces
    the golden stream exactly."""
    rows, ctr = GOLDEN[name]
    res = _serve_golden(
        name, admission="fifo", admission_control="none",
        tenant_weights={"gold": 4.0, "bronze": 1.0}, max_overtakes=99,
    )
    assert _fingerprint(res) == rows
    assert _counters(res) == ctr
    assert res.max_overtakes_observed <= 99


def test_tiered_stream_under_fifo_keeps_timeline_bit_identical():
    """Tier annotation rides a decoupled RNG: the base stream and the
    committed timeline are unchanged; only SLO accounting appears."""
    base_evs = production_arrivals(5, rate=1 / 6, n_jobs=10, n_racks=6,
                                   n_wireless=2)
    tier_evs = tiered_production_arrivals(5, 1 / 6, 10, n_racks=6,
                                          n_wireless=2)
    assert [e.time for e in tier_evs] == [e.time for e in base_evs]
    assert [e.job_id for e in tier_evs] == [e.job_id for e in base_evs]
    assert [e.family for e in tier_evs] == [e.family for e in base_evs]
    for a, b in zip(tier_evs, base_evs):
        assert a.inst.n_racks == b.inst.n_racks
        assert a.inst.n_wireless == b.inst.n_wireless
        assert np.array_equal(a.inst.q_wired, b.inst.q_wired)
    args = dict(window=4.0, policy="greedy_list", seed=5)
    base = OnlineScheduler(6, 2, **args).serve(base_evs)
    tier = OnlineScheduler(6, 2, **args).serve(tier_evs)
    assert _fingerprint(tier) == _fingerprint(base) \
        == GOLDEN["production_greedy"][0]
    assert tier.n_deadline_jobs == sum(
        1 for e in tier_evs if e.deadline is not None
    ) > 0
    assert sum(tot for _, tot in tier.tier_slo.values()) \
        == tier.n_deadline_jobs
    assert set(tier.tenant_queue_stats) <= {f"tenant-{i}" for i in range(3)}


# ---------------------------------------------------------------------------
# Layer 2: exhaustive-permutation oracle through replay_commit_order
# ---------------------------------------------------------------------------

def _edf_order(ddls):
    return tuple(sorted(
        range(len(ddls)),
        key=lambda i: (ddls[i] if ddls[i] is not None else np.inf, i),
    ))


def _replay(cl, views, ddls, order):
    return replay_commit_order(
        cl, 0.0, views, order, solver=_greedy_solver, deadlines=ddls
    )


def _lb_deadlines(views, alpha):
    return [alpha * lower_bound(v.inst) for v in views]


@pytest.mark.parametrize("rhos,alpha", [
    ((8.0, 0.5, 4.0), 2.0),
    ((8.0, 0.5, 4.0, 2.0), 2.5),
    ((6.0, 1.0, 3.0, 9.0, 0.25), 2.0),
])
def test_oracle_edf_within_miss_envelope_and_never_worse_than_fifo(
    rhos, alpha
):
    """Brute force every admission order of a <= 5 job batch through the
    real replay: EDF's miss count sits inside the oracle envelope and is
    never worse than FIFO on these batches."""
    cl, views = _contended_batch(rhos)
    n = len(views)
    ddls = _lb_deadlines(views, alpha)
    misses = {
        perm: _replay(cl, views, ddls, perm).n_deadline_missed
        for perm in itertools.permutations(range(n))
    }
    oracle, worst = min(misses.values()), max(misses.values())
    edf = misses[_edf_order(ddls)]
    fifo = misses[tuple(range(n))]
    assert oracle <= edf <= worst
    assert edf <= fifo
    assert worst > oracle  # the case is not vacuous: order matters


@pytest.mark.parametrize("rhos", [
    (8.0, 0.5, 4.0),
    (8.0, 0.5, 4.0, 2.0),
])
def test_oracle_edf_is_optimal_on_slack_separated_batches(rhos):
    """Deadlines achievable exactly in EDF order (each job's deadline is
    its EDF-order completion): EDF misses zero — the oracle optimum —
    while the worst order still misses, so the case is discriminative."""
    cl, views = _contended_batch(rhos)
    n = len(views)
    # Volume-ordered commit (shortest wired demand first) on a single
    # shared link; stamp each job's deadline at its completion there.
    seed_order = tuple(sorted(
        range(n), key=lambda i: float(np.sum(views[i].inst.q_wired))
    ))
    comps = _replay(cl, views, [None] * n, seed_order).completions
    ddls = [comps[i] * (1.0 + 1e-9) for i in range(n)]
    assert _edf_order(ddls) == seed_order
    misses = [
        _replay(cl, views, ddls, perm).n_deadline_missed
        for perm in itertools.permutations(range(n))
    ]
    assert _replay(cl, views, ddls, _edf_order(ddls)).n_deadline_missed \
        == min(misses) == 0
    assert max(misses) > 0


@pytest.mark.parametrize("rhos", [(8.0, 0.5, 4.0)])
def test_oracle_replay_miss_prediction_matches_real_commits(rhos):
    """For every admission order, the trial replay's completions and
    deadline-miss count equal a real commit pass bit-for-bit."""
    n = len(rhos)
    ddls = None
    for perm in itertools.permutations(range(n)):
        cl, views = _contended_batch(rhos)
        if ddls is None:
            ddls = _lb_deadlines(views, 2.0)
        predicted = _replay(cl, views, ddls, perm)
        comps = [None] * n
        for pos in perm:
            view = views[pos]
            placed = _greedy_solver(view, cl.channel_busy(view, 0.0))
            comps[pos] = cl.commit(view, placed, 0.0)
        cl.assert_feasible(full=True)
        assert comps == predicted.completions
        assert predicted.n_deadline_missed == sum(
            1 for i in range(n) if comps[i] > ddls[i]
        )
        assert predicted.n_rejected == 0


def test_replay_deadlines_length_validated():
    cl, views = _contended_batch((4.0, 1.0))
    with pytest.raises(ValueError, match="deadlines"):
        _replay(cl, views, [1.0], (0, 1))


# ---------------------------------------------------------------------------
# Layer 3: service-level SLO semantics
# ---------------------------------------------------------------------------

def _batch_events(rhos, ddls, tenants=None):
    """All-at-t=0 trace of 2-rack mapreduce jobs with SLO annotations."""
    evs = []
    for j, rho in enumerate(rhos):
        inst = _mr_inst(j, rho=rho)
        ev = trace_arrivals([0.0], [inst.job], n_racks=2, n_wireless=0)[0]
        evs.append(dataclasses.replace(
            ev, job_id=j, deadline=ddls[j],
            tenant=None if tenants is None else tenants[j],
        ))
    return evs


def _serve_batch(evs, n_racks, **kw):
    svc = OnlineScheduler(
        n_racks, 0, window=1.0, policy="greedy_list", seed=0, **kw
    )
    return svc.serve(evs)


def test_service_edf_reduces_misses_on_contended_batch():
    rhos = (8.0, 0.5, 4.0, 2.0)
    cl, views = _contended_batch(rhos)
    ddls = _lb_deadlines(views, 2.5)
    evs = _batch_events(rhos, ddls)
    fifo = _serve_batch(evs, 2 * len(rhos))
    edf = _serve_batch(evs, 2 * len(rhos), admission="edf")
    for res in (fifo, edf):
        res.timeline.assert_feasible(full=True)
        assert res.n_served == len(rhos)
        assert res.n_deadline_jobs == len(rhos)
    assert edf.n_deadline_missed < fifo.n_deadline_missed
    assert edf.n_deadline_missed == sum(m.deadline_missed for m in edf.jobs)
    assert edf.admission == "edf"
    assert "adm=edf" in edf.summary()


def test_admission_control_reject_drops_provably_unmeetable():
    """``now + lower_bound(inst) > deadline`` is a rigorous proof the
    deadline is unmeetable on *any* residual cluster — the job is dropped
    at arrival, never served, and excluded from JCT aggregates."""
    rhos = (4.0, 1.0)
    lb0 = lower_bound(_mr_inst(0, rho=4.0))
    evs = _batch_events(rhos, [0.5 * lb0, None])
    res = _serve_batch(evs, 4, admission="edf", admission_control="reject")
    res.timeline.assert_feasible(full=True)
    assert res.n_deadline_rejected == 1
    assert res.rejected_job_ids == [0]
    assert res.n_served == 1 and [m.job_id for m in res.jobs] == [1]
    assert "rejected=1" in res.summary()
    # A meetable deadline is NOT rejected: the proof is sound, not greedy.
    ok = _serve_batch(
        _batch_events(rhos, [10.0 * lb0, None]), 4,
        admission="edf", admission_control="reject",
    )
    assert ok.n_deadline_rejected == 0 and ok.n_served == 2


def test_admission_control_defer_postpones_replayed_late_commits():
    """Under ``defer``, a commit whose arbitrated completion overruns the
    deadline is postponed while the job can still make it; every job is
    still served (no drops) and the audit passes."""
    rhos = (8.0, 0.5, 4.0, 2.0)
    cl, views = _contended_batch(rhos)
    ddls = _lb_deadlines(views, 2.5)
    evs = _batch_events(rhos, ddls)
    res = _serve_batch(
        evs, 2 * len(rhos), admission="edf", admission_control="defer",
    )
    res.timeline.assert_feasible(full=True)
    assert res.n_served == len(rhos)
    assert res.n_deadline_deferrals >= 1
    assert "deferrals=" in res.summary()


def _flood_events(ddls, tenants=None):
    """j0 occupies the full 2-rack cluster; j1..j3 queue behind it."""
    rhos = (6.0, 2.0, 2.0, 2.0)
    evs = []
    for j, rho in enumerate(rhos):
        inst = _mr_inst(10 + j, rho=rho)
        ev = trace_arrivals(
            [0.0 if j == 0 else 0.5 + 0.1 * j], [inst.job],
            n_racks=2, n_wireless=0,
        )[0]
        evs.append(dataclasses.replace(
            ev, job_id=j, deadline=ddls[j],
            tenant=None if tenants is None else tenants[j],
        ))
    return evs


def _serve_flood(evs, **kw):
    svc = OnlineScheduler(
        2, 0, window=0.5, policy="greedy_list", seed=0,
        require_full_demand=True, **kw
    )
    return svc.serve(evs)


def test_edf_overtakes_are_counted_and_hoisting_enforces_bound():
    """j3 carries the earliest deadline and jumps the queue under EDF;
    the overtaken jobs' counts are recorded, and with ``max_overtakes=1``
    the saturated job is hoisted ahead of later deadlines."""
    ddls = [None, 400.0, 300.0, 100.0]
    evs = _flood_events(ddls)
    free = _serve_flood(evs, admission="edf")
    free.timeline.assert_feasible(full=True)
    order_free = sorted(range(4), key=lambda j: free.jobs[j].admitted)
    assert order_free == [0, 3, 2, 1]  # EDF: earliest deadline first
    assert free.jobs[1].n_overtaken == 2  # j3 and j2 both jumped j1
    assert free.jobs[2].n_overtaken == 1
    assert free.max_overtakes_observed == 2

    capped = _serve_flood(evs, admission="edf", max_overtakes=1)
    capped.timeline.assert_feasible(full=True)
    order_capped = sorted(range(4), key=lambda j: capped.jobs[j].admitted)
    # j3 jumps once; j1 is then saturated and hoisted ahead of j2's
    # earlier deadline.
    assert order_capped == [0, 3, 1, 2]
    assert capped.max_overtakes_observed <= 1
    for m in capped.jobs:
        assert m.n_overtaken <= 1


def test_max_overtakes_zero_restores_bitexact_fifo_stream():
    """``max_overtakes=0`` forbids every overtake: the EDF service
    degenerates to the FIFO stream bit-for-bit."""
    ddls = [None, 400.0, 300.0, 100.0]
    evs = _flood_events(ddls)
    fifo = _serve_flood(evs)
    pinned = _serve_flood(evs, admission="edf", max_overtakes=0)
    assert _fingerprint(pinned) == _fingerprint(fifo)
    assert pinned.max_overtakes_observed == 0


def test_wfair_serves_light_tenant_ahead_of_heavy_backlog():
    ddls = [None] * 4
    tenants = ["heavy", "heavy", "heavy", "light"]
    evs = _flood_events(ddls, tenants)
    fifo = _serve_flood(evs)
    wfair = _serve_flood(
        evs, admission="wfair",
        tenant_weights={"heavy": 1.0, "light": 1.0},
    )
    wfair.timeline.assert_feasible(full=True)
    # After j0 commits, tenant "heavy" has attained service and "light"
    # has none: j3 is served ahead of j1/j2.
    assert wfair.jobs[3].admitted < fifo.jobs[3].admitted
    order = sorted(range(4), key=lambda j: wfair.jobs[j].admitted)
    assert order == [0, 3, 1, 2]
    assert set(wfair.tenant_queue_stats) == {"heavy", "light"}
    assert set(wfair.tenant_p99_queueing_delay) == {"heavy", "light"}
    assert "tenant_p99q(" in wfair.summary()


def test_constructor_validation_for_slo_knobs():
    with pytest.raises(ValueError, match="admission must be"):
        OnlineScheduler(4, 0, admission="lifo")
    with pytest.raises(ValueError, match="admission_control must be"):
        OnlineScheduler(4, 0, admission_control="drop")
    with pytest.raises(ValueError, match="max_overtakes"):
        OnlineScheduler(4, 0, max_overtakes=-1)
    with pytest.raises(ValueError, match="tenant_weights"):
        OnlineScheduler(4, 0, tenant_weights={"a": 0.0})


def test_edf_solo_baseline_registered_and_deadline_aware():
    """``edf_solo`` shares ``fifo_solo``'s placement (apples-to-apples:
    only the admission order differs) and auto-selects EDF admission."""
    assert ONLINE_BASELINES["edf_solo"] is edf_solo_schedule
    inst = _mr_inst(0, rho=2.0)
    a = edf_solo_schedule(inst, use_wireless=False)
    b = ONLINE_BASELINES["fifo_solo"](inst, use_wireless=False)
    assert a.makespan == b.makespan

    svc = OnlineScheduler(2, 0, policy="edf_solo", window=0.5)
    assert svc.admission == "edf"
    # Explicit admission choices are respected, not overwritten.
    assert OnlineScheduler(
        2, 0, policy="edf_solo", window=0.5, admission="wfair"
    ).admission == "wfair"

    # j1 (short, tight deadline) arrives behind j0 (long, loose): solo
    # EDF serves j1 first and meets both; solo FIFO misses j1's deadline.
    insts = [_mr_inst(1, rho=6.0), _mr_inst(2, rho=1.0)]
    lbs = [lower_bound(i) for i in insts]
    evs = trace_arrivals(
        [0.0, 0.0], [i.job for i in insts], n_racks=2, n_wireless=0,
    )
    ddls = [20.0 * (lbs[0] + lbs[1]), 2.0 * lbs[1]]
    evs = [
        dataclasses.replace(e, job_id=j, deadline=ddls[j])
        for j, e in enumerate(evs)
    ]
    fifo = OnlineScheduler(
        2, 0, policy="fifo_solo", window=0.5
    ).serve(evs)
    edf = OnlineScheduler(2, 0, policy="edf_solo", window=0.5).serve(evs)
    for res in (fifo, edf):
        res.timeline.assert_feasible(full=True)
        assert res.n_served == 2
    assert edf.jobs[1].admitted < edf.jobs[0].admitted
    assert edf.n_deadline_missed < fifo.n_deadline_missed


# ---------------------------------------------------------------------------
# Layer 4: property layer (Hypothesis with seeded fallback)
# ---------------------------------------------------------------------------

def _check_tiered_overload_serve(seed):
    admission = ("edf", "wfair")[seed % 2]
    control = ("none", "defer")[(seed // 2) % 2]
    evs = tiered_production_arrivals(
        seed, 1 / 3, 8, n_racks=4, n_wireless=2,
    )
    svc = OnlineScheduler(
        4, 2, window=4.0, policy="greedy_list", seed=seed,
        admission=admission, admission_control=control, max_overtakes=3,
        tenant_weights={t.name: t.share for t in DEFAULT_SLO_TIERS},
    )
    res = svc.serve(evs)
    res.timeline.assert_feasible(full=True)
    assert res.n_served == 8 and res.n_deadline_rejected == 0
    # Starvation bound: no job is ever overtaken past the allowance.
    assert res.max_overtakes_observed <= 3
    assert all(m.n_overtaken <= 3 for m in res.jobs)
    # SLO counters reconcile with the per-job records.
    assert res.n_deadline_jobs == sum(
        1 for m in res.jobs if m.deadline is not None
    )
    assert res.n_deadline_missed == sum(
        m.deadline_missed for m in res.jobs
    )
    assert sum(tot for _, tot in res.tier_slo.values()) \
        == res.n_deadline_jobs
    for tier, frac in res.slo_attainment.items():
        met, tot = res.tier_slo[tier]
        assert frac == pytest.approx(met / tot)


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=10,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(st.integers(min_value=0, max_value=10_000))
    def test_tiered_overload_serves_feasibly_hypothesis(seed):
        _check_tiered_overload_serve(seed)

else:

    @pytest.mark.parametrize("seed", range(6))
    def test_tiered_overload_serves_feasibly_seeded(seed):
        _check_tiered_overload_serve(seed)


# ---------------------------------------------------------------------------
# Layer 5: backfill interaction (PR-5 head-of-line protections re-locked)
# ---------------------------------------------------------------------------

def _scaled(job, factor):
    return dataclasses.replace(job, p=job.p * factor, d=job.d * factor)


def _hol_stream(tail_factor):
    """The PR-5 head-of-line trace: t=0 a long 3-rack job takes racks
    0-2 of a 4-rack cluster; t=1 a 2-rack job arrives (blocked); t=2 a
    1-rack job scaled by ``tail_factor`` arrives behind it."""
    rng = np.random.default_rng(9)
    jobs = [
        _scaled(random_job(rng, None, n_tasks=6), 10.0),
        random_job(rng, None, n_tasks=6),
        _scaled(random_job(rng, None, n_tasks=5), tail_factor),
    ]
    evs = trace_arrivals([0.0, 1.0, 2.0], jobs, n_racks=4, n_wireless=0)
    demands = (3, 2, 1)
    return [
        dataclasses.replace(e, inst=dataclasses.replace(e.inst, n_racks=d))
        for e, d in zip(evs, demands)
    ]


def _serve_hol(evs, admission, **kw):
    svc = OnlineScheduler(
        4, 0, window=0.0, policy="greedy_list", require_full_demand=True,
        preserve_order=True, backfill=True, admission=admission, **kw
    )
    return svc.serve(evs)


@pytest.mark.parametrize("admission", ["edf", "wfair"])
def test_admission_reorder_preserves_hol_backfill_protections(admission):
    """On the deadline-less PR-5 trace the non-FIFO orders are arrival
    ties: backfill counters and every admission epoch re-lock exactly."""
    evs = _hol_stream(tail_factor=0.02)
    fifo = _serve_hol(evs, "fifo")
    re = _serve_hol(evs, admission, tenant_weights={"unused": 2.0})
    assert re.n_backfilled == fifo.n_backfilled == 1
    assert re.jobs[2].backfilled
    assert re.jobs[2].admitted == 2.0  # its own arrival epoch
    assert re.jobs[1].admitted == fifo.jobs[1].admitted
    assert re.jobs[0].admitted == fifo.jobs[0].admitted == 0.0
    # The backfill overtake is recorded against the blocked job.
    assert re.jobs[1].n_overtaken == 1
    re.timeline.assert_feasible(full=True)


@pytest.mark.parametrize("admission", ["edf", "wfair"])
def test_admission_reorder_keeps_backfill_rejections(admission):
    """A long job the shadow-slack proof cannot clear stays rejected no
    matter the admission order."""
    evs = _hol_stream(tail_factor=50.0)
    fifo = _serve_hol(evs, "fifo")
    re = _serve_hol(evs, admission)
    assert re.n_backfilled == fifo.n_backfilled == 0
    assert re.n_backfill_rejected >= 1
    assert [j.jct for j in re.jobs] == [j.jct for j in fifo.jobs]


def test_hol_backfill_respects_max_overtakes_zero():
    """``max_overtakes=0`` also forbids the backfill overtake itself:
    the tail job waits behind the blocked head-of-line job."""
    evs = _hol_stream(tail_factor=0.02)
    res = _serve_hol(evs, "fifo", max_overtakes=0)
    assert res.n_backfilled == 0
    assert res.max_overtakes_observed == 0
    assert res.jobs[1].admitted <= res.jobs[2].admitted
    res.timeline.assert_feasible(full=True)


# ---------------------------------------------------------------------------
# Units: tiered generators and summary rendering
# ---------------------------------------------------------------------------

def test_tiered_generators_are_deterministic_and_annotated():
    a = tiered_poisson_arrivals(7, 1 / 8, 12, n_racks=4, n_wireless=2)
    b = tiered_poisson_arrivals(7, 1 / 8, 12, n_racks=4, n_wireless=2)
    assert [(e.time, e.tier, e.tenant, e.deadline) for e in a] \
        == [(e.time, e.tier, e.tenant, e.deadline) for e in b]
    names = {t.name: t for t in DEFAULT_SLO_TIERS}
    assert {e.tier for e in a} <= set(names)
    for e in a:
        tier = names[e.tier]
        if tier.slack is None:
            assert e.deadline is None
        else:
            assert e.deadline == e.time + tier.slack * lower_bound(e.inst)
        assert e.tenant.startswith("tenant-")
    # Base stream bit-identity (times and DAG volumes).
    base = poisson_arrivals(7, rate=1 / 8, n_jobs=12, n_racks=4,
                            n_wireless=2)
    assert [e.time for e in a] == [e.time for e in base]
    for x, y in zip(a, base):
        assert np.array_equal(x.inst.q_wired, y.inst.q_wired)


def test_stream_tiered_arrivals_custom_tiers_and_validation():
    tiers = (SloTier("rt", weight=1.0, slack=1.5, share=3.0),)
    evs = poisson_arrivals(3, rate=1 / 4, n_jobs=5, n_racks=2,
                           n_wireless=0)
    out = list(stream_tiered_arrivals(evs, 3, tiers=tiers, n_tenants=1))
    assert all(e.tier == "rt" and e.tenant == "tenant-0" for e in out)
    assert all(e.deadline is not None for e in out)
    with pytest.raises(ValueError, match="non-empty"):
        list(stream_tiered_arrivals(evs, 3, tiers=()))
    with pytest.raises(ValueError, match="weights"):
        list(stream_tiered_arrivals(
            evs, 3, tiers=(SloTier("x", weight=-1.0, slack=None),)
        ))
    with pytest.raises(ValueError, match="slack"):
        list(stream_tiered_arrivals(
            evs, 3, tiers=(SloTier("x", weight=1.0, slack=0.0),)
        ))
    with pytest.raises(ValueError, match="share"):
        list(stream_tiered_arrivals(
            evs, 3, tiers=(SloTier("x", weight=1.0, slack=1.0, share=0.0),)
        ))
    with pytest.raises(ValueError, match="n_tenants"):
        list(stream_tiered_arrivals(evs, 3, n_tenants=0))


def _toy_result(**kw):
    jobs = [
        JobMetrics(0, "mapreduce", 0.0, 0.0, 5.0, 5.0, 2, 0, 1,
                   deadline=6.0, tenant="acme", tier="gold"),
        JobMetrics(1, "mapreduce", 1.0, 5.0, 12.0, 7.0, 2, 0, 1,
                   deadline=10.0, tenant="acme", tier="silver",
                   n_overtaken=2),
    ]
    base = dict(
        jobs=jobs, policy="greedy_list", warm_start=False, n_epochs=3,
        n_batches=0, n_solves=2, n_candidates=0, n_pruned=0,
        solver_wall=0.0, horizon=12.0, rack_utilization=0.5,
        wired_utilization=0.25, wireless_utilization=0.0,
    )
    base.update(kw)
    return OnlineResult(**base)


def test_deadline_missed_property_and_slo_attainment():
    res = _toy_result(
        admission="edf", n_deadline_jobs=2, n_deadline_missed=1,
        tier_slo={"gold": (1, 1), "silver": (0, 1)},
    )
    assert not res.jobs[0].deadline_missed
    assert res.jobs[1].deadline_missed
    assert res.slo_attainment == {"gold": 1.0, "silver": 0.0}


def test_summary_renders_slo_fields_and_inf_solver_rate():
    stats = StreamingSeries()
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        stats.push(v)
    res = _toy_result(
        admission="edf", n_deadline_jobs=2, n_deadline_missed=1,
        n_deadline_deferrals=2, n_deadline_rejected=1,
        rejected_job_ids=[7], tier_slo={"gold": (1, 1), "silver": (0, 1)},
        tenant_queue_stats={"acme": stats}, max_overtakes_observed=2,
    )
    s = res.summary()
    assert "adm=edf" in s
    assert "misses=1/2" in s
    assert "slo(gold=1.00,silver=0.00)" in s
    assert "deferrals=2" in s
    assert "rejected=1" in s
    assert "max_overtaken=2" in s
    assert "tenant_p99q(acme=" in s
    # solver_wall=0 with served jobs: the rate renders as literal "inf".
    assert "jobs_per_solver_s=inf" in s


def test_summary_omits_slo_section_for_plain_fifo_runs():
    res = _toy_result()
    res.jobs[0] = dataclasses.replace(res.jobs[0], deadline=None)
    res.jobs[1] = dataclasses.replace(res.jobs[1], deadline=None)
    s = res.summary()
    assert "adm=" not in s
    assert "slo(" not in s
    # FIFO runs that *did* carry deadlines still render the SLO section.
    tracked = _toy_result(n_deadline_jobs=2, n_deadline_missed=1)
    assert "adm=fifo" in tracked.summary()
