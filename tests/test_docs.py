"""Docs lane contracts: README/docs snippets import-and-run, and no
broken intra-repo links (the same checks CI's docs job runs via
``tools/check_docs.py``)."""

import importlib.util
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "tools" / "check_docs.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_exist():
    checker = load_checker()
    files = {p.name for p in checker.doc_files()}
    assert "README.md" in files
    assert "architecture.md" in files
    assert "benchmarks.md" in files


def test_no_broken_intra_repo_links():
    checker = load_checker()
    errors = []
    for path in checker.doc_files():
        errors += checker.check_links(path)
    assert not errors, "\n".join(errors)


def test_doc_snippets_run():
    checker = load_checker()
    errors = []
    for path in checker.doc_files():
        errors += checker.check_snippets(path)
    assert not errors, "\n".join(errors)


def test_checker_catches_broken_link(tmp_path):
    checker = load_checker()
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](no/such/file.py) and [ok](ok.md)\n")
    (tmp_path / "ok.md").write_text("fine\n")
    errors = checker.check_links(bad)
    assert len(errors) == 1 and "no/such/file.py" in errors[0]


def test_checker_catches_failing_snippet(tmp_path):
    checker = load_checker()
    bad = tmp_path / "bad.md"
    bad.write_text("```python\nraise RuntimeError('boom')\n```\n")
    errors = checker.check_snippets(bad)
    assert len(errors) == 1 and "boom" in errors[0]


def test_checker_cli_passes_on_repo(capsys):
    checker = load_checker()
    assert checker.main() == 0
