"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, asserting output shapes and finiteness (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.config import layer_kinds, layer_period
from repro.models.lm import build_model, count_params
from repro.optim.adamw import AdamWConfig
from repro.runtime.steps import build_train_step, make_train_state


# Two light architectures stay in the default CI lane; the rest of the zoo
# (the multi-minute compile-heavy smokes) runs in the slow/full lane.
_FAST_ARCHS = {"llama3_2_3b", "qwen1_5_4b"}
SMOKE_ARCHS = [
    a if a in _FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
    for a in ARCH_IDS
]


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.n_enc_layers or cfg.cross_attn_every:
        T = S if cfg.n_enc_layers else 16
        batch["memory"] = jnp.asarray(
            rng.standard_normal((B, T, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.n_layers >= 12 and cfg.d_model >= 1024
    assert cfg.n_layers % layer_period(cfg) == 0


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    batch = _batch(cfg)
    state = make_train_state(model, jax.random.PRNGKey(0))
    logits, aux = model.forward(
        state.params, batch["tokens"], memory=batch.get("memory")
    )
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    step = jax.jit(build_train_step(model, AdamWConfig(warmup_steps=2), n_micro=2))
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert metrics["grad_norm"] > 0.0
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), state.params, state2.params
    )
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_decode_consistency(arch):
    """Greedy decode over the same prefix must match teacher-forced forward
    logits (cache correctness), for every architecture family."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    memory = None
    enc_out = None
    if cfg.n_enc_layers:
        # forward() encodes raw frames itself; the decode cache stores the
        # ENCODED memory (prefill-time encoder output).
        frames = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
        memory = frames
        enc_out = model.encode(params, frames)
    elif cfg.cross_attn_every:
        memory = jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)), jnp.float32
        )
        enc_out = memory

    full_logits, _ = model.forward(params, tokens, memory=memory)

    cache = model.init_cache(B, 32, memory=enc_out)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, tokens[:, t])
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    if cfg.n_experts:
        # MoE top-k routing is discontinuous and random-init logits are
        # nearly flat (argmax flips on noise), so compare output
        # DISTRIBUTIONS: per-position KL(forward || decode) must be tiny.
        p = jax.nn.log_softmax(full_logits.astype(jnp.float32))
        q = jax.nn.log_softmax(dec_logits.astype(jnp.float32))
        kl = jnp.sum(jnp.exp(p) * (p - q), axis=-1)
        assert float(kl.max()) < 0.1, f"max KL {float(kl.max()):.4f}"
        assert float(kl.mean()) < 0.02, f"mean KL {float(kl.mean()):.4f}"
    else:
        tol = max(0.05, 0.02 * cfg.n_layers)  # bf16 noise compounds per layer
        np.testing.assert_allclose(
            np.asarray(dec_logits, np.float32),
            np.asarray(full_logits, np.float32),
            atol=tol,
            rtol=tol,
        )


def test_moe_capacity_drops_are_bounded():
    cfg = smoke_config("dbrx_132b")
    from repro.models.moe import init_moe, moe_ffn

    params = init_moe(jax.random.PRNGKey(0), cfg.d_model, 64, 4)
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((2, 32, cfg.d_model)),
        jnp.float32,
    )
    y, aux = moe_ffn(params, x, 4, 2, capacity_factor=4.0)
    assert y.shape == x.shape
    assert float(aux) > 0.0
    # generous capacity => output differs from zero for almost all tokens
    nz = float(jnp.mean((jnp.abs(y) > 0).any(-1)))
    assert nz > 0.9


def test_param_count_deepseek_structure():
    """Analytic parameter audit of the biggest dense config (layer math)."""
    cfg = get_config("deepseek_67b")
    d, ff, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    hd = cfg.head_dim
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    mlp = 3 * d * ff
    expected = L * (attn + mlp) + 2 * V * d
    assert 6.0e10 < expected < 7.5e10  # ~67B
