"""Substrate tests: optimizer, data pipeline, checkpointing, grad utils."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, make_pipeline
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.grad import accumulate_grads, compress_bf16


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr_peak=0.1, lr_min=0.01, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state, m = adamw_update(cfg, params, grads, state)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr_peak=1.0, lr_min=0.1, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)


def test_grad_clipping_applied():
    cfg = AdamWConfig(clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.ones(4)}
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e6)}
    p2, _, metrics = adamw_update(cfg, params, huge, state)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 1.0


def test_accumulate_grads_matches_full_batch():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    xs = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    ys = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)

    def loss(params, batch):
        pred = batch["x"] @ params
        return jnp.mean((pred - batch["y"]) ** 2)

    full_loss, full_grads = jax.value_and_grad(loss)(w, {"x": xs, "y": ys})
    micro = {"x": xs.reshape(4, 4, 8), "y": ys.reshape(4, 4, 4)}
    acc_loss, acc_grads = accumulate_grads(loss, w, micro)
    np.testing.assert_allclose(acc_loss, full_loss, rtol=1e-6)
    np.testing.assert_allclose(acc_grads, full_grads, rtol=1e-5, atol=1e-6)


def test_bf16_compression_error_feedback():
    g = {"w": jnp.asarray([1.0 + 1e-4, -2.0 - 3e-4], jnp.float32)}
    c1, r1 = compress_bf16(g)
    # residual keeps exactly what bf16 dropped
    recon = c1["w"].astype(jnp.float32) + r1["w"]
    np.testing.assert_allclose(recon, g["w"], atol=1e-7)
    # next round re-injects the residual
    c2, r2 = compress_bf16(g, r1)
    total = c1["w"].astype(jnp.float32) + c2["w"].astype(jnp.float32)
    np.testing.assert_allclose(total, 2 * g["w"], atol=2e-3)


def test_data_pipeline_deterministic_and_restartable():
    cfg = DataConfig(vocab_size=100, global_batch=8, seq_len=16, seed=3)
    p1 = make_pipeline(cfg)
    p2 = make_pipeline(cfg)
    b1 = p1.batch_for_step(17)
    b2 = p2.batch_for_step(17)  # fresh pipeline, same step => same batch
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 16)
    # labels are next-token
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_host_sharding_partitions():
    cfg = DataConfig(vocab_size=50, global_batch=8, seq_len=4)
    p = make_pipeline(cfg)
    b = p.batch_for_step(0)
    shards = [p.host_shard(b, h, 4) for h in range(4)]
    recon = np.concatenate([s["tokens"] for s in shards], axis=0)
    np.testing.assert_array_equal(recon, b["tokens"])


def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": {"c": np.asarray(7, dtype=np.int32)},
    }
    d = str(tmp_path / "ck")
    ckpt.save(d, 10, tree)
    tree2 = jax.tree.map(lambda x: x * 0, tree)
    restored, step = ckpt.restore(d, tree2)
    assert step == 10
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])
    # newer step wins
    ckpt.save(d, 20, jax.tree.map(lambda x: x + 1, tree))
    _, step = ckpt.restore(d, tree2)
    assert step == 20
    assert ckpt.latest_step(d) == 20


def test_checkpoint_multihost_stripes(tmp_path):
    tree = {"a": np.ones((4,)), "b": np.zeros((2,)), "c": np.full((3,), 5.0)}
    d = str(tmp_path / "ck")
    for h in range(2):
        ckpt.save(d, 1, tree, host_id=h, n_hosts=2)
    restored, _ = ckpt.restore(d, jax.tree.map(np.zeros_like, tree))
    for k in tree:
        np.testing.assert_array_equal(restored[k], tree[k])


def test_checkpoint_incomplete_rejected(tmp_path):
    tree = {"a": np.ones((4,)), "b": np.zeros((2,))}
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, tree, host_id=0, n_hosts=2)  # missing shard 1
    with pytest.raises(IOError):
        ckpt.restore(d, tree)
