"""Checkpointing: atomic, shard-per-host npz snapshots with step management.

Layout:
  <dir>/step_<N>/meta.json             — treedef + shapes + step
  <dir>/step_<N>/shard_<H>.npz         — flat leaves owned by host H
  <dir>/LATEST                         — committed step pointer (atomic rename)

Fault-tolerance contract: a checkpoint is visible only after its LATEST
pointer is renamed into place, so a crash mid-write never corrupts restart
state. Restore is layout-agnostic (stores logical arrays, not device
shards), so elastic restarts onto a different mesh reshard on load.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step"]


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(directory: str, step: int, tree: Any, host_id: int = 0, n_hosts: int = 1) -> str:
    """Write a checkpoint snapshot. Returns the committed step dir."""
    os.makedirs(directory, exist_ok=True)
    step_dir = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(step_dir, exist_ok=True)

    items = _flatten_with_paths(tree)
    # Host H owns leaves with index % n_hosts == H (layout-agnostic striping).
    owned = {
        f"leaf_{i:05d}": np.asarray(leaf)
        for i, (_, leaf) in enumerate(items)
        if i % n_hosts == host_id
    }
    tmp = tempfile.NamedTemporaryFile(
        dir=step_dir, suffix=".tmp", delete=False
    )
    np.savez(tmp, **owned)
    tmp.close()
    os.replace(tmp.name, os.path.join(step_dir, f"shard_{host_id:04d}.npz"))

    if host_id == 0:
        treedef = jax.tree.structure(tree)
        meta = {
            "step": step,
            "n_hosts": n_hosts,
            "n_leaves": len(items),
            "paths": [p for p, _ in items],
            "treedef": str(treedef),
        }
        with open(os.path.join(step_dir, "meta.json"), "w") as f:
            json.dump(meta, f)
        # Atomic commit.
        tmp_ptr = os.path.join(directory, ".LATEST.tmp")
        with open(tmp_ptr, "w") as f:
            f.write(str(step))
        os.replace(tmp_ptr, os.path.join(directory, "LATEST"))
    return step_dir


def latest_step(directory: str) -> int | None:
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            return int(f.read().strip())
    except FileNotFoundError:
        return None


def restore(directory: str, tree_like: Any, step: int | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like`` (shapes/dtypes preserved).

    Works across host counts: reads every shard file present.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    step_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(step_dir, "meta.json")) as f:
        meta = json.load(f)
    leaves: dict[int, np.ndarray] = {}
    for fn in sorted(os.listdir(step_dir)):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(step_dir, fn)) as z:
                for key in z.files:
                    leaves[int(key.split("_")[1])] = z[key]
    if len(leaves) != meta["n_leaves"]:
        raise IOError(
            f"checkpoint incomplete: {len(leaves)}/{meta['n_leaves']} leaves"
        )
    flat, treedef = jax.tree.flatten(tree_like)
    if len(flat) != meta["n_leaves"]:
        raise ValueError("tree structure mismatch vs checkpoint")
    restored = [
        np.asarray(leaves[i]).reshape(np.shape(ref)) for i, ref in enumerate(flat)
    ]
    return jax.tree.unflatten(treedef, restored), step
