"""Combinatorial Branch-and-Bound for the joint scheduling problem.

The paper solves RP with an LP-based B&B (Gurobi). Big-M disjunctive models
have notoriously weak LP relaxations, so as a *beyond-paper* exact method we
also implement a two-level combinatorial B&B that exploits the problem
structure directly while reusing the paper's §IV-A bounds:

  Level 1 — DFS over task->rack assignments in topological order with rack
            symmetry breaking (a task may open at most one fresh rack).
            Pruned by a partial-assignment lower bound: critical path with
            optimistic transfer costs, per-rack loads, and aggregate channel
            work; seeded with the single-rack incumbent that attains the
            paper's T_max and with contention-aware greedy schedules.
  Level 2 — at complete assignments, channels and sequencing are solved
            exactly by Giffler–Thompson active-schedule enumeration over a
            flexible job shop: task operations are fixed to their rack
            machine; cross-rack transfer operations are flexible over
            {wired b} ∪ K wireless channels; local transfers are folded into
            ready times (the infinite-capacity virtual channel c of §IV-B).
            Identical channels are canonicalized (only one of each distinct
            availability time is branched) and states are pruned through a
            Pareto transposition table keyed by the scheduled-operation set.

For a regular objective (makespan) the set of active schedules contains an
optimal schedule, so enumeration of active schedules plus exact assignment
enumeration yields the OP optimum. Cross-validated against the RP/HiGHS
solver on small instances by the test suite.

The hot path is deliberately numpy-free: at these instance sizes (|V| <= ~12,
|E| <= ~30) Python lists are ~10x faster than numpy scalar indexing.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import bounds as bounds_mod
from repro.core.instance import CH_LOCAL, CH_WIRED, ProblemInstance
from repro.core.schedule import Schedule, check_feasible
from repro.core.simulator import simulate

__all__ = ["BnbResult", "solve_bnb"]

_INF = float("inf")


@dataclasses.dataclass
class BnbResult:
    schedule: Schedule
    makespan: float
    nodes_assignment: int
    nodes_sequencing: int
    wall_s: float
    proved_optimal: bool


class _GT:
    """Giffler–Thompson B&B over the fixed-assignment flexible job shop."""

    def __init__(self, inst: ProblemInstance, rack, ub: float, topo):
        job = inst.job
        self.inst = inst
        self.n = job.n_tasks
        self.n_racks = inst.n_racks
        self.n_chan = 1 + inst.n_wireless  # pooled: 0 = wired, 1.. = wireless
        self.p = [float(x) for x in job.p]
        self.rack = [int(x) for x in rack]
        self.topo = [int(v) for v in topo]
        q = inst.q_wired
        qw = inst.q_wireless
        r = inst.r_local
        src = job.edges[:, 0]
        dst = job.edges[:, 1]

        # Split edges into cross (network) and local (folded into readiness).
        self.cross: list[int] = []      # original edge ids
        self.cq: list[float] = []       # wired duration per cross edge
        self.cqw: list[float] = []      # wireless duration per cross edge
        self.csrc: list[int] = []
        self.cdst: list[int] = []
        in_local: list[list[tuple[int, float]]] = [[] for _ in range(self.n)]
        in_cross: list[list[int]] = [[] for _ in range(self.n)]
        for e in range(job.n_edges):
            u, v = int(src[e]), int(dst[e])
            if self.rack[u] != self.rack[v]:
                ci = len(self.cross)
                self.cross.append(e)
                self.cq.append(float(q[e]))
                self.cqw.append(float(qw[e]))
                self.csrc.append(u)
                self.cdst.append(v)
                in_cross[v].append(ci)
            else:
                in_local[v].append((u, float(r[e])))
        self.in_local = in_local
        self.in_cross = in_cross
        self.nc = len(self.cross)
        # All channels truly identical? (paper's experiments: B == B_s)
        self.pooled = all(
            abs(a - b) < 1e-12 for a, b in zip(self.cq, self.cqw)
        ) or inst.n_wireless == 0

        # Optimistic tails: tail[v] = p_v + max downstream path.
        cmin = [
            min(self.cq[i], self.cqw[i]) if inst.n_wireless else self.cq[i]
            for i in range(self.nc)
        ]
        self.cmin = cmin
        tail = list(self.p)
        out_local: list[list[tuple[int, float]]] = [[] for _ in range(self.n)]
        out_cross: list[list[int]] = [[] for _ in range(self.n)]
        for v in range(self.n):
            for (u, rr) in in_local[v]:
                out_local[u].append((v, rr))
            for ci in in_cross[v]:
                out_cross[self.csrc[ci]].append(ci)
        for v in reversed(self.topo):
            best = 0.0
            for (w, rr) in out_local[v]:
                c = rr + tail[w]
                if c > best:
                    best = c
            for ci in out_cross[v]:
                c = cmin[ci] + tail[self.cdst[ci]]
                if c > best:
                    best = c
            tail[v] = self.p[v] + best
        self.tail = tail
        self.out_cross = out_cross

        self.best_ub = float(ub)
        self.best: tuple[list, list, list] | None = None
        self.nodes = 0
        self.deadline: float | None = None
        self.proved = True
        # Pareto transposition table: scheduled-set bitmask -> state tuples.
        self.tt: dict[int, list[tuple]] = {}
        self.tt_cap = 64

    def solve(self, time_limit: float | None = None):
        self.deadline = (
            time.perf_counter() + time_limit if time_limit is not None else None
        )
        self._dfs(
            [-1.0] * self.n,
            [-1.0] * self.nc,
            [-1] * self.nc,
            [0.0] * self.n_racks,
            [0.0] * self.n_chan,
        )
        return self.best, self.best_ub, self.nodes, self.proved

    # ------------------------------------------------------------------
    def _quick_lb(self, sstart, tstart, tchan) -> float:
        """LB: resource-relaxed critical path + rack and channel bounds."""
        p, tail = self.p, self.tail
        est = [0.0] * self.n
        lb = 0.0
        for v in self.topo:
            sv = sstart[v]
            if sv >= 0.0:
                t = sv
            else:
                t = 0.0
                for (u, rr) in self.in_local[v]:
                    c = est[u] + p[u] + rr
                    if c > t:
                        t = c
                for ci in self.in_cross[v]:
                    ts = tstart[ci]
                    if ts >= 0.0:
                        d = self.cq[ci] if tchan[ci] == 0 else self.cqw[ci]
                        c = ts + d
                    else:
                        u = self.csrc[ci]
                        c = est[u] + p[u] + self.cmin[ci]
                    if c > t:
                        t = c
            est[v] = t
            c = t + tail[v]
            if c > lb:
                lb = c

        # Rack head+work+tail bounds over unscheduled tasks.
        head = [_INF] * self.n_racks
        work = [0.0] * self.n_racks
        tl = [_INF] * self.n_racks
        any_work = False
        for v in range(self.n):
            if sstart[v] < 0.0:
                i = self.rack[v]
                if est[v] < head[i]:
                    head[i] = est[v]
                work[i] += p[v]
                t2 = tail[v] - p[v]
                if t2 < tl[i]:
                    tl[i] = t2
                any_work = True
        if any_work:
            for i in range(self.n_racks):
                if work[i] > 0.0:
                    c = head[i] + work[i] + tl[i]
                    if c > lb:
                        lb = c

        # Aggregate channel bound over unscheduled cross transfers.
        h, w, t2 = _INF, 0.0, _INF
        for ci in range(self.nc):
            if tstart[ci] < 0.0:
                u = self.csrc[ci]
                c = est[u] + p[u]
                if c < h:
                    h = c
                w += self.cmin[ci]
                tt = tail[self.cdst[ci]]
                if tt < t2:
                    t2 = tt
        if w > 0.0:
            c = h + w / self.n_chan + t2
            if c > lb:
                lb = c
        return lb

    # ------------------------------------------------------------------
    def _dfs(self, sstart, tstart, tchan, rack_avail, chan_avail):
        if self.deadline is not None and time.perf_counter() > self.deadline:
            self.proved = False
            return
        self.nodes += 1
        p = self.p

        # Scheduled-set bitmask + dominance check.
        mask = 0
        for v in range(self.n):
            if sstart[v] >= 0.0:
                mask |= 1 << v
        for ci in range(self.nc):
            if tstart[ci] >= 0.0:
                mask |= 1 << (self.n + ci)
        fins = tuple(
            sstart[v] + p[v] if sstart[v] >= 0.0 else 0.0 for v in range(self.n)
        )
        if self.pooled:
            state = tuple(rack_avail) + tuple(sorted(chan_avail)) + fins
        else:
            state = (
                tuple(rack_avail)
                + (chan_avail[0],)
                + tuple(sorted(chan_avail[1:]))
                + fins
            )
        bucket = self.tt.get(mask)
        if bucket is not None:
            for vec in bucket:
                dominated = True
                for a, b in zip(vec, state):
                    if a > b + 1e-9:
                        dominated = False
                        break
                if dominated:
                    return
            keep = []
            for vec in bucket:
                dominates = True
                for a, b in zip(state, vec):
                    if a > b + 1e-9:
                        dominates = False
                        break
                if not dominates:
                    keep.append(vec)
            if len(keep) < self.tt_cap:
                keep.append(state)
            self.tt[mask] = keep
        else:
            self.tt[mask] = [state]

        # Completion: all tasks scheduled (transfers precede their dests).
        ntasks_done = 0
        for v in range(self.n):
            if sstart[v] >= 0.0:
                ntasks_done += 1
        if ntasks_done == self.n:
            mk = 0.0
            for v in range(self.n):
                c = sstart[v] + p[v]
                if c > mk:
                    mk = c
            if mk < self.best_ub - 1e-9:
                self.best_ub = mk
                self.best = (list(sstart), list(tstart), list(tchan))
            return

        # --- Candidates: (ect, est, kind, idx, machine) -------------------
        cands: list[tuple[float, float, int, int, int]] = []
        for v in range(self.n):
            if sstart[v] >= 0.0:
                continue
            ready = 0.0
            ok = True
            for (u, rr) in self.in_local[v]:
                if sstart[u] < 0.0:
                    ok = False
                    break
                c = sstart[u] + p[u] + rr
                if c > ready:
                    ready = c
            if not ok:
                continue
            for ci in self.in_cross[v]:
                if tstart[ci] < 0.0:
                    ok = False
                    break
                d = self.cq[ci] if tchan[ci] == 0 else self.cqw[ci]
                c = tstart[ci] + d
                if c > ready:
                    ready = c
            if not ok:
                continue
            i = self.rack[v]
            a = rack_avail[i]
            est = ready if ready > a else a
            cands.append((est + p[v], est, 0, v, i))
        for ci in range(self.nc):
            if tstart[ci] >= 0.0:
                continue
            u = self.csrc[ci]
            if sstart[u] < 0.0:
                continue
            ready = sstart[u] + p[u]
            if self.pooled:
                seen: set[float] = set()
                for c in range(self.n_chan):
                    a = chan_avail[c]
                    if a in seen:
                        continue
                    seen.add(a)
                    est = ready if ready > a else a
                    cands.append((est + self.cq[ci], est, 1, ci, c))
            else:
                a = chan_avail[0]
                est = ready if ready > a else a
                cands.append((est + self.cq[ci], est, 1, ci, 0))
                seen = set()
                for c in range(1, self.n_chan):
                    a = chan_avail[c]
                    if a in seen:
                        continue
                    seen.add(a)
                    est = ready if ready > a else a
                    cands.append((est + self.cqw[ci], est, 1, ci, c))

        if not cands:
            return  # dead end (cannot happen on a DAG)

        cands.sort()
        ect_star = cands[0][0]
        m_star = cands[0][4]
        conflict = [
            c for c in cands if c[4] == m_star and c[1] < ect_star - 1e-12
        ]
        # No-delay dominance: if the earliest-completing op finishes before
        # any competitor can start, branching on it alone is sufficient.
        if len(conflict) > 1:
            ect0 = conflict[0][0]
            if all(ect0 <= c[1] + 1e-12 for c in conflict[1:]):
                conflict = conflict[:1]

        for ect, est, kind, idx, mach in conflict:
            if kind == 0:
                v = idx
                sstart[v] = est
                old = rack_avail[mach]
                rack_avail[mach] = ect
                if self._quick_lb(sstart, tstart, tchan) < self.best_ub - 1e-9:
                    self._dfs(sstart, tstart, tchan, rack_avail, chan_avail)
                sstart[v] = -1.0
                rack_avail[mach] = old
            else:
                ci = idx
                tstart[ci] = est
                tchan[ci] = mach
                old = chan_avail[mach]
                chan_avail[mach] = ect
                if self._quick_lb(sstart, tstart, tchan) < self.best_ub - 1e-9:
                    self._dfs(sstart, tstart, tchan, rack_avail, chan_avail)
                tstart[ci] = -1.0
                tchan[ci] = -1
                chan_avail[mach] = old
            if self.deadline is not None and time.perf_counter() > self.deadline:
                self.proved = False
                return


# The level-1 partial-assignment bound lives in repro.core.bounds so the
# B&B pruner, the vectorized stage-1 pruner, and the property tests all
# share one §IV-A implementation.
_assignment_lb = bounds_mod.partial_assignment_bound


def solve_fixed_assignment(
    inst: ProblemInstance,
    rack: np.ndarray,
    time_limit: float | None = None,
) -> BnbResult:
    """Exact channels + sequencing for a FIXED task->rack assignment (the
    Giffler–Thompson level alone). Used by distribution.plan where placement
    is dictated by the hardware, not optimized."""
    t0 = time.perf_counter()
    job = inst.job
    rack = np.asarray(rack, dtype=np.int64)
    topo = job.topo_order()
    heur = simulate(inst, rack, use_wireless=inst.n_wireless > 0)
    best_sched = heur
    gt = _GT(inst, rack, heur.makespan, topo)
    best, ub2, nodes, proved = gt.solve(time_limit=time_limit)
    if best is not None and ub2 < best_sched.makespan - 1e-9:
        sstart_l, tstart_l, tchan_l = best
        sstart = np.asarray(sstart_l)
        chan = np.zeros(job.n_edges, dtype=np.int64)
        ts = np.zeros(job.n_edges)
        for ci, e in enumerate(gt.cross):
            chan[e] = CH_WIRED if tchan_l[ci] == 0 else 1 + tchan_l[ci]
            ts[e] = tstart_l[ci]
        for e in range(job.n_edges):
            u, v = int(job.edges[e, 0]), int(job.edges[e, 1])
            if rack[u] == rack[v]:
                chan[e] = CH_LOCAL
                ts[e] = sstart[u] + float(job.p[u])
        best_sched = Schedule.build(inst, rack, sstart, chan, ts)
        check_feasible(inst, best_sched)
    return BnbResult(
        schedule=best_sched,
        makespan=best_sched.makespan,
        nodes_assignment=0,
        nodes_sequencing=nodes,
        wall_s=time.perf_counter() - t0,
        proved_optimal=proved,
    )


def solve_bnb(
    inst: ProblemInstance,
    time_limit: float | None = None,
    incumbent: Schedule | None = None,
    assignment_bound=None,
) -> BnbResult:
    """Exact two-level B&B. Returns the best (optimal unless timed out).

    ``assignment_bound`` is the level-1 bound hook: an optional callable
    ``(inst, rack_partial) -> float`` (rack_partial[v] = -1 when undecided)
    whose value is maxed with the built-in §IV-A partial-assignment bound
    (:func:`repro.core.bounds.partial_assignment_bound`). It MUST be
    admissible — never exceed the best completion time reachable from the
    partial assignment — or optimality is lost. The vectorized fleet
    scheduler shares the same bound family through this module's
    ``_assignment_lb`` alias.
    """
    t0 = time.perf_counter()
    job = inst.job
    n = job.n_tasks
    topo = job.topo_order()
    min_cost = np.minimum(inst.r_local, inst.q_wired)
    if inst.n_wireless:
        min_cost = np.minimum(min_cost, inst.q_wireless)

    from repro.core.baselines import g_list_schedule, single_rack_schedule

    best_sched = single_rack_schedule(inst)
    for cand in (
        g_list_schedule(inst, use_wireless=inst.n_wireless > 0),
        *([incumbent] if incumbent is not None else []),
    ):
        if cand.makespan < best_sched.makespan:
            best_sched = cand
    best_ub = best_sched.makespan

    nodes_a = 0
    nodes_s = 0
    proved = True
    deadline = t0 + time_limit if time_limit else None

    order = [int(v) for v in topo]
    rack = np.full(n, -1, dtype=np.int64)

    def dfs(pos: int, n_used: int):
        nonlocal nodes_a, nodes_s, best_ub, best_sched, proved
        if deadline is not None and time.perf_counter() > deadline:
            proved = False
            return
        nodes_a += 1
        lb = _assignment_lb(inst, rack, topo, min_cost)
        if assignment_bound is not None:
            # Copy: the DFS mutates this buffer after the frame returns, so
            # a hook that retains its argument must not see it rewritten.
            lb = max(lb, float(assignment_bound(inst, rack.copy())))
        if lb >= best_ub - 1e-9:
            return
        if pos == n:
            # Leaf-local heuristic incumbent before exact sequencing.
            # rack.copy(): the DFS buffer mutates after this frame returns.
            heur = simulate(
                inst, rack.copy(), use_wireless=inst.n_wireless > 0, check=False
            )
            if heur.makespan < best_ub - 1e-9:
                check_feasible(inst, heur)
                best_ub = heur.makespan
                best_sched = heur
            gt = _GT(inst, rack.copy(), best_ub, topo)
            remaining = None
            if deadline is not None:
                remaining = max(0.05, deadline - time.perf_counter())
            best, ub2, nn, pr = gt.solve(time_limit=remaining)
            nodes_s += nn
            proved = proved and pr
            if best is not None and ub2 < best_ub - 1e-9:
                sstart_l, tstart_l, tchan_l = best
                sstart = np.asarray(sstart_l)
                chan = np.zeros(job.n_edges, dtype=np.int64)
                ts = np.zeros(job.n_edges)
                for ci, e in enumerate(gt.cross):
                    # pooled channel 0 is wired; 1.. are wireless ids.
                    chan[e] = CH_WIRED if tchan_l[ci] == 0 else 1 + tchan_l[ci]
                    ts[e] = tstart_l[ci]
                for e in range(job.n_edges):
                    u, v = int(job.edges[e, 0]), int(job.edges[e, 1])
                    if rack[u] == rack[v]:
                        chan[e] = CH_LOCAL
                        ts[e] = sstart[u] + float(job.p[u])
                sched = Schedule.build(inst, rack.copy(), sstart, chan, ts)
                check_feasible(inst, sched)
                best_ub = sched.makespan
                best_sched = sched
            return
        v = order[pos]
        for i in range(min(n_used + 1, inst.n_racks)):
            rack[v] = i
            dfs(pos + 1, max(n_used, i + 1))
            rack[v] = -1
            if deadline is not None and time.perf_counter() > deadline:
                proved = False
                return

    dfs(0, 0)
    return BnbResult(
        schedule=best_sched,
        makespan=best_sched.makespan,
        nodes_assignment=nodes_a,
        nodes_sequencing=nodes_s,
        wall_s=time.perf_counter() - t0,
        proved_optimal=proved,
    )
