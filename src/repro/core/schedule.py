"""Schedule representation and the OP-semantics feasibility checker.

The checker validates a complete schedule directly against the ORIGINAL
problem OP's constraints (1)-(10) (plus the generalized-channel restatement
(11)), independently of any solver. Every solver and baseline in this package
must produce schedules that pass ``check_feasible`` — the property-based test
suite enforces this invariant.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.instance import CH_LOCAL, CH_WIRED, ProblemInstance

__all__ = ["Schedule", "check_feasible", "FeasibilityError"]


class FeasibilityError(AssertionError):
    pass


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A complete joint schedule.

    Attributes:
      rack: int64[n_tasks] rack assignment (0..M-1)        — the x variables.
      start: float64[n_tasks] task start times s_v         — the s variables.
      chan: int64[n_edges] channel per edge (0=b,1=c,2+=K) — the y variables.
      tstart: float64[n_edges] transfer start s_(u,v).
      makespan: max_v s_v + p_v.
    """

    rack: np.ndarray
    start: np.ndarray
    chan: np.ndarray
    tstart: np.ndarray
    makespan: float

    @staticmethod
    def build(
        inst: ProblemInstance,
        rack: np.ndarray,
        start: np.ndarray,
        chan: np.ndarray,
        tstart: np.ndarray,
    ) -> "Schedule":
        # np.array (not asarray): always copy — callers may pass live search
        # buffers that mutate after the schedule is recorded.
        rack = np.array(rack, dtype=np.int64, copy=True)
        start = np.array(start, dtype=np.float64, copy=True)
        chan = np.array(chan, dtype=np.int64, copy=True)
        tstart = np.array(tstart, dtype=np.float64, copy=True)
        mk = float(np.max(start + inst.job.p)) if inst.job.n_tasks else 0.0
        return Schedule(rack=rack, start=start, chan=chan, tstart=tstart, makespan=mk)


def _check_no_overlap(
    starts: np.ndarray, durs: np.ndarray, label: str, tol: float
) -> None:
    """All intervals [start, start+dur) must be pairwise disjoint.

    Zero-duration intervals occupy nothing (a zero-size transfer conflicts
    with no one under constraints (8)/(10)) and are ignored.
    """
    nz = durs > 0
    starts, durs = starts[nz], durs[nz]
    order = np.argsort(starts, kind="stable")
    s = starts[order]
    d = durs[order]
    gaps = s[1:] - (s[:-1] + d[:-1])
    if gaps.size and float(gaps.min()) < -tol:
        i = int(np.argmin(gaps))
        raise FeasibilityError(
            f"{label}: overlap between interval {i} and {i + 1}: "
            f"[{s[i]}, {s[i] + d[i]}) vs [{s[i + 1]}, ...)"
        )


def check_feasible(
    inst: ProblemInstance, sched: Schedule, tol: float = 1e-6
) -> float:
    """Validate ``sched`` against OP's constraints. Returns the makespan.

    Raises FeasibilityError with a diagnostic message on the first violation.
    """
    job = inst.job
    n, m = job.n_tasks, job.n_edges
    rack, start = sched.rack, sched.start
    chan, tstart = sched.chan, sched.tstart

    if rack.shape != (n,) or start.shape != (n,):
        raise FeasibilityError("bad task arrays")
    if chan.shape != (m,) or tstart.shape != (m,):
        raise FeasibilityError("bad edge arrays")

    # (1) Non-repetition: rack in range (one rack per task by representation).
    if n and (rack.min() < 0 or rack.max() >= inst.n_racks):
        raise FeasibilityError("rack assignment out of range")
    if n and float(start.min()) < -tol:
        raise FeasibilityError("negative task start")
    if m and float(tstart.min()) < -tol:
        raise FeasibilityError("negative transfer start")
    # (11) channel in range.
    if m and (chan.min() < 0 or chan.max() >= inst.n_channels):
        raise FeasibilityError("channel assignment out of range")

    dur = inst.duration_on(chan)

    # (4)/(26) Channel/locality consistency: local channel iff same rack.
    for e in range(m):
        u, v = job.edges[e]
        same = rack[u] == rack[v]
        if same != (chan[e] == CH_LOCAL):
            raise FeasibilityError(
                f"edge {e} ({u}->{v}): same_rack={bool(same)} but channel={chan[e]}"
            )

    # (6) transfer starts after producer completes.
    for e in range(m):
        u, v = job.edges[e]
        if tstart[e] < start[u] + job.p[u] - tol:
            raise FeasibilityError(
                f"edge {e}: transfer starts at {tstart[e]} before task {u} "
                f"completes at {start[u] + job.p[u]}"
            )
        # (5)/(7)/(9): consumer starts after transfer completes.
        if start[v] < tstart[e] + dur[e] - tol:
            raise FeasibilityError(
                f"edge {e}: task {v} starts at {start[v]} before transfer "
                f"completes at {tstart[e] + dur[e]}"
            )

    # (3) precedence (implied by the above, but checked for the slack form).
    for e in range(m):
        u, v = job.edges[e]
        if start[v] < start[u] + job.p[u] - tol:
            raise FeasibilityError(f"precedence violated on edge {u}->{v}")

    # (2) rack non-overlap.
    for i in range(inst.n_racks):
        sel = np.nonzero(rack == i)[0]
        if sel.size > 1:
            _check_no_overlap(start[sel], job.p[sel], f"rack {i}", tol)

    # (8) wired-channel exclusivity (single shared channel b).
    sel = np.nonzero(chan == CH_WIRED)[0]
    if sel.size > 1:
        _check_no_overlap(tstart[sel], dur[sel], "wired channel b", tol)

    # (10) per-subchannel wireless exclusivity.
    for k in range(inst.n_wireless):
        sel = np.nonzero(chan == 2 + k)[0]
        if sel.size > 1:
            _check_no_overlap(tstart[sel], dur[sel], f"wireless subchannel {k}", tol)

    mk = float(np.max(start + job.p)) if n else 0.0
    if abs(mk - sched.makespan) > max(tol, 1e-9 * max(1.0, abs(mk))):
        raise FeasibilityError(
            f"recorded makespan {sched.makespan} != recomputed {mk}"
        )
    return mk
