"""DAG job model and workload generators.

A job is a directed acyclic graph G = (V, E): tasks with processing times
``p_v`` and edges carrying intermediate data of size ``d_(u,v)`` (paper §II).
Workload generators follow the paper's §V evaluation setup, which mirrors
Giroire et al. [19]: simple MapReduce workflows, one-stage MapReduce
workflows, and random workflows, with task processing times ~ U[1, 100] and
data sizes set through the *network factor* rho = E[transfer time]/E[proc time].
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "DagJob",
    "topological_order",
    "make_simple_mapreduce",
    "make_onestage_mapreduce",
    "make_random_workflow",
    "random_job",
    "JOB_FAMILIES",
]


@dataclasses.dataclass(frozen=True)
class DagJob:
    """An immutable DAG job.

    Attributes:
      p: float64[n_tasks] task processing times.
      edges: int64[n_edges, 2] (u, v) pairs, u -> v dependency.
      d: float64[n_edges] intermediate data sizes (abstract units; transfer
         times are derived in :class:`repro.core.instance.ProblemInstance`).
      name: human-readable family tag.
    """

    p: np.ndarray
    edges: np.ndarray
    d: np.ndarray
    name: str = "job"

    def __post_init__(self) -> None:
        p = np.asarray(self.p, dtype=np.float64)
        edges = np.asarray(self.edges, dtype=np.int64).reshape(-1, 2)
        d = np.asarray(self.d, dtype=np.float64).reshape(-1)
        object.__setattr__(self, "p", p)
        object.__setattr__(self, "edges", edges)
        object.__setattr__(self, "d", d)
        if edges.shape[0] != d.shape[0]:
            raise ValueError("edges and d must have the same length")
        if edges.size and (edges.min() < 0 or edges.max() >= p.shape[0]):
            raise ValueError("edge endpoint out of range")
        if edges.size:
            if np.any(edges[:, 0] == edges[:, 1]):
                raise ValueError("self-loop edge")
            key = edges[:, 0] * p.shape[0] + edges[:, 1]
            if np.unique(key).size != key.size:
                raise ValueError("duplicate edge")
        # Validate acyclicity eagerly (raises on cycles).
        topological_order(p.shape[0], edges)

    @property
    def n_tasks(self) -> int:
        return int(self.p.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    def in_edges(self, v: int) -> np.ndarray:
        """Indices into ``edges`` of edges entering v."""
        return np.nonzero(self.edges[:, 1] == v)[0]

    def out_edges(self, v: int) -> np.ndarray:
        return np.nonzero(self.edges[:, 0] == v)[0]

    def topo_order(self) -> np.ndarray:
        return topological_order(self.n_tasks, self.edges)

    def adjacency(self) -> np.ndarray:
        """bool[n, n] adjacency matrix (u -> v)."""
        a = np.zeros((self.n_tasks, self.n_tasks), dtype=bool)
        if self.n_edges:
            a[self.edges[:, 0], self.edges[:, 1]] = True
        return a


def topological_order(n: int, edges: np.ndarray) -> np.ndarray:
    """Kahn topological sort; raises ValueError on cycles."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    indeg = np.zeros(n, dtype=np.int64)
    for _, v in edges:
        indeg[v] += 1
    stack = sorted(np.nonzero(indeg == 0)[0].tolist(), reverse=True)
    order: list[int] = []
    out: dict[int, list[int]] = {}
    for u, v in edges:
        out.setdefault(int(u), []).append(int(v))
    while stack:
        u = stack.pop()
        order.append(u)
        for v in out.get(u, ()):
            indeg[v] -= 1
            if indeg[v] == 0:
                stack.append(v)
    if len(order) != n:
        raise ValueError("graph has a cycle")
    return np.asarray(order, dtype=np.int64)


def _scale_data_sizes(
    p: np.ndarray, d_raw: np.ndarray, rho: float, rate: float
) -> np.ndarray:
    """Scale raw data sizes so E[d/rate] = rho * E[p] (paper's network factor)."""
    if d_raw.size == 0:
        return d_raw
    mean_transfer = float(np.mean(d_raw)) / rate
    target = rho * float(np.mean(p))
    if mean_transfer <= 0:
        return np.full_like(d_raw, target * rate)
    return d_raw * (target / mean_transfer)


def make_simple_mapreduce(
    rng: np.random.Generator,
    n_map: int = 4,
    rho: float = 0.5,
    rate: float = 1.0,
) -> DagJob:
    """Simple MapReduce: n_map mappers -> 1 reducer (fan-in star), per [19].

    Tasks 0..n_map-1 are mappers, task n_map is the reducer.
    """
    n = n_map + 1
    p = rng.uniform(1.0, 100.0, size=n)
    edges = np.stack(
        [np.arange(n_map), np.full(n_map, n_map)], axis=1
    ).astype(np.int64)
    d = rng.uniform(0.5, 1.5, size=n_map)
    d = _scale_data_sizes(p, d, rho, rate)
    return DagJob(p=p, edges=edges, d=d, name="simple_mapreduce")


def make_onestage_mapreduce(
    rng: np.random.Generator,
    n_map: int = 3,
    n_reduce: int = 2,
    rho: float = 0.5,
    rate: float = 1.0,
) -> DagJob:
    """One-stage MapReduce: full bipartite shuffle mappers -> reducers [19]."""
    n = n_map + n_reduce
    p = rng.uniform(1.0, 100.0, size=n)
    us, vs = np.meshgrid(np.arange(n_map), np.arange(n_map, n), indexing="ij")
    edges = np.stack([us.ravel(), vs.ravel()], axis=1).astype(np.int64)
    d = rng.uniform(0.5, 1.5, size=edges.shape[0])
    d = _scale_data_sizes(p, d, rho, rate)
    return DagJob(p=p, edges=edges, d=d, name="onestage_mapreduce")


def make_random_workflow(
    rng: np.random.Generator,
    n_tasks: int = 8,
    edge_prob: float = 0.3,
    rho: float = 0.5,
    rate: float = 1.0,
) -> DagJob:
    """Random layered-free DAG: edge (u, v) for u < v with prob edge_prob [19].

    A random topological labelling guarantees acyclicity. Isolated sinks are
    allowed (they model independent final tasks).
    """
    p = rng.uniform(1.0, 100.0, size=n_tasks)
    pairs = [
        (u, v)
        for u in range(n_tasks)
        for v in range(u + 1, n_tasks)
        if rng.uniform() < edge_prob
    ]
    # Guarantee weak connectivity of interest: ensure every non-root has at
    # least a chance of an in-edge; keep pure random otherwise (matches [19]).
    if not pairs and n_tasks > 1:
        pairs = [(0, n_tasks - 1)]
    edges = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    d = rng.uniform(0.5, 1.5, size=edges.shape[0])
    d = _scale_data_sizes(p, d, rho, rate)
    return DagJob(p=p, edges=edges, d=d, name="random_workflow")


JOB_FAMILIES = ("simple_mapreduce", "onestage_mapreduce", "random_workflow")


def random_job(
    rng: np.random.Generator,
    family: str | None = None,
    n_tasks: int | None = None,
    rho: float = 0.5,
    rate: float = 1.0,
) -> DagJob:
    """Sample a job from one of the three §V families.

    ``n_tasks`` pins the total task count (paper: uniform in [5, 10]).
    """
    if family is None:
        family = JOB_FAMILIES[int(rng.integers(len(JOB_FAMILIES)))]
    if n_tasks is None:
        n_tasks = int(rng.integers(5, 11))
    if family == "simple_mapreduce":
        return make_simple_mapreduce(rng, n_map=max(1, n_tasks - 1), rho=rho, rate=rate)
    if family == "onestage_mapreduce":
        n_map = max(1, n_tasks // 2)
        return make_onestage_mapreduce(
            rng, n_map=n_map, n_reduce=max(1, n_tasks - n_map), rho=rho, rate=rate
        )
    if family == "random_workflow":
        return make_random_workflow(rng, n_tasks=n_tasks, rho=rho, rate=rate)
    raise ValueError(f"unknown family {family!r}")
