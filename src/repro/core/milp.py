"""The linearized reformulation RP of the joint scheduling MINLP (paper §IV).

Variable blocks (flattened into one decision vector):

  x[v,i]    binary   task v assigned to rack i                     — (1)
  xt[v,i]   cont.    "time-product" auxiliary x̃_vi ∈ [0, Tmax]     — (12)
  y[e,k]    binary   edge e on channel k ∈ {b, c} ∪ K              — (11)
  yt[e,k]   cont.    auxiliary ỹ_ek ∈ [0, Tmax]                    — (13)
  psi[p,i]  binary   ψ: tasks of unordered pair p both on rack i   — (14),(16)
  sigma[o]  binary   σ: ordered task pair (v,v'), v starts no later — (18)
  chi[q,k]  binary   χ: unordered edge pair q contends on k∈{b}∪K  — (15),(17)
  phi[o]    binary   φ: ordered edge pair (e,e'), e transfers first — (20),(22)
  Cmax      cont.    makespan                                       — objective

Start times are recovered as S_v = Σ_i x̃_vi and S_e = Σ_k ỹ_ek (§IV-D).

Documented paper deviations (see DESIGN.md §8 "Risks"):
  * (12)/(13) as literally printed allow x̃_vi ≤ 1-ε slack on UNASSIGNED racks.
    This is harmless (it only translates recovered start times within the
    feasible region; any optimal solution of the tight model remains optimal)
    but numerically messy, so the default binding is the tight big-M
    x̃_vi ≤ Tmax·x_vi. ``paper_exact_binding=True`` reproduces (12)/(13)
    verbatim; tests assert both variants reach the same optimum.
  * (20) prints σ_ee' where the flow-precedence indicator φ_ee' (defined in
    §IV-C for transfer starts) is meant; (22) prints ỹ_eb for Σ_k ỹ_ek. We
    define ONE φ family on total transfer starts S_e — this is exactly the
    paper's own definition of φ ("if the data on e begins to transfer no
    later than the data on e', φ_ee' = 1") and makes (21)/(23) consistent.
  * (25)'s printed LHS/RHS both end in Σ_i x̃_vi; the intended constraint is
    S_(uv) + duration(uv) ≤ S_v. (24)'s printed LHS uses x̃_vi where the
    producer u is meant: S_u + p_u ≤ S_(uv).
  * RP's printed bound chain "T_min ≥ Σ_i x̃_vi + p_v" would force all tasks
    to finish before T_min; the intended constraints are C_max ≥ S_v + p_v
    and T_min ≤ C_max ≤ T_max.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from repro.core import bounds as bounds_mod
from repro.core.dag import DagJob
from repro.core.instance import CH_LOCAL, CH_WIRED, ProblemInstance

__all__ = ["RPModel", "VarMap", "build_rp", "extract_schedule"]

EPS = 0.1  # the paper's ε for strict-precedence reformulation


@dataclasses.dataclass(frozen=True)
class VarMap:
    """Offsets of each variable block in the flat decision vector."""

    n: int
    M: int
    m: int
    C: int  # channels incl. b (0) and c (1)
    n_pairs_v: int
    n_pairs_e: int

    @property
    def contend_channels(self) -> int:
        """Channels that can contend: {b} ∪ K (local never contends)."""
        return self.C - 1

    # Block offsets -------------------------------------------------------
    @property
    def off_x(self) -> int:
        return 0

    @property
    def off_xt(self) -> int:
        return self.off_x + self.n * self.M

    @property
    def off_y(self) -> int:
        return self.off_xt + self.n * self.M

    @property
    def off_yt(self) -> int:
        return self.off_y + self.m * self.C

    @property
    def off_psi(self) -> int:
        return self.off_yt + self.m * self.C

    @property
    def off_sigma(self) -> int:
        return self.off_psi + self.n_pairs_v * self.M

    @property
    def off_chi(self) -> int:
        return self.off_sigma + self.n * (self.n - 1)

    @property
    def off_phi(self) -> int:
        return self.off_chi + self.n_pairs_e * self.contend_channels

    @property
    def off_cmax(self) -> int:
        return self.off_phi + self.m * (self.m - 1)

    @property
    def n_vars(self) -> int:
        return self.off_cmax + 1

    # Index helpers -------------------------------------------------------
    def x(self, v: int, i: int) -> int:
        return self.off_x + v * self.M + i

    def xt(self, v: int, i: int) -> int:
        return self.off_xt + v * self.M + i

    def y(self, e: int, k: int) -> int:
        return self.off_y + e * self.C + k

    def yt(self, e: int, k: int) -> int:
        return self.off_yt + e * self.C + k

    def pair_v(self, v: int, vp: int) -> int:
        """Unordered task-pair index, v < vp."""
        a, b = (v, vp) if v < vp else (vp, v)
        # index of (a,b) in lexicographic unordered enumeration
        return a * self.n - a * (a + 1) // 2 + (b - a - 1)

    def psi(self, v: int, vp: int, i: int) -> int:
        return self.off_psi + self.pair_v(v, vp) * self.M + i

    def sigma(self, v: int, vp: int) -> int:
        """Ordered pair (v, vp), v != vp."""
        idx = v * (self.n - 1) + (vp if vp < v else vp - 1)
        return self.off_sigma + idx

    def pair_e(self, e: int, ep: int) -> int:
        a, b = (e, ep) if e < ep else (ep, e)
        return a * self.m - a * (a + 1) // 2 + (b - a - 1)

    def chi(self, e: int, ep: int, k: int) -> int:
        """k indexes contention channels: 0 = wired b, 1.. = wireless."""
        return self.off_chi + self.pair_e(e, ep) * self.contend_channels + k

    def phi(self, e: int, ep: int) -> int:
        idx = e * (self.m - 1) + (ep if ep < e else ep - 1)
        return self.off_phi + idx

    def cmax(self) -> int:
        return self.off_cmax


@dataclasses.dataclass
class RPModel:
    """Assembled MILP: min c'z s.t. A_ub z <= b_ub, A_eq z == b_eq."""

    vm: VarMap
    c: np.ndarray
    A_ub: sp.csr_matrix
    b_ub: np.ndarray
    A_eq: sp.csr_matrix
    b_eq: np.ndarray
    lb: np.ndarray
    ub: np.ndarray
    integrality: np.ndarray
    tmax: float
    tmin: float
    inst: ProblemInstance


class _Rows:
    """Incremental sparse row builder."""

    def __init__(self, n_vars: int) -> None:
        self.n_vars = n_vars
        self.data: list[float] = []
        self.rows: list[int] = []
        self.cols: list[int] = []
        self.rhs: list[float] = []
        self.nrows = 0

    def add(self, coeffs: list[tuple[int, float]], rhs: float) -> None:
        for col, val in coeffs:
            self.rows.append(self.nrows)
            self.cols.append(col)
            self.data.append(val)
        self.rhs.append(rhs)
        self.nrows += 1

    def matrix(self) -> tuple[sp.csr_matrix, np.ndarray]:
        a = sp.csr_matrix(
            (self.data, (self.rows, self.cols)),
            shape=(self.nrows, self.n_vars),
        )
        return a, np.asarray(self.rhs, dtype=np.float64)


def build_rp(
    inst: ProblemInstance,
    tmax: float | None = None,
    tmin: float | None = None,
    paper_exact_binding: bool = False,
    feasibility_only: bool = False,
) -> RPModel:
    """Assemble RP for ``inst``.

    Args:
      tmax: big-M / horizon; defaults to the §IV-A upper bound. The §IV-D
        bisection passes the shrunk ℓ here.
      tmin: lower bound on C_max; defaults to Algorithm 1.
      paper_exact_binding: use (12)/(13) verbatim instead of the tight big-M.
      feasibility_only: zero objective (the FP subproblem of §IV-D).
    """
    job: DagJob = inst.job
    n, M, m = job.n_tasks, inst.n_racks, job.n_edges
    C = inst.n_channels
    if tmax is None:
        tmax = bounds_mod.upper_bound(inst)
    if tmin is None:
        tmin = bounds_mod.lower_bound(inst)
    tmax = float(max(tmax, tmin))

    vm = VarMap(
        n=n, M=M, m=m, C=C,
        n_pairs_v=n * (n - 1) // 2,
        n_pairs_e=m * (m - 1) // 2,
    )
    q = inst.q_wired
    qw = inst.q_wireless
    r = inst.r_local

    ub_rows = _Rows(vm.n_vars)
    eq_rows = _Rows(vm.n_vars)

    def S_task(v: int, sign: float = 1.0) -> list[tuple[int, float]]:
        return [(vm.xt(v, i), sign) for i in range(M)]

    def S_edge(e: int, sign: float = 1.0) -> list[tuple[int, float]]:
        return [(vm.yt(e, k), sign) for k in range(C)]

    # (1) Σ_i x_vi = 1
    for v in range(n):
        eq_rows.add([(vm.x(v, i), 1.0) for i in range(M)], 1.0)
    # (11) Σ_k y_ek = 1
    for e in range(m):
        eq_rows.add([(vm.y(e, k), 1.0) for k in range(C)], 1.0)

    # (12)/(13) time-product bindings.
    if paper_exact_binding:
        # x̃_vi - 1 ≤ x_vi·Tmax - (1 - x_vi)·ε   ⇔   x̃ - (Tmax+ε)x ≤ 1 - ε
        for v in range(n):
            for i in range(M):
                ub_rows.add(
                    [(vm.xt(v, i), 1.0), (vm.x(v, i), -(tmax + EPS))], 1.0 - EPS
                )
        for e in range(m):
            for k in range(C):
                ub_rows.add(
                    [(vm.yt(e, k), 1.0), (vm.y(e, k), -(tmax + EPS))], 1.0 - EPS
                )
    else:
        for v in range(n):
            for i in range(M):
                ub_rows.add([(vm.xt(v, i), 1.0), (vm.x(v, i), -tmax)], 0.0)
        for e in range(m):
            for k in range(C):
                ub_rows.add([(vm.yt(e, k), 1.0), (vm.y(e, k), -tmax)], 0.0)

    # (16) ψ AND-link: 0 ≤ x_vi + x_v'i - 2ψ ≤ 1
    for v in range(n):
        for vp in range(v + 1, n):
            for i in range(M):
                xv, xvp, ps = vm.x(v, i), vm.x(vp, i), vm.psi(v, vp, i)
                ub_rows.add([(xv, 1.0), (xvp, 1.0), (ps, -2.0)], 1.0)
                ub_rows.add([(xv, -1.0), (xvp, -1.0), (ps, 2.0)], 0.0)
            # (14) Σ_i ψ ≤ 1
            ub_rows.add([(vm.psi(v, vp, i), 1.0) for i in range(M)], 1.0)

    # (17) χ AND-link over contention channels {b} ∪ K; (15) Σ_k χ ≤ 1.
    # Contention channel c-index mapping: 0 ↔ CH_WIRED, 1.. ↔ wireless 2..
    def chan_of_contend(kc: int) -> int:
        return CH_WIRED if kc == 0 else kc + 1

    for e in range(m):
        for ep in range(e + 1, m):
            for kc in range(vm.contend_channels):
                k = chan_of_contend(kc)
                ye, yep, ch = vm.y(e, k), vm.y(ep, k), vm.chi(e, ep, kc)
                ub_rows.add([(ye, 1.0), (yep, 1.0), (ch, -2.0)], 1.0)
                ub_rows.add([(ye, -1.0), (yep, -1.0), (ch, 2.0)], 0.0)
            ub_rows.add(
                [(vm.chi(e, ep, kc), 1.0) for kc in range(vm.contend_channels)],
                1.0,
            )

    # (18) σ definition: S_v' - S_v ≤ Tmax·σ - ε(1-σ)
    #   ⇔ S_v' - S_v - (Tmax+ε)σ ≤ -ε
    # (19) rack non-overlap: S_v + p_v - S_v' ≤ Tmax(2 - σ_vv' - Σψ)
    for v in range(n):
        for vp in range(n):
            if v == vp:
                continue
            ub_rows.add(
                S_task(vp) + S_task(v, -1.0) + [(vm.sigma(v, vp), -(tmax + EPS))],
                -EPS,
            )
            coeffs = (
                S_task(v)
                + S_task(vp, -1.0)
                + [(vm.sigma(v, vp), tmax)]
                + [(vm.psi(v, vp, i), tmax) for i in range(M)]
            )
            ub_rows.add(coeffs, 2.0 * tmax - float(job.p[v]))

    # (20)-(23) flow precedence + channel non-overlap.
    for e in range(m):
        for ep in range(m):
            if e == ep:
                continue
            # φ definition on total transfer starts.
            ub_rows.add(
                S_edge(ep) + S_edge(e, -1.0) + [(vm.phi(e, ep), -(tmax + EPS))],
                -EPS,
            )
            # (21) wired: S_e + q_e - S_e' ≤ Tmax(2 - φ - χ_b)
            ub_rows.add(
                S_edge(e)
                + S_edge(ep, -1.0)
                + [(vm.phi(e, ep), tmax), (vm.chi(e, ep, 0), tmax)],
                2.0 * tmax - float(q[e]),
            )
            # (23) wireless: S_e + q̌_e - S_e' ≤ Tmax(2 - φ - Σ_K χ_k)
            if vm.contend_channels > 1:
                ub_rows.add(
                    S_edge(e)
                    + S_edge(ep, -1.0)
                    + [(vm.phi(e, ep), tmax)]
                    + [
                        (vm.chi(e, ep, kc), tmax)
                        for kc in range(1, vm.contend_channels)
                    ],
                    2.0 * tmax - float(qw[e]),
                )

    # (24)-(25) precedence chaining through transfers.
    for e in range(m):
        u, v = int(job.edges[e, 0]), int(job.edges[e, 1])
        # S_u + p_u ≤ S_e
        ub_rows.add(S_task(u) + S_edge(e, -1.0), -float(job.p[u]))
        # S_e + q_e·y_eb + q̌_e·Σ_K y_ek + r_e·y_ec ≤ S_v
        coeffs = S_edge(e) + S_task(v, -1.0)
        coeffs.append((vm.y(e, CH_WIRED), float(q[e])))
        coeffs.append((vm.y(e, CH_LOCAL), float(r[e])))
        for k in range(2, C):
            coeffs.append((vm.y(e, k), float(qw[e])))
        ub_rows.add(coeffs, 0.0)
        # (26) Σ_i ψ_uvi = y_(uv),c
        eq_rows.add(
            [(vm.psi(u, v, i), 1.0) for i in range(M)]
            + [(vm.y(e, CH_LOCAL), -1.0)],
            0.0,
        )

    # C_max ≥ S_v + p_v
    for v in range(n):
        ub_rows.add(S_task(v) + [(vm.cmax(), -1.0)], -float(job.p[v]))

    # Bounds and integrality ------------------------------------------------
    lb = np.zeros(vm.n_vars)
    ub = np.ones(vm.n_vars)
    integrality = np.ones(vm.n_vars)  # 1 = integer
    for blk_off, blk_len in (
        (vm.off_xt, n * M),
        (vm.off_yt, m * C),
    ):
        ub[blk_off : blk_off + blk_len] = tmax
        integrality[blk_off : blk_off + blk_len] = 0
    lb[vm.cmax()] = tmin
    ub[vm.cmax()] = tmax
    integrality[vm.cmax()] = 0

    c = np.zeros(vm.n_vars)
    if not feasibility_only:
        c[vm.cmax()] = 1.0

    A_ub, b_ub = ub_rows.matrix()
    A_eq, b_eq = eq_rows.matrix()
    return RPModel(
        vm=vm, c=c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq,
        lb=lb, ub=ub, integrality=integrality,
        tmax=tmax, tmin=tmin, inst=inst,
    )


def extract_schedule(model: RPModel, z: np.ndarray):
    """Recover the OP decision vectors from an RP solution vector.

    s_v = Σ_i x̃_vi, s_(u,v) = Σ_k ỹ_ek (paper §IV-D); rack/channel from the
    one-hot binaries.
    """
    from repro.core.schedule import Schedule

    vm = model.vm
    n, M, m, C = vm.n, vm.M, vm.m, vm.C
    x = z[vm.off_x : vm.off_x + n * M].reshape(n, M)
    xt = z[vm.off_xt : vm.off_xt + n * M].reshape(n, M)
    y = z[vm.off_y : vm.off_y + m * C].reshape(m, C)
    yt = z[vm.off_yt : vm.off_yt + m * C].reshape(m, C)
    rack = np.argmax(x, axis=1).astype(np.int64)
    chan = np.argmax(y, axis=1).astype(np.int64) if m else np.zeros(0, np.int64)
    start = xt.sum(axis=1)
    tstart = yt.sum(axis=1) if m else np.zeros(0)
    return Schedule.build(model.inst, rack, start, chan, tstart)
