"""Baseline schedulers (paper §V, Fig. 4).

Six wired-only baselines are compared against the paper's optimal method:

  * Random Scheduling          — uniform random rack per task.
  * List Scheduling [20]       — classic ETF list scheduling; communication
                                 counted as a delay but the network treated as
                                 uncapacitated during GREEDY DECISIONS (the
                                 Rayward-Smith model); the resulting
                                 assignment is then executed under real
                                 contention by the simulator.
  * Partition Scheduling [19]  — topological chunking into load-balanced
                                 contiguous partitions, one rack each.
  * G-List Scheduling [19]     — generalized list scheduling: network
                                 transfers are first-class operations that
                                 reserve capacity on the shared wired channel
                                 (and wireless subchannels when enabled).
  * G-List-Master [19]         — G-List restricted to predecessor racks plus
                                 the least-loaded fresh rack (data-locality /
                                 "master" placement flavor).
  * Optimal (wired only)       — the paper's own solver with K = ∅.

All baselines return feasibility-checked Schedules. Exact pseudo-code for the
[19] heuristics is not public; implementations follow the descriptions above
and are documented as interpretations in DESIGN.md.
"""

from __future__ import annotations

import numpy as np

from repro.core.instance import CH_WIRED, ProblemInstance
from repro.core.schedule import Schedule
from repro.core.simulator import (
    _Timeline,
    critical_path_priority,
    seed_channel_timelines,
    simulate,
)

__all__ = [
    "single_rack_schedule",
    "random_schedule",
    "list_schedule",
    "partition_schedule",
    "g_list_schedule",
    "g_list_master_schedule",
    "fifo_solo_schedule",
    "edf_solo_schedule",
    "greedy_list_online_schedule",
    "wired_only",
    "BASELINES",
    "ONLINE_BASELINES",
]


def wired_only(inst: ProblemInstance) -> ProblemInstance:
    """Drop wireless resources (the paper's wired-only optimal)."""
    return ProblemInstance(
        job=inst.job,
        n_racks=inst.n_racks,
        n_wireless=0,
        wired_rate=inst.wired_rate,
        wireless_rate=inst.wireless_rate,
        local_delay=inst.local_delay,
    )


def single_rack_schedule(inst: ProblemInstance) -> Schedule:
    """All tasks on rack 0 — attains the §IV-A upper bound T_max."""
    rack = np.zeros(inst.job.n_tasks, dtype=np.int64)
    return simulate(inst, rack, use_wireless=False)


def random_schedule(
    inst: ProblemInstance, rng: np.random.Generator, use_wireless: bool = False
) -> Schedule:
    rack = rng.integers(0, inst.n_racks, size=inst.job.n_tasks)
    return simulate(inst, rack, use_wireless=use_wireless)


def list_schedule(
    inst: ProblemInstance,
    use_wireless: bool = False,
    channel_busy: dict | None = None,
) -> Schedule:
    """ETF list scheduling with uncapacitated-network estimates [20].

    Greedy pass chooses racks assuming transfers never contend; the final
    schedule is produced by the contention-aware simulator on that
    assignment. ``channel_busy`` (the simulator's replay hook) lets the
    online service hand over pre-existing busy intervals of the shared
    physical channels, so the executed schedule gap-inserts around other
    jobs' committed transfers.
    """
    job = inst.job
    n = job.n_tasks
    prio = critical_path_priority(inst, pessimistic=True)
    order = np.argsort(-prio, kind="stable")

    rack = np.full(n, -1, dtype=np.int64)
    finish = np.zeros(n)
    rack_free = np.zeros(inst.n_racks)
    q = inst.q_wired
    r = inst.r_local

    # Process tasks in priority order, but only when predecessors are placed
    # (argsort of downstream-path priority is precedence-compatible for DAGs
    # with positive processing times; assert to be safe).
    placed = np.zeros(n, dtype=bool)
    for v in order:
        v = int(v)
        for e in job.in_edges(v):
            assert placed[int(job.edges[e, 0])], "priority order not topological"
        best = None
        for i in range(inst.n_racks):
            arrival = 0.0
            for e in job.in_edges(v):
                u = int(job.edges[e, 0])
                delay = r[e] if rack[u] == i else q[e]
                arrival = max(arrival, finish[u] + delay)
            s = max(arrival, rack_free[i])
            key = (s + job.p[v], s, i)
            if best is None or key < best:
                best = key
        assert best is not None
        _, s, i = best
        rack[v] = i
        finish[v] = s + job.p[v]
        rack_free[i] = finish[v]
        placed[v] = True
    return simulate(
        inst, rack, use_wireless=use_wireless, channel_busy=channel_busy
    )


def partition_schedule(inst: ProblemInstance, use_wireless: bool = False) -> Schedule:
    """Topological chunking into ≤M load-balanced contiguous partitions [19]."""
    job = inst.job
    topo = job.topo_order()
    total = float(np.sum(job.p))
    n_parts = min(inst.n_racks, max(1, job.n_tasks))
    target = total / n_parts
    rack = np.zeros(job.n_tasks, dtype=np.int64)
    acc, part = 0.0, 0
    for v in topo:
        rack[int(v)] = part
        acc += float(job.p[int(v)])
        if acc >= target * (part + 1) and part < n_parts - 1:
            part += 1
    return simulate(inst, rack, use_wireless=use_wireless)


def _g_list(
    inst: ProblemInstance,
    use_wireless: bool,
    candidate_racks,
    channel_busy: dict | None = None,
) -> Schedule:
    """Shared engine for G-List variants: contention-aware greedy placement.

    ``candidate_racks(v, rack, load)`` yields the rack ids considered for v.
    ``channel_busy`` seeds the channel timelines with pre-existing busy
    intervals (other jobs' committed transfers, in this instance's time
    frame), so both the greedy channel choices and the final placement
    respect cross-job contention on the shared physical channels.
    """
    job = inst.job
    n, m = job.n_tasks, job.n_edges
    prio = critical_path_priority(inst, pessimistic=True)
    order = np.argsort(-prio, kind="stable")

    rack = np.full(n, -1, dtype=np.int64)
    chan = np.full(m, -1, dtype=np.int64)
    rack_tl = [_Timeline() for _ in range(inst.n_racks)]
    chan_ids = [CH_WIRED] + ([2 + k for k in range(inst.n_wireless)] if use_wireless else [])
    # Wireless subchannel 2+k is a candidate for a cross-rack edge only when
    # both endpoint racks reach k; wired (always reachable) backstops every
    # pair, so the candidate list below is never empty.
    reach = None if inst.topology is None else inst.topology.reach
    chan_tl = {c: _Timeline() for c in chan_ids}
    # Non-strict: channels this variant does not place on (e.g. wireless
    # under use_wireless=False) cannot conflict, so their intervals are
    # irrelevant rather than an error.
    seed_channel_timelines(chan_tl, channel_busy, strict=False)
    dur = inst.durations_matrix()
    start = np.zeros(n)
    finish = np.zeros(n)
    tstart = np.zeros(m)

    for v in order:
        v = int(v)
        in_es = [int(e) for e in job.in_edges(v)]
        best = None
        for i in candidate_racks(v, rack, finish):
            # Tentative: earliest arrival of all inputs if v runs on rack i.
            # Channel picks must see each other, so reserve into scratch
            # copies of the channel timelines during evaluation.
            scratch = {c: list(chan_tl[c].busy) for c in chan_ids}
            arrival = 0.0
            picks: list[tuple[int, int, float]] = []  # (edge, channel, start)
            for e in in_es:
                u = int(job.edges[e, 0])
                if rack[u] == i:
                    picks.append((e, 1, finish[u]))  # CH_LOCAL
                    arrival = max(arrival, finish[u] + dur[e, 1])
                else:
                    cbest = None
                    for c in chan_ids:
                        if (
                            reach is not None
                            and c >= 2
                            and not (reach[rack[u], c - 2] and reach[i, c - 2])
                        ):
                            continue
                        tl = _Timeline()
                        tl.busy = scratch[c]
                        s = tl.earliest_fit(finish[u], float(dur[e, c]))
                        k = (s + float(dur[e, c]), s, c)
                        if cbest is None or k < cbest:
                            cbest = k
                    assert cbest is not None
                    fin, s, c = cbest
                    picks.append((e, c, s))
                    scratch[c] = sorted(scratch[c] + [(s, fin)])
                    arrival = max(arrival, fin)
            s_v = rack_tl[i].earliest_fit(arrival, float(job.p[v]))
            key = (s_v + float(job.p[v]), s_v, i)
            if best is None or key < best[0]:
                best = (key, i, picks, s_v)
        assert best is not None
        _, i, picks, s_v = best
        rack[v] = i
        for e, c, s in picks:
            chan[e] = c
            tstart[e] = s
            if c != 1:  # local channel has no capacity
                chan_tl[c].insert(s, float(dur[e, c]))
        rack_tl[i].insert(s_v, float(job.p[v]))
        start[v] = s_v
        finish[v] = s_v + float(job.p[v])

    sched = Schedule.build(inst, rack, start, chan, tstart)
    from repro.core.schedule import check_feasible

    check_feasible(inst, sched)
    return sched


def g_list_schedule(
    inst: ProblemInstance,
    use_wireless: bool = False,
    channel_busy: dict | None = None,
) -> Schedule:
    return _g_list(
        inst,
        use_wireless,
        lambda v, rack, fin: range(inst.n_racks),
        channel_busy=channel_busy,
    )


def g_list_master_schedule(
    inst: ProblemInstance, use_wireless: bool = False
) -> Schedule:
    """G-List restricted to predecessor racks + one fresh least-used rack."""
    job = inst.job

    def candidates(v: int, rack: np.ndarray, finish: np.ndarray):
        preds = {int(rack[int(job.edges[e, 0])]) for e in job.in_edges(v)}
        preds.discard(-1)
        used = set(int(x) for x in rack if x >= 0)
        fresh = [i for i in range(inst.n_racks) if i not in used]
        cands = sorted(preds) + (fresh[:1] if fresh else [])
        if not cands:
            cands = [0]
        return cands

    return _g_list(inst, use_wireless, candidates)


BASELINES = {
    "random": random_schedule,
    "list": list_schedule,
    "partition": partition_schedule,
    "g_list": g_list_schedule,
    "g_list_master": g_list_master_schedule,
}


# ---------------------------------------------------------------------------
# Online (arrival-driven) baselines
# ---------------------------------------------------------------------------
#
# The online serving layer (:mod:`repro.online.service`) schedules each
# admitted job with a per-job policy function ``(inst, use_wireless) ->
# Schedule``. The two entries below are the classic online comparison
# points for the arrival-driven benchmarks; ``"fleet"`` (the mega-batch
# search engine with warm-started re-optimization) is the policy under
# test and lives in the service itself.


def fifo_solo_schedule(
    inst: ProblemInstance,
    use_wireless: bool = True,
    channel_busy: dict | None = None,
) -> Schedule:
    """Per-job scheduler of the online *FIFO-solo* baseline.

    FIFO-solo serves jobs strictly one at a time in arrival order, each
    getting the whole cluster to itself (the service enforces the solo
    admission rule — whole cluster idle, head-of-line job only); the
    per-job schedule is ETF list scheduling executed under real
    contention. JCT is then dominated by head-of-line queueing, which is
    what the batched fleet policy is measured against. ``channel_busy``
    is accepted for signature uniformity with the other online baselines
    (the service commits every policy through the same channel-feasible
    arbitration path); under the solo rule the cluster is idle at
    admission, so it is always empty.
    """
    return list_schedule(
        inst, use_wireless=use_wireless, channel_busy=channel_busy
    )


def edf_solo_schedule(
    inst: ProblemInstance,
    use_wireless: bool = True,
    channel_busy: dict | None = None,
) -> Schedule:
    """Per-job scheduler of the online *EDF-solo* baseline.

    The deadline-aware twin of :func:`fifo_solo_schedule`: identical
    per-job placement (critical-path list scheduling on the idle
    cluster), but the service orders its solo queue earliest-deadline
    first instead of by arrival (``OnlineScheduler(policy="edf_solo")``
    implies ``admission="edf"``). Keeping the placement bit-identical to
    FIFO-solo makes the pair an apples-to-apples measurement of the
    *admission order* alone — any deadline-miss delta between them is
    attributable to EDF, not to solver quality.
    """
    return list_schedule(
        inst, use_wireless=use_wireless, channel_busy=channel_busy
    )


def greedy_list_online_schedule(
    inst: ProblemInstance,
    use_wireless: bool = True,
    channel_busy: dict | None = None,
) -> Schedule:
    """Per-job scheduler of the online *greedy-list* baseline.

    Greedy-list admits jobs onto residual capacity exactly like the fleet
    policy (same windows, same residual instances, same channel-feasible
    arbitrated commits) but places each job with the contention-aware
    G-List heuristic instead of searching — no candidate batches, no warm
    starts. ``channel_busy`` carries the busy intervals already committed
    on the job's physical channels, so the heuristic's channel choices
    see cross-job contention too. It isolates the value of the search
    engine from the value of the admission machinery.
    """
    return g_list_schedule(
        inst, use_wireless=use_wireless, channel_busy=channel_busy
    )


ONLINE_BASELINES = {
    "fifo_solo": fifo_solo_schedule,
    "edf_solo": edf_solo_schedule,
    "greedy_list": greedy_list_online_schedule,
}
