"""Discrete-event schedule executor (serial schedule generation with gap
insertion).

Given the two discrete decision vectors of the joint problem — task->rack and
edge->channel — this module derives start times greedily and returns a
complete, feasibility-checked :class:`Schedule`. It is the execution
substrate shared by all heuristic baselines, the vectorized solver's
incumbent generation, and the test oracle that re-executes MILP decisions.

Semantics follow OP exactly: racks are unary resources for computation,
channel ``b`` and each wireless subchannel are unary resources for transfers,
the virtual local channel ``c`` has infinite capacity, and an operation placed
into a timeline occupies a half-open interval [start, start+dur).
"""

from __future__ import annotations

import heapq

import numpy as np

from repro.core.instance import CH_LOCAL, CH_WIRED, ProblemInstance
from repro.core.schedule import Schedule

__all__ = ["simulate", "critical_path_priority", "AUTO_CHANNEL"]

AUTO_CHANNEL = -1


class _Timeline:
    """Sorted busy intervals of a unary resource with gap search."""

    __slots__ = ("busy",)

    def __init__(self) -> None:
        self.busy: list[tuple[float, float]] = []

    def earliest_fit(self, ready: float, dur: float) -> float:
        t = ready
        for s, e in self.busy:
            if t + dur <= s:
                break
            if e > t:
                t = e
        return t

    def insert(self, start: float, dur: float) -> None:
        self.busy.append((start, start + dur))
        self.busy.sort()


def critical_path_priority(inst: ProblemInstance, pessimistic: bool = False) -> np.ndarray:
    """Task priority = longest downstream path (larger = more critical).

    ``pessimistic`` uses wired transfer times on edges (assume remote);
    otherwise local delays (assume co-located), matching Algorithm 1's cost.
    """
    job = inst.job
    cost = inst.q_wired if pessimistic else inst.r_local
    tail = job.p.astype(np.float64).copy()
    topo = job.topo_order()
    out_by_node: list[list[int]] = [[] for _ in range(job.n_tasks)]
    for e in range(job.n_edges):
        out_by_node[int(job.edges[e, 0])].append(e)
    for v in reversed(topo):
        best = 0.0
        for e in out_by_node[int(v)]:
            w = int(job.edges[e, 1])
            cand = cost[e] + tail[w]
            if cand > best:
                best = cand
        tail[int(v)] = job.p[int(v)] + best
    return tail


def simulate(
    inst: ProblemInstance,
    rack: np.ndarray,
    chan: np.ndarray | None = None,
    priority: np.ndarray | None = None,
    use_wireless: bool = True,
    check: bool = True,
) -> Schedule:
    """Serial schedule generation.

    Args:
      rack: int[n_tasks] rack per task.
      chan: int[n_edges] channel per edge; entries may be AUTO_CHANNEL (-1) to
        let the simulator pick the earliest-finishing permitted channel at
        schedule time. Same-rack edges are always forced to CH_LOCAL, and
        cross-rack edges must not be CH_LOCAL. ``None`` = all AUTO.
      priority: float[n_tasks]; higher = scheduled earlier among ready ops.
        Defaults to critical-path priority.
      use_wireless: when False, AUTO channels may only pick the wired channel
        (the paper's wired-only baselines).
      check: run the OP feasibility checker on the result.

    Returns a complete Schedule.
    """
    job = inst.job
    n, m = job.n_tasks, job.n_edges
    rack = np.asarray(rack, dtype=np.int64)
    if chan is None:
        chan_in = np.full(m, AUTO_CHANNEL, dtype=np.int64)
    else:
        chan_in = np.asarray(chan, dtype=np.int64).copy()
    if priority is None:
        priority = critical_path_priority(inst)

    dur_matrix = inst.durations_matrix()

    # Resolve forced channels from locality.
    same = rack[job.edges[:, 0]] == rack[job.edges[:, 1]] if m else np.zeros(0, bool)
    for e in range(m):
        if same[e]:
            chan_in[e] = CH_LOCAL
        elif chan_in[e] == CH_LOCAL:
            raise ValueError(f"edge {e} is cross-rack but assigned local channel")

    rack_tl = [_Timeline() for _ in range(inst.n_racks)]
    chan_tl = {CH_WIRED: _Timeline()}
    for k in range(inst.n_wireless):
        chan_tl[2 + k] = _Timeline()

    start = np.full(n, -1.0)
    finish_task = np.full(n, np.inf)
    tstart = np.full(m, -1.0)
    finish_edge = np.full(m, np.inf)
    chan_out = chan_in.copy()

    # Dependency bookkeeping: task v waits on all in-edges; edge e waits on
    # its source task.
    n_wait_task = np.zeros(n, dtype=np.int64)
    for e in range(m):
        n_wait_task[int(job.edges[e, 1])] += 1

    # Ready heaps keyed by (-priority, index). Edge priority inherits the
    # priority of its destination task (it gates that task).
    ready: list[tuple[float, int, str, int]] = []
    seq = 0

    def push_task(v: int) -> None:
        nonlocal seq
        heapq.heappush(ready, (-float(priority[v]), seq, "T", v))
        seq += 1

    def push_edge(e: int) -> None:
        nonlocal seq
        v = int(job.edges[e, 1])
        heapq.heappush(ready, (-float(priority[v]), seq, "E", e))
        seq += 1

    for v in range(n):
        if n_wait_task[v] == 0:
            push_task(v)

    scheduled = 0
    total_ops = n + m
    while scheduled < total_ops:
        if not ready:
            raise RuntimeError("deadlock: no ready operations (cycle?)")
        _, _, kind, idx = heapq.heappop(ready)
        if kind == "T":
            v = idx
            ready_t = 0.0
            for e in np.nonzero(job.edges[:, 1] == v)[0]:
                ready_t = max(ready_t, finish_edge[int(e)])
            tl = rack_tl[int(rack[v])]
            s = tl.earliest_fit(ready_t, float(job.p[v]))
            tl.insert(s, float(job.p[v]))
            start[v] = s
            finish_task[v] = s + float(job.p[v])
            # Out-edges become ready.
            for e in np.nonzero(job.edges[:, 0] == v)[0]:
                push_edge(int(e))
            scheduled += 1
        else:
            e = idx
            u, v = int(job.edges[e, 0]), int(job.edges[e, 1])
            ready_t = finish_task[u]
            c = int(chan_out[e])
            if c == AUTO_CHANNEL:
                # Earliest-finish channel among permitted ones.
                cands = [CH_WIRED]
                if use_wireless:
                    cands += [2 + k for k in range(inst.n_wireless)]
                best = None
                for cc in cands:
                    d = float(dur_matrix[e, cc])
                    s = chan_tl[cc].earliest_fit(ready_t, d)
                    key = (s + d, s, cc)
                    if best is None or key < best[0]:
                        best = (key, cc, s, d)
                assert best is not None
                _, c, s, d = best
                chan_out[e] = c
                chan_tl[c].insert(s, d)
            elif c == CH_LOCAL:
                d = float(dur_matrix[e, CH_LOCAL])
                s = ready_t
            else:
                d = float(dur_matrix[e, c])
                s = chan_tl[c].earliest_fit(ready_t, d)
                chan_tl[c].insert(s, d)
            tstart[e] = s
            finish_edge[e] = s + d
            n_wait_task[v] -= 1
            if n_wait_task[v] == 0:
                push_task(v)
            scheduled += 1

    sched = Schedule.build(inst, rack, start, chan_out, tstart)
    if check:
        from repro.core.schedule import check_feasible

        check_feasible(inst, sched)
    return sched
