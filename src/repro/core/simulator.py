"""Discrete-event schedule executor (serial schedule generation with gap
insertion).

Given the two discrete decision vectors of the joint problem — task->rack and
edge->channel — this module derives start times greedily and returns a
complete, feasibility-checked :class:`Schedule`. It is the execution
substrate shared by all heuristic baselines, the vectorized solver's
incumbent generation, and the test oracle that re-executes MILP decisions.

Semantics follow OP exactly: racks are unary resources for computation,
channel ``b`` and each wireless subchannel are unary resources for transfers,
the virtual local channel ``c`` has infinite capacity, and an operation placed
into a timeline occupies a half-open interval [start, start+dur).
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.instance import CH_LOCAL, CH_WIRED, ProblemInstance
from repro.core.schedule import Schedule

__all__ = [
    "simulate",
    "seed_channel_timelines",
    "critical_path_priority",
    "build_op_tables",
    "pad_op_tables",
    "OpTables",
    "PaddedOpTables",
    "AUTO_CHANNEL",
    "OP_TASK",
    "OP_EDGE",
    "OP_PAD",
]

AUTO_CHANNEL = -1

# Operation kinds in the static op table. OP_PAD marks no-op rows appended by
# consumers that pad the table to a fixed size bucket (the vectorized engine).
OP_TASK = 0
OP_EDGE = 1
OP_PAD = 2


@dataclasses.dataclass(frozen=True)
class OpTables:
    """Static, precedence-compatible operation tables for one instance.

    The shared substrate between the host simulator and the vectorized batch
    evaluator: both walk the same interleaved (edge*, task) sequence in
    topological order, and both resolve task readiness through the same
    padded in-edge table instead of scanning the edge list per event.

    Attributes:
      kind: int32[n_ops] OP_TASK / OP_EDGE rows, n_ops = n_tasks + n_edges.
      idx: int32[n_ops] task id for OP_TASK rows, edge id for OP_EDGE rows.
      edge_src / edge_dst: int32[n_edges] endpoints (copies of job.edges cols).
      task_in_edges: int32[n_tasks, max_indeg] edge ids entering each task,
        right-padded with -1 (max_indeg >= 1 always).
      task_out_edges: int32[n_tasks, max_outdeg] edge ids leaving each task,
        right-padded with -1 (max_outdeg >= 1 always).
    """

    kind: np.ndarray
    idx: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    task_in_edges: np.ndarray
    task_out_edges: np.ndarray

    @property
    def n_ops(self) -> int:
        return int(self.kind.shape[0])


def build_op_tables(inst: ProblemInstance) -> OpTables:
    """Build the static op tables for ``inst`` (topo order: in-edges, then task)."""
    job = inst.job
    n, m = job.n_tasks, job.n_edges
    in_lists: list[list[int]] = [[] for _ in range(n)]
    out_lists: list[list[int]] = [[] for _ in range(n)]
    for e in range(m):
        out_lists[int(job.edges[e, 0])].append(e)
        in_lists[int(job.edges[e, 1])].append(e)

    kind: list[int] = []
    idx: list[int] = []
    for v in job.topo_order():
        for e in in_lists[int(v)]:
            kind.append(OP_EDGE)
            idx.append(e)
        kind.append(OP_TASK)
        idx.append(int(v))

    def pad_table(lists: list[list[int]]) -> np.ndarray:
        width = max(1, max((len(l) for l in lists), default=0))
        out = np.full((n, width), -1, dtype=np.int32)
        for v, l in enumerate(lists):
            out[v, : len(l)] = l
        return out

    return OpTables(
        kind=np.asarray(kind, dtype=np.int32),
        idx=np.asarray(idx, dtype=np.int32),
        edge_src=job.edges[:, 0].astype(np.int32),
        edge_dst=job.edges[:, 1].astype(np.int32),
        task_in_edges=pad_table(in_lists),
        task_out_edges=pad_table(out_lists),
    )


@dataclasses.dataclass(frozen=True)
class PaddedOpTables:
    """Device-layout op tables padded to a caller-chosen size bucket.

    The SINGLE op-table layout shared by every batched evaluator: each row
    of the interleaved (edge*, task) sequence is flattened into parallel
    scalar columns so a ``lax.scan`` can walk it, and all columns are padded
    with OP_PAD no-op rows up to ``n_ops``. Instances of a heterogeneous
    fleet are padded to the SAME dims and stacked on a leading instance
    axis, so one compiled mega-batch program serves them all.

    Attributes:
      kind: int32[n_ops] OP_TASK / OP_EDGE / OP_PAD.
      op_task: int32[n_ops] task id on OP_TASK rows (0 otherwise).
      op_edge: int32[n_ops] edge id on OP_EDGE rows (0 otherwise).
      op_src / op_dst: int32[n_ops] edge endpoints on OP_EDGE rows.
      op_p: float32[n_ops] task duration on OP_TASK rows.
      op_wired / op_wireless / op_local: float32[n_ops] edge transfer
        durations on OP_EDGE rows (q, q̌, r of §II).
      op_in: int32[n_ops, indeg_pad] in-edge ids gating an OP_TASK row,
        right-padded with ``edge_sentinel`` (an always-zero slot the
        evaluator reserves past its edge-finish table).
    """

    kind: np.ndarray
    op_task: np.ndarray
    op_edge: np.ndarray
    op_src: np.ndarray
    op_dst: np.ndarray
    op_p: np.ndarray
    op_wired: np.ndarray
    op_wireless: np.ndarray
    op_local: np.ndarray
    op_in: np.ndarray


def pad_op_tables(
    inst: ProblemInstance,
    *,
    n_ops: int,
    indeg_pad: int,
    edge_sentinel: int,
    tables: OpTables | None = None,
) -> PaddedOpTables:
    """Pad ``build_op_tables(inst)`` into the flat device layout above.

    ``n_ops`` and ``indeg_pad`` must be at least the instance's true op
    count / max in-degree (callers pass the fleet-wide size bucket).
    ``tables`` lets callers that already built the instance's op tables
    (e.g. while sizing the fleet bucket) skip rebuilding them.
    """
    job = inst.job
    if tables is None:
        tables = build_op_tables(inst)
    if n_ops < tables.n_ops or indeg_pad < tables.task_in_edges.shape[1]:
        raise ValueError("padded dims smaller than the instance's op tables")

    kind = np.full(n_ops, OP_PAD, dtype=np.int32)
    op_task = np.zeros(n_ops, dtype=np.int32)
    op_edge = np.zeros(n_ops, dtype=np.int32)
    op_src = np.zeros(n_ops, dtype=np.int32)
    op_dst = np.zeros(n_ops, dtype=np.int32)
    op_p = np.zeros(n_ops, dtype=np.float32)
    op_wired = np.zeros(n_ops, dtype=np.float32)
    op_wireless = np.zeros(n_ops, dtype=np.float32)
    op_local = np.zeros(n_ops, dtype=np.float32)
    op_in = np.full((n_ops, indeg_pad), edge_sentinel, dtype=np.int32)

    q, qw, r = inst.q_wired, inst.q_wireless, inst.r_local
    for row in range(tables.n_ops):
        k, i = int(tables.kind[row]), int(tables.idx[row])
        kind[row] = k
        if k == OP_TASK:
            op_task[row] = i
            op_p[row] = job.p[i]
            ins = tables.task_in_edges[i]
            ins = ins[ins >= 0]
            op_in[row, : ins.size] = ins
        else:
            op_edge[row] = i
            op_src[row] = tables.edge_src[i]
            op_dst[row] = tables.edge_dst[i]
            op_wired[row] = q[i]
            op_wireless[row] = qw[i]
            op_local[row] = r[i]

    return PaddedOpTables(
        kind=kind,
        op_task=op_task,
        op_edge=op_edge,
        op_src=op_src,
        op_dst=op_dst,
        op_p=op_p,
        op_wired=op_wired,
        op_wireless=op_wireless,
        op_local=op_local,
        op_in=op_in,
    )


def seed_channel_timelines(
    chan_tl: dict, channel_busy: dict | None, *, strict: bool = True
) -> None:
    """Seed capacitated-channel timelines with pre-existing busy intervals.

    The single normalization point for the ``channel_busy`` replay hook
    (shared by :func:`simulate` and the busy-aware heuristic baselines):
    intervals are sorted and empty/inverted ones dropped. ``strict=True``
    rejects a channel id the caller's timeline set does not model;
    ``strict=False`` ignores it (a scheduler that never places transfers
    on that channel cannot conflict with it).
    """
    if not channel_busy:
        return
    for c, intervals in channel_busy.items():
        if c not in chan_tl:
            if strict:
                raise ValueError(
                    f"channel_busy for channel {c} not in this instance "
                    f"(capacitated channels: {sorted(chan_tl)})"
                )
            continue
        chan_tl[c].busy = sorted(
            (float(s), float(e)) for s, e in intervals if float(e) > float(s)
        )


class _Timeline:
    """Sorted busy intervals of a unary resource with gap search."""

    __slots__ = ("busy",)

    def __init__(self) -> None:
        self.busy: list[tuple[float, float]] = []

    def earliest_fit(self, ready: float, dur: float) -> float:
        t = ready
        for s, e in self.busy:
            if t + dur <= s:
                break
            if e > t:
                t = e
        return t

    def insert(self, start: float, dur: float) -> None:
        self.busy.append((start, start + dur))
        self.busy.sort()


def critical_path_priority(inst: ProblemInstance, pessimistic: bool = False) -> np.ndarray:
    """Task priority = longest downstream path (larger = more critical).

    ``pessimistic`` uses wired transfer times on edges (assume remote);
    otherwise local delays (assume co-located), matching Algorithm 1's cost.
    """
    job = inst.job
    cost = inst.q_wired if pessimistic else inst.r_local
    tail = job.p.astype(np.float64).copy()
    topo = job.topo_order()
    out_by_node: list[list[int]] = [[] for _ in range(job.n_tasks)]
    for e in range(job.n_edges):
        out_by_node[int(job.edges[e, 0])].append(e)
    for v in reversed(topo):
        best = 0.0
        for e in out_by_node[int(v)]:
            w = int(job.edges[e, 1])
            cand = cost[e] + tail[w]
            if cand > best:
                best = cand
        tail[int(v)] = job.p[int(v)] + best
    return tail


def simulate(
    inst: ProblemInstance,
    rack: np.ndarray,
    chan: np.ndarray | None = None,
    priority: np.ndarray | None = None,
    use_wireless: bool = True,
    check: bool = True,
    channel_busy: dict | None = None,
) -> Schedule:
    """Serial schedule generation.

    Args:
      rack: int[n_tasks] rack per task.
      chan: int[n_edges] channel per edge; entries may be AUTO_CHANNEL (-1) to
        let the simulator pick the earliest-finishing permitted channel at
        schedule time. Same-rack edges are always forced to CH_LOCAL, and
        cross-rack edges must not be CH_LOCAL. ``None`` = all AUTO.
      priority: float[n_tasks]; higher = scheduled earlier among ready ops.
        Defaults to critical-path priority.
      use_wireless: when False, AUTO channels may only pick the wired channel
        (the paper's wired-only baselines).
      check: run the OP feasibility checker on the result.
      channel_busy: optional offset-respecting replay hook — a mapping from
        channel id (CH_WIRED or 2+k) to pre-existing busy intervals
        ``[(start, end), ...]`` in this instance's time frame. Transfers are
        gap-inserted around them exactly like around the job's own transfers,
        so a schedule committed onto a shared cluster can be re-derived with
        cross-job channel offsets while keeping the rack and channel decision
        vectors fixed. Intervals may start before time 0 (a transfer of
        another job straddling the replay origin). With no busy intervals and
        a fixed ``chan`` equal to a previous run's resolved channels, the
        replay reproduces that run bit-for-bit.

    Returns a complete Schedule.
    """
    job = inst.job
    n, m = job.n_tasks, job.n_edges
    rack = np.asarray(rack, dtype=np.int64)
    if chan is None:
        chan_in = np.full(m, AUTO_CHANNEL, dtype=np.int64)
    else:
        chan_in = np.asarray(chan, dtype=np.int64).copy()
    if priority is None:
        priority = critical_path_priority(inst)

    dur_matrix = inst.durations_matrix()
    tables = build_op_tables(inst)
    # Reachability gating: with a restricted topology a cross-rack edge may
    # only use subchannels BOTH endpoint racks reach (None = all-ones mask,
    # the paper's model — the loop below is untouched).
    reach = None if inst.topology is None else inst.topology.reach

    # Resolve forced channels from locality.
    same = rack[job.edges[:, 0]] == rack[job.edges[:, 1]] if m else np.zeros(0, bool)
    for e in range(m):
        if same[e]:
            chan_in[e] = CH_LOCAL
        elif chan_in[e] == CH_LOCAL:
            raise ValueError(f"edge {e} is cross-rack but assigned local channel")

    rack_tl = [_Timeline() for _ in range(inst.n_racks)]
    chan_tl = {CH_WIRED: _Timeline()}
    for k in range(inst.n_wireless):
        chan_tl[2 + k] = _Timeline()
    seed_channel_timelines(chan_tl, channel_busy)

    start = np.full(n, -1.0)
    finish_task = np.full(n, np.inf)
    tstart = np.full(m, -1.0)
    finish_edge = np.full(m, np.inf)
    chan_out = chan_in.copy()

    # Dependency bookkeeping: task v waits on all in-edges; edge e waits on
    # its source task.
    n_wait_task = (tables.task_in_edges >= 0).sum(axis=1).astype(np.int64)

    # Ready heaps keyed by (-priority, index). Edge priority inherits the
    # priority of its destination task (it gates that task).
    ready: list[tuple[float, int, str, int]] = []
    seq = 0

    def push_task(v: int) -> None:
        nonlocal seq
        heapq.heappush(ready, (-float(priority[v]), seq, "T", v))
        seq += 1

    def push_edge(e: int) -> None:
        nonlocal seq
        v = int(job.edges[e, 1])
        heapq.heappush(ready, (-float(priority[v]), seq, "E", e))
        seq += 1

    for v in range(n):
        if n_wait_task[v] == 0:
            push_task(v)

    scheduled = 0
    total_ops = n + m
    while scheduled < total_ops:
        if not ready:
            raise RuntimeError("deadlock: no ready operations (cycle?)")
        _, _, kind, idx = heapq.heappop(ready)
        if kind == "T":
            v = idx
            ready_t = 0.0
            for e in tables.task_in_edges[v]:
                if e < 0:
                    break
                ready_t = max(ready_t, finish_edge[int(e)])
            tl = rack_tl[int(rack[v])]
            s = tl.earliest_fit(ready_t, float(job.p[v]))
            tl.insert(s, float(job.p[v]))
            start[v] = s
            finish_task[v] = s + float(job.p[v])
            # Out-edges become ready.
            for e in tables.task_out_edges[v]:
                if e < 0:
                    break
                push_edge(int(e))
            scheduled += 1
        else:
            e = idx
            u, v = int(job.edges[e, 0]), int(job.edges[e, 1])
            ready_t = finish_task[u]
            c = int(chan_out[e])
            if c == AUTO_CHANNEL:
                # Earliest-finish channel among permitted ones.
                cands = [CH_WIRED]
                if use_wireless:
                    if reach is None:
                        cands += [2 + k for k in range(inst.n_wireless)]
                    else:
                        ru, rv = int(rack[u]), int(rack[v])
                        cands += [
                            2 + k
                            for k in range(inst.n_wireless)
                            if reach[ru, k] and reach[rv, k]
                        ]
                best = None
                for cc in cands:
                    d = float(dur_matrix[e, cc])
                    s = chan_tl[cc].earliest_fit(ready_t, d)
                    key = (s + d, s, cc)
                    if best is None or key < best[0]:
                        best = (key, cc, s, d)
                assert best is not None
                _, c, s, d = best
                chan_out[e] = c
                chan_tl[c].insert(s, d)
            elif c == CH_LOCAL:
                d = float(dur_matrix[e, CH_LOCAL])
                s = ready_t
            else:
                if reach is not None and c >= 2:
                    ru, rv = int(rack[u]), int(rack[v])
                    if not (reach[ru, c - 2] and reach[rv, c - 2]):
                        raise ValueError(
                            f"edge {e} assigned subchannel {c - 2} "
                            f"unreachable from racks ({ru}, {rv})"
                        )
                d = float(dur_matrix[e, c])
                s = chan_tl[c].earliest_fit(ready_t, d)
                chan_tl[c].insert(s, d)
            tstart[e] = s
            finish_edge[e] = s + d
            n_wait_task[v] -= 1
            if n_wait_task[v] == 0:
                push_task(v)
            scheduled += 1

    sched = Schedule.build(inst, rack, start, chan_out, tstart)
    if check:
        from repro.core.schedule import check_feasible

        check_feasible(inst, sched)
    return sched
