"""Heuristic bounds on the optimal job completion time (paper §IV-A).

Upper bound T_max: run the whole job on one rack in topological order; all
transfers are local. T_max = sum_v p_v + sum_e r_e.

Lower bound T_min: Algorithm 1 ("The Longest Branch Algorithm") — convert
node costs to out-edge costs c_(u,v) = p_u + r_(u,v), then longest path by
dynamic programming over a topological order; T_min = max_v dist(v) + p_v.

The paper's Algorithm 1 uses the LOCAL delay r as the per-edge transfer cost,
which is a valid lower bound whenever local transfer is never slower than a
network transfer (true in the paper's experiments where r = 0). ``safe=True``
instead uses min(r_e, q_e, q̌_e), which is a valid bound for arbitrary rates.
"""

from __future__ import annotations

import numpy as np

from repro.core.instance import ProblemInstance

__all__ = ["upper_bound", "lower_bound", "longest_branch", "critical_path_dist"]


def upper_bound(inst: ProblemInstance) -> float:
    """T_max = Σ p_v + Σ r_(u,v): single-rack topological execution."""
    return float(np.sum(inst.job.p) + np.sum(inst.r_local))


def critical_path_dist(
    n: int,
    edges: np.ndarray,
    p: np.ndarray,
    edge_cost: np.ndarray,
    topo: np.ndarray,
) -> np.ndarray:
    """dist(v): longest path from any source to v, where traversing edge
    (u, v) costs p_u + edge_cost_e (Algorithm 1 lines 4-8)."""
    dist = np.zeros(n, dtype=np.float64)
    in_by_node: list[list[int]] = [[] for _ in range(n)]
    for e in range(edges.shape[0]):
        in_by_node[int(edges[e, 1])].append(e)
    for v in topo:
        best = 0.0
        for e in in_by_node[int(v)]:
            u = int(edges[e, 0])
            cand = dist[u] + p[u] + edge_cost[e]
            if cand > best:
                best = cand
        dist[int(v)] = best
    return dist


def longest_branch(inst: ProblemInstance, safe: bool = False) -> float:
    """Algorithm 1: T_min = max_v dist(v) + p_v."""
    job = inst.job
    if safe:
        cost = np.minimum(
            inst.r_local, np.minimum(inst.q_wired, inst.q_wireless)
        )
    else:
        cost = inst.r_local
    dist = critical_path_dist(job.n_tasks, job.edges, job.p, cost, job.topo_order())
    return float(np.max(dist + job.p)) if job.n_tasks else 0.0


def lower_bound(inst: ProblemInstance, safe: bool = True) -> float:
    """T_min. ``safe=True`` guards against instances where local transfer is
    slower than network transfer (not the paper's regime)."""
    return longest_branch(inst, safe=safe)
