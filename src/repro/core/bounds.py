"""Heuristic bounds on the optimal job completion time (paper §IV-A).

Upper bound T_max: run the whole job on one rack in topological order; all
transfers are local. T_max = sum_v p_v + sum_e r_e.

Lower bound T_min: Algorithm 1 ("The Longest Branch Algorithm") — convert
node costs to out-edge costs c_(u,v) = p_u + r_(u,v), then longest path by
dynamic programming over a topological order; T_min = max_v dist(v) + p_v.

The paper's Algorithm 1 uses the LOCAL delay r as the per-edge transfer cost,
which is a valid lower bound whenever local transfer is never slower than a
network transfer (true in the paper's experiments where r = 0). ``safe=True``
instead uses min(r_e, q_e, q̌_e), which is a valid bound for arbitrary rates.

Assignment-conditional load bounds (§IV-A resource terms)
---------------------------------------------------------
Once a task->rack assignment x is fixed, two contention terms sharpen the
contention-free critical path (which several dense seeds cannot prune with
at all):

  * per-rack work   — racks are unary compute resources (constraint (5)),
    so makespan >= max_i Σ_{v: x_v = i} p_v
    (:func:`rack_load_bounds`; maps job.p onto the rack axis).
  * aggregate channel work — every cross-rack edge must occupy exactly one
    of the 1 + |K| network channels (wired ``b`` of rate B_s, constraint (8),
    plus the orthogonal wireless subchannels of rate B, constraint (9)) for
    at least min(q_(u,v), q̌_(u,v)) = d_(u,v) / max(B_s, B) time units, so
    makespan >= Σ_{(u,v): x_u != x_v} min(q, q̌) / (1 + |K|)
    (:func:`network_work_bounds`; maps job.d through q_wired / q_wireless).

Each term individually lower-bounds the optimal makespan for that
assignment AND the batched greedy evaluator's non-delay score, so
max(critical_path, rack_load, network_work) is admissible both for exact
B&B pruning and for the vectorized stage-1 pruner
(:func:`repro.core.vectorized.batched_lower_bound`, fused on-device via
:func:`repro.kernels.ops.batched_combined_lb`).

Reachability-aware terms (restricted :class:`~repro.core.instance.Topology`)
---------------------------------------------------------------------------
Under a restricted reachability mask two sharpenings apply, both still
admissible (``topology=None`` takes the exact pre-topology code path,
bit-identical):

  * forced-wired edges — a cross-rack edge whose endpoint racks share no
    reachable subchannel must use the wired channel, so its optimistic
    duration is q (not min(q, q̌)) and the wired channel alone must carry
    Σ q over forced edges: makespan >= that serial load.
  * active-subchannel counting — the aggregate channel work only divides
    by subchannels some cross edge of THIS assignment can actually reach
    (1 + |K_active|), so unreachable subchannels no longer dilute the
    bound ("a subchannel's aggregate work only counts racks that can
    reach it").
"""

from __future__ import annotations

import numpy as np

from repro.core.instance import ProblemInstance

__all__ = [
    "upper_bound",
    "lower_bound",
    "longest_branch",
    "critical_path_dist",
    "rack_load_bounds",
    "network_work_bounds",
    "contention_lower_bounds",
    "partial_assignment_bound",
]


def upper_bound(inst: ProblemInstance) -> float:
    """T_max = Σ p_v + Σ r_(u,v): single-rack topological execution."""
    return float(np.sum(inst.job.p) + np.sum(inst.r_local))


def critical_path_dist(
    n: int,
    edges: np.ndarray,
    p: np.ndarray,
    edge_cost: np.ndarray,
    topo: np.ndarray,
) -> np.ndarray:
    """dist(v): longest path from any source to v, where traversing edge
    (u, v) costs p_u + edge_cost_e (Algorithm 1 lines 4-8)."""
    dist = np.zeros(n, dtype=np.float64)
    in_by_node: list[list[int]] = [[] for _ in range(n)]
    for e in range(edges.shape[0]):
        in_by_node[int(edges[e, 1])].append(e)
    for v in topo:
        best = 0.0
        for e in in_by_node[int(v)]:
            u = int(edges[e, 0])
            cand = dist[u] + p[u] + edge_cost[e]
            if cand > best:
                best = cand
        dist[int(v)] = best
    return dist


def longest_branch(inst: ProblemInstance, safe: bool = False) -> float:
    """Algorithm 1: T_min = max_v dist(v) + p_v."""
    job = inst.job
    if safe:
        cost = np.minimum(
            inst.r_local, np.minimum(inst.q_wired, inst.q_wireless)
        )
    else:
        cost = inst.r_local
    dist = critical_path_dist(job.n_tasks, job.edges, job.p, cost, job.topo_order())
    return float(np.max(dist + job.p)) if job.n_tasks else 0.0


def lower_bound(inst: ProblemInstance, safe: bool = True) -> float:
    """T_min. ``safe=True`` guards against instances where local transfer is
    slower than network transfer (not the paper's regime)."""
    return longest_branch(inst, safe=safe)


def min_network_durations(inst: ProblemInstance) -> np.ndarray:
    """Per-edge optimistic network transfer time: min(q, q̌) (q if |K| = 0)."""
    if inst.n_wireless:
        return np.minimum(inst.q_wired, inst.q_wireless)
    return np.asarray(inst.q_wired)


def rack_load_bounds(inst: ProblemInstance, racks: np.ndarray) -> np.ndarray:
    """Per-assignment §IV-A rack-work bound: max_i Σ_{x_v = i} p_v.

    ``racks``: int[B, n_tasks] batch of COMPLETE assignments; returns
    float64[B]. Partial assignments (-1 sentinels) are rejected — wrapping
    them onto the last rack would inflate the bound past admissibility; use
    :func:`partial_assignment_bound` for partial information.
    """
    racks = np.asarray(racks)
    if racks.size and racks.min() < 0:
        raise ValueError("rack_load_bounds needs complete assignments (no -1)")
    B, n = racks.shape
    load = np.zeros((B, inst.n_racks), dtype=np.float64)
    rows = np.arange(B)
    for v in range(n):
        load[rows, racks[:, v]] += inst.job.p[v]
    return load.max(axis=1)


def network_work_bounds(inst: ProblemInstance, racks: np.ndarray) -> np.ndarray:
    """Per-assignment §IV-A channel-work bound.

    Σ over cross-rack edges of min(q, q̌), divided by the 1 + |K| network
    channels (wired ``b`` + wireless subchannels). float64[B].

    With a restricted ``inst.topology`` the bound sharpens (still
    admissible): forced-wired edges (no common reachable subchannel)
    contribute q and must serialize on the wired channel, and the
    aggregate divides by 1 + |K_active| — only subchannels some cross
    edge of the row's assignment can reach.
    """
    racks = np.asarray(racks)
    job = inst.job
    if job.n_edges == 0:
        return np.zeros(racks.shape[0], dtype=np.float64)
    net = min_network_durations(inst)
    eu, ev = job.edges[:, 0], job.edges[:, 1]
    cross = racks[:, eu] != racks[:, ev]
    topo = inst.topology
    if topo is None:
        return (cross * net[None, :]).sum(axis=1) / (1 + inst.n_wireless)
    q = np.asarray(inst.q_wired)
    # [B, E, K]: subchannels usable by each row's placement of each edge.
    edge_reach = topo.pair_reach()[racks[:, eu], racks[:, ev], :]
    ok = edge_reach.any(axis=2)  # [B, E] pair shares >= 1 subchannel
    minfeas = np.where(ok, net[None, :], q[None, :])
    k_active = (edge_reach & cross[:, :, None]).any(axis=1).sum(axis=1)
    agg = (cross * minfeas).sum(axis=1) / (1 + k_active)
    wired_forced = (cross * ~ok * q[None, :]).sum(axis=1)
    return np.maximum(agg, wired_forced)


def contention_lower_bounds(inst: ProblemInstance, racks: np.ndarray) -> np.ndarray:
    """max of the two assignment-conditional §IV-A load bounds. float64[B]."""
    return np.maximum(
        rack_load_bounds(inst, racks), network_work_bounds(inst, racks)
    )


def partial_assignment_bound(
    inst: ProblemInstance,
    rack: np.ndarray,
    topo: np.ndarray,
    min_cost: np.ndarray,
) -> float:
    """LB for a PARTIAL assignment (rack[v] = -1 when undecided): optimistic
    critical path + per-rack work over assigned tasks + aggregate channel
    work over decided cross-rack edges.

    This is the §IV-A bound family generalized to partial information: the
    shared bound hook of the combinatorial B&B
    (:func:`repro.core.bnb.solve_bnb`) and the single-assignment special
    case used by :func:`contention_lower_bounds`.

    Args:
      inst: the instance.
      rack: int[n_tasks] with ``rack[v] = -1`` for undecided tasks; decided
        entries must be in ``[0, inst.n_racks)``.
      topo: int[n_tasks] topological order of the DAG
        (``inst.job.topo_order()``; passed in so B&B amortizes it).
      min_cost: float[n_edges] optimistic per-edge cost for edges with at
        least one undecided endpoint — ``min(r, q, q̌)`` per edge; copied,
        never mutated. Decided edges use their exact local/network cost.

    Returns:
      A float lower bound on the optimal makespan of any completion of
      ``rack`` (monotone: deciding more tasks never decreases it).
      Admissible for both exact B&B pruning and the greedy evaluator.
    """
    job = inst.job
    cost = min_cost.copy()
    net = min_network_durations(inst)
    q = np.asarray(inst.q_wired)
    conn = None
    topology = inst.topology
    if topology is not None:
        conn = topology.pair_connected()
    for e in range(job.n_edges):
        u, v = int(job.edges[e, 0]), int(job.edges[e, 1])
        if rack[u] >= 0 and rack[v] >= 0:
            if rack[u] == rack[v]:
                cost[e] = inst.r_local[e]
            elif conn is None or conn[rack[u], rack[v]]:
                cost[e] = net[e]
            else:
                cost[e] = q[e]  # forced wired: no common subchannel
    dist = critical_path_dist(job.n_tasks, job.edges, job.p, cost, topo)
    lb = float(np.max(dist + job.p))
    for i in range(inst.n_racks):
        sel = rack == i
        if sel.any():
            load = float(job.p[sel].sum())
            if load > lb:
                lb = load
    work = 0.0
    wired_forced = 0.0
    k_active: set[int] | None = None if topology is None else set()
    for e in range(job.n_edges):
        u, v = int(job.edges[e, 0]), int(job.edges[e, 1])
        if rack[u] >= 0 and rack[v] >= 0 and rack[u] != rack[v]:
            if conn is None or conn[rack[u], rack[v]]:
                work += net[e]
                if k_active is not None:
                    k_active.update(
                        topology.edge_channels(int(rack[u]), int(rack[v]))
                    )
            else:
                work += q[e]
                wired_forced += q[e]
    if work > 0.0:
        n_chan = (
            1 + inst.n_wireless if k_active is None else 1 + len(k_active)
        )
        lb = max(lb, work / n_chan)
    if wired_forced > 0.0:
        lb = max(lb, wired_forced)
    return lb
