"""Coflow view of an admission epoch and cross-job commit-order search.

The paper's model (and our engine) optimizes each job's *intra-job*
decisions — task->rack assignment, per-transfer channel choice — but the
online service commits one epoch's admitted jobs in queue (FIFO) order,
and the commit order is exactly the cross-job priority on the shared
wired channel: a job committed earlier gap-inserts its transfers first
and everyone after it queues around them. That order is a free
optimization dimension the per-job solver never sees.

This module treats it as a coflow scheduling problem. Each admitted
job's transfer set is one :class:`Coflow` — its aggregate busy-time
demand on every *shared* physical resource (the wired channel, plus each
granted wireless subchannel) — and the epoch's batch is scheduled as a
set of coflows:

* :func:`sigma_order` — a Sincronia-style bottleneck-first ordering
  ("Near Optimal Coflow Scheduling in Networks", PAPERS.md): repeatedly
  find the most-loaded shared resource and place *last* the remaining
  coflow with the largest demand on it. With one shared resource (the
  common case here: co-admitted jobs' rack and subchannel grants are
  disjoint, so only the wired channel is contended inside an epoch) this
  degenerates to shortest-demand-first, the 2-approximation ordering for
  total completion time on a single shared link.
* :func:`search_commit_order` — a deterministic permutation-neighborhood
  search over commit orders, driven by the existing
  :class:`~repro.core.portfolio.Portfolio` allocator: the registered
  arbitration strategies (:class:`OrderSwapStrategy`,
  :class:`OrderInsertStrategy`) propose permutations of the incumbent
  order, each unique order is evaluated once through the caller's
  replay, and FIFO is always evaluated first — the returned order is
  never worse than FIFO under the caller's objective. Batches of at most
  ``exhaustive_max`` jobs are solved exactly by enumerating every
  permutation (the oracle regime the test layer locks).

The evaluation itself lives with the owner of the cluster state
(:func:`repro.online.cluster.replay_commit_order` replays a candidate
order through the host simulator's ``channel_busy`` hook); this module
is pure search and never touches a timeline.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core.instance import CH_WIRED, ProblemInstance
from repro.core.portfolio import (
    ARBITRATION_STRATEGIES,
    Portfolio,
    SearchView,
    StrategyBase,
    register_arbitration_strategy,
)
from repro.core.schedule import Schedule

__all__ = [
    "Coflow",
    "OrderInsertStrategy",
    "OrderSearchResult",
    "OrderSwapStrategy",
    "WIRED",
    "DEFAULT_ORDER_PORTFOLIO",
    "build_order_strategies",
    "coflow_from_instance",
    "coflow_from_schedule",
    "search_commit_order",
    "sigma_order",
    "wireless_resource",
]

# Shared-resource keys. The wired channel is one global resource; each
# wireless subchannel is keyed by its *physical* index so demands from
# different jobs' local channel labels land on the same key.
WIRED = "wired"


def wireless_resource(phys: int) -> str:
    """Resource key of physical wireless subchannel ``phys``."""
    return f"wireless:{int(phys)}"


@dataclasses.dataclass(frozen=True)
class Coflow:
    """One job's aggregate transfer demand on the shared resources.

    Attributes:
      index: the job's position in the epoch batch (its FIFO rank).
      job_id: stream job id (labels only; -1 when unknown).
      demand: busy-time demanded per shared resource key
        (:data:`WIRED` / :func:`wireless_resource`); zero-demand
        resources are omitted.
    """

    index: int
    job_id: int
    demand: Mapping[str, float]

    @property
    def total(self) -> float:
        """Total busy-time across every shared resource."""
        return float(sum(self.demand.values()))


def coflow_from_schedule(
    view, sched: Schedule, *, index: int, job_id: int = -1
) -> Coflow:
    """Coflow of one *solved* job: exact per-resource busy time of the
    schedule's transfers (wired edges on :data:`WIRED`, wireless edges on
    their physical subchannel via ``view.wireless_map``). Local traffic
    occupies no shared resource and is ignored."""
    inst = view.inst
    demand: dict[str, float] = {}
    if inst.job.n_edges:
        dur = inst.duration_on(sched.chan)
        for e in range(inst.job.n_edges):
            d = float(dur[e])
            if d <= 0.0:
                continue
            c = int(sched.chan[e])
            if c == CH_WIRED:
                key = WIRED
            elif c >= 2:
                key = wireless_resource(int(view.wireless_map[c - 2]))
            else:
                continue  # local: private to the rack, never shared
            demand[key] = demand.get(key, 0.0) + d
    return Coflow(index=int(index), job_id=int(job_id), demand=demand)


def coflow_from_instance(
    inst: ProblemInstance, *, index: int, job_id: int = -1
) -> Coflow:
    """Coflow of one *unsolved* job: a placement-free proxy charging the
    job's whole transfer volume to the wired channel at the wired rate
    (the worst case — any transfer the eventual placement keeps local or
    moves to wireless only shrinks the true wired demand). Used for
    baseline policies, whose schedules are solved lazily at commit time
    so exact per-resource demands do not exist yet."""
    total = float(np.sum(inst.q_wired)) if inst.job.n_edges else 0.0
    demand = {WIRED: total} if total > 0.0 else {}
    return Coflow(index=int(index), job_id=int(job_id), demand=demand)


def sigma_order(coflows: Sequence[Coflow]) -> list[int]:
    """Sincronia-style bottleneck-first ordering of one epoch's coflows.

    Repeatedly: find the most-loaded shared resource (the bottleneck),
    schedule *last* the remaining coflow with the largest demand on it,
    and recurse on the rest. Coflows with no shared-resource demand at
    all keep their FIFO rank at the front (they cannot contend). Ties are
    deterministic: the bottleneck is the lexicographically smallest
    max-load resource, and among equal-demand coflows the latest FIFO
    rank goes last — so an all-equal batch returns pure FIFO.

    Returns the batch positions (``Coflow.index``) in commit order,
    first-to-commit first.
    """
    remaining = list(coflows)
    suffix: list[Coflow] = []  # chosen back-to-front
    while remaining:
        load: dict[str, float] = {}
        for c in remaining:
            for key, d in c.demand.items():
                if d > 0.0:
                    load[key] = load.get(key, 0.0) + d
        if not load:
            break  # only demand-free coflows left: they head the order
        peak = max(load.values())
        bottleneck = min(k for k, v in load.items() if v == peak)
        last = max(
            (c for c in remaining if c.demand.get(bottleneck, 0.0) > 0.0),
            key=lambda c: (c.demand[bottleneck], c.index),
        )
        suffix.append(last)
        remaining.remove(last)
    head = sorted(remaining, key=lambda c: c.index)
    return [c.index for c in head] + [c.index for c in reversed(suffix)]


# -- permutation-neighborhood strategies --------------------------------------


class _OrderStrategyBase(StrategyBase):
    """Arbitration strategies perturb the incumbent *commit order*
    (``view.best_rack`` is an int32 permutation of ``range(n_jobs)``).
    Shared helper: draw two distinct positions from the view's RNG."""

    @staticmethod
    def _two_positions(rng: np.random.Generator, n: int) -> tuple[int, int]:
        a = int(rng.integers(0, n))
        b = int(rng.integers(0, n - 1))
        return a, b + 1 if b >= a else b


@register_arbitration_strategy
class OrderSwapStrategy(_OrderStrategyBase):
    """Transposition neighborhood: swap two distinct positions of the
    incumbent commit order."""

    name = "order_swap"

    def propose(self, view: SearchView, count: int) -> np.ndarray:
        base = np.asarray(view.best_rack, dtype=np.int32)
        n = base.shape[0]
        out = np.tile(base, (count, 1))
        for r in range(count):
            a, b = self._two_positions(view.rng, n)
            out[r, a], out[r, b] = out[r, b], out[r, a]
        return out


@register_arbitration_strategy
class OrderInsertStrategy(_OrderStrategyBase):
    """Reinsertion neighborhood: remove one job from the incumbent order
    and reinsert it at another position (shifting the span between — the
    natural move when one job should jump the queue entirely)."""

    name = "order_insert"

    def propose(self, view: SearchView, count: int) -> np.ndarray:
        base = np.asarray(view.best_rack, dtype=np.int32)
        n = base.shape[0]
        out = np.empty((count, n), dtype=np.int32)
        for r in range(count):
            a, b = self._two_positions(view.rng, n)
            row = np.delete(base, a)
            out[r] = np.insert(row, b, base[a])
        return out


DEFAULT_ORDER_PORTFOLIO = ("order_swap", "order_insert")


def build_order_strategies(spec=None) -> list:
    """Resolve an arbitration-strategy spec into fresh Strategy objects.

    ``spec`` may be ``None`` (:data:`DEFAULT_ORDER_PORTFOLIO`), a single
    registry name, or a sequence of registry names / zero-arg factories /
    live Strategy objects — the same shapes
    :func:`repro.core.portfolio.build_strategies` accepts, resolved
    against :data:`~repro.core.portfolio.ARBITRATION_STRATEGIES`.
    """
    if spec is None:
        spec = DEFAULT_ORDER_PORTFOLIO
    elif isinstance(spec, str):
        spec = (spec,)
    out = []
    for item in spec:
        if isinstance(item, str):
            if item not in ARBITRATION_STRATEGIES:
                raise ValueError(
                    f"unknown arbitration strategy {item!r}; "
                    f"registry: {sorted(ARBITRATION_STRATEGIES)}"
                )
            out.append(ARBITRATION_STRATEGIES[item]())
        elif isinstance(item, type) or (
            callable(item) and not hasattr(item, "propose")
        ):
            out.append(item())
        elif hasattr(item, "propose"):
            out.append(item)
        else:
            raise TypeError(f"not a strategy, factory, or name: {item!r}")
    names = [s.name for s in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate strategy names in order portfolio: {names}")
    return out


# -- order search -------------------------------------------------------------


def _scalar(obj) -> float:
    """Portfolio-accounting scalar of an order objective. Objectives are
    either a plain float or a ``(n_rejected, total_jct)`` tuple — the
    tuple is folded rejection-dominant so the allocator's improvement
    credits line up with the driver's lexicographic comparisons."""
    if isinstance(obj, tuple):
        rejected, total = obj
        return float(rejected) * 1e12 + float(total)
    return float(obj)


@dataclasses.dataclass(frozen=True)
class OrderSearchResult:
    """Outcome of one :func:`search_commit_order` call.

    Attributes:
      order: best commit order found (batch positions, first-first).
      objective: its objective, as returned by the caller's ``evaluate``.
      fifo_objective: the FIFO order's objective — always evaluated, so
        ``objective <= fifo_objective`` holds by construction.
      n_evals: unique orders evaluated (duplicates are cached).
      exhaustive: True when every permutation was enumerated (the result
        is the oracle optimum of ``evaluate``).
    """

    order: tuple[int, ...]
    objective: object
    fifo_objective: object
    n_evals: int
    exhaustive: bool


def search_commit_order(
    evaluate: Callable[[tuple[int, ...]], object],
    n: int,
    *,
    rng: np.random.Generator,
    seeds: Sequence[Sequence[int]] = (),
    rounds: int = 2,
    pool_size: int = 8,
    strategies=None,
    exhaustive_max: int = 3,
) -> OrderSearchResult:
    """Search the space of commit permutations of an ``n``-job batch.

    ``evaluate(order)`` scores one full commit order (lower is better;
    any ``<``-comparable value works — the online service returns
    ``(n_rejected, total_jct)`` tuples). Each unique order is evaluated
    at most once. FIFO (``(0, 1, ..., n-1)``) is always evaluated first
    and only *strictly* better orders replace it, so the result is never
    worse than FIFO under ``evaluate`` — the invariant the oracle test
    layer locks.

    Batches with ``n <= exhaustive_max`` enumerate every permutation and
    return the exact optimum. Larger batches evaluate the ``seeds``
    (e.g. the sigma ordering), then run ``rounds`` rounds of the
    :class:`~repro.core.portfolio.Portfolio` allocator over the
    registered permutation neighborhoods, ``pool_size`` proposals per
    round. Deterministic for a fixed ``rng`` state.
    """
    if n < 1:
        raise ValueError("need at least one job to order")
    identity = list(range(n))
    cache: dict[tuple[int, ...], object] = {}

    def ev(order) -> tuple[tuple[int, ...], object]:
        key = tuple(int(x) for x in order)
        if sorted(key) != identity:
            raise ValueError(f"not a permutation of range({n}): {key}")
        if key not in cache:
            cache[key] = evaluate(key)
        return key, cache[key]

    fifo = tuple(identity)
    _, fifo_obj = ev(fifo)
    best, best_obj = fifo, fifo_obj
    if n <= exhaustive_max:
        for perm in itertools.permutations(identity):
            key, obj = ev(perm)
            if obj < best_obj:
                best, best_obj = key, obj
        return OrderSearchResult(best, best_obj, fifo_obj, len(cache), True)
    for seed_order in seeds:
        key, obj = ev(seed_order)
        if obj < best_obj:
            best, best_obj = key, obj
    # Portfolio-driven neighborhood search. The driver's `inst` is only
    # ever handed to strategies through the SearchView; order strategies
    # need no instance, so none is attached.
    driver = Portfolio(
        build_order_strategies(strategies), None, rng, pool_size=int(pool_size)
    )
    for _ in range(max(0, int(rounds))):
        incumbent_scalar = _scalar(best_obj)
        pool, tags = driver.begin_round(
            np.asarray(best, dtype=np.int32), incumbent_scalar
        )
        if pool.shape[0] == 0:
            break
        vals = np.empty(pool.shape[0], dtype=np.float64)
        for r in range(pool.shape[0]):
            key, obj = ev(pool[r])
            vals[r] = _scalar(obj)
            if obj < best_obj:
                best, best_obj = key, obj
        driver.observe(tags, pool, vals, prev_best=incumbent_scalar)
        driver.end_round(np.asarray(best, dtype=np.int32), _scalar(best_obj))
    return OrderSearchResult(best, best_obj, fifo_obj, len(cache), False)
