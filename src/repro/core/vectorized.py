"""JAX-vectorized schedule search (beyond-paper, TPU-native).

The paper's solver is host-side B&B. On TPU-class hardware the natural
adaptation of its *search* is massive data parallelism: evaluate tens of
thousands of candidate rack assignments simultaneously as one batched tensor
program. This module implements that search as a two-stage, device-sharded
batch engine whose padding and masking are **instance-aware end-to-end**: a
fleet of heterogeneous :class:`ProblemInstance`\\ s is packed into one padded
mega-batch (shared size bucket, per-row instance ids, per-instance channel
masks) and solved by a single pair of compiled programs.

  Stage 1 (bound): every candidate passes through the paper's combined
  §IV-A lower bound, computed batched on-device by the fused Pallas kernel
  :func:`repro.kernels.ops.batched_combined_lb` — the critical-path bound
  (iterated max-plus relaxation on dense adjacency blocks) maxed with the
  contention terms (per-rack work, aggregate wired+wireless channel work;
  see :mod:`repro.core.bounds` for the §IV-A term-to-array mapping).
  Candidates whose bound already meets the running incumbent are discarded
  without ever being scheduled; the contention terms are what let dense
  instances (where the contention-free critical path prunes 0%) prune.

  Stage 2 (evaluate): survivors are scored by a greedy non-delay schedule
  executed in lock-step across the batch. The evaluator is a single
  ``lax.scan`` over *static op tables* in the shared layout of
  :func:`repro.core.simulator.pad_op_tables` — per-instance tables are
  stacked on a leading axis and gathered per batch row by instance id, so
  candidates of **different** jobs ride in the same launch, and one
  compiled program serves every fleet whose size bucket matches. Batches
  are sharded across local devices with ``shard_map`` when more than one
  device is present, degrading gracefully to a plain ``jit``.

Fleet API: :func:`schedule_fleet` runs N heterogeneous instances through
the lockstep driver — per-instance incumbents, pruning and refinement
evolve exactly as in the single-instance :func:`vectorized_search` (which
is now the fleet-of-one special case), so each per-instance result is
bit-for-bit identical to solving that instance alone, while the fleet pays
one sharded launch (and at most one trace) per stage instead of one per
instance. :class:`FleetResult` reports per-instance results plus fleet
prune / launch / trace counters.

  Refinement (sampled regime): the incumbent stream feeds the strategy
  portfolio of :mod:`repro.core.portfolio` — mutation local search by
  default (bit-for-bit the pre-portfolio loop), optionally elite
  crossover and simulated annealing with a multiplicative-weights budget
  allocator (``strategies="portfolio"``). All strategies' proposals ride
  the same lockstep launches and the same stage-1 pruner; per-strategy
  counters surface as ``strategy_stats`` on the results.

This module is an *incumbent generator / pruner*: the winning assignment is
re-executed exactly with the host simulator and verified by the OP checker.
Exactness guarantees come from `bnb`/`solver_milp`; tests assert the
vectorized score is always >= the exact optimum and == the simulator's
makespan for the reconstructed schedule. Pruning is exact with respect to
the greedy objective: greedy(c) >= LB(c), so LB(c) >= incumbent implies c
cannot improve the incumbent.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds as bounds_mod
from repro.core import portfolio as portfolio_mod
from repro.core.instance import ProblemInstance
from repro.core.schedule import Schedule
from repro.core.simulator import OP_EDGE, OP_TASK, build_op_tables, pad_op_tables, simulate
from repro.obs.trace import as_tracer

__all__ = [
    "enumerate_assignments",
    "sample_assignments",
    "make_batched_evaluator",
    "batched_lower_bound",
    "vectorized_search",
    "schedule_fleet",
    "VectorizedResult",
    "FleetResult",
]


def enumerate_assignments(n: int, max_racks: int, limit: int | None = None) -> np.ndarray:
    """All canonical task->rack assignments (restricted growth strings).

    Canonical = rack labels appear in first-use order, which quotients out
    rack-relabelling symmetry. Returns int32[count, n].
    """
    out: list[list[int]] = []

    def rec(prefix: list[int], n_used: int) -> None:
        if limit is not None and len(out) >= limit:
            return
        if len(prefix) == n:
            out.append(list(prefix))
            return
        for i in range(min(n_used + 1, max_racks)):
            prefix.append(i)
            rec(prefix, max(n_used, i + 1))
            prefix.pop()
            if limit is not None and len(out) >= limit:
                return

    rec([], 0)
    return np.asarray(out, dtype=np.int32).reshape(-1, n)


def sample_assignments(
    rng: np.random.Generator, n: int, max_racks: int, count: int
) -> np.ndarray:
    """Random assignments (not canonicalized; used when enumeration is big)."""
    return rng.integers(0, max_racks, size=(count, n), dtype=np.int32).astype(np.int32)


# ---------------------------------------------------------------------------
# Size buckets
# ---------------------------------------------------------------------------

def _bucket(x: int, lo: int = 8) -> int:
    """Smallest power of two >= max(x, lo): the size-bucket rounding used for
    every padded dimension so compiled programs are shared across fleets."""
    b = lo
    while b < x:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class _FleetDims:
    """Shared size bucket of a (possibly heterogeneous) instance fleet.

    Every padded dimension is the bucket of the fleet-wide maximum, so all
    instances share one op-table layout and one compiled program per stage.
    ``n_iters`` is the true relaxation depth bound (max task count - 1):
    extra rounds past an instance's own depth are exact no-ops, which keeps
    per-instance bounds bit-identical under any fleet padding.
    """

    n_ops: int
    n_pad: int
    m_pad: int
    M_pad: int
    indeg_pad: int
    n_chan: int
    n_iters: int


def _fleet_dims(instances, use_wireless: bool, op_tables=None) -> _FleetDims:
    """Size bucket of a fleet. ``op_tables`` (one prebuilt ``OpTables`` per
    instance) sizes the evaluator dims; LB-only callers omit it and must
    not read ``n_ops`` / ``indeg_pad`` (they stay at the bucket floor)."""
    n_ops = n = m = M = indeg = wireless = 1
    for i, inst in enumerate(instances):
        if op_tables is not None:
            n_ops = max(n_ops, op_tables[i].n_ops)
            indeg = max(indeg, op_tables[i].task_in_edges.shape[1])
        n = max(n, inst.job.n_tasks)
        m = max(m, inst.job.n_edges)
        M = max(M, inst.n_racks)
        if use_wireless:
            wireless = max(wireless, inst.n_wireless)
    return _FleetDims(
        n_ops=_bucket(n_ops),
        n_pad=_bucket(n),
        m_pad=_bucket(m),
        M_pad=_bucket(M, lo=2),
        indeg_pad=_bucket(indeg, lo=4),
        n_chan=1 + (wireless if use_wireless else 0),
        n_iters=max(0, n - 1),
    )


# ---------------------------------------------------------------------------
# Stage-2 evaluator: instance-aware op-table lax.scan program
# ---------------------------------------------------------------------------

# Incremented each time the scan evaluator is traced; lets tests assert that
# fleets sharing a size bucket reuse the compiled program.
TRACE_COUNT = 0

# Same, for the stage-1 combined-bound program.
LB_TRACE_COUNT = 0


def _scan_evaluate(
    rack,       # int32[B, n_pad]  candidate assignments (one job's tasks per row)
    inst_id,    # int32[B]         which fleet instance each row belongs to
    kind,       # int32[I, n_ops]  OP_TASK / OP_EDGE / OP_PAD
    op_task,    # int32[I, n_ops]  task id for OP_TASK rows (0 otherwise)
    op_edge,    # int32[I, n_ops]  edge id for OP_EDGE rows (0 otherwise)
    op_src,     # int32[I, n_ops]  edge source task (0 otherwise)
    op_dst,     # int32[I, n_ops]  edge dest task (0 otherwise)
    op_p,       # f32[I, n_ops]    task duration
    op_wired,   # f32[I, n_ops]    wired transfer duration
    op_wireless,  # f32[I, n_ops]  wireless transfer duration
    op_local,   # f32[I, n_ops]    local transfer delay
    op_in,      # int32[I, n_ops, indeg_pad] in-edge ids gating a task row;
                #                  the sentinel id m_pad always reads 0.0
    chan_free0,  # f32[I, n_chan]  initial channel availability: 0 = usable,
                #                  +inf = masked (instance has fewer channels)
    reach,      # f32[I, M_pad, n_chan] topology reachability: 1 = rack may
                #                  use the channel (col 0, wired, always 1);
                #                  all-ones when the instance has no topology
    *,
    m_pad: int,
    M_pad: int,
    n_chan: int,
):
    global TRACE_COUNT
    TRACE_COUNT += 1
    B, n_pad = rack.shape

    def take(t):
        return jnp.take(t, inst_id, axis=0)

    # Per-row reachability rows; constant over the scan.
    reach_b = take(reach)  # [B, M_pad, n_chan]

    # Per-row tables, scan axis leading. Rows of different instances walk
    # different op sequences in lock-step; OP_PAD rows are no-ops.
    xs = (
        take(kind).T, take(op_task).T, take(op_edge).T, take(op_src).T,
        take(op_dst).T, take(op_p).T, take(op_wired).T, take(op_wireless).T,
        take(op_local).T, jnp.swapaxes(take(op_in), 0, 1),
    )
    carry0 = (
        jnp.zeros((B, M_pad), jnp.float32),      # rack_free
        take(chan_free0),                        # chan_free (+inf = masked)
        jnp.zeros((B, n_pad), jnp.float32),      # task_fin
        jnp.zeros((B, m_pad + 1), jnp.float32),  # edge_fin (+1 sentinel col)
    )

    def pick(tab, idx):  # tab[B, W], idx[B] -> [B]
        return jnp.take_along_axis(tab, idx[:, None], axis=1)[:, 0]

    def step(carry, x):
        rack_free, chan_free, task_fin, edge_fin = carry
        kind_t, t_v, e_id, u, v, p_v, q_w, q_wl, r_l, in_row = x
        is_task = kind_t == OP_TASK
        is_edge = kind_t == OP_EDGE

        # Task branch (reads the pre-step carry): start when all gating
        # in-edges have finished and the task's rack is free.
        ready_t = jnp.max(jnp.take_along_axis(edge_fin, in_row, axis=1), axis=1)
        rv = pick(rack, t_v)
        fin_t = jnp.maximum(ready_t, pick(rack_free, rv)) + p_v

        # Edge branch (reads the pre-step carry; a row is task OR edge at
        # any step, so both branches can share it).
        ready_e = pick(task_fin, u)
        same = pick(rack, u) == pick(rack, v)
        fin_local = ready_e + r_l
        # Network path: earliest-finish channel (0 wired, 1.. wireless);
        # masked channels sit at +inf and are never selected.
        durs = jnp.concatenate(
            [q_w[:, None], jnp.broadcast_to(q_wl[:, None], (B, n_chan - 1))],
            axis=1,
        )
        s = jnp.maximum(ready_e[:, None], chan_free)
        # Topology gating: a channel is usable iff both endpoint racks reach
        # it (col 0, wired, is always reachable); infeasible channels sit at
        # +inf exactly like instance-masked channels.
        def chan_rows(idx):  # rack ids [B] -> reach rows [B, n_chan]
            return jnp.take_along_axis(reach_b, idx[:, None, None], axis=1)[:, 0, :]

        feas = chan_rows(pick(rack, u)) * chan_rows(pick(rack, v))
        f = jnp.where(feas > 0, s + durs, jnp.inf)
        best = jnp.argmin(f, axis=1)
        fin_net = jnp.take_along_axis(f, best[:, None], axis=1)[:, 0]
        new_free = jnp.where(
            jax.nn.one_hot(best, n_chan, dtype=bool), fin_net[:, None], chan_free
        )
        fin_e = jnp.where(same, fin_local, fin_net)

        # Merge by per-row op kind (OP_PAD rows change nothing).
        rack_free = jnp.where(
            is_task[:, None] & jax.nn.one_hot(rv, M_pad, dtype=bool),
            fin_t[:, None], rack_free,
        )
        task_fin = jnp.where(
            is_task[:, None] & jax.nn.one_hot(t_v, n_pad, dtype=bool),
            fin_t[:, None], task_fin,
        )
        chan_free = jnp.where((is_edge & ~same)[:, None], new_free, chan_free)
        edge_fin = jnp.where(
            is_edge[:, None] & jax.nn.one_hot(e_id, m_pad + 1, dtype=bool),
            fin_e[:, None], edge_fin,
        )
        return (rack_free, chan_free, task_fin, edge_fin), None

    (_, _, task_fin, _), _ = jax.lax.scan(step, carry0, xs)
    return jnp.max(task_fin, axis=1)


@functools.lru_cache(maxsize=None)
def _compiled_evaluator(n_dev: int, m_pad: int, M_pad: int, n_chan: int):
    """Jitted (and, with >1 local device, shard_map-sharded) scan evaluator.

    The returned callable is cached per (device count, static dims); jit then
    caches per concrete table/batch shape — so any two fleets in the same
    size bucket share one compiled program.
    """
    core = functools.partial(
        _scan_evaluate, m_pad=m_pad, M_pad=M_pad, n_chan=n_chan
    )
    if n_dev <= 1:
        return jax.jit(core)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    # Local devices only: batch padding by the callers is sized to divide by
    # local_device_count, and each process shards its own host-local batch.
    # Only the candidate rows are sharded; tables are replicated.
    mesh = Mesh(np.asarray(jax.local_devices()), ("b",))
    r2, r3 = P(None, None), P(None, None, None)
    sharded = shard_map(
        core,
        mesh=mesh,
        in_specs=(P("b", None), P("b"), r2, r2, r2, r2, r2, r2, r2, r2, r2,
                  r3, r2, r3),
        out_specs=P("b"),
        check_rep=False,
    )
    return jax.jit(sharded)


def _build_eval_stack(instances, dims: _FleetDims, use_wireless: bool, op_tables=None):
    """Stacked device op tables [I, ...] in ``_scan_evaluate`` order."""
    I = len(instances)
    fields = {
        "kind": np.zeros((I, dims.n_ops), np.int32),
        "op_task": np.zeros((I, dims.n_ops), np.int32),
        "op_edge": np.zeros((I, dims.n_ops), np.int32),
        "op_src": np.zeros((I, dims.n_ops), np.int32),
        "op_dst": np.zeros((I, dims.n_ops), np.int32),
        "op_p": np.zeros((I, dims.n_ops), np.float32),
        "op_wired": np.zeros((I, dims.n_ops), np.float32),
        "op_wireless": np.zeros((I, dims.n_ops), np.float32),
        "op_local": np.zeros((I, dims.n_ops), np.float32),
        "op_in": np.zeros((I, dims.n_ops, dims.indeg_pad), np.int32),
    }
    chan_free0 = np.full((I, dims.n_chan), np.inf, np.float32)
    reach = np.ones((I, dims.M_pad, dims.n_chan), np.float32)
    for i, inst in enumerate(instances):
        t = pad_op_tables(
            inst,
            n_ops=dims.n_ops,
            indeg_pad=dims.indeg_pad,
            edge_sentinel=dims.m_pad,
            tables=None if op_tables is None else op_tables[i],
        )
        for name in fields:
            fields[name][i] = getattr(t, name)
        n_ch = 1 + (inst.n_wireless if use_wireless else 0)
        chan_free0[i, :n_ch] = 0.0
        if inst.topology is not None and n_ch > 1:
            reach[i, : inst.n_racks, 1:n_ch] = inst.topology.reach
    return tuple(jnp.asarray(fields[name]) for name in fields) + (
        jnp.asarray(chan_free0),
        jnp.asarray(reach),
    )


def make_batched_evaluator(inst: ProblemInstance, use_wireless: bool = True):
    """Build a fn: rack[B, n] int -> makespan[B] float32 (greedy non-delay).

    The fleet-of-one special case of the mega-batch evaluator: pads its
    batch to the instance's size bucket (batch to a power of two times the
    local device count) and dispatches the shared compiled scan program —
    identical instances never retrace, and instances of similar size share
    one compiled program per bucket.
    """
    ops = [build_op_tables(inst)]
    dims = _fleet_dims([inst], use_wireless, ops)
    tables = _build_eval_stack([inst], dims, use_wireless, ops)
    n = inst.job.n_tasks
    n_dev = jax.local_device_count()
    fn = _compiled_evaluator(n_dev, dims.m_pad, dims.M_pad, dims.n_chan)

    def evaluate(rack) -> jax.Array:
        rack = np.asarray(rack, dtype=np.int32)
        B = rack.shape[0]
        B_pad = _bucket(B) * (n_dev if _bucket(B) % n_dev else 1)
        padded = np.zeros((B_pad, dims.n_pad), dtype=np.int32)
        padded[:B, :n] = rack
        inst_id = np.zeros(B_pad, dtype=np.int32)
        return fn(jnp.asarray(padded), jnp.asarray(inst_id), *tables)[:B]

    evaluate.dims = dims
    return evaluate


# ---------------------------------------------------------------------------
# Stage-1 bound: fused Pallas combined §IV-A bound over the mega-batch
# ---------------------------------------------------------------------------

def _build_lb_arrays(instances, dims: _FleetDims):
    """Stacked stage-1 arrays [I, ...] for ``_fleet_lb_device``.

    Padded edges carry -inf costs (their scatter into the max-plus adjacency
    is a no-op) and zero ``net_work`` (they add nothing to the aggregate
    channel-work term); padded tasks carry zero duration.

    When any instance carries a :class:`~repro.core.instance.Topology`, two
    extra arrays feed the matching-feasibility mask of the fused kernel:
    ``pair_ok[I, M_pad, M_pad]`` (1 = the rack pair shares at least one
    reachable subchannel; all-ones for topology-free instances) and
    ``uplift[I, m_pad]`` (the forced-wired uplift ``q - min(q, q̌)`` per
    edge, 0 on padding). Topology-free fleets omit them, so the compiled
    stage-1 program is byte-for-byte the pre-topology one.
    """
    I = len(instances)
    src = np.zeros((I, dims.m_pad), np.int32)
    dst = np.zeros((I, dims.m_pad), np.int32)
    p_src = np.zeros((I, dims.m_pad), np.float32)
    c_local = np.full((I, dims.m_pad), -np.inf, np.float32)
    c_net = np.full((I, dims.m_pad), -np.inf, np.float32)
    net_work = np.zeros((I, dims.m_pad), np.float32)
    p_task = np.zeros((I, dims.n_pad), np.float32)
    chan_div = np.ones(I, np.float32)
    topo_on = any(inst.topology is not None for inst in instances)
    pair_ok = np.ones((I, dims.M_pad, dims.M_pad), np.float32) if topo_on else None
    uplift = np.zeros((I, dims.m_pad), np.float32) if topo_on else None
    for i, inst in enumerate(instances):
        job = inst.job
        m = job.n_edges
        p_task[i, : job.n_tasks] = job.p
        chan_div[i] = 1 + inst.n_wireless
        if m:
            src[i, :m] = job.edges[:, 0]
            dst[i, :m] = job.edges[:, 1]
            p_src[i, :m] = job.p[job.edges[:, 0]]
            c_local[i, :m] = inst.r_local
            net = bounds_mod.min_network_durations(inst)
            c_net[i, :m] = net
            net_work[i, :m] = net
            if topo_on:
                uplift[i, :m] = np.asarray(inst.q_wired, np.float32) - net
        if topo_on and inst.topology is not None:
            M = inst.n_racks
            pair_ok[i, :M, :M] = inst.topology.pair_connected()
    out = (src, dst, p_src, c_local, c_net, net_work, p_task, chan_div)
    if topo_on:
        out = out + (pair_ok, uplift)
    return tuple(jnp.asarray(a) for a in out)


@functools.partial(
    jax.jit, static_argnames=("M_pad", "n_iters", "block_b", "contention")
)
def _fleet_lb_device(
    racks,      # int32[B, n_pad]
    inst_id,    # int32[B]
    src,        # int32[I, m_pad]
    dst,        # int32[I, m_pad]
    p_src,      # f32[I, m_pad]  source-task duration per edge (0 on padding)
    c_local,    # f32[I, m_pad]  local delay per edge (-inf on padding)
    c_net,      # f32[I, m_pad]  optimistic network duration (-inf on padding)
    net_work,   # f32[I, m_pad]  min network duration (0 on padding)
    p_task,     # f32[I, n_pad]  task durations (0 on padding)
    chan_div,   # f32[I]         1 + |K| network channels
    pair_ok=None,  # f32[I, M_pad, M_pad] 1 = rack pair shares a reachable
                #                  subchannel (omitted: no topology in fleet)
    uplift=None,   # f32[I, m_pad]  forced-wired uplift q - min(q, q̌)
    *,
    M_pad: int,
    n_iters: int,
    block_b: int,
    contention: bool,
):
    """Batched combined §IV-A bound: one device program for the whole fleet.

    Builds the per-candidate max-plus adjacency (edge cost = p_u + r or
    p_u + min(q, q̌) depending on co-location), accumulates the contention
    terms, and hands both to the fused Pallas kernel
    :func:`repro.kernels.ops.batched_combined_lb`.

    With ``pair_ok``/``uplift`` present, cross edges whose rack pair shares
    no reachable subchannel are charged the wired uplift through the
    kernel's matching-feasibility mask, and the contention side gains the
    serial forced-wired load term (all such edges traverse the single wired
    channel). Both terms stay admissible: any feasible schedule must pay
    ``q`` on forced edges.
    """
    global LB_TRACE_COUNT
    LB_TRACE_COUNT += 1
    B, n_pad = racks.shape
    m_pad = src.shape[1]

    def take(t):
        return jnp.take(t, inst_id, axis=0)

    src_b, dst_b = take(src), take(dst)
    ru = jnp.take_along_axis(racks, src_b, axis=1)
    rv = jnp.take_along_axis(racks, dst_b, axis=1)
    same = ru == rv
    cost = jnp.where(same, take(c_local), take(c_net)) + take(p_src)
    # Batched static-index scatter: padded edges all write -inf at (0, 0),
    # which no real edge can occupy (self-loops are rejected by DagJob).
    w = jnp.full((B, n_pad, n_pad), -jnp.inf, jnp.float32)
    w = w.at[jnp.arange(B)[:, None], src_b, dst_b].set(cost)
    p_b = take(p_task)

    if pair_ok is not None:
        # Per-edge pair connectivity under each candidate's rack choice.
        pk = take(pair_ok)  # [B, M_pad, M_pad]
        ok = (
            jnp.take_along_axis(
                jnp.take_along_axis(pk, ru[:, :, None], axis=1),
                rv[:, :, None],
                axis=2,
            )[..., 0]
            > 0.5
        )
        # Additive matching-feasibility mask for the kernel: 0 on feasible
        # edges, the wired uplift on forced ones (same scatter as ``w``, so
        # parallel edges pair cost and uplift consistently).
        up = jnp.where(same | ok, 0.0, take(uplift))
        mask = jnp.zeros((B, n_pad, n_pad), jnp.float32)
        mask = mask.at[jnp.arange(B)[:, None], src_b, dst_b].set(up)
    else:
        ok = None
        mask = None

    if contention:
        # §IV-A contention terms, accumulated in a fixed sequential order so
        # an instance's bounds are bit-identical under any fleet padding
        # (padded tasks/edges contribute exact zeros).
        def load_body(v, load):
            rv = jax.lax.dynamic_index_in_dim(racks, v, axis=1, keepdims=False)
            pv = jax.lax.dynamic_index_in_dim(p_b, v, axis=1, keepdims=False)
            return load + jnp.where(
                jax.nn.one_hot(rv, M_pad, dtype=bool), pv[:, None], 0.0
            )

        load = jax.lax.fori_loop(
            0, n_pad, load_body, jnp.zeros((B, M_pad), jnp.float32)
        )
        lb_load = jnp.max(load, axis=1)

        nw = take(net_work)

        if ok is None:

            def work_body(e, acc):
                ne = jax.lax.dynamic_index_in_dim(nw, e, axis=1, keepdims=False)
                se = jax.lax.dynamic_index_in_dim(same, e, axis=1, keepdims=False)
                return acc + jnp.where(se, 0.0, ne)

            work = jax.lax.fori_loop(
                0, m_pad, work_body, jnp.zeros((B,), jnp.float32)
            )
            extra = jnp.maximum(lb_load, work / take(chan_div))
        else:
            # Forced cross edges pay the full wired duration in the
            # aggregate-work term and, being confined to the single wired
            # channel, also a serial forced-wired load bound.
            nw_eff = nw + jnp.where(ok, 0.0, take(uplift))

            def work_body_topo(e, acc):
                work, forced = acc
                ne = jax.lax.dynamic_index_in_dim(
                    nw_eff, e, axis=1, keepdims=False
                )
                se = jax.lax.dynamic_index_in_dim(same, e, axis=1, keepdims=False)
                oke = jax.lax.dynamic_index_in_dim(ok, e, axis=1, keepdims=False)
                return (
                    work + jnp.where(se, 0.0, ne),
                    forced + jnp.where(se | oke, 0.0, ne),
                )

            zero = jnp.zeros((B,), jnp.float32)
            work, forced = jax.lax.fori_loop(
                0, m_pad, work_body_topo, (zero, zero)
            )
            extra = jnp.maximum(
                jnp.maximum(lb_load, work / take(chan_div)), forced
            )
    else:
        extra = jnp.full((B,), -jnp.inf, jnp.float32)

    from repro.kernels import ops as kops

    return kops.batched_combined_lb(
        w, p_b, extra, mask=mask, block_b=min(block_b, B), n_iters=n_iters
    )


def batched_lower_bound(
    inst: ProblemInstance,
    racks: np.ndarray,
    use_kernel: bool = False,
    block_b: int = 1024,
    contention: bool = True,
) -> np.ndarray:
    """Combined §IV-A LB per assignment (critical path + contention terms).

    Critical path: dist[v] >= dist[u] + p_u + cost(u, v) where cost is r
    (same rack) or the optimistic network duration (different racks);
    converges in <= depth iterations. With ``contention=True`` (default)
    the result is maxed with the per-rack work and aggregate channel-work
    bounds of :mod:`repro.core.bounds`, which is what makes dense instances
    prunable at all.

    With ``use_kernel=True`` the whole bound runs through the fused Pallas
    path (`_fleet_lb_device` -> `repro.kernels.ops.batched_combined_lb`) on
    dense size-bucketed adjacency blocks — the production stage-1 path of
    `vectorized_search` / `schedule_fleet`. The edge-list jit path is the
    portable reference oracle.
    """
    job = inst.job
    n, m = job.n_tasks, job.n_edges
    racks = np.asarray(racks, dtype=np.int32)
    B = racks.shape[0]

    if use_kernel:
        # LB-only dims: no op tables needed (only the n/m/M buckets and the
        # relaxation depth feed the bound program).
        dims = _fleet_dims([inst], use_wireless=True)
        lb_args = _build_lb_arrays([inst], dims)
        B_pad = _bucket(B)
        racks_pad = np.zeros((B_pad, dims.n_pad), dtype=np.int32)
        racks_pad[:B, :n] = racks
        out = _fleet_lb_device(
            jnp.asarray(racks_pad),
            jnp.zeros(B_pad, jnp.int32),
            *lb_args,
            M_pad=dims.M_pad,
            n_iters=dims.n_iters,
            block_b=min(block_b, B_pad),
            contention=contention,
        )
        return np.asarray(out)[:B]

    if m == 0:
        base = np.broadcast_to(np.float32(np.max(job.p)), (B,)).astype(np.float32)
        if contention:
            extra = bounds_mod.contention_lower_bounds(inst, racks)
            base = np.maximum(base, extra.astype(np.float32))
        return base
    net = bounds_mod.min_network_durations(inst)

    p = jnp.asarray(job.p, dtype=jnp.float32)
    r = jnp.asarray(inst.r_local, dtype=jnp.float32)
    netc = jnp.asarray(net, dtype=jnp.float32)
    src = jnp.asarray(job.edges[:, 0].astype(np.int32))
    dst = jnp.asarray(job.edges[:, 1].astype(np.int32))
    topo = inst.topology
    conn = None if topo is None else jnp.asarray(topo.pair_connected())
    q_wired = jnp.asarray(inst.q_wired, dtype=jnp.float32)

    @jax.jit
    def lb(rk: jax.Array) -> jax.Array:
        if conn is None:
            netc_eff = netc
        else:
            # Forced-wired edges (rack pair shares no subchannel) pay q.
            netc_eff = jnp.where(conn[rk[:, src], rk[:, dst]], netc, q_wired)
        cost = jnp.where(rk[:, src] == rk[:, dst], r, netc_eff)
        dist = jnp.zeros((rk.shape[0], n), dtype=jnp.float32)

        def body(_, dist):
            cand = dist[:, src] + p[src] + cost
            return jnp.zeros_like(dist).at[:, dst].max(cand)

        dist = jax.lax.fori_loop(0, n - 1, body, dist)
        return jnp.max(dist + p[None, :], axis=1)

    out = np.asarray(lb(jnp.asarray(racks)))
    if contention:
        extra = bounds_mod.contention_lower_bounds(inst, racks)
        out = np.maximum(out, extra.astype(np.float32))
    return out


# ---------------------------------------------------------------------------
# Search driver: lockstep fleet state machines + mega-batch launches
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VectorizedResult:
    """Outcome of one instance's vectorized search.

    Attributes:
      schedule: the winning assignment re-executed *exactly* by the host
        simulator (OP-checked; can only improve on the device score).
      makespan: ``schedule.makespan``.
      n_evaluated: candidates scored by the stage-2 greedy evaluator.
      best_assignment: int64[n_tasks] winning task->rack assignment.
      n_candidates: candidates considered (``n_evaluated + n_pruned``).
      n_pruned: candidates discarded by the stage-1 §IV-A bound.
      refine_rounds: refinement rounds actually run (sampled regime only).
      strategy_stats: per-strategy refinement counters keyed by strategy
        name (:class:`repro.core.portfolio.StrategyStats`); all-zero when
        the instance was enumerated exhaustively or ``refine_rounds=0``.
    """

    schedule: Schedule
    makespan: float
    n_evaluated: int
    best_assignment: np.ndarray
    n_candidates: int = 0
    n_pruned: int = 0
    refine_rounds: int = 0
    strategy_stats: dict[str, portfolio_mod.StrategyStats] = dataclasses.field(
        default_factory=dict
    )


@dataclasses.dataclass
class FleetResult:
    """Outcome of one fleet mega-batch search.

    ``results[i]`` is bit-for-bit what ``vectorized_search(instances[i])``
    with the same parameters would return. Launch counters tell how many
    device dispatches the whole fleet cost; trace counters how many fresh
    program traces (0 when a same-bucket fleet already warmed the caches,
    at most one per stage otherwise).

    Attributes:
      results: per-instance :class:`VectorizedResult`, in input order.
      makespans: float64[n_instances] of per-instance makespans.
      n_candidates / n_pruned / n_evaluated: fleet-total candidate counters
        (sums of the per-instance counters).
      n_stage1_launches / n_stage2_launches: device dispatches per stage.
      n_stage1_traces / n_stage2_traces: fresh program traces per stage.
      strategy_stats: fleet-aggregated per-strategy refinement counters
        (counter sums; ``weight`` is the mean final allocator weight).
    """

    results: list[VectorizedResult]
    makespans: np.ndarray
    n_candidates: int
    n_pruned: int
    n_evaluated: int
    n_stage1_launches: int
    n_stage2_launches: int
    n_stage1_traces: int
    n_stage2_traces: int
    strategy_stats: dict[str, portfolio_mod.StrategyStats] = dataclasses.field(
        default_factory=dict
    )


# The refinement mutation kernel now lives in repro.core.portfolio (it is
# the "mutation" portfolio strategy); kept aliased for callers of the old
# private name.
_mutate_pool = portfolio_mod.mutate_pool


class _InstanceState:
    """Per-instance search state machine.

    Mirrors the single-instance candidate flow exactly — chunking, buffered
    stage-1 pruning against the running incumbent, fixed-size stage-2
    flushes, strict-improvement incumbent updates — while the fleet driver
    advances all states in lockstep and batches their device work into
    shared launches. Because each state's decisions depend only on its own
    rows (and per-row device results are padding-invariant), fleet results
    equal single-instance results bit for bit.
    """

    def __init__(
        self,
        idx: int,
        inst: ProblemInstance,
        *,
        seed: int,
        max_enumerate: int,
        n_samples: int,
        batch_size: int,
        strategies=None,
        refine_pool: int = 1024,
        patience: int = 1,
        seed_pool: np.ndarray | None = None,
    ):
        self.idx = idx
        self.inst = inst
        self.n = inst.job.n_tasks
        self.batch_size = batch_size
        M = inst.n_racks
        # Bell-number guard: enumerate if the canonical count fits the budget.
        cands = enumerate_assignments(self.n, M, limit=max_enumerate + 1)
        self.sampled = cands.shape[0] > max_enumerate
        if self.sampled:
            rng = np.random.default_rng(seed)
            # Warm-start seed pool: known-good assignments (e.g. incumbents
            # of a previous solve of the same job) lead the sweep so the
            # incumbent — and with it stage-1 pruning — is strong from the
            # first block. Budget-neutral: each seed row displaces one
            # random sample, so warm and cold runs consider the same
            # number of candidates (the random rows are drawn identically
            # and truncated, keeping the RNG stream comparable).
            random_rows = sample_assignments(rng, self.n, M, n_samples)
            parts = [
                enumerate_assignments(self.n, min(2, M), limit=n_samples),
                random_rows,
            ]
            if seed_pool is not None and len(seed_pool):
                seeds = np.asarray(seed_pool, dtype=np.int32).reshape(-1, self.n)
                seeds = (seeds % M)[:n_samples].astype(np.int32)
                parts = [seeds] + parts[:1] + [random_rows[: n_samples - seeds.shape[0]]]
            cands = np.concatenate(parts, axis=0)
        self.cands = cands
        self.pos = 0
        self.buffer: list[np.ndarray] = []
        self.tag_buffer: list[np.ndarray] = []
        self.buffered = 0
        self.best_val = np.inf
        self.best_rack: np.ndarray | None = None
        self.n_eval = 0
        self.n_pruned = 0
        self.n_cands = 0
        self.rng_refine = np.random.default_rng(seed + 1)
        self.refine_rounds_run = 0
        self.prev_best = np.inf
        self.patience = patience
        self.stall = 0
        self.portfolio = portfolio_mod.Portfolio(
            portfolio_mod.build_strategies(strategies),
            inst,
            self.rng_refine,
            pool_size=refine_pool,
        )

    def next_chunk(self) -> np.ndarray | None:
        if self.pos >= self.cands.shape[0]:
            return None
        chunk = self.cands[self.pos : self.pos + self.batch_size]
        self.pos += self.batch_size
        return chunk

    def consider(self, chunk: np.ndarray, lbs: np.ndarray | None, tags=None):
        """Prune a chunk against the incumbent, buffer survivors, emit any
        full stage-2 blocks. ``tags`` are per-row portfolio strategy ids
        (-1 = untagged sweep candidates) threaded through buffering so
        scores can be credited back. Returns [(state, block, true_b, tags)].
        """
        self.n_cands += chunk.shape[0]
        if tags is None:
            tags = np.full(chunk.shape[0], -1, dtype=np.int32)
        if lbs is not None:
            keep = lbs < self.best_val - 1e-6
            self.n_pruned += int((~keep).sum())
            self.portfolio.note_pruned(tags[~keep])
            chunk = chunk[keep]
            tags = tags[keep]
        if chunk.shape[0]:
            self.buffer.append(chunk)
            self.tag_buffer.append(tags)
            self.buffered += chunk.shape[0]
        return self._emit_full()

    def _cat_buffer(self):
        pool = (
            np.concatenate(self.buffer, axis=0)
            if len(self.buffer) > 1
            else self.buffer[0]
        )
        tags = (
            np.concatenate(self.tag_buffer, axis=0)
            if len(self.tag_buffer) > 1
            else self.tag_buffer[0]
        )
        return pool, tags

    def _emit_full(self):
        if self.buffered < self.batch_size:
            return []
        pool, tags = self._cat_buffer()
        bs = self.batch_size
        n_full = (pool.shape[0] // bs) * bs
        blocks = [
            (self, pool[i : i + bs], bs, tags[i : i + bs])
            for i in range(0, n_full, bs)
        ]
        tail, tail_tags = pool[n_full:], tags[n_full:]
        self.buffer = [tail] if tail.shape[0] else []
        self.tag_buffer = [tail_tags] if tail.shape[0] else []
        self.buffered = tail.shape[0]
        return blocks

    def flush_partial(self):
        """Emit everything still buffered (tail padded to the block size;
        pad-row scores are discarded on apply)."""
        blocks = self._emit_full()
        if self.buffered:
            tail, tail_tags = self._cat_buffer()
            true_b = tail.shape[0]
            block = np.concatenate(
                [tail, np.tile(tail[:1], (self.batch_size - true_b, 1))], axis=0
            )
            blocks.append((self, block, true_b, tail_tags))
            self.buffer = []
            self.tag_buffer = []
            self.buffered = 0
        return blocks

    def apply_scores(self, block: np.ndarray, vals: np.ndarray, tags) -> None:
        """Strict-improvement incumbent update over one block's true rows,
        then feed the scored rows back to the portfolio (elite pool plus
        per-strategy credit for tagged refinement rows)."""
        self.n_eval += vals.shape[0]
        prev_best = self.best_val
        j = int(np.argmin(vals))
        if vals[j] < self.best_val:
            self.best_val = float(vals[j])
            self.best_rack = block[j].astype(np.int64)
        self.portfolio.observe(tags, block[: vals.shape[0]], vals, prev_best)


def _run_fleet(
    instances: list[ProblemInstance],
    *,
    max_enumerate: int,
    n_samples: int,
    seeds: list[int],
    use_wireless: bool,
    batch_size: int,
    lb_prune: bool,
    use_kernel: bool,
    contention: bool,
    refine_rounds: int,
    refine_pool: int,
    strategies=None,
    refine_patience: int | None = None,
    seed_pools=None,
    op_tables=None,
    tracer=None,
):
    """Lockstep fleet driver: one mega-batch launch geometry per stage.

    Every stage-1 launch is ``[I * batch_size]`` rows and every stage-2
    launch ``[I * batch_size]`` rounded up to the device count, so the whole
    fleet run traces (at most) one program per stage no matter how pruning
    fragments the candidate streams.

    ``tracer`` (a :class:`repro.obs.trace.Tracer` or ``None``) records a
    wall-time span per stage-1/stage-2 device dispatch, the fleet's
    candidate/prune/launch/retrace totals as a ``fleet_solve`` event, and
    the per-strategy refinement yields as a ``portfolio_yields`` event.
    """
    tr = as_tracer(tracer)
    I = len(instances)
    if op_tables is None:
        op_tables = [build_op_tables(inst) for inst in instances]
    dims = _fleet_dims(instances, use_wireless, op_tables)
    eval_tables = _build_eval_stack(instances, dims, use_wireless, op_tables)
    lb_args = _build_lb_arrays(instances, dims) if use_kernel else None
    n_dev = jax.local_device_count()
    fn = _compiled_evaluator(n_dev, dims.m_pad, dims.M_pad, dims.n_chan)
    t2_0, t1_0 = TRACE_COUNT, LB_TRACE_COUNT
    launches = [0, 0]  # [stage1, stage2]

    B1 = I * batch_size
    B2 = I * batch_size
    if B2 % n_dev:
        B2 += n_dev - B2 % n_dev

    # Patience default: stop at the first non-improving round (the
    # pre-portfolio rule) for a single strategy; give multi-strategy
    # portfolios a few stalled rounds so annealing can tunnel.
    if refine_patience is None:
        refine_patience = 1 if portfolio_mod.spec_length(strategies) == 1 else 3
    if seed_pools is None:
        seed_pools = [None] * I
    states = [
        _InstanceState(
            i,
            inst,
            seed=seeds[i],
            max_enumerate=max_enumerate,
            n_samples=n_samples,
            batch_size=batch_size,
            strategies=strategies,
            refine_pool=refine_pool,
            patience=refine_patience,
            seed_pool=seed_pools[i],
        )
        for i, inst in enumerate(instances)
    ]

    def launch_stage2(blocks) -> None:
        # blocks: [(state, block[batch_size, state.n], true_b, tags)],
        # applied in order so per-state incumbent evolution matches the
        # solo flow.
        for g0 in range(0, len(blocks), I):
            group = blocks[g0 : g0 + I]
            rack = np.zeros((B2, dims.n_pad), dtype=np.int32)
            iid = np.zeros(B2, dtype=np.int32)
            for s, (st, blk, _tb, _tg) in enumerate(group):
                lo = s * batch_size
                rack[lo : lo + batch_size, : st.n] = blk
                iid[lo : lo + batch_size] = st.idx
            with tr.span("stage2_launch", rows=B2):
                vals = np.asarray(
                    fn(jnp.asarray(rack), jnp.asarray(iid), *eval_tables)
                )
            launches[1] += 1
            for s, (st, blk, tb, tg) in enumerate(group):
                lo = s * batch_size
                st.apply_scores(blk, vals[lo : lo + tb], tg)

    def launch_stage1(reqs):
        # reqs: [(state, chunk)] -> per-request float32 LB arrays.
        if not reqs:
            return []
        if not use_kernel:
            launches[0] += len(reqs)
            with tr.span("stage1_launch", n_requests=len(reqs), kernel=False):
                return [
                    batched_lower_bound(
                        st.inst, chunk, use_kernel=False, contention=contention
                    )
                    for st, chunk in reqs
                ]
        out = [np.empty(chunk.shape[0], np.float32) for _, chunk in reqs]
        pieces = []
        for ri, (_st, chunk) in enumerate(reqs):
            for off in range(0, chunk.shape[0], batch_size):
                pieces.append((ri, off, chunk[off : off + batch_size]))
        for g0 in range(0, len(pieces), I):
            group = pieces[g0 : g0 + I]
            rack = np.zeros((B1, dims.n_pad), dtype=np.int32)
            iid = np.zeros(B1, dtype=np.int32)
            for s, (ri, _off, rows) in enumerate(group):
                st = reqs[ri][0]
                lo = s * batch_size
                rack[lo : lo + rows.shape[0], : st.n] = rows
                iid[lo : lo + batch_size] = st.idx
            with tr.span("stage1_launch", rows=B1, kernel=True):
                lbs = np.asarray(
                    _fleet_lb_device(
                        jnp.asarray(rack),
                        jnp.asarray(iid),
                        *lb_args,
                        M_pad=dims.M_pad,
                        n_iters=dims.n_iters,
                        block_b=min(1024, B1),
                        contention=contention,
                    )
                )
            launches[0] += 1
            for s, (ri, off, rows) in enumerate(group):
                lo = s * batch_size
                out[ri][off : off + rows.shape[0]] = lbs[lo : lo + rows.shape[0]]
        return out

    def prune_and_score(round_chunks) -> None:
        prune_reqs = [
            (st, chunk)
            for st, chunk in round_chunks
            if lb_prune and np.isfinite(st.best_val)
        ]
        lbs_list = launch_stage1(prune_reqs)
        lbs_by_state = {
            id(st): lbs for (st, _), lbs in zip(prune_reqs, lbs_list)
        }
        blocks = []
        for st, chunk in round_chunks:
            blocks += st.consider(chunk, lbs_by_state.get(id(st)))
        launch_stage2(blocks)

    # Main sweep: one chunk per instance per lockstep round.
    while any(st.pos < st.cands.shape[0] for st in states):
        round_chunks = []
        for st in states:
            chunk = st.next_chunk()
            if chunk is not None:
                round_chunks.append((st, chunk))
        prune_and_score(round_chunks)
    blocks = []
    for st in states:
        blocks += st.flush_partial()
    launch_stage2(blocks)
    for st in states:
        assert st.best_rack is not None

    # Refinement: the lockstep strategy portfolio for sampled-regime
    # instances. Each round every active instance's portfolio proposes one
    # tagged candidate pool (budget split across strategies by recent
    # yield); proposals ride the shared stage-1/stage-2 launches exactly
    # like sweep candidates. An instance stops independently after
    # ``patience`` consecutive non-improving rounds.
    active = [st for st in states if st.sampled] if refine_rounds > 0 else []
    for _ in range(refine_rounds):
        if not active:
            break
        round_chunks = []
        for st in active:
            st.prev_best = st.best_val
            pool, tags = st.portfolio.begin_round(st.best_rack, st.best_val)
            round_chunks.append((st, pool, tags))
        prune_reqs = [
            (st, chunk)
            for st, chunk, _tags in round_chunks
            if lb_prune and np.isfinite(st.best_val) and chunk.shape[0]
        ]
        lbs_list = launch_stage1(prune_reqs)
        lbs_by_state = {id(st): lbs for (st, _), lbs in zip(prune_reqs, lbs_list)}
        blocks = []
        for st, chunk, tags in round_chunks:
            blocks += st.consider(chunk, lbs_by_state.get(id(st)), tags=tags)
            blocks += st.flush_partial()
        launch_stage2(blocks)
        nxt = []
        for st in active:
            st.portfolio.end_round(st.best_rack, st.best_val)
            st.refine_rounds_run += 1
            if st.best_val < st.prev_best - 1e-9:
                st.stall = 0
            else:
                st.stall += 1
            if st.stall < st.patience:
                nxt.append(st)
        active = nxt

    results = []
    for st in states:
        sched = simulate(st.inst, st.best_rack, use_wireless=use_wireless)
        results.append(
            VectorizedResult(
                schedule=sched,
                makespan=sched.makespan,
                n_evaluated=st.n_eval,
                best_assignment=st.best_rack,
                n_candidates=st.n_cands,
                n_pruned=st.n_pruned,
                refine_rounds=st.refine_rounds_run,
                strategy_stats=st.portfolio.stats,
            )
        )
    stats = {
        "n_stage1_launches": launches[0],
        "n_stage2_launches": launches[1],
        "n_stage1_traces": LB_TRACE_COUNT - t1_0,
        "n_stage2_traces": TRACE_COUNT - t2_0,
    }
    if tr.enabled:
        tr.count("stage1_launches", launches[0])
        tr.count("stage2_launches", launches[1])
        tr.count(
            "compile_cache_misses",
            stats["n_stage1_traces"] + stats["n_stage2_traces"],
        )
        tr.event(
            "fleet_solve",
            n_instances=I,
            n_candidates=sum(s.n_cands for s in states),
            n_pruned=sum(s.n_pruned for s in states),
            n_evaluated=sum(s.n_eval for s in states),
            **stats,
        )
        merged = portfolio_mod.merge_strategy_stats(
            s.portfolio.stats for s in states
        )
        if merged:
            tr.event(
                "portfolio_yields",
                strategies=portfolio_mod.stats_snapshot(merged),
            )
    return results, stats


def vectorized_search(
    inst: ProblemInstance,
    max_enumerate: int = 200_000,
    n_samples: int = 8192,
    seed: int = 0,
    use_wireless: bool = True,
    batch_size: int = 8192,
    lb_prune: bool = True,
    use_kernel: bool = True,
    refine_rounds: int = 4,
    refine_pool: int = 1024,
    contention: bool = True,
    strategies=None,
    refine_patience: int | None = None,
    seed_pool: np.ndarray | None = None,
    tracer=None,
) -> VectorizedResult:
    """Best-of-batch schedule search with bound-driven pruning.

    Enumerates all canonical assignments when that is small enough, else
    samples. Each batch first passes through the combined §IV-A Pallas
    bound (stage 1); only candidates whose bound beats the incumbent are
    scheduled by the batched greedy evaluator (stage 2). In the sampled
    regime the incumbent is refined by the strategy portfolio of
    :mod:`repro.core.portfolio`. The winner is re-executed with the exact
    host simulator (which can only improve on the vectorized non-delay
    score) and verified. The fleet-of-one special case of
    :func:`schedule_fleet`.

    Args:
      inst: the problem instance.
      max_enumerate: enumerate exhaustively iff the canonical assignment
        count (restricted growth strings) is at most this; else sample.
      n_samples: random candidates in the sampled regime (plus a 2-rack
        canonical prefix of the same size).
      seed: master seed. Sampling uses ``default_rng(seed)``; refinement
        draws from ``default_rng(seed + 1)``. Fixed seed + fixed
        parameters => bit-identical results across runs and across fleet
        packings (device scores are float32-deterministic on one backend).
      use_wireless: expose the instance's wireless subchannels to the
        evaluator (``False`` models wired-only operation).
      batch_size: stage-2 block size; candidate streams are chunked,
        pruned, and re-blocked to exactly this many rows per launch.
      lb_prune: enable stage-1 pruning (exact w.r.t. the greedy objective:
        ``LB(c) >= incumbent`` implies c cannot improve the incumbent).
      use_kernel: stage-1 via the fused Pallas kernel (else the portable
        edge-list jit oracle).
      refine_rounds: max refinement rounds (sampled regime only).
      refine_pool: per-round refinement candidate budget, split across the
        portfolio's strategies by recent yield.
      contention: include the §IV-A contention terms (per-rack work +
        aggregate channel work) in the stage-1 bound.
      strategies: refinement portfolio spec for
        :func:`repro.core.portfolio.build_strategies`. ``None`` (default)
        is mutation-only local search — bit-for-bit the pre-portfolio
        refinement loop; ``"portfolio"`` enables
        mutation + elite crossover + simulated annealing under the
        multiplicative-weights budget allocator.
      refine_patience: stop refining after this many consecutive
        non-improving rounds. ``None`` => 1 for a single strategy (the
        pre-portfolio rule), 3 for a multi-strategy portfolio.
      seed_pool: optional int[S, n_tasks] warm-start assignments (e.g.
        incumbents from a previous solve of the same job) injected at the
        head of the sampled-regime sweep. Budget-neutral: each seed
        displaces one random sample, so ``n_candidates`` is unchanged.
        Labels are folded into ``[0, n_racks)`` with a modulo, letting
        incumbents from a differently-sized resource view seed a residual
        re-solve. Ignored in the exhaustive-enumeration regime (the sweep
        already covers every canonical assignment). Scored seeds enter
        the refinement portfolio's elite pool like any sweep candidate,
        so crossover can recombine them from round one.
      tracer: optional :class:`repro.obs.trace.Tracer` recording
        per-stage device-dispatch spans and the solve's candidate /
        prune / retrace totals (``None`` = no tracing; bit-identical).

    Returns:
      :class:`VectorizedResult` (per-strategy refinement counters in
      ``strategy_stats``).
    """
    tr = as_tracer(tracer)
    with tr.span("schedule_fleet", n_instances=1):
        results, _ = _run_fleet(
            [inst],
            max_enumerate=max_enumerate,
            n_samples=n_samples,
            seeds=[seed],
            use_wireless=use_wireless,
            batch_size=batch_size,
            lb_prune=lb_prune,
            use_kernel=use_kernel,
            contention=contention,
            refine_rounds=refine_rounds,
            refine_pool=refine_pool,
            strategies=strategies,
            refine_patience=refine_patience,
            seed_pools=[seed_pool],
            tracer=tr,
        )
    return results[0]


def schedule_fleet(
    instances,
    max_enumerate: int = 200_000,
    n_samples: int = 8192,
    seed=0,
    use_wireless: bool = True,
    batch_size: int = 8192,
    lb_prune: bool = True,
    use_kernel: bool = True,
    refine_rounds: int = 4,
    refine_pool: int = 1024,
    contention: bool = True,
    strategies=None,
    refine_patience: int | None = None,
    seed_pools=None,
    op_tables=None,
    tracer=None,
) -> FleetResult:
    """Solve a heterogeneous fleet of instances in one padded mega-batch.

    All instances are padded to one shared size bucket and their candidate
    streams advance in lockstep: each round contributes one chunk per
    instance to a single stage-1 bound launch and the survivors to a single
    sharded stage-2 evaluation launch, so the whole fleet compiles at most
    one program per stage and amortizes every dispatch across jobs.
    Refinement proposals (one tagged pool per instance per round, from that
    instance's private strategy portfolio) ride the same shared launches.

    Args:
      instances: iterable of :class:`ProblemInstance` (at least one).
      seed: scalar (shared by all instances) or one seed per instance.
      strategies: portfolio spec shared by all instances; each instance
        gets its own freshly built strategy objects, so pass registry
        names (e.g. ``"portfolio"`` or ``("mutation", "crossover")``) or
        zero-arg factories — live Strategy objects would alias state
        across the fleet and are rejected for fleets of more than one.
      seed_pools: ``None``, or one warm-start pool per instance (each
        ``None`` or int[S, n_tasks]; see ``seed_pool`` on
        :func:`vectorized_search`). The online serving layer uses this to
        re-optimize still-queued jobs from their incumbent assignments.
      op_tables: ``None``, or one prebuilt
        :class:`~repro.core.simulator.OpTables` per instance. Tables
        depend only on ``inst.job``, so a caller that re-solves the same
        jobs across epochs (the online service) can build each job's
        tables once and skip the per-launch rebuild; passing ``None``
        builds them here. Results are bit-identical either way.
      tracer: optional :class:`repro.obs.trace.Tracer`. Records a
        ``schedule_fleet`` span enclosing per-stage device-dispatch
        spans, plus ``fleet_solve`` (candidates / pruned / launches /
        retraces) and ``portfolio_yields`` decision events. ``None``
        (default) traces nothing and is bit-identical.
      (remaining arguments: see :func:`vectorized_search`.)

    Determinism / solo equivalence: with the same seed and parameters,
    ``results[i]`` is bit-for-bit identical to
    ``vectorized_search(instances[i], ...)`` run alone — fleet packing
    never changes any per-instance score, prune decision, or RNG draw.

    Returns:
      :class:`FleetResult` with per-instance results, fleet candidate /
      launch / trace counters, and fleet-aggregated ``strategy_stats``.
    """
    instances = list(instances)
    if not instances:
        raise ValueError("schedule_fleet needs at least one instance")
    if len(instances) > 1 and strategies is not None and not isinstance(strategies, str):
        for item in strategies:
            if (
                not isinstance(item, (str, type))
                and hasattr(item, "propose")
            ):
                raise ValueError(
                    "fleets need per-instance strategy state: pass names or "
                    "factories, not live Strategy objects"
                )
    if np.ndim(seed) == 0:
        seeds = [int(seed)] * len(instances)
    else:
        seeds = [int(s) for s in seed]
        if len(seeds) != len(instances):
            raise ValueError("one seed per instance required")
    if seed_pools is not None and len(seed_pools) != len(instances):
        raise ValueError("one seed pool (or None) per instance required")
    if op_tables is not None and len(op_tables) != len(instances):
        raise ValueError("one OpTables per instance required")
    tr = as_tracer(tracer)
    with tr.span("schedule_fleet", n_instances=len(instances)):
        results, stats = _run_fleet(
            instances,
            max_enumerate=max_enumerate,
            n_samples=n_samples,
            seeds=seeds,
            use_wireless=use_wireless,
            batch_size=batch_size,
            lb_prune=lb_prune,
            use_kernel=use_kernel,
            contention=contention,
            refine_rounds=refine_rounds,
            refine_pool=refine_pool,
            strategies=strategies,
            refine_patience=refine_patience,
            seed_pools=seed_pools,
            op_tables=op_tables,
            tracer=tr,
        )
    return FleetResult(
        results=results,
        makespans=np.asarray([r.makespan for r in results]),
        n_candidates=sum(r.n_candidates for r in results),
        n_pruned=sum(r.n_pruned for r in results),
        n_evaluated=sum(r.n_evaluated for r in results),
        strategy_stats=portfolio_mod.merge_strategy_stats(
            r.strategy_stats for r in results
        ),
        **stats,
    )
