"""JAX-vectorized schedule search (beyond-paper, TPU-native).

The paper's solver is host-side B&B. On TPU-class hardware the natural
adaptation of its *search* is massive data parallelism: evaluate tens of
thousands of candidate rack assignments simultaneously as one batched tensor
program. This module implements that search as a two-stage, device-sharded
batch engine:

  Stage 1 (bound): the critical-path lower bound of every candidate in the
  batch is computed with :func:`repro.kernels.ops.batched_critical_path`
  (the Pallas ``cpm`` kernel — iterated max-plus relaxation on dense
  adjacency blocks). Candidates whose bound already meets the running
  incumbent are discarded without ever being scheduled.

  Stage 2 (evaluate): survivors are scored by a greedy non-delay schedule
  executed in lock-step across the batch. The evaluator is a single
  ``lax.scan`` over a *static op table* — padded int32/float32 tables
  (kind / task / edge / endpoints / durations / in-edge lists, built by
  :func:`repro.core.simulator.build_op_tables`) describing the interleaved
  (edge*, task) sequence in topological order. Because the tables are scan
  inputs rather than Python-unrolled constants, one compiled program serves
  every instance that fits the same size bucket; new instances cost zero
  recompilation. Batches are sharded across local devices with ``shard_map``
  when more than one device is present, degrading gracefully to a plain
  ``jit`` on a single-device (CPU) host.

A seeded local-search refinement loop mutates the incumbent's assignment and
feeds the mutants back through the same two stages, so the sampled regime
(instances too big to enumerate) converges instead of being one-shot.

This module is an *incumbent generator / pruner*: the winning assignment is
re-executed exactly with the host simulator and verified by the OP checker.
Exactness guarantees come from `bnb`/`solver_milp`; tests assert the
vectorized score is always >= the exact optimum and == the simulator's
makespan for the reconstructed schedule. Pruning is exact with respect to
the greedy objective: greedy(c) >= LB(c), so LB(c) >= incumbent implies c
cannot improve the incumbent.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.instance import ProblemInstance
from repro.core.schedule import Schedule
from repro.core.simulator import OP_PAD, OP_TASK, build_op_tables, simulate

__all__ = [
    "enumerate_assignments",
    "sample_assignments",
    "make_batched_evaluator",
    "batched_lower_bound",
    "vectorized_search",
    "VectorizedResult",
]


def enumerate_assignments(n: int, max_racks: int, limit: int | None = None) -> np.ndarray:
    """All canonical task->rack assignments (restricted growth strings).

    Canonical = rack labels appear in first-use order, which quotients out
    rack-relabelling symmetry. Returns int32[count, n].
    """
    out: list[list[int]] = []

    def rec(prefix: list[int], n_used: int) -> None:
        if limit is not None and len(out) >= limit:
            return
        if len(prefix) == n:
            out.append(list(prefix))
            return
        for i in range(min(n_used + 1, max_racks)):
            prefix.append(i)
            rec(prefix, max(n_used, i + 1))
            prefix.pop()
            if limit is not None and len(out) >= limit:
                return

    rec([], 0)
    return np.asarray(out, dtype=np.int32).reshape(-1, n)


def sample_assignments(
    rng: np.random.Generator, n: int, max_racks: int, count: int
) -> np.ndarray:
    """Random assignments (not canonicalized; used when enumeration is big)."""
    return rng.integers(0, max_racks, size=(count, n), dtype=np.int32).astype(np.int32)


# ---------------------------------------------------------------------------
# Size buckets
# ---------------------------------------------------------------------------

def _bucket(x: int, lo: int = 8) -> int:
    """Smallest power of two >= max(x, lo): the size-bucket rounding used for
    every padded dimension so compiled programs are shared across instances."""
    b = lo
    while b < x:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# Stage-2 evaluator: op-table lax.scan program
# ---------------------------------------------------------------------------

# Incremented each time the scan evaluator is traced; lets tests assert that
# instances sharing a size bucket reuse the compiled program.
TRACE_COUNT = 0


def _scan_evaluate(
    rack,       # int32[B, n_pad]
    kind,       # int32[n_ops]   OP_TASK / OP_EDGE / OP_PAD
    op_task,    # int32[n_ops]   task id for OP_TASK rows (0 otherwise)
    op_edge,    # int32[n_ops]   edge id for OP_EDGE rows (0 otherwise)
    op_src,     # int32[n_ops]   edge source task (0 otherwise)
    op_dst,     # int32[n_ops]   edge dest task (0 otherwise)
    op_p,       # f32[n_ops]     task duration
    op_wired,   # f32[n_ops]     wired transfer duration
    op_wireless,  # f32[n_ops]   wireless transfer duration
    op_local,   # f32[n_ops]     local transfer delay
    op_in,      # int32[n_ops, indeg_pad] in-edge ids gating a task row;
                #                the sentinel id m_pad always reads 0.0
    *,
    m_pad: int,
    M_pad: int,
    n_chan: int,
):
    global TRACE_COUNT
    TRACE_COUNT += 1
    B = rack.shape[0]
    carry0 = (
        jnp.zeros((B, M_pad), jnp.float32),      # rack_free
        jnp.zeros((B, n_chan), jnp.float32),     # chan_free
        jnp.zeros((B, rack.shape[1]), jnp.float32),  # task_fin
        jnp.zeros((B, m_pad + 1), jnp.float32),  # edge_fin (+1 sentinel col)
    )
    xs = (kind, op_task, op_edge, op_src, op_dst, op_p, op_wired, op_wireless,
          op_local, op_in)

    def step(carry, x):
        kind_t, t_v, e_id, u, v, p_v, q_w, q_wl, r_l, in_row = x

        def do_task(carry):
            rack_free, chan_free, task_fin, edge_fin = carry
            ready = jnp.max(jnp.take(edge_fin, in_row, axis=1), axis=1)
            rv = jnp.take(rack, t_v, axis=1)
            free_v = jnp.take_along_axis(rack_free, rv[:, None], axis=1)[:, 0]
            fin = jnp.maximum(ready, free_v) + p_v
            rack_free = jnp.where(
                jax.nn.one_hot(rv, M_pad, dtype=bool), fin[:, None], rack_free
            )
            task_fin = task_fin.at[:, t_v].set(fin)
            return rack_free, chan_free, task_fin, edge_fin

        def do_edge(carry):
            rack_free, chan_free, task_fin, edge_fin = carry
            ready = jnp.take(task_fin, u, axis=1)
            same = jnp.take(rack, u, axis=1) == jnp.take(rack, v, axis=1)
            # Local path: no resource, duration r.
            fin_local = ready + r_l
            # Network path: earliest-finish channel (0 wired, 1.. wireless).
            durs = jnp.concatenate(
                [q_w[None], jnp.broadcast_to(q_wl, (n_chan - 1,))]
            )
            s = jnp.maximum(ready[:, None], chan_free)
            f = s + durs[None, :]
            best = jnp.argmin(f, axis=1)
            fin_net = jnp.take_along_axis(f, best[:, None], axis=1)[:, 0]
            new_free = jnp.where(
                jax.nn.one_hot(best, n_chan, dtype=bool), fin_net[:, None], chan_free
            )
            chan_free = jnp.where(same[:, None], chan_free, new_free)
            fin = jnp.where(same, fin_local, fin_net)
            edge_fin = edge_fin.at[:, e_id].set(fin)
            return rack_free, chan_free, task_fin, edge_fin

        return jax.lax.switch(kind_t, (do_task, do_edge, lambda c: c), carry), None

    (_, _, task_fin, _), _ = jax.lax.scan(step, carry0, xs)
    return jnp.max(task_fin, axis=1)


@functools.lru_cache(maxsize=None)
def _compiled_evaluator(n_dev: int, m_pad: int, M_pad: int, n_chan: int):
    """Jitted (and, with >1 local device, shard_map-sharded) scan evaluator.

    The returned callable is cached per (device count, static dims); jit then
    caches per concrete table/batch shape — so any two instances in the same
    size bucket share one compiled program.
    """
    core = functools.partial(
        _scan_evaluate, m_pad=m_pad, M_pad=M_pad, n_chan=n_chan
    )
    if n_dev <= 1:
        return jax.jit(core)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    # Local devices only: batch padding in make_batched_evaluator is sized by
    # local_device_count, and each process shards its own host-local batch.
    mesh = Mesh(np.asarray(jax.local_devices()), ("b",))
    rep1, rep2 = P(None), P(None, None)
    sharded = shard_map(
        core,
        mesh=mesh,
        in_specs=(P("b", None), rep1, rep1, rep1, rep1, rep1, rep1, rep1,
                  rep1, rep1, rep2),
        out_specs=P("b"),
        check_rep=False,
    )
    return jax.jit(sharded)


@dataclasses.dataclass(frozen=True)
class _EvalTables:
    """Device-ready padded op tables plus the static dims of their bucket."""

    kind: jax.Array
    op_task: jax.Array
    op_edge: jax.Array
    op_src: jax.Array
    op_dst: jax.Array
    op_p: jax.Array
    op_wired: jax.Array
    op_wireless: jax.Array
    op_local: jax.Array
    op_in: jax.Array
    n_pad: int
    m_pad: int
    M_pad: int
    n_chan: int


def _build_eval_tables(inst: ProblemInstance, use_wireless: bool) -> _EvalTables:
    job = inst.job
    n, m, M = job.n_tasks, job.n_edges, inst.n_racks
    n_chan = 1 + (inst.n_wireless if use_wireless else 0)
    tables = build_op_tables(inst)

    n_ops = _bucket(tables.n_ops)
    n_pad = _bucket(n)
    m_pad = _bucket(max(m, 1))
    M_pad = _bucket(M, lo=2)
    indeg_pad = _bucket(tables.task_in_edges.shape[1], lo=4)

    kind = np.full(n_ops, OP_PAD, dtype=np.int32)
    op_task = np.zeros(n_ops, dtype=np.int32)
    op_edge = np.zeros(n_ops, dtype=np.int32)
    op_src = np.zeros(n_ops, dtype=np.int32)
    op_dst = np.zeros(n_ops, dtype=np.int32)
    op_p = np.zeros(n_ops, dtype=np.float32)
    op_wired = np.zeros(n_ops, dtype=np.float32)
    op_wireless = np.zeros(n_ops, dtype=np.float32)
    op_local = np.zeros(n_ops, dtype=np.float32)
    # Sentinel edge id m_pad indexes the always-zero extra column of edge_fin.
    op_in = np.full((n_ops, indeg_pad), m_pad, dtype=np.int32)

    q, qw, r = inst.q_wired, inst.q_wireless, inst.r_local
    for row in range(tables.n_ops):
        k, i = int(tables.kind[row]), int(tables.idx[row])
        kind[row] = k
        if k == OP_TASK:
            op_task[row] = i
            op_p[row] = job.p[i]
            ins = tables.task_in_edges[i]
            ins = ins[ins >= 0]
            op_in[row, : ins.size] = ins
        else:
            op_edge[row] = i
            op_src[row] = tables.edge_src[i]
            op_dst[row] = tables.edge_dst[i]
            op_wired[row] = q[i]
            op_wireless[row] = qw[i]
            op_local[row] = r[i]

    return _EvalTables(
        kind=jnp.asarray(kind),
        op_task=jnp.asarray(op_task),
        op_edge=jnp.asarray(op_edge),
        op_src=jnp.asarray(op_src),
        op_dst=jnp.asarray(op_dst),
        op_p=jnp.asarray(op_p),
        op_wired=jnp.asarray(op_wired),
        op_wireless=jnp.asarray(op_wireless),
        op_local=jnp.asarray(op_local),
        op_in=jnp.asarray(op_in),
        n_pad=n_pad,
        m_pad=m_pad,
        M_pad=M_pad,
        n_chan=n_chan,
    )


def make_batched_evaluator(inst: ProblemInstance, use_wireless: bool = True):
    """Build a fn: rack[B, n] int -> makespan[B] float32 (greedy non-delay).

    The returned callable pads its batch to the evaluator's size bucket
    (batch to a power of two times the local device count, tasks to the
    bucket task count) and dispatches the shared compiled scan program —
    identical instances never retrace, and instances of similar size share
    one compiled program per bucket.
    """
    t = _build_eval_tables(inst, use_wireless)
    n = inst.job.n_tasks
    n_dev = jax.local_device_count()
    fn = _compiled_evaluator(n_dev, t.m_pad, t.M_pad, t.n_chan)
    table_args = (
        t.kind, t.op_task, t.op_edge, t.op_src, t.op_dst, t.op_p,
        t.op_wired, t.op_wireless, t.op_local, t.op_in,
    )

    def evaluate(rack) -> jax.Array:
        rack = np.asarray(rack, dtype=np.int32)
        B = rack.shape[0]
        B_pad = _bucket(B) * (n_dev if _bucket(B) % n_dev else 1)
        padded = np.zeros((B_pad, t.n_pad), dtype=np.int32)
        padded[:B, :n] = rack
        return fn(jnp.asarray(padded), *table_args)[:B]

    evaluate.tables = t
    return evaluate


# ---------------------------------------------------------------------------
# Stage-1 bound: Pallas cpm kernel over dense max-plus adjacency
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_pad",))
def _dense_maxplus_w(racks, src, dst, p_src, r, netc, *, n_pad: int):
    """w[B, n_pad, n_pad] max-plus adjacency per candidate assignment.

    Edge positions are identical across the batch, so this is one batched
    static-index scatter (edges are unique by construction; padded edges all
    write -inf at (0, 0), which no real edge can occupy — self-loops are
    rejected by DagJob). Padded nodes have no incident edges, so their dist
    stays 0 and never dominates the final max.
    """
    cost = jnp.where(racks[:, src] == racks[:, dst], r, netc) + p_src
    w = jnp.full((racks.shape[0], n_pad, n_pad), -jnp.inf, dtype=jnp.float32)
    # No unique_indices: every padded edge writes -inf at (0, 0).
    return w.at[:, src, dst].set(cost, mode="drop")


def batched_lower_bound(
    inst: ProblemInstance,
    racks: np.ndarray,
    use_kernel: bool = False,
    block_b: int = 1024,
) -> np.ndarray:
    """Critical-path LB per assignment via iterated max-plus relaxation.

    dist[v] >= dist[u] + p_u + cost(u, v) where cost is r (same rack) or the
    optimistic network duration (different racks). Converges in <= depth
    iterations.

    With ``use_kernel=True`` the relaxation runs through the Pallas ``cpm``
    kernel (`repro.kernels.ops.batched_critical_path`) on dense size-bucketed
    adjacency blocks — the production stage-1 path of `vectorized_search`.
    The edge-list jit path is the portable reference oracle.
    """
    job = inst.job
    n, m = job.n_tasks, job.n_edges
    racks = np.asarray(racks, dtype=np.int32)
    if m == 0:
        return np.broadcast_to(
            np.float32(np.max(job.p)), (racks.shape[0],)
        ).astype(np.float32)
    net = np.minimum(inst.q_wired, inst.q_wireless) if inst.n_wireless else inst.q_wired

    p = jnp.asarray(job.p, dtype=jnp.float32)
    r = jnp.asarray(inst.r_local, dtype=jnp.float32)
    netc = jnp.asarray(net, dtype=jnp.float32)
    src = jnp.asarray(job.edges[:, 0].astype(np.int32))
    dst = jnp.asarray(job.edges[:, 1].astype(np.int32))

    if use_kernel:
        from repro.kernels import ops as kops

        B = racks.shape[0]
        B_pad = _bucket(B)
        n_pad = _bucket(n)
        m_pad = _bucket(m, lo=1)
        # Bucket every dim so the build + kernel compile once per bucket:
        # padded batch rows are zero-filled (sliced off before return),
        # padded edges scatter -inf (a no-op).
        racks_pad = np.zeros((B_pad, n), dtype=np.int32)
        racks_pad[:B] = racks
        src_pad = np.zeros(m_pad, dtype=np.int32)
        dst_pad = np.zeros(m_pad, dtype=np.int32)
        src_pad[:m] = job.edges[:, 0]
        dst_pad[:m] = job.edges[:, 1]
        cost_pad = np.full((3, m_pad), -np.inf, dtype=np.float32)
        cost_pad[0, :m] = job.p[job.edges[:, 0]]
        cost_pad[1, :m] = inst.r_local
        cost_pad[2, :m] = net
        w = _dense_maxplus_w(
            jnp.asarray(racks_pad),
            jnp.asarray(src_pad),
            jnp.asarray(dst_pad),
            jnp.asarray(cost_pad[0]),
            jnp.asarray(cost_pad[1]),
            jnp.asarray(cost_pad[2]),
            n_pad=n_pad,
        )
        dist = kops.batched_critical_path(
            w, block_b=min(block_b, B_pad), n_iters=n - 1
        )
        p_full = jnp.zeros(n_pad, jnp.float32).at[:n].set(p)
        return np.asarray(jnp.max(dist + p_full[None, :], axis=1))[:B]

    @jax.jit
    def lb(rk: jax.Array) -> jax.Array:
        cost = jnp.where(rk[:, src] == rk[:, dst], r, netc)
        B = rk.shape[0]
        dist = jnp.zeros((B, n), dtype=jnp.float32)

        def body(_, dist):
            cand = dist[:, src] + p[src] + cost
            return jnp.zeros_like(dist).at[:, dst].max(cand)

        dist = jax.lax.fori_loop(0, n - 1, body, dist)
        return jnp.max(dist + p[None, :], axis=1)

    return np.asarray(lb(jnp.asarray(racks)))


# ---------------------------------------------------------------------------
# Search driver: LB-pruned batch sweep + local-search refinement
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VectorizedResult:
    schedule: Schedule
    makespan: float
    n_evaluated: int
    best_assignment: np.ndarray
    n_candidates: int = 0
    n_pruned: int = 0
    refine_rounds: int = 0


def _mutate_pool(
    rng: np.random.Generator,
    best: np.ndarray,
    inst: ProblemInstance,
    count: int,
) -> np.ndarray:
    """Seeded local-search mutations of the incumbent assignment.

    Mix of single-task resamples, co-locations along DAG edges (move the two
    endpoints of a transfer onto one rack), and rack swaps between two tasks.
    """
    n, M = best.shape[0], inst.n_racks
    pool = np.tile(best.astype(np.int32), (count, 1))
    kind = rng.integers(0, 3, size=count)
    edges = inst.job.edges
    for i in range(count):
        if kind[i] == 0 or edges.shape[0] == 0:
            # Resample 1-2 random coordinates.
            for v in rng.integers(0, n, size=int(rng.integers(1, 3))):
                pool[i, v] = rng.integers(0, M)
        elif kind[i] == 1:
            e = int(rng.integers(0, edges.shape[0]))
            u, v = int(edges[e, 0]), int(edges[e, 1])
            pool[i, v] = pool[i, u]
        else:
            u, v = rng.integers(0, n, size=2)
            pool[i, u], pool[i, v] = pool[i, v], pool[i, u]
    return pool


def vectorized_search(
    inst: ProblemInstance,
    max_enumerate: int = 200_000,
    n_samples: int = 8192,
    seed: int = 0,
    use_wireless: bool = True,
    batch_size: int = 8192,
    lb_prune: bool = True,
    use_kernel: bool = True,
    refine_rounds: int = 4,
    refine_pool: int = 1024,
) -> VectorizedResult:
    """Best-of-batch schedule search with bound-driven pruning.

    Enumerates all canonical assignments when that is small enough, else
    samples. Each batch first passes through the Pallas critical-path bound
    (stage 1); only candidates whose bound beats the incumbent are scheduled
    by the batched greedy evaluator (stage 2). In the sampled regime a
    local-search refinement loop mutates the incumbent until no round
    improves it. The winner is re-executed with the exact host simulator
    (which can only improve on the vectorized non-delay score) and verified.
    """
    job = inst.job
    n, M = job.n_tasks, inst.n_racks
    # Bell-number guard: enumerate if the canonical count fits the budget.
    cands = enumerate_assignments(n, M, limit=max_enumerate + 1)
    sampled = cands.shape[0] > max_enumerate
    if sampled:
        rng = np.random.default_rng(seed)
        cands = np.concatenate(
            [
                enumerate_assignments(n, min(2, M), limit=n_samples),
                sample_assignments(rng, n, M, n_samples),
            ],
            axis=0,
        )
    evaluate = make_batched_evaluator(inst, use_wireless=use_wireless)

    best_val = np.inf
    best_rack: np.ndarray | None = None
    n_eval = 0
    n_pruned = 0
    n_cands = 0
    # Stage-1 survivors queue here and are scored in fixed-size batches, so
    # the whole search compiles exactly one stage-2 program shape no matter
    # how pruning fragments the candidate stream.
    buffer: list[np.ndarray] = []
    buffered = 0

    def score(chunk: np.ndarray) -> None:
        nonlocal best_val, best_rack, n_eval
        true_b = chunk.shape[0]
        if true_b < batch_size:
            # Pad partial flushes to the one stage-2 batch shape (repeats of
            # row 0 are discarded below) so pruning's fragmentation never
            # triggers a fresh compile.
            chunk = np.concatenate(
                [chunk, np.tile(chunk[:1], (batch_size - true_b, 1))], axis=0
            )
        vals = np.asarray(evaluate(chunk))[:true_b]
        n_eval += true_b
        j = int(np.argmin(vals))
        if vals[j] < best_val:
            best_val = float(vals[j])
            best_rack = chunk[j].astype(np.int64)

    def flush(partial: bool = False) -> None:
        nonlocal buffer, buffered
        if not buffered:
            return
        pool = np.concatenate(buffer, axis=0) if len(buffer) > 1 else buffer[0]
        n_full = (pool.shape[0] // batch_size) * batch_size
        for i in range(0, n_full, batch_size):
            score(pool[i : i + batch_size])
        tail = pool[n_full:]
        if partial and tail.shape[0]:
            score(tail)
            tail = tail[:0]
        buffer = [tail] if tail.shape[0] else []
        buffered = tail.shape[0]

    def consider(chunk: np.ndarray) -> None:
        nonlocal n_pruned, n_cands, buffered
        n_cands += chunk.shape[0]
        if lb_prune and np.isfinite(best_val):
            lbs = batched_lower_bound(inst, chunk, use_kernel=use_kernel)
            keep = lbs < best_val - 1e-6
            n_pruned += int((~keep).sum())
            chunk = chunk[keep]
        if chunk.shape[0] == 0:
            return
        buffer.append(chunk)
        buffered += chunk.shape[0]
        if buffered >= batch_size:
            flush()

    for i in range(0, cands.shape[0], batch_size):
        consider(cands[i : i + batch_size])
    flush(partial=True)
    assert best_rack is not None

    rounds_run = 0
    if sampled and refine_rounds > 0:
        rng = np.random.default_rng(seed + 1)
        for _ in range(refine_rounds):
            prev = best_val
            consider(_mutate_pool(rng, best_rack, inst, refine_pool))
            flush(partial=True)
            rounds_run += 1
            if best_val >= prev - 1e-9:
                break

    sched = simulate(inst, best_rack, use_wireless=use_wireless)
    return VectorizedResult(
        schedule=sched,
        makespan=sched.makespan,
        n_evaluated=n_eval,
        best_assignment=best_rack,
        n_candidates=n_cands,
        n_pruned=n_pruned,
        refine_rounds=rounds_run,
    )
