"""JAX-vectorized schedule search (beyond-paper, TPU-native).

The paper's solver is host-side B&B. On TPU-class hardware the natural
adaptation of its *search* is massive data parallelism: evaluate tens of
thousands of candidate rack assignments simultaneously as one batched tensor
program. Each candidate is scored by a greedy non-delay schedule executed in
lock-step across the batch (one unrolled pass over operations in topological
order, channel choice = earliest finishing channel), and by a batched
critical-path lower bound (iterated max-plus relaxation — the Pallas `cpm`
kernel accelerates this inner loop on TPU).

This module is an *incumbent generator / pruner*: the winning assignment is
re-executed exactly with the host simulator and verified by the OP checker.
Exactness guarantees come from `bnb`/`solver_milp`; tests assert the
vectorized score is always >= the exact optimum and == the simulator's
makespan for the reconstructed schedule.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.instance import CH_LOCAL, CH_WIRED, ProblemInstance
from repro.core.schedule import Schedule
from repro.core.simulator import simulate

__all__ = [
    "enumerate_assignments",
    "sample_assignments",
    "make_batched_evaluator",
    "batched_lower_bound",
    "vectorized_search",
    "VectorizedResult",
]


def enumerate_assignments(n: int, max_racks: int, limit: int | None = None) -> np.ndarray:
    """All canonical task->rack assignments (restricted growth strings).

    Canonical = rack labels appear in first-use order, which quotients out
    rack-relabelling symmetry. Returns int32[count, n].
    """
    out: list[list[int]] = []

    def rec(prefix: list[int], n_used: int) -> None:
        if limit is not None and len(out) >= limit:
            return
        if len(prefix) == n:
            out.append(list(prefix))
            return
        for i in range(min(n_used + 1, max_racks)):
            prefix.append(i)
            rec(prefix, max(n_used, i + 1))
            prefix.pop()
            if limit is not None and len(out) >= limit:
                return

    rec([], 0)
    return np.asarray(out, dtype=np.int32).reshape(-1, n)


def sample_assignments(
    rng: np.random.Generator, n: int, max_racks: int, count: int
) -> np.ndarray:
    """Random assignments (not canonicalized; used when enumeration is big)."""
    return rng.integers(0, max_racks, size=(count, n), dtype=np.int32).astype(np.int32)


def _op_order(inst: ProblemInstance) -> list[tuple[str, int]]:
    """Static precedence-compatible op order: in-edges then task, topo order."""
    job = inst.job
    order: list[tuple[str, int]] = []
    for v in job.topo_order():
        for e in job.in_edges(int(v)):
            order.append(("E", int(e)))
        order.append(("T", int(v)))
    return order


def make_batched_evaluator(inst: ProblemInstance, use_wireless: bool = True):
    """Build a jitted fn: rack[B, n] int32 -> makespan[B] float32.

    Greedy non-delay schedule per batch element, identical control flow
    across the batch (fully vectorized; no host sync inside).
    """
    job = inst.job
    n, m, M = job.n_tasks, job.n_edges, inst.n_racks
    n_chan = 1 + (inst.n_wireless if use_wireless else 0)
    order = _op_order(inst)
    p = jnp.asarray(job.p, dtype=jnp.float32)
    q = jnp.asarray(inst.q_wired, dtype=jnp.float32)
    qw = jnp.asarray(inst.q_wireless, dtype=jnp.float32)
    r = jnp.asarray(inst.r_local, dtype=jnp.float32)
    edges = job.edges

    @jax.jit
    def evaluate(rack: jax.Array) -> jax.Array:
        B = rack.shape[0]
        rack_free = jnp.zeros((B, M), dtype=jnp.float32)
        chan_free = jnp.zeros((B, n_chan), dtype=jnp.float32)
        task_fin = jnp.zeros((B, n), dtype=jnp.float32)
        edge_fin = jnp.zeros((B, m), dtype=jnp.float32) if m else None

        for kind, idx in order:
            if kind == "E":
                e = idx
                u, v = int(edges[e, 0]), int(edges[e, 1])
                ready = task_fin[:, u]
                same = rack[:, u] == rack[:, v]
                # Local path: no resource, duration r.
                fin_local = ready + r[e]
                # Network path: earliest-finish channel (0 wired, 1.. wireless).
                durs = jnp.concatenate(
                    [
                        jnp.full((B, 1), q[e]),
                        jnp.broadcast_to(qw[e], (B, n_chan - 1)),
                    ],
                    axis=1,
                ) if n_chan > 1 else jnp.full((B, 1), q[e])
                s = jnp.maximum(ready[:, None], chan_free)
                f = s + durs
                best = jnp.argmin(f, axis=1)
                fin_net = jnp.take_along_axis(f, best[:, None], axis=1)[:, 0]
                new_free = jnp.where(
                    jax.nn.one_hot(best, n_chan, dtype=bool),
                    fin_net[:, None],
                    chan_free,
                )
                chan_free = jnp.where(same[:, None], chan_free, new_free)
                fin = jnp.where(same, fin_local, fin_net)
                edge_fin = edge_fin.at[:, e].set(fin)
            else:
                v = idx
                ready = jnp.zeros((rack.shape[0],), dtype=jnp.float32)
                for e in job.in_edges(v):
                    ready = jnp.maximum(ready, edge_fin[:, int(e)])
                rv = rack[:, v].astype(jnp.int32)
                free_v = jnp.take_along_axis(rack_free, rv[:, None], axis=1)[:, 0]
                s = jnp.maximum(ready, free_v)
                fin = s + p[v]
                rack_free = jnp.where(
                    jax.nn.one_hot(rv, M, dtype=bool), fin[:, None], rack_free
                )
                task_fin = task_fin.at[:, v].set(fin)

        return jnp.max(task_fin, axis=1)

    return evaluate


def batched_lower_bound(
    inst: ProblemInstance, racks: np.ndarray, use_kernel: bool = False
) -> np.ndarray:
    """Critical-path LB per assignment via iterated max-plus relaxation.

    dist[v] >= dist[u] + p_u + cost(u, v) where cost is r (same rack) or the
    optimistic network duration (different racks). Converges in <= depth
    iterations; we run n-1 (the max possible DAG depth).
    """
    job = inst.job
    n, m = job.n_tasks, job.n_edges
    if m == 0:
        return np.broadcast_to(np.max(job.p), (racks.shape[0],)).astype(np.float32)
    net = np.minimum(inst.q_wired, inst.q_wireless) if inst.n_wireless else inst.q_wired

    p = jnp.asarray(job.p, dtype=jnp.float32)
    r = jnp.asarray(inst.r_local, dtype=jnp.float32)
    netc = jnp.asarray(net, dtype=jnp.float32)
    src = jnp.asarray(job.edges[:, 0])
    dst = jnp.asarray(job.edges[:, 1])

    if use_kernel:
        from repro.kernels import ops as kops

        # Dense max-plus adjacency per batch element.
        def build_w(rk):
            cost = jnp.where(rk[src] == rk[dst], r, netc) + p[src]
            w = jnp.full((n, n), -jnp.inf, dtype=jnp.float32)
            return w.at[src, dst].max(cost)

        w = jax.vmap(build_w)(jnp.asarray(racks))
        dist = kops.batched_critical_path(w)
        return np.asarray(jnp.max(dist + p[None, :], axis=1))

    @jax.jit
    def lb(rk: jax.Array) -> jax.Array:
        cost = jnp.where(rk[:, :][:, src] == rk[:, :][:, dst], r, netc)
        B = rk.shape[0]
        dist = jnp.zeros((B, n), dtype=jnp.float32)

        def body(_, dist):
            cand = dist[:, src] + p[src] + cost
            return jnp.zeros_like(dist).at[:, dst].max(cand)

        dist = jax.lax.fori_loop(0, n - 1, body, dist)
        return jnp.max(dist + p[None, :], axis=1)

    return np.asarray(lb(jnp.asarray(racks)))


@dataclasses.dataclass
class VectorizedResult:
    schedule: Schedule
    makespan: float
    n_evaluated: int
    best_assignment: np.ndarray


def vectorized_search(
    inst: ProblemInstance,
    max_enumerate: int = 200_000,
    n_samples: int = 8192,
    seed: int = 0,
    use_wireless: bool = True,
    batch_size: int = 65536,
) -> VectorizedResult:
    """Best-of-batch schedule search.

    Enumerates all canonical assignments when that is small enough, else
    samples. The winner is re-executed with the exact host simulator (which
    can only improve on the vectorized non-delay score) and verified.
    """
    job = inst.job
    n, M = job.n_tasks, inst.n_racks
    # Bell-number guard: enumerate if the canonical count fits the budget.
    cands = enumerate_assignments(n, M, limit=max_enumerate + 1)
    if cands.shape[0] > max_enumerate:
        rng = np.random.default_rng(seed)
        cands = np.concatenate(
            [
                enumerate_assignments(n, min(2, M)),
                sample_assignments(rng, n, M, n_samples),
            ],
            axis=0,
        )
    evaluate = make_batched_evaluator(inst, use_wireless=use_wireless)
    best_val = np.inf
    best_rack: np.ndarray | None = None
    n_eval = 0
    for i in range(0, cands.shape[0], batch_size):
        chunk = cands[i : i + batch_size]
        vals = np.asarray(evaluate(jnp.asarray(chunk)))
        n_eval += chunk.shape[0]
        j = int(np.argmin(vals))
        if vals[j] < best_val:
            best_val = float(vals[j])
            best_rack = chunk[j].astype(np.int64)
    assert best_rack is not None
    sched = simulate(inst, best_rack, use_wireless=use_wireless)
    return VectorizedResult(
        schedule=sched,
        makespan=sched.makespan,
        n_evaluated=n_eval,
        best_assignment=best_rack,
    )
