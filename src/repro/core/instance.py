"""Problem instance: a job plus the hybrid-DCN resource environment.

Paper §II: M racks connected by (a) wired links with guaranteed per-flow
bandwidth B_s, shared as a single logical channel ``b`` (constraint (8) forbids
any two concurrent wired flows), (b) |K| orthogonal wireless subchannels of
bandwidth B each, and (c) local (same-rack) transfer with delay r_(u,v) —
modelled in §IV-B as the infinite-capacity *virtual channel* ``c``.

Channel index convention used throughout the codebase:
  CH_WIRED = 0   (channel "b")
  CH_LOCAL = 1   (virtual channel "c", no contention)
  2 .. K+1       (wireless subchannels)
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dag import DagJob

__all__ = ["ProblemInstance", "CH_WIRED", "CH_LOCAL", "first_wireless"]

CH_WIRED = 0
CH_LOCAL = 1


def first_wireless() -> int:
    return 2


@dataclasses.dataclass(frozen=True)
class ProblemInstance:
    """A scheduling instance.

    Attributes:
      job: the DAG job.
      n_racks: M, number of feasible racks.
      n_wireless: |K|, number of orthogonal wireless subchannels.
      wired_rate: B_s (data units / time unit).
      wireless_rate: B.
      local_delay: r_(u,v); either a scalar applied to all edges or a
        per-edge array. The paper's experiments use symmetric 10 Gbps rates
        and local transfers that are effectively free (in-rack disk/memory).
    """

    job: DagJob
    n_racks: int
    n_wireless: int = 1
    wired_rate: float = 1.0
    wireless_rate: float = 1.0
    local_delay: float | np.ndarray = 0.0

    @property
    def n_channels(self) -> int:
        """Total channels in the generalized model: {b, c} ∪ K."""
        return 2 + self.n_wireless

    @property
    def q_wired(self) -> np.ndarray:
        """q_(u,v) = d / B_s  (paper §II)."""
        return self.job.d / self.wired_rate

    @property
    def q_wireless(self) -> np.ndarray:
        """q̌_(u,v) = d / B."""
        return self.job.d / self.wireless_rate

    @property
    def r_local(self) -> np.ndarray:
        r = np.asarray(self.local_delay, dtype=np.float64)
        if r.ndim == 0:
            return np.full(self.job.n_edges, float(r))
        if r.shape != (self.job.n_edges,):
            raise ValueError("local_delay must be scalar or per-edge")
        return r

    def duration_on(self, chan: np.ndarray) -> np.ndarray:
        """Per-edge transfer duration under a channel assignment vector.

        chan[e] uses the module-level convention (0 wired, 1 local, >=2
        wireless).
        """
        chan = np.asarray(chan)
        dur = np.where(
            chan == CH_WIRED,
            self.q_wired,
            np.where(chan == CH_LOCAL, self.r_local, self.q_wireless),
        )
        return dur

    def durations_matrix(self) -> np.ndarray:
        """float64[n_edges, n_channels] duration of edge e on channel c."""
        m = np.empty((self.job.n_edges, self.n_channels), dtype=np.float64)
        m[:, CH_WIRED] = self.q_wired
        m[:, CH_LOCAL] = self.r_local
        for k in range(self.n_wireless):
            m[:, 2 + k] = self.q_wireless
        return m
