"""Problem instance: a job plus the hybrid-DCN resource environment.

Paper §II: M racks connected by (a) wired links with guaranteed per-flow
bandwidth B_s, shared as a single logical channel ``b`` (constraint (8) forbids
any two concurrent wired flows), (b) |K| orthogonal wireless subchannels of
bandwidth B each, and (c) local (same-rack) transfer with delay r_(u,v) —
modelled in §IV-B as the infinite-capacity *virtual channel* ``c``.

Channel index convention used throughout the codebase:
  CH_WIRED = 0   (channel "b")
  CH_LOCAL = 1   (virtual channel "c", no contention)
  2 .. K+1       (wireless subchannels)

Reconfigurable topology (the reachability layer)
------------------------------------------------
The paper fixes which racks can reach the wireless subchannels; the
:class:`Topology` abstraction makes that reachability itself part of the
model — a per-(rack, subchannel) boolean mask plus transceiver degree
limits and a reconfiguration delay δ ("Scheduling Opportunistic Links in
Two-Tiered Reconfigurable Datacenters" regime). ``ProblemInstance.topology
= None`` is the paper's all-ones mask and keeps every solver path
bit-identical to the topology-free code; a restricted mask forces edges
between racks with no common reachable subchannel onto the wired channel.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dag import DagJob

__all__ = [
    "ProblemInstance",
    "Topology",
    "CH_WIRED",
    "CH_LOCAL",
    "first_wireless",
]

CH_WIRED = 0
CH_LOCAL = 1


def first_wireless() -> int:
    return 2


@dataclasses.dataclass(frozen=True)
class Topology:
    """Reconfigurable wireless reachability: which racks see which
    subchannels, how many links a transceiver can hold, and the cost of
    changing the configuration.

    Attributes:
      reach: bool[n_racks, n_wireless]; ``reach[i, k]`` iff rack i's
        transceivers can use subchannel k. A cross-rack edge may use
        subchannel k only when BOTH endpoint racks reach k; a rack pair
        with no common subchannel is wired-only.
      degree: max subchannels a single rack may be configured onto
        (transceiver count); ``None`` = unbounded. Only constrains
        *matching* construction (:meth:`match`) — a given ``reach`` mask
        is always taken at face value.
      channel_degree: max racks configurable onto one subchannel;
        ``None`` = unbounded. Same scope as ``degree``.
      delta: reconfiguration delay δ — the time a subchannel is unusable
        after its rack set changes (charged by the online timeline as a
        busy interval).
    """

    reach: np.ndarray
    degree: int | None = None
    channel_degree: int | None = None
    delta: float = 0.0

    def __post_init__(self):
        r = np.ascontiguousarray(np.asarray(self.reach, dtype=bool))
        if r.ndim != 2:
            raise ValueError("Topology.reach must be [n_racks, n_wireless]")
        object.__setattr__(self, "reach", r)
        if self.degree is not None and self.degree < 0:
            raise ValueError("Topology.degree must be >= 0")
        if self.channel_degree is not None and self.channel_degree < 0:
            raise ValueError("Topology.channel_degree must be >= 0")
        if self.delta < 0:
            raise ValueError("Topology.delta must be >= 0")

    @property
    def n_racks(self) -> int:
        return self.reach.shape[0]

    @property
    def n_wireless(self) -> int:
        return self.reach.shape[1]

    @property
    def is_all_ones(self) -> bool:
        """True iff this mask never restricts a pick (the paper's model)."""
        return bool(self.reach.all())

    @staticmethod
    def all_ones(
        n_racks: int, n_wireless: int, *, delta: float = 0.0
    ) -> "Topology":
        return Topology(
            reach=np.ones((n_racks, n_wireless), dtype=bool), delta=delta
        )

    def pair_reach(self) -> np.ndarray:
        """bool[n_racks, n_racks, n_wireless]: both endpoints reach k."""
        return self.reach[:, None, :] & self.reach[None, :, :]

    def pair_connected(self) -> np.ndarray:
        """bool[n_racks, n_racks]: the pair shares >= 1 subchannel (the
        wireless-eligibility matrix; diagonal is irrelevant — same-rack
        edges are local)."""
        return self.pair_reach().any(axis=2)

    def edge_channels(self, rack_u: int, rack_v: int) -> np.ndarray:
        """Subchannel indices (0-based, NOT offset by ``first_wireless``)
        usable by an edge placed on ``(rack_u, rack_v)``."""
        return np.nonzero(self.reach[rack_u] & self.reach[rack_v])[0]

    def restrict(
        self, racks: np.ndarray, subchannels: np.ndarray
    ) -> "Topology":
        """The induced topology on a rack subset × subchannel subset (the
        residual-view projection used by the online timeline)."""
        racks = np.asarray(racks, dtype=np.int64)
        subchannels = np.asarray(subchannels, dtype=np.int64)
        return dataclasses.replace(
            self, reach=self.reach[np.ix_(racks, subchannels)]
        )

    def match(
        self,
        weight: np.ndarray,
        *,
        feasible: np.ndarray | None = None,
        keep: np.ndarray | None = None,
    ) -> np.ndarray:
        """Greedy weighted b-matching: configure (rack, subchannel) links
        by descending rack weight under the degree limits.

        ``weight``: float[n_racks] demand weight per rack (e.g. the epoch
        batch's wireless transfer volume landing on that rack). Links of
        zero-or-negative weight racks are never configured. ``feasible``
        optionally masks out links (e.g. outaged ones) on top of
        ``reach``. ``keep`` optionally pins links that must stay
        configured (e.g. links of subchannels mid-transfer, which the
        online timeline cannot reconfigure); pinned links are installed
        first and count toward the degree limits. Returns the configured
        bool[n_racks, n_wireless] mask — a subset of
        ``(reach & feasible) | keep``. Deterministic: ties break on
        (rack, subchannel) index.
        """
        weight = np.asarray(weight, dtype=np.float64)
        if weight.shape != (self.n_racks,):
            raise ValueError("weight must be [n_racks]")
        allowed = self.reach if feasible is None else (self.reach & feasible)
        out = np.zeros_like(self.reach)
        rack_deg = np.zeros(self.n_racks, dtype=np.int64)
        chan_deg = np.zeros(self.n_wireless, dtype=np.int64)
        if keep is not None:
            keep = np.asarray(keep, dtype=bool)
            out |= keep
            rack_deg += keep.sum(axis=1)
            chan_deg += keep.sum(axis=0)
            allowed = allowed & ~keep
        order = sorted(
            (
                (i, k)
                for i in range(self.n_racks)
                for k in range(self.n_wireless)
                if allowed[i, k] and weight[i] > 0.0
            ),
            key=lambda ik: (-weight[ik[0]], ik[0], ik[1]),
        )
        for i, k in order:
            if self.degree is not None and rack_deg[i] >= self.degree:
                continue
            if (
                self.channel_degree is not None
                and chan_deg[k] >= self.channel_degree
            ):
                continue
            out[i, k] = True
            rack_deg[i] += 1
            chan_deg[k] += 1
        return out


@dataclasses.dataclass(frozen=True)
class ProblemInstance:
    """A scheduling instance.

    Attributes:
      job: the DAG job.
      n_racks: M, number of feasible racks.
      n_wireless: |K|, number of orthogonal wireless subchannels.
      wired_rate: B_s (data units / time unit).
      wireless_rate: B.
      local_delay: r_(u,v); either a scalar applied to all edges or a
        per-edge array. The paper's experiments use symmetric 10 Gbps rates
        and local transfers that are effectively free (in-rack disk/memory).
      topology: optional :class:`Topology` reachability mask over
        ``[n_racks, n_wireless]``. ``None`` (the default) is the paper's
        model — every rack reaches every subchannel — and keeps all solver
        paths bit-identical to the pre-topology code.
    """

    job: DagJob
    n_racks: int
    n_wireless: int = 1
    wired_rate: float = 1.0
    wireless_rate: float = 1.0
    local_delay: float | np.ndarray = 0.0
    topology: Topology | None = None

    def __post_init__(self):
        t = self.topology
        if t is not None and t.reach.shape != (self.n_racks, self.n_wireless):
            raise ValueError(
                f"topology.reach shape {t.reach.shape} != "
                f"({self.n_racks}, {self.n_wireless})"
            )

    @property
    def reach_mask(self) -> np.ndarray:
        """Effective bool[n_racks, n_wireless] reachability (all-ones when
        ``topology`` is None)."""
        if self.topology is None:
            return np.ones((self.n_racks, self.n_wireless), dtype=bool)
        return self.topology.reach

    @property
    def n_channels(self) -> int:
        """Total channels in the generalized model: {b, c} ∪ K."""
        return 2 + self.n_wireless

    @property
    def q_wired(self) -> np.ndarray:
        """q_(u,v) = d / B_s  (paper §II)."""
        return self.job.d / self.wired_rate

    @property
    def q_wireless(self) -> np.ndarray:
        """q̌_(u,v) = d / B."""
        return self.job.d / self.wireless_rate

    @property
    def r_local(self) -> np.ndarray:
        r = np.asarray(self.local_delay, dtype=np.float64)
        if r.ndim == 0:
            return np.full(self.job.n_edges, float(r))
        if r.shape != (self.job.n_edges,):
            raise ValueError("local_delay must be scalar or per-edge")
        return r

    def duration_on(self, chan: np.ndarray) -> np.ndarray:
        """Per-edge transfer duration under a channel assignment vector.

        chan[e] uses the module-level convention (0 wired, 1 local, >=2
        wireless).
        """
        chan = np.asarray(chan)
        dur = np.where(
            chan == CH_WIRED,
            self.q_wired,
            np.where(chan == CH_LOCAL, self.r_local, self.q_wireless),
        )
        return dur

    def durations_matrix(self) -> np.ndarray:
        """float64[n_edges, n_channels] duration of edge e on channel c."""
        m = np.empty((self.job.n_edges, self.n_channels), dtype=np.float64)
        m[:, CH_WIRED] = self.q_wired
        m[:, CH_LOCAL] = self.r_local
        m[:, 2:] = self.q_wireless[:, None]
        return m
