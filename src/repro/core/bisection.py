"""Decomposition & acceleration via bisection on feasibility subproblems (§IV-D).

RP is decomposed into feasibility subproblems FP(ℓ): "does a schedule with
C_max ≤ ℓ exist?", with ℓ bisected over [T_min, T_max]. Each iteration halves
the interval; after g iterations the optimality gap is 2^-g (T_max - T_min).
Because ℓ also serves as the big-M horizon, FP instances shrink as the upper
bound tightens — this is the paper's acceleration.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import bounds as bounds_mod
from repro.core.instance import ProblemInstance
from repro.core.milp import build_rp
from repro.core.schedule import Schedule
from repro.core.solver_milp import solve_rp

__all__ = ["BisectionResult", "solve_bisection"]


@dataclasses.dataclass
class BisectionResult:
    schedule: Schedule | None
    makespan: float
    iterations: int
    final_gap: float
    wall_s: float
    history: list[tuple[float, float, bool]]  # (lo, hi, feasible-at-mid)


def solve_bisection(
    inst: ProblemInstance,
    rel_tol: float = 1e-3,
    abs_tol: float = 1e-6,
    max_iters: int = 64,
    time_limit_per_fp: float | None = None,
    paper_exact_binding: bool = False,
) -> BisectionResult:
    """Optimal C_max via §IV-D bisection over FP feasibility subproblems."""
    t0 = time.perf_counter()
    lo = bounds_mod.lower_bound(inst)
    hi = bounds_mod.upper_bound(inst)
    best: Schedule | None = None
    history: list[tuple[float, float, bool]] = []

    # First check: is the lower bound itself attainable? (saves an iteration
    # when the critical path dominates — common at small network factors.)
    it = 0
    while hi - lo > max(abs_tol, rel_tol * max(1.0, hi)) and it < max_iters:
        mid = 0.5 * (lo + hi)
        model = build_rp(
            inst,
            tmax=mid,
            feasibility_only=True,
            paper_exact_binding=paper_exact_binding,
        )
        res = solve_rp(model, time_limit=time_limit_per_fp, verify=False)
        feasible = res.schedule is not None
        history.append((lo, hi, feasible))
        if feasible:
            assert res.schedule is not None
            # Verify against OP semantics before trusting the incumbent.
            from repro.core.schedule import check_feasible

            check_feasible(inst, res.schedule, tol=1e-4)
            best = res.schedule
            hi = res.schedule.makespan  # jump below mid: actual achieved value
        else:
            lo = mid
        it += 1

    if best is None:
        # hi (= T_max) is always attainable: everything on one rack.
        from repro.core.baselines import single_rack_schedule

        best = single_rack_schedule(inst)
    return BisectionResult(
        schedule=best,
        makespan=best.makespan,
        iterations=it,
        final_gap=hi - lo,
        wall_s=time.perf_counter() - t0,
        history=history,
    )
