"""Metaheuristic search portfolio for the refinement stage (beyond-paper).

The paper's B&B attains optimality because its §IV-A bounds focus the
search; the vectorized engine's sampled regime instead relies on a
refinement loop to close the gap, and a single neighborhood (mutation
local search) stalls on dense instances. This module turns that loop into
a **portfolio** of pluggable strategies sharing one candidate budget:

  * :class:`MutationStrategy` — the PR 2 local search (single-task
    resamples, edge co-locations, rack swaps around the incumbent).
  * :class:`CrossoverStrategy` — elite recombination: uniform crossover
    between two distinct members of the per-instance elite pool, with a
    rack-count feasibility repair on the children.
  * :class:`AnnealingStrategy` — simulated annealing: a walker proposes
    mutations of *its own* state (not the incumbent) and accepts worse
    rounds with temperature-scheduled Metropolis probability, so it can
    tunnel out of the basins where plain local search stalls.

The :class:`Portfolio` driver allocates each round's batch budget across
strategies by **recent yield** (incumbent improvement per evaluated
candidate, multiplicative-weights style) and runs *inside* the lockstep
fleet driver of :mod:`repro.core.vectorized`: every strategy's proposals
ride the same mega-batch launches, pass the same fused §IV-A stage-1
pruner, and are scored by the one compiled stage-2 evaluator. Per-strategy
proposed/pruned/evaluated/improved counters and final weights surface in
``VectorizedResult.strategy_stats`` / ``FleetResult.strategy_stats``.

Determinism contract
--------------------
All randomness flows through the single per-instance refinement generator
(``np.random.default_rng(seed + 1)``), consumed in a fixed order each
round: strategies propose in portfolio order, then end-of-round hooks run
in the same order. Fixed seed + fixed strategy list => bit-identical
results across runs and across fleet packings. With the default
single-strategy spec ``("mutation",)`` the RNG call sequence is exactly
the pre-portfolio refinement loop's, so results reproduce it bit-for-bit.

Authoring a new strategy: see :class:`Strategy` and
``docs/architecture.md`` ("Writing a new strategy").
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.instance import ProblemInstance

__all__ = [
    "Strategy",
    "StrategyBase",
    "SearchView",
    "StrategyStats",
    "ElitePool",
    "MutationStrategy",
    "CrossoverStrategy",
    "AnnealingStrategy",
    "Portfolio",
    "STRATEGIES",
    "ARBITRATION_STRATEGIES",
    "DEFAULT_PORTFOLIO",
    "build_strategies",
    "register_arbitration_strategy",
    "spec_length",
    "merge_strategy_stats",
    "mutate_pool",
    "stats_snapshot",
]


def mutate_pool(
    rng: np.random.Generator,
    best: np.ndarray,
    inst: ProblemInstance,
    count: int,
) -> np.ndarray:
    """Seeded local-search mutations of one assignment (the PR 2 kernel).

    Mix of single-task resamples, co-locations along DAG edges (move the two
    endpoints of a transfer onto one rack), and rack swaps between two tasks.

    Args:
      rng: generator consumed in a fixed call order (determinism contract).
      best: int[n_tasks] assignment to perturb.
      inst: the instance (rack count and DAG edges drive the moves).
      count: number of candidates to emit.

    Returns:
      int32[count, n_tasks] candidate assignments.
    """
    n, M = best.shape[0], inst.n_racks
    pool = np.tile(best.astype(np.int32), (count, 1))
    kind = rng.integers(0, 3, size=count)
    edges = inst.job.edges
    for i in range(count):
        if kind[i] == 0 or edges.shape[0] == 0:
            # Resample 1-2 random coordinates.
            for v in rng.integers(0, n, size=int(rng.integers(1, 3))):
                pool[i, v] = rng.integers(0, M)
        elif kind[i] == 1:
            e = int(rng.integers(0, edges.shape[0]))
            u, v = int(edges[e, 0]), int(edges[e, 1])
            pool[i, v] = pool[i, u]
        else:
            u, v = rng.integers(0, n, size=2)
            pool[i, u], pool[i, v] = pool[i, v], pool[i, u]
    return pool


class ElitePool:
    """Best distinct assignments seen so far, sorted best-first.

    Fed from every scored block (sweep and refinement); insertion is
    deterministic (stable ties: earlier entrants keep their rank) and
    duplicates are dropped by exact assignment equality, so the pool stays
    diverse enough for crossover to recombine.
    """

    def __init__(self, capacity: int = 16):
        self.capacity = capacity
        self.vals: list[float] = []
        self.racks: list[np.ndarray] = []
        self._keys: set[bytes] = set()

    def __len__(self) -> int:
        return len(self.racks)

    def add(self, rack: np.ndarray, val: float) -> None:
        rack = np.asarray(rack, dtype=np.int32)
        key = rack.tobytes()
        if key in self._keys:
            return
        if len(self.racks) >= self.capacity:
            if val >= self.vals[-1]:
                return
            worst = self.racks.pop()
            self.vals.pop()
            self._keys.discard(worst.tobytes())
        # Stable: a new entry goes after equal-valued incumbents.
        i = int(np.searchsorted(np.asarray(self.vals), val, side="right"))
        self.vals.insert(i, float(val))
        self.racks.insert(i, rack.copy())
        self._keys.add(key)

    def add_batch(self, racks: np.ndarray, vals: np.ndarray) -> None:
        """Offer a scored block; only the block's best ``capacity`` rows can
        possibly enter, so insertion cost stays O(capacity log B) per block."""
        if racks.shape[0] == 0:
            return
        order = np.argsort(vals, kind="stable")[: self.capacity]
        for j in order:
            self.add(racks[j], float(vals[j]))


@dataclasses.dataclass
class SearchView:
    """Read-only snapshot a strategy sees when proposing/observing.

    Attributes:
      inst: the problem instance being refined.
      rng: the shared per-instance generator (consume deterministically!).
      best_rack: int[n_tasks] current incumbent assignment.
      best_val: incumbent greedy makespan (float32-accurate).
      elites: the per-instance :class:`ElitePool`.
      round_index: 0-based refinement round.
    """

    inst: ProblemInstance
    rng: np.random.Generator
    best_rack: np.ndarray
    best_val: float
    elites: ElitePool
    round_index: int


@runtime_checkable
class Strategy(Protocol):
    """One member of the refinement portfolio.

    A strategy is a *candidate generator with memory*: each round the
    portfolio asks it to ``propose`` a block of assignments, routes the
    block through the shared stage-1 pruner and stage-2 evaluator, and
    feeds the scored survivors back via ``observe``/``end_round``.

    Contract:
      * ``name``: unique identifier; keys the ``strategy_stats`` counters.
      * ``propose(view, count) -> int32[count, n_tasks]`` with every entry
        in ``[0, view.inst.n_racks)``. Must draw randomness only from
        ``view.rng`` (the determinism contract).
      * ``observe(view, racks, vals)``: scored survivors of *this
        strategy's* proposals (pruned rows never appear). Optional hook —
        update internal state only; the incumbent is driver-owned.
      * ``end_round(view)``: called once per round after all blocks are
        scored, in portfolio order; ``view`` holds the post-round
        incumbent. Optional hook.

    The driver applies incumbent updates itself and only ever *improves*
    the incumbent, so a strategy (annealing included) can never make the
    returned result worse than its input.
    """

    name: str

    def propose(self, view: SearchView, count: int) -> np.ndarray: ...

    def observe(self, view: SearchView, racks: np.ndarray, vals: np.ndarray) -> None: ...

    def end_round(self, view: SearchView) -> None: ...


class StrategyBase:
    """No-op ``observe``/``end_round`` so minimal strategies only write
    ``name`` and ``propose``."""

    name = "base"

    def observe(self, view: SearchView, racks: np.ndarray, vals: np.ndarray) -> None:
        return None

    def end_round(self, view: SearchView) -> None:
        return None


class MutationStrategy(StrategyBase):
    """The PR 2 local search: mutate the incumbent with :func:`mutate_pool`.

    With a single-strategy portfolio this reproduces the pre-portfolio
    refinement loop bit-for-bit (same RNG call sequence, same pool size).
    """

    name = "mutation"

    def propose(self, view: SearchView, count: int) -> np.ndarray:
        return mutate_pool(view.rng, view.best_rack, view.inst, count)


class CrossoverStrategy(StrategyBase):
    """Elite recombination: uniform crossover between two distinct elites.

    Each child copies every task's rack from one of two distinct parents
    drawn from the elite pool (coordinate-wise coin flips), then passes a
    rack-count feasibility repair: any label outside ``[0, n_racks)`` is
    folded back with a modulo (parents from the same instance already
    satisfy this, so the repair guards only externally injected elites).
    Falls back to incumbent mutation until the pool has two members.
    """

    name = "crossover"

    def propose(self, view: SearchView, count: int) -> np.ndarray:
        elites = view.elites
        if len(elites) < 2:
            return mutate_pool(view.rng, view.best_rack, view.inst, count)
        E = len(elites)
        n = view.best_rack.shape[0]
        rng = view.rng
        a = rng.integers(0, E, size=count)
        b = rng.integers(0, E - 1, size=count)
        b = np.where(b >= a, b + 1, b)  # force distinct parents
        parents = np.stack(elites.racks, axis=0)  # int32[E, n]
        mask = rng.random((count, n)) < 0.5
        child = np.where(mask, parents[a], parents[b]).astype(np.int32)
        M = view.inst.n_racks
        bad = (child < 0) | (child >= M)
        if bad.any():
            child[bad] = np.abs(child[bad]) % M
        return child


class AnnealingStrategy(StrategyBase):
    """Simulated annealing on a walker seeded from the incumbent.

    The walker proposes mutations of its *own* state. At end of round the
    best scored proposal replaces the walker if it improves it, else with
    Metropolis probability ``exp(-delta / T)``; ``T`` starts at
    ``t0_frac * incumbent`` and decays by ``alpha`` per round. Because the
    walker — not the incumbent — absorbs the worse moves, the strategy
    explores distant basins while the driver's strict-improvement rule
    keeps the returned incumbent monotone.

    Args:
      t0_frac: initial temperature as a fraction of the starting incumbent.
      alpha: geometric cooling factor per round, in (0, 1].
    """

    name = "annealing"

    def __init__(self, t0_frac: float = 0.25, alpha: float = 0.85):
        self.t0_frac = float(t0_frac)
        self.alpha = float(alpha)
        self._walker: np.ndarray | None = None
        self._walker_val = math.inf
        self._temp = 0.0
        self._round_best: np.ndarray | None = None
        self._round_best_val = math.inf

    def propose(self, view: SearchView, count: int) -> np.ndarray:
        if self._walker is None:
            self._walker = np.asarray(view.best_rack, dtype=np.int32).copy()
            self._walker_val = float(view.best_val)
            self._temp = max(self.t0_frac * float(view.best_val), 1e-9)
        self._round_best = None
        self._round_best_val = math.inf
        return mutate_pool(view.rng, self._walker, view.inst, count)

    def observe(self, view: SearchView, racks: np.ndarray, vals: np.ndarray) -> None:
        j = int(np.argmin(vals))
        if float(vals[j]) < self._round_best_val:
            self._round_best_val = float(vals[j])
            self._round_best = np.asarray(racks[j], dtype=np.int32).copy()

    def end_round(self, view: SearchView) -> None:
        if self._walker is None:
            return
        if self._round_best is not None:
            delta = self._round_best_val - self._walker_val
            if delta <= 0.0 or view.rng.random() < math.exp(
                -delta / max(self._temp, 1e-12)
            ):
                self._walker = self._round_best
                self._walker_val = self._round_best_val
        # Consume the round's candidate either way: a round in which the
        # allocator gave this strategy no proposals must neither re-judge a
        # stale candidate nor draw from the RNG.
        self._round_best = None
        self._round_best_val = math.inf
        self._temp *= self.alpha


@dataclasses.dataclass
class StrategyStats:
    """Per-strategy refinement counters (one entry per portfolio member).

    Attributes:
      proposed: candidates the strategy emitted.
      pruned: proposals discarded by the stage-1 §IV-A bound.
      evaluated: proposals scored by the stage-2 evaluator.
      improved: scored proposals that beat the incumbent at score time.
      improvement: total incumbent decrease credited to the strategy
        (sum over rounds of ``max(0, round_start_best - round_min)``).
      weight: final multiplicative weight in the allocator.
    """

    proposed: int = 0
    pruned: int = 0
    evaluated: int = 0
    improved: int = 0
    improvement: float = 0.0
    weight: float = 1.0

    @property
    def yield_per_eval(self) -> float:
        """Improvement per evaluated candidate — the allocator's signal."""
        return self.improvement / self.evaluated if self.evaluated else 0.0


def stats_snapshot(stats: dict[str, StrategyStats]) -> dict[str, dict]:
    """Plain-dict snapshot of per-strategy counters, sorted by name.

    The trace layer attaches this to its ``portfolio_yields`` decision
    events — JSON-serializable, no live :class:`StrategyStats` refs.
    """
    return {
        name: {
            "proposed": s.proposed,
            "pruned": s.pruned,
            "evaluated": s.evaluated,
            "improved": s.improved,
            "improvement": s.improvement,
            "weight": s.weight,
            "yield_per_eval": s.yield_per_eval,
        }
        for name, s in sorted(stats.items())
    }


def merge_strategy_stats(
    stats_dicts: Iterable[dict[str, StrategyStats]],
) -> dict[str, StrategyStats]:
    """Aggregate per-instance stats into fleet totals (weights averaged)."""
    out: dict[str, StrategyStats] = {}
    weights: dict[str, list[float]] = {}
    for d in stats_dicts:
        for name, s in d.items():
            agg = out.setdefault(name, StrategyStats(weight=0.0))
            agg.proposed += s.proposed
            agg.pruned += s.pruned
            agg.evaluated += s.evaluated
            agg.improved += s.improved
            agg.improvement += s.improvement
            weights.setdefault(name, []).append(s.weight)
    for name, ws in weights.items():
        out[name].weight = float(np.mean(ws))
    return out


STRATEGIES = {
    "mutation": MutationStrategy,
    "crossover": CrossoverStrategy,
    "annealing": AnnealingStrategy,
}

# Arbitration-order strategies: the same Strategy protocol, but proposals
# are int32[count, n_jobs] *commit permutations* of one admission epoch's
# batch instead of task->rack assignments (``view.best_rack`` holds the
# incumbent order; every row must be a permutation of ``range(n_jobs)``).
# A separate registry keeps the two search spaces from mixing — an
# assignment strategy in an order portfolio (or vice versa) would propose
# out-of-space rows. Members live in :mod:`repro.core.coflow`, which
# registers them at import via :func:`register_arbitration_strategy`;
# the registry is defined here so the driver machinery (one
# :class:`Portfolio` per epoch) and both registries share one module.
ARBITRATION_STRATEGIES: dict[str, type] = {}


def register_arbitration_strategy(cls: type) -> type:
    """Class decorator: add an arbitration-order Strategy to the registry
    under its ``name`` (duplicate names raise — they would shadow)."""
    name = getattr(cls, "name", None)
    if not isinstance(name, str) or not name:
        raise ValueError(f"arbitration strategy {cls!r} needs a `name`")
    if name in ARBITRATION_STRATEGIES:
        raise ValueError(f"duplicate arbitration strategy name {name!r}")
    ARBITRATION_STRATEGIES[name] = cls
    return cls

# The full portfolio spec (the ``strategies="portfolio"`` alias).
DEFAULT_PORTFOLIO = ("mutation", "crossover", "annealing")


def _normalize_spec(spec) -> tuple:
    if spec is None:
        return ("mutation",)
    if isinstance(spec, str):
        if spec == "portfolio":
            return DEFAULT_PORTFOLIO
        return (spec,)
    return tuple(spec)


def spec_length(spec) -> int:
    """Number of strategies a spec resolves to (without instantiating)."""
    return len(_normalize_spec(spec))


def build_strategies(spec) -> list:
    """Resolve a strategy spec into fresh Strategy objects.

    ``spec`` may be ``None`` (the single-strategy ``("mutation",)`` default,
    which reproduces the pre-portfolio refinement loop bit-for-bit), the
    string ``"portfolio"`` (alias for :data:`DEFAULT_PORTFOLIO`), a single
    registry name, or a sequence whose elements are registry names
    (``"mutation"`` / ``"crossover"`` / ``"annealing"``), zero-arg factories
    returning a Strategy, or live Strategy objects (single-instance
    searches only — strategies are stateful, so a fleet must receive names
    or factories to get one private copy per instance).
    """
    out = []
    for item in _normalize_spec(spec):
        if isinstance(item, str):
            if item not in STRATEGIES:
                raise ValueError(
                    f"unknown strategy {item!r}; registry: {sorted(STRATEGIES)}"
                )
            out.append(STRATEGIES[item]())
        elif isinstance(item, type) or (
            callable(item) and not hasattr(item, "propose")
        ):
            out.append(item())
        elif hasattr(item, "propose"):
            out.append(item)
        else:
            raise TypeError(f"not a strategy, factory, or name: {item!r}")
    names = [s.name for s in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate strategy names in portfolio: {names}")
    return out


class Portfolio:
    """Yield-driven budget allocator over a set of strategies.

    One ``Portfolio`` instance drives the refinement of ONE problem
    instance inside the lockstep fleet driver
    (:func:`repro.core.vectorized.schedule_fleet` constructs one per
    instance). Each round it:

      1. splits the round's candidate budget (``pool_size``) across
         strategies proportionally to their multiplicative weights (with a
         ``min_share`` exploration floor, largest-remainder rounding), and
         concatenates their proposals into one tagged block;
      2. receives pruning and scoring feedback row-by-row (``note_pruned``
         / ``observe``) as the fleet driver's shared launches complete;
      3. at ``end_round`` credits each strategy with
         ``max(0, round_start_best - round_min_strategy)`` improvement,
         converts credits to yields (improvement per evaluated candidate),
         and updates weights ``w *= exp(eta * yield / max_yield)``
         (multiplicative weights), clipped to keep every strategy alive.
         With ``yield_decay`` > 0 the update signal is a geometrically
         decayed running yield (``acc = yield_decay * acc + yield``) so a
         strategy's past rounds keep a fading vote; the update only fires
         on rounds whose *current* yields are non-zero (stalled rounds
         never re-apply stale evidence). The 0.0 default is memoryless
         and reproduces the plain update bit for bit.

    Determinism: weight arithmetic is pure float; the only randomness is
    the strategies' draws from the shared per-instance generator, in fixed
    portfolio order. With a single strategy the allocator is the identity
    (full budget, no weight dynamics), which is what makes the
    mutation-only portfolio reproduce the PR 2 loop bit-for-bit.
    """

    def __init__(
        self,
        strategies: Sequence,
        inst: ProblemInstance,
        rng: np.random.Generator,
        *,
        pool_size: int,
        eta: float = 2.0,
        min_share: float = 0.10,
        elite_capacity: int = 16,
        yield_decay: float = 0.0,
    ):
        self.strategies = list(strategies)
        if not self.strategies:
            raise ValueError("portfolio needs at least one strategy")
        self.inst = inst
        self.rng = rng
        self.pool_size = int(pool_size)
        self.eta = float(eta)
        self.min_share = float(min_share)
        self.yield_decay = float(yield_decay)
        if not 0.0 <= self.yield_decay < 1.0:
            raise ValueError("yield_decay must be in [0, 1)")
        self.elites = ElitePool(elite_capacity)
        k = len(self.strategies)
        self.weights = np.ones(k, dtype=np.float64)
        self._yield_acc = np.zeros(k, dtype=np.float64)
        self.stats = {s.name: StrategyStats() for s in self.strategies}
        self.round_index = 0
        self._view: SearchView | None = None
        self._round_min = np.full(k, np.inf)
        self._round_eval = np.zeros(k, dtype=np.int64)
        self._round_start_best = math.inf

    def _allocations(self) -> np.ndarray:
        k = len(self.strategies)
        if k == 1:
            return np.asarray([self.pool_size])
        share = self.weights / self.weights.sum()
        share = np.maximum(share, self.min_share)
        share = share / share.sum()
        counts = np.floor(share * self.pool_size).astype(np.int64)
        frac = share * self.pool_size - counts
        # Largest-remainder rounding, stable ties by portfolio order.
        for idx in np.argsort(-frac, kind="stable")[: self.pool_size - counts.sum()]:
            counts[idx] += 1
        return counts

    def _make_view(self, best_rack: np.ndarray, best_val: float) -> SearchView:
        return SearchView(
            inst=self.inst,
            rng=self.rng,
            best_rack=best_rack,
            best_val=best_val,
            elites=self.elites,
            round_index=self.round_index,
        )

    def begin_round(
        self, best_rack: np.ndarray, best_val: float
    ) -> tuple[np.ndarray, np.ndarray]:
        """Collect this round's proposals.

        Returns ``(pool, tags)``: int32[P, n_tasks] candidates and
        int32[P] per-row strategy indices (P == ``pool_size``).
        """
        self._view = self._make_view(best_rack, best_val)
        self._round_start_best = float(best_val)
        self._round_min[:] = np.inf
        self._round_eval[:] = 0
        counts = self._allocations()
        pools, tags = [], []
        n = int(np.asarray(best_rack).shape[0])
        for s_idx, (strat, c) in enumerate(zip(self.strategies, counts)):
            if c <= 0:
                continue
            block = np.asarray(strat.propose(self._view, int(c)), dtype=np.int32)
            if block.shape != (int(c), n):
                raise ValueError(
                    f"strategy {strat.name!r} proposed shape {block.shape}, "
                    f"expected {(int(c), n)}"
                )
            self.stats[strat.name].proposed += block.shape[0]
            pools.append(block)
            tags.append(np.full(block.shape[0], s_idx, dtype=np.int32))
        if not pools:  # pool_size == 0: the round is an exact no-op
            return np.zeros((0, n), dtype=np.int32), np.zeros(0, dtype=np.int32)
        return np.concatenate(pools, axis=0), np.concatenate(tags, axis=0)

    def note_pruned(self, tags: np.ndarray) -> None:
        """Record stage-1 discards (rows never reach a strategy's observe)."""
        tags = tags[tags >= 0]
        if tags.size == 0:
            return
        for s_idx, cnt in enumerate(np.bincount(tags, minlength=len(self.strategies))):
            if cnt:
                self.stats[self.strategies[s_idx].name].pruned += int(cnt)

    def observe(
        self,
        tags: np.ndarray,
        racks: np.ndarray,
        vals: np.ndarray,
        prev_best: float,
    ) -> None:
        """Feed one scored block back (sweep blocks carry tag -1: they only
        grow the elite pool; refinement rows update strategy accounting and
        are dispatched to their strategy's ``observe`` hook)."""
        self.elites.add_batch(racks, vals)
        if self._view is None or not (tags >= 0).any():
            return
        for s_idx, strat in enumerate(self.strategies):
            m = tags == s_idx
            if not m.any():
                continue
            v = vals[m]
            st = self.stats[strat.name]
            st.evaluated += int(v.size)
            st.improved += int((v < prev_best - 1e-9).sum())
            self._round_eval[s_idx] += v.size
            mn = float(v.min())
            if mn < self._round_min[s_idx]:
                self._round_min[s_idx] = mn
            strat.observe(self._view, racks[m], v)

    def end_round(self, best_rack: np.ndarray, best_val: float) -> None:
        """Close the round: strategy hooks, improvement credits, weights."""
        self._view = self._make_view(best_rack, best_val)
        for strat in self.strategies:
            strat.end_round(self._view)
        credits = np.where(
            self._round_eval > 0,
            np.maximum(0.0, self._round_start_best - self._round_min),
            0.0,
        )
        yields = credits / np.maximum(self._round_eval, 1)
        # Allocator signal: the current round's yields, plus (with
        # ``yield_decay`` > 0) a geometrically decayed memory of past
        # rounds' yields — stale evidence keeps a fading vote in how a
        # productive round's budget shift is apportioned. The update
        # itself stays gated on the *current* round producing yield
        # (``yields.max() > 0``): a stalled round must never re-apply old
        # evidence, or one early lucky round would pin the weights at the
        # clip extremes. The default 0.0 contributes exact zeros,
        # reproducing the memoryless multiplicative-weights update bit
        # for bit.
        self._yield_acc = self.yield_decay * self._yield_acc + yields
        if float(yields.max()) > 0.0 and len(self.strategies) > 1:
            signal = self._yield_acc
            self.weights *= np.exp(self.eta * signal / float(signal.max()))
            self.weights = np.clip(self.weights / self.weights.mean(), 0.05, 20.0)
        for s_idx, strat in enumerate(self.strategies):
            st = self.stats[strat.name]
            st.improvement += float(credits[s_idx])
            st.weight = float(self.weights[s_idx])
        self.round_index += 1
