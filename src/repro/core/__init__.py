"""The paper's primary contribution: optimal joint job scheduling and
bandwidth augmentation for hybrid data-center networks (Guo et al., 2022).

Layers:
  dag / instance / schedule   — problem model and OP-semantics checker
  bounds                      — §IV-A heuristic bounds (Algorithm 1)
  simulator                   — discrete-event schedule executor
  milp / solver_milp          — §IV-B/C generalized transfer model + RP
                                 linearization, solved by B&B (HiGHS)
  bisection                   — §IV-D feasibility-subproblem decomposition
  bnb                         — beyond-paper combinatorial exact B&B
  vectorized                  — beyond-paper JAX-batched assignment search
  portfolio                   — refinement strategy portfolio (mutation /
                                crossover / annealing + yield allocator)
  coflow                      — beyond-paper coflow view of an admission
                                epoch + commit-order search (sigma
                                ordering, permutation portfolio)
  baselines                   — §V comparison schedulers
"""

from repro.core.dag import (
    DagJob,
    JOB_FAMILIES,
    make_onestage_mapreduce,
    make_random_workflow,
    make_simple_mapreduce,
    random_job,
)
from repro.core.instance import CH_LOCAL, CH_WIRED, ProblemInstance
from repro.core.schedule import FeasibilityError, Schedule, check_feasible
from repro.core.bounds import (
    contention_lower_bounds,
    lower_bound,
    longest_branch,
    network_work_bounds,
    rack_load_bounds,
    upper_bound,
)
from repro.core.simulator import simulate
from repro.core.milp import build_rp, extract_schedule
from repro.core.solver_milp import MilpResult, solve_optimal, solve_rp
from repro.core.bisection import BisectionResult, solve_bisection
from repro.core.bnb import BnbResult, solve_bnb
from repro.core.vectorized import (
    FleetResult,
    VectorizedResult,
    schedule_fleet,
    vectorized_search,
)
from repro.core.portfolio import (
    ARBITRATION_STRATEGIES,
    DEFAULT_PORTFOLIO,
    AnnealingStrategy,
    CrossoverStrategy,
    MutationStrategy,
    Portfolio,
    Strategy,
    StrategyStats,
    build_strategies,
    register_arbitration_strategy,
)
from repro.core.coflow import (
    Coflow,
    OrderSearchResult,
    build_order_strategies,
    coflow_from_instance,
    coflow_from_schedule,
    search_commit_order,
    sigma_order,
)
from repro.core.baselines import (
    BASELINES,
    ONLINE_BASELINES,
    fifo_solo_schedule,
    g_list_master_schedule,
    g_list_schedule,
    greedy_list_online_schedule,
    list_schedule,
    partition_schedule,
    random_schedule,
    single_rack_schedule,
    wired_only,
)

__all__ = [
    "DagJob", "JOB_FAMILIES", "make_onestage_mapreduce", "make_random_workflow",
    "make_simple_mapreduce", "random_job",
    "CH_LOCAL", "CH_WIRED", "ProblemInstance",
    "FeasibilityError", "Schedule", "check_feasible",
    "lower_bound", "longest_branch", "upper_bound",
    "contention_lower_bounds", "network_work_bounds", "rack_load_bounds",
    "simulate",
    "build_rp", "extract_schedule",
    "MilpResult", "solve_optimal", "solve_rp",
    "BisectionResult", "solve_bisection",
    "BnbResult", "solve_bnb",
    "VectorizedResult", "vectorized_search",
    "FleetResult", "schedule_fleet",
    "DEFAULT_PORTFOLIO", "AnnealingStrategy", "CrossoverStrategy",
    "MutationStrategy", "Portfolio", "Strategy", "StrategyStats",
    "build_strategies",
    "ARBITRATION_STRATEGIES", "register_arbitration_strategy",
    "Coflow", "OrderSearchResult", "build_order_strategies",
    "coflow_from_instance", "coflow_from_schedule", "search_commit_order",
    "sigma_order",
    "BASELINES", "ONLINE_BASELINES", "fifo_solo_schedule",
    "g_list_master_schedule", "g_list_schedule", "greedy_list_online_schedule",
    "list_schedule", "partition_schedule", "random_schedule",
    "single_rack_schedule", "wired_only",
]
