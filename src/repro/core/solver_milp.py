"""Solve RP exactly by Branch and Bound (HiGHS via scipy.optimize.milp).

The paper solves RP with Gurobi's B&B; HiGHS is the offline-available
equivalent (LP-relaxation-based branch and bound with cuts). The public entry
point returns a verified :class:`Schedule` plus solver metadata.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np
from scipy.optimize import Bounds, LinearConstraint, milp

from repro.core.instance import ProblemInstance
from repro.core.milp import RPModel, build_rp, extract_schedule
from repro.core.schedule import Schedule, check_feasible

__all__ = ["MilpResult", "solve_rp", "solve_optimal"]


@dataclasses.dataclass
class MilpResult:
    schedule: Schedule | None
    makespan: float
    status: int  # scipy milp status: 0 optimal, 1 iter/time limit, 2 infeasible
    mip_gap: float
    wall_s: float
    n_vars: int
    n_constraints: int


def solve_rp(
    model: RPModel,
    time_limit: float | None = None,
    mip_rel_gap: float = 0.0,
    verify: bool = True,
) -> MilpResult:
    t0 = time.perf_counter()
    constraints = []
    if model.A_ub.shape[0]:
        constraints.append(
            LinearConstraint(model.A_ub, -np.inf, model.b_ub)
        )
    if model.A_eq.shape[0]:
        constraints.append(LinearConstraint(model.A_eq, model.b_eq, model.b_eq))
    options: dict = {"mip_rel_gap": mip_rel_gap}
    if time_limit is not None:
        options["time_limit"] = time_limit
    res = milp(
        c=model.c,
        constraints=constraints,
        integrality=model.integrality,
        bounds=Bounds(model.lb, model.ub),
        options=options,
    )
    wall = time.perf_counter() - t0
    ncons = model.A_ub.shape[0] + model.A_eq.shape[0]
    if res.x is None:
        return MilpResult(
            schedule=None,
            makespan=float("inf"),
            status=int(res.status),
            mip_gap=float("nan"),
            wall_s=wall,
            n_vars=model.vm.n_vars,
            n_constraints=ncons,
        )
    sched = extract_schedule(model, np.asarray(res.x))
    if verify:
        check_feasible(model.inst, sched, tol=1e-4)
    gap = float(getattr(res, "mip_gap", 0.0) or 0.0)
    return MilpResult(
        schedule=sched,
        makespan=sched.makespan,
        status=int(res.status),
        mip_gap=gap,
        wall_s=wall,
        n_vars=model.vm.n_vars,
        n_constraints=ncons,
    )


def solve_optimal(
    inst: ProblemInstance,
    time_limit: float | None = None,
    mip_rel_gap: float = 0.0,
    paper_exact_binding: bool = False,
    tmax: float | None = None,
) -> MilpResult:
    """Build RP for ``inst`` and solve to optimality (the paper's method)."""
    model = build_rp(
        inst, tmax=tmax, paper_exact_binding=paper_exact_binding
    )
    return solve_rp(model, time_limit=time_limit, mip_rel_gap=mip_rel_gap)
