"""Paper-solver-driven communication planning for the training loop.

This is the beyond-paper integration (DESIGN.md §2): the hybrid-DCN joint
scheduler plans the BACKWARD-PASS gradient-reduction schedule of a multi-pod
training step.

Mapping (per DESIGN.md):
  * tasks 0..L-1  = per-layer-group backward compute (chained, one "rack" =
                    the pod's compute — unary, so they serialize exactly as
                    the backward pass does);
  * task L        = the optimizer step, placed on a second "rack" so every
                    gradient edge is forced cross-rack (i.e. actually uses
                    the network, as cross-pod reductions do);
  * edge (i, L)   = layer-group i's gradient bucket, bytes = bucket size;
  * wired channel = the step's reserved ICI share (always present);
  * wireless k    = reconfigurable auxiliary channels (OCS circuits / DCN
                    overlay paths provisioned for this job's reduction).

Solving the restricted OP (fixed placement -> exact channels + sequencing via
the Giffler–Thompson level) yields the overlap schedule: which buckets
reduce on which channel, in what order, overlapped with remaining backward
compute. ``replan`` re-solves with degraded rates — the straggler-mitigation
hook used by the elastic runtime.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bnb import solve_fixed_assignment
from repro.core.dag import DagJob
from repro.core.instance import CH_WIRED, ProblemInstance
from repro.core.simulator import simulate
from repro.models.config import ModelConfig, layer_kinds

__all__ = ["LinkSpec", "PlanResult", "backward_profile", "plan_gradient_schedule", "replan"]


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Per-pod network rates, bytes/second."""

    ici_share: float = 10e9      # reserved ICI share for cross-pod reduction
    aux_channels: int = 2        # reconfigurable OCS/DCN channels |K|
    aux_rate: float = 4e9        # per aux channel


@dataclasses.dataclass
class PlanResult:
    t_optimal: float       # joint schedule (paper's method)
    t_greedy: float        # greedy earliest-finish channel overlap
    t_serial: float        # no overlap: all reductions after backward, wired only
    schedule: object       # repro.core Schedule for the optimal plan
    channel_of_bucket: np.ndarray  # 0 = ICI share, >=2: aux channel id
    proved_optimal: bool

    @property
    def gain_vs_serial(self) -> float:
        return 1.0 - self.t_optimal / self.t_serial

    @property
    def gain_vs_greedy(self) -> float:
        return 1.0 - self.t_optimal / self.t_greedy


def backward_profile(
    cfg: ModelConfig,
    tokens_per_device: int,
    chip_flops: float = 197e12,
    groups: int = 8,
    mfu: float = 0.4,
) -> tuple[np.ndarray, np.ndarray]:
    """(compute_seconds[groups], grad_bytes[groups]) for one device's
    backward pass, grouping layers into ``groups`` reduction buckets."""
    kinds = layer_kinds(cfg)
    d, ff = cfg.d_model, cfg.d_ff
    per_layer_params = []
    for mixer, ffn in kinds:
        p = 0.0
        if mixer in ("attn", "attn_cross", "cross"):
            p += d * cfg.n_heads * cfg.head_dim * 2 + 2 * d * cfg.n_kv_heads * cfg.head_dim
            if mixer == "attn_cross":
                p *= 2
        else:  # recurrent mixers, approximate with their projections
            p += 2 * d * cfg.d_inner + cfg.d_inner * d
        if ffn == "mlp":
            p += 3 * d * ff
        elif ffn == "moe":
            p += 3 * d * ff * cfg.experts_per_token  # active compute
        per_layer_params.append(p)
    per_layer_params = np.asarray(per_layer_params)
    # backward ~ 4·P·tokens flops (2x forward), at assumed MFU
    secs = 4.0 * per_layer_params * tokens_per_device / (chip_flops * mfu)
    # gradient bytes: full parameters (incl. all experts), bf16-compressed
    grad_bytes = []
    for (mixer, ffn), p in zip(kinds, per_layer_params):
        full = p if ffn != "moe" else p / max(cfg.experts_per_token, 1) * cfg.n_experts
        grad_bytes.append(2.0 * full)
    grad_bytes = np.asarray(grad_bytes)
    # bucket into groups (backward order: last layer first)
    groups = min(groups, len(kinds))  # never emit empty (zero-byte) buckets
    idx = np.array_split(np.arange(len(kinds))[::-1], groups)
    g_secs = np.asarray([secs[i].sum() for i in idx])
    g_bytes = np.asarray([grad_bytes[i].sum() for i in idx])
    return g_secs, g_bytes


def _build_instance(
    g_secs: np.ndarray, g_bytes: np.ndarray, link: LinkSpec
) -> tuple[ProblemInstance, np.ndarray]:
    L = len(g_secs)
    # tasks: 0..L-1 backward groups (chained), L = optimizer step (tiny).
    p = np.concatenate([g_secs, [1e-6]])
    edges = []
    d = []
    for i in range(L - 1):
        edges.append((i, i + 1))   # backward chain, zero-size local edge
        d.append(0.0)
    for i in range(L):
        edges.append((i, L))       # gradient bucket -> optimizer
        d.append(g_bytes[i])
    job = DagJob(p=p, edges=np.asarray(edges), d=np.asarray(d), name="backward")
    inst = ProblemInstance(
        job=job,
        n_racks=2,
        n_wireless=link.aux_channels,
        wired_rate=link.ici_share,
        wireless_rate=link.aux_rate,
        local_delay=0.0,
    )
    rack = np.asarray([0] * L + [1], dtype=np.int64)
    return inst, rack


def plan_gradient_schedule(
    g_secs: np.ndarray,
    g_bytes: np.ndarray,
    link: LinkSpec = LinkSpec(),
    time_limit: float = 10.0,
) -> PlanResult:
    inst, rack = _build_instance(g_secs, g_bytes, link)
    L = len(g_secs)

    # Serial baseline: no overlap, single wired channel.
    t_serial = float(np.sum(g_secs) + np.sum(g_bytes) / link.ici_share)

    # Greedy overlap (earliest-finish channel, list order).
    greedy = simulate(inst, rack, use_wireless=link.aux_channels > 0)
    t_greedy = greedy.makespan

    # Paper's optimal joint schedule (fixed placement level).
    res = solve_fixed_assignment(inst, rack, time_limit=time_limit)
    sched = res.schedule
    chan = np.full(L, CH_WIRED, dtype=np.int64)
    for e in range(inst.job.n_edges):
        u, v = inst.job.edges[e]
        if v == L and inst.job.d[e] > 0:
            chan[int(u)] = sched.chan[e]
    return PlanResult(
        t_optimal=sched.makespan,
        t_greedy=t_greedy,
        t_serial=t_serial,
        schedule=sched,
        channel_of_bucket=chan,
        proved_optimal=res.proved_optimal,
    )


def replan(
    g_secs: np.ndarray,
    g_bytes: np.ndarray,
    link: LinkSpec = LinkSpec(),
    compute_slowdown: float = 1.0,
    degraded_aux: int | None = None,
    time_limit: float = 10.0,
) -> PlanResult:
    """Straggler / failure mitigation: re-plan with degraded resources.

    compute_slowdown > 1 models a slow pod (all compute stretched);
    degraded_aux drops auxiliary channels (OCS circuit loss).
    """
    link2 = LinkSpec(
        ici_share=link.ici_share,
        aux_channels=link.aux_channels if degraded_aux is None else degraded_aux,
        aux_rate=link.aux_rate,
    )
    return plan_gradient_schedule(
        g_secs * compute_slowdown, g_bytes, link2, time_limit=time_limit
    )
