"""Sharding policy: parameter/optimizer/cache NamedShardings + activation rules.

Strategy (production mesh, v5e target):
  * Batch (DP): over ('pod', 'data') — multi-pod data parallelism.
  * FSDP: parameter/optimizer rows sharded over 'data' (within-pod only —
    cross-pod parameter gathers would traverse DCN every layer).
  * TP: attention heads / FFN inner / experts (EP) over 'model'.

Every rule degrades gracefully: an axis is dropped from a spec whenever the
dimension is not divisible by the axis extent (e.g. seamless's 256206 vocab
over 16-way 'model', or batch=1 long-context cells over 'data'). This keeps
one policy valid for all 10 architectures × 4 input shapes.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "batch_axes",
    "fit_spec",
    "param_sharding",
    "state_sharding",
    "cache_sharding",
    "batch_sharding",
    "activation_rules",
]


def batch_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, axis) -> int | None:
    """Extent of a (possibly tuple) mesh axis; None if absent from mesh."""
    if axis is None:
        return 1
    names = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for a in names:
        if a not in mesh.shape:
            return None
        size *= int(mesh.shape[a])
    return size


def fit_spec(mesh: Mesh, shape: tuple[int, ...], spec: P) -> P:
    """Drop axes absent from the mesh or whose extent does not divide the
    dimension."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, parts):
        size = _axis_size(mesh, axis) if axis else None
        out.append(axis if axis and size and dim % size == 0 else None)
    return P(*out)


# (path regex, spec builder) — first match wins. Specs exclude the stacked
# leading repeat axis, which is added automatically for leaves under
# ['layers'] / ['enc'].
_PARAM_RULES: list[tuple[str, P]] = [
    (r"\['embed'\]\['table'\]", P("model", "data")),
    (r"\['out'\]\['table'\]", P("model", "data")),
    # Attention: column-parallel QKV, row-parallel O.
    (r"\['w[qkv]'\]\['w'\]", P("data", "model")),
    (r"\['w[qkv]'\]\['b'\]", P("model")),
    (r"\['wo'\]\['w'\]", P("model", "data")),
    (r"\['wo'\]\['b'\]", P()),
    # Dense MLP (wi/wg are column-parallel; wo matched above).
    (r"\['w[ig]'\]\['w'\]", P("data", "model")),
    # MoE: experts over 'model' (EP), rows FSDP over 'data'.
    (r"\['moe'\]\['router'\]", P("data", None)),
    (r"\['moe'\]\['w[ig]'\]", P("model", "data", None)),
    (r"\['moe'\]\['wo'\]", P("model", None, "data")),
    # SSD / mamba.
    (r"\['w[zx]'\]\['w'\]", P("data", "model")),
    (r"\['wbc'\]", P("data", None)),
    (r"\['wdt'\]", P("data", None)),
    (r"\['conv_w'\]", P(None, "model")),
    (r"\['conv_b'\]", P("model")),
    (r"\['out_proj'\]\['w'\]", P("model", "data")),
    # xLSTM blocks.
    (r"\['up'\]\['w'\]", P("data", "model")),
    (r"\['down'\]\['w'\]", P("model", "data")),
    (r"\['wif'\]\['w'\]", P("data", None)),
    (r"\['wx'\]\['w'\]", P("data", "model")),
    (r"\['wh'\]\['w'\]", P("data", "model")),
    # Norm scales and leftovers: replicate.
    (r".*", P()),
]


def _spec_for_path(path: str, shape: tuple[int, ...]) -> P:
    stacked = "['layers']" in path or "['enc']" in path
    core_shape = shape[1:] if stacked else shape
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            chosen = spec
            break
    if stacked:
        chosen = P(*((None,) + tuple(chosen) + (None,) * max(0, len(core_shape) - len(chosen))))
    return chosen


def param_sharding(params_shapes: Any, mesh: Mesh) -> Any:
    """NamedSharding tree for a params (or grads/opt-moment) shape tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        shape = tuple(leaf.shape)
        spec = fit_spec(mesh, shape, _spec_for_path(pstr, shape))
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def state_sharding(state_shapes: Any, mesh: Mesh) -> Any:
    """TrainState sharding: m/v mirror params; scalars replicate."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
    out = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        shape = tuple(leaf.shape)
        if leaf.ndim == 0:
            out.append(NamedSharding(mesh, P()))
            continue
        spec = fit_spec(mesh, shape, _spec_for_path(pstr, shape))
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def cache_sharding(cache_shapes: Any, mesh: Mesh) -> Any:
    """Decode-cache sharding.

    Attention KV [R, B, T, KV, D]: batch over DP axes when divisible,
    otherwise the TIME axis shards over 'data' (long-context, batch=1);
    D over 'model' when divisible. States shard batch + heads.
    """
    dp = batch_axes(mesh)

    def leaf_spec(path: str, shape: tuple[int, ...]) -> P:
        nd = len(shape)
        if nd == 0:
            return P()
        if re.search(r"\['memory'\]", path):
            return fit_spec(mesh, shape, P(dp, None, None))
        if re.search(r"\['[kv]'\]$", path) and nd == 5:
            R, B, T, KV, D = shape
            if B % _axis_size(mesh, dp) == 0:
                return fit_spec(mesh, shape, P(None, dp, None, None, "model"))
            return fit_spec(mesh, shape, P(None, None, "data", None, "model"))
        if re.search(r"\['ssm'\]", path) and nd == 5:
            return fit_spec(mesh, shape, P(None, dp, "model", None, None))
        if re.search(r"\['conv'\]", path) and nd == 4:
            return fit_spec(mesh, shape, P(None, dp, None, "model"))
        if re.search(r"\['C'\]", path) and nd == 4:
            return fit_spec(mesh, shape, P(None, dp, "model", None))
        # Generic states: shard batch dim (axis 1 after stacking) if possible.
        spec = [None] * nd
        if nd >= 2:
            spec[1] = dp
        return fit_spec(mesh, shape, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        out.append(NamedSharding(mesh, leaf_spec(pstr, tuple(np.shape(leaf)))))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_sharding(batch_shapes: Any, mesh: Mesh) -> Any:
    dp = batch_axes(mesh)

    def spec(leaf) -> NamedSharding:
        shape = tuple(leaf.shape)
        s = [None] * len(shape)
        if len(shape) >= 1:
            s[0] = dp
        return NamedSharding(mesh, fit_spec(mesh, shape, P(*s)))

    return jax.tree.map(spec, batch_shapes)


def activation_rules(mesh: Mesh) -> dict[str, NamedSharding]:
    """Logical-activation constraints consumed by models.layers.shard()."""
    import os

    dp = batch_axes(mesh)
    mk = lambda *spec: NamedSharding(mesh, P(*spec))
    # Activation residency mode (§Perf iterations):
    #   dshard     — hidden d-sharded everywhere (min HBM footprint/traffic;
    #                consumers re-gather per use)
    #   replicated — hidden replicated over 'model' (min collectives; remat
    #                carry is full-size)
    #   boundary   — d-sharded carry, un-sharded once per period
    mode = os.environ.get("REPRO_ACT_MODE", "dshard")
    full = mk(dp, None, None)
    dsh = mk(dp, None, "model")
    if mode == "replicated":
        act = {"act_in": full, "act_mid": full, "act_out": full}
    elif mode == "boundary":
        act = {"act_in": full, "act_mid": full, "act_out": dsh}
    else:
        act = {"act_in": dsh, "act_mid": dsh, "act_out": dsh}
    return {
        **act,
        "act_hidden": act["act_out"],
        "act_logits": mk(dp, None, "model"),
        "act_ffn": mk(dp, None, "model"),
        "act_heads": mk(dp, None, "model", None),
        "act_lse": mk(dp, None, "model"),
        # Experts over 'model' (EP); capacity deliberately UNSHARDED: a
        # (model, data) spec was measured 7.5x WORSE on collectives (GSPMD
        # reshards the whole dispatch; see §Perf refuted iteration). The
        # proper fix is an explicit shard_map all-to-all dispatch.
        "act_expert": mk("model", None, None),
        "act_expert_ffn": mk("model", None, None),
    }
