"""Arrival-driven scheduling service: a three-stage epoch pipeline over the
fleet engine with warm-started re-optimization.

Each admission epoch runs the same pipeline:

  1. **Collect** (:meth:`OnlineScheduler._collect_arrivals`). Arrivals are
     pulled from a lazily consumed stream (any iterator of time-sorted
     :class:`~repro.online.workload.ArrivalEvent`; a plain list is sorted
     and wrapped) and batched into the epoch — the first unserved arrival
     opens a window of length ``window``; every job arriving inside it
     joins the epoch's batch. Completions due at the epoch wake the loop
     and release their grants back into the incrementally maintained
     free-rack/subchannel sets (delta-updates on grant/release instead of
     per-epoch ``np.nonzero`` rebuilds over all holds).
  2. **Plan** (:meth:`OnlineScheduler._plan_batch`). Admission selection
     draws residual views from shrinking per-epoch pools so co-admitted
     jobs' grants are disjoint, then all admission and planning solves of
     the epoch launch as ONE ``schedule_fleet`` mega-batch: the lockstep
     driver and the fused §IV-A stage-1 pruner are shared across the
     batch, each job's ``OpTables`` (built once at first solve, cached on
     the queue entry) skip the per-launch rebuild, and compiled programs
     are reused across epochs — fleets in the same size bucket retrace
     nothing, so steady state launches with zero retraces.
  3. **Arbitrate & commit** (:meth:`OnlineScheduler._arbitrate_and_commit`).
     Every commit — fleet policy and baselines alike — passes through the
     timeline's cross-job arbitration pass, which sequences the job's
     transfers around the busy intervals already committed on its
     physical channels (the shared wired channel above all) by replaying
     the schedule through the host simulator; committed timelines are
     audited channel-feasible before ``serve`` returns. Committed grants
     are pushed into the free sets and per-completion streaming stats
     (p50/p90/p99 queueing delay and JCT, peak gauges), and — with
     ``compact_interval > 0`` — the timeline's interval index is
     periodically compacted so steady-state cost depends only on *active*
     jobs, not the full arrival history (observationally identical;
     locked by ``tests/test_online_scale.py``).

  2b. **Backfilling** (``backfill=True``, an extension of
     ``preserve_order``): when the head-of-line job is blocked, a later
     queued job may overtake it only when arbitration *proves* it cannot
     delay the head-of-line admission — either the candidate's
     post-arbitration completion lands by the head job's resource
     reservation (the earliest time its demanded racks/subchannels can
     all be free, so everything the candidate touches is released again
     in time), or, shadow slack, the reservation keeps enough free
     resources for the head job even with the candidate's grant removed
     for good. A candidate that cannot prove either stays queued (its
     solve still feeds the warm-start incumbents).

  **Warm-started re-optimization.** A job that cannot be admitted
  (no free rack, or fewer than ``min_free_racks``) stays queued, but is
  still *planned* in the epoch's mega-batch against its full demanded
  shape. With ``warm_start=True`` each planning solve (and the eventual
  admission solve) seeds the engine's sweep with the job's incumbent
  assignments via the ``seed_pools`` hook — budget-neutral (seeds
  displace an equal number of random samples), so warm vs cold is an
  equal-candidate-budget comparison, and since seeds are themselves
  evaluated, a warm re-solve can never return a worse assignment than
  its own incumbent's greedy score.

Determinism: with a fixed ``seed`` and a fixed arrival stream the service
is bit-reproducible. Engine seeds follow a common-random-numbers
discipline (the standard variance-reduction tool for comparing policies
on one trace): a job's *admission* solve always uses
``seed + 1009 * job_id``, while *planning* re-solves of a queued job add
``9173 * n_prior_solves`` so each re-optimization explores fresh samples.
Consequence: a cold-start arm's committed result for job ``j`` is the
deterministic unseeded solve ``R_j`` (its admission solve ignores queue
history), and a warm arm's chain *starts* at exactly ``R_j`` (the first
solve has no incumbents yet and shares its seed) — so keep-incumbent
re-optimization makes the warm arm's served *solver* makespan provably
<= the cold arm's for every job whose admitted shape matches its
planning shape (e.g. under ``require_full_demand``). The post-arbitration
completion additionally depends on the other jobs sharing the physical
channels, so the per-job guarantee is on the served schedule, not on the
cross-job channel queueing around it.

Degenerate reduction (locked by ``tests/test_online.py``): with every job
arriving at t=0, ``window=0``, an empty cluster granting every job its
full demanded shape, and no cross-job traffic on the shared wired
channel, the single epoch's batch is exactly a direct ``schedule_fleet``
call — per-job assignments and JCTs are bit-for-bit identical.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
import time as _time
from collections.abc import Sequence as _SequenceABC
from typing import Iterable, Iterator, Sequence

import numpy as np

from repro.core.baselines import ONLINE_BASELINES
from repro.core.bounds import lower_bound
from repro.core.coflow import (
    coflow_from_instance,
    coflow_from_schedule,
    search_commit_order,
    sigma_order,
)
from repro.core.schedule import Schedule
from repro.core.simulator import OpTables, build_op_tables
from repro.core.vectorized import schedule_fleet
from repro.online.cluster import (
    ClusterTimeline,
    ResidualView,
    channel_delay_attribution,
    replay_commit_order,
    reservation_backfill_safe,
)
from repro.core.instance import Topology
from repro.online.metrics import JobMetrics, OnlineResult, StreamingSeries
from repro.online.workload import ArrivalEvent, LinkEvent
from repro.obs.trace import as_tracer

__all__ = ["OnlineScheduler", "DEFAULT_SOLVER_KWARGS"]

# Engine budget per epoch solve. Deliberately lighter than the offline
# defaults: a serving epoch re-optimizes often, so per-solve budget trades
# against responsiveness. Benchmarks override freely.
DEFAULT_SOLVER_KWARGS = dict(
    max_enumerate=2_000,
    n_samples=512,
    batch_size=512,
    refine_rounds=2,
    refine_pool=256,
)


def _shape_key(inst) -> tuple:
    """Resource-shape fingerprint an incumbent schedule is valid for.

    A stored schedule replays only against a view with the same rack /
    subchannel counts AND the same induced topology mask — under a
    reconfigurable topology a channel pick that was feasible last epoch
    may be unreachable now. An all-ones mask never restricts a pick, so
    it fingerprints identically to ``topology=None``: queued planning
    solves run on the topology-free full-demand instance, and their
    incumbents must stay commit-eligible on an unrestricted view (this is
    what keeps the static all-ones serve bit-identical to pre-topology).
    """
    key: tuple = (inst.n_racks, inst.n_wireless)
    if inst.topology is not None and not inst.topology.is_all_ones:
        key += (inst.topology.reach.tobytes(),)
    return key


@dataclasses.dataclass(eq=False)
class _PendingJob:
    """Queue entry: one arrived, not-yet-admitted job.

    Identity equality (``eq=False``): queue membership is by object, and
    the generated field-wise ``__eq__`` would compare nested numpy arrays
    (ambiguous truth value) the moment ``list.remove`` scans past a
    *different* entry with an equal arrival time — which reordered
    commits do routinely.
    """

    event: ArrivalEvent
    n_solves: int = 0
    # Distinct incumbent assignments from prior solves, best-first
    # (labels in the shape of the solve that produced them; the seed-pool
    # hook folds them into the residual shape with a modulo).
    incumbents: list[np.ndarray] = dataclasses.field(default_factory=list)
    # Best *simulated* schedule over the job's solve chain, with the
    # resource shape it was solved for: a warm admission commits this
    # incumbent schedule when the fresh re-solve fails to beat it (and
    # the admitted shape matches), making the served makespan monotone
    # over re-optimizations.
    best_sched: Schedule | None = None
    best_makespan: float = np.inf
    best_shape: tuple | None = None  # _shape_key of the producing solve
    # Simulator op tables for this job, built on first solve and reused
    # across every re-optimization epoch (tables depend only on the job's
    # DAG, so one build serves full-demand and residual shapes alike).
    op_tables: OpTables | None = None
    # Free-capacity fingerprint at the job's last planning solve; the
    # bounded re-plan mode skips re-solving while it is unchanged.
    view_sig: tuple | None = None
    # SLO admission state: how many later-arriving jobs were admitted
    # ahead of this one (bounded by ``max_overtakes`` when set), the
    # cached rigorous lower bound backing the rejection proof, and the
    # defer-mode flag that stops protecting a provably unmeetable
    # deadline (the job then serves ASAP and the miss is counted).
    n_overtaken: int = 0
    lb: float | None = None
    hopeless: bool = False

    def tables(self) -> OpTables:
        if self.op_tables is None:
            self.op_tables = build_op_tables(self.event.inst)
        return self.op_tables

    def remember(self, res, shape: tuple, cap: int) -> None:
        assignment = np.asarray(res.best_assignment, dtype=np.int64)
        key = assignment.tobytes()
        self.incumbents = [a for a in self.incumbents if a.tobytes() != key]
        self.incumbents.insert(0, assignment.copy())
        del self.incumbents[cap:]
        # A shape change invalidates the stored schedule (it was feasible
        # only for the old resource view); same-shape solves keep the min.
        if shape != self.best_shape or res.makespan < self.best_makespan:
            self.best_sched = res.schedule
            self.best_makespan = float(res.makespan)
            self.best_shape = shape


class _ArrivalStream:
    """Pull-based arrival source consumed one event at a time.

    A materialized ``Sequence`` is sorted by ``(time, job_id)`` exactly as
    the pre-pipeline loop did; any other iterable is treated as a lazy
    stream and must already be time-sorted (enforced event by event), so
    100k-arrival traces flow through the service without ever
    materializing.
    """

    __slots__ = ("_it", "_next", "_last_time")

    def __init__(self, arrivals: Iterable[ArrivalEvent]):
        if isinstance(arrivals, _SequenceABC):
            self._it: Iterator[ArrivalEvent] = iter(
                sorted(arrivals, key=lambda e: (e.time, e.job_id))
            )
        else:
            self._it = iter(arrivals)
        self._next: ArrivalEvent | None = None
        self._last_time = -np.inf
        self._advance()

    def _advance(self) -> None:
        self._next = next(self._it, None)
        if self._next is not None:
            if self._next.time < self._last_time:
                raise ValueError(
                    "streaming arrivals must be sorted by time "
                    f"(saw {self._next.time} after {self._last_time})"
                )
            self._last_time = self._next.time

    @property
    def exhausted(self) -> bool:
        return self._next is None

    def peek_time(self) -> float:
        return self._next.time if self._next is not None else np.inf

    def pop(self) -> ArrivalEvent:
        ev = self._next
        assert ev is not None
        self._advance()
        return ev


class _FreeSet:
    """Incrementally maintained set of free resource ids.

    Mirrors ``np.nonzero(hold <= t)[0]`` without re-scanning the hold
    vector every epoch: ``grant`` removes an id and records its release time
    in a min-heap; ``advance`` pops due releases and re-checks the *live*
    hold (a later commit may have extended it — the stale heap entry is
    then re-pushed at the real hold, so entries are self-correcting). The
    id list stays sorted, so ``as_array()`` is bit-identical to the
    ``np.nonzero`` scan at every epoch.
    """

    __slots__ = ("ids", "_members", "_releases")

    def __init__(self, n: int):
        self.ids: list[int] = list(range(n))
        self._members = set(self.ids)
        self._releases: list[tuple[float, int]] = []

    def advance(self, t: float, hold: np.ndarray) -> None:
        rel = self._releases
        while rel and rel[0][0] <= t:
            _, i = heapq.heappop(rel)
            if i in self._members:
                continue
            h = float(hold[i])
            if h <= t:
                bisect.insort(self.ids, i)
                self._members.add(i)
            else:  # stale entry: the hold was extended after this push
                heapq.heappush(rel, (h, i))

    def grant(self, i: int, release: float) -> None:
        if i in self._members:
            del self.ids[bisect.bisect_left(self.ids, i)]
            self._members.discard(i)
        heapq.heappush(self._releases, (float(release), i))

    def as_array(self) -> np.ndarray:
        return np.asarray(self.ids, dtype=np.int64)

    def __len__(self) -> int:
        return len(self.ids)


@dataclasses.dataclass
class _EpochPlan:
    """Output of the plan stage, consumed by arbitrate-and-commit."""

    admit: list[_PendingJob]
    views: list[ResidualView]
    is_backfill: list[bool]
    hol_need: tuple[int, int] | None
    # Fleet policy: one engine result per admitted job (solves already
    # counted); baselines solve lazily inside the commit stage because
    # their placement depends on the busy intervals of this epoch's
    # earlier commits.
    results: list | None


@dataclasses.dataclass
class _ServeState:
    """Mutable state threaded through one ``serve`` run's pipeline."""

    cluster: ClusterTimeline
    free_r: _FreeSet
    free_w: _FreeSet
    queue_stats: StreamingSeries
    jct_stats: StreamingSeries
    pending: list[_PendingJob] = dataclasses.field(default_factory=list)
    completions: list[float] = dataclasses.field(default_factory=list)
    records: list[JobMetrics] = dataclasses.field(default_factory=list)
    counters: dict = dataclasses.field(
        default_factory=lambda: {
            "epochs": 0, "batches": 0, "solves": 0,
            "candidates": 0, "pruned": 0, "wall": 0.0,
            "backfilled": 0, "backfill_rejected": 0,
            "order_evals": 0, "epochs_reordered": 0,
            "arbitration_gain": 0.0,
            "deadline_jobs": 0, "deadline_missed": 0,
            "deadline_deferrals": 0, "deadline_rejected": 0,
            "max_overtaken": 0,
            "reconfigs": 0, "link_events": 0,
        }
    )
    peak_active: int = 0
    peak_queue: int = 0
    n_served: int = 0
    epoch_latency: list[float] | None = None
    avail_sig: tuple | None = None
    stream_exhausted: bool = False
    # Cursor into the service's sorted outage trace (events applied once).
    outage_pos: int = 0
    # Per-tier (met, total) SLO tallies, per-tenant queueing-delay
    # sketches and attained service (the wfair ordering key), and the
    # stream ids dropped by admission_control="reject".
    tier_slo: dict = dataclasses.field(default_factory=dict)
    tenant_queue: dict = dataclasses.field(default_factory=dict)
    tenant_service: dict = dataclasses.field(default_factory=dict)
    rejected_ids: list = dataclasses.field(default_factory=list)


class OnlineScheduler:
    """Serve an arrival stream on one cluster.

    Args:
      n_racks: physical racks in the cluster.
      n_wireless: physical wireless subchannels (0 = wired-only cluster,
        i.e. bandwidth augmentation off).
      window: admission window length — arrivals within ``window`` of the
        epoch-opening arrival are batched into one mega-batch solve.
        ``0.0`` gives every arrival instant its own epoch.
      policy: ``"fleet"`` (the mega-batch search engine, default) or an
        online baseline name from
        :data:`repro.core.baselines.ONLINE_BASELINES` (``"fifo_solo"``
        serves one job at a time on the idle cluster; ``"greedy_list"``
        admits on residual capacity but places jobs with the G-List
        heuristic instead of searching).
      warm_start: seed each queued job's re-solve (and its admission
        solve) with its incumbent assignments. Fleet policy only.
      min_free_racks: admit only when at least this many racks are free;
        queued jobs below the threshold are planned, not placed.
      require_full_demand: admit a job only when its full demanded shape
        (``inst.n_racks`` racks and ``inst.n_wireless`` subchannels) is
        free, instead of running degraded on a smaller residual. Queued
        jobs wait (and keep re-planning) until capacity frees up; because
        the planning shape then equals the admission shape, warm-start
        incumbents transfer exactly.
      preserve_order: admit strictly in arrival order — the first queued
        job that does not fit blocks everything behind it (head-of-line
        FIFO, no overtaking). Keeps service trajectories stable under
        small makespan perturbations, at the cost of some utilization.
      backfill: relax ``preserve_order`` head-of-line blocking with
        conservative (EASY-style) backfilling: a queued job behind the
        blocked head-of-line job may be admitted out of order only when
        its *post-arbitration* completion lands at or before the head
        job's resource reservation — the earliest time the head job's
        demanded racks and subchannels can all be free given the current
        holds — so every resource the overtaker touches is released by
        then and the head-of-line admission epoch is provably never
        delayed. Requires ``preserve_order=True`` (without it every
        fitting job may overtake anyway). Ignored by the solo baselines
        (``fifo_solo`` / ``edf_solo``). Under a non-FIFO ``admission``
        order, "head-of-line" means the head of the *admission-ordered*
        queue (e.g. the earliest-deadline job under ``"edf"``) — the
        same blocking and backfill proofs apply to that order.
      seed: master seed for the per-solve engine seeds (see module
        docstring for the exact derivation).
      seed_pool_size: incumbents remembered per queued job.
      solver_kwargs: overrides merged over :data:`DEFAULT_SOLVER_KWARGS`
        and passed to :func:`repro.core.vectorized.schedule_fleet`.
      compact_interval: compact the timeline's interval index every this
        many epochs (0, the default, never compacts — the full committed
        history stays inspectable on ``OnlineResult.timeline``).
        Compaction is observationally identical (same commits, same
        metrics; locked by ``tests/test_online_scale.py``) and keeps
        steady-state memory proportional to *active* jobs — turn it on
        for long streams.
      replan: ``"always"`` (default) re-solves every queued job every
        epoch — the PR 5 behavior the warm-vs-cold equal-budget
        comparisons rest on. ``"changed"`` bounds the re-plan set: a
        queued job is re-solved only when the free-capacity fingerprint
        (free rack/subchannel id sets) changed since its last planning
        solve — re-solving against an unchanged cluster can only redraw
        fresh random samples, so skipping it trades that marginal
        exploration for an O(changed) epoch cost. Changes ``n_solves``
        and (under ``warm_start``) incumbent chains; keep ``"always"``
        for budget-matched policy comparisons.
      record_jobs: keep one :class:`JobMetrics` per served job (default).
        ``False`` drops the per-job list (streaming stats, gauges and
        counters still populate) so 100k-job stress runs hold O(active)
        memory.
      track_epoch_latency: record the wall-clock seconds of each epoch's
        arbitrate-and-commit stage on ``OnlineResult.epoch_commit_latency``
        (the stress lane's flat-latency check; off by default).
      arbitration: cross-job commit-order policy within an epoch.
        ``"fifo"`` (default) commits in queue order — bit-identical to
        the pre-coflow service on every stream. ``"sigma"`` commits in
        the Sincronia-style bottleneck-first coflow order
        (:func:`repro.core.coflow.sigma_order`). ``"search"`` evaluates
        candidate orders by trial-replaying them through the timeline's
        ``channel_busy`` hook (:func:`repro.online.cluster
        .replay_commit_order`) and commits the best: small batches are
        solved exactly by permutation enumeration, larger ones run the
        portfolio-driven neighborhood search seeded with FIFO and sigma
        — FIFO is always evaluated, so a searched epoch never commits an
        order with a worse replayed objective than FIFO (rejected
        backfills first, then total batch JCT).
      arbitration_rounds / arbitration_pool: neighborhood-search budget
        for ``arbitration="search"`` on batches too large to enumerate —
        rounds of the portfolio allocator and candidate orders per round.
      wireless_grants: ``"hold"`` (default) grants a wireless subchannel
        only when free (its hold has expired) — exclusive grants, the
        pre-coflow behavior. ``"interval"`` additionally lets an epoch's
        admission pool reach subchannels still *held* by running jobs
        (free ones first): the busy-interval index proves exactly which
        windows are taken, so a new job's transfers gap-insert around the
        holder's — disjointness is guaranteed by the same arbitration
        pass as the wired channel (the end-of-serve audit covers it).
        Trades earlier admission for possible channel queueing on the
        shared subchannel.
      admission: queue-ordering policy for admission selection.
        ``"fifo"`` (default) considers the queue strictly in arrival
        order — bit-identical to the pre-SLO service on every stream (no
        sort, no extra RNG or float work). ``"edf"`` orders by earliest
        deadline first (deadline-less jobs last, arrival-order
        tie-break) — EDF *within feasibility*: the ordering only ranks
        the queue, every admission still passes the same capacity /
        head-of-line / backfill machinery. ``"wfair"`` orders by weighted
        attained service: each tenant accumulates the makespan of its
        committed jobs, and the queue is ranked by
        ``attained_service[tenant] / weight`` ascending (see
        ``tenant_weights``), so light / high-share tenants are served
        first and cross-tenant fairness is enforced continuously.
      admission_control: what to do about jobs whose deadline cannot be
        met. ``"none"`` (default) serves everything and just counts
        misses. ``"reject"`` drops a queued job the moment the rigorous
        proof ``now + lower_bound(inst) > deadline`` holds — the bound
        is the resource-independent critical path
        (:func:`repro.core.bounds.lower_bound`), and epochs only move
        forward, so a job rejected now could never meet its deadline in
        any future epoch either; rejected ids land on
        ``OnlineResult.rejected_job_ids`` (no ``JobMetrics`` row, JCT
        aggregates unpolluted). ``"defer"`` never drops: a job whose
        *post-arbitration* completion would overrun its deadline — the
        same mutation-free trial arbitration
        :func:`repro.online.cluster.replay_commit_order` replays, so the
        proof is exact, and ``replay_commit_order(...,
        deadlines=...)`` predicts every defer bit-for-bit — stays queued
        for a later (possibly less contended) epoch instead of
        committing a guaranteed miss. Deferral is bounded: once the
        deadline passes (or the lower-bound proof shows it must), the
        job serves ASAP and the miss is counted, and a job never defers
        without a future wakeup to retry on (no livelock — the deadlock
        guard stays unreachable).
      max_overtakes: starvation bound — a queued job may see at most
        this many later-arriving jobs admitted ahead of it (via non-FIFO
        admission orders or backfilling). Saturated jobs are hoisted to
        the head of the admission queue, and any admission that would
        overtake a saturated job is withheld that epoch. Overtakes are
        counted per job (``JobMetrics.n_overtaken``) and the bound is
        asserted at every commit — exceeding it raises, it is an
        invariant, not advice. ``None`` (default) counts overtakes under
        non-FIFO admission but does not bound them.
      tenant_weights: ``wfair`` share per tenant tag (falls back to the
        job's *tier* tag, then 1.0) — a tenant with weight 2 is entitled
        to twice the attained service of a weight-1 tenant before
        ranking behind it. Unknown tags default to 1.0.
      topology: wireless-link configuration policy under a
        ``cluster_topology`` — ``"static"`` (default) exposes the
        topology's reach mask as-is (minus outaged links), while
        ``"matching"`` re-matches the links to the queue's wireless
        demand every epoch (greedy weighted b-matching under the
        topology's degree limits; reconfigured subchannels are charged
        the topology's δ as busy time). Ignored without a
        ``cluster_topology``; with an all-ones topology and no outages,
        ``"static"`` serves bit-identically to no topology at all.
      cluster_topology: optional cluster-level
        :class:`~repro.core.instance.Topology` over
        ``[n_racks, n_wireless]``. Residual views carry its induced mask,
        so every solver stage co-optimizes placement, channel assignment
        and the active matching. ``None`` (default) = the paper's model.
      outages: optional seeded link outage trace
        (:func:`repro.online.workload.link_outage_trace`): events with
        ``time <= epoch`` flip the cluster's link state, and the active
        link set folds into the ``replan="changed"`` fingerprint so
        flaps re-solve exactly the invalidated plans.
      tracer: optional :class:`repro.obs.trace.Tracer`. When set, each
        epoch records nested wall-time spans (``epoch`` →
        ``collect_arrivals`` / ``plan_batch`` / ``arbitrate_and_commit``),
        typed decision events at every admission / arbitration / backfill
        branch, per-job lifecycle marks in simulated time, and the
        metrics registry (``queue_depth`` / ``epoch_latency`` histograms,
        ``prune_rate`` / per-tier ``slo_attainment`` gauges) — export via
        :mod:`repro.obs.export`, analyze via ``tools/trace_report.py``.
        The default ``None`` serves **bit-identically** through a no-op
        tracer (locked by ``tests/test_obs.py``; the stress lane asserts
        the traced overhead stays small).
    """

    def __init__(
        self,
        n_racks: int,
        n_wireless: int,
        *,
        window: float = 0.0,
        policy: str = "fleet",
        warm_start: bool = True,
        min_free_racks: int = 1,
        require_full_demand: bool = False,
        preserve_order: bool = False,
        backfill: bool = False,
        seed: int = 0,
        seed_pool_size: int = 4,
        solver_kwargs: dict | None = None,
        compact_interval: int = 0,
        replan: str = "always",
        record_jobs: bool = True,
        track_epoch_latency: bool = False,
        arbitration: str = "fifo",
        arbitration_rounds: int = 2,
        arbitration_pool: int = 8,
        wireless_grants: str = "hold",
        admission: str = "fifo",
        admission_control: str = "none",
        max_overtakes: int | None = None,
        tenant_weights: dict | None = None,
        topology: str = "static",
        cluster_topology: Topology | None = None,
        outages: Sequence[LinkEvent] | None = None,
        tracer=None,
    ):
        if policy != "fleet" and policy not in ONLINE_BASELINES:
            raise ValueError(
                f"unknown policy {policy!r}; "
                f"choose 'fleet' or one of {sorted(ONLINE_BASELINES)}"
            )
        if window < 0.0:
            raise ValueError("window must be non-negative")
        if not 1 <= min_free_racks <= n_racks:
            raise ValueError("min_free_racks must be in [1, n_racks]")
        if backfill and not preserve_order:
            raise ValueError(
                "backfill extends preserve_order head-of-line admission; "
                "set preserve_order=True (without it any fitting job may "
                "overtake already)"
            )
        if compact_interval < 0:
            raise ValueError("compact_interval must be non-negative")
        if replan not in ("always", "changed"):
            raise ValueError("replan must be 'always' or 'changed'")
        if arbitration not in ("fifo", "sigma", "search"):
            raise ValueError("arbitration must be 'fifo', 'sigma' or 'search'")
        if arbitration_rounds < 0:
            raise ValueError("arbitration_rounds must be non-negative")
        if arbitration_pool < 1:
            raise ValueError("arbitration_pool must be positive")
        if wireless_grants not in ("hold", "interval"):
            raise ValueError("wireless_grants must be 'hold' or 'interval'")
        if admission not in ("fifo", "edf", "wfair"):
            raise ValueError("admission must be 'fifo', 'edf' or 'wfair'")
        if admission_control not in ("none", "defer", "reject"):
            raise ValueError(
                "admission_control must be 'none', 'defer' or 'reject'"
            )
        if max_overtakes is not None and max_overtakes < 0:
            raise ValueError("max_overtakes must be non-negative (or None)")
        if tenant_weights is not None and any(
            w <= 0 for w in tenant_weights.values()
        ):
            raise ValueError("tenant_weights must be positive")
        if topology not in ("static", "matching"):
            raise ValueError("topology must be 'static' or 'matching'")
        if topology == "matching" and cluster_topology is None:
            raise ValueError("topology='matching' needs a cluster_topology")
        if outages and cluster_topology is None:
            raise ValueError("an outage trace needs a cluster_topology")
        # The deadline-aware solo baseline is fifo_solo's placement under
        # EDF queue ordering; selecting it implies the ordering unless the
        # caller explicitly asked for another one.
        if policy == "edf_solo" and admission == "fifo":
            admission = "edf"
        self.n_racks = int(n_racks)
        self.n_wireless = int(n_wireless)
        self.window = float(window)
        self.policy = policy
        self.warm_start = bool(warm_start)
        self.min_free_racks = int(min_free_racks)
        self.require_full_demand = bool(require_full_demand)
        self.preserve_order = bool(preserve_order)
        self.backfill = bool(backfill)
        self.seed = int(seed)
        self.seed_pool_size = int(seed_pool_size)
        self.solver_kwargs = dict(DEFAULT_SOLVER_KWARGS)
        if solver_kwargs:
            self.solver_kwargs.update(solver_kwargs)
        self.compact_interval = int(compact_interval)
        self.replan = replan
        self.record_jobs = bool(record_jobs)
        self.track_epoch_latency = bool(track_epoch_latency)
        self.arbitration = arbitration
        self.arbitration_rounds = int(arbitration_rounds)
        self.arbitration_pool = int(arbitration_pool)
        self.wireless_grants = wireless_grants
        self.admission = admission
        self.admission_control = admission_control
        self.max_overtakes = None if max_overtakes is None else int(max_overtakes)
        self.tenant_weights = dict(tenant_weights) if tenant_weights else {}
        self.topology = topology
        self.cluster_topology = cluster_topology
        self.outages = sorted(
            outages or [], key=lambda e: (e.time, e.rack, e.subchannel)
        )
        self.tracer = as_tracer(tracer)
        # Overtake bookkeeping runs only when overtakes are possible and
        # observable — the default FIFO/unbounded path skips it entirely.
        self._track_overtakes = (
            self.admission != "fifo" or self.max_overtakes is not None
        )

    # -- public API ----------------------------------------------------------

    def serve(
        self, arrivals: Sequence[ArrivalEvent] | Iterable[ArrivalEvent]
    ) -> OnlineResult:
        """Run the epoch pipeline over ``arrivals`` until every job completes.

        ``arrivals`` may be a materialized sequence (sorted here) or a lazy
        time-sorted iterator (e.g. :func:`~repro.online.workload
        .stream_production_arrivals`) — the stream is consumed one epoch
        at a time.
        """
        stream = _ArrivalStream(arrivals)
        tr = self.tracer
        st = _ServeState(
            cluster=ClusterTimeline(
                self.n_racks,
                self.n_wireless,
                topology=self.cluster_topology,
                tracer=tr if tr.enabled else None,
            ),
            free_r=_FreeSet(self.n_racks),
            free_w=_FreeSet(self.n_wireless),
            queue_stats=StreamingSeries(),
            jct_stats=StreamingSeries(),
            epoch_latency=[] if self.track_epoch_latency else None,
        )

        # Wakeup comparisons are exact (no epsilon): holds are recorded at
        # exact float completion times and the free-resource queries use the
        # same ``hold <= t`` rule, so a completion popped at epoch ``t``
        # guarantees its resources are re-grantable at ``t``, while a
        # completion any amount past ``t`` stays in the heap for its own
        # epoch instead of being consumed early against still-held
        # resources (the _EPS double-booking regression).
        while not stream.exhausted or st.pending:
            t_arr = stream.peek_time() + self.window
            t_cmp = (
                st.completions[0] if (st.pending and st.completions) else np.inf
            )
            t = min(t_arr, t_cmp) if st.pending else t_arr
            if not np.isfinite(t):
                raise RuntimeError(
                    "online event loop deadlocked: jobs queued with no "
                    "outstanding completion or arrival to wake on"
                )
            k = st.counters["epochs"]
            with tr.span("epoch", epoch=k, t=float(t)) as ep_sp:
                with tr.span("collect_arrivals", epoch=k) as sp:
                    self._collect_arrivals(stream, st, t)
                    if tr.enabled:
                        sp.set(n_pending=len(st.pending))
                        tr.observe("queue_depth", len(st.pending))
                if self.admission_control != "none":
                    self._deadline_control(t, st)
                st.counters["epochs"] += 1
                with tr.span("plan_batch", epoch=k) as sp:
                    plan = self._plan_batch(t, st)
                    if tr.enabled:
                        sp.set(n_admit=len(plan.admit) if plan else 0)
                with tr.span("arbitrate_and_commit", epoch=k) as sp:
                    t0 = (
                        _time.perf_counter()
                        if st.epoch_latency is not None and not tr.enabled
                        else 0.0
                    )
                    new_completions = self._arbitrate_and_commit(t, st, plan)
                    if st.epoch_latency is not None and not tr.enabled:
                        st.epoch_latency.append(_time.perf_counter() - t0)
                    if tr.enabled:
                        sp.set(n_committed=len(new_completions))
                # When traced, the commit latency IS the span duration, so
                # the exported trace reconciles with epoch_commit_latency
                # exactly instead of within span-entry overhead.
                if tr.enabled and st.epoch_latency is not None:
                    st.epoch_latency.append(sp.duration)
                for comp in new_completions:
                    heapq.heappush(st.completions, comp)
                st.peak_active = max(st.peak_active, len(st.completions))
                if (
                    self.compact_interval
                    and st.counters["epochs"] % self.compact_interval == 0
                ):
                    st.cluster.compact(t)
            if tr.enabled:
                tr.observe("epoch_latency", ep_sp.duration)

        st.cluster.assert_feasible()
        st.records.sort(key=lambda r: r.job_id)
        horizon = st.cluster.last_completion
        util = st.cluster.utilization(horizon)
        if tr.enabled:
            # End-of-serve registry snapshot for the Prometheus
            # exposition: prune/SLO gauges, the streaming sketches by
            # reference, and every serve counter.
            tr.gauge(
                "prune_rate",
                st.counters["pruned"] / max(st.counters["candidates"], 1),
            )
            for tier, (met, tot) in sorted(st.tier_slo.items()):
                if tot:
                    tr.gauge("slo_attainment", met / tot, tier=tier)
            tr.adopt_series("queueing_delay", st.queue_stats)
            tr.adopt_series("jct", st.jct_stats)
            for tenant, series in sorted(st.tenant_queue.items()):
                tr.adopt_series("tenant_queueing_delay", series, tenant=tenant)
            for name, v in st.counters.items():
                tr.count(f"serve_{name}", float(v))
        return OnlineResult(
            jobs=st.records,
            policy=self.policy,
            warm_start=self.warm_start and self.policy == "fleet",
            n_epochs=st.counters["epochs"],
            n_batches=st.counters["batches"],
            n_solves=st.counters["solves"],
            n_candidates=st.counters["candidates"],
            n_pruned=st.counters["pruned"],
            solver_wall=st.counters["wall"],
            horizon=horizon,
            rack_utilization=util["rack"],
            wired_utilization=util["wired"],
            wireless_utilization=util["wireless"],
            n_backfilled=st.counters["backfilled"],
            n_backfill_rejected=st.counters["backfill_rejected"],
            timeline=st.cluster,
            queue_stats=st.queue_stats,
            jct_stats=st.jct_stats,
            peak_active=st.peak_active,
            peak_queue_depth=st.peak_queue,
            n_served=st.n_served,
            epoch_commit_latency=st.epoch_latency,
            arbitration=self.arbitration,
            n_order_evals=st.counters["order_evals"],
            n_epochs_reordered=st.counters["epochs_reordered"],
            arbitration_gain=st.counters["arbitration_gain"],
            admission=self.admission,
            n_deadline_jobs=st.counters["deadline_jobs"],
            n_deadline_missed=st.counters["deadline_missed"],
            n_deadline_deferrals=st.counters["deadline_deferrals"],
            n_deadline_rejected=st.counters["deadline_rejected"],
            rejected_job_ids=st.rejected_ids,
            tier_slo=st.tier_slo,
            tenant_queue_stats=st.tenant_queue,
            max_overtakes_observed=st.counters["max_overtaken"],
            n_reconfigs=st.counters["reconfigs"],
            n_link_events=st.counters["link_events"],
        )

    # -- stage 1: collect ----------------------------------------------------

    def _collect_arrivals(
        self, stream: _ArrivalStream, st: _ServeState, t: float
    ) -> None:
        """Pull arrivals due at epoch ``t`` into the queue, retire due
        completions, and advance the free sets to ``t``."""
        tr = self.tracer
        while not stream.exhausted and stream.peek_time() <= t:
            ev = stream.pop()
            st.pending.append(_PendingJob(ev))
            if tr.enabled:
                tr.job(
                    ev.job_id,
                    "arrival",
                    ev.time,
                    family=ev.family,
                    tenant=ev.tenant,
                    tier=ev.tier,
                    deadline=ev.deadline,
                )
        st.peak_queue = max(st.peak_queue, len(st.pending))
        while st.completions and st.completions[0] <= t:
            heapq.heappop(st.completions)
        st.free_r.advance(t, st.cluster.rack_hold)
        st.free_w.advance(t, st.cluster.wireless_hold)
        st.stream_exhausted = stream.exhausted
        if st.cluster.topology is not None:
            self._epoch_topology(t, st)
        if self.replan == "changed":
            sig = (tuple(st.free_r.ids), tuple(st.free_w.ids))
            tsig = st.cluster.topology_signature()
            if tsig is not None:
                # Matching / outage changes invalidate cached plans: a
                # schedule solved under the old link set may pick a now
                # unreachable subchannel.
                sig = sig + (tsig,)
            st.avail_sig = sig

    def _epoch_topology(self, t: float, st: _ServeState) -> None:
        """Advance the reconfigurable-topology state to epoch ``t``: apply
        due outage-trace events, then (under ``topology="matching"``)
        re-match the wireless links to the queue's demand.

        The matching weight is the queue's aggregate wireless transfer
        volume placed on the racks currently free at ``t`` — pending jobs
        are not placed yet, so per-rack demand is unknowable; weighting
        the free racks steers links toward where the epoch's admissions
        can actually land, and the greedy matcher's deterministic
        tie-break does the rest. Subchannels mid-transfer keep their
        links; every reconfigured idle subchannel is charged δ as a busy
        interval by the timeline. Both steps are traced as decision
        events (``link_outage`` / ``topology_matching``).
        """
        cluster = st.cluster
        tr = self.tracer
        flipped = 0
        while st.outage_pos < len(self.outages):
            ev = self.outages[st.outage_pos]
            if ev.time > t:
                break
            flipped += cluster.set_link(ev.rack, ev.subchannel, ev.up)
            st.outage_pos += 1
        if flipped:
            st.counters["link_events"] += flipped
            if tr.enabled:
                tr.event(
                    "link_outage",
                    t=float(t),
                    n_links_changed=flipped,
                    n_up=int(cluster.link_state.sum()),
                )
        if self.topology != "matching":
            return
        demand = np.zeros(self.n_racks, dtype=np.float64)
        vol = 0.0
        for p in st.pending:
            inst = p.event.inst
            if inst.n_wireless and inst.job.n_edges:
                vol += float(np.sum(inst.q_wireless))
        if vol > 0.0:
            demand[st.free_r.as_array()] = vol
        n_re = cluster.reconfigure(demand, t)
        if n_re:
            st.counters["reconfigs"] += n_re
        if tr.enabled:
            tr.event(
                "topology_matching",
                t=float(t),
                n_reconfigured=n_re,
                n_active=int(cluster.active_reach().sum()),
                demand_volume=float(vol),
            )

    def _deadline_control(self, t: float, st: _ServeState) -> None:
        """Resolve provably unmeetable deadlines at epoch ``t``.

        The proof is the rigorous resource-independent critical-path
        bound: no scheduler on any cluster can finish ``inst`` in under
        ``lower_bound(inst)`` time, so ``t + lower_bound(inst) >
        deadline`` is a certificate the deadline is lost — and since the
        event loop only moves forward, lost forever. Under
        ``admission_control="reject"`` the job is dropped from the queue
        (counted, id recorded); under ``"defer"`` it is marked hopeless
        so the commit stage stops deferring it (it serves ASAP and the
        miss is counted). The bound is computed once per job and cached.
        """
        doomed: list[_PendingJob] = []
        for p in st.pending:
            ddl = p.event.deadline
            if ddl is None or p.hopeless:
                continue
            if p.lb is None:
                p.lb = lower_bound(p.event.inst)
            if t + p.lb > ddl:
                if self.admission_control == "reject":
                    doomed.append(p)
                else:
                    p.hopeless = True
                    if self.tracer.enabled:
                        self.tracer.event(
                            "deadline_hopeless",
                            job_id=p.event.job_id,
                            t=float(t),
                            deadline=float(ddl),
                            lower_bound=float(p.lb),
                        )
        for p in doomed:
            st.pending.remove(p)
            st.counters["deadline_rejected"] += 1
            st.rejected_ids.append(p.event.job_id)
            if self.tracer.enabled:
                # The rejection proof: t + lower_bound(inst) > deadline.
                self.tracer.event(
                    "deadline_reject",
                    job_id=p.event.job_id,
                    t=float(t),
                    deadline=float(p.event.deadline),
                    lower_bound=float(p.lb),
                )

    # -- stage 2: plan -------------------------------------------------------

    def _engine_seed(self, job: _PendingJob, planning: bool) -> int:
        base = self.seed + 1009 * job.event.job_id
        return base + 9173 * job.n_solves if planning else base

    def _hol_need(self, inst) -> tuple[int, int]:
        """Racks and wireless subchannels a blocked head-of-line job needs
        free before it can be admitted (demands clamped to the cluster)."""
        need_r = self.min_free_racks
        need_w = 0
        if self.require_full_demand:
            need_r = max(need_r, min(inst.n_racks, self.n_racks))
            need_w = min(inst.n_wireless, self.n_wireless)
        return need_r, need_w

    def _admission_queue(self, st: _ServeState) -> list[_PendingJob]:
        """The queue in admission order.

        ``admission="fifo"`` returns the pending list itself — no copy,
        no sort, no float work, so the default path is bit-identical to
        the pre-SLO loop. ``"edf"`` stable-sorts by
        ``(deadline, arrival)`` with deadline-less jobs last; ``"wfair"``
        by weighted attained tenant service (ties by arrival). When a
        ``max_overtakes`` bound is set, saturated jobs (overtaken the
        full allowance) are hoisted to the head in arrival order — they
        must be next, and the selection loop below refuses any admission
        that would overtake them again.
        """
        if self.admission == "fifo":
            return st.pending
        if self.admission == "edf":
            def key(p: _PendingJob):
                d = p.event.deadline
                return (d if d is not None else np.inf, p.event.job_id)
        else:  # wfair
            def key(p: _PendingJob):
                ev = p.event
                w = self.tenant_weights.get(
                    ev.tenant, self.tenant_weights.get(ev.tier, 1.0)
                )
                return (
                    st.tenant_service.get(ev.tenant, 0.0) / w,
                    ev.job_id,
                )
        bound = self.max_overtakes
        if bound is not None:
            head = [p for p in st.pending if p.n_overtaken >= bound]
            if head:  # pending is arrival-ordered, so head is too
                tail = [p for p in st.pending if p.n_overtaken < bound]
                return head + sorted(tail, key=key)
        return sorted(st.pending, key=key)

    def _select_admissions(self, t: float, st: _ServeState) -> _EpochPlan:
        """Admission selection: draw disjoint residual views from shrinking
        pools; order-preserving modes flag overtake candidates."""
        cluster = st.cluster
        hol_need = None  # head-of-line protection bound for backfills
        queue = self._admission_queue(st)
        if self.tracer.enabled and queue is not st.pending:
            ordered = [p.event.job_id for p in queue]
            if ordered != [p.event.job_id for p in st.pending]:
                self.tracer.event(
                    "admission_reorder",
                    policy=self.admission,
                    order=ordered,
                )
        if self.policy in ("fifo_solo", "edf_solo"):
            # Solo rule: head-of-queue job only, and only on a fully idle
            # cluster (every rack free implies every channel free too —
            # channel holds never outlast the rack hold of the consumer).
            if len(st.free_r) < self.n_racks:
                return _EpochPlan([], [], [], None, None)
            admit = queue[:1]
            views = [cluster.residual_view(admit[0].event.inst, t)]
            return _EpochPlan(admit, views, [False], None, None)
        # Racks AND wireless subchannels granted within one epoch are
        # mutually exclusive: each admitted job consumes its grant
        # from a shrinking pool, so later jobs of the epoch see only
        # what is left. The shared wired channel is never granted —
        # cross-job wired contention is resolved at commit time by the
        # timeline's arbitration pass.
        pool = st.free_r.as_array()
        pool_w = st.free_w.as_array()
        if self.wireless_grants == "interval" and self.n_wireless:
            # Interval-aware grants: subchannels still held by running
            # jobs join the back of the epoch pool (free ones are granted
            # first). A job granted a held subchannel gap-inserts its
            # transfers around the holder's committed windows — the same
            # arbitration pass that already shares the wired channel —
            # so exclusivity of the *grant* is relaxed while per-interval
            # disjointness stays audited. Racks stay exclusive: the
            # simulator re-derives only channel times, never rack times.
            held = np.setdiff1d(
                np.arange(self.n_wireless, dtype=np.int64), pool_w
            )
            if held.size:
                pool_w = np.concatenate([pool_w, held])
        admit, views, is_backfill = [], [], []
        blocked = False  # head-of-line blocked (order-preserving modes)
        # Starvation-bound bookkeeping (only under _track_overtakes):
        # ``prospective`` counts, per still-queued job, the overtakes
        # *this epoch's* selections would add if every admission commits;
        # ``firm`` holds ids of admissions that are certain to commit
        # (not backfill candidates, not defer-eligible), whose co-epoch
        # admission is simultaneous — not an overtake. The check below is
        # conservative: a commit-stage rejection can only return counted
        # prospective overtakes, never add uncounted ones, so the
        # commit-time assertion holds by construction.
        bound = self.max_overtakes
        prospective: dict[int, int] = {}
        firm: set[int] = set()
        for p in queue:
            inst = p.event.inst
            ok = pool.size >= self.min_free_racks
            if ok and self.require_full_demand:
                # Demands are clamped to the cluster shape so an
                # oversized job can still (eventually) be admitted.
                ok = (
                    pool.size >= min(inst.n_racks, self.n_racks)
                    and pool_w.size >= min(inst.n_wireless, self.n_wireless)
                )
            overtakes = self.preserve_order and blocked
            if overtakes and not self.backfill:
                ok = False  # head-of-line blocking: no overtaking
            if ok and bound is not None:
                # Withhold any admission that would push an earlier-
                # arrived, still-queued job past its overtake allowance.
                jid = p.event.job_id
                for q in st.pending:
                    if (
                        q is not p
                        and q.event.job_id < jid
                        and id(q) not in firm
                        and q.n_overtaken + prospective.get(id(q), 0)
                        >= bound
                    ):
                        ok = False
                        break
            if ok:
                view = cluster.residual_view(
                    inst, t, rack_pool=pool, wireless_pool=pool_w
                )
                pool = pool[view.inst.n_racks :]
                pool_w = pool_w[view.inst.n_wireless :]
                admit.append(p)
                views.append(view)
                # An overtaker is only a *candidate*: its commit below
                # must pass the head-of-line no-delay proof
                # (``_backfill_safe``) or it stays queued (the racks
                # it consumed from the pool stay unused this epoch —
                # conservative and deterministic).
                is_backfill.append(overtakes)
                if bound is not None:
                    jid = p.event.job_id
                    for q in st.pending:
                        if (
                            q is not p
                            and q.event.job_id < jid
                            and id(q) not in firm
                        ):
                            prospective[id(q)] = (
                                prospective.get(id(q), 0) + 1
                            )
                    if not overtakes and not (
                        self.admission_control == "defer"
                        and p.event.deadline is not None
                        and not p.hopeless
                    ):
                        firm.add(id(p))
            elif self.preserve_order and not blocked:
                blocked = True
                hol_need = self._hol_need(inst)
        return _EpochPlan(admit, views, is_backfill, hol_need, None)

    def _plan_batch(self, t: float, st: _ServeState) -> _EpochPlan | None:
        """Admission selection plus the epoch's single mega-batch launch."""
        if not st.pending:
            return None
        plan = self._select_admissions(t, st)
        if self.policy != "fleet":
            return plan
        # Queued ("plan") jobs are re-solved every epoch in BOTH warm
        # and cold modes: cold-start re-optimization means searching
        # from scratch each epoch, and running its (discarded)
        # planning solves keeps warm-vs-cold an equal-total-budget
        # comparison — the benchmarks' warm_solves == cold_solves
        # records rest on this. Cold planning never changes a
        # committed schedule (admission solves ignore history), only
        # solver_wall/n_solves. (``replan="changed"`` opts out: it
        # skips queued jobs whose free-capacity fingerprint is
        # unchanged since their last solve.)
        admitted = set(map(id, plan.admit))
        queued = [p for p in st.pending if id(p) not in admitted]
        if self.replan == "changed":
            queued = [
                p
                for p in queued
                if p.n_solves == 0 or p.view_sig != st.avail_sig
            ]
            for p in queued:
                p.view_sig = st.avail_sig
        batch = plan.admit + queued
        if not batch:
            return plan
        instances = [v.inst for v in plan.views] + [
            p.event.inst for p in queued
        ]
        seeds = [self._engine_seed(p, planning=False) for p in plan.admit] + [
            self._engine_seed(p, planning=True) for p in queued
        ]
        seed_pools = None
        if self.warm_start:
            seed_pools = [
                np.stack(p.incumbents, axis=0) if p.incumbents else None
                for p in batch
            ]
        t0 = _time.perf_counter()
        fleet = schedule_fleet(
            instances,
            seed=seeds,
            seed_pools=seed_pools,
            op_tables=[p.tables() for p in batch],
            tracer=self.tracer if self.tracer.enabled else None,
            **self.solver_kwargs,
        )
        st.counters["wall"] += _time.perf_counter() - t0
        st.counters["batches"] += 1
        st.counters["solves"] += len(batch)
        st.counters["candidates"] += fleet.n_candidates
        st.counters["pruned"] += fleet.n_pruned
        for p, inst, res in zip(batch, instances, fleet.results):
            p.n_solves += 1
            p.remember(res, _shape_key(inst), self.seed_pool_size)
        plan.results = fleet.results[: len(plan.admit)]
        return plan

    # -- stage 3: arbitrate & commit -----------------------------------------

    def _backfill_safe(
        self,
        cluster: ClusterTimeline,
        view: ResidualView,
        completion: float,
        t: float,
        hol_need: tuple[int, int],
    ) -> bool:
        """Prove (or refuse) that committing a backfill candidate cannot
        delay the blocked head-of-line job's admission epoch.

        The head job's *reservation* is the earliest time its needed racks
        and subchannels can all be free given the holds committed so far —
        including this epoch's earlier commits, which is why the proof
        runs at commit time, on current holds, per candidate. The commit
        is safe when either

        * the candidate's post-arbitration ``completion`` lands at or
          before the reservation (every hold a job takes — racks and
          channels alike — is released by its completion, so everything
          the candidate touches is free again in time), or
        * shadow slack: even with the candidate's grant removed for good,
          the reservation time still has enough free racks/subchannels
          for the head job (its demand is met without the candidate's
          resources, so the candidate may run arbitrarily long).

        Either branch preserves the invariant that at the current
        reservation the head job's demand is satisfiable, so the head job
        is admitted at the first wakeup past it — exactly as it would be
        with no overtaking (backfill completions only *add* wakeups).

        The proof itself is the pure hold-vector function
        :func:`repro.online.cluster.reservation_backfill_safe`, shared
        with the order search's trial replay so a replayed epoch makes
        bit-identical backfill decisions."""
        return reservation_backfill_safe(
            cluster.rack_hold,
            cluster.wireless_hold,
            view.inst.n_racks,
            view.inst.n_wireless,
            completion,
            t,
            hol_need,
        )

    def _commit_job(
        self,
        t: float,
        st: _ServeState,
        p: _PendingJob,
        view: ResidualView,
        placed: Schedule,
        solver_mk: float,
        backfilled: bool,
        solver_sched: Schedule | None = None,
    ) -> float:
        """Land one arbitrated schedule: timeline commit, free-set grants,
        streaming stats, and (optionally) the per-job record.

        ``solver_sched`` (fleet policy) is the pre-arbitration schedule;
        traced serves diff it against ``placed`` to attribute the job's
        cross-job channel queueing to wired vs wireless resources."""
        holds: list[tuple[str, int, float]] = []
        comp = st.cluster.commit(
            view, placed, t, job_id=p.event.job_id, holds_out=holds
        )
        for kind, phys, hold in holds:
            (st.free_r if kind == "rack" else st.free_w).grant(phys, hold)
        st.counters["backfilled"] += backfilled
        st.n_served += 1
        st.queue_stats.push(t - p.event.time)
        st.jct_stats.push(comp - p.event.time)
        ev = p.event
        if ev.deadline is not None:
            st.counters["deadline_jobs"] += 1
            met = comp <= ev.deadline
            if not met:
                st.counters["deadline_missed"] += 1
            if ev.tier is not None:
                m, tot = st.tier_slo.get(ev.tier, (0, 0))
                st.tier_slo[ev.tier] = (m + int(met), tot + 1)
        if ev.tenant is not None:
            series = st.tenant_queue.get(ev.tenant)
            if series is None:
                series = st.tenant_queue[ev.tenant] = StreamingSeries()
            series.push(t - ev.time)
            st.tenant_service[ev.tenant] = (
                st.tenant_service.get(ev.tenant, 0.0) + float(placed.makespan)
            )
        if self.record_jobs:
            st.records.append(
                self._record(p, view, t, comp, placed, solver_mk, backfilled)
            )
        tr = self.tracer
        if tr.enabled:
            qw, qwl = (
                channel_delay_attribution(view, solver_sched, placed)
                if solver_sched is not None
                else (0.0, 0.0)
            )
            tr.job(ev.job_id, "admit", float(t), backfilled=bool(backfilled))
            tr.job(
                ev.job_id,
                "complete",
                float(comp),
                makespan=float(placed.makespan),
                solver_makespan=float(solver_mk),
                queue_wired=qw,
                queue_wireless=qwl,
                n_racks=view.inst.n_racks,
                n_wireless=view.inst.n_wireless,
                backfilled=bool(backfilled),
            )
        return comp

    def _should_defer(
        self,
        p: _PendingJob,
        t: float,
        comp: float,
        st: _ServeState,
        new_completions: list[float],
    ) -> bool:
        """Deadline-defer decision for one arbitrated commit candidate.

        ``comp`` is the candidate's post-arbitration completion — the
        output of the exact same trial arbitration
        :func:`repro.online.cluster.replay_commit_order` runs per
        position, so ``replay_commit_order(..., deadlines=...)`` over the
        epoch's committed prefix predicts every defer decision
        bit-for-bit (``tests/test_admission.py`` locks the parity).
        Deferring requires a future wakeup (an outstanding completion,
        one committed earlier this epoch, or more arrivals) so the event
        loop can never deadlock on an all-deferred queue, and stops once
        the deadline has passed or is provably lost (``hopeless``): the
        job then serves ASAP and the miss is counted.
        """
        if self.admission_control != "defer" or p.hopeless:
            return False
        ddl = p.event.deadline
        if ddl is None or comp <= ddl or t > ddl:
            return False
        return (
            bool(st.completions)
            or bool(new_completions)
            or not st.stream_exhausted
        )

    def _count_overtakes(
        self, st: _ServeState, committed: list[_PendingJob]
    ) -> None:
        """Charge this epoch's commits against the jobs still queued.

        Every committed job with a larger stream id than a still-pending
        job overtook it (job ids are arrival order — ties broken the
        same way the stream is sorted). The ``max_overtakes`` bound is
        asserted here, at the moment of counting: the selection-stage
        barrier makes a violation unreachable, so tripping this raise
        means the starvation bound was actually broken, not merely
        approached.
        """
        for q in st.pending:
            inc = sum(
                1 for c in committed if c.event.job_id > q.event.job_id
            )
            if not inc:
                continue
            q.n_overtaken += inc
            if q.n_overtaken > st.counters["max_overtaken"]:
                st.counters["max_overtaken"] = q.n_overtaken
            if (
                self.max_overtakes is not None
                and q.n_overtaken > self.max_overtakes
            ):
                raise RuntimeError(
                    f"starvation bound violated: job {q.event.job_id} "
                    f"overtaken {q.n_overtaken} times "
                    f"(max_overtakes={self.max_overtakes})"
                )

    def _arbitrate_and_commit(
        self, t: float, st: _ServeState, plan: _EpochPlan | None
    ) -> list[float]:
        """Arbitrate each admitted schedule onto the shared channels and
        commit the survivors; returns their completion times."""
        if plan is None or not plan.admit:
            return []
        cluster = st.cluster
        new_completions: list[float] = []
        committed: list[_PendingJob] = []
        if self.policy == "fleet":
            serve_scheds: list[Schedule] = []
            serve_mks: list[float] = []
            for p, view, res in zip(plan.admit, plan.views, plan.results):
                sched, mk = res.schedule, res.makespan
                if (
                    self.warm_start
                    and p.best_makespan < mk
                    and p.best_shape == _shape_key(view.inst)
                ):
                    # Keep-incumbent re-optimization: the fresh solve did
                    # not beat the chain's best simulated schedule for
                    # this exact resource shape, so serve the incumbent.
                    sched, mk = p.best_sched, p.best_makespan
                serve_scheds.append(sched)
                serve_mks.append(mk)
            order = self._commit_order(t, st, plan, serve_scheds)
            for i in order:
                p, view, bf = plan.admit[i], plan.views[i], plan.is_backfill[i]
                # Cross-job arbitration: sequence the served schedule onto
                # the shared physical channels in the chosen commit order
                # (queue order under the default ``arbitration="fifo"``;
                # identity when the channels are clear).
                placed = cluster.arbitrate(view, serve_scheds[i], t)
                if bf and not self._backfill_safe(
                    cluster, view, t + placed.makespan, t, plan.hol_need
                ):
                    # Arbitration cannot prove the overtake harmless: the
                    # candidate would hold a resource the head-of-line job
                    # needs past its reservation. It stays queued; its
                    # solve already fed the warm-start incumbents above.
                    st.counters["backfill_rejected"] += 1
                    if self.tracer.enabled:
                        self.tracer.event(
                            "backfill_reject",
                            job_id=p.event.job_id,
                            completion=float(t + placed.makespan),
                        )
                    continue
                if self._should_defer(
                    p, t, t + float(placed.makespan), st, new_completions
                ):
                    # The trial completion overruns the deadline: a
                    # commit now is a proven miss, so the job stays
                    # queued for a less contended epoch.
                    st.counters["deadline_deferrals"] += 1
                    if self.tracer.enabled:
                        self.tracer.event(
                            "deadline_defer",
                            job_id=p.event.job_id,
                            completion=float(t + placed.makespan),
                            deadline=float(p.event.deadline),
                        )
                    continue
                if bf and self.tracer.enabled:
                    self.tracer.event(
                        "backfill_commit",
                        job_id=p.event.job_id,
                        completion=float(t + placed.makespan),
                    )
                comp = self._commit_job(
                    t, st, p, view, placed, serve_mks[i], bf,
                    solver_sched=serve_scheds[i],
                )
                new_completions.append(comp)
                committed.append(p)
        else:
            # Online baselines commit through the same feasible path: the
            # per-job heuristic is handed the busy intervals already
            # committed on its physical channels and gap-inserts its own
            # transfers around them (``channel_busy`` seeds the same
            # timeline machinery the replay uses), so its schedule is
            # already cross-job arbitrated — committing it directly keeps
            # the heuristic's placement and skips a redundant replay.
            # Solving stays in this stage, not ``_plan_batch``, because
            # each placement depends on the busy intervals of this
            # epoch's *earlier* commits. The end-of-serve audit verifies
            # the invariant like everywhere else.
            fn = ONLINE_BASELINES[self.policy]
            order = self._commit_order(t, st, plan, None)
            for i in order:
                p, view, bf = plan.admit[i], plan.views[i], plan.is_backfill[i]
                t0 = _time.perf_counter()
                placed = fn(
                    view.inst,
                    use_wireless=view.inst.n_wireless > 0,
                    channel_busy=cluster.channel_busy(view, t),
                )
                st.counters["wall"] += _time.perf_counter() - t0
                st.counters["solves"] += 1
                p.n_solves += 1
                if bf and not self._backfill_safe(
                    cluster, view, t + placed.makespan, t, plan.hol_need
                ):
                    st.counters["backfill_rejected"] += 1
                    if self.tracer.enabled:
                        self.tracer.event(
                            "backfill_reject",
                            job_id=p.event.job_id,
                            completion=float(t + placed.makespan),
                        )
                    continue
                if self._should_defer(
                    p, t, t + float(placed.makespan), st, new_completions
                ):
                    st.counters["deadline_deferrals"] += 1
                    if self.tracer.enabled:
                        self.tracer.event(
                            "deadline_defer",
                            job_id=p.event.job_id,
                            completion=float(t + placed.makespan),
                            deadline=float(p.event.deadline),
                        )
                    continue
                if bf and self.tracer.enabled:
                    self.tracer.event(
                        "backfill_commit",
                        job_id=p.event.job_id,
                        completion=float(t + placed.makespan),
                    )
                comp = self._commit_job(
                    t, st, p, view, placed, placed.makespan, bf
                )
                new_completions.append(comp)
                committed.append(p)

        for p in committed:
            st.pending.remove(p)
        if self._track_overtakes and committed and st.pending:
            self._count_overtakes(st, committed)
        return new_completions

    def _commit_order(
        self,
        t: float,
        st: _ServeState,
        plan: _EpochPlan,
        scheds: list[Schedule] | None,
    ) -> Sequence[int]:
        """Choose the epoch's cross-job commit order (batch positions,
        first-to-commit first).

        ``arbitration="fifo"`` — and any single-job batch — returns the
        identity immediately: no replay, no RNG, no float work, so the
        default service is bit-identical to the pre-coflow commit loop.
        ``"sigma"`` commits the bottleneck-first coflow order
        unconditionally (replaying FIFO and sigma once each only to feed
        the ``arbitration_gain`` counter). ``"search"`` minimizes the
        replayed objective — ``(backfills rejected, total batch JCT)``,
        lexicographic — over permutations: exhaustively for small
        batches, portfolio neighborhood search seeded with sigma
        otherwise; FIFO is always evaluated first, so the committed
        order's replayed objective is never worse than FIFO's.

        ``scheds`` carries the fleet policy's already-served schedules
        (exact per-resource coflow demands); baselines pass ``None`` and
        are replayed through their lazy per-commit solver with a
        wired-volume proxy coflow for the sigma seed.
        """
        n = len(plan.admit)
        if self.arbitration == "fifo" or n <= 1:
            return range(n)
        solver = None
        if scheds is None:
            fn = ONLINE_BASELINES[self.policy]

            def solver(view, busy):
                return fn(
                    view.inst,
                    use_wireless=view.inst.n_wireless > 0,
                    channel_busy=busy,
                )

        arrivals = [p.event.time for p in plan.admit]

        def evaluate(order):
            return replay_commit_order(
                st.cluster,
                t,
                plan.views,
                order,
                scheds=scheds,
                solver=solver,
                arrivals=arrivals,
                is_backfill=plan.is_backfill,
                hol_need=plan.hol_need,
            ).objective

        if scheds is not None:
            coflows = [
                coflow_from_schedule(v, s, index=i, job_id=p.event.job_id)
                for i, (p, v, s) in enumerate(
                    zip(plan.admit, plan.views, scheds)
                )
            ]
        else:
            coflows = [
                coflow_from_instance(p.event.inst, index=i, job_id=p.event.job_id)
                for i, p in enumerate(plan.admit)
            ]
        fifo = tuple(range(n))
        sigma = tuple(sigma_order(coflows))
        if self.arbitration == "sigma":
            fifo_obj = evaluate(fifo)
            chosen, chosen_obj = sigma, fifo_obj
            st.counters["order_evals"] += 1
            if sigma != fifo:
                chosen_obj = evaluate(sigma)
                st.counters["order_evals"] += 1
        else:
            rng = np.random.default_rng(
                self.seed + 6151 * st.counters["epochs"]
            )
            res = search_commit_order(
                evaluate,
                n,
                rng=rng,
                seeds=(sigma,),
                rounds=self.arbitration_rounds,
                pool_size=self.arbitration_pool,
            )
            chosen, chosen_obj, fifo_obj = (
                res.order, res.objective, res.fifo_objective
            )
            st.counters["order_evals"] += res.n_evals
        if chosen != fifo:
            st.counters["epochs_reordered"] += 1
        # Replayed total-JCT delta vs FIFO for this epoch (positive =
        # improvement; sigma commits its order even when negative).
        st.counters["arbitration_gain"] += fifo_obj[1] - chosen_obj[1]
        if self.tracer.enabled:
            self.tracer.event(
                "arbitration_order",
                policy=self.arbitration,
                order=[plan.admit[i].event.job_id for i in chosen],
                gain=float(fifo_obj[1] - chosen_obj[1]),
                reordered=chosen != fifo,
            )
        return chosen

    @staticmethod
    def _record(
        p: _PendingJob,
        view: ResidualView,
        t: float,
        comp: float,
        placed: Schedule,
        solver_mk: float,
        backfilled: bool,
    ) -> JobMetrics:
        return JobMetrics(
            job_id=p.event.job_id,
            family=p.event.family,
            arrival=p.event.time,
            admitted=t,
            completion=comp,
            makespan=placed.makespan,
            n_racks_granted=view.inst.n_racks,
            n_wireless_granted=view.inst.n_wireless,
            n_solves=p.n_solves,
            solver_makespan=float(solver_mk),
            backfilled=bool(backfilled),
            assignment=view.rack_map[np.asarray(placed.rack, dtype=np.int64)],
            deadline=p.event.deadline,
            tenant=p.event.tenant,
            tier=p.event.tier,
            n_overtaken=p.n_overtaken,
        )
