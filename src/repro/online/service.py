"""Arrival-driven scheduling service: admission event loop over the fleet
engine with warm-started re-optimization.

The event loop turns the offline mega-batch engine
(:func:`repro.core.vectorized.schedule_fleet`) into a serving system:

  1. **Windowed admission.** Arrivals are batched into admission epochs —
     the first unserved arrival opens a window of length ``window``; every
     job arriving inside it joins the epoch's batch. All jobs of one epoch
     are solved in ONE ``schedule_fleet`` mega-batch launch, so the
     lockstep driver and the fused §IV-A stage-1 pruner are shared across
     the batch and compiled programs are reused across epochs (fleets in
     the same size bucket retrace nothing).
  2. **Residual capacity.** Each job is solved against the cluster's
     residual view at the epoch (:class:`repro.online.cluster
     .ClusterTimeline`): the racks and wireless subchannels not held by
     previously committed jobs. Committed schedules hold their resources
     until their last use, and completions wake the loop to admit queued
     work.
  3. **Warm-started re-optimization.** A job that cannot be admitted
     (no free rack, or fewer than ``min_free_racks``) stays queued, but is
     still *planned* in the epoch's mega-batch against its full demanded
     shape. With ``warm_start=True`` each planning solve (and the eventual
     admission solve) seeds the engine's sweep with the job's incumbent
     assignments via the ``seed_pools`` hook — budget-neutral (seeds
     displace an equal number of random samples), so warm vs cold is an
     equal-candidate-budget comparison, and since seeds are themselves
     evaluated, a warm re-solve can never return a worse assignment than
     its own incumbent's greedy score.

Determinism: with a fixed ``seed`` and a fixed arrival stream the service
is bit-reproducible. Engine seeds follow a common-random-numbers
discipline (the standard variance-reduction tool for comparing policies
on one trace): a job's *admission* solve always uses
``seed + 1009 * job_id``, while *planning* re-solves of a queued job add
``9173 * n_prior_solves`` so each re-optimization explores fresh samples.
Consequence: a cold-start arm's committed result for job ``j`` is the
deterministic unseeded solve ``R_j`` (its admission solve ignores queue
history), and a warm arm's chain *starts* at exactly ``R_j`` (the first
solve has no incumbents yet and shares its seed) — so keep-incumbent
re-optimization makes the warm arm's committed makespan provably <= the
cold arm's for every job whose admitted shape matches its planning shape
(e.g. under ``require_full_demand``).

Degenerate reduction (locked by ``tests/test_online.py``): with every job
arriving at t=0, ``window=0`` and an empty cluster, the single epoch's
batch is exactly a direct ``schedule_fleet`` call — per-job assignments
and JCTs are bit-for-bit identical.
"""

from __future__ import annotations

import dataclasses
import heapq
import time as _time
from typing import Sequence

import numpy as np

from repro.core.baselines import ONLINE_BASELINES
from repro.core.schedule import Schedule
from repro.core.vectorized import schedule_fleet
from repro.online.cluster import ClusterTimeline, ResidualView
from repro.online.metrics import JobMetrics, OnlineResult
from repro.online.workload import ArrivalEvent

__all__ = ["OnlineScheduler", "DEFAULT_SOLVER_KWARGS"]

_EPS = 1e-9

# Engine budget per epoch solve. Deliberately lighter than the offline
# defaults: a serving epoch re-optimizes often, so per-solve budget trades
# against responsiveness. Benchmarks override freely.
DEFAULT_SOLVER_KWARGS = dict(
    max_enumerate=2_000,
    n_samples=512,
    batch_size=512,
    refine_rounds=2,
    refine_pool=256,
)


@dataclasses.dataclass
class _PendingJob:
    """Queue entry: one arrived, not-yet-admitted job."""

    event: ArrivalEvent
    n_solves: int = 0
    # Distinct incumbent assignments from prior solves, best-first
    # (labels in the shape of the solve that produced them; the seed-pool
    # hook folds them into the residual shape with a modulo).
    incumbents: list[np.ndarray] = dataclasses.field(default_factory=list)
    # Best *simulated* schedule over the job's solve chain, with the
    # resource shape it was solved for: a warm admission commits this
    # incumbent schedule when the fresh re-solve fails to beat it (and
    # the admitted shape matches), making the served makespan monotone
    # over re-optimizations.
    best_sched: Schedule | None = None
    best_makespan: float = np.inf
    best_shape: tuple[int, int] | None = None

    def remember(self, res, shape: tuple[int, int], cap: int) -> None:
        assignment = np.asarray(res.best_assignment, dtype=np.int64)
        key = assignment.tobytes()
        self.incumbents = [a for a in self.incumbents if a.tobytes() != key]
        self.incumbents.insert(0, assignment.copy())
        del self.incumbents[cap:]
        # A shape change invalidates the stored schedule (it was feasible
        # only for the old resource view); same-shape solves keep the min.
        if shape != self.best_shape or res.makespan < self.best_makespan:
            self.best_sched = res.schedule
            self.best_makespan = float(res.makespan)
            self.best_shape = shape


class OnlineScheduler:
    """Serve an arrival stream on one cluster.

    Args:
      n_racks: physical racks in the cluster.
      n_wireless: physical wireless subchannels (0 = wired-only cluster,
        i.e. bandwidth augmentation off).
      window: admission window length — arrivals within ``window`` of the
        epoch-opening arrival are batched into one mega-batch solve.
        ``0.0`` gives every arrival instant its own epoch.
      policy: ``"fleet"`` (the mega-batch search engine, default) or an
        online baseline name from
        :data:`repro.core.baselines.ONLINE_BASELINES` (``"fifo_solo"``
        serves one job at a time on the idle cluster; ``"greedy_list"``
        admits on residual capacity but places jobs with the G-List
        heuristic instead of searching).
      warm_start: seed each queued job's re-solve (and its admission
        solve) with its incumbent assignments. Fleet policy only.
      min_free_racks: admit only when at least this many racks are free;
        queued jobs below the threshold are planned, not placed.
      require_full_demand: admit a job only when its full demanded shape
        (``inst.n_racks`` racks and ``inst.n_wireless`` subchannels) is
        free, instead of running degraded on a smaller residual. Queued
        jobs wait (and keep re-planning) until capacity frees up; because
        the planning shape then equals the admission shape, warm-start
        incumbents transfer exactly.
      preserve_order: admit strictly in arrival order — the first queued
        job that does not fit blocks everything behind it (head-of-line
        FIFO, no overtaking). Keeps service trajectories stable under
        small makespan perturbations, at the cost of some utilization.
      seed: master seed for the per-solve engine seeds (see module
        docstring for the exact derivation).
      seed_pool_size: incumbents remembered per queued job.
      solver_kwargs: overrides merged over :data:`DEFAULT_SOLVER_KWARGS`
        and passed to :func:`repro.core.vectorized.schedule_fleet`.
    """

    def __init__(
        self,
        n_racks: int,
        n_wireless: int,
        *,
        window: float = 0.0,
        policy: str = "fleet",
        warm_start: bool = True,
        min_free_racks: int = 1,
        require_full_demand: bool = False,
        preserve_order: bool = False,
        seed: int = 0,
        seed_pool_size: int = 4,
        solver_kwargs: dict | None = None,
    ):
        if policy != "fleet" and policy not in ONLINE_BASELINES:
            raise ValueError(
                f"unknown policy {policy!r}; "
                f"choose 'fleet' or one of {sorted(ONLINE_BASELINES)}"
            )
        if window < 0.0:
            raise ValueError("window must be non-negative")
        if not 1 <= min_free_racks <= n_racks:
            raise ValueError("min_free_racks must be in [1, n_racks]")
        self.n_racks = int(n_racks)
        self.n_wireless = int(n_wireless)
        self.window = float(window)
        self.policy = policy
        self.warm_start = bool(warm_start)
        self.min_free_racks = int(min_free_racks)
        self.require_full_demand = bool(require_full_demand)
        self.preserve_order = bool(preserve_order)
        self.seed = int(seed)
        self.seed_pool_size = int(seed_pool_size)
        self.solver_kwargs = dict(DEFAULT_SOLVER_KWARGS)
        if solver_kwargs:
            self.solver_kwargs.update(solver_kwargs)

    # -- public API ----------------------------------------------------------

    def serve(self, arrivals: Sequence[ArrivalEvent]) -> OnlineResult:
        """Run the event loop over ``arrivals`` until every job completes."""
        arrivals = sorted(arrivals, key=lambda e: (e.time, e.job_id))
        cluster = ClusterTimeline(self.n_racks, self.n_wireless)
        pending: list[_PendingJob] = []
        completions: list[float] = []  # heap of outstanding completion times
        records: list[JobMetrics] = []
        counters = {
            "epochs": 0, "batches": 0, "solves": 0,
            "candidates": 0, "pruned": 0, "wall": 0.0,
        }

        i = 0
        while i < len(arrivals) or pending:
            t_arr = arrivals[i].time + self.window if i < len(arrivals) else np.inf
            t_cmp = completions[0] if (pending and completions) else np.inf
            t = min(t_arr, t_cmp) if pending else t_arr
            if not np.isfinite(t):
                raise RuntimeError(
                    "online event loop deadlocked: jobs queued with no "
                    "outstanding completion or arrival to wake on"
                )
            while i < len(arrivals) and arrivals[i].time <= t + _EPS:
                pending.append(_PendingJob(arrivals[i]))
                i += 1
            while completions and completions[0] <= t + _EPS:
                heapq.heappop(completions)
            counters["epochs"] += 1
            admitted = self._process_epoch(
                t, pending, cluster, records, counters
            )
            for comp in admitted:
                heapq.heappush(completions, comp)

        records.sort(key=lambda r: r.job_id)
        horizon = cluster.last_completion
        util = cluster.utilization(horizon)
        return OnlineResult(
            jobs=records,
            policy=self.policy,
            warm_start=self.warm_start and self.policy == "fleet",
            n_epochs=counters["epochs"],
            n_batches=counters["batches"],
            n_solves=counters["solves"],
            n_candidates=counters["candidates"],
            n_pruned=counters["pruned"],
            solver_wall=counters["wall"],
            horizon=horizon,
            rack_utilization=util["rack"],
            wired_utilization=util["wired"],
            wireless_utilization=util["wireless"],
        )

    # -- epoch processing ----------------------------------------------------

    def _engine_seed(self, job: _PendingJob, planning: bool) -> int:
        base = self.seed + 1009 * job.event.job_id
        return base + 9173 * job.n_solves if planning else base

    def _admissible(self, cluster: ClusterTimeline, t: float) -> bool:
        return cluster.free_racks(t).size >= self.min_free_racks

    def _process_epoch(
        self,
        t: float,
        pending: list[_PendingJob],
        cluster: ClusterTimeline,
        records: list[JobMetrics],
        counters: dict,
    ) -> list[float]:
        """Admit / plan the queue at epoch ``t``; returns new completions."""
        if not pending:
            return []
        if self.policy == "fifo_solo":
            # Solo rule: head-of-line job only, and only on a fully idle
            # cluster (every rack free implies every channel free too —
            # channel holds never outlast the rack hold of the consumer).
            if cluster.free_racks(t).size < self.n_racks:
                return []
            admit, plan = pending[:1], []
            views = [cluster.residual_view(admit[0].event.inst, t)]
        else:
            # Racks granted within one epoch are mutually exclusive:
            # each admitted job consumes its grant from a shrinking pool,
            # so later jobs of the epoch see only what is left. Wireless
            # subchannels are shared within the epoch (cross-job channel
            # contention is the fleet model's approximation) and gated
            # only by cross-epoch holds.
            pool = cluster.free_racks(t)
            n_free_w = cluster.free_wireless(t).size
            admit, plan, views = [], [], []
            for p in pending:
                ok = pool.size >= self.min_free_racks
                if ok and self.require_full_demand:
                    # Demands are clamped to the cluster shape so an
                    # oversized job can still (eventually) be admitted.
                    ok = (
                        pool.size >= min(p.event.inst.n_racks, self.n_racks)
                        and n_free_w
                        >= min(p.event.inst.n_wireless, self.n_wireless)
                    )
                if self.preserve_order and plan:
                    ok = False  # head-of-line blocking: no overtaking
                if ok:
                    view = cluster.residual_view(p.event.inst, t, rack_pool=pool)
                    pool = pool[view.inst.n_racks :]
                    admit.append(p)
                    views.append(view)
                else:
                    plan.append(p)
        assert all(v is not None for v in views)

        new_completions: list[float] = []
        if self.policy == "fleet":
            # Queued ("plan") jobs are re-solved every epoch in BOTH warm
            # and cold modes: cold-start re-optimization means searching
            # from scratch each epoch, and running its (discarded)
            # planning solves keeps warm-vs-cold an equal-total-budget
            # comparison — the benchmarks' warm_solves == cold_solves
            # records rest on this. Cold planning never changes a
            # committed schedule (admission solves ignore history), only
            # solver_wall/n_solves.
            batch = admit + plan
            if not batch:
                return []
            instances = [v.inst for v in views] + [p.event.inst for p in plan]
            seeds = [self._engine_seed(p, planning=False) for p in admit] + [
                self._engine_seed(p, planning=True) for p in plan
            ]
            seed_pools = None
            if self.warm_start:
                seed_pools = [
                    np.stack(p.incumbents, axis=0) if p.incumbents else None
                    for p in batch
                ]
            t0 = _time.perf_counter()
            fleet = schedule_fleet(
                instances, seed=seeds, seed_pools=seed_pools, **self.solver_kwargs
            )
            counters["wall"] += _time.perf_counter() - t0
            counters["batches"] += 1
            counters["solves"] += len(batch)
            counters["candidates"] += fleet.n_candidates
            counters["pruned"] += fleet.n_pruned
            for p, inst, res in zip(batch, instances, fleet.results):
                p.n_solves += 1
                p.remember(
                    res, (inst.n_racks, inst.n_wireless), self.seed_pool_size
                )
            for p, view, res in zip(admit, views, fleet.results):
                sched, mk = res.schedule, res.makespan
                if (
                    self.warm_start
                    and p.best_makespan < mk
                    and p.best_shape
                    == (view.inst.n_racks, view.inst.n_wireless)
                ):
                    # Keep-incumbent re-optimization: the fresh solve did
                    # not beat the chain's best simulated schedule for
                    # this exact resource shape, so serve the incumbent.
                    sched, mk = p.best_sched, p.best_makespan
                comp = cluster.commit(view, sched, t)
                records.append(self._record(p, view, t, comp, mk, sched))
                new_completions.append(comp)
        else:
            fn = ONLINE_BASELINES[self.policy]
            for p, view in zip(admit, views):
                t0 = _time.perf_counter()
                sched = fn(view.inst, use_wireless=view.inst.n_wireless > 0)
                counters["wall"] += _time.perf_counter() - t0
                counters["solves"] += 1
                p.n_solves += 1
                comp = cluster.commit(view, sched, t)
                records.append(
                    self._record(p, view, t, comp, sched.makespan, sched)
                )
                new_completions.append(comp)

        for p in admit:
            pending.remove(p)
        return new_completions

    @staticmethod
    def _record(
        p: _PendingJob,
        view: ResidualView,
        t: float,
        comp: float,
        mk: float,
        sched: Schedule,
    ) -> JobMetrics:
        return JobMetrics(
            job_id=p.event.job_id,
            family=p.event.family,
            arrival=p.event.time,
            admitted=t,
            completion=comp,
            makespan=mk,
            n_racks_granted=view.inst.n_racks,
            n_wireless_granted=view.inst.n_wireless,
            n_solves=p.n_solves,
            assignment=view.rack_map[np.asarray(sched.rack, dtype=np.int64)],
        )
