"""Global cluster timeline: residual capacity for arrival-driven admission.

The offline engine solves each :class:`~repro.core.instance.ProblemInstance`
against a *private* resource view (its own racks and subchannels). Online,
admitted jobs occupy the shared cluster over time, so a newly arrived job
must be solved against what is actually free. :class:`ClusterTimeline`
tracks, per physical rack and per physical channel (the wired channel plus
each wireless subchannel), the time until which the resource is held by
committed jobs, and constructs **residual-capacity instances**: the same
DAG, but with ``n_racks`` / ``n_wireless`` clamped to the resources free
at the admission epoch, together with the local->physical maps needed to
commit the resulting schedule back onto the shared timeline.

Occupancy model: **racks are exclusive** — jobs admitted at the same
epoch draw disjoint rack grants from a shrinking pool (the service passes
``rack_pool``), and a committed job holds each granted rack it uses until
its last task there finishes. **Wireless subchannels are gated across
epochs** by their hold times (a held subchannel is excluded from later
residual views) but shared by the jobs of one epoch. **The wired channel
is never gated**: every job needs it, so it is contended only *within*
each job's own schedule (the fleet model of
:func:`repro.core.vectorized.schedule_fleet`, which solves co-admitted
jobs as independent instances) — cross-job wired contention, at any
epoch distance, is the model's deliberate approximation, and the
reported wired utilization is the sum of per-job busy times (it can
exceed 1 under overlap). With an empty cluster, one admission
epoch, and total rack demand within the cluster, every job is granted
exactly its demanded shape, so the online service reduces bit-for-bit to
one ``schedule_fleet`` call (locked by ``tests/test_online.py::
test_degenerate_arrivals_match_schedule_fleet``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.instance import CH_WIRED, ProblemInstance
from repro.core.schedule import Schedule

__all__ = ["ClusterTimeline", "ResidualView"]

# Tolerance for "free at t" comparisons on float timelines.
_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ResidualView:
    """A job's residual-capacity view of the cluster at one epoch.

    Attributes:
      inst: the residual instance — the job's DAG with ``n_racks`` =
        granted racks and ``n_wireless`` = free subchannels (0 when all
        are held: the job runs wired-only).
      rack_map: int[granted] physical rack id of each local rack index.
      wireless_map: int[free_wireless] physical subchannel index (0-based)
        of each local subchannel index.
      full: True iff the view grants the job's full demanded shape.
    """

    inst: ProblemInstance
    rack_map: np.ndarray
    wireless_map: np.ndarray
    full: bool


class ClusterTimeline:
    """Hold-until-free occupancy of one cluster's racks and channels.

    Args:
      n_racks: M physical racks.
      n_wireless: |K| physical wireless subchannels.
    """

    def __init__(self, n_racks: int, n_wireless: int):
        if n_racks < 1:
            raise ValueError("cluster needs at least one rack")
        if n_wireless < 0:
            raise ValueError("n_wireless must be non-negative")
        self.n_racks = int(n_racks)
        self.n_wireless = int(n_wireless)
        self.rack_hold = np.zeros(self.n_racks, dtype=np.float64)
        self.wireless_hold = np.zeros(self.n_wireless, dtype=np.float64)
        # Busy-time accumulators for utilization metrics.
        self.rack_busy_time = 0.0
        self.wired_busy_time = 0.0
        self.wireless_busy_time = 0.0
        self.last_completion = 0.0

    # -- residual capacity ---------------------------------------------------

    def free_racks(self, t: float) -> np.ndarray:
        """Physical rack ids free at time ``t`` (ascending)."""
        return np.nonzero(self.rack_hold <= t + _EPS)[0]

    def free_wireless(self, t: float) -> np.ndarray:
        """Physical wireless subchannel indices free at time ``t``."""
        return np.nonzero(self.wireless_hold <= t + _EPS)[0]

    def residual_view(
        self,
        inst: ProblemInstance,
        t: float,
        rack_pool: np.ndarray | None = None,
    ) -> ResidualView | None:
        """Residual-capacity instance for ``inst`` at epoch ``t``.

        Grants ``min(inst.n_racks, |pool|)`` racks — the lowest-id entries
        of ``rack_pool``, or of the free set at ``t`` when no pool is
        given (the service passes a shrinking pool so racks granted within
        one epoch are mutually exclusive) — and every free wireless
        subchannel up to the job's demand (subchannels are shared by jobs
        of one epoch, like the wired channel; only cross-epoch holds gate
        them). Returns ``None`` when the pool is empty — the job cannot
        be admitted at this epoch.
        """
        free_r = self.free_racks(t) if rack_pool is None else np.asarray(rack_pool)
        if free_r.size == 0:
            return None
        granted = free_r[: inst.n_racks]
        free_w = self.free_wireless(t)[: inst.n_wireless]
        residual = ProblemInstance(
            job=inst.job,
            n_racks=int(granted.size),
            n_wireless=int(free_w.size),
            wired_rate=inst.wired_rate,
            wireless_rate=inst.wireless_rate,
            local_delay=inst.local_delay,
        )
        full = granted.size == inst.n_racks and free_w.size == inst.n_wireless
        return ResidualView(
            inst=residual,
            rack_map=granted.astype(np.int64),
            wireless_map=free_w.astype(np.int64),
            full=bool(full),
        )

    # -- commit --------------------------------------------------------------

    def commit(self, view: ResidualView, sched: Schedule, t: float) -> float:
        """Place ``sched`` (solved in the residual view's local frame,
        relative time 0) onto the cluster starting at absolute time ``t``.

        Each rack the job uses is held until the job's last task on it
        finishes, and each used wireless subchannel until the job's last
        transfer on it finishes; wired-channel usage only accumulates
        busy time (it never gates admission — see the module docstring).
        Returns the job's absolute completion time (``t + makespan``).
        """
        inst = view.inst
        job = inst.job
        dur = inst.duration_on(sched.chan)
        for i in range(inst.n_racks):
            on_i = sched.rack == i
            if not on_i.any():
                continue
            fin = float(np.max(sched.start[on_i] + job.p[on_i]))
            phys = int(view.rack_map[i])
            self.rack_hold[phys] = max(self.rack_hold[phys], t + fin)
            self.rack_busy_time += float(np.sum(job.p[on_i]))
        if job.n_edges:
            wired = sched.chan == CH_WIRED
            if wired.any():
                self.wired_busy_time += float(np.sum(dur[wired]))
            for k in range(inst.n_wireless):
                on_k = sched.chan == 2 + k
                if not on_k.any():
                    continue
                fin = float(np.max(sched.tstart[on_k] + dur[on_k]))
                phys = int(view.wireless_map[k])
                self.wireless_hold[phys] = max(self.wireless_hold[phys], t + fin)
                self.wireless_busy_time += float(np.sum(dur[on_k]))
        completion = t + sched.makespan
        self.last_completion = max(self.last_completion, completion)
        return completion

    # -- metrics -------------------------------------------------------------

    def utilization(self, horizon: float) -> dict[str, float]:
        """Busy-time fractions over ``[0, horizon]``. Rack and wireless
        figures are exact under their exclusivity rules; the wired figure
        sums per-job busy times and can exceed 1 when concurrent jobs'
        wired transfers overlap (see the module docstring)."""
        if horizon <= 0.0:
            return {"rack": 0.0, "wired": 0.0, "wireless": 0.0}
        return {
            "rack": self.rack_busy_time / (self.n_racks * horizon),
            "wired": self.wired_busy_time / horizon,
            "wireless": (
                self.wireless_busy_time / (self.n_wireless * horizon)
                if self.n_wireless
                else 0.0
            ),
        }

