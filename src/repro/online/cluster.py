"""Global cluster timeline: residual capacity and channel-feasible commits.

The offline engine solves each :class:`~repro.core.instance.ProblemInstance`
against a *private* resource view (its own racks and subchannels). Online,
admitted jobs occupy the shared cluster over time, so a newly arrived job
must be solved against what is actually free, and its committed transfers
must not overlap other jobs' transfers on the same physical link.
:class:`ClusterTimeline` therefore tracks two things per physical resource:

* a **hold time** (per rack and per wireless subchannel) — the time until
  which the resource is granted to a committed job; grants are exclusive,
  so holds gate admission, and
* the **busy intervals** of every physical channel — the single wired
  channel and each wireless subchannel — carrying the exact committed
  transfer windows of every job, with the owning job id.

Occupancy model: **racks and wireless subchannels are exclusive grants** —
jobs admitted at the same epoch draw disjoint grants from shrinking pools
(the service passes ``rack_pool`` / ``wireless_pool``), a committed job
holds each granted rack until its last task there finishes and each granted
subchannel until its last transfer there finishes, and held resources are
excluded from later epochs' residual views. **The wired channel is shared
by every job** and is never granted; instead every commit passes through
:meth:`ClusterTimeline.arbitrate` — a deterministic commit-order
arbitration pass that replays the job's schedule through the host
simulator (:func:`repro.core.simulator.simulate` with the ``channel_busy``
hook) against the busy intervals already committed on its physical
channels. The replay keeps the engine's intra-job decisions (task->rack
and edge->channel vectors) and only shifts start times, gap-inserting the
job's transfers around other jobs' — so every committed timeline is
physically feasible: no two jobs ever overlap on the wired channel or on
one wireless subchannel (:meth:`ClusterTimeline.assert_feasible` audits
exactly this), and reported utilizations are true fractions in [0, 1].

Interval index (the O(active) serving core): every per-resource interval
list is maintained **sorted by start** with ``bisect.insort``. Committed
intervals on one resource are pairwise disjoint (the feasibility
invariant), so their end times are sorted too, and
:meth:`ClusterTimeline.channel_busy` answers "which intervals end after
``t``" with one bisect on the end column — O(log n + hits) instead of a
full-history scan. :meth:`ClusterTimeline.compact` retires intervals
ending at or before a frontier ``t``: epochs are monotone and every
residual/busy query at ``t' >= t`` drops such intervals anyway, so
compaction is *observationally identical* — busy-time accumulators were
already charged at commit, holds are untouched, and ``channel_busy`` /
``arbitrate`` / ``utilization`` return bit-identical answers (the
equivalence property is locked by ``tests/test_online_scale.py``). After
compaction the steady-state cost of every timeline operation depends only
on the intervals of *active* jobs, not on the full arrival history.

The feasibility audit is incremental on the same index: commits buffer
their new intervals, and :meth:`assert_feasible` checks only those against
their sorted neighbors (``full=True`` rescans every retained interval from
scratch — the test-suite escape hatch). :meth:`compact` audits the pending
buffer before dropping anything, so no committed interval is ever retired
unaudited.

When a job's physical channels carry no committed intervals past the
admission epoch, ``arbitrate`` returns the schedule object unchanged —
with an empty cluster, one admission epoch, disjoint grants and no
cross-job wired traffic, the online service still reduces bit-for-bit to
one ``schedule_fleet`` call (locked by ``tests/test_online.py::
test_degenerate_arrivals_match_schedule_fleet``).

Float semantics: holds are recorded at exact float completion times and
``free_racks`` / ``free_wireless`` use an exact ``hold <= t`` comparison —
a resource released at exactly ``t`` is re-grantable at ``t``, while an
in-flight hold any amount past ``t`` (even within the old ``_EPS``
tolerance window) is busy, so back-to-back admissions can never
double-book (regression-locked in ``tests/test_online.py``). ``_EPS`` is
kept only as the audit's overlap tolerance.
"""

from __future__ import annotations

import bisect
import dataclasses
import operator

import numpy as np

from repro.core.instance import CH_WIRED, ProblemInstance
from repro.core.schedule import Schedule
from repro.core.simulator import simulate

__all__ = ["ClusterTimeline", "ResidualView"]

# Overlap tolerance for the feasibility audit. Grant/release comparisons are
# exact (see the module docstring); this only absorbs float noise when two
# independently computed transfer windows abut.
_EPS = 1e-9

# Sort key of one committed interval: its end time. Intervals on one
# resource are disjoint (the feasibility invariant), so the start-sorted
# index has sorted ends too and both columns bisect.
_END = operator.itemgetter(1)


@dataclasses.dataclass(frozen=True)
class ResidualView:
    """A job's residual-capacity view of the cluster at one epoch.

    Attributes:
      inst: the residual instance — the job's DAG with ``n_racks`` =
        granted racks and ``n_wireless`` = granted subchannels (0 when all
        are held: the job runs wired-only).
      rack_map: int[granted] physical rack id of each local rack index.
      wireless_map: int[granted_wireless] physical subchannel index
        (0-based) of each local subchannel index.
      full: True iff the view grants the job's full demanded shape.
    """

    inst: ProblemInstance
    rack_map: np.ndarray
    wireless_map: np.ndarray
    full: bool


class ClusterTimeline:
    """Hold-until-free grants plus per-channel busy intervals of one cluster.

    Args:
      n_racks: M physical racks.
      n_wireless: |K| physical wireless subchannels.
    """

    def __init__(self, n_racks: int, n_wireless: int):
        if n_racks < 1:
            raise ValueError("cluster needs at least one rack")
        if n_wireless < 0:
            raise ValueError("n_wireless must be non-negative")
        self.n_racks = int(n_racks)
        self.n_wireless = int(n_wireless)
        self.rack_hold = np.zeros(self.n_racks, dtype=np.float64)
        self.wireless_hold = np.zeros(self.n_wireless, dtype=np.float64)
        # Committed occupancy, (start, end, job_id) in absolute time. Each
        # list is a sorted interval index (ascending starts; disjoint
        # intervals make the ends ascending too).
        self.rack_intervals: list[list[tuple[float, float, int]]] = [
            [] for _ in range(self.n_racks)
        ]
        self.wired_intervals: list[tuple[float, float, int]] = []
        self.wireless_intervals: list[list[tuple[float, float, int]]] = [
            [] for _ in range(self.n_wireless)
        ]
        # Busy-time accumulators for utilization metrics. Charged at
        # commit, so compaction never has to re-derive them.
        self.rack_busy_time = 0.0
        self.wired_busy_time = 0.0
        self.wireless_busy_time = 0.0
        self.last_completion = 0.0
        # Compaction frontier: intervals ending at or before it have been
        # retired from the index (their busy time stays accumulated).
        self.compact_frontier = 0.0
        self.n_compacted = 0
        # Intervals committed since the last audit: (label, index_list,
        # interval) triples checked incrementally by assert_feasible.
        self._audit_backlog: list[
            tuple[str, list[tuple[float, float, int]], tuple[float, float, int]]
        ] = []

    # -- residual capacity ---------------------------------------------------

    def free_racks(self, t: float) -> np.ndarray:
        """Physical rack ids free at time ``t`` (ascending, exact release)."""
        return np.nonzero(self.rack_hold <= t)[0]

    def free_wireless(self, t: float) -> np.ndarray:
        """Physical wireless subchannel indices free at time ``t``."""
        return np.nonzero(self.wireless_hold <= t)[0]

    def residual_view(
        self,
        inst: ProblemInstance,
        t: float,
        rack_pool: np.ndarray | None = None,
        wireless_pool: np.ndarray | None = None,
    ) -> ResidualView | None:
        """Residual-capacity instance for ``inst`` at epoch ``t``.

        Grants ``min(inst.n_racks, |rack_pool|)`` racks and
        ``min(inst.n_wireless, |wireless_pool|)`` wireless subchannels —
        the lowest-id entries of each pool, or of the free sets at ``t``
        when no pool is given. The service passes shrinking pools so that
        resources granted within one epoch are mutually exclusive, for
        racks and subchannels alike. Returns ``None`` when the rack pool
        is empty — the job cannot be admitted at this epoch.
        """
        free_r = self.free_racks(t) if rack_pool is None else np.asarray(rack_pool)
        if free_r.size == 0:
            return None
        granted = free_r[: inst.n_racks]
        free_w = (
            self.free_wireless(t) if wireless_pool is None else np.asarray(wireless_pool)
        )[: inst.n_wireless]
        residual = ProblemInstance(
            job=inst.job,
            n_racks=int(granted.size),
            n_wireless=int(free_w.size),
            wired_rate=inst.wired_rate,
            wireless_rate=inst.wireless_rate,
            local_delay=inst.local_delay,
        )
        full = granted.size == inst.n_racks and free_w.size == inst.n_wireless
        return ResidualView(
            inst=residual,
            rack_map=granted.astype(np.int64),
            wireless_map=free_w.astype(np.int64),
            full=bool(full),
        )

    # -- cross-job arbitration ----------------------------------------------

    @staticmethod
    def _tail(
        intervals: list[tuple[float, float, int]], t: float
    ) -> list[tuple[float, float, int]]:
        """Intervals ending strictly after ``t``: one bisect on the sorted
        end column, then the contiguous tail of the index."""
        i = bisect.bisect_right(intervals, t, key=_END)
        return intervals[i:]

    def channel_busy(self, view: ResidualView, t: float) -> dict:
        """Committed busy intervals on ``view``'s physical channels, mapped
        into the view's local frame (channel ids CH_WIRED / 2+k, times
        relative to ``t``). Intervals ending at or before ``t`` are
        dropped; an interval straddling ``t`` keeps its negative-start
        tail (the simulator's gap search handles it). Channels with no
        remaining intervals are omitted, so an empty dict certifies the
        job's channels are clear from ``t`` on. O(log n + hits) per
        channel on the sorted interval index; ``t`` must not precede the
        compaction frontier (retired intervals cannot be reconstructed).
        """
        if t < self.compact_frontier:
            raise RuntimeError(
                f"channel_busy at t={t} precedes the compaction frontier "
                f"{self.compact_frontier}: intervals ending before the "
                "frontier have been retired and cannot be replayed"
            )
        busy: dict[int, list[tuple[float, float]]] = {}
        wired = [(s - t, e - t) for s, e, _ in self._tail(self.wired_intervals, t)]
        if wired:
            busy[CH_WIRED] = wired
        for k in range(view.inst.n_wireless):
            phys = int(view.wireless_map[k])
            ivs = [
                (s - t, e - t)
                for s, e, _ in self._tail(self.wireless_intervals[phys], t)
            ]
            if ivs:
                busy[2 + k] = ivs
        return busy

    def arbitrate(self, view: ResidualView, sched: Schedule, t: float) -> Schedule:
        """Sequence ``sched`` onto the shared physical channels at ``t``.

        The cross-job arbitration pass: replays the schedule through the
        host simulator with the busy intervals already committed on the
        job's physical channels, keeping the engine's task->rack and
        edge->channel decisions and re-deriving exact start times (the
        job's transfers gap-insert around other jobs'). Deterministic for
        a fixed commit order, and the identity when the job's channels
        carry no committed intervals past ``t`` — so an uncontended
        commit stays bit-for-bit the engine's schedule.
        """
        busy = self.channel_busy(view, t)
        if not busy:
            return sched
        return simulate(view.inst, sched.rack, chan=sched.chan, channel_busy=busy)

    # -- commit --------------------------------------------------------------

    def _insert(
        self,
        label: str,
        intervals: list[tuple[float, float, int]],
        iv: tuple[float, float, int],
    ) -> None:
        """Sorted insert into one resource's interval index, buffering the
        interval for the incremental feasibility audit."""
        bisect.insort(intervals, iv)
        self._audit_backlog.append((label, intervals, iv))

    def commit(
        self,
        view: ResidualView,
        sched: Schedule,
        t: float,
        job_id: int = -1,
        holds_out: list | None = None,
    ) -> float:
        """Place ``sched`` (solved in the residual view's local frame,
        relative time 0) onto the cluster starting at absolute time ``t``.

        Each granted rack the job uses is held until the job's last task
        on it finishes, and each granted wireless subchannel until the
        job's last transfer on it finishes; every transfer's exact window
        is recorded on its physical channel (the wired channel included).
        The caller is responsible for channel feasibility — pass the
        schedule through :meth:`arbitrate` first when the cluster is not
        empty; :meth:`assert_feasible` audits the invariant after the
        fact. ``holds_out``, when given, receives one
        ``("rack" | "wireless", physical_id, hold_time)`` triple per
        resource this commit (re)holds — the delta feed for the service's
        incrementally maintained free sets. Returns the job's absolute
        completion time (``t + makespan``).
        """
        inst = view.inst
        job = inst.job
        dur = inst.duration_on(sched.chan)
        held_w: dict[int, float] = {}
        for i in range(inst.n_racks):
            on_i = sched.rack == i
            if not on_i.any():
                continue
            fin = float(np.max(sched.start[on_i] + job.p[on_i]))
            phys = int(view.rack_map[i])
            self.rack_hold[phys] = max(self.rack_hold[phys], t + fin)
            if holds_out is not None:
                holds_out.append(("rack", phys, self.rack_hold[phys]))
            self.rack_busy_time += float(np.sum(job.p[on_i]))
            for s, p in zip(sched.start[on_i], job.p[on_i]):
                if p > 0:
                    self._insert(
                        f"rack {phys}",
                        self.rack_intervals[phys],
                        (t + float(s), t + float(s) + float(p), job_id),
                    )
        if job.n_edges:
            for e in range(job.n_edges):
                c, d = int(sched.chan[e]), float(dur[e])
                if d <= 0.0:
                    continue  # zero-size transfers occupy nothing
                s = float(sched.tstart[e])
                if c == CH_WIRED:
                    self._insert(
                        "wired channel",
                        self.wired_intervals,
                        (t + s, t + s + d, job_id),
                    )
                    self.wired_busy_time += d
                elif c >= 2:
                    phys = int(view.wireless_map[c - 2])
                    self._insert(
                        f"wireless subchannel {phys}",
                        self.wireless_intervals[phys],
                        (t + s, t + s + d, job_id),
                    )
                    self.wireless_hold[phys] = max(
                        self.wireless_hold[phys], t + s + d
                    )
                    held_w[phys] = self.wireless_hold[phys]
                    self.wireless_busy_time += d
        if holds_out is not None:
            for phys, hold in held_w.items():
                holds_out.append(("wireless", phys, hold))
        completion = t + sched.makespan
        self.last_completion = max(self.last_completion, completion)
        return completion

    # -- compaction ----------------------------------------------------------

    def _indexes(self):
        for i, ivs in enumerate(self.rack_intervals):
            yield f"rack {i}", ivs
        yield "wired channel", self.wired_intervals
        for k, ivs in enumerate(self.wireless_intervals):
            yield f"wireless subchannel {k}", ivs

    @property
    def n_intervals(self) -> int:
        """Committed intervals currently retained in the index (excludes
        the ``n_compacted`` already retired)."""
        return sum(len(ivs) for _label, ivs in self._indexes())

    def compact(self, t: float) -> int:
        """Retire every committed interval ending at or before ``t`` from
        the interval index; returns how many were retired.

        Safe whenever ``t`` does not exceed the current epoch: epochs are
        monotone and every later ``channel_busy`` / ``arbitrate`` query
        drops intervals ending at or before its (later) epoch anyway, so
        compaction changes no observable answer — busy-time accumulators
        were charged at commit and holds are untouched. The pending audit
        backlog is flushed first (:meth:`assert_feasible`), so no interval
        is retired unaudited.
        """
        self.assert_feasible()
        t = float(t)
        dropped = 0
        for _label, ivs in self._indexes():
            i = bisect.bisect_right(ivs, t, key=_END)
            if i:
                del ivs[:i]
                dropped += i
        self.n_compacted += dropped
        self.compact_frontier = max(self.compact_frontier, t)
        return dropped

    # -- feasibility audit ---------------------------------------------------

    def assert_feasible(self, tol: float = _EPS, full: bool = False) -> None:
        """Audit the committed timeline: no two committed operations may
        overlap on the same physical resource — tasks on a rack, transfers
        on the wired channel, transfers on one wireless subchannel —
        regardless of which jobs they belong to. Raises ``AssertionError``
        (a real raise, alive under ``python -O``) naming the resource and
        the two owning jobs on the first overlap.

        Incremental by default: only intervals committed since the last
        audit are checked, each against its sorted neighbors in the index
        (disjointness of adjacent pairs is equivalent to global
        disjointness on a start-sorted index). ``full=True`` rescans every
        *retained* interval from scratch — intervals already retired by
        :meth:`compact` were audited before retirement.
        """
        if full:
            self._audit_backlog.clear()
            for label, ivs in self._indexes():
                ordered = sorted(ivs)
                for (s0, e0, j0), (s1, _e1, j1) in zip(ordered, ordered[1:]):
                    if s1 < e0 - tol:
                        raise AssertionError(
                            f"{label}: committed intervals of job {j0} "
                            f"[{s0}, {e0}) and job {j1} [{s1}, ...) overlap"
                        )
            return
        backlog, self._audit_backlog = self._audit_backlog, []
        for label, ivs, iv in backlog:
            pos = bisect.bisect_left(ivs, iv)
            s, e, j = iv
            if pos > 0:
                s0, e0, j0 = ivs[pos - 1]
                if s < e0 - tol:
                    raise AssertionError(
                        f"{label}: committed intervals of job {j0} "
                        f"[{s0}, {e0}) and job {j} [{s}, ...) overlap"
                    )
            if pos + 1 < len(ivs):
                s1, _e1, j1 = ivs[pos + 1]
                if s1 < e - tol:
                    raise AssertionError(
                        f"{label}: committed intervals of job {j} "
                        f"[{s}, {e}) and job {j1} [{s1}, ...) overlap"
                    )

    # -- metrics -------------------------------------------------------------

    def utilization(self, horizon: float) -> dict[str, float]:
        """Busy-time fractions over ``[0, horizon]``. All three figures are
        exact under the channel-feasible commit model (compaction never
        touches the accumulators) and guaranteed to be true fractions in
        [0, 1]; a fraction outside the float-noise band raises
        ``RuntimeError`` — a real raise, NOT an ``assert``, so the audit
        survives ``python -O`` stripping."""
        if horizon <= 0.0:
            return {"rack": 0.0, "wired": 0.0, "wireless": 0.0}
        util = {
            "rack": self.rack_busy_time / (self.n_racks * horizon),
            "wired": self.wired_busy_time / horizon,
            "wireless": (
                self.wireless_busy_time / (self.n_wireless * horizon)
                if self.n_wireless
                else 0.0
            ),
        }
        for name, frac in util.items():
            if not (-1e-12 <= frac <= 1.0 + 1e-9):
                raise RuntimeError(
                    f"{name} utilization {frac} outside [0, 1]: committed "
                    "timeline is not channel-feasible"
                )
        return {name: min(max(frac, 0.0), 1.0) for name, frac in util.items()}
