"""Global cluster timeline: residual capacity and channel-feasible commits.

The offline engine solves each :class:`~repro.core.instance.ProblemInstance`
against a *private* resource view (its own racks and subchannels). Online,
admitted jobs occupy the shared cluster over time, so a newly arrived job
must be solved against what is actually free, and its committed transfers
must not overlap other jobs' transfers on the same physical link.
:class:`ClusterTimeline` therefore tracks two things per physical resource:

* a **hold time** (per rack and per wireless subchannel) — the time until
  which the resource is granted to a committed job; grants are exclusive,
  so holds gate admission, and
* the **busy intervals** of every physical channel — the single wired
  channel and each wireless subchannel — carrying the exact committed
  transfer windows of every job, with the owning job id.

Occupancy model: **racks and wireless subchannels are exclusive grants** —
jobs admitted at the same epoch draw disjoint grants from shrinking pools
(the service passes ``rack_pool`` / ``wireless_pool``), a committed job
holds each granted rack until its last task there finishes and each granted
subchannel until its last transfer there finishes, and held resources are
excluded from later epochs' residual views. **The wired channel is shared
by every job** and is never granted; instead every commit passes through
:meth:`ClusterTimeline.arbitrate` — a deterministic commit-order
arbitration pass that replays the job's schedule through the host
simulator (:func:`repro.core.simulator.simulate` with the ``channel_busy``
hook) against the busy intervals already committed on its physical
channels. The replay keeps the engine's intra-job decisions (task->rack
and edge->channel vectors) and only shifts start times, gap-inserting the
job's transfers around other jobs' — so every committed timeline is
physically feasible: no two jobs ever overlap on the wired channel or on
one wireless subchannel (:meth:`ClusterTimeline.assert_feasible` audits
exactly this), and reported utilizations are true fractions in [0, 1].

Interval index (the O(active) serving core): every per-resource interval
list is maintained **sorted by start** with ``bisect.insort``. Committed
intervals on one resource are pairwise disjoint (the feasibility
invariant), so their end times are sorted too, and
:meth:`ClusterTimeline.channel_busy` answers "which intervals end after
``t``" with one bisect on the end column — O(log n + hits) instead of a
full-history scan. :meth:`ClusterTimeline.compact` retires intervals
ending at or before a frontier ``t``: epochs are monotone and every
residual/busy query at ``t' >= t`` drops such intervals anyway, so
compaction is *observationally identical* — busy-time accumulators were
already charged at commit, holds are untouched, and ``channel_busy`` /
``arbitrate`` / ``utilization`` return bit-identical answers (the
equivalence property is locked by ``tests/test_online_scale.py``). After
compaction the steady-state cost of every timeline operation depends only
on the intervals of *active* jobs, not on the full arrival history.

The feasibility audit is incremental on the same index: commits buffer
their new intervals, and :meth:`assert_feasible` checks only those against
their sorted neighbors (``full=True`` rescans every retained interval from
scratch — the test-suite escape hatch). :meth:`compact` audits the pending
buffer before dropping anything, so no committed interval is ever retired
unaudited.

When a job's physical channels carry no committed intervals past the
admission epoch, ``arbitrate`` returns the schedule object unchanged —
with an empty cluster, one admission epoch, disjoint grants and no
cross-job wired traffic, the online service still reduces bit-for-bit to
one ``schedule_fleet`` call (locked by ``tests/test_online.py::
test_degenerate_arrivals_match_schedule_fleet``).

Reconfigurable topology: constructed with a cluster-level
:class:`~repro.core.instance.Topology`, the timeline additionally tracks
**which wireless links are configured** (``matching``, a per-epoch greedy
weighted b-matching over the topology's candidate links; see
:meth:`ClusterTimeline.reconfigure`) and **which are physically up**
(``link_state``, flipped by seeded outage traces via
:meth:`ClusterTimeline.set_link`). Residual views then carry the induced
:class:`~repro.core.instance.Topology` on their granted racks ×
subchannels, so every solver stage — bounds, kernels, simulator —
respects the active links. Reconfiguring a subchannel charges the
topology's δ as a busy interval (owner id ``RECONFIG_JOB``) on that
subchannel, audited by :meth:`assert_feasible` like any transfer; only
subchannels idle at the epoch are ever reconfigured, links mid-transfer
are pinned. With ``topology=None`` (default) all of this is inert and the
timeline is bit-identical to the pre-topology code.

Float semantics: holds are recorded at exact float completion times and
``free_racks`` / ``free_wireless`` use an exact ``hold <= t`` comparison —
a resource released at exactly ``t`` is re-grantable at ``t``, while an
in-flight hold any amount past ``t`` (even within the old ``_EPS``
tolerance window) is busy, so back-to-back admissions can never
double-book (regression-locked in ``tests/test_online.py``). ``_EPS`` is
kept only as the audit's overlap tolerance.
"""

from __future__ import annotations

import bisect
import dataclasses
import operator

import numpy as np

from repro.core.instance import CH_WIRED, ProblemInstance, Topology
from repro.core.schedule import Schedule
from repro.core.simulator import simulate
from repro.obs.trace import as_tracer

__all__ = [
    "ClusterTimeline",
    "OrderReplay",
    "RECONFIG_JOB",
    "ResidualView",
    "channel_delay_attribution",
    "job_holds",
    "replay_commit_order",
    "reservation_backfill_safe",
    "wired_windows",
]

# Overlap tolerance for the feasibility audit. Grant/release comparisons are
# exact (see the module docstring); this only absorbs float noise when two
# independently computed transfer windows abut.
_EPS = 1e-9

# Sort key of one committed interval: its end time. Intervals on one
# resource are disjoint (the feasibility invariant), so the start-sorted
# index has sorted ends too and both columns bisect.
_END = operator.itemgetter(1)

# Owner id of δ reconfiguration intervals on wireless subchannels (no real
# job ever commits with this id; the service reserves -1 for anonymous
# commits, so reconfigurations get their own marker).
RECONFIG_JOB = -2


@dataclasses.dataclass(frozen=True)
class ResidualView:
    """A job's residual-capacity view of the cluster at one epoch.

    Attributes:
      inst: the residual instance — the job's DAG with ``n_racks`` =
        granted racks and ``n_wireless`` = granted subchannels (0 when all
        are held: the job runs wired-only).
      rack_map: int[granted] physical rack id of each local rack index.
      wireless_map: int[granted_wireless] physical subchannel index
        (0-based) of each local subchannel index.
      full: True iff the view grants the job's full demanded shape.
    """

    inst: ProblemInstance
    rack_map: np.ndarray
    wireless_map: np.ndarray
    full: bool


class ClusterTimeline:
    """Hold-until-free grants plus per-channel busy intervals of one cluster.

    Args:
      n_racks: M physical racks.
      n_wireless: |K| physical wireless subchannels.
      topology: optional cluster-level
        :class:`~repro.core.instance.Topology` over
        ``[n_racks, n_wireless]``. When given, residual views carry the
        induced topology of the currently configured + up links, and
        :meth:`reconfigure` / :meth:`set_link` manage the matching and
        outage state. ``None`` (default) = the paper's model, bit-identical
        to the pre-topology timeline.
      tracer: optional :class:`repro.obs.trace.Tracer` receiving
        compaction and audit-backlog events (``None`` = no tracing).
    """

    def __init__(
        self,
        n_racks: int,
        n_wireless: int,
        *,
        topology: Topology | None = None,
        tracer=None,
    ):
        self.tracer = as_tracer(tracer)
        if n_racks < 1:
            raise ValueError("cluster needs at least one rack")
        if n_wireless < 0:
            raise ValueError("n_wireless must be non-negative")
        self.n_racks = int(n_racks)
        self.n_wireless = int(n_wireless)
        if topology is not None and topology.reach.shape != (
            self.n_racks,
            self.n_wireless,
        ):
            raise ValueError(
                f"cluster topology shape {topology.reach.shape} != "
                f"({self.n_racks}, {self.n_wireless})"
            )
        self.topology = topology
        # Configured links (the current matching) and physical link health.
        # Start fully configured: "static" serving never reconfigures and
        # simply exposes reach & link_state.
        self.matching = None if topology is None else topology.reach.copy()
        self.link_state = (
            None
            if topology is None
            else np.ones((self.n_racks, self.n_wireless), dtype=bool)
        )
        self.n_reconfigs = 0
        self.rack_hold = np.zeros(self.n_racks, dtype=np.float64)
        self.wireless_hold = np.zeros(self.n_wireless, dtype=np.float64)
        # Committed occupancy, (start, end, job_id) in absolute time. Each
        # list is a sorted interval index (ascending starts; disjoint
        # intervals make the ends ascending too).
        self.rack_intervals: list[list[tuple[float, float, int]]] = [
            [] for _ in range(self.n_racks)
        ]
        self.wired_intervals: list[tuple[float, float, int]] = []
        self.wireless_intervals: list[list[tuple[float, float, int]]] = [
            [] for _ in range(self.n_wireless)
        ]
        # Busy-time accumulators for utilization metrics. Charged at
        # commit, so compaction never has to re-derive them.
        self.rack_busy_time = 0.0
        self.wired_busy_time = 0.0
        self.wireless_busy_time = 0.0
        self.last_completion = 0.0
        # Compaction frontier: intervals ending at or before it have been
        # retired from the index (their busy time stays accumulated).
        self.compact_frontier = 0.0
        self.n_compacted = 0
        # Intervals committed since the last audit: (label, index_list,
        # interval) triples checked incrementally by assert_feasible.
        self._audit_backlog: list[
            tuple[str, list[tuple[float, float, int]], tuple[float, float, int]]
        ] = []

    # -- reconfigurable topology ---------------------------------------------

    def active_reach(self) -> np.ndarray | None:
        """bool[n_racks, n_wireless] of usable links — configured by the
        current matching AND physically up — or ``None`` without a
        cluster topology."""
        if self.topology is None:
            return None
        return self.matching & self.link_state

    def topology_signature(self):
        """Hashable fingerprint of the active link set (``None`` without a
        topology): folds into the service's availability signature so
        matching / outage changes invalidate ``replan="changed"`` plans."""
        if self.topology is None:
            return None
        return (self.matching & self.link_state).tobytes()

    def set_link(self, rack: int, k: int, up: bool) -> bool:
        """Flip one physical link's health (outage / repair); returns
        whether the state changed. Links mid-transfer stay committed —
        outages only gate *future* views and matchings."""
        if self.topology is None:
            raise RuntimeError("set_link needs a cluster topology")
        up = bool(up)
        if self.link_state[rack, k] == up:
            return False
        self.link_state[rack, k] = up
        return True

    def reconfigure(self, weight: np.ndarray, t: float) -> int:
        """Re-match the wireless links to this epoch's demand at time ``t``.

        Runs the topology's greedy weighted b-matching
        (:meth:`~repro.core.instance.Topology.match`) over the links that
        are physically up, with two timeline-imposed rules: subchannels
        still busy at ``t`` (``wireless_hold > t``) keep their configured
        links — those are pinned into the matching and count toward the
        degree limits — and every *idle* subchannel whose link set changes
        is charged the reconfiguration delay δ as a busy interval
        ``[t, t + δ)`` owned by :data:`RECONFIG_JOB` (disjoint by
        construction: an idle subchannel has no committed interval ending
        after ``t``). Returns the number of subchannels reconfigured.
        No-op (returns 0) without a cluster topology.
        """
        if self.topology is None:
            return 0
        idle = self.wireless_hold <= t
        keep = self.matching.copy()
        keep[:, idle] = False
        feasible = self.link_state.copy()
        feasible[:, ~idle] = False
        new = self.topology.match(
            np.asarray(weight, dtype=np.float64), feasible=feasible, keep=keep
        )
        changed = ((new != self.matching).any(axis=0)) & idle
        n_changed = int(changed.sum())
        delta = float(self.topology.delta)
        if delta > 0.0 and n_changed:
            for k in np.nonzero(changed)[0]:
                self._insert(
                    f"wireless subchannel {k}",
                    self.wireless_intervals[int(k)],
                    (t, t + delta, RECONFIG_JOB),
                )
                self.wireless_hold[k] = max(self.wireless_hold[k], t + delta)
                self.wireless_busy_time += delta
        self.matching = new
        self.n_reconfigs += n_changed
        return n_changed

    # -- residual capacity ---------------------------------------------------

    def free_racks(self, t: float) -> np.ndarray:
        """Physical rack ids free at time ``t`` (ascending, exact release)."""
        return np.nonzero(self.rack_hold <= t)[0]

    def free_wireless(self, t: float) -> np.ndarray:
        """Physical wireless subchannel indices free at time ``t``."""
        return np.nonzero(self.wireless_hold <= t)[0]

    def residual_view(
        self,
        inst: ProblemInstance,
        t: float,
        rack_pool: np.ndarray | None = None,
        wireless_pool: np.ndarray | None = None,
    ) -> ResidualView | None:
        """Residual-capacity instance for ``inst`` at epoch ``t``.

        Grants ``min(inst.n_racks, |rack_pool|)`` racks and
        ``min(inst.n_wireless, |wireless_pool|)`` wireless subchannels —
        the lowest-id entries of each pool, or of the free sets at ``t``
        when no pool is given. The service passes shrinking pools so that
        resources granted within one epoch are mutually exclusive, for
        racks and subchannels alike. Returns ``None`` when the rack pool
        is empty — the job cannot be admitted at this epoch.
        """
        free_r = self.free_racks(t) if rack_pool is None else np.asarray(rack_pool)
        if free_r.size == 0:
            return None
        granted = free_r[: inst.n_racks]
        free_w = (
            self.free_wireless(t) if wireless_pool is None else np.asarray(wireless_pool)
        )[: inst.n_wireless]
        topo = None
        if self.topology is not None:
            # The induced topology of the currently usable links on the
            # granted racks × subchannels; the solver stack (bounds,
            # kernels, simulator) gates channel picks on it.
            topo = dataclasses.replace(
                self.topology,
                reach=self.active_reach()[
                    np.ix_(granted.astype(np.int64), free_w.astype(np.int64))
                ],
            )
        residual = ProblemInstance(
            job=inst.job,
            n_racks=int(granted.size),
            n_wireless=int(free_w.size),
            wired_rate=inst.wired_rate,
            wireless_rate=inst.wireless_rate,
            local_delay=inst.local_delay,
            topology=topo,
        )
        full = granted.size == inst.n_racks and free_w.size == inst.n_wireless
        return ResidualView(
            inst=residual,
            rack_map=granted.astype(np.int64),
            wireless_map=free_w.astype(np.int64),
            full=bool(full),
        )

    # -- cross-job arbitration ----------------------------------------------

    @staticmethod
    def _tail(
        intervals: list[tuple[float, float, int]], t: float
    ) -> list[tuple[float, float, int]]:
        """Intervals ending strictly after ``t``: one bisect on the sorted
        end column, then the contiguous tail of the index."""
        i = bisect.bisect_right(intervals, t, key=_END)
        return intervals[i:]

    def channel_busy(
        self,
        view: ResidualView,
        t: float,
        wired_extra: list[tuple[float, float]] | tuple = (),
    ) -> dict:
        """Committed busy intervals on ``view``'s physical channels, mapped
        into the view's local frame (channel ids CH_WIRED / 2+k, times
        relative to ``t``). Intervals ending at or before ``t`` are
        dropped; an interval straddling ``t`` keeps its negative-start
        tail (the simulator's gap search handles it). Channels with no
        remaining intervals are omitted, so an empty dict certifies the
        job's channels are clear from ``t`` on. O(log n + hits) per
        channel on the sorted interval index; ``t`` must not precede the
        compaction frontier (retired intervals cannot be reconstructed).

        ``wired_extra`` appends *hypothetical* wired intervals in absolute
        time on top of the committed index — the trial-commit feed of
        :func:`replay_commit_order`, which accumulates the wired windows
        earlier jobs of a candidate order would commit without mutating
        the timeline. The simulator sorts seeded intervals itself, so the
        extras need no order. With the default empty extras the answer is
        bit-identical to the two-argument form.
        """
        if t < self.compact_frontier:
            raise RuntimeError(
                f"channel_busy at t={t} precedes the compaction frontier "
                f"{self.compact_frontier}: intervals ending before the "
                "frontier have been retired and cannot be replayed"
            )
        busy: dict[int, list[tuple[float, float]]] = {}
        wired = [(s - t, e - t) for s, e, _ in self._tail(self.wired_intervals, t)]
        for s, e in wired_extra:
            if e > t:
                wired.append((s - t, e - t))
        if wired:
            busy[CH_WIRED] = wired
        for k in range(view.inst.n_wireless):
            phys = int(view.wireless_map[k])
            ivs = [
                (s - t, e - t)
                for s, e, _ in self._tail(self.wireless_intervals[phys], t)
            ]
            if ivs:
                busy[2 + k] = ivs
        return busy

    def arbitrate(
        self,
        view: ResidualView,
        sched: Schedule,
        t: float,
        wired_extra: list[tuple[float, float]] | tuple = (),
    ) -> Schedule:
        """Sequence ``sched`` onto the shared physical channels at ``t``.

        The cross-job arbitration pass: replays the schedule through the
        host simulator with the busy intervals already committed on the
        job's physical channels, keeping the engine's task->rack and
        edge->channel decisions and re-deriving exact start times (the
        job's transfers gap-insert around other jobs'). Deterministic for
        a fixed commit order, and the identity when the job's channels
        carry no committed intervals past ``t`` — so an uncontended
        commit stays bit-for-bit the engine's schedule. ``wired_extra``
        (absolute-time hypothetical wired intervals) is the trial-commit
        hook of :func:`replay_commit_order`; empty by default.
        """
        busy = self.channel_busy(view, t, wired_extra=wired_extra)
        if not busy:
            return sched
        return simulate(view.inst, sched.rack, chan=sched.chan, channel_busy=busy)

    # -- commit --------------------------------------------------------------

    def _insert(
        self,
        label: str,
        intervals: list[tuple[float, float, int]],
        iv: tuple[float, float, int],
    ) -> None:
        """Sorted insert into one resource's interval index, buffering the
        interval for the incremental feasibility audit."""
        bisect.insort(intervals, iv)
        self._audit_backlog.append((label, intervals, iv))

    def commit(
        self,
        view: ResidualView,
        sched: Schedule,
        t: float,
        job_id: int = -1,
        holds_out: list | None = None,
    ) -> float:
        """Place ``sched`` (solved in the residual view's local frame,
        relative time 0) onto the cluster starting at absolute time ``t``.

        Each granted rack the job uses is held until the job's last task
        on it finishes, and each granted wireless subchannel until the
        job's last transfer on it finishes; every transfer's exact window
        is recorded on its physical channel (the wired channel included).
        The caller is responsible for channel feasibility — pass the
        schedule through :meth:`arbitrate` first when the cluster is not
        empty; :meth:`assert_feasible` audits the invariant after the
        fact. ``holds_out``, when given, receives one
        ``("rack" | "wireless", physical_id, hold_time)`` triple per
        resource this commit (re)holds — the delta feed for the service's
        incrementally maintained free sets. Returns the job's absolute
        completion time (``t + makespan``).
        """
        inst = view.inst
        job = inst.job
        dur = inst.duration_on(sched.chan)
        held_w: dict[int, float] = {}
        for i in range(inst.n_racks):
            on_i = sched.rack == i
            if not on_i.any():
                continue
            fin = float(np.max(sched.start[on_i] + job.p[on_i]))
            phys = int(view.rack_map[i])
            self.rack_hold[phys] = max(self.rack_hold[phys], t + fin)
            if holds_out is not None:
                holds_out.append(("rack", phys, self.rack_hold[phys]))
            self.rack_busy_time += float(np.sum(job.p[on_i]))
            for s, p in zip(sched.start[on_i], job.p[on_i]):
                if p > 0:
                    self._insert(
                        f"rack {phys}",
                        self.rack_intervals[phys],
                        (t + float(s), t + float(s) + float(p), job_id),
                    )
        if job.n_edges:
            for e in range(job.n_edges):
                c, d = int(sched.chan[e]), float(dur[e])
                if d <= 0.0:
                    continue  # zero-size transfers occupy nothing
                s = float(sched.tstart[e])
                if c == CH_WIRED:
                    self._insert(
                        "wired channel",
                        self.wired_intervals,
                        (t + s, t + s + d, job_id),
                    )
                    self.wired_busy_time += d
                elif c >= 2:
                    phys = int(view.wireless_map[c - 2])
                    self._insert(
                        f"wireless subchannel {phys}",
                        self.wireless_intervals[phys],
                        (t + s, t + s + d, job_id),
                    )
                    self.wireless_hold[phys] = max(
                        self.wireless_hold[phys], t + s + d
                    )
                    held_w[phys] = self.wireless_hold[phys]
                    self.wireless_busy_time += d
        if holds_out is not None:
            for phys, hold in held_w.items():
                holds_out.append(("wireless", phys, hold))
        completion = t + sched.makespan
        self.last_completion = max(self.last_completion, completion)
        return completion

    # -- compaction ----------------------------------------------------------

    def _indexes(self):
        for i, ivs in enumerate(self.rack_intervals):
            yield f"rack {i}", ivs
        yield "wired channel", self.wired_intervals
        for k, ivs in enumerate(self.wireless_intervals):
            yield f"wireless subchannel {k}", ivs

    @property
    def n_intervals(self) -> int:
        """Committed intervals currently retained in the index (excludes
        the ``n_compacted`` already retired)."""
        return sum(len(ivs) for _label, ivs in self._indexes())

    def compact(self, t: float) -> int:
        """Retire every committed interval ending at or before ``t`` from
        the interval index; returns how many were retired.

        Safe whenever ``t`` does not exceed the current epoch: epochs are
        monotone and every later ``channel_busy`` / ``arbitrate`` query
        drops intervals ending at or before its (later) epoch anyway, so
        compaction changes no observable answer — busy-time accumulators
        were charged at commit and holds are untouched. The pending audit
        backlog is flushed first (:meth:`assert_feasible`), so no interval
        is retired unaudited.
        """
        self.assert_feasible()
        t = float(t)
        dropped = 0
        for _label, ivs in self._indexes():
            i = bisect.bisect_right(ivs, t, key=_END)
            if i:
                del ivs[:i]
                dropped += i
        self.n_compacted += dropped
        self.compact_frontier = max(self.compact_frontier, t)
        if self.tracer.enabled:
            self.tracer.event(
                "timeline_compact",
                t=t,
                dropped=dropped,
                retained=self.n_intervals,
            )
            self.tracer.count("intervals_compacted", dropped)
        return dropped

    # -- feasibility audit ---------------------------------------------------

    def assert_feasible(self, tol: float = _EPS, full: bool = False) -> None:
        """Audit the committed timeline: no two committed operations may
        overlap on the same physical resource — tasks on a rack, transfers
        on the wired channel, transfers on one wireless subchannel —
        regardless of which jobs they belong to. Raises ``AssertionError``
        (a real raise, alive under ``python -O``) naming the resource and
        the two owning jobs on the first overlap.

        Incremental by default: only intervals committed since the last
        audit are checked, each against its sorted neighbors in the index
        (disjointness of adjacent pairs is equivalent to global
        disjointness on a start-sorted index). ``full=True`` rescans every
        *retained* interval from scratch — intervals already retired by
        :meth:`compact` were audited before retirement.
        """
        if full:
            self._audit_backlog.clear()
            if self.tracer.enabled:
                self.tracer.event(
                    "timeline_audit", n_checked=self.n_intervals, full=True
                )
            for label, ivs in self._indexes():
                ordered = sorted(ivs)
                for (s0, e0, j0), (s1, _e1, j1) in zip(ordered, ordered[1:]):
                    if s1 < e0 - tol:
                        raise AssertionError(
                            f"{label}: committed intervals of job {j0} "
                            f"[{s0}, {e0}) and job {j1} [{s1}, ...) overlap"
                        )
            return
        backlog, self._audit_backlog = self._audit_backlog, []
        if self.tracer.enabled and backlog:
            self.tracer.event("timeline_audit", n_checked=len(backlog))
            self.tracer.count("intervals_audited", len(backlog))
        for label, ivs, iv in backlog:
            pos = bisect.bisect_left(ivs, iv)
            s, e, j = iv
            if pos > 0:
                s0, e0, j0 = ivs[pos - 1]
                if s < e0 - tol:
                    raise AssertionError(
                        f"{label}: committed intervals of job {j0} "
                        f"[{s0}, {e0}) and job {j} [{s}, ...) overlap"
                    )
            if pos + 1 < len(ivs):
                s1, _e1, j1 = ivs[pos + 1]
                if s1 < e - tol:
                    raise AssertionError(
                        f"{label}: committed intervals of job {j} "
                        f"[{s}, {e}) and job {j1} [{s1}, ...) overlap"
                    )

    # -- metrics -------------------------------------------------------------

    def utilization(self, horizon: float) -> dict[str, float]:
        """Busy-time fractions over ``[0, horizon]``. All three figures are
        exact under the channel-feasible commit model (compaction never
        touches the accumulators) and guaranteed to be true fractions in
        [0, 1]; a fraction outside the float-noise band raises
        ``RuntimeError`` — a real raise, NOT an ``assert``, so the audit
        survives ``python -O`` stripping."""
        if horizon <= 0.0:
            return {"rack": 0.0, "wired": 0.0, "wireless": 0.0}
        util = {
            "rack": self.rack_busy_time / (self.n_racks * horizon),
            "wired": self.wired_busy_time / horizon,
            "wireless": (
                self.wireless_busy_time / (self.n_wireless * horizon)
                if self.n_wireless
                else 0.0
            ),
        }
        for name, frac in util.items():
            if not (-1e-12 <= frac <= 1.0 + 1e-9):
                raise RuntimeError(
                    f"{name} utilization {frac} outside [0, 1]: committed "
                    "timeline is not channel-feasible"
                )
        return {name: min(max(frac, 0.0), 1.0) for name, frac in util.items()}


# -- commit-order replay ------------------------------------------------------
#
# Within one admission epoch the only *shared* resource is the wired
# channel: co-admitted jobs draw disjoint rack and subchannel grants from
# shrinking pools, and every subchannel a job can touch already carries its
# committed intervals in the index (interval-aware grants included). So a
# candidate commit order can be trial-run exactly by accumulating only the
# wired windows earlier trial jobs would commit and feeding them to
# ``arbitrate`` via ``wired_extra`` — no timeline mutation, bit-identical
# to really committing in that order. These helpers are the evaluation side
# of the arbitration-order search in :mod:`repro.core.coflow`.


def wired_windows(
    view: ResidualView, sched: Schedule, t: float
) -> list[tuple[float, float]]:
    """Absolute-time wired-channel transfer windows one commit would add
    (exactly the intervals :meth:`ClusterTimeline.commit` inserts on the
    wired index; zero-size transfers occupy nothing)."""
    inst = view.inst
    if not inst.job.n_edges:
        return []
    dur = inst.duration_on(sched.chan)
    out = []
    for e in range(inst.job.n_edges):
        d = float(dur[e])
        if d > 0.0 and int(sched.chan[e]) == CH_WIRED:
            s = t + float(sched.tstart[e])
            out.append((s, s + d))
    return out


def channel_delay_attribution(
    view: ResidualView, sched: Schedule, placed: Schedule
) -> tuple[float, float]:
    """Split one job's cross-job channel queueing by resource.

    ``placed`` is ``sched`` after :meth:`ClusterTimeline.arbitrate`
    gap-inserted its transfers around other jobs' committed windows;
    arbitration keeps the task->rack and edge->channel decisions, so the
    per-edge start-time slips ``placed.tstart - sched.tstart`` are
    exactly the waiting the shared channels imposed. Returns
    ``(wired_seconds, wireless_seconds)`` — the queueing attribution the
    trace's job-completion marks carry (an uncontended commit returns
    ``(0, 0)`` since arbitrate is the identity there).
    """
    if placed is sched or not view.inst.job.n_edges:
        return 0.0, 0.0
    wired = wireless = 0.0
    for e in range(view.inst.job.n_edges):
        d = float(placed.tstart[e]) - float(sched.tstart[e])
        if d <= 0.0:
            continue
        c = int(placed.chan[e])
        if c == CH_WIRED:
            wired += d
        elif c >= 2:
            wireless += d
    return wired, wireless


def job_holds(
    view: ResidualView, sched: Schedule, t: float
) -> tuple[dict[int, float], dict[int, float]]:
    """Per-physical-resource hold times one commit would take: a
    ``(rack_holds, wireless_holds)`` pair mapping physical id to the
    absolute release time, mirroring :meth:`ClusterTimeline.commit`'s
    hold updates (callers ``max`` them into existing holds)."""
    inst = view.inst
    job = inst.job
    rack_holds: dict[int, float] = {}
    wireless_holds: dict[int, float] = {}
    for i in range(inst.n_racks):
        on_i = sched.rack == i
        if not on_i.any():
            continue
        fin = float(np.max(sched.start[on_i] + job.p[on_i]))
        rack_holds[int(view.rack_map[i])] = t + fin
    if job.n_edges:
        dur = inst.duration_on(sched.chan)
        for e in range(job.n_edges):
            c, d = int(sched.chan[e]), float(dur[e])
            if d <= 0.0 or c < 2:
                continue
            phys = int(view.wireless_map[c - 2])
            end = t + float(sched.tstart[e]) + d
            if end > wireless_holds.get(phys, -np.inf):
                wireless_holds[phys] = end
    return rack_holds, wireless_holds


def reservation_backfill_safe(
    rack_hold: np.ndarray,
    wireless_hold: np.ndarray,
    n_racks_granted: int,
    n_wireless_granted: int,
    completion: float,
    t: float,
    hol_need: tuple[int, int],
) -> bool:
    """Prove (or refuse) that a backfill commit cannot delay the blocked
    head-of-line job's admission epoch, from the hold vectors alone.

    The head job's *reservation* is the earliest time its needed racks and
    subchannels can all be free given ``rack_hold`` / ``wireless_hold``.
    The commit is safe when either the candidate's post-arbitration
    ``completion`` lands at or before the reservation (every hold a job
    takes is released by its completion, so everything the candidate
    touches is free again in time), or — shadow slack — the reservation
    time keeps enough free racks/subchannels for the head job even with
    the candidate's grant removed for good. Pure function of the hold
    vectors so the service's live commits and
    :func:`replay_commit_order`'s trial commits run the *same* proof
    (the service method delegates here).
    """
    need_r, need_w = hol_need
    t_res = max(t, float(np.sort(rack_hold)[need_r - 1]))
    if need_w:
        t_res = max(t_res, float(np.sort(wireless_hold)[need_w - 1]))
    if completion <= t_res:
        return True
    free_r = int(np.sum(rack_hold <= t_res))
    if free_r - n_racks_granted < need_r:
        return False
    if need_w:
        free_w = int(np.sum(wireless_hold <= t_res))
        if free_w - n_wireless_granted < need_w:
            return False
    return True


@dataclasses.dataclass(frozen=True)
class OrderReplay:
    """Outcome of trial-committing one epoch batch in one candidate order.

    ``placed`` / ``completions`` are indexed by *batch position* (not
    commit rank); a position is ``None`` when the trial's backfill proof
    rejected that candidate (it would stay queued). ``objective`` is the
    lexicographic figure the order search minimizes: reject as few
    backfill candidates as possible, then minimize the batch's total
    arrival-to-completion time. ``n_deadline_missed`` counts trial
    commits whose completion overran the job's deadline (positions with
    ``deadlines[pos] is None`` and rejected positions never count); the
    admission oracle in ``tests/test_admission.py`` compares candidate
    admission orders on it.
    """

    order: tuple[int, ...]
    placed: list
    completions: list
    n_rejected: int
    total_jct: float
    n_deadline_missed: int = 0

    @property
    def objective(self) -> tuple[int, float]:
        return (self.n_rejected, self.total_jct)


def replay_commit_order(
    cluster: ClusterTimeline,
    t: float,
    views: list[ResidualView],
    order,
    *,
    scheds: list[Schedule] | None = None,
    solver=None,
    arrivals: list[float] | None = None,
    is_backfill: list[bool] | None = None,
    hol_need: tuple[int, int] | None = None,
    deadlines: "list[float | None] | None" = None,
) -> OrderReplay:
    """Trial-run one commit permutation of an epoch batch, mutating nothing.

    Mirrors the service's commit loop exactly: jobs are arbitrated in
    ``order`` (each seeing the wired windows of every earlier trial
    commit via ``wired_extra``), and backfill candidates run the same
    reservation/shadow-slack proof on trial copies of the hold vectors —
    so really committing in ``order`` afterwards produces bit-identical
    schedules, completions, and backfill decisions.

    Exactly one of ``scheds`` (pre-solved schedules, the fleet policy) or
    ``solver`` (``solver(view, busy) -> Schedule``, lazy baselines whose
    placement depends on the busy intervals seen) must be given.
    ``arrivals`` (defaults to ``t``) weight each job's completion into
    ``total_jct``; ``deadlines`` (per batch position, ``None`` entries =
    best-effort) feeds :attr:`OrderReplay.n_deadline_missed`.
    """
    n = len(views)
    if (scheds is None) == (solver is None):
        raise ValueError("pass exactly one of scheds= or solver=")
    order = tuple(int(i) for i in order)
    if sorted(order) != list(range(n)):
        raise ValueError(f"order {order} is not a permutation of range({n})")
    arr = [float(t)] * n if arrivals is None else [float(a) for a in arrivals]
    bf = [False] * n if is_backfill is None else list(is_backfill)
    need_holds = any(bf)
    rack_hold = cluster.rack_hold.copy() if need_holds else None
    wireless_hold = cluster.wireless_hold.copy() if need_holds else None
    wired_extra: list[tuple[float, float]] = []
    ddl = [None] * n if deadlines is None else list(deadlines)
    if len(ddl) != n:
        raise ValueError("deadlines must match views in length")
    placed_out: list = [None] * n
    completions: list = [None] * n
    n_rejected = 0
    n_deadline_missed = 0
    total_jct = 0.0
    for pos in order:
        view = views[pos]
        if solver is not None:
            busy = cluster.channel_busy(view, t, wired_extra=wired_extra)
            placed = solver(view, busy)
        else:
            placed = cluster.arbitrate(view, scheds[pos], t, wired_extra=wired_extra)
        comp = t + float(placed.makespan)
        if bf[pos] and not reservation_backfill_safe(
            rack_hold,
            wireless_hold,
            view.inst.n_racks,
            view.inst.n_wireless,
            comp,
            t,
            hol_need,
        ):
            n_rejected += 1
            continue
        placed_out[pos] = placed
        completions[pos] = comp
        total_jct += comp - arr[pos]
        if ddl[pos] is not None and comp > ddl[pos]:
            n_deadline_missed += 1
        wired_extra.extend(wired_windows(view, placed, t))
        if need_holds:
            r_holds, w_holds = job_holds(view, placed, t)
            for phys, h in r_holds.items():
                if h > rack_hold[phys]:
                    rack_hold[phys] = h
            for phys, h in w_holds.items():
                if h > wireless_hold[phys]:
                    wireless_hold[phys] = h
    return OrderReplay(
        order, placed_out, completions, n_rejected, total_jct, n_deadline_missed
    )
