"""Per-job and aggregate metrics for the online scheduling service.

The figures of merit of the paper's production claim (§V, ~10% JCT
reduction) are *arrival-to-completion* job completion times, not solver
makespans: a job's JCT includes the time it queued for resources. This
module defines the per-job record (:class:`JobMetrics`) and the aggregate
(:class:`OnlineResult`) the service returns — mean/percentile JCT,
queueing delay, cluster utilization, service makespan, and the scheduler
throughput / candidate counters used by the serving benchmarks.
"""

from __future__ import annotations

import dataclasses
import typing

import numpy as np

if typing.TYPE_CHECKING:  # pragma: no cover - type-only import
    from repro.online.cluster import ClusterTimeline

__all__ = ["JobMetrics", "OnlineResult"]


@dataclasses.dataclass(frozen=True)
class JobMetrics:
    """Lifecycle record of one served job.

    Attributes:
      job_id: stream position (matches the :class:`ArrivalEvent`).
      family: workload family tag.
      arrival: absolute arrival time.
      admitted: absolute admission epoch (start of execution).
      completion: absolute completion time.
      makespan: the committed (channel-arbitrated) schedule's makespan —
        the job's true execution time on the shared cluster, so
        ``completion == admitted + makespan`` always.
      n_racks_granted / n_wireless_granted: residual shape the job ran on
        (may be below its demand under contention).
      n_solves: solver invocations for this job (1 + re-optimizations
        while queued; 1 for baseline policies).
      solver_makespan: the served schedule's makespan as the solver saw it
        (private resource view, before cross-job arbitration); the gap
        ``makespan - solver_makespan`` is the job's cross-job channel
        queueing.
      backfilled: True when the job overtook a blocked head-of-line job
        under the service's backfilling admission mode.
      assignment: int64[n_tasks] committed task->rack assignment in
        *physical* rack ids (the residual view's local labels mapped
        through its rack grant).
    """

    job_id: int
    family: str
    arrival: float
    admitted: float
    completion: float
    makespan: float
    n_racks_granted: int
    n_wireless_granted: int
    n_solves: int
    solver_makespan: float = float("nan")
    backfilled: bool = False
    assignment: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64)
    )

    @property
    def queueing_delay(self) -> float:
        """Time spent waiting for admission (``admitted - arrival``)."""
        return self.admitted - self.arrival

    @property
    def jct(self) -> float:
        """Arrival-to-completion time (``completion - arrival``)."""
        return self.completion - self.arrival


@dataclasses.dataclass
class OnlineResult:
    """Outcome of serving one arrival stream.

    Attributes:
      jobs: one :class:`JobMetrics` per served job, in ``job_id`` order.
      policy: scheduling policy name (``"fleet"`` or an online baseline).
      warm_start: whether queued-job re-optimization was warm-started.
      n_epochs: admission epochs the event loop processed.
      n_batches: ``schedule_fleet`` mega-batch launches (0 for baselines).
      n_solves: solver invocations summed over jobs (admission solves plus
        planning re-optimizations of queued jobs).
      n_candidates / n_pruned: fleet-engine candidate counters summed over
        every solve (0 for baseline policies).
      solver_wall: wall-clock seconds spent inside the per-epoch solvers.
      horizon: last completion time (the service makespan).
      rack_utilization / wired_utilization / wireless_utilization:
        busy-time fractions of the cluster over ``[0, horizon]``; all
        three are true fractions in [0, 1] under channel-feasible commits.
      n_backfilled: jobs admitted by overtaking a blocked head-of-line job
        (0 unless the service runs with ``backfill=True``).
      n_backfill_rejected: overtake candidates whose commit was refused
        because arbitration could not prove them harmless (their
        post-arbitration completion overran the head-of-line
        reservation); each rejection left the candidate queued.
      timeline: the committed :class:`~repro.online.cluster
        .ClusterTimeline` (audited feasible by the service before it
        returns) — kept for post-hoc inspection and the test-suite
        feasibility audit.
    """

    jobs: list[JobMetrics]
    policy: str
    warm_start: bool
    n_epochs: int
    n_batches: int
    n_solves: int
    n_candidates: int
    n_pruned: int
    solver_wall: float
    horizon: float
    rack_utilization: float
    wired_utilization: float
    wireless_utilization: float
    n_backfilled: int = 0
    n_backfill_rejected: int = 0
    timeline: "ClusterTimeline | None" = None

    @property
    def jcts(self) -> np.ndarray:
        return np.asarray([j.jct for j in self.jobs], dtype=np.float64)

    @property
    def queueing_delays(self) -> np.ndarray:
        return np.asarray([j.queueing_delay for j in self.jobs], dtype=np.float64)

    @property
    def mean_jct(self) -> float:
        return float(self.jcts.mean()) if self.jobs else 0.0

    @property
    def p95_jct(self) -> float:
        return float(np.percentile(self.jcts, 95)) if self.jobs else 0.0

    @property
    def mean_queueing_delay(self) -> float:
        return float(self.queueing_delays.mean()) if self.jobs else 0.0

    @property
    def makespan(self) -> float:
        """Service makespan: last completion (== ``horizon``)."""
        return self.horizon

    @property
    def jobs_per_solver_second(self) -> float:
        """Scheduler throughput: served jobs per second of solver wall time.

        A zero-cost policy (e.g. a heuristic baseline whose per-job wall
        time is below timer resolution) has *infinite* throughput, not
        zero — returned as ``inf`` so benchmark tables sort it above, not
        below, every engine configuration. An empty result is 0.0.
        """
        if self.solver_wall > 0:
            return len(self.jobs) / self.solver_wall
        return float("inf") if self.jobs else 0.0

    def summary(self) -> str:
        """One-line human summary (used by the example and benchmarks)."""
        jps = self.jobs_per_solver_second
        jps_s = f"{jps:.2f}" if np.isfinite(jps) else "inf"
        return (
            f"policy={self.policy} warm={self.warm_start} jobs={len(self.jobs)} "
            f"mean_jct={self.mean_jct:.1f} p95_jct={self.p95_jct:.1f} "
            f"mean_queue={self.mean_queueing_delay:.1f} "
            f"makespan={self.makespan:.1f} "
            f"util(rack/wired/wireless)="
            f"{self.rack_utilization:.2f}/{self.wired_utilization:.2f}/"
            f"{self.wireless_utilization:.2f} "
            f"epochs={self.n_epochs} solves={self.n_solves} "
            f"backfilled={self.n_backfilled} "
            f"pruned={self.n_pruned}/{self.n_candidates} "
            f"jobs_per_solver_s={jps_s} solver_wall={self.solver_wall:.2f}s"
        )
