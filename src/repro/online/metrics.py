"""Per-job and aggregate metrics for the online scheduling service.

The figures of merit of the paper's production claim (§V, ~10% JCT
reduction) are *arrival-to-completion* job completion times, not solver
makespans: a job's JCT includes the time it queued for resources. This
module defines the per-job record (:class:`JobMetrics`), the aggregate
(:class:`OnlineResult`) the service returns — mean/percentile JCT,
queueing delay, cluster utilization, service makespan, and the scheduler
throughput / candidate counters used by the serving benchmarks — and
:class:`StreamingSeries`, the O(1)-memory quantile sketch the service
feeds per completion so 100k-job runs never materialize a JCT array.
"""

from __future__ import annotations

import dataclasses
import typing

import numpy as np

if typing.TYPE_CHECKING:  # pragma: no cover - type-only import
    from repro.online.cluster import ClusterTimeline

__all__ = ["JobMetrics", "OnlineResult", "StreamingSeries"]


class _P2Quantile:
    """Jain & Chlamtac's P-squared estimator for one quantile.

    Five markers track (min, two intermediates, the target quantile, max);
    each observation shifts marker positions and parabolically adjusts the
    heights, so the estimate is O(1) memory and O(1) per observation.
    Callers must seed it with exactly five observations (any order).
    """

    __slots__ = ("p", "q", "n", "np_", "dn")

    def __init__(self, p: float, first5: typing.Sequence[float]):
        if len(first5) != 5:
            raise ValueError("P2 estimator must be seeded with 5 samples")
        self.p = float(p)
        self.q = sorted(float(x) for x in first5)
        self.n = [0.0, 1.0, 2.0, 3.0, 4.0]
        self.np_ = [0.0, 2 * p, 4 * p, 2 + 2 * p, 4.0]
        self.dn = [0.0, p / 2, p, (1 + p) / 2, 1.0]

    def add(self, x: float) -> None:
        q, n = self.q, self.n
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            while x >= q[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            n[i] += 1.0
        for i in range(5):
            self.np_[i] += self.dn[i]
        for i in (1, 2, 3):
            d = self.np_[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1.0) or (
                d <= -1.0 and n[i - 1] - n[i] < -1.0
            ):
                d = 1.0 if d > 0 else -1.0
                qp = self._parabolic(i, d)
                if not q[i - 1] < qp < q[i + 1]:
                    qp = self._linear(i, d)
                q[i] = qp
                n[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        q, n = self.q, self.n
        return q[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1])
        )

    def _linear(self, i: int, d: float) -> float:
        j = i + int(d)
        return self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])

    @property
    def value(self) -> float:
        return self.q[2]


class StreamingSeries:
    """Streaming scalar summary: count/mean/min/max plus quantile sketches.

    Exact while small, sketched at scale: the first ``exact_max``
    observations are buffered and quantiles answered exactly
    (``np.percentile`` semantics); past that the buffer is replayed into
    one P-squared estimator per tracked quantile and dropped, after which
    memory is O(1) regardless of stream length. The replay preserves
    arrival order, so the sketch state is identical to having streamed
    from the first observation.
    """

    __slots__ = ("quantiles", "count", "_sum", "_min", "_max", "_exact",
                 "_exact_max", "_sketches")

    # p95 rides along so OnlineResult.p95_jct stays answerable at scale.
    DEFAULT_QUANTILES = (0.50, 0.90, 0.95, 0.99)

    def __init__(
        self,
        quantiles: typing.Sequence[float] = DEFAULT_QUANTILES,
        *,
        exact_max: int = 64,
    ):
        if exact_max < 5:
            raise ValueError("exact_max must be >= 5 to seed the sketches")
        for p in quantiles:
            if not 0.0 < p < 1.0:
                raise ValueError(f"quantile {p} not in (0, 1)")
        self.quantiles = tuple(float(p) for p in quantiles)
        self.count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._exact: list[float] | None = []
        self._exact_max = int(exact_max)
        self._sketches: dict[float, _P2Quantile] | None = None

    def push(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self._sum += x
        if x < self._min:
            self._min = x
        if x > self._max:
            self._max = x
        if self._exact is not None:
            self._exact.append(x)
            if len(self._exact) > self._exact_max:
                buf, self._exact = self._exact, None
                self._sketches = {
                    p: _P2Quantile(p, buf[:5]) for p in self.quantiles
                }
                for v in buf[5:]:
                    for sk in self._sketches.values():
                        sk.add(v)
        else:
            assert self._sketches is not None
            for sk in self._sketches.values():
                sk.add(x)

    # Zero-sample semantics: every statistic of an empty stream is NaN,
    # not 0.0 — a serve with no completions has *no* p99, and rendering
    # it as 0 would read as "instant". Renderers (OnlineResult.summary,
    # the Prometheus exposition) detect NaN and print "n/a" / omit the
    # quantile lines instead.

    @property
    def mean(self) -> float:
        return self._sum / self.count if self.count else float("nan")

    @property
    def max(self) -> float:
        return self._max if self.count else float("nan")

    @property
    def min(self) -> float:
        return self._min if self.count else float("nan")

    def quantile(self, p: float) -> float:
        """Estimated ``p``-quantile (exact while the buffer is alive).

        NaN when no samples have been observed (see class note above).
        """
        if not self.count:
            return float("nan")
        if self._exact is not None:
            return float(np.percentile(self._exact, 100.0 * p))
        sketches = self._sketches
        assert sketches is not None
        if p not in sketches:
            raise KeyError(
                f"quantile {p} not tracked (tracked: {self.quantiles}); "
                "construct the series with it in `quantiles`"
            )
        # P² safety clamp. Right after the exact->sketch switch the
        # estimator has seen only a handful of post-seed samples, and the
        # parabolic marker adjustment can place the target marker anywhere
        # between its neighbors — for extreme quantiles that is a poor
        # (though finite) estimate; with non-finite inputs the marker
        # heights can be poisoned into NaN outright. Any quantile of the
        # observed stream lies in [min, max] by definition, so clamp the
        # sketch value into the exact observed range and fall back to the
        # nearest observed extreme when the sketch state is not finite —
        # percentile accessors then never return NaN or an out-of-range
        # value, no matter how few samples arrived past the boundary.
        v = float(sketches[p].value)
        if not np.isfinite(v):
            v = self._max if p >= 0.5 else self._min
        return float(min(max(v, self._min), self._max))

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p90(self) -> float:
        return self.quantile(0.90)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        mode = "exact" if self._exact is not None else "p2"
        return (
            f"StreamingSeries(n={self.count}, mean={self.mean:.3g}, "
            f"p50={self.p50:.3g}, p90={self.p90:.3g}, p99={self.p99:.3g}, "
            f"mode={mode})"
        )


@dataclasses.dataclass(frozen=True)
class JobMetrics:
    """Lifecycle record of one served job.

    Attributes:
      job_id: stream position (matches the :class:`ArrivalEvent`).
      family: workload family tag.
      arrival: absolute arrival time.
      admitted: absolute admission epoch (start of execution).
      completion: absolute completion time.
      makespan: the committed (channel-arbitrated) schedule's makespan —
        the job's true execution time on the shared cluster, so
        ``completion == admitted + makespan`` always.
      n_racks_granted / n_wireless_granted: residual shape the job ran on
        (may be below its demand under contention).
      n_solves: solver invocations for this job (1 + re-optimizations
        while queued; 1 for baseline policies).
      solver_makespan: the served schedule's makespan as the solver saw it
        (private resource view, before cross-job arbitration); the gap
        ``makespan - solver_makespan`` is the job's cross-job channel
        queueing.
      backfilled: True when the job overtook a blocked head-of-line job
        under the service's backfilling admission mode.
      assignment: int64[n_tasks] committed task->rack assignment in
        *physical* rack ids (the residual view's local labels mapped
        through its rack grant).
      deadline / tenant / tier: SLO metadata copied from the
        :class:`~repro.online.workload.ArrivalEvent` (``None`` for
        untiered streams).
      n_overtaken: admissions of *later-arriving* jobs that jumped ahead
        of this job while it queued (non-FIFO admission orders and
        backfilling both count); bounded by the service's
        ``max_overtakes`` knob when set.
    """

    job_id: int
    family: str
    arrival: float
    admitted: float
    completion: float
    makespan: float
    n_racks_granted: int
    n_wireless_granted: int
    n_solves: int
    solver_makespan: float = float("nan")
    backfilled: bool = False
    assignment: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64)
    )
    deadline: float | None = None
    tenant: str | None = None
    tier: str | None = None
    n_overtaken: int = 0

    @property
    def queueing_delay(self) -> float:
        """Time spent waiting for admission (``admitted - arrival``)."""
        return self.admitted - self.arrival

    @property
    def jct(self) -> float:
        """Arrival-to-completion time (``completion - arrival``)."""
        return self.completion - self.arrival

    @property
    def deadline_missed(self) -> bool:
        """True when the job had a deadline and completed after it."""
        return self.deadline is not None and self.completion > self.deadline


@dataclasses.dataclass
class OnlineResult:
    """Outcome of serving one arrival stream.

    Attributes:
      jobs: one :class:`JobMetrics` per served job, in ``job_id`` order.
      policy: scheduling policy name (``"fleet"`` or an online baseline).
      warm_start: whether queued-job re-optimization was warm-started.
      n_epochs: admission epochs the event loop processed.
      n_batches: ``schedule_fleet`` mega-batch launches (0 for baselines).
      n_solves: solver invocations summed over jobs (admission solves plus
        planning re-optimizations of queued jobs).
      n_candidates / n_pruned: fleet-engine candidate counters summed over
        every solve (0 for baseline policies).
      solver_wall: wall-clock seconds spent inside the per-epoch solvers.
      horizon: last completion time (the service makespan).
      rack_utilization / wired_utilization / wireless_utilization:
        busy-time fractions of the cluster over ``[0, horizon]``; all
        three are true fractions in [0, 1] under channel-feasible commits.
      n_backfilled: jobs admitted by overtaking a blocked head-of-line job
        (0 unless the service runs with ``backfill=True``).
      n_backfill_rejected: overtake candidates whose commit was refused
        because arbitration could not prove them harmless (their
        post-arbitration completion overran the head-of-line
        reservation); each rejection left the candidate queued.
      timeline: the committed :class:`~repro.online.cluster
        .ClusterTimeline` (audited feasible by the service before it
        returns) — kept for post-hoc inspection and the test-suite
        feasibility audit.
      queue_stats / jct_stats: per-completion :class:`StreamingSeries`
        over queueing delays and JCTs (``None`` when the result was built
        without streaming stats, e.g. hand-constructed in tests); the
        percentile properties below fall back to the ``jobs`` list.
      peak_active: maximum number of jobs executing concurrently.
      peak_queue_depth: maximum number of jobs queued (arrived, not yet
        admitted) at any epoch.
      n_served: jobs served — equals ``len(jobs)`` unless the service ran
        with ``record_jobs=False``, in which case ``jobs`` is empty and
        this counter is the only cardinality record.
      epoch_commit_latency: per-epoch wall seconds of the
        arbitrate-and-commit stage (populated only under
        ``track_epoch_latency=True``; the stress lane's flat-latency
        check reads it).
      arbitration: cross-job commit-order policy the service ran
        (``"fifo"`` / ``"sigma"`` / ``"search"``).
      n_order_evals: unique commit orders trial-replayed by the
        arbitration-order search across all epochs (0 under FIFO).
      n_epochs_reordered: epochs whose committed order differed from
        queue order.
      arbitration_gain: summed per-epoch replayed total-JCT delta of the
        committed order vs FIFO (positive = the reordering improved the
        batch; sigma commits its order unconditionally, so its gain can
        go negative).
      admission: queue-ordering policy the service ran (``"fifo"`` /
        ``"edf"`` / ``"wfair"``).
      n_deadline_jobs: served jobs that carried a deadline.
      n_deadline_missed: served deadline jobs that completed after it.
      n_deadline_deferrals: commits postponed by ``admission_control=
        "defer"`` because the replayed trial proved the post-arbitration
        completion would overrun the deadline (each deferral left the job
        queued for a later epoch).
      n_deadline_rejected: jobs dropped by ``admission_control="reject"``
        on the rigorous lower-bound proof ``now + lower_bound(inst) >
        deadline`` (never served; ids in ``rejected_job_ids``, no
        :class:`JobMetrics` row, excluded from JCT aggregates).
      rejected_job_ids: stream ids of the rejected jobs, in rejection
        order.
      tier_slo: per-tier ``(n_met, n_deadline_jobs)`` pairs over served
        deadline-carrying jobs (see :attr:`slo_attainment`).
      tenant_queue_stats: per-tenant :class:`StreamingSeries` of queueing
        delays (feeds :attr:`tenant_p99_queueing_delay`).
      max_overtakes_observed: largest per-job overtake count; when the
        service ran with a ``max_overtakes`` bound this is asserted
        ``<= max_overtakes`` before ``serve`` returns.
      n_reconfigs: wireless subchannels reconfigured by the per-epoch
        matching (0 unless the service ran with ``topology="matching"``).
      n_link_events: link outage/repair events applied from the outage
        trace (0 without one).
    """

    jobs: list[JobMetrics]
    policy: str
    warm_start: bool
    n_epochs: int
    n_batches: int
    n_solves: int
    n_candidates: int
    n_pruned: int
    solver_wall: float
    horizon: float
    rack_utilization: float
    wired_utilization: float
    wireless_utilization: float
    n_backfilled: int = 0
    n_backfill_rejected: int = 0
    timeline: "ClusterTimeline | None" = None
    queue_stats: StreamingSeries | None = None
    jct_stats: StreamingSeries | None = None
    peak_active: int = 0
    peak_queue_depth: int = 0
    n_served: int = 0
    epoch_commit_latency: "list[float] | None" = None
    arbitration: str = "fifo"
    n_order_evals: int = 0
    n_epochs_reordered: int = 0
    arbitration_gain: float = 0.0
    admission: str = "fifo"
    n_deadline_jobs: int = 0
    n_deadline_missed: int = 0
    n_deadline_deferrals: int = 0
    n_deadline_rejected: int = 0
    rejected_job_ids: list[int] = dataclasses.field(default_factory=list)
    tier_slo: "dict[str, tuple[int, int]]" = dataclasses.field(
        default_factory=dict
    )
    tenant_queue_stats: "dict[str, StreamingSeries]" = dataclasses.field(
        default_factory=dict
    )
    max_overtakes_observed: int = 0
    n_reconfigs: int = 0
    n_link_events: int = 0

    @property
    def slo_attainment(self) -> "dict[str, float]":
        """Per-tier fraction of deadline-carrying jobs that met their SLO.

        Tiers with no deadline-carrying served jobs (e.g. best-effort
        tiers) are omitted rather than reported as 0 or 1.
        """
        return {
            tier: met / total
            for tier, (met, total) in sorted(self.tier_slo.items())
            if total
        }

    @property
    def tenant_p99_queueing_delay(self) -> "dict[str, float]":
        """Per-tenant p99 queueing delay (from the streaming sketches)."""
        return {
            tenant: s.p99
            for tenant, s in sorted(self.tenant_queue_stats.items())
            if s.count
        }

    @property
    def jcts(self) -> np.ndarray:
        return np.asarray([j.jct for j in self.jobs], dtype=np.float64)

    @property
    def queueing_delays(self) -> np.ndarray:
        return np.asarray([j.queueing_delay for j in self.jobs], dtype=np.float64)

    # Empty-serve semantics mirror StreamingSeries: a result with no
    # served jobs has NaN aggregates (there is no mean JCT of nothing),
    # and summary() renders them as "n/a".

    @property
    def mean_jct(self) -> float:
        if self.jobs:
            return float(self.jcts.mean())
        return self.jct_stats.mean if self.jct_stats is not None else float("nan")

    @property
    def p95_jct(self) -> float:
        if self.jobs:
            return float(np.percentile(self.jcts, 95))
        if self.jct_stats is not None:
            return self.jct_stats.quantile(0.95)
        return float("nan")

    @property
    def mean_queueing_delay(self) -> float:
        if self.jobs:
            return float(self.queueing_delays.mean())
        return (
            self.queue_stats.mean
            if self.queue_stats is not None
            else float("nan")
        )

    @property
    def makespan(self) -> float:
        """Service makespan: last completion (== ``horizon``)."""
        return self.horizon

    @property
    def n_jobs(self) -> int:
        """Served-job count, valid even when per-job records were elided."""
        return max(len(self.jobs), self.n_served)

    def _quantile(self, stats: StreamingSeries | None, values, p: float) -> float:
        if stats is not None and stats.count:
            return stats.quantile(p)
        if len(values):
            return float(np.percentile(values, 100.0 * p))
        return float("nan")

    @property
    def p50_queueing_delay(self) -> float:
        return self._quantile(self.queue_stats, self.queueing_delays, 0.50)

    @property
    def p90_queueing_delay(self) -> float:
        return self._quantile(self.queue_stats, self.queueing_delays, 0.90)

    @property
    def p99_queueing_delay(self) -> float:
        return self._quantile(self.queue_stats, self.queueing_delays, 0.99)

    @property
    def p50_jct(self) -> float:
        return self._quantile(self.jct_stats, self.jcts, 0.50)

    @property
    def p90_jct(self) -> float:
        return self._quantile(self.jct_stats, self.jcts, 0.90)

    @property
    def p99_jct(self) -> float:
        return self._quantile(self.jct_stats, self.jcts, 0.99)

    @property
    def jobs_per_solver_second(self) -> float:
        """Scheduler throughput: served jobs per second of solver wall time.

        A zero-cost policy (e.g. a heuristic baseline whose per-job wall
        time is below timer resolution) has *infinite* throughput, not
        zero — returned as ``inf`` so benchmark tables sort it above, not
        below, every engine configuration. An empty result is 0.0.
        """
        if self.solver_wall > 0:
            return len(self.jobs) / self.solver_wall
        return float("inf") if self.jobs else 0.0

    def summary(self) -> str:
        """One-line human summary (used by the example and benchmarks).

        NaN aggregates (empty serve: 0 arrivals or an all-rejected
        stream) render as ``n/a`` rather than ``nan``/``0.0``.
        """

        def f1(v: float) -> str:
            return f"{v:.1f}" if np.isfinite(v) else "n/a"

        jps = self.jobs_per_solver_second
        jps_s = f"{jps:.2f}" if np.isfinite(jps) else "inf"
        arb = (
            f"arb={self.arbitration} reordered={self.n_epochs_reordered} "
            f"gain={self.arbitration_gain:.1f} "
            if self.arbitration != "fifo"
            else ""
        )
        adm = ""
        if (
            self.admission != "fifo"
            or self.n_deadline_jobs
            or self.n_deadline_rejected
        ):
            adm = (
                f"adm={self.admission} "
                f"misses={self.n_deadline_missed}/{self.n_deadline_jobs} "
            )
            slo = self.slo_attainment
            if slo:
                adm += (
                    "slo("
                    + ",".join(f"{t}={v:.2f}" for t, v in slo.items())
                    + ") "
                )
            if self.n_deadline_deferrals:
                adm += f"deferrals={self.n_deadline_deferrals} "
            if self.n_deadline_rejected:
                adm += f"rejected={self.n_deadline_rejected} "
            if self.max_overtakes_observed:
                adm += f"max_overtaken={self.max_overtakes_observed} "
            p99q = self.tenant_p99_queueing_delay
            if p99q:
                adm += (
                    "tenant_p99q("
                    + ",".join(f"{t}={v:.1f}" for t, v in p99q.items())
                    + ") "
                )
        return (
            f"policy={self.policy} warm={self.warm_start} jobs={self.n_jobs} "
            f"mean_jct={f1(self.mean_jct)} p95_jct={f1(self.p95_jct)} "
            f"mean_queue={f1(self.mean_queueing_delay)} "
            f"queue_p50/p90/p99={f1(self.p50_queueing_delay)}/"
            f"{f1(self.p90_queueing_delay)}/{f1(self.p99_queueing_delay)} "
            f"jct_p50/p90/p99={f1(self.p50_jct)}/{f1(self.p90_jct)}/"
            f"{f1(self.p99_jct)} "
            f"peak_active={self.peak_active} peak_queue={self.peak_queue_depth} "
            f"makespan={self.makespan:.1f} "
            f"util(rack/wired/wireless)="
            f"{self.rack_utilization:.2f}/{self.wired_utilization:.2f}/"
            f"{self.wireless_utilization:.2f} "
            f"epochs={self.n_epochs} solves={self.n_solves} "
            f"{arb}"
            f"{adm}"
            f"backfilled={self.n_backfilled} "
            f"pruned={self.n_pruned}/{self.n_candidates} "
            f"jobs_per_solver_s={jps_s} solver_wall={self.solver_wall:.2f}s"
        )
