"""Online arrival-driven scheduling service (beyond-paper).

Turns the offline mega-batch engine into a serving system for the paper's
production scenario (§V): jobs arrive over time, queue for residual
cluster capacity, and are (re-)optimized in windowed ``schedule_fleet``
mega-batches with warm-started search. Commits are channel-feasible:
every schedule is arbitrated onto the shared physical wired channel and
its exclusively granted wireless subchannels before it lands on the
cluster timeline, and the committed timeline is audited overlap-free.
Layers:

  workload  — seeded Poisson / production-mix / trace arrival generators
              + SLO-tier/tenant annotation layer (deadlines from the
              rigorous critical-path bound)
  cluster   — global cluster timeline, residual-capacity instances,
              cross-job channel arbitration + commit-order replay +
              feasibility audit
  service   — admission event loop (FIFO / backfilling / free overtaking)
              + SLO admission (fifo / edf / wfair queue ordering,
              reject-or-defer admission control, bounded starvation)
              + warm-started re-optimization + coflow-aware commit-order
              arbitration (fifo / sigma / search)
  metrics   — per-job queueing/JCT records and aggregate OnlineResult
              (per-tier SLO attainment, per-tenant queueing percentiles)
"""

from repro.online.cluster import (
    ClusterTimeline,
    OrderReplay,
    ResidualView,
    replay_commit_order,
    reservation_backfill_safe,
)
from repro.online.metrics import JobMetrics, OnlineResult, StreamingSeries
from repro.online.service import DEFAULT_SOLVER_KWARGS, OnlineScheduler
from repro.online.workload import (
    ArrivalEvent,
    DEFAULT_SLO_TIERS,
    SloTier,
    poisson_arrivals,
    production_arrivals,
    stream_poisson_arrivals,
    stream_production_arrivals,
    stream_tiered_arrivals,
    tiered_poisson_arrivals,
    tiered_production_arrivals,
    trace_arrivals,
)

__all__ = [
    "ArrivalEvent",
    "ClusterTimeline",
    "DEFAULT_SLO_TIERS",
    "SloTier",
    "DEFAULT_SOLVER_KWARGS",
    "JobMetrics",
    "OnlineResult",
    "OnlineScheduler",
    "OrderReplay",
    "ResidualView",
    "StreamingSeries",
    "replay_commit_order",
    "reservation_backfill_safe",
    "poisson_arrivals",
    "production_arrivals",
    "stream_poisson_arrivals",
    "stream_production_arrivals",
    "stream_tiered_arrivals",
    "tiered_poisson_arrivals",
    "tiered_production_arrivals",
    "trace_arrivals",
]
