"""Arrival-stream workload generators for the online scheduling service.

Every solver below :mod:`repro.online` is single-shot offline; this module
supplies the missing half of the paper's "production scenario" (§V): jobs
*arriving over time* and competing for the same wired channel, wireless
subchannels, and racks. Three generators, all emitting reproducible
streams of :class:`ArrivalEvent`:

  * :func:`poisson_arrivals` — memoryless arrivals at a given rate over
    the §V job families (``JOB_FAMILIES``), every job demanding the full
    cluster shape.
  * :func:`production_arrivals` — the paper's §V production-scenario mix:
    family weights skewed toward MapReduce workflows, task counts
    U[5, 10], fan-out drawn per family, per-job network factor rho drawn
    from a weighted palette (the heavy tail models shuffle-dominant
    jobs), and per-job rack demand below the full cluster so admission
    actually has packing decisions to make.
  * :func:`trace_arrivals` — trace-driven replay of explicit
    ``(arrival_time, job)`` pairs.

The seeded generators are *streaming first*: :func:`stream_poisson_arrivals`
and :func:`stream_production_arrivals` yield events lazily in arrival
order (O(1) memory per event), which is what lets the stress lane push
100k-arrival traces through the service without materializing them. The
list-returning functions above are thin ``list(...)`` wrappers over the
streams and emit bit-identical events.

SLO tiers and tenants
---------------------
:func:`stream_tiered_arrivals` decorates *any* arrival stream with
multi-tenant SLO metadata: each job draws a tenant tag and an SLO tier
(:class:`SloTier`) from a seeded mix, and tiers with finite slack get a
deadline ``arrival + slack * lower_bound(inst)`` — the rigorous
resource-independent critical-path bound from :mod:`repro.core.bounds`,
so a slack of 1.0 is the tightest deadline any scheduler could ever
meet. The tier draw uses its *own* RNG (derived from, but independent
of, the base seed), so the underlying arrival times / DAGs / demands are
bit-identical to the untiered stream — tiering is a pure annotation
layer. :func:`tiered_poisson_arrivals` and
:func:`tiered_production_arrivals` are the pre-composed list forms.

Determinism contract: a generator called twice with the same seed and
parameters returns bit-identical streams (same arrival times, same DAGs,
same demands). Streams are sorted by arrival time, times are
non-negative, and every generated instance is feasible by construction —
``tests/test_online.py`` locks all three properties in.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Sequence

import numpy as np

from repro.core.dag import (
    DagJob,
    JOB_FAMILIES,
    make_onestage_mapreduce,
    make_random_workflow,
    make_simple_mapreduce,
)
from repro.core.bounds import lower_bound
from repro.core.instance import ProblemInstance

__all__ = [
    "ArrivalEvent",
    "LinkEvent",
    "SloTier",
    "DEFAULT_SLO_TIERS",
    "link_outage_trace",
    "poisson_arrivals",
    "production_arrivals",
    "stream_poisson_arrivals",
    "stream_production_arrivals",
    "stream_tiered_arrivals",
    "tiered_poisson_arrivals",
    "tiered_production_arrivals",
    "trace_arrivals",
    "PRODUCTION_FAMILY_WEIGHTS",
    "PRODUCTION_RHO_PALETTE",
]


@dataclasses.dataclass(frozen=True)
class ArrivalEvent:
    """One job arrival.

    Attributes:
      time: absolute arrival time (non-negative; streams are sorted).
      inst: the job plus its *demanded* resource shape — ``inst.n_racks``
        / ``inst.n_wireless`` are what the job asks for; the cluster may
        grant less (a residual-capacity view) at admission time.
      job_id: position in the stream (0-based, unique per stream).
      family: workload family tag (for metrics breakdowns).
      deadline: absolute completion deadline, or ``None`` (best-effort).
      tenant: owning-tenant tag, or ``None`` (anonymous).
      tier: SLO tier name, or ``None`` (untiered).

    The three SLO fields default to ``None`` so pre-existing streams and
    pickles are unchanged; :func:`stream_tiered_arrivals` fills them in.
    """

    time: float
    inst: ProblemInstance
    job_id: int
    family: str
    deadline: float | None = None
    tenant: str | None = None
    tier: str | None = None


@dataclasses.dataclass(frozen=True)
class SloTier:
    """One SLO class in a tiered workload mix.

    Attributes:
      name: tier tag stamped on ``ArrivalEvent.tier``.
      weight: sampling weight in the tier mix (normalized internally).
      slack: deadline slack multiplier — a job's deadline is
        ``arrival + slack * lower_bound(inst)`` where ``lower_bound`` is
        the rigorous critical-path bound (so ``slack < 1`` is unmeetable
        by construction). ``None`` means best-effort: no deadline.
      share: weighted-fairness share used by ``admission="wfair"``
        (larger = more service per unit of attained work).
    """

    name: str
    weight: float
    slack: float | None
    share: float = 1.0


# Default three-class mix: a small latency-critical gold class with tight
# deadlines, a silver bulk class with loose deadlines, and a best-effort
# bronze class with none. Shares follow the usual 4:2:1 weighted-fair split.
DEFAULT_SLO_TIERS = (
    SloTier("gold", weight=0.2, slack=2.0, share=4.0),
    SloTier("silver", weight=0.5, slack=4.0, share=2.0),
    SloTier("bronze", weight=0.3, slack=None, share=1.0),
)


def _sorted_events(events: list[ArrivalEvent]) -> list[ArrivalEvent]:
    events.sort(key=lambda e: (e.time, e.job_id))
    return events


def _sample_family_job(
    rng: np.random.Generator, family: str, n_tasks: int, rho: float
) -> DagJob:
    """One job of ``family`` with ~``n_tasks`` tasks (§V fan-out shapes)."""
    if family == "simple_mapreduce":
        return make_simple_mapreduce(rng, n_map=max(1, n_tasks - 1), rho=rho)
    if family == "onestage_mapreduce":
        n_map = max(1, n_tasks // 2)
        return make_onestage_mapreduce(
            rng, n_map=n_map, n_reduce=max(1, n_tasks - n_map), rho=rho
        )
    if family == "random_workflow":
        return make_random_workflow(rng, n_tasks=n_tasks, rho=rho)
    raise ValueError(f"unknown family {family!r}")


def stream_poisson_arrivals(
    seed: int,
    rate: float,
    n_jobs: int,
    *,
    n_racks: int = 6,
    n_wireless: int = 2,
    rho: float = 0.5,
    families: Sequence[str] = JOB_FAMILIES,
    wired_rate: float = 1.0,
    wireless_rate: float = 1.0,
) -> Iterator[ArrivalEvent]:
    """Streaming form of :func:`poisson_arrivals`.

    Yields the same events, in the same (time-sorted) order, one at a
    time — arrival times are a cumulative sum of non-negative exponential
    gaps, so the generation order *is* the sorted order. Parameter
    validation happens eagerly at call time, not at first ``next()``.
    """
    if rate <= 0:
        raise ValueError("rate must be positive")

    def _gen() -> Iterator[ArrivalEvent]:
        rng = np.random.default_rng(seed)
        t = 0.0
        for j in range(n_jobs):
            t += float(rng.exponential(1.0 / rate))
            family = str(families[int(rng.integers(len(families)))])
            n_tasks = int(rng.integers(5, 11))
            job = _sample_family_job(rng, family, n_tasks, rho)
            inst = ProblemInstance(
                job=job,
                n_racks=n_racks,
                n_wireless=n_wireless,
                wired_rate=wired_rate,
                wireless_rate=wireless_rate,
            )
            yield ArrivalEvent(time=t, inst=inst, job_id=j, family=family)

    return _gen()


def poisson_arrivals(
    seed: int,
    rate: float,
    n_jobs: int,
    *,
    n_racks: int = 6,
    n_wireless: int = 2,
    rho: float = 0.5,
    families: Sequence[str] = JOB_FAMILIES,
    wired_rate: float = 1.0,
    wireless_rate: float = 1.0,
) -> list[ArrivalEvent]:
    """Seeded Poisson arrivals over the §V job families.

    Inter-arrival gaps are Exponential(``rate``) (``rate`` = expected jobs
    per unit time, on the same clock as task durations ~ U[1, 100]);
    each job is drawn uniformly from ``families`` with the paper's
    task-count range U[5, 10] and a fixed network factor ``rho``. Every
    job demands the full ``(n_racks, n_wireless)`` cluster shape.

    Returns a time-sorted list of :class:`ArrivalEvent`; same seed =>
    bit-identical stream. This is a ``list(...)`` wrapper over
    :func:`stream_poisson_arrivals`.
    """
    return _sorted_events(
        list(
            stream_poisson_arrivals(
                seed,
                rate,
                n_jobs,
                n_racks=n_racks,
                n_wireless=n_wireless,
                rho=rho,
                families=families,
                wired_rate=wired_rate,
                wireless_rate=wireless_rate,
            )
        )
    )


# §V production mix: MapReduce-style workflows dominate the trace, and a
# minority of shuffle-heavy jobs (rho >= 1) supplies the data-size tail.
PRODUCTION_FAMILY_WEIGHTS = {
    "simple_mapreduce": 0.45,
    "onestage_mapreduce": 0.35,
    "random_workflow": 0.20,
}
PRODUCTION_RHO_PALETTE = ((0.5, 0.55), (1.0, 0.30), (1.5, 0.15))


def production_arrivals(
    seed: int,
    rate: float,
    n_jobs: int,
    *,
    n_racks: int = 6,
    n_wireless: int = 2,
    min_rack_demand: int = 3,
    min_wireless_demand: int | None = None,
    wired_rate: float = 1.0,
    wireless_rate: float = 1.0,
) -> list[ArrivalEvent]:
    """The paper's §V production-scenario arrival mix.

    Poisson arrivals at ``rate`` whose jobs follow the production
    distributions: families weighted by
    :data:`PRODUCTION_FAMILY_WEIGHTS`, task counts U[5, 10] with
    family-specific fan-out (mappers = ``n_tasks - 1`` for simple
    MapReduce, a balanced map/reduce split for one-stage shuffles), and a
    per-job network factor drawn from :data:`PRODUCTION_RHO_PALETTE` —
    most jobs are compute-bound (rho 0.5) with a shuffle-heavy tail
    (rho 1.0 / 1.5) that stresses the shared channels. Each job demands
    between ``min_rack_demand`` and ``n_racks`` racks (uniform), so the
    cluster timeline has real packing decisions; wireless demand is the
    full ``n_wireless`` by default, or uniform in
    ``[min_wireless_demand, n_wireless]`` when that is given (not every
    production job uses the augmentation links — a spread of wireless
    demands is what gives exclusive subchannel grants, and backfilling
    around wireless-heavy head-of-line jobs, real packing decisions).

    Returns a time-sorted list of :class:`ArrivalEvent`; same seed =>
    bit-identical stream (the default ``min_wireless_demand=None`` draws
    nothing extra, so legacy streams are unchanged). This is a
    ``list(...)`` wrapper over :func:`stream_production_arrivals`.
    """
    return _sorted_events(
        list(
            stream_production_arrivals(
                seed,
                rate,
                n_jobs,
                n_racks=n_racks,
                n_wireless=n_wireless,
                min_rack_demand=min_rack_demand,
                min_wireless_demand=min_wireless_demand,
                wired_rate=wired_rate,
                wireless_rate=wireless_rate,
            )
        )
    )


def stream_production_arrivals(
    seed: int,
    rate: float,
    n_jobs: int,
    *,
    n_racks: int = 6,
    n_wireless: int = 2,
    min_rack_demand: int = 3,
    min_wireless_demand: int | None = None,
    wired_rate: float = 1.0,
    wireless_rate: float = 1.0,
) -> Iterator[ArrivalEvent]:
    """Streaming form of :func:`production_arrivals`.

    Yields the same events, in the same (time-sorted) order, one at a
    time, so arbitrarily long production traces cost O(1) memory in the
    generator. Parameter validation happens eagerly at call time.
    """
    if rate <= 0:
        raise ValueError("rate must be positive")
    if not 1 <= min_rack_demand <= n_racks:
        raise ValueError("min_rack_demand must be in [1, n_racks]")
    if min_wireless_demand is not None and not (
        0 <= min_wireless_demand <= n_wireless
    ):
        raise ValueError("min_wireless_demand must be in [0, n_wireless]")

    def _gen() -> Iterator[ArrivalEvent]:
        rng = np.random.default_rng(seed)
        fam_names = tuple(PRODUCTION_FAMILY_WEIGHTS)
        fam_p = np.asarray([PRODUCTION_FAMILY_WEIGHTS[f] for f in fam_names])
        fam_p = fam_p / fam_p.sum()
        rho_vals = np.asarray([v for v, _ in PRODUCTION_RHO_PALETTE])
        rho_p = np.asarray([w for _, w in PRODUCTION_RHO_PALETTE])
        rho_p = rho_p / rho_p.sum()

        t = 0.0
        for j in range(n_jobs):
            t += float(rng.exponential(1.0 / rate))
            family = str(fam_names[int(rng.choice(len(fam_names), p=fam_p))])
            rho = float(rho_vals[int(rng.choice(len(rho_vals), p=rho_p))])
            n_tasks = int(rng.integers(5, 11))
            job = _sample_family_job(rng, family, n_tasks, rho)
            demand = int(rng.integers(min_rack_demand, n_racks + 1))
            demand_w = (
                n_wireless
                if min_wireless_demand is None
                else int(rng.integers(min_wireless_demand, n_wireless + 1))
            )
            inst = ProblemInstance(
                job=job,
                n_racks=demand,
                n_wireless=demand_w,
                wired_rate=wired_rate,
                wireless_rate=wireless_rate,
            )
            yield ArrivalEvent(time=t, inst=inst, job_id=j, family=family)

    return _gen()


def _validated_tiers(tiers: Sequence[SloTier]) -> tuple[SloTier, ...]:
    tiers = tuple(tiers)
    if not tiers:
        raise ValueError("tiers must be non-empty")
    if any(t.weight < 0 for t in tiers) or not any(t.weight > 0 for t in tiers):
        raise ValueError("tier weights must be non-negative with positive sum")
    if any(t.slack is not None and t.slack <= 0 for t in tiers):
        raise ValueError("tier slack must be positive (or None for no deadline)")
    if any(t.share <= 0 for t in tiers):
        raise ValueError("tier share must be positive")
    return tiers


def stream_tiered_arrivals(
    events: Iterable[ArrivalEvent],
    seed: int,
    *,
    tiers: Sequence[SloTier] = DEFAULT_SLO_TIERS,
    n_tenants: int = 3,
) -> Iterator[ArrivalEvent]:
    """Annotate an arrival stream with seeded tenant + SLO-tier metadata.

    Each event draws a tenant uniformly from ``n_tenants`` and a tier from
    the ``tiers`` mix (weighted by :attr:`SloTier.weight`) using an RNG
    derived from ``(seed, "slo-tiers")`` — *not* the base stream's RNG —
    so the wrapped events carry identical ``time`` / ``inst`` / ``job_id``
    / ``family`` to the unwrapped stream. Tiers with finite slack stamp
    ``deadline = time + slack * lower_bound(inst)``; ``slack=None`` tiers
    leave ``deadline=None`` (best-effort).

    Lazily yields :class:`ArrivalEvent` copies, preserving input order.
    """
    tiers = _validated_tiers(tiers)
    if n_tenants < 1:
        raise ValueError("n_tenants must be >= 1")

    def _gen() -> Iterator[ArrivalEvent]:
        # Independent seed sequence: spawning off (seed, tag) keeps the tier
        # draws decoupled from the base stream's RNG consumption.
        rng = np.random.default_rng([seed, int.from_bytes(b"slo", "big")])
        p = np.asarray([t.weight for t in tiers], dtype=np.float64)
        p = p / p.sum()
        for ev in events:
            tier = tiers[int(rng.choice(len(tiers), p=p))]
            tenant = f"tenant-{int(rng.integers(n_tenants))}"
            deadline = (
                None
                if tier.slack is None
                else ev.time + tier.slack * lower_bound(ev.inst)
            )
            yield dataclasses.replace(
                ev, deadline=deadline, tenant=tenant, tier=tier.name
            )

    return _gen()


def tiered_poisson_arrivals(
    seed: int,
    rate: float,
    n_jobs: int,
    *,
    tiers: Sequence[SloTier] = DEFAULT_SLO_TIERS,
    n_tenants: int = 3,
    **kwargs,
) -> list[ArrivalEvent]:
    """:func:`poisson_arrivals` with tenant/SLO annotations.

    The base stream is bit-identical to ``poisson_arrivals(seed, ...)``
    (same times, DAGs, demands); only the SLO fields differ from ``None``.
    Extra ``kwargs`` pass through to the base generator.
    """
    return list(
        stream_tiered_arrivals(
            stream_poisson_arrivals(seed, rate, n_jobs, **kwargs),
            seed,
            tiers=tiers,
            n_tenants=n_tenants,
        )
    )


def tiered_production_arrivals(
    seed: int,
    rate: float,
    n_jobs: int,
    *,
    tiers: Sequence[SloTier] = DEFAULT_SLO_TIERS,
    n_tenants: int = 3,
    **kwargs,
) -> list[ArrivalEvent]:
    """:func:`production_arrivals` with tenant/SLO annotations.

    Same contract as :func:`tiered_poisson_arrivals`: the underlying
    production stream is bit-identical to the untiered one.
    """
    return list(
        stream_tiered_arrivals(
            stream_production_arrivals(seed, rate, n_jobs, **kwargs),
            seed,
            tiers=tiers,
            n_tenants=n_tenants,
        )
    )


def trace_arrivals(
    times: Iterable[float],
    jobs: Iterable[DagJob],
    *,
    n_racks: int = 6,
    n_wireless: int = 2,
    wired_rate: float = 1.0,
    wireless_rate: float = 1.0,
) -> list[ArrivalEvent]:
    """Trace-driven arrivals: replay explicit ``(time, job)`` pairs.

    ``times`` need not be pre-sorted (the stream is sorted, stably by
    input order on ties) but must be non-negative and match ``jobs`` in
    length. Every job demands the full cluster shape; wrap the result to
    override per-job demands.
    """
    times = [float(t) for t in times]
    jobs = list(jobs)
    if len(times) != len(jobs):
        raise ValueError("times and jobs must have the same length")
    if times and min(times) < 0.0:
        raise ValueError("arrival times must be non-negative")
    events = [
        ArrivalEvent(
            time=t,
            inst=ProblemInstance(
                job=job,
                n_racks=n_racks,
                n_wireless=n_wireless,
                wired_rate=wired_rate,
                wireless_rate=wireless_rate,
            ),
            job_id=j,
            family=job.name,
        )
        for j, (t, job) in enumerate(zip(times, jobs))
    ]
    return _sorted_events(events)


# -- seeded link outage traces -------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkEvent:
    """One wireless-link state flip in an outage trace.

    Attributes:
      time: absolute event time (traces are sorted by time).
      rack: physical rack id of the flapping link.
      subchannel: physical wireless subchannel index (0-based).
      up: new link state — ``False`` = outage, ``True`` = repair.
    """

    time: float
    rack: int
    subchannel: int
    up: bool


def link_outage_trace(
    seed: int,
    n_racks: int,
    n_wireless: int,
    horizon: float,
    *,
    outage_rate: float = 0.02,
    mean_downtime: float = 10.0,
) -> list[LinkEvent]:
    """Seeded two-state link flap trace for a reconfigurable topology.

    Every (rack, subchannel) link alternates between up and down phases:
    up phases last ``Exp(1 / outage_rate)`` (so ``outage_rate`` is the
    per-link failure rate per time unit) and down phases
    ``Exp(mean_downtime)``. Events past ``horizon`` are dropped; a link
    down at the horizon simply stays down. Uses its own derived RNG
    (``(seed, "flap")``), so composing a trace with any arrival stream
    of the same seed leaves the arrivals bit-identical.

    The online service applies events with ``time <= epoch`` to the
    cluster's link state and folds the active-link fingerprint into the
    availability signature, so ``replan="changed"`` re-solves exactly the
    jobs whose plans a flap invalidates.

    Returns the events sorted by ``(time, rack, subchannel)``.
    """
    if n_racks < 1 or n_wireless < 0:
        raise ValueError("need n_racks >= 1 and n_wireless >= 0")
    if outage_rate < 0 or mean_downtime < 0:
        raise ValueError("outage_rate and mean_downtime must be >= 0")
    events: list[LinkEvent] = []
    if outage_rate == 0.0 or horizon <= 0.0:
        return events
    rng = np.random.default_rng([seed, int.from_bytes(b"flap", "big")])
    for i in range(n_racks):
        for k in range(n_wireless):
            t = 0.0
            while True:
                t += float(rng.exponential(1.0 / outage_rate))
                if t >= horizon:
                    break
                events.append(LinkEvent(t, i, k, False))
                t += float(rng.exponential(mean_downtime))
                if t >= horizon:
                    break
                events.append(LinkEvent(t, i, k, True))
    events.sort(key=lambda e: (e.time, e.rack, e.subchannel))
    return events
