"""State-space and recurrent mixers: SSD (Mamba-2 style) and xLSTM blocks.

Hardware adaptation (DESIGN.md §2): Mamba-1's per-channel selective scan is
elementwise/DMA-bound and maps poorly to the MXU. We adapt hybrid layers to
the SSD (state-space duality) chunked formulation — intra-chunk work becomes
Q×Q matmuls (MXU-friendly), inter-chunk work is a short lax.scan over chunk
boundary states. Decode uses the O(1) recurrent update.

The mLSTM uses the stabilized parallel (quadratic) form for training/prefill
and the matrix-memory recurrent form for decode; the sLSTM is inherently
sequential and runs as a lax.scan over time with a fused cell.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import init_linear, init_rms_norm, linear, rms_norm

Params = dict[str, Any]

__all__ = [
    "init_ssd", "ssd_forward", "ssd_init_state", "ssd_decode_step",
    "init_mlstm", "mlstm_forward", "mlstm_init_state", "mlstm_decode_step",
    "init_slstm", "slstm_forward", "slstm_init_state", "slstm_decode_step",
]


# ==========================================================================
# SSD (Mamba-2 style)
# ==========================================================================

def init_ssd(key: jax.Array, cfg: ModelConfig) -> Params:
    d, di, H, N = cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state_dim
    ks = jax.random.split(key, 6)
    return {
        # Separate projections (clean tensor-parallel sharding of z/x on the
        # inner dim; B/C/dt are small and replicated).
        "wz": init_linear(ks[0], d, di),
        "wx": init_linear(ks[1], d, di),
        "wbc": init_linear(ks[2], d, 2 * N),
        "wdt": init_linear(ks[3], d, H),
        "conv_w": jax.random.normal(ks[4], (cfg.ssm_conv_dim, di), jnp.float32)
        * (1.0 / np.sqrt(cfg.ssm_conv_dim)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": init_rms_norm(di),
        "out_proj": init_linear(ks[5], di, d),
    }


def _split_ssd(cfg: ModelConfig, params: Params, u: jax.Array):
    N = cfg.ssm_state_dim
    z = linear(params["wz"], u)
    x = linear(params["wx"], u)
    bc = linear(params["wbc"], u)
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt = linear(params["wdt"], u)
    return z, x, Bm, Cm, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. x: [B, S, di]; w: [K, di]."""
    K = w.shape[0]
    wc = w.astype(x.dtype)
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):  # K is tiny (4); unrolled adds
        out = out + pad[:, k : k + x.shape[1], :] * wc[K - 1 - k]
    return out + b.astype(x.dtype)


def _segsum(a: jax.Array) -> jax.Array:
    """segsum[..., i, j] = sum_{t=j+1..i} a[..., t] for i >= j else -inf.

    a: [..., Q]; returns [..., Q, Q].
    """
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(
    x: jax.Array,    # [B, S, H, P] inputs (already dt-scaled)
    a: jax.Array,    # [B, S, H] log-decay per step (<= 0)
    Bm: jax.Array,   # [B, S, N] input matrix (shared across heads)
    Cm: jax.Array,   # [B, S, N] output matrix
    chunk: int,
    init_state: jax.Array | None = None,  # [B, H, P, N]
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD: returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, "sequence length must be divisible by ssm_chunk"
    nc = S // Q
    xr = x.reshape(B, nc, Q, H, P)
    ar = a.reshape(B, nc, Q, H).astype(jnp.float32)
    Br = Bm.reshape(B, nc, Q, N)
    Cr = Cm.reshape(B, nc, Q, N)

    cum = jnp.cumsum(ar, axis=2)                       # [B,nc,Q,H]
    # Intra-chunk (diagonal) term: att[i,j] = C_i.B_j exp(cum_i - cum_j), i>=j
    # Kept in f32: casting the decay matrix to bf16 compounds ~1% error per
    # layer and breaks decode/forward consistency on deep hybrids.
    L = jnp.exp(_segsum(ar.transpose(0, 1, 3, 2)))     # [B,nc,H,Q,Q]
    cb = jnp.einsum(
        "bcin,bcjn->bcij", Cr, Br, preferred_element_type=jnp.float32
    )
    att = cb[:, :, None] * L                           # [B,nc,H,Q,Q] f32
    y_diag = jnp.einsum(
        "bchij,bcjhp->bcihp", att, xr.astype(jnp.float32)
    ).astype(x.dtype)

    # Chunk boundary states: state_c = sum_j exp(cum_last - cum_j) x_j B_j^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)    # [B,nc,Q,H]
    states = jnp.einsum(
        "bcqh,bcqhp,bcqn->bchpn",
        decay_to_end.astype(x.dtype),
        xr,
        Br.astype(x.dtype),
    )                                                   # [B,nc,H,P,N]
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # [B,nc,H]

    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )

    def body(carry, inp):
        st, dec = inp  # [B,H,P,N], [B,H]
        new = carry * dec[..., None, None] + st.astype(jnp.float32)
        return new, carry  # emit state BEFORE this chunk

    final, prev_states = jax.lax.scan(
        body,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    # Inter-chunk (off-diagonal) term: y_i += C_i . prev_state * exp(cum_i)
    y_off = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp",
        Cr.astype(jnp.float32),
        prev_states,
        jnp.exp(cum),
    ).astype(x.dtype)

    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, final


def ssd_forward(
    params: Params,
    cfg: ModelConfig,
    u: jax.Array,  # [B, S, d_model]
    init_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Full SSD mixer; returns (output [B,S,d], final ssm state)."""
    B, S, _ = u.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    z, x, Bm, Cm, dt = _split_ssd(cfg, params, u)
    x = jax.nn.silu(_causal_conv(x, params["conv_w"], params["conv_b"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    A = -jnp.exp(params["A_log"])  # [H]
    a = dt * A  # log decay
    xh = x.reshape(B, S, H, P)
    x_dt = xh * dt[..., None].astype(x.dtype)
    y, state = ssd_scan(x_dt, a, Bm, Cm, cfg.ssm_chunk, init_state)
    y = y + params["D"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, cfg.d_inner)
    y = rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return linear(params["out_proj"], y), state


def ssd_init_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    return {
        "ssm": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state_dim),
            jnp.float32,
        ),
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, cfg.d_inner), jnp.bfloat16),
    }


def ssd_decode_step(
    params: Params,
    cfg: ModelConfig,
    u: jax.Array,  # [B, 1, d_model]
    state: dict[str, jax.Array],
) -> tuple[jax.Array, dict[str, jax.Array]]:
    B = u.shape[0]
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    z, x, Bm, Cm, dt = _split_ssd(cfg, params, u)
    x = x[:, 0]  # [B, di]
    # Rolling causal conv buffer.
    conv_in = jnp.concatenate(
        [state["conv"].astype(x.dtype), x[:, None, :]], axis=1
    )  # [B, K, di] oldest..newest
    # Match _causal_conv's orientation: w[0] multiplies the NEWEST sample.
    w = params["conv_w"].astype(x.dtype)[::-1]
    xc = jnp.einsum("bkd,kd->bd", conv_in, w) + params["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)
    new_conv = conv_in[:, 1:, :].astype(jnp.bfloat16)

    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # [B,H]
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dtp * A)  # [B,H]
    xh = xc.reshape(B, H, P)
    s = state["ssm"]
    s = s * decay[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn",
        xh.astype(jnp.float32),
        Bm[:, 0].astype(jnp.float32),
        dtp,
    )
    y = jnp.einsum("bhpn,bn->bhp", s, Cm[:, 0].astype(jnp.float32)).astype(u.dtype)
    y = y + params["D"].astype(u.dtype)[None, :, None] * xh
    y = y.reshape(B, 1, cfg.d_inner)
    y = rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return linear(params["out_proj"], y), {"ssm": s, "conv": new_conv}


# ==========================================================================
# mLSTM (matrix-memory LSTM, xLSTM)
# ==========================================================================

def init_mlstm(key: jax.Array, cfg: ModelConfig) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    H = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "up": init_linear(ks[0], d, 2 * di),     # (x, gate z)
        "wq": init_linear(ks[1], di, di),
        "wk": init_linear(ks[2], di, di),
        "wv": init_linear(ks[3], di, di),
        "wif": init_linear(ks[4], di, 2 * H),    # input/forget gate logits
        "norm": init_rms_norm(di),
        "down": init_linear(ks[5], di, d),
    }


def mlstm_forward(
    params: Params, cfg: ModelConfig, u: jax.Array
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Stabilized parallel mLSTM. Returns (out [B,S,d], final state)."""
    B, S, _ = u.shape
    H = cfg.n_heads
    di = cfg.d_inner
    P = di // H
    xz = linear(params["up"], u)
    x, z = xz[..., :di], xz[..., di:]
    q = linear(params["wq"], x).reshape(B, S, H, P)
    k = linear(params["wk"], x).reshape(B, S, H, P) / np.sqrt(P)
    v = linear(params["wv"], x).reshape(B, S, H, P)
    gif = linear(params["wif"], x).astype(jnp.float32)
    log_i = gif[..., :H]                       # [B,S,H]
    log_f = jax.nn.log_sigmoid(gif[..., H:])   # [B,S,H]

    # D[i,j] = sum_{t=j+1..i} log_f_t + log_i_j  (i >= j)
    fseg = _segsum(log_f.transpose(0, 2, 1))   # [B,H,S,S]
    Dm = fseg + log_i.transpose(0, 2, 1)[:, :, None, :]
    m = jnp.max(Dm, axis=-1, keepdims=True)    # [B,H,S,1] stabilizer
    m = jnp.maximum(m, -1e30)                  # guard all -inf rows
    W = jnp.exp(Dm - m)                        # [B,H,S,S]
    qk = jnp.einsum("bihp,bjhp->bhij", q, k).astype(jnp.float32)
    num = jnp.einsum("bhij,bhij,bjhp->bihp", W, qk, v.astype(jnp.float32))
    den = jnp.einsum("bhij,bhij->bhi", W, qk)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m[..., 0]))
    h = (num / den.transpose(0, 2, 1)[..., None]).astype(u.dtype)  # [B,S,H,P]
    h = h.reshape(B, S, di)
    h = rms_norm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    out = linear(params["down"], h)

    # Final recurrent state (for decode continuation after prefill).
    cum_f = jnp.cumsum(log_f, axis=1)  # [B,S,H]
    w_last = jnp.exp(
        cum_f[:, -1:, :] - cum_f + log_i
    )  # weight of each step in final state [B,S,H]
    C = jnp.einsum(
        "bsh,bshp,bshq->bhpq", w_last, k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n = jnp.einsum("bsh,bshp->bhp", w_last, k.astype(jnp.float32))
    m_fin = jnp.max(cum_f[:, -1:, :] - cum_f + log_i, axis=1)[:, None]  # rough
    state = {"C": C, "n": n, "m": m_fin[:, 0]}
    return out, state


def mlstm_init_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    H = cfg.n_heads
    P = cfg.d_inner // H
    return {
        "C": jnp.zeros((batch, H, P, P), jnp.float32),
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode_step(
    params: Params, cfg: ModelConfig, u: jax.Array, state: dict[str, jax.Array]
) -> tuple[jax.Array, dict[str, jax.Array]]:
    B = u.shape[0]
    H = cfg.n_heads
    di = cfg.d_inner
    P = di // H
    xz = linear(params["up"], u)
    x, z = xz[..., :di], xz[..., di:]
    q = linear(params["wq"], x).reshape(B, H, P)
    k = linear(params["wk"], x).reshape(B, H, P) / np.sqrt(P)
    v = linear(params["wv"], x).reshape(B, H, P)
    gif = linear(params["wif"], x)[:, 0].astype(jnp.float32)
    log_i = gif[:, :H]
    log_f = jax.nn.log_sigmoid(gif[:, H:])

    m_new = jnp.maximum(log_f + state["m"], log_i)
    a = jnp.exp(log_f + state["m"] - m_new)[..., None]
    b = jnp.exp(log_i - m_new)[..., None]
    kf = k[:, 0] if k.ndim == 4 else k
    C = state["C"] * a[..., None] + b[..., None] * jnp.einsum(
        "bhp,bhq->bhpq", kf.astype(jnp.float32), v.astype(jnp.float32)
    )
    n = state["n"] * a + b * kf.astype(jnp.float32)
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhpq,bhp->bhq", C, qf)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhp,bhp->bh", n, qf)), jnp.exp(-m_new)
    )
    h = (num / den[..., None]).astype(u.dtype).reshape(B, 1, di)
    h = rms_norm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    return linear(params["down"], h), {"C": C, "n": n, "m": m_new}


# ==========================================================================
# sLSTM (scalar-memory LSTM with exponential gating; sequential)
# ==========================================================================

def init_slstm(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    # 4 gates (z, i, f, o) from input and recurrent h.
    return {
        "wx": init_linear(ks[0], d, 4 * d),
        "wh": init_linear(ks[1], d, 4 * d, scale=0.5 / np.sqrt(d)),
        "norm": init_rms_norm(d),
        "up": init_linear(ks[2], d, 2 * (4 * d // 3)),
        "down": init_linear(ks[3], 4 * d // 3, d),
    }


def _slstm_cell(params: Params, d: int, gx_t, carry):
    """One sLSTM step. carry = (c, n, m, h); gx_t = precomputed W_x·x_t.

    The input projection is hoisted out of the time scan (§Perf: one
    [B·S, d]x[d, 4d] matmul instead of S small ones re-reading W_x from HBM
    every step). Only the genuinely recurrent W_h·h_{t-1} stays inside.
    """
    c, n, m, h = carry
    g = (gx_t + linear(params["wh"], h)).astype(jnp.float32)
    zt = jnp.tanh(g[..., :d])
    it = g[..., d : 2 * d]
    ft = g[..., 2 * d : 3 * d]
    ot = jax.nn.sigmoid(g[..., 3 * d :])
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)
    ia = jnp.exp(it - m_new)
    fa = jnp.exp(log_f + m - m_new)
    c_new = fa * c + ia * zt
    n_new = fa * n + ia
    h_new = (ot * c_new / jnp.maximum(n_new, 1.0)).astype(gx_t.dtype)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_forward(
    params: Params, cfg: ModelConfig, u: jax.Array
) -> tuple[jax.Array, tuple]:
    B, S, d = u.shape
    init = (
        jnp.zeros((B, d), jnp.float32),
        jnp.zeros((B, d), jnp.float32),
        jnp.full((B, d), -1e30, jnp.float32),
        jnp.zeros((B, d), u.dtype),
    )

    gx = linear(params["wx"], u)  # [B, S, 4d] — hoisted input projection

    def step(carry, gx_t):
        return _slstm_cell(params, d, gx_t, carry)

    carry, hs = jax.lax.scan(step, init, gx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2)  # [B,S,d]
    h = rms_norm(params["norm"], h, cfg.norm_eps)
    up = linear(params["up"], h)
    half = up.shape[-1] // 2
    h = jax.nn.gelu(up[..., :half]) * up[..., half:]
    return linear(params["down"], h), carry


def slstm_init_state(cfg: ModelConfig, batch: int) -> tuple:
    d = cfg.d_model
    return (
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
        jnp.full((batch, d), -1e30, jnp.float32),
        jnp.zeros((batch, d), jnp.bfloat16),
    )


def slstm_decode_step(
    params: Params, cfg: ModelConfig, u: jax.Array, state: tuple
) -> tuple[jax.Array, tuple]:
    d = cfg.d_model
    x_t = u[:, 0]
    gx_t = linear(params["wx"], x_t)
    c, n, m, h = state
    carry, h_new = _slstm_cell(params, d, gx_t, (c, n, m, h.astype(x_t.dtype)))
    h2 = rms_norm(params["norm"], h_new[:, None, :], cfg.norm_eps)
    up = linear(params["up"], h2)
    half = up.shape[-1] // 2
    h2 = jax.nn.gelu(up[..., :half]) * up[..., half:]
    out = linear(params["down"], h2)
    c, n, m, hh = carry
    return out, (c, n, m, hh.astype(jnp.bfloat16))
