"""Unified model builder for every assigned architecture family.

``build_model(cfg)`` returns a :class:`Model` with four pure functions:

  init(rng)                          -> params
  forward(params, tokens, memory)    -> logits [B, S, V]      (train/prefill)
  init_cache(batch, max_len, memory) -> cache                 (decode state)
  decode_step(params, cache, token)  -> (logits [B, 1, V], cache)

Layer stacks scan over the smallest repeating period of layer kinds with
parameters stacked along a leading repeat axis, so HLO size is independent
of depth (95-layer deepseek compiles the same graph as a 1-period model).

Mixers: attn (causal self), attn_cross (self + cross), cross (cross-only),
mamba (SSD), slstm, mlstm. FFNs: mlp (SwiGLU), moe, none.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.config import ModelConfig, layer_kinds, layer_period
from repro.models.layers import (
    apply_rope,
    attention,
    decode_attention,
    embed,
    init_attention,
    init_embedding,
    init_mlp,
    init_rms_norm,
    linear,
    mlp_swiglu,
    rms_norm,
    rope_tables,
    shard,
    unembed,
)

Params = Any
AUX_COEF = 0.01

__all__ = ["Model", "build_model", "count_params", "active_param_fraction"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    init_cache: Callable
    decode_step: Callable
    loss: Callable
    encode: Callable | None = None  # enc-dec only: frames -> memory
    hidden: Callable | None = None  # trunk without unembed
    prefill: Callable | None = None  # last-position logits (serving)


# --------------------------------------------------------------------------
# Per-kind layer init
# --------------------------------------------------------------------------

def _init_mixer(key, cfg: ModelConfig, mixer: str) -> Params:
    if mixer in ("attn", "cross"):
        return {
            "norm": init_rms_norm(cfg.d_model),
            "attn": init_attention(
                key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                qkv_bias=cfg.qkv_bias,
            ),
        }
    if mixer == "attn_cross":
        k1, k2 = jax.random.split(key)
        return {
            "norm": init_rms_norm(cfg.d_model),
            "attn": init_attention(
                k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                qkv_bias=cfg.qkv_bias,
            ),
            "xnorm": init_rms_norm(cfg.d_model),
            "xattn": init_attention(
                k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            ),
        }
    if mixer == "mamba":
        return {"norm": init_rms_norm(cfg.d_model), "ssd": ssm.init_ssd(key, cfg)}
    if mixer == "slstm":
        return {"norm": init_rms_norm(cfg.d_model), "cell": ssm.init_slstm(key, cfg)}
    if mixer == "mlstm":
        return {"norm": init_rms_norm(cfg.d_model), "cell": ssm.init_mlstm(key, cfg)}
    raise ValueError(mixer)


def _init_ffn(key, cfg: ModelConfig, ffn: str) -> Params:
    if ffn == "mlp":
        return {
            "norm": init_rms_norm(cfg.d_model),
            "mlp": init_mlp(key, cfg.d_model, cfg.d_ff),
        }
    if ffn == "moe":
        return {
            "norm": init_rms_norm(cfg.d_model),
            "moe": moe_mod.init_moe(key, cfg.d_model, cfg.d_ff, cfg.n_experts),
        }
    if ffn == "none":
        return {}
    raise ValueError(ffn)


def _stack(trees: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


# --------------------------------------------------------------------------
# Forward layer application (full sequence)
# --------------------------------------------------------------------------

def _apply_mixer(
    lp: Params,
    cfg: ModelConfig,
    mixer: str,
    x: jax.Array,
    cos: jax.Array,
    sin: jax.Array,
    memory: jax.Array | None,
) -> jax.Array:
    h = rms_norm(lp["norm"], x, cfg.norm_eps)
    if mixer == "attn":
        return x + attention(
            lp["attn"], h, cos, sin, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        )
    if mixer == "cross":
        return x + attention(
            lp["attn"], h, cos, sin, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            causal=False, kv_input=memory, use_rope=False,
        )
    if mixer == "attn_cross":
        x = x + attention(
            lp["attn"], h, cos, sin, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        )
        h2 = rms_norm(lp["xnorm"], x, cfg.norm_eps)
        return x + attention(
            lp["xattn"], h2, cos, sin, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            causal=False, kv_input=memory, use_rope=False,
        )
    if mixer == "mamba":
        y, _ = ssm.ssd_forward(lp["ssd"], cfg, h)
        return x + y
    if mixer == "slstm":
        y, _ = ssm.slstm_forward(lp["cell"], cfg, h)
        return x + y
    if mixer == "mlstm":
        y, _ = ssm.mlstm_forward(lp["cell"], cfg, h)
        return x + y
    raise ValueError(mixer)


def _apply_ffn(
    lp: Params, cfg: ModelConfig, ffn: str, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    if ffn == "none":
        return x, jnp.zeros((), jnp.float32)
    h = rms_norm(lp["norm"], x, cfg.norm_eps)
    if ffn == "mlp":
        return x + mlp_swiglu(lp["mlp"], h), jnp.zeros((), jnp.float32)
    y, aux = moe_mod.moe_ffn(
        lp["moe"], h, cfg.n_experts, cfg.experts_per_token,
        capacity_factor=cfg.capacity_factor, normalize=cfg.router_normalize,
    )
    return x + y, aux


# --------------------------------------------------------------------------
# Decode layer application (single token, cached state)
# --------------------------------------------------------------------------

def _mixer_cache(cfg: ModelConfig, mixer: str, batch: int, max_len: int):
    kvd = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if mixer == "attn":
        return {
            "k": jnp.zeros(kvd, jnp.bfloat16),
            "v": jnp.zeros(kvd, jnp.bfloat16),
        }
    if mixer == "cross":
        return {}  # cross K/V live in the shared memory cache
    if mixer == "attn_cross":
        return {
            "k": jnp.zeros(kvd, jnp.bfloat16),
            "v": jnp.zeros(kvd, jnp.bfloat16),
        }
    if mixer == "mamba":
        return ssm.ssd_init_state(cfg, batch)
    if mixer == "slstm":
        return ssm.slstm_init_state(cfg, batch)
    if mixer == "mlstm":
        return ssm.mlstm_init_state(cfg, batch)
    raise ValueError(mixer)


def _decode_mixer(
    lp: Params,
    cfg: ModelConfig,
    mixer: str,
    x: jax.Array,           # [B, 1, d]
    pos: jax.Array,
    mcache: Any,
    memory: jax.Array | None,
):
    h = rms_norm(lp["norm"], x, cfg.norm_eps)
    if mixer in ("attn", "attn_cross"):
        out, k, v = decode_attention(
            lp["attn"], h, pos, mcache["k"], mcache["v"], cfg.rope_theta,
            cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
        )
        x = x + out
        mcache = dict(mcache, k=k, v=v)
        if mixer == "attn_cross":
            h2 = rms_norm(lp["xnorm"], x, cfg.norm_eps)
            xout = attention(
                lp["xattn"], h2, None, None, cfg.n_heads,
                cfg.n_kv_heads, cfg.head_dim, causal=False, kv_input=memory,
                use_rope=False,
            )
            x = x + xout
        return x, mcache
    if mixer == "cross":
        out = attention(
            lp["attn"], h, None, None, cfg.n_heads, cfg.n_kv_heads,
            cfg.head_dim, causal=False, kv_input=memory, use_rope=False,
        )
        return x + out, mcache
    if mixer == "mamba":
        y, st = ssm.ssd_decode_step(lp["ssd"], cfg, h, mcache)
        return x + y, st
    if mixer == "slstm":
        y, st = ssm.slstm_decode_step(lp["cell"], cfg, h, mcache)
        return x + y, st
    if mixer == "mlstm":
        y, st = ssm.mlstm_decode_step(lp["cell"], cfg, h, mcache)
        return x + y, st
    raise ValueError(mixer)


# --------------------------------------------------------------------------
# Model assembly
# --------------------------------------------------------------------------

def build_model(cfg: ModelConfig, compute_dtype=jnp.bfloat16) -> Model:
    kinds = layer_kinds(cfg)
    period = layer_period(cfg)
    repeats = cfg.n_layers // period
    pkinds = kinds[:period]

    # ---------------- init ----------------
    def init(rng: jax.Array) -> Params:
        keys = jax.random.split(rng, 8)
        params: dict[str, Any] = {
            "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model),
            "norm": init_rms_norm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["out"] = init_embedding(keys[1], cfg.vocab_size, cfg.d_model)
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        stacks = []
        for j, (mixer, ffn) in enumerate(pkinds):
            per_repeat = []
            for rep in range(repeats):
                k = lkeys[rep * period + j]
                k1, k2 = jax.random.split(k)
                per_repeat.append(
                    {
                        "mixer": _init_mixer(k1, cfg, mixer),
                        "ffn": _init_ffn(k2, cfg, ffn),
                    }
                )
            stacks.append(_stack(per_repeat))
        params["layers"] = tuple(stacks)
        if cfg.n_enc_layers:
            ekeys = jax.random.split(keys[3], cfg.n_enc_layers)
            enc = [
                {
                    "mixer": _init_mixer(jax.random.split(k)[0], cfg, "attn"),
                    "ffn": _init_ffn(jax.random.split(k)[1], cfg, "mlp"),
                }
                for k in ekeys
            ]
            params["enc"] = {"layers": _stack(enc), "norm": init_rms_norm(cfg.d_model)}
        return params

    # ---------------- encoder (enc-dec only) ----------------
    def encode(params: Params, memory_in: jax.Array) -> jax.Array:
        """Non-causal encoder over stub frame embeddings [B, S, d]."""
        x = memory_in.astype(compute_dtype)
        B, S, _ = x.shape
        pos = jnp.arange(S)
        cos, sin = rope_tables(pos, cfg.head_dim, cfg.rope_theta)
        cos, sin = cos[None], sin[None]

        @jax.checkpoint
        def body_fn(carry, lp):
            h = rms_norm(lp["mixer"]["norm"], carry, cfg.norm_eps)
            y = carry + attention(
                lp["mixer"]["attn"], h, cos, sin, cfg.n_heads, cfg.n_kv_heads,
                cfg.head_dim, causal=False,
            )
            h2 = rms_norm(lp["ffn"]["norm"], y, cfg.norm_eps)
            y = y + mlp_swiglu(lp["ffn"]["mlp"], h2)
            y = shard(y, "act_hidden")
            return y, None

        def body(carry, lp):
            return body_fn(carry, lp)

        x, _ = jax.lax.scan(body, x, params["enc"]["layers"])
        return rms_norm(params["enc"]["norm"], x, cfg.norm_eps)

    # ---------------- hidden trunk ----------------
    def hidden(
        params: Params,
        tokens: jax.Array,                 # [B, S]
        memory: jax.Array | None = None,   # [B, T, d] frames/patches
    ) -> tuple[jax.Array, jax.Array]:
        """Final hidden states [B, S, d] and accumulated aux loss."""
        x = embed(params["embed"], tokens, compute_dtype)
        x = shard(x, "act_hidden")
        B, S, _ = x.shape
        pos = jnp.arange(S)
        cos, sin = rope_tables(pos, cfg.head_dim, cfg.rope_theta)
        cos, sin = cos[None], sin[None]
        mem = None
        if cfg.n_enc_layers:
            assert memory is not None, "enc-dec model needs frame embeddings"
            mem = encode(params, memory)
        elif memory is not None:
            mem = memory.astype(compute_dtype)

        def period_fn(x, stacked):
            # Activation-sharding mode is set by distribution.sharding
            # (act_in / act_mid / act_out rules); see §Perf iterations 2-7.
            x = shard(x, "act_in")
            aux = jnp.zeros((), jnp.float32)
            for j, (mixer, ffn) in enumerate(pkinds):
                lp = stacked[j]
                x = _apply_mixer(lp["mixer"], cfg, mixer, x, cos, sin, mem)
                x = shard(x, "act_mid")
                x, a = _apply_ffn(lp["ffn"], cfg, ffn, x)
                x = shard(x, "act_mid")
                aux = aux + a
            # The carry saved by remat across the layer scan.
            x = shard(x, "act_out")
            return x, aux

        # Rematerialize each period in the backward pass: activation memory
        # is one period's inputs per repeat instead of every intermediate.
        period_ckpt = jax.checkpoint(period_fn)

        def body(carry, stacked):
            x, aux = carry
            x, a = period_ckpt(x, stacked)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["layers"]
        )
        x = rms_norm(params["norm"], x, cfg.norm_eps)
        return x, aux

    def out_table(params: Params) -> jax.Array:
        return params["embed"] if cfg.tie_embeddings else params["out"]

    # ---------------- forward (logits; small-model / test path) ----------
    def forward(
        params: Params,
        tokens: jax.Array,
        memory: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array]:
        x, aux = hidden(params, tokens, memory)
        logits = unembed(out_table(params), x)
        logits = shard(logits, "act_logits")
        return logits, aux

    # ---------------- loss (vocab-safe chunked cross-entropy) -------------
    def loss(params: Params, batch: dict[str, jax.Array]) -> jax.Array:
        x, aux = hidden(params, batch["tokens"], memory=batch.get("memory"))
        labels = batch["labels"]
        mask = batch.get("mask")
        tbl = out_table(params)["table"]
        B, S, d = x.shape
        chunk = S
        for cand in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
            if S % cand == 0:
                chunk = cand
                break
        nc = S // chunk
        xs = x.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
        ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
        ms = (
            mask.reshape(B, nc, chunk).transpose(1, 0, 2)
            if mask is not None
            else jnp.ones((nc, B, chunk), jnp.float32)
        )

        @jax.checkpoint
        def chunk_ce(xc, lc, mc):
            lg = (xc @ tbl.astype(xc.dtype).T).astype(jnp.float32)
            lg = shard(lg, "act_logits")
            logz = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
            return jnp.sum((logz - gold) * mc), jnp.sum(mc)

        def body(carry, inp):
            tot, cnt = carry
            s, c = chunk_ce(*inp)
            return (tot + s, cnt + c), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(())), (xs, ls, ms)
        )
        return tot / jnp.maximum(cnt, 1.0) + AUX_COEF * aux

    # ---------------- prefill (serving: last-position logits) -------------
    def prefill(
        params: Params,
        tokens: jax.Array,
        memory: jax.Array | None = None,
    ) -> jax.Array:
        x, _ = hidden(params, tokens, memory)
        logits = unembed(out_table(params), x[:, -1:, :])
        return logits

    # ---------------- decode ----------------
    def init_cache(
        batch: int, max_len: int, memory: jax.Array | None = None
    ) -> dict[str, Any]:
        layer_caches = []
        for j, (mixer, ffn) in enumerate(pkinds):
            per_repeat = [
                _mixer_cache(cfg, mixer, batch, max_len) for _ in range(repeats)
            ]
            layer_caches.append(_stack(per_repeat) if per_repeat[0] else {})
        return {
            "pos": jnp.zeros((), jnp.int32),
            "layers": tuple(layer_caches),
            "memory": memory,
        }

    def decode_step(
        params: Params, cache: dict[str, Any], token: jax.Array  # [B]
    ) -> tuple[jax.Array, dict[str, Any]]:
        x = embed(params["embed"], token[:, None], compute_dtype)  # [B,1,d]
        pos = cache["pos"]
        mem = cache.get("memory")
        if mem is not None:
            mem = mem.astype(compute_dtype)

        # Mirror forward's layer order exactly: scan over REPEATS with the
        # whole period applied inside the body (period positions interleave
        # within each repeat; iterating positions as the outer loop would
        # reorder the layers for period > 1 architectures).
        def body(x, sc):
            lps, mcs = sc  # tuples over period positions, sliced per repeat
            new_mcs = []
            for j, (mixer, ffn) in enumerate(pkinds):
                x, mc = _decode_mixer(
                    lps[j]["mixer"], cfg, mixer, x, pos, mcs[j], mem
                )
                x, _ = _apply_ffn(lps[j]["ffn"], cfg, ffn, x)
                new_mcs.append(mc)
            return x, tuple(new_mcs)

        if repeats > 1:
            x, new_layers = jax.lax.scan(
                body, x, (params["layers"], cache["layers"])
            )
        else:
            take0 = lambda t: jax.tree.map(lambda a: a[0], t)
            x, c0 = body(
                x, (take0(params["layers"]), take0(cache["layers"]))
            )
            new_layers = jax.tree.map(lambda a: a[None], c0)

        x = rms_norm(params["norm"], x, cfg.norm_eps)
        out_tbl = params["embed"] if cfg.tie_embeddings else params["out"]
        logits = unembed(out_tbl, x)
        new_cache = {
            "pos": pos + 1,
            "layers": tuple(new_layers),
            "memory": cache.get("memory"),
        }
        return logits, new_cache

    return Model(
        cfg=cfg,
        init=init,
        forward=forward,
        init_cache=init_cache,
        decode_step=decode_step,
        loss=loss,
        encode=encode if cfg.n_enc_layers else None,
        hidden=hidden,
        prefill=prefill,
    )


# --------------------------------------------------------------------------
# Parameter accounting (used by the roofline's MODEL_FLOPS = 6·N·D)
# --------------------------------------------------------------------------

def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_param_fraction(cfg: ModelConfig) -> float:
    """Fraction of FFN params active per token (MoE top-k / E); 1.0 dense."""
    if not cfg.n_experts:
        return 1.0
    # Count MoE vs non-MoE parameter volumes analytically.
    kinds = layer_kinds(cfg)
    moe_layers = sum(1 for _, f in kinds if f == "moe")
    mlp_layers = sum(1 for _, f in kinds if f == "mlp")
    per_expert = 3 * cfg.d_model * cfg.d_ff
    moe_total = moe_layers * cfg.n_experts * per_expert
    moe_active = moe_layers * cfg.experts_per_token * per_expert
    rest = mlp_layers * per_expert  # dense MLP layers
    # Attention/mamba/embed params are always active; approximate by
    # computing them as total - moe_total via callers that know totals.
    return (moe_active + rest) / max(moe_total + rest, 1)
