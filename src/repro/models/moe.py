"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

TPU-native formulation: tokens are routed top-k, then dispatched into a
dense [E, C, d] expert buffer via scatter (NOT the O(T·E·C) one-hot einsum,
which is memory-infeasible at production token counts). Expert FFNs run as
one batched einsum over the expert dimension, which shards cleanly over the
``model`` mesh axis (expert parallelism); XLA inserts the all-to-all at the
dispatch/combine boundaries.

Over-capacity tokens are dropped (standard capacity-factor semantics); the
auxiliary load-balancing loss keeps routing near-uniform so drops are rare.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_linear, shard

Params = dict[str, Any]

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key: jax.Array, d_model: int, d_ff: int, n_experts: int) -> Params:
    ks = jax.random.split(key, 4)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "router": init_linear(ks[0], d_model, n_experts, scale=0.02),
        "wi": jax.random.normal(ks[1], (n_experts, d_model, d_ff), jnp.float32) * s_in,
        "wg": jax.random.normal(ks[2], (n_experts, d_model, d_ff), jnp.float32) * s_in,
        "wo": jax.random.normal(ks[3], (n_experts, d_ff, d_model), jnp.float32) * s_out,
    }


def moe_ffn(
    params: Params,
    x: jax.Array,  # [B, S, d]
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    normalize: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B, S, d], aux load-balance loss scalar)."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf @ params["router"]["w"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # [T, k]
    if normalize:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Auxiliary load-balancing loss (Switch-style).
    me = jnp.mean(probs, axis=0)  # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.float32), axis=1),
        axis=0,
    )
    aux = n_experts * jnp.sum(me * ce)

    capacity = int(np.ceil(T * top_k / n_experts * capacity_factor))
    capacity = max(capacity, top_k)

    # Position of each (token, k) pair within its expert's buffer.
    flat_expert = expert_idx.reshape(T * top_k)  # row-major: pair p = t*k + j
    onehot = jax.nn.one_hot(flat_expert, n_experts, dtype=jnp.int32)  # [TK, E]
    pos_all = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos_all, flat_expert[:, None], axis=1)[:, 0]
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, 0)

    token_of_pair = jnp.arange(T * top_k) // top_k
    gathered = xf[token_of_pair]  # [TK, d]
    gathered = jnp.where(keep[:, None], gathered, 0)

    expert_in = jnp.zeros((n_experts, capacity, d), dtype=x.dtype)
    expert_in = expert_in.at[flat_expert, pos_c].add(gathered)
    expert_in = shard(expert_in, "act_expert")

    # Batched expert FFN (SwiGLU).
    wi = params["wi"].astype(x.dtype)
    wg = params["wg"].astype(x.dtype)
    wo = params["wo"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, wg))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, wi)
    h = shard(h, "act_expert_ffn")
    expert_out = jnp.einsum("ecf,efd->ecd", h, wo)  # [E, C, d]

    out_pairs = expert_out[flat_expert, pos_c]  # [TK, d]
    out_pairs = out_pairs * (
        gate_vals.reshape(T * top_k, 1).astype(x.dtype)
        * keep[:, None].astype(x.dtype)
    )
    out = out_pairs.reshape(T, top_k, d).sum(axis=1)
    return out.reshape(B, S, d), aux
