"""Memory-tiled (flash) attention in pure JAX with a custom VJP.

Why this exists: XLA materializes the full [B, H, S, S] score tensor for
einsum attention — 137 GB/device for deepseek-67b at S=4096 — so both the
CPU dry-run and the TPU target need blockwise attention with online softmax
and block-recomputed backward. This implementation scans over KV blocks with
O(B·S·H·D) carry and is the numerical REFERENCE for the Pallas flash kernel
(same blocking scheme, same stabilization); kernels/flash_attention.py is
the TPU-optimized twin validated against it.

Forward saves only (o, lse); backward re-walks KV blocks recomputing scores
(flash-attention-2 style).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _blockify(x: jax.Array, block: int, axis: int) -> jax.Array:
    """[..., T, ...] -> [..., T//block, block, ...] moved to leading scan axis."""
    T = x.shape[axis]
    nb = T // block
    shape = x.shape[:axis] + (nb, block) + x.shape[axis + 1 :]
    x = x.reshape(shape)
    return jnp.moveaxis(x, axis, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, T, KV, D]
    v: jax.Array,  # [B, T, KV, D]
    causal: bool = True,
    block_kv: int = 512,
) -> jax.Array:
    o, _ = _flash_fwd_inner(q, k, v, causal, block_kv)
    return o


def _flash_fwd_inner(q, k, v, causal, block_kv):
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(block_kv, T)
    assert T % bk == 0, (T, bk)
    scale = 1.0 / np.sqrt(D)

    qg = q.reshape(B, S, KV, G, D)
    kb = _blockify(k, bk, 1)  # [nb, B, bk, KV, D]
    vb = _blockify(v, bk, 1)

    o0 = jnp.zeros((B, S, KV, G, D), jnp.float32)
    m0 = jnp.full((B, S, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, KV, G), jnp.float32)
    qi = jnp.arange(S)

    def body(carry, inp):
        o, m, l, jb = carry[0], carry[1], carry[2], carry[3]
        kj, vj = inp
        s = (
            jnp.einsum("bskgd,btkd->bskgt", qg, kj).astype(jnp.float32) * scale
        )  # [B,S,KV,G,bk]
        if causal:
            kj_idx = jb * bk + jnp.arange(bk)
            mask = qi[:, None] >= kj_idx[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        o = o * alpha[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", p.astype(vj.dtype), vj
        ).astype(jnp.float32)
        return (o, m_new, l, jb + 1), None

    (o, m, l, _), _ = jax.lax.scan(body, (o0, m0, l0, jnp.zeros((), jnp.int32)), (kb, vb))
    l = jnp.maximum(l, 1e-30)
    out = (o / l[..., None]).astype(q.dtype).reshape(B, S, H, D)
    lse = (m + jnp.log(l)).reshape(B, S, H)  # logsumexp per query
    return out, lse


def _flash_fwd(q, k, v, causal, block_kv):
    o, lse = _flash_fwd_inner(q, k, v, causal, block_kv)
    # The residuals of a custom_vjp are OPAQUE to jax.checkpoint (they are
    # always stored across the layer scan). Constrain them explicitly so the
    # stored buffers shard on the model axis — without this GSPMD may store
    # them replicated (~64 MB/layer each at deepseek scale).
    from repro.models.layers import shard

    q = shard(q, "act_heads")
    k = shard(k, "act_heads")
    v = shard(v, "act_heads")
    o = shard(o, "act_heads")
    lse = shard(lse, "act_lse")
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, block_kv, res, do):
    q, k, v, o, lse = res
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(block_kv, T)
    scale = 1.0 / np.sqrt(D)

    qg = q.reshape(B, S, KV, G, D)
    dog = do.reshape(B, S, KV, G, D)
    lseg = lse.reshape(B, S, KV, G)
    # delta_i = rowsum(dO_i * O_i)
    delta = jnp.sum(
        do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1
    ).reshape(B, S, KV, G)

    kb = _blockify(k, bk, 1)
    vb = _blockify(v, bk, 1)
    qi = jnp.arange(S)

    def body(dq_acc, inp):
        jb, kj, vj = inp
        s = (
            jnp.einsum("bskgd,btkd->bskgt", qg, kj).astype(jnp.float32) * scale
        )
        if causal:
            kj_idx = jb * bk + jnp.arange(bk)
            mask = qi[:, None] >= kj_idx[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lseg[..., None])  # [B,S,KV,G,bk]
        pv = p.astype(v.dtype)
        dv_j = jnp.einsum("bskgt,bskgd->btkd", pv, dog)
        dp = jnp.einsum("bskgd,btkd->bskgt", dog, vj).astype(jnp.float32)
        ds = p * (dp - delta[..., None]) * scale
        dsv = ds.astype(q.dtype)
        dq_acc = dq_acc + jnp.einsum("bskgt,btkd->bskgd", dsv, kj).astype(
            jnp.float32
        )
        dk_j = jnp.einsum("bskgt,bskgd->btkd", dsv, qg)
        return dq_acc, (dk_j, dv_j)

    nb = T // bk
    dq0 = jnp.zeros((B, S, KV, G, D), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(
        body, dq0, (jnp.arange(nb), kb, vb)
    )
    dq = dq.astype(q.dtype).reshape(B, S, H, D)
    dk = jnp.moveaxis(dk_b, 0, 1).reshape(B, T, KV, D).astype(k.dtype)
    dv = jnp.moveaxis(dv_b, 0, 1).reshape(B, T, KV, D).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)
