"""Unified model configuration covering all assigned architecture families.

A model is a stack of (mixer, ffn) layer kinds:
  mixer ∈ {attn, attn_cross, mamba, slstm, mlstm}
  ffn   ∈ {mlp, moe, none}
plus an optional non-causal encoder stack (audio/enc-dec) and stubbed
modality frontends (audio frames / vision patches arrive as precomputed
embeddings via input_specs — see launch.dryrun).

The layer-kind sequence is derived from the family fields below and then
grouped into its smallest repeating period so the runtime can scan over
stacked parameter periods (keeps HLO size independent of depth).
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "layer_kinds", "layer_period"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # apply MoE FFN on layers where (i % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_normalize: bool = True

    # Hybrid (jamba): attention on layers where (i % attn_every == attn_offset),
    # mamba elsewhere. attn_every == 0 -> all layers attention.
    attn_every: int = 0
    attn_offset: int = 0

    # SSM (mamba/SSD)
    ssm_expand: int = 2
    ssm_state_dim: int = 64
    ssm_conv_dim: int = 4
    ssm_heads: int = 8  # SSD heads (scalar-decay-per-head)
    ssm_chunk: int = 256

    # xLSTM: alternate sLSTM / mLSTM with this period (0 = not xlstm)
    xlstm_slstm_every: int = 0

    # Encoder-decoder (audio): non-causal encoder depth; 0 = decoder-only.
    n_enc_layers: int = 0

    # VLM: cross-attention layers every k-th layer (0 = none)
    cross_attn_every: int = 0
    cross_attn_offset: int = 0
    n_patches: int = 1600  # stub vision frontend sequence length

    # serving
    max_seq_len: int = 32768

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_head_dim(self) -> int:
        return self.d_inner // self.ssm_heads


def layer_kinds(cfg: ModelConfig) -> list[tuple[str, str]]:
    """Per-layer (mixer, ffn) kinds for the DECODER stack."""
    kinds: list[tuple[str, str]] = []
    for i in range(cfg.n_layers):
        # Mixer selection.
        if cfg.xlstm_slstm_every:
            mixer = "slstm" if i % cfg.xlstm_slstm_every == 0 else "mlstm"
        elif cfg.attn_every:
            mixer = "attn" if i % cfg.attn_every == cfg.attn_offset else "mamba"
        elif cfg.cross_attn_every and i % cfg.cross_attn_every == cfg.cross_attn_offset:
            mixer = "cross"  # cross-attention-only block (Mllama style)
        elif cfg.n_enc_layers:
            mixer = "attn_cross"  # every decoder layer self- AND cross-attends
        else:
            mixer = "attn"
        # FFN selection.
        if cfg.xlstm_slstm_every:
            ffn = "none"  # xLSTM blocks integrate their projections
        elif cfg.n_experts and i % cfg.moe_every == cfg.moe_offset:
            ffn = "moe"
        else:
            ffn = "mlp"
        kinds.append((mixer, ffn))
    return kinds


def layer_period(cfg: ModelConfig) -> int:
    """Smallest period p with kinds[i] == kinds[i % p] and p | n_layers."""
    kinds = layer_kinds(cfg)
    n = len(kinds)
    for p in range(1, n + 1):
        if n % p:
            continue
        if all(kinds[i] == kinds[i % p] for i in range(n)):
            return p
    return n
