"""Core neural layers: RMSNorm, RoPE, GQA attention, SwiGLU — pure JAX.

Conventions:
  * Params are nested dicts of jnp arrays; every layer has init_*/apply_*.
  * Compute runs in ``compute_dtype`` (bf16 by default) with fp32 softmax
    and norm statistics; params are stored in fp32 for training.
  * Activation sharding is injected via `shard` hooks that consult the
    ambient policy installed by repro.distribution.sharding — models stay
    distribution-agnostic.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "shard",
    "activation_sharding",
    "rms_norm",
    "init_rms_norm",
    "init_linear",
    "linear",
    "rope_tables",
    "apply_rope",
    "init_attention",
    "attention",
    "decode_attention",
    "init_mlp",
    "mlp_swiglu",
    "init_embedding",
]

Params = dict[str, Any]

_TLS = threading.local()


def _rules() -> dict[str, Any]:
    return getattr(_TLS, "rules", None) or {}


@contextlib.contextmanager
def activation_sharding(rules: dict[str, Any]):
    """Install logical-activation -> PartitionSpec rules (see distribution)."""
    old = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield
    finally:
        _TLS.rules = old


def shard(x: jax.Array, name: str) -> jax.Array:
    """Apply the ambient sharding constraint for logical activation ``name``.

    Constraints degrade per-dimension: any mesh axis whose extent does not
    divide the corresponding dimension is dropped (e.g. batch=1 long-context
    cells cannot shard batch). Rank mismatches skip the constraint entirely.
    """
    sh = _rules().get(name)
    if sh is None:
        return x
    import numpy as _np
    from jax.sharding import NamedSharding, PartitionSpec

    mesh = sh.mesh
    parts = list(sh.spec) + [None] * (x.ndim - len(sh.spec))
    if len(parts) != x.ndim:
        return x

    def axsize(a):
        if a is None:
            return 1
        if isinstance(a, tuple):
            return int(_np.prod([mesh.shape[n] for n in a]))
        return int(mesh.shape[a])

    fitted = [
        a if a is not None and d % axsize(a) == 0 else None
        for d, a in zip(x.shape, parts)
    ]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*fitted))
    )


# --------------------------------------------------------------------------
# Norms / projections
# --------------------------------------------------------------------------

def init_rms_norm(d: int) -> Params:
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rms_norm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with fp32 statistics via a mixed-precision reduction.

    Deliberately avoids `x.astype(f32)` on the full tensor: that standalone
    convert is loop-invariant-hoisted by XLA out of the backward layer scan,
    materializing an fp32 copy of EVERY saved layer input at once (+12 GiB
    per device at deepseek-67b scale — §Perf iteration 5). The einsum
    accumulates in fp32 directly; only the per-token inverse-RMS scalar is
    rounded back to the compute dtype.
    """
    var = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    ) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv[..., None] * params["scale"].astype(x.dtype)


def init_linear(
    key: jax.Array, d_in: int, d_out: int, bias: bool = False, scale: float | None = None
) -> Params:
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    p: Params = {
        "w": jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    }
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=jnp.float32)
    return p


def linear(params: Params, x: jax.Array) -> jax.Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_tables(
    positions: jax.Array, head_dim: int, theta: float
) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables [..., head_dim//2] for integer positions."""
    half = head_dim // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., S, H, D]; cos/sin: [..., S, D//2] broadcast over heads."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


# --------------------------------------------------------------------------
# Attention (GQA, causal/full/cross) — XLA einsum path.
# The Pallas flash kernel (repro.kernels) is an interchangeable drop-in for
# the inner softmax(QK^T)V; launch-time flag selects it on real TPUs.
# --------------------------------------------------------------------------

def init_attention(
    key: jax.Array,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    qkv_bias: bool = False,
) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d_model, n_heads * head_dim, bias=qkv_bias),
        "wk": init_linear(ks[1], d_model, n_kv_heads * head_dim, bias=qkv_bias),
        "wv": init_linear(ks[2], d_model, n_kv_heads * head_dim, bias=qkv_bias),
        "wo": init_linear(ks[3], n_heads * head_dim, d_model),
    }


def _sdpa(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, T, KV, D]
    v: jax.Array,  # [B, T, KV, D]
    causal: bool,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
) -> jax.Array:
    """Grouped scaled-dot-product attention, fp32 softmax.

    q_offset: absolute position of q[0] (for causal masking of suffixes).
    kv_len: optional number of valid kv positions (decode with cache).
    """
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    scale = 1.0 / np.sqrt(D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if causal:
        qi = jnp.arange(S)[:, None] + q_offset
        ki = jnp.arange(T)[None, :]
        mask = qi >= ki
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    if kv_len is not None:
        valid = jnp.arange(T) < kv_len  # [T]
        logits = jnp.where(valid[None, None, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, D)


def attention(
    params: Params,
    x: jax.Array,            # [B, S, d_model]
    cos: jax.Array,
    sin: jax.Array,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    causal: bool = True,
    kv_input: jax.Array | None = None,  # cross-attention source [B, T, d]
    use_rope: bool = True,
) -> jax.Array:
    B, S, _ = x.shape
    src = x if kv_input is None else kv_input
    T = src.shape[1]
    q = linear(params["wq"], x).reshape(B, S, n_heads, head_dim)
    k = linear(params["wk"], src).reshape(B, T, n_kv_heads, head_dim)
    v = linear(params["wv"], src).reshape(B, T, n_kv_heads, head_dim)
    if use_rope and kv_input is None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = shard(q, "act_heads")
    if S > 1:
        # Memory-tiled attention: O(S·D) residency instead of O(S^2).
        # KV heads are expanded to full heads first: the flat [B, *, H, D]
        # layout keeps every flash residual (q, k, v, o) cleanly sharded on
        # the 'model' axis — the grouped (KV, G) layout is unshardable when
        # KV < mesh model extent and would store residuals replicated.
        from repro.models.flash import flash_attention

        g = n_heads // n_kv_heads
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        k = shard(k, "act_heads")
        v = shard(v, "act_heads")
        bk = 512
        while T % bk:
            bk //= 2
        out = flash_attention(q, k, v, causal and kv_input is None, max(bk, 1))
    else:
        out = _sdpa(q, k, v, causal=causal and kv_input is None)
    out = out.reshape(B, S, n_heads * head_dim)
    return linear(params["wo"], out)


def decode_attention(
    params: Params,
    x: jax.Array,            # [B, 1, d_model]
    pos: jax.Array,          # [] current position
    cache_k: jax.Array,      # [B, T_max, KV, D]
    cache_v: jax.Array,
    rope_theta: float,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    cross: bool = False,
    kv_len: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token attention against a KV cache; returns (out, k', v')."""
    B = x.shape[0]
    q = linear(params["wq"], x).reshape(B, 1, n_heads, head_dim)
    cos, sin = rope_tables(pos[None], head_dim, rope_theta)
    q = apply_rope(q, cos[None], sin[None])
    if not cross:
        k_new = linear(params["wk"], x).reshape(B, 1, n_kv_heads, head_dim)
        v_new = linear(params["wv"], x).reshape(B, 1, n_kv_heads, head_dim)
        k_new = apply_rope(k_new, cos[None], sin[None])
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), pos, axis=1
        )
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), pos, axis=1
        )
        valid = pos + 1
    else:
        valid = kv_len if kv_len is not None else cache_k.shape[1]
    out = _sdpa(
        q,
        cache_k.astype(x.dtype),
        cache_v.astype(x.dtype),
        causal=False,
        kv_len=valid,
    )
    out = out.reshape(B, 1, n_heads * head_dim)
    return linear(params["wo"], out), cache_k, cache_v


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def init_mlp(key: jax.Array, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wi": init_linear(ks[0], d_model, d_ff),
        "wg": init_linear(ks[1], d_model, d_ff),
        "wo": init_linear(ks[2], d_ff, d_model),
    }


def mlp_swiglu(params: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(linear(params["wg"], x)) * linear(params["wi"], x)
    h = shard(h, "act_ffn")
    return linear(params["wo"], h)


# --------------------------------------------------------------------------
# Embedding
# --------------------------------------------------------------------------

def init_embedding(key: jax.Array, vocab: int, d_model: int) -> Params:
    return {
        "table": jax.random.normal(key, (vocab, d_model), dtype=jnp.float32)
        * 0.02
    }


def embed(params: Params, tokens: jax.Array, dtype) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params: Params, x: jax.Array) -> jax.Array:
    return x @ params["table"].astype(x.dtype).T
