"""Step builders: train_step (grad-accumulated AdamW), prefill_step,
serve_step. These are the functions the launcher jits/lowers; each is a pure
function of (state, batch) suitable for pjit with NamedShardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.lm import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.grad import accumulate_grads, compress_bf16

__all__ = ["TrainState", "make_train_state", "build_train_step", "build_prefill_step", "build_serve_step"]

Params = Any


@dataclasses.dataclass
class TrainState:
    params: Params
    opt: dict[str, Any]
    residual: Params | None = None  # error-feedback state (compression on)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.residual), None),
    lambda _, c: TrainState(params=c[0], opt=c[1], residual=c[2]),
)


def make_train_state(
    model: Model, rng: jax.Array, compress: bool = False
) -> TrainState:
    params = model.init(rng)
    residual = (
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if compress
        else None
    )
    return TrainState(params=params, opt=adamw_init(params), residual=residual)


def build_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    n_micro: int = 1,
    compress_grads: bool = False,
    cast_params_bf16: bool = False,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``n_micro`` splits the (local) batch into microbatches accumulated via
    lax.scan — the standard activation-memory lever at scale. With
    ``compress_grads`` the accumulated gradient is bf16-compressed with
    fp32 error feedback before the (XLA-inserted) data-parallel reduction.
    ``cast_params_bf16`` casts the parameter tree once at loss entry so
    FSDP parameter all-gathers move bf16 instead of fp32 (§Perf iteration:
    halves forward gather volume; fp32 masters stay in the optimizer).
    """

    def loss_fn(params, mb):
        if cast_params_bf16:
            params = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if x.dtype == jnp.float32
                else x,
                params,
            )
        return model.loss(params, mb)

    def train_step(state: TrainState, batch: dict[str, jax.Array]):
        if n_micro > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch,
            )
            loss, grads = accumulate_grads(loss_fn, state.params, micro)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        residual = state.residual
        if compress_grads:
            grads, residual = compress_bf16(grads, residual)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        params, opt, metrics = adamw_update(opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=params, opt=opt, residual=residual), metrics

    return train_step


def build_prefill_step(model: Model) -> Callable:
    """prefill_step(params, batch) -> last-position logits [B, 1, V]."""

    def prefill_step(params: Params, batch: dict[str, jax.Array]):
        return model.prefill(params, batch["tokens"], memory=batch.get("memory"))

    return prefill_step


def build_serve_step(model: Model, serve_bf16: bool = False) -> Callable:
    """serve_step(params, cache, token) -> (logits, cache): one decode step.

    ``serve_bf16`` casts fp32 parameters to bf16 at entry — on TPU this
    halves the per-layer FSDP gather bytes (the decode-cell bottleneck).
    Default False for the dry-run: the CPU backend's FloatNormalization
    re-upcasts the gathers (measured neutral) while the hoisted cast adds a
    full bf16 parameter copy to peak memory (§Perf, measured +1 GiB).
    Deployments on real bf16 hardware should enable it.
    """

    def serve_step(params: Params, cache: dict[str, Any], token: jax.Array):
        if serve_bf16:
            params = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if x.dtype == jnp.float32
                else x,
                params,
            )
        return model.decode_step(params, cache, token)

    return serve_step
