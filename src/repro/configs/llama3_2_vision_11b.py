"""Llama-3.2-Vision-11B text backbone — cross-attention VLM
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40 layers, d_model 4096, 32 heads GQA kv=8, d_ff 14336, vocab 128256.
Cross-attention to vision patch embeddings every 5th layer (offset 3).
The vision tower is a STUB: input_specs() provides [B, n_patches, d_model]
precomputed patch embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    cross_attn_every=5,
    cross_attn_offset=3,
    n_patches=1600,
)
