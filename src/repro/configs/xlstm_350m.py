"""xLSTM-350M — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24 layers, d_model 1024, 4 heads, vocab 50304, no separate FFN (the xLSTM
blocks integrate up/down projections). Alternating sLSTM / mLSTM stacking.
Recurrent O(1) state => long_500k decode RUNS for this arch.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm_slstm_every=2,
    tie_embeddings=True,
    max_seq_len=524288,
)
