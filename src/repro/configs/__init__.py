"""Assigned-architecture registry: one module per architecture.

Every module defines CONFIG (the full, paper-exact configuration) and the
registry provides reduced smoke variants that preserve the layer-kind
structure (same family, same period pattern) at toy dimensions.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "deepseek_67b",
    "qwen1_5_4b",
    "llama3_2_3b",
    "phi3_mini_3_8b",
    "xlstm_350m",
    "seamless_m4t_medium",
    "jamba_v0_1_52b",
    "llama3_2_vision_11b",
    "dbrx_132b",
    "phi3_5_moe_42b",
]

# Aliases matching the assignment spelling.
ALIASES = {
    "deepseek-67b": "deepseek_67b",
    "qwen1.5-4b": "qwen1_5_4b",
    "llama3.2-3b": "llama3_2_3b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "xlstm-350m": "xlstm_350m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "dbrx-132b": "dbrx_132b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
}


def get_config(name: str) -> ModelConfig:
    name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown architecture {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    """Reduced config preserving the family structure at toy scale."""
    cfg = get_config(name)
    period = max(1, _period(cfg))
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_heads = 4
    n_kv = max(1, n_heads // min(ratio, n_heads))
    return dataclasses.replace(
        cfg,
        n_layers=2 * period,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        n_experts=4 if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.n_experts else 0,
        # Generous capacity so smoke tests exercise the no-drop regime
        # (capacity drops make decode/forward legitimately diverge; capacity
        # behaviour has its own dedicated test).
        capacity_factor=8.0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        n_patches=16 if cfg.cross_attn_every else cfg.n_patches,
        ssm_heads=4,
        ssm_state_dim=16,
        ssm_chunk=16,
        max_seq_len=128,
    )


def _period(cfg: ModelConfig) -> int:
    from repro.models.config import layer_period

    return layer_period(cfg)
