"""Qwen1.5-4B — dense LM with QKV bias [hf:Qwen/Qwen1.5 family; hf].

40 layers, d_model 2560, 20 heads (MHA expressed as GQA kv=20), d_ff 6912,
vocab 151936. Qwen attention projections carry bias terms.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
)
