"""Llama-3.2-3B — small llama3 [hf:meta-llama/Llama-3.2 family; unverified].

28 layers, d_model 3072, 24 heads GQA kv=8, d_ff 8192, vocab 128256.
Llama-3.2 ties input/output embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    tie_embeddings=True,
)
