"""Phi-3-mini-3.8B — dense LM, RoPE + SwiGLU + GQA(32/32) [arXiv:2404.14219].

32 layers, d_model 3072, 32 heads kv=32, d_ff 8192, vocab 32064.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
)
