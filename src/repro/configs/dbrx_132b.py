"""DBRX-132B — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base].

40 layers, d_model 6144, 48 heads GQA kv=8, expert d_ff 10752, vocab 100352,
MoE on every layer.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    experts_per_token=4,
    rope_theta=500000.0,
)
