"""Jamba-v0.1-52B — hybrid Mamba+attention 7:1 with MoE [arXiv:2403.19887; hf].

32 layers, d_model 4096, attention every 8th layer (offset 3 -> layers
3,11,19,27 are attention; kv=8 GQA), Mamba elsewhere; MoE (16 experts,
top-2) on every other layer, dense MLP d_ff 14336 otherwise. vocab 65536.
Hardware adaptation (DESIGN.md): Mamba-1 selective scan is realized as
Mamba-2-style SSD chunked scan (MXU-friendly matmul formulation).
Mamba state + 4 attention layers => long_500k decode RUNS.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=3,
    ssm_expand=2,
    ssm_state_dim=16,
    ssm_heads=64,
    ssm_chunk=256,
    rope_theta=10000.0,
    max_seq_len=524288,
)
