"""SeamlessM4T-medium text backbone — encoder-decoder [arXiv:2308.11596; hf].

12 encoder + 12 decoder layers, d_model 1024, 16 heads kv=16, d_ff 4096,
vocab 256206. The audio frontend (speech encoder frame features) is a STUB:
input_specs() provides precomputed [B, S, d_model] frame embeddings.
Hardware adaptation (DESIGN.md): relative/conformer position handling is
replaced by RoPE on the TPU-native backbone.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,          # decoder depth
    n_enc_layers=12,      # encoder depth
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    rope_theta=10000.0,
    tie_embeddings=True,
)
