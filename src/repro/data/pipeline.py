"""Deterministic synthetic token pipeline.

Stateless by construction: ``batch_for_step(step)`` is a pure function of
(seed, step, shape), so checkpoint restart resumes the exact data stream with
no pipeline state to save — the fault-tolerance contract of the framework.
Host-sharding is positional: each data-parallel host slices its rows.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticTokens", "make_pipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    memory_len: int = 0   # >0: also emit stub frame/patch embeddings
    d_model: int = 0


class SyntheticTokens:
    """Zipf-ish synthetic LM stream with next-token labels."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf-like unigram distribution fixed by seed (structured enough
        # that loss decreases during the e2e example runs).
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks
        probs /= probs.sum()
        self._probs = probs
        self._perm = rng.permutation(cfg.vocab_size)

    def batch_for_step(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        base = rng.choice(
            cfg.vocab_size, size=(cfg.global_batch, cfg.seq_len + 1), p=self._probs
        )
        toks = self._perm[base]
        # Inject a copy pattern so models can actually learn something.
        half = cfg.seq_len // 2
        toks[:, half + 1 : cfg.seq_len + 1] = toks[:, 1 : cfg.seq_len - half + 1]
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if cfg.memory_len:
            batch["memory"] = rng.standard_normal(
                (cfg.global_batch, cfg.memory_len, cfg.d_model)
            ).astype(np.float32)
        return batch

    def host_shard(
        self, batch: dict[str, np.ndarray], host_id: int, n_hosts: int
    ) -> dict[str, np.ndarray]:
        per = self.cfg.global_batch // n_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in batch.items()}


def make_pipeline(cfg: DataConfig) -> SyntheticTokens:
    return SyntheticTokens(cfg)
