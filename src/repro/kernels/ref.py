"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ref_flash_attention",
    "ref_decode_attention",
    "ref_critical_path",
    "ref_combined_lb",
]


def ref_flash_attention(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, T, KV, D]
    v: jax.Array,
    causal: bool = True,
) -> jax.Array:
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    s = (
        jnp.einsum("bskgd,btkd->bskgt", qg.astype(jnp.float32), k.astype(jnp.float32))
        / np.sqrt(D)
    )
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgt,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def ref_decode_attention(
    q: jax.Array,       # [B, H, D]
    k: jax.Array,       # [B, T, KV, D]
    v: jax.Array,
    kv_len: jax.Array,  # [] or [B]
) -> jax.Array:
    B, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    s = (
        jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32), k.astype(jnp.float32))
        / np.sqrt(D)
    )
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    valid = jnp.arange(T)[None, :] < kv_len[:, None]  # [B, T]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def ref_critical_path(w: np.ndarray) -> np.ndarray:
    """Bellman longest-path over max-plus adjacency. w: [B, n, n]."""
    w = np.asarray(w, dtype=np.float64)
    B, n, _ = w.shape
    dist = np.zeros((B, n))
    for _ in range(n - 1):
        cand = dist[:, :, None] + w  # [B, u, v]
        dist = np.maximum(dist, cand.max(axis=1))
    return dist.astype(np.float32)


def ref_combined_lb(
    w: np.ndarray,      # [B, n, n] max-plus adjacency (-inf = no edge)
    p: np.ndarray,      # [B, n] per-row task durations (0 on padding)
    extra: np.ndarray,  # [B] contention bound terms (-inf to disable)
    mask: np.ndarray | None = None,  # [B, n, n] feasibility uplift (>= 0)
) -> np.ndarray:
    """Oracle for the fused §IV-A combined stage-1 bound kernel.

    lb[b] = max(max_v dist[b, v] + p[b, v], extra[b]); all-padding rows
    (no edges, zero durations, -inf extra) yield exactly 0. ``mask`` is
    the additive matching-feasibility mask: the longest path is taken
    over ``w + mask`` (0 where the optimistic network cost is reachable,
    the forced-wired uplift where the topology forbids it); -inf no-edge
    entries stay no-edges.
    """
    w = np.asarray(w, dtype=np.float64)
    if mask is not None:
        w = np.where(np.isfinite(w), w + np.asarray(mask, np.float64), w)
    dist = ref_critical_path(w).astype(np.float64)
    p = np.asarray(p, dtype=np.float64)
    extra = np.asarray(extra, dtype=np.float64).reshape(-1)
    lb = np.maximum((dist + p).max(axis=1), extra)
    return lb.astype(np.float32)
