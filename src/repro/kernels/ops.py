"""Public jit'd wrappers for the Pallas kernels.

On CPU hosts (this container, and any unit-test environment) the kernels run
in ``interpret=True`` mode — the kernel body executes as traced JAX ops, so
correctness is identical while TPU Mosaic lowering is exercised only on real
hardware. The wrapper picks the mode from the default backend.
"""

from __future__ import annotations

import jax


def tpu_compiler_params(dimension_semantics: tuple[str, ...]):
    """Version-compat constructor for Pallas TPU compiler params.

    Newer JAX exposes ``pltpu.CompilerParams``; the pinned 0.4.x series calls
    it ``TPUCompilerParams``.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(dimension_semantics=dimension_semantics)


from repro.kernels.cpm import batched_combined_lb as _combined_lb
from repro.kernels.cpm import batched_critical_path as _cpm
from repro.kernels.decode_attention import decode_attention_fwd as _decode
from repro.kernels.flash_attention import flash_attention_fwd as _flash

__all__ = [
    "flash_attention",
    "decode_attention",
    "batched_critical_path",
    "batched_combined_lb",
    "tpu_compiler_params",
]


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, causal=True, block_q=128, block_kv=128):
    return _flash(
        q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=_interpret(),
    )


def decode_attention(q, k, v, kv_len, block_kv=512):
    return _decode(q, k, v, kv_len, block_kv=block_kv, interpret=_interpret())


def batched_critical_path(w, block_b=8, n_iters=None):
    return _cpm(w, block_b=block_b, n_iters=n_iters, interpret=_interpret())


def batched_combined_lb(w, p, extra, mask=None, block_b=8, n_iters=None):
    return _combined_lb(
        w, p, extra, mask=mask, block_b=block_b, n_iters=n_iters,
        interpret=_interpret(),
    )
