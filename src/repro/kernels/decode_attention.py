"""Pallas TPU decode attention: one query token against a long KV cache.

Flash-decoding structure: grid (B·KV, n_kv_blocks) with the KV dimension
``arbitrary`` so VMEM scratch (acc, m, l) accumulates across cache blocks.
The query block is [G, D] (all group heads of one kv head); a kv-length
mask handles partially-filled caches (decode position < T_max).

Hotspot of decode_32k / long_500k cells: the entire cache streams HBM→VMEM
once, with no [1, T] score materialization in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_fwd"]

NEG_INF = -1e30


def _kernel(
    kvlen_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, bk: int, G: int, n_kv: int, scale: float,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # [G, D]
    k = k_ref[0]  # [bk, D]
    v = v_ref[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [G, bk]
    kv_len = kvlen_ref[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1) + j * bk
    s = jnp.where(cols < kv_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention_fwd(
    q: jax.Array,      # [B, H, D] single-position queries
    k: jax.Array,      # [B, T, KV, D] cache
    v: jax.Array,
    kv_len: jax.Array,  # [] or [B] valid cache length
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    from repro.kernels.ops import tpu_compiler_params

    B, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(block_kv, T)
    assert T % bk == 0
    nk = T // bk
    scale = 1.0 / np.sqrt(D)

    qf = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    kvlen_f = jnp.repeat(kv_len, KV)  # [B*KV]

    kernel = functools.partial(_kernel, bk=bk, G=G, n_kv=nk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,)),
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(kvlen_f, qf, kf, vf)
    return out.reshape(B, KV, G, D).reshape(B, H, D)
