"""Pallas TPU batched critical-path (longest path) kernel.

The inner bound evaluation of the paper's scheduler, vectorized: given a
batch of max-plus adjacency matrices w[B, n, n] (w[u, v] = edge cost
p_u + transfer(u,v), -inf when no edge), compute dist[B, n] — the longest
path from any source to each node — by n-1 Bellman relaxation rounds:

    dist[v] <- max(dist[v], max_u dist[u] + w[u, v])

Each round is a max-plus matrix-vector product, mapped to VPU broadcast
adds + row-max reductions on a [bb, n, n] VMEM block. Graphs are padded to
the TPU lane width (n <= 128) — the paper's production jobs have <= 10
tasks, so thousands of candidate assignments evaluate in one launch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["batched_critical_path"]

NEG_INF = -1e30


def _kernel(w_ref, o_ref, *, n: int, bb: int, n_iters: int):
    w = w_ref[...]  # [bb, n, n]
    dist = jnp.zeros((bb, n), jnp.float32)

    def body(_, dist):
        # cand[b, u, v] = dist[b, u] + w[b, u, v]
        cand = dist[:, :, None] + w
        return jnp.maximum(dist, jnp.max(cand, axis=1))

    dist = jax.lax.fori_loop(0, n_iters, body, dist)
    o_ref[...] = dist


@functools.partial(jax.jit, static_argnames=("block_b", "n_iters", "interpret"))
def batched_critical_path(
    w: jax.Array,  # [B, n, n] float32 max-plus adjacency (-inf = no edge)
    block_b: int = 8,
    n_iters: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """dist[B, n]: longest path into each node by Bellman relaxation rounds.

    ``n_iters`` bounds the relaxation count (default n-1, the worst-case DAG
    depth). Callers that pad graphs to a size bucket should pass the true
    depth bound so padding does not add rounds.
    """
    B, n, _ = w.shape
    if n_iters is None:
        n_iters = n - 1
    n_iters = max(0, min(n_iters, n - 1))
    bb = min(block_b, B)
    pad = (-B) % bb
    w = jnp.where(jnp.isfinite(w), w, NEG_INF).astype(jnp.float32)
    if pad:
        w = jnp.concatenate([w, jnp.full((pad, n, n), NEG_INF, jnp.float32)], 0)
    out = pl.pallas_call(
        functools.partial(_kernel, n=n, bb=bb, n_iters=n_iters),
        grid=((B + pad) // bb,),
        in_specs=[pl.BlockSpec((bb, n, n), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((bb, n), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pad, n), jnp.float32),
        interpret=interpret,
    )(w)
    return out[:B]
