"""Pallas TPU batched critical-path (longest path) and combined-LB kernels.

The inner bound evaluation of the paper's scheduler, vectorized: given a
batch of max-plus adjacency matrices w[B, n, n] (w[u, v] = edge cost
p_u + transfer(u,v), -inf when no edge), compute dist[B, n] — the longest
path from any source to each node — by n-1 Bellman relaxation rounds:

    dist[v] <- max(dist[v], max_u dist[u] + w[u, v])

Each round is a max-plus matrix-vector product, mapped to VPU broadcast
adds + row-max reductions on a [bb, n, n] VMEM block. Graphs are padded to
the TPU lane width (n <= 128) — the paper's production jobs have <= 10
tasks, so thousands of candidate assignments evaluate in one launch.

Two entry points share the relaxation loop:

  :func:`batched_critical_path` returns the raw dist[B, n] table.

  :func:`batched_combined_lb` fuses the paper's full §IV-A stage-1 bound
  into one launch: lb[b] = max(max_v dist[b, v] + p[b, v], extra[b]), where
  ``extra`` carries the contention terms (per-rack work and aggregate
  wired+wireless channel work) precomputed per batch row. Taking the max of
  the critical-path bound and the contention bounds keeps the result
  admissible — each term individually lower-bounds the makespan — while
  pruning dense instances the contention-free critical path cannot touch.
  ``p`` is per-row (heterogeneous mega-batches carry a different job per
  row), and all-padding rows (w = -inf, p = 0, extra = -inf) yield lb = 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["batched_critical_path", "batched_combined_lb"]

NEG_INF = -1e30


def _relax(w, bb: int, n: int, n_iters: int):
    """dist[bb, n] after ``n_iters`` Bellman max-plus relaxation rounds —
    the shared loop body of both kernels."""
    dist = jnp.zeros((bb, n), jnp.float32)

    def body(_, dist):
        # cand[b, u, v] = dist[b, u] + w[b, u, v]
        cand = dist[:, :, None] + w
        return jnp.maximum(dist, jnp.max(cand, axis=1))

    return jax.lax.fori_loop(0, n_iters, body, dist)


def _kernel(w_ref, o_ref, *, n: int, bb: int, n_iters: int):
    o_ref[...] = _relax(w_ref[...], bb, n, n_iters)


@functools.partial(jax.jit, static_argnames=("block_b", "n_iters", "interpret"))
def batched_critical_path(
    w: jax.Array,  # [B, n, n] float32 max-plus adjacency (-inf = no edge)
    block_b: int = 8,
    n_iters: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """dist[B, n]: longest path into each node by Bellman relaxation rounds.

    ``n_iters`` bounds the relaxation count (default n-1, the worst-case DAG
    depth). Callers that pad graphs to a size bucket should pass the true
    depth bound so padding does not add rounds.
    """
    B, n, _ = w.shape
    if n_iters is None:
        n_iters = n - 1
    n_iters = max(0, min(n_iters, n - 1))
    bb = min(block_b, B)
    pad = (-B) % bb
    w = jnp.where(jnp.isfinite(w), w, NEG_INF).astype(jnp.float32)
    if pad:
        w = jnp.concatenate([w, jnp.full((pad, n, n), NEG_INF, jnp.float32)], 0)
    out = pl.pallas_call(
        functools.partial(_kernel, n=n, bb=bb, n_iters=n_iters),
        grid=((B + pad) // bb,),
        in_specs=[pl.BlockSpec((bb, n, n), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((bb, n), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pad, n), jnp.float32),
        interpret=interpret,
    )(w)
    return out[:B]


def _lb_kernel(w_ref, p_ref, x_ref, o_ref, *, n: int, bb: int, n_iters: int):
    dist = _relax(w_ref[...], bb, n, n_iters)
    # Fused epilogue: close the path bound with the sink task's own duration
    # and fold in the precomputed contention terms (max keeps admissibility).
    lb = jnp.max(dist + p_ref[...], axis=1, keepdims=True)  # [bb, 1]
    o_ref[...] = jnp.maximum(lb, x_ref[...])


def _lb_kernel_masked(
    w_ref, p_ref, x_ref, m_ref, o_ref, *, n: int, bb: int, n_iters: int
):
    # Matching-feasibility mask, additive form: m[u, v] = 0 where the
    # optimistic (wireless-augmented) edge cost in w is reachable under the
    # topology, = the wired-minus-wireless cost uplift where it is not.
    # Adding before relaxation keeps -inf (no edge) at -inf and raises
    # infeasible network edges to their forced-wired cost.
    dist = _relax(w_ref[...] + m_ref[...], bb, n, n_iters)
    lb = jnp.max(dist + p_ref[...], axis=1, keepdims=True)  # [bb, 1]
    o_ref[...] = jnp.maximum(lb, x_ref[...])


@functools.partial(jax.jit, static_argnames=("block_b", "n_iters", "interpret"))
def batched_combined_lb(
    w: jax.Array,      # [B, n, n] float32 max-plus adjacency (-inf = no edge)
    p: jax.Array,      # [B, n] float32 per-row task durations (0 on padding)
    extra: jax.Array,  # [B] or [B, 1] float32 contention bound (-inf to disable)
    mask: jax.Array | None = None,  # [B, n, n] float32 feasibility uplift
    block_b: int = 8,
    n_iters: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """lb[B] = max(critical-path bound, contention bound) per batch row.

    The §IV-A combined stage-1 bound of the batched pruner: the Bellman
    relaxation of :func:`batched_critical_path` plus a fused epilogue that
    adds the sink task duration (max_v dist[v] + p[v]) and maxes in the
    per-row ``extra`` contention terms, so one kernel launch emits the final
    admissible bound. ``n_iters`` as in :func:`batched_critical_path`.

    ``mask`` is the topology layer's matching-feasibility mask in additive
    form: 0 where the row's placement of edge (u, v) can reach a common
    wireless subchannel (w's optimistic cost stands), and the non-negative
    forced-wired cost uplift (q - min(q, q̌)) where it cannot — the kernel
    relaxes over ``w + mask``, so infeasible picks are priced at the wired
    channel before the bound is taken. ``mask=None`` (all topologies
    unrestricted) compiles the exact pre-topology kernel, bit-identical.
    """
    B, n, _ = w.shape
    if n_iters is None:
        n_iters = n - 1
    n_iters = max(0, min(n_iters, n - 1))
    bb = min(block_b, B)
    pad = (-B) % bb
    w = jnp.where(jnp.isfinite(w), w, NEG_INF).astype(jnp.float32)
    p = p.astype(jnp.float32)
    extra = jnp.asarray(extra, jnp.float32).reshape(B, 1)
    extra = jnp.where(jnp.isfinite(extra), extra, NEG_INF)
    if mask is not None:
        mask = jnp.asarray(mask, jnp.float32)
    if pad:
        w = jnp.concatenate([w, jnp.full((pad, n, n), NEG_INF, jnp.float32)], 0)
        p = jnp.concatenate([p, jnp.zeros((pad, n), jnp.float32)], 0)
        extra = jnp.concatenate([extra, jnp.full((pad, 1), NEG_INF, jnp.float32)], 0)
        if mask is not None:
            mask = jnp.concatenate(
                [mask, jnp.zeros((pad, n, n), jnp.float32)], 0
            )
    if mask is None:
        out = pl.pallas_call(
            functools.partial(_lb_kernel, n=n, bb=bb, n_iters=n_iters),
            grid=((B + pad) // bb,),
            in_specs=[
                pl.BlockSpec((bb, n, n), lambda b: (b, 0, 0)),
                pl.BlockSpec((bb, n), lambda b: (b, 0)),
                pl.BlockSpec((bb, 1), lambda b: (b, 0)),
            ],
            out_specs=pl.BlockSpec((bb, 1), lambda b: (b, 0)),
            out_shape=jax.ShapeDtypeStruct((B + pad, 1), jnp.float32),
            interpret=interpret,
        )(w, p, extra)
    else:
        out = pl.pallas_call(
            functools.partial(_lb_kernel_masked, n=n, bb=bb, n_iters=n_iters),
            grid=((B + pad) // bb,),
            in_specs=[
                pl.BlockSpec((bb, n, n), lambda b: (b, 0, 0)),
                pl.BlockSpec((bb, n), lambda b: (b, 0)),
                pl.BlockSpec((bb, 1), lambda b: (b, 0)),
                pl.BlockSpec((bb, n, n), lambda b: (b, 0, 0)),
            ],
            out_specs=pl.BlockSpec((bb, 1), lambda b: (b, 0)),
            out_shape=jax.ShapeDtypeStruct((B + pad, 1), jnp.float32),
            interpret=interpret,
        )(w, p, extra, mask)
    return out[:B, 0]
