"""Pallas TPU flash attention (forward), GQA-aware.

Grid (B·KV, n_q_blocks, n_kv_blocks) with ``arbitrary`` semantics on the KV
dimension: VMEM scratch (acc, m, l) persists across KV steps, implementing
online softmax without materializing the [S, T] score matrix in HBM. Query
rows fold the GQA group dimension (bq queries × G group heads per block row)
so the MXU sees [bq·G, D] × [D, bk] matmuls with D = head_dim = 128-aligned.

This is the TPU-optimized twin of models/flash.py (the pure-jnp reference
with custom VJP used by the CPU dry-run); tests sweep shapes/dtypes and
assert allclose between the two in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd"]

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref,  # blocks
    acc_ref, m_ref, l_ref,       # VMEM scratch
    *, bq: int, bk: int, G: int, causal: bool, n_kv: int, scale: float,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # [bq*G, D]
    k = k_ref[0]  # [bk, D]
    v = v_ref[0]  # [bk, D]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq*G, bk]

    if causal:
        i = pl.program_id(1)
        rows = jax.lax.broadcasted_iota(jnp.int32, (bq * G, bk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bq * G, bk), 1)
        q_idx = i * bq + rows // G
        k_idx = j * bk + cols
        s = jnp.where(q_idx >= k_idx, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_kv", "interpret")
)
def flash_attention_fwd(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, T, KV, D]
    v: jax.Array,
    causal: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    from repro.kernels.ops import tpu_compiler_params

    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bk = min(block_kv, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / np.sqrt(D)

    # [B, S, KV, G, D] -> [B*KV, S*G, D] with query-major, group-minor rows.
    qf = (
        q.reshape(B, S, KV, G, D)
        .transpose(0, 2, 1, 3, 4)
        .reshape(B * KV, S * G, D)
    )
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, D)

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, G=G, causal=causal, n_kv=nk, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq * G, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq * G, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, S * G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq * G, D), jnp.float32),
            pltpu.VMEM((bq * G,), jnp.float32),
            pltpu.VMEM((bq * G,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return (
        out.reshape(B, KV, S, G, D).transpose(0, 2, 1, 3, 4).reshape(B, S, H, D)
    )
