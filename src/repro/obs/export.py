"""Exporters for :class:`~repro.obs.trace.Tracer` state.

Two render targets, both text, both dependency-free:

* :func:`chrome_trace_events` / :func:`write_chrome_trace` — the Chrome
  / Perfetto ``trace_event`` JSON format (load at ``ui.perfetto.dev`` or
  ``chrome://tracing``). Wall-clock spans become "X" complete events on
  pid 1 (one thread row per nesting depth); decision events become "i"
  instants; job lifecycle marks become async "b"/"n"/"e" tracks on pid 2
  with *simulated* time as the timestamp axis, so a job's
  arrival→admit→complete bar is its queueing delay + execution laid out
  on the serve's own clock.
* :func:`prometheus_exposition` — Prometheus text format of the metrics
  registry: counters, labelled gauges, and summary-style quantile lines
  rendered from each :class:`~repro.online.metrics.StreamingSeries`.
  Zero-sample series emit their ``_count``/``_sum`` lines but *omit*
  quantile lines (a quantile of nothing is not 0).
"""

from __future__ import annotations

import json
import math
import typing

if typing.TYPE_CHECKING:  # pragma: no cover - type-only import
    from repro.obs.trace import Tracer

__all__ = [
    "chrome_trace_events",
    "prometheus_exposition",
    "write_chrome_trace",
]

# Perfetto pids: wall-clock spans/events vs simulated-time job tracks.
PID_WALL = 1
PID_SIM = 2

_US = 1e6  # trace_event timestamps are microseconds


def _json_safe(v):
    """Coerce attr values into JSON-serializable plain types."""
    if isinstance(v, (str, int, bool)) or v is None:
        return v
    if isinstance(v, float):
        return v if math.isfinite(v) else repr(v)
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    try:  # numpy scalars expose .item()
        return _json_safe(v.item())
    except AttributeError:
        return repr(v)


def _args(attrs: dict) -> dict:
    return {str(k): _json_safe(v) for k, v in attrs.items()}


def chrome_trace_events(tracer: "Tracer") -> dict:
    """Render the tracer as a Chrome ``trace_event`` JSON object."""
    ev: list[dict] = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": PID_WALL,
            "args": {"name": "serving wall clock"},
        },
        {
            "name": "process_name",
            "ph": "M",
            "pid": PID_SIM,
            "args": {"name": "jobs (simulated time)"},
        },
    ]
    for sp in tracer.spans:
        t1 = sp.t1 if math.isfinite(sp.t1) else sp.t0
        ev.append(
            {
                "name": sp.name,
                "cat": "span",
                "ph": "X",
                "pid": PID_WALL,
                # One thread row per nesting depth keeps child spans
                # visually inside their parents without tid bookkeeping.
                "tid": sp.depth,
                "ts": sp.t0 * _US,
                "dur": max(t1 - sp.t0, 0.0) * _US,
                "args": _args(sp.attrs),
            }
        )
    for e in tracer.events:
        ev.append(
            {
                "name": e.kind,
                "cat": "decision",
                "ph": "i",
                "s": "t",
                "pid": PID_WALL,
                "tid": 0,
                "ts": e.t * _US,
                "args": _args(e.attrs),
            }
        )
    _PH = {"arrival": "b", "admit": "n", "complete": "e"}
    for m in tracer.job_marks:
        ph = _PH.get(m.phase, "n")
        ev.append(
            {
                "name": "job" if ph != "n" else m.phase,
                "cat": "job",
                "ph": ph,
                "id": m.job_id,
                "pid": PID_SIM,
                "tid": 0,
                "ts": m.t * _US,
                "args": _args(dict(m.attrs, job_id=m.job_id, phase=m.phase)),
            }
        )
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_chrome_trace(tracer: "Tracer", path) -> None:
    """Serialize :func:`chrome_trace_events` to ``path`` as JSON."""
    with open(path, "w") as f:
        json.dump(chrome_trace_events(tracer), f)


def _labels(label_items: tuple) -> str:
    if not label_items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in label_items)
    return "{" + body + "}"


def prometheus_exposition(tracer: "Tracer") -> str:
    """Render counters/gauges/series as Prometheus text exposition."""
    lines: list[str] = []
    for name in sorted(tracer.counters):
        lines.append(f"# TYPE {name} counter")
        lines.append(f"{name} {tracer.counters[name]:g}")
    seen_gauges: set[str] = set()
    for (name, labels), v in sorted(tracer.gauges.items()):
        if name not in seen_gauges:
            seen_gauges.add(name)
            lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name}{_labels(labels)} {v:g}")
    seen_series: set[str] = set()
    for (name, labels), s in sorted(tracer.series.items()):
        if name not in seen_series:
            seen_series.add(name)
            lines.append(f"# TYPE {name} summary")
        if s.count:
            for p in s.quantiles:
                items = labels + (("quantile", f"{p:g}"),)
                lines.append(f"{name}{_labels(items)} {s.quantile(p):g}")
        lines.append(f"{name}_count{_labels(labels)} {s.count}")
        # mean is NaN on an empty series; the sum of nothing is 0.
        total = s.mean * s.count if s.count else 0.0
        lines.append(f"{name}_sum{_labels(labels)} {total:g}")
    return "\n".join(lines) + "\n"
