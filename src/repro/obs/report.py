"""Offline analysis of exported serving traces.

:mod:`tools.trace_report` is a thin CLI over this module: load a
Chrome/Perfetto trace written by
:func:`repro.obs.export.write_chrome_trace` and answer the questions the
counters on :class:`~repro.online.metrics.OnlineResult` cannot — where
did each epoch's wall time go (:func:`epoch_breakdown`), which jobs were
slowest and *why* (:func:`job_table`, with the ``makespan -
solver_makespan`` channel-queueing gap split by resource), and what
decisions touched one particular job (:func:`decision_audit`).

Everything operates on the parsed JSON dict, so tests and docs snippets
can feed :func:`repro.obs.export.chrome_trace_events` output directly
without touching disk.
"""

from __future__ import annotations

import json

__all__ = [
    "commit_latency_total",
    "decision_audit",
    "epoch_breakdown",
    "job_table",
    "load_trace",
    "render_report",
    "report_dict",
]

# The three stage spans every epoch nests (see OnlineScheduler.serve).
STAGE_SPANS = ("collect_arrivals", "plan_batch", "arbitrate_and_commit")


def load_trace(path) -> dict:
    """Load a trace JSON file written by ``write_chrome_trace``."""
    with open(path) as f:
        return json.load(f)


def _span_events(trace: dict) -> "list[dict]":
    return [e for e in trace["traceEvents"] if e.get("ph") == "X"]


def epoch_breakdown(trace: dict) -> "list[dict]":
    """Per-epoch wall-time table: one row per epoch, seconds per stage.

    Rows carry ``epoch``, ``total`` (the enclosing ``epoch`` span), one
    column per stage span, and the epoch-span attrs (``t``, ``n_pending``,
    ...) that were recorded at plan time.
    """
    rows: dict[int, dict] = {}
    for e in _span_events(trace):
        args = e.get("args", {})
        if "epoch" not in args:
            continue
        k = int(args["epoch"])
        row = rows.setdefault(
            k, {"epoch": k, "total": 0.0, **{s: 0.0 for s in STAGE_SPANS}}
        )
        dur_s = e.get("dur", 0.0) / 1e6
        if e["name"] == "epoch":
            row["total"] += dur_s
            for key, v in args.items():
                if key != "epoch":
                    row.setdefault(key, v)
        elif e["name"] in STAGE_SPANS:
            row[e["name"]] += dur_s
    return [rows[k] for k in sorted(rows)]


def commit_latency_total(trace: dict) -> float:
    """Summed wall seconds of the arbitrate-and-commit stage spans.

    Reconciles with ``sum(OnlineResult.epoch_commit_latency)`` (the
    ``track_epoch_latency`` timer wraps the same call the span wraps).
    """
    return sum(
        e.get("dur", 0.0) / 1e6
        for e in _span_events(trace)
        if e["name"] == "arbitrate_and_commit"
    )


def job_table(trace: dict, top: int = 5) -> "list[dict]":
    """Top-``top`` slowest jobs by JCT, with queueing attribution.

    Each row splits the job's arrival-to-completion time into admission
    queueing (``admit - arrival``), solver makespan, and the cross-job
    channel queueing gap ``makespan - solver_makespan`` — itself split
    into wired/wireless shares when the trace recorded the attribution.
    """
    jobs: dict[int, dict] = {}
    for e in trace["traceEvents"]:
        if e.get("cat") != "job":
            continue
        args = e.get("args", {})
        jid = int(args.get("job_id", e.get("id", -1)))
        row = jobs.setdefault(jid, {"job_id": jid})
        phase = args.get("phase")
        row[phase] = e["ts"] / 1e6
        for key in (
            "makespan",
            "solver_makespan",
            "queue_wired",
            "queue_wireless",
            "family",
            "backfilled",
            "tenant",
            "tier",
        ):
            if key in args:
                row[key] = args[key]
    out = []
    for row in jobs.values():
        if "arrival" not in row or "complete" not in row:
            continue
        row["jct"] = row["complete"] - row["arrival"]
        if "admit" in row:
            row["queueing_delay"] = row["admit"] - row["arrival"]
        if "makespan" in row and "solver_makespan" in row:
            row["channel_queueing"] = row["makespan"] - row["solver_makespan"]
        out.append(row)
    out.sort(key=lambda r: (-r["jct"], r["job_id"]))
    return out[: top if top else len(out)]


def decision_audit(trace: dict, job_id: int) -> "list[dict]":
    """Every decision event and lifecycle mark that touched ``job_id``.

    An event matches when its ``job_id`` arg equals the id or any of its
    list-valued args (e.g. an arbitration ``order``) contains it.
    Returned in timestamp order as ``{"t", "kind", "args"}`` rows (``t``
    in the event's own clock: wall seconds for decisions, simulated
    seconds for lifecycle marks).
    """
    rows = []
    for e in trace["traceEvents"]:
        cat, args = e.get("cat"), e.get("args", {})
        if cat == "job":
            if int(args.get("job_id", e.get("id", -1))) == job_id:
                rows.append(
                    {"t": e["ts"] / 1e6, "kind": f"job:{args.get('phase')}",
                     "args": args}
                )
        elif cat == "decision":
            hit = args.get("job_id") == job_id or any(
                isinstance(v, list) and job_id in v for v in args.values()
            )
            if hit:
                rows.append({"t": e["ts"] / 1e6, "kind": e["name"], "args": args})
    rows.sort(key=lambda r: r["t"])
    return rows


def report_dict(
    trace: dict, top: int = 5, job: "int | None" = None
) -> dict:
    """The report as one JSON-serializable dict (machine-readable twin of
    :func:`render_report` — same per-epoch breakdown and top-k slow jobs,
    plus the commit-latency total; ``decision_audit`` rows when ``job`` is
    given). Keys: ``epochs``, ``commit_latency_s``, ``slow_jobs``, and
    optionally ``audit`` = ``{"job_id", "events"}``.
    """
    out: dict = {
        "epochs": epoch_breakdown(trace),
        "commit_latency_s": commit_latency_total(trace),
        "slow_jobs": job_table(trace, top=top),
    }
    if job is not None:
        out["audit"] = {"job_id": job, "events": decision_audit(trace, job)}
    return out


def _fmt_ms(seconds: float) -> str:
    return f"{seconds * 1e3:9.3f}ms"


def render_report(trace: dict, top: int = 5, job: "int | None" = None) -> str:
    """Human-readable report: epoch breakdown, slow jobs, optional audit."""
    lines = []
    rows = epoch_breakdown(trace)
    lines.append(f"== per-epoch latency breakdown ({len(rows)} epochs) ==")
    lines.append(
        "epoch      total    collect       plan     commit"
    )
    for r in rows:
        lines.append(
            f"{r['epoch']:5d} {_fmt_ms(r['total'])} "
            f"{_fmt_ms(r['collect_arrivals'])} {_fmt_ms(r['plan_batch'])} "
            f"{_fmt_ms(r['arbitrate_and_commit'])}"
        )
    total = sum(r["total"] for r in rows)
    commit = commit_latency_total(trace)
    lines.append(f"total epoch wall {total:.4f}s  (commit stage {commit:.4f}s)")
    lines.append("")
    lines.append(f"== top {top} slowest jobs ==")
    for r in job_table(trace, top=top):
        parts = [f"job {r['job_id']:6d}  jct={r['jct']:9.2f}"]
        if "queueing_delay" in r:
            parts.append(f"queue={r['queueing_delay']:8.2f}")
        if "channel_queueing" in r:
            cq = f"channel={r['channel_queueing']:7.2f}"
            if "queue_wired" in r or "queue_wireless" in r:
                cq += (
                    f" (wired={r.get('queue_wired', 0.0):.2f}"
                    f" wireless={r.get('queue_wireless', 0.0):.2f})"
                )
            parts.append(cq)
        if r.get("backfilled"):
            parts.append("backfilled")
        if r.get("family"):
            parts.append(str(r["family"]))
        lines.append("  ".join(parts))
    if job is not None:
        lines.append("")
        lines.append(f"== decision audit for job {job} ==")
        audit = decision_audit(trace, job)
        if not audit:
            lines.append("(no events recorded for this job id)")
        for r in audit:
            args = {k: v for k, v in r["args"].items() if k != "phase"}
            lines.append(f"t={r['t']:12.4f}  {r['kind']:22s} {args}")
    return "\n".join(lines)
