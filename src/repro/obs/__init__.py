"""Structured tracing and metrics export for the serving loop and solver.

Public surface:

* :class:`~repro.obs.trace.Tracer` / :class:`~repro.obs.trace.NullTracer`
  — nested wall-time spans, typed decision events, per-job lifecycle
  marks, counters/gauges/histograms.
* :func:`~repro.obs.export.write_chrome_trace` /
  :func:`~repro.obs.export.chrome_trace_events` — Chrome/Perfetto
  ``trace_event`` JSON.
* :func:`~repro.obs.export.prometheus_exposition` — Prometheus text
  format of the metrics registry.
* :mod:`repro.obs.report` — offline per-epoch / per-job analysis
  (``tools/trace_report.py`` is its CLI).
"""

from repro.obs.export import (
    chrome_trace_events,
    prometheus_exposition,
    write_chrome_trace,
)
from repro.obs.trace import (
    NULL_TRACER,
    Event,
    JobMark,
    NullTracer,
    Span,
    Tracer,
    as_tracer,
)

__all__ = [
    "Event",
    "JobMark",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "as_tracer",
    "chrome_trace_events",
    "prometheus_exposition",
    "write_chrome_trace",
]
