"""Zero-dependency structured tracing for the serving loop and solver.

The serving stack makes layered decisions per epoch — admission ordering,
coflow commit-order search, backfill proofs, portfolio budget splits —
and until now each layer only surfaced aggregate counters on
:class:`~repro.online.metrics.OnlineResult`. This module records the
*structure*: nested wall-time spans (epoch → collect/plan/commit), typed
decision events at every admission/arbitration/backfill branch, per-job
lifecycle marks in simulated time, and a small metrics registry
(counters, gauges, :class:`~repro.online.metrics.StreamingSeries`
histograms) that :mod:`repro.obs.export` renders as a Chrome/Perfetto
trace and a Prometheus-style text exposition.

Everything is plain Python on the host — no jax, no I/O — so a traced
serve differs from an untraced one only by appending records to lists.
The default is :data:`NULL_TRACER`, whose every method is a no-op and
whose ``span`` returns a shared reusable context manager, so passing
``tracer=None`` anywhere keeps the hot loop bit-identical at negligible
overhead (the stress lane asserts < 2%). Instrumented call sites guard
any *extra computation* (not just the record) behind ``tracer.enabled``.
"""

from __future__ import annotations

import dataclasses
import time
import typing

if typing.TYPE_CHECKING:  # pragma: no cover - type-only import
    from repro.online.metrics import StreamingSeries

__all__ = [
    "Event",
    "JobMark",
    "NullTracer",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "as_tracer",
]


@dataclasses.dataclass
class Span:
    """One closed (or still-open) wall-time interval.

    ``t0``/``t1`` are seconds relative to the tracer's epoch
    (``Tracer.t0``); ``t1`` is NaN until the span exits. ``parent`` is
    the index of the enclosing span in ``Tracer.spans`` (-1 at top
    level), so the hierarchy is reconstructible offline.
    """

    name: str
    t0: float
    t1: float
    depth: int
    parent: int
    index: int
    attrs: dict

    @property
    def duration(self) -> float:
        """Wall seconds spent inside the span (NaN while open)."""
        return self.t1 - self.t0


@dataclasses.dataclass(frozen=True)
class Event:
    """One typed point-in-time decision record (wall-clock ``t``)."""

    kind: str
    t: float
    span: int
    attrs: dict


@dataclasses.dataclass(frozen=True)
class JobMark:
    """One job-lifecycle phase transition in *simulated* time.

    ``phase`` is one of ``"arrival"`` / ``"admit"`` / ``"complete"``;
    the exporter renders the marks of one ``job_id`` as an async track.
    """

    job_id: int
    phase: str
    t: float
    attrs: dict


class _SpanCtx:
    """Context manager handed out by :meth:`Tracer.span`.

    Reused objects are cheap but spans nest, so each ``span()`` call
    builds a fresh one; the :class:`NullTracer` instead hands out one
    shared no-op instance forever.
    """

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> "_SpanCtx":
        return self

    def __exit__(self, *exc) -> None:
        tr = self._tracer
        self._span.t1 = time.perf_counter() - tr.t0
        tr._stack.pop()

    def set(self, **attrs) -> None:
        """Attach attributes discovered while the span is running."""
        self._span.attrs.update(attrs)

    @property
    def duration(self) -> float:
        """Wall seconds of the span (valid after exit; NaN while open)."""
        return self._span.duration


class _NullSpanCtx:
    """The shared no-op span context (singleton via :class:`NullTracer`)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpanCtx":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **attrs) -> None:
        return None

    @property
    def duration(self) -> float:
        return 0.0


class Tracer:
    """Collects spans, events, job marks, and scalar metrics in memory.

    All timestamps are ``time.perf_counter()`` seconds relative to the
    tracer's construction (``t0``), so exported traces start near zero.
    The metrics registry is deliberately tiny: ``counters`` are plain
    monotonically-growing floats, ``gauges`` hold the last value set,
    and ``series`` maps ``(name, labels)`` to a
    :class:`~repro.online.metrics.StreamingSeries` — the same O(1)
    sketch the serving layer already uses — so histogram state stays
    bounded on 100k-job serves.
    """

    enabled: bool = True

    def __init__(self) -> None:
        self.t0 = time.perf_counter()
        self.spans: list[Span] = []
        self.events: list[Event] = []
        self.job_marks: list[JobMark] = []
        self.counters: dict[str, float] = {}
        self.gauges: dict[tuple[str, tuple], float] = {}
        self.series: dict[tuple[str, tuple], StreamingSeries] = {}
        self._stack: list[int] = []

    # -- spans / events / job marks ------------------------------------

    def span(self, name: str, **attrs) -> _SpanCtx:
        """Open a nested wall-time span; use as a context manager."""
        sp = Span(
            name=name,
            t0=time.perf_counter() - self.t0,
            t1=float("nan"),
            depth=len(self._stack),
            parent=self._stack[-1] if self._stack else -1,
            index=len(self.spans),
            attrs=attrs,
        )
        self.spans.append(sp)
        self._stack.append(sp.index)
        return _SpanCtx(self, sp)

    def event(self, kind: str, **attrs) -> None:
        """Record a typed decision event at the current wall time."""
        self.events.append(
            Event(
                kind=kind,
                t=time.perf_counter() - self.t0,
                span=self._stack[-1] if self._stack else -1,
                attrs=attrs,
            )
        )

    def job(self, job_id: int, phase: str, sim_time: float, **attrs) -> None:
        """Record a job lifecycle mark at simulated time ``sim_time``."""
        self.job_marks.append(
            JobMark(job_id=int(job_id), phase=phase, t=float(sim_time), attrs=attrs)
        )

    # -- metrics registry ----------------------------------------------

    @staticmethod
    def _key(name: str, labels: dict) -> tuple[str, tuple]:
        return name, tuple(sorted(labels.items()))

    def count(self, name: str, inc: float = 1.0) -> None:
        """Increment a monotone counter."""
        self.counters[name] = self.counters.get(name, 0.0) + inc

    def gauge(self, name: str, value: float, **labels) -> None:
        """Set a gauge to its latest value (labelled)."""
        self.gauges[self._key(name, labels)] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        """Push one observation into a labelled histogram series."""
        # Local import: repro.online.service/cluster import this module,
        # so a top-level metrics import would cycle through the package
        # __init__ when repro.obs loads first.
        from repro.online.metrics import StreamingSeries

        key = self._key(name, labels)
        s = self.series.get(key)
        if s is None:
            s = self.series[key] = StreamingSeries()
        s.push(value)

    def adopt_series(self, name: str, series: "StreamingSeries", **labels) -> None:
        """Register an existing series (e.g. a per-tenant sketch) by ref."""
        self.series[self._key(name, labels)] = series

    # -- convenience ---------------------------------------------------

    def spans_named(self, name: str) -> "list[Span]":
        return [s for s in self.spans if s.name == name]

    def events_of(self, kind: str) -> "list[Event]":
        return [e for e in self.events if e.kind == kind]


class NullTracer:
    """No-op tracer: every method returns immediately.

    ``enabled`` is False so call sites can skip computing span/event
    *arguments* entirely; ``span()`` returns one shared context manager
    whose enter/exit do nothing, keeping per-epoch overhead to a couple
    of attribute lookups.
    """

    enabled: bool = False
    _CTX = _NullSpanCtx()

    def span(self, name: str, **attrs) -> _NullSpanCtx:
        return self._CTX

    def event(self, kind: str, **attrs) -> None:
        return None

    def job(self, job_id: int, phase: str, sim_time: float, **attrs) -> None:
        return None

    def count(self, name: str, inc: float = 1.0) -> None:
        return None

    def gauge(self, name: str, value: float, **labels) -> None:
        return None

    def observe(self, name: str, value: float, **labels) -> None:
        return None

    def adopt_series(self, name: str, series: "StreamingSeries", **labels) -> None:
        return None


NULL_TRACER = NullTracer()


def as_tracer(tracer: "Tracer | NullTracer | None") -> "Tracer | NullTracer":
    """Normalize an optional tracer argument (``None`` → the null tracer)."""
    return NULL_TRACER if tracer is None else tracer
