"""Serving launcher: batched KV-cache decode through the production sharding
(the program the decode_32k / long_500k dry-run cells compile).

  python -m repro.launch.serve --arch llama3.2-3b --batch 4 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.layers import activation_sharding
from repro.distribution.sharding import activation_rules
from repro.models.lm import build_model
from repro.runtime.steps import build_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (
        make_production_mesh() if len(jax.devices()) >= 256 else make_local_mesh()
    )
    model = build_model(cfg)
    with activation_sharding(activation_rules(mesh)), mesh:
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        memory = None
        if cfg.n_enc_layers:
            frames = jnp.asarray(
                rng.standard_normal((args.batch, args.prompt, cfg.d_model)),
                jnp.float32,
            )
            memory = model.encode(params, frames)
        elif cfg.cross_attn_every:
            memory = jnp.asarray(
                rng.standard_normal((args.batch, 16, cfg.d_model)), jnp.float32
            )
        cache = model.init_cache(args.batch, args.prompt + args.gen + 1, memory=memory)
        serve = jax.jit(build_serve_step(model), donate_argnums=(1,))

        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt)), jnp.int32
        )
        for t in range(args.prompt):
            logits, cache = serve(params, cache, prompts[:, t])
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        outs = [tok]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            logits, cache = serve(params, cache, outs[-1])
            outs.append(jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32))
        dt = time.perf_counter() - t0
        print(f"generated {args.batch}x{args.gen} tokens "
              f"({args.batch * args.gen / dt:.1f} tok/s)")
        print(np.asarray(jnp.stack(outs, axis=1)))


if __name__ == "__main__":
    main()
