"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — smoke tests must keep seeing 1 CPU device.

Target hardware: TPU v5e pods. Single pod = 16x16 = 256 chips
(axes data x model); multi-pod = 2 pods x 256 chips with the leading 'pod'
axis mapped onto the DCN/OCS inter-pod fabric (the paper's reconfigurable
"wireless" augmentation layer — see DESIGN.md §2).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)}; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older make_mesh without devices kwarg
        arr = np.asarray(devices[:n]).reshape(shape)
        return Mesh(arr, axes)


def make_local_mesh(model: int = 1) -> Mesh:
    """1-device mesh for smoke tests and examples on CPU."""
    arr = np.asarray(jax.devices()[:model]).reshape((1, model))
    return Mesh(arr, ("data", "model"))
