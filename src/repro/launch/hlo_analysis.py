"""Post-SPMD HLO cost analyzer with while-loop trip-count multiplication.

XLA's backend ``cost_analysis()`` counts while-loop bodies ONCE (verified in
EXPERIMENTS.md §Dry-run), which under-counts scanned programs by orders of
magnitude — our step functions nest up to four scans (microbatch × layer ×
flash-KV-block × loss-chunk). This analyzer parses the optimized
(per-partition) HLO text with a real instruction parser (symbol table per
computation, tuple shapes, operand lookup) and recursively multiplies
through while-loop trip counts, producing:

  * flops            — exact for dot (2·|out|·K from contracting dims)
  * collective_bytes — exact per collective kind (output-shape bytes)
  * hbm_bytes        — proxy: every materialized (non-fused) buffer written
                       + read once (2× output bytes)

Trip counts come from the while op's ``known_trip_count`` backend config
(present in scheduled XLA output), falling back to the loop-condition
comparison constant.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

__all__ = ["analyze_hlo", "xla_cost_analysis", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "u1": 1, "s1": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_TRIVIAL = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
    "iota", "copy",
}


# --------------------------------------------------------------------------
# Shapes
# --------------------------------------------------------------------------

def _parse_shape(s: str) -> Any:
    """Parse 'bf16[2,3]{1,0}' or '(s32[], f32[64,64]{1,0})' -> shape tree."""
    s = s.strip()
    if s.startswith("("):
        inner = s[1:-1] if s.endswith(")") else s[1:]
        parts, depth, cur = [], 0, []
        for ch in inner:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append("".join(cur))
                cur = []
            else:
                cur.append(ch)
        if cur:
            parts.append("".join(cur))
        return ("tuple", [_parse_shape(p) for p in parts if p.strip()])
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", s)
    if not m:
        return ("array", "s32", ())
    dt, dims = m.group(1), m.group(2)
    shape = tuple(int(d) for d in dims.split(",") if d)
    return ("array", dt, shape)


def _elems(shape: Any) -> float:
    if shape[0] == "tuple":
        return sum(_elems(s) for s in shape[1])
    n = 1.0
    for d in shape[2]:
        n *= d
    return n


def _bytes(shape: Any) -> float:
    if shape[0] == "tuple":
        return sum(_bytes(s) for s in shape[1])
    n = 1.0
    for d in shape[2]:
        n *= d
    return n * _DTYPE_BYTES.get(shape[1], 0)


# --------------------------------------------------------------------------
# Instruction parsing
# --------------------------------------------------------------------------

_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=")


@dataclasses.dataclass
class Instr:
    name: str
    shape: Any
    op: str
    operands: list[str]
    attrs: str


def _split_top(s: str) -> list[str]:
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def _parse_instr(line: str) -> Instr | None:
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[line.find("=") + 1 :].strip()
    # Output shape: tuple (balanced parens) or typed array shape.
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape = _parse_shape(rest[: i + 1])
                    rest = rest[i + 1 :].strip()
                    break
        else:
            return None
    else:
        sm = re.match(r"([a-z0-9]+\[[0-9,]*\])(\{[^}]*\})?\s*", rest)
        if not sm:
            return None
        shape = _parse_shape(sm.group(1))
        rest = rest[sm.end() :]
    om = re.match(r"([\w\-]+)\s*\(", rest)
    if not om:
        return None
    op = om.group(1)
    # operand list: balanced parens after op name
    start = om.end() - 1
    depth = 0
    end = start
    for i in range(start, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args = _split_top(rest[start + 1 : end])
    operands = []
    for a in args:
        am = re.match(r"(?:[a-z0-9]+\[[0-9,]*\]\S*\s+)?%?([\w\.\-]+)", a)
        operands.append(am.group(1) if am else a)
    attrs = rest[end + 1 :]
    return Instr(name=name, shape=shape, op=op, operands=operands, attrs=attrs)


def _split_computations(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: str | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            if line.endswith("{") and "->" in line:
                hm = _HEADER_RE.match(line)
                if hm:
                    cur = hm.group(1)
                    comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            comps[cur].append(ins)
    return comps


def _entry_name(text: str) -> str | None:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
    return m.group(1) if m else None


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_S32_CONST_RE = re.compile(r"constant\((\d+)\)")


# --------------------------------------------------------------------------
# Cost accumulation
# --------------------------------------------------------------------------

@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "HloCost") -> "HloCost":
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "HloCost":
        return HloCost(
            flops=self.flops * m,
            hbm_bytes=self.hbm_bytes * m,
            collective_bytes={k: v * m for k, v in self.collective_bytes.items()},
        )

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _dot_flops(ins: Instr, symtab: dict[str, Any]) -> float:
    out_elems = _elems(ins.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    lhs_shape = symtab.get(ins.operands[0]) if ins.operands else None
    if not m or lhs_shape is None or lhs_shape[0] != "array":
        return 2.0 * out_elems
    k = 1.0
    dims = lhs_shape[2]
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(dims):
            k *= dims[idx]
    return 2.0 * out_elems * k


def _called(ins: Instr) -> list[str]:
    out = []
    for key in ("calls", "body", "condition", "to_apply", "branch_computations"):
        for m in re.finditer(rf"{key}=\{{?([^,\s}}]+(?:,\s*[^,\s}}]+)*)\}}?", ins.attrs):
            for name in m.group(1).split(","):
                out.append(name.strip().lstrip("%"))
    return out


def xla_cost_analysis(compiled: Any) -> dict[str, float]:
    """XLA's own ``Compiled.cost_analysis()``, normalized across JAX versions.

    Older releases return a per-partition ``[dict]`` list, newer ones a flat
    dict. Always returns a (possibly empty) dict so callers can compare the
    backend numbers against :func:`analyze_hlo` — which multiplies through
    while-loop trip counts where XLA's analysis counts loop bodies once.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    entry = _entry_name(text)
    if entry is None or entry not in comps:
        entry = next(iter(comps), None)
        if entry is None:
            return HloCost()

    # Symbol tables (op name -> shape) per computation, with gte resolution.
    symtabs: dict[str, dict[str, Any]] = {}
    for cname, instrs in comps.items():
        tab: dict[str, Any] = {}
        for ins in instrs:
            tab[ins.name] = ins.shape
        symtabs[cname] = tab

    memo: dict[tuple[str, bool], HloCost] = {}
    visiting: set[str] = set()

    def trip_count(ins: Instr) -> float:
        m = _TRIP_RE.search(ins.attrs)
        if m:
            return float(m.group(1))
        cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
        if cm and cm.group(1) in comps:
            best = 1.0
            for ci in comps[cm.group(1)]:
                if ci.op == "constant" and ci.operands:
                    try:
                        best = max(best, float(ci.operands[0]))
                    except ValueError:
                        pass
            return best
        return 1.0

    def analyze(cname: str, fused: bool) -> HloCost:
        key = (cname, fused)
        if key in memo:
            return memo[key]
        if cname in visiting or cname not in comps:
            return HloCost()
        visiting.add(cname)
        cost = HloCost()
        tab = symtabs[cname]
        for ins in comps[cname]:
            if ins.op == "while":
                trips = trip_count(ins)
                inner = HloCost()
                for sub in _called(ins):
                    inner += analyze(sub, fused)
                cost += inner.scaled(trips)
                continue
            if ins.op in ("fusion", "call", "custom-call", "reduce", "sort",
                          "map", "scatter", "select-and-scatter",
                          "reduce-window", "conditional", "all-reduce",
                          "reduce-scatter"):
                inner_fused = fused or ins.op == "fusion"
                for sub in _called(ins):
                    cost += analyze(sub, inner_fused)
            if ins.op == "dot":
                cost.flops += _dot_flops(ins, tab)
            elif ins.op == "convolution":
                cost.flops += 2.0 * _elems(ins.shape)
            if ins.op in _COLLECTIVES:
                b = _bytes(ins.shape)
                cost.collective_bytes[ins.op] = (
                    cost.collective_bytes.get(ins.op, 0.0) + b
                )
            if not fused and ins.op not in _TRIVIAL:
                if ins.op == "dot":
                    # write output + READ both operands: weight re-reads
                    # inside loops are real HBM traffic (a dot re-reading a
                    # loop-invariant weight every iteration pays every time).
                    cost.hbm_bytes += _bytes(ins.shape)
                    for opr in ins.operands[:2]:
                        oshape = tab.get(opr)
                        if oshape is not None:
                            cost.hbm_bytes += _bytes(oshape)
                elif ins.op in ("dynamic-slice", "gather"):
                    # DMA reads only the slice, not the source buffer.
                    cost.hbm_bytes += 2.0 * _bytes(ins.shape)
                else:
                    cost.hbm_bytes += 2.0 * _bytes(ins.shape)
        visiting.discard(cname)
        memo[key] = cost
        return cost

    return analyze(entry, False)
