import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, and extract roofline terms from the compiled HLO.

The two lines above MUST precede every other import (JAX locks the device
count at first initialization).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out EXPERIMENTS/dryrun.json
"""

import argparse
import dataclasses
import json
import re
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.distribution.sharding import (
    activation_rules,
    batch_sharding,
    cache_sharding,
    param_sharding,
    state_sharding,
)
from repro.launch.mesh import make_production_mesh
from repro.models.config import ModelConfig
from repro.models.layers import activation_sharding
from repro.models.lm import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.steps import (
    TrainState,
    build_prefill_step,
    build_serve_step,
    build_train_step,
    make_train_state,
)

# --------------------------------------------------------------------------
# Input-shape matrix (assignment): seq_len × global_batch per shape id.
# --------------------------------------------------------------------------
SHAPES: dict[str, dict[str, Any]] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

# long_500k runs only for sub-quadratic-capable families (DESIGN.md §4).
LONG_OK_FAMILIES = ("ssm", "hybrid")

# Hardware constants (TPU v5e, per chip).
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link


def skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    if shape == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
        return f"full-attention family '{cfg.family}' is quadratic at 500k (DESIGN.md §4)"
    return None


# --------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input.
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_id: str) -> dict[str, jax.ShapeDtypeStruct]:
    info = SHAPES[shape_id]
    B, S = info["batch"], info["seq"]
    sds = jax.ShapeDtypeStruct
    batch: dict[str, jax.ShapeDtypeStruct] = {}
    if info["kind"] in ("train",):
        batch["tokens"] = sds((B, S), jnp.int32)
        batch["labels"] = sds((B, S), jnp.int32)
    elif info["kind"] == "prefill":
        batch["tokens"] = sds((B, S), jnp.int32)
    if cfg.n_enc_layers or cfg.cross_attn_every:
        T = S if cfg.n_enc_layers else cfg.n_patches
        if info["kind"] != "decode":
            batch["memory"] = sds((B, T, cfg.d_model), jnp.float32)
    return batch


def _micro(cfg: ModelConfig, mesh, global_batch: int) -> int:
    """Microbatch count: 1 batch row per device per microbatch for big
    models, up to 4 rows for small ones."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    rows = 1 if cfg.d_model >= 4096 else 4
    n = max(1, global_batch // (dp * rows))
    while global_batch % n or (global_batch // n) % dp:
        n -= 1
    return max(n, 1)


# --------------------------------------------------------------------------
# Roofline extraction
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skip: str | None = None
    error: str | None = None
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: dict[str, float] = dataclasses.field(default_factory=dict)
    peak_memory_per_device: float = 0.0
    model_flops: float = 0.0
    n_params: float = 0.0
    n_active_params: float = 0.0
    compile_s: float = 0.0
    terms: dict[str, float] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _analytic_params(shapes_tree) -> float:
    return float(
        sum(np.prod(x.shape) for x in jax.tree.leaves(shapes_tree))
    )


def model_flops_estimate(cfg: ModelConfig, n_params: float, kind: str,
                         batch: int, seq: int) -> float:
    """6·N_active·D (train) or 2·N_active·D (inference)."""
    from repro.models.config import layer_kinds

    n_active = n_params
    if cfg.n_experts:
        kinds = layer_kinds(cfg)
        moe_layers = sum(1 for _, f in kinds if f == "moe")
        per_expert = 3 * cfg.d_model * cfg.d_ff
        n_active = n_params - moe_layers * (
            (cfg.n_experts - cfg.experts_per_token) * per_expert
        )
    tokens = batch * seq if kind != "decode" else batch  # one token per decode
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


def run_cell(arch: str, shape_id: str, multi_pod: bool) -> CellResult:
    cfg = get_config(arch)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    res = CellResult(arch=arch, shape=shape_id, mesh=mesh_name, ok=False)
    reason = skip_reason(cfg, shape_id)
    if reason:
        res.skip = reason
        res.ok = True
        return res

    info = SHAPES[shape_id]
    B, S = info["batch"], info["seq"]
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    rules = activation_rules(mesh)
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)

    batch_specs = input_specs(cfg, shape_id)
    params_shapes = jax.eval_shape(model.init, rng)
    n_params = _analytic_params(params_shapes)
    res.n_params = n_params
    res.model_flops = model_flops_estimate(cfg, n_params, info["kind"], B, S)

    with activation_sharding(rules):
        if info["kind"] == "train":
            n_micro = int(os.environ.get("REPRO_NMICRO", 0)) or _micro(cfg, mesh, B)
            opt_cfg = AdamWConfig()
            step = build_train_step(
                model,
                opt_cfg,
                n_micro=n_micro,
                cast_params_bf16=os.environ.get("REPRO_CAST_BF16", "0") == "1",
            )
            state_shapes = jax.eval_shape(
                lambda r: make_train_state(model, r), rng
            )
            in_sh = (
                state_sharding(state_shapes, mesh),
                batch_sharding(batch_specs, mesh),
            )
            lowered = jax.jit(
                step,
                in_shardings=in_sh,
                out_shardings=(in_sh[0], None),
                donate_argnums=(0,),  # alias state in/out — halves state HBM
            ).lower(state_shapes, batch_specs)
        elif info["kind"] == "prefill":
            step = build_prefill_step(model)
            in_sh = (
                param_sharding(params_shapes, mesh),
                batch_sharding(batch_specs, mesh),
            )
            lowered = jax.jit(step, in_shardings=in_sh).lower(
                params_shapes, batch_specs
            )
        else:  # decode
            step = build_serve_step(model)
            mem_struct = None
            if cfg.n_enc_layers or cfg.cross_attn_every:
                T = S if cfg.n_enc_layers else cfg.n_patches
                mem_struct = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.float32)
            cache_shapes = jax.eval_shape(
                lambda m: model.init_cache(B, S, memory=m), mem_struct
            )
            token = jax.ShapeDtypeStruct((B,), jnp.int32)
            in_sh = (
                param_sharding(params_shapes, mesh),
                cache_sharding(cache_shapes, mesh),
                batch_sharding(token, mesh),
            )
            lowered = jax.jit(
                step,
                in_shardings=in_sh,
                out_shardings=(None, in_sh[1]),
                donate_argnums=(1,),  # alias cache in/out
            ).lower(params_shapes, cache_shapes, token)

        compiled = lowered.compile()

    res.compile_s = time.perf_counter() - t0
    # XLA's cost_analysis does not multiply while-loop trip counts (verified
    # in EXPERIMENTS.md §Dry-run), so we analyze the optimized per-partition
    # HLO ourselves. All counts below are PER DEVICE.
    from repro.launch.hlo_analysis import analyze_hlo

    cost = analyze_hlo(compiled.as_text())
    res.flops = cost.flops
    res.bytes_accessed = cost.hbm_bytes
    res.coll_bytes = dict(cost.collective_bytes)
    try:
        ma = compiled.memory_analysis()
        res.peak_memory_per_device = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        res.peak_memory_per_device = 0.0

    chips = 512 if multi_pod else 256
    total_coll = sum(res.coll_bytes.values())
    # Counts are per-device (post-SPMD module), so divide by per-chip rates.
    res.terms = {
        "compute_s": res.flops / PEAK_FLOPS,
        "memory_s": res.bytes_accessed / HBM_BW,
        "collective_s": total_coll / ICI_BW,
        "useful_flops_ratio": (
            (res.model_flops / chips) / res.flops if res.flops else 0.0
        ),
    }
    res.ok = True
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [ALIASES.get(args.arch, args.arch).replace("-", "_").replace(".", "_")]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    results = []
    for a, s, mp in cells:
        try:
            r = run_cell(a, s, mp)
        except Exception as e:  # noqa: BLE001 — report, keep going
            r = CellResult(
                arch=a, shape=s, mesh="2x16x16" if mp else "16x16",
                ok=False, error=f"{type(e).__name__}: {e}",
            )
        results.append(r)
        status = "SKIP" if r.skip else ("OK" if r.ok else "FAIL")
        print(
            f"[{status}] {r.arch:22s} {r.shape:12s} {r.mesh:8s} "
            f"flops={r.flops:.3e} bytes={r.bytes_accessed:.3e} "
            f"coll={sum(r.coll_bytes.values()):.3e} mem/dev={r.peak_memory_per_device/2**30:.2f}GiB "
            f"compile={r.compile_s:.1f}s"
            + (f" err={r.error}" if r.error else "")
            + (f" skip={r.skip}" if r.skip else ""),
            flush=True,
        )
    if args.out:
        with open(args.out, "w") as f:
            json.dump([r.to_json() for r in results], f, indent=1)
    nfail = sum(1 for r in results if not r.ok)
    print(f"\n{len(results) - nfail}/{len(results)} cells passed")
    raise SystemExit(1 if nfail else 0)


if __name__ == "__main__":
    main()
