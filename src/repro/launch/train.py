"""Production training launcher.

Builds the (arch, mesh) training program the dry-run proves out:
  * mesh from launch.mesh (single- or multi-pod),
  * NamedShardings from distribution.sharding,
  * scheduler-planned gradient-reduction schedule from distribution.plan,
  * checkpoint/restart via checkpoint.ckpt (resume is automatic),
  * deterministic restartable data from data.pipeline.

On real hardware this is the entry point per host:
  python -m repro.launch.train --arch llama3.2-3b --steps 1000 ...
On this CPU container, use --smoke to run the reduced config end to end.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distribution.plan import LinkSpec, backward_profile, plan_gradient_schedule
from repro.distribution.sharding import activation_rules, batch_sharding, state_sharding
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.layers import activation_sharding
from repro.models.lm import build_model, count_params
from repro.optim.adamw import AdamWConfig
from repro.runtime.steps import build_train_step, make_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    mesh = (
        make_production_mesh(multi_pod=args.multi_pod)
        if n_dev >= 256
        else make_local_mesh()
    )
    print(f"mesh: {dict(mesh.shape)}  devices={n_dev}")

    model = build_model(cfg)
    opt_cfg = AdamWConfig(total_steps=args.steps)
    step_fn = build_train_step(
        model, opt_cfg, n_micro=args.n_micro, compress_grads=args.compress_grads
    )

    # Scheduler-planned reduction schedule (logged; on hardware this feeds
    # the collective-stream assignment).
    g_secs, g_bytes = backward_profile(
        cfg, tokens_per_device=args.global_batch * args.seq
    )
    plan = plan_gradient_schedule(g_secs, g_bytes, LinkSpec(), time_limit=2.0)
    print(
        f"reduction plan: gain_vs_serial={100 * plan.gain_vs_serial:.1f}% "
        f"channels={plan.channel_of_bucket.tolist()}"
    )

    rules = activation_rules(mesh)
    with activation_sharding(rules), mesh:
        state = make_train_state(
            model, jax.random.PRNGKey(0), compress=args.compress_grads
        )
        st_sh = state_sharding(jax.eval_shape(lambda: state), mesh)
        state = jax.device_put(state, st_sh)
        print(f"params: {count_params(state.params):,}")

        data = make_pipeline(
            DataConfig(
                vocab_size=cfg.vocab_size,
                global_batch=args.global_batch,
                seq_len=args.seq,
                memory_len=args.seq if cfg.n_enc_layers else (
                    cfg.n_patches if cfg.cross_attn_every else 0
                ),
                d_model=cfg.d_model,
            )
        )
        start = 0
        if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
            restored, start = ckpt.restore(
                args.ckpt_dir, jax.tree.map(np.asarray, state)
            )
            state = jax.device_put(jax.tree.map(jnp.asarray, restored), st_sh)
            print(f"resumed at step {start}")

        jstep = jax.jit(step_fn, donate_argnums=(0,))
        for s in range(start, args.steps):
            batch = {
                k: jnp.asarray(v) for k, v in data.batch_for_step(s).items()
            }
            batch = jax.device_put(batch, batch_sharding(batch, mesh))
            state, metrics = jstep(state, batch)
            if s % 10 == 0 or s == args.steps - 1:
                print(
                    f"step {s:5d} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f}"
                )
            if args.ckpt_dir and s and s % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, s, jax.tree.map(np.asarray, state))


if __name__ == "__main__":
    main()
