"""AdamW from scratch: decoupled weight decay, global-norm clipping, cosine
schedule with linear warmup. State is a pytree mirroring params (ZeRO-1 style
sharding falls out of using the same NamedSharding as the parameters).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule", "global_norm"]

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_init(params: Params) -> dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def adamw_update(
    cfg: AdamWConfig,
    params: Params,
    grads: Params,
    state: dict[str, Any],
) -> tuple[Params, dict[str, Any], dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = cosine_schedule(cfg, step)

    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1t
        vh = v / b2t
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
